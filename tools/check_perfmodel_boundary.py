#!/usr/bin/env python
"""Boundary lint for the perfmodel subsystem (DESIGN.md §13, satellite 5).

``repro.core.heuristic`` is a deprecation shim: every ``*_cost``/``*_bytes``
function it re-exports actually lives in ``repro.perfmodel``.  Existing
imports keep working (that is the point of the shim), but NEW code must not
grow fresh dependencies on the deprecated spelling — consumers go through
``repro.perfmodel`` (or a ``CostModel``) so the subsystem keeps one front
door.

This lint walks the ASTs of ``src/`` and ``benchmarks/`` and fails on:

  * ``from repro.core.heuristic import <any *_cost / *_bytes name>``
  * ``from repro.core import <any *_cost / *_bytes name>`` (the package
    re-exports the shim's names)
  * attribute uses ``heuristic.<*_cost|*_bytes>`` / ``H.<...>`` where the
    name was bound by ``from repro.core import heuristic [as H]``

Allowlisted: the perfmodel package itself, the shim, and ``core/__init__``
(whose whole job is re-exporting the legacy surface).  ``tests/`` is NOT
scanned — the suite deliberately exercises the shim's backward
compatibility.
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "benchmarks")
ALLOW = {
    ROOT / "src" / "repro" / "core" / "heuristic.py",
    ROOT / "src" / "repro" / "core" / "__init__.py",
}
ALLOW_DIRS = (ROOT / "src" / "repro" / "perfmodel",)

SHIM_MODULES = ("repro.core.heuristic", "repro.core")


def _is_cost_name(name: str) -> bool:
    return name.endswith("_cost") or name.endswith("_bytes")


def _check_file(path: Path) -> list:
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:
        return [(path, e.lineno or 0, f"syntax error: {e.msg}")]
    problems = []
    heuristic_aliases = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module in SHIM_MODULES:
            for a in node.names:
                if a.name == "heuristic":
                    heuristic_aliases.add(a.asname or a.name)
                elif _is_cost_name(a.name):
                    problems.append((
                        path, node.lineno,
                        f"'from {node.module} import {a.name}' — import it "
                        f"from repro.perfmodel instead"))
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "repro.core.heuristic":
                    heuristic_aliases.add(
                        a.asname or "repro.core.heuristic")
    if heuristic_aliases:
        for node in ast.walk(tree):
            if (isinstance(node, ast.Attribute)
                    and _is_cost_name(node.attr)
                    and isinstance(node.value, ast.Name)
                    and node.value.id in heuristic_aliases):
                problems.append((
                    path, node.lineno,
                    f"'{node.value.id}.{node.attr}' goes through the "
                    f"deprecated shim — use repro.perfmodel"))
    return problems


def main() -> int:
    problems = []
    for d in SCAN_DIRS:
        for path in sorted((ROOT / d).rglob("*.py")):
            if path in ALLOW or any(ad in path.parents
                                    for ad in ALLOW_DIRS):
                continue
            problems.extend(_check_file(path))
    for path, line, msg in problems:
        print(f"{path.relative_to(ROOT)}:{line}: {msg}")
    if problems:
        print(f"\n{len(problems)} perfmodel boundary violation(s). "
              "New code imports cost/byte models from repro.perfmodel.")
        return 1
    print("perfmodel boundary: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
