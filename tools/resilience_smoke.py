"""Serving-resilience smoke scenario (ISSUE 9 / DESIGN.md §14) — CI gate.

One seeded end-to-end scenario hitting all three §14 surfaces at once:

  1. **crash-safe state**: the warm plan-cache file AND the measured
     threshold table are corrupted on disk (garbage bytes / torn write)
     before the server starts — the server must construct anyway, rename
     both aside as ``*.corrupt``, rebuild plans / re-measure thresholds,
     and count the ``corrupt_state`` incidents;
  2. **fault injection**: ``kernel=0.1`` fires deterministic kernel faults
     on every rung, and ``nan@mixed=1.0`` poisons EVERY batch served on a
     mixed-policy rung — the finite check must catch it and the ladder must
     degrade to the uniform rung;
  3. **zero drops**: despite all of the above, 100% of submitted requests
     come back with finite probabilities.

Exit 0 = all assertions hold; any failure raises (non-zero exit).  Run as::

    PYTHONPATH=src python tools/resilience_smoke.py
"""
from __future__ import annotations

import os
import sys
import tempfile

import numpy as np

from repro.launch.cnn_serve import CNNServer, ImageRequest
from repro.runtime.resilience import FaultInjector, parse_inject_spec

NETWORK = "lenet"
REQUESTS = 48
MAX_BUCKET = 8
INJECT_SPEC = "kernel=0.1,nan@mixed=1.0"


def make_requests(cfg, n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    c, h = cfg.in_channels, cfg.image_hw
    return [ImageRequest(i, rng.standard_normal((c, h, h)).astype(np.float32))
            for i in range(n)]


def main() -> int:
    tmp = tempfile.mkdtemp(prefix="resilience_smoke_")
    cache_path = os.path.join(tmp, f"{NETWORK}.plans.json")
    calib_path = os.path.join(tmp, "thresholds.json")

    # -- warm run: build and persist a healthy plan cache + measured
    #    threshold table -----------------------------------------------------
    srv = CNNServer(NETWORK, max_bucket=MAX_BUCKET, impl="xla",
                    cache_path=cache_path, calib_path=calib_path,
                    dtype_policy="mixed")
    done = srv.run(make_requests(srv.cfg, 16))
    assert len(done) == 16, f"warm run dropped requests: {len(done)}/16"
    assert os.path.exists(cache_path), "warm run did not persist the cache"
    assert os.path.exists(calib_path), "warm run did not persist thresholds"

    # -- corrupt BOTH persisted files (torn write / disk garbage) ------------
    FaultInjector.corrupt_json(cache_path, mode="garbage")
    FaultInjector.corrupt_json(calib_path, mode="truncate")

    # -- cold run under injection: corrupt state + kernel faults + NaN on
    #    every mixed-path batch ----------------------------------------------
    srv = CNNServer(NETWORK, max_bucket=MAX_BUCKET, impl="xla",
                    cache_path=cache_path, calib_path=calib_path,
                    dtype_policy="mixed",
                    injector=parse_inject_spec(INJECT_SPEC, seed=0))
    counts = srv.incidents.counts
    assert counts.get("corrupt_state", 0) >= 2, (
        f"corrupt cache/threshold files not both detected: {counts}")
    assert os.path.exists(cache_path + ".corrupt"), (
        "corrupt cache was not renamed aside")
    assert os.path.exists(calib_path + ".corrupt"), (
        "corrupt threshold table was not renamed aside")

    reqs = make_requests(srv.cfg, REQUESTS, seed=1)
    done = srv.run(reqs)
    dropped = len(reqs) - len(done)

    for line in srv.report_lines():
        print(line)
    counts = srv.incidents.counts
    print(f"served={len(done)}/{len(reqs)} dropped={dropped} "
          f"incidents={srv.incidents.total}")

    assert dropped == 0, f"resilience gate: {dropped} requests dropped"
    assert set(done) == {r.rid for r in reqs}, "served ids != submitted ids"
    for rid, probs in done.items():
        assert np.isfinite(probs).all(), f"request {rid}: non-finite output"
    # the NaN injector fires on every mixed-rung batch, so serving MUST have
    # degraded off the mixed path at least once — proves the ladder engaged
    assert counts.get("nonfinite", 0) >= 1, (
        f"nan@mixed never tripped the finite check: {counts}")
    assert counts.get("degraded", 0) >= 1, (
        f"no batch was served on a fallback rung: {counts}")
    print("resilience smoke: OK (zero drops under injection)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
