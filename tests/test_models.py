"""Per-architecture smoke tests (deliverable f) + decode/forward consistency.

Each assigned arch instantiates a REDUCED config of the same family and runs
one forward + one train step on CPU, asserting output shapes and no NaNs.
Prefill+decode must agree with the teacher-forced forward pass.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCH_IDS, ShapeConfig, TrainConfig, get_config,
                           reduced_config, shapes_for)
from repro.models import (chunked_xent, decode_step, forward, init_params,
                          logits_fwd, prefill)
from repro.models.transformer import CLIP_DIM

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _inputs(cfg):
    kw = {}
    total = S
    if cfg.frontend == "clip_stub":
        kw["embeds"] = jax.random.normal(
            KEY, (B, cfg.frontend_tokens, CLIP_DIM)).astype(jnp.bfloat16)
        total += cfg.frontend_tokens
    if cfg.family == "encdec":
        kw["frames"] = jax.random.normal(
            KEY, (B, cfg.encoder_seq, cfg.d_model)).astype(jnp.bfloat16)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return tokens, pos, kw, total


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = reduced_config(get_config(arch))
    params = init_params(KEY, cfg)
    tokens, pos, kw, total = _inputs(cfg)
    h, aux = forward(params, tokens, pos, cfg, **kw)
    assert h.shape == (B, total, cfg.d_model)
    assert not bool(jnp.isnan(h.astype(jnp.float32)).any())
    logits = logits_fwd(params, h[:, -1, :], cfg)
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


# jamba's long block pattern makes its reduced config the heaviest by far
# (~40 s each on CPU): slow tier
_TRAIN_ARCHS = [pytest.param(a, marks=pytest.mark.slow)
                if a.startswith("jamba") else a for a in ARCH_IDS]


@pytest.mark.parametrize("arch", _TRAIN_ARCHS)
def test_smoke_train_step(arch):
    """One full train step (loss+grads+adamw) on the reduced config."""
    from repro.optim import adamw
    cfg = reduced_config(get_config(arch))
    params = init_params(KEY, cfg)
    tokens, pos, kw, total = _inputs(cfg)
    labels = jax.random.randint(KEY, (B, total), 0, cfg.vocab_size)

    def loss_fn(p):
        h, aux = forward(p, tokens, pos, cfg, **kw)
        return chunked_xent(p, h, labels, cfg, chunk=8) + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    state = adamw.init(params)
    tc = TrainConfig()
    new_params, new_state, stats = adamw.update(grads, state, params, tc)
    assert int(new_state.step) == 1
    assert np.isfinite(float(stats["grad_norm"]))
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", [
    "qwen2_7b", "gemma2_27b", "rwkv6_7b",
    pytest.param("jamba_1p5_large_398b", marks=pytest.mark.slow),
    "whisper_base", "dbrx_132b"])
def test_decode_matches_forward(arch):
    """Greedy decode logits == teacher-forced forward logits at each step
    (validates KV cache, rolling states and cross attention)."""
    cfg = reduced_config(get_config(arch))
    params = init_params(KEY, cfg)
    tokens, pos, kw, total = _inputs(cfg)

    h, _ = forward(params, tokens, pos, cfg, **kw)
    full_logits = logits_fwd(params, h, cfg)            # [B, total, V]

    n_prompt = S - 4
    lg, cache, cross = prefill(params, tokens[:, :n_prompt], cfg,
                               max_len=total + 4, **kw)
    front = cfg.frontend_tokens if cfg.frontend == "clip_stub" else 0
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(full_logits[:, front + n_prompt - 1]),
        atol=0.15, rtol=0.05)

    cache_len = front + n_prompt
    for t in range(n_prompt, S):
        tok = tokens[:, t:t + 1]
        lg, cache = decode_step(params, cache, tok, jnp.int32(cache_len),
                                cfg, cross=cross)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full_logits[:, front + t]),
            atol=0.15, rtol=0.05)
        cache_len += 1


def test_gemma2_softcap_applied():
    cfg = reduced_config(get_config("gemma2_27b"))
    params = init_params(KEY, cfg)
    tokens, pos, kw, total = _inputs(cfg)
    h, _ = forward(params, tokens, pos, cfg, **kw)
    logits = logits_fwd(params, h, cfg)
    assert float(jnp.abs(logits).max()) <= cfg.final_logit_softcap + 1e-3


def test_local_vs_global_attention_differ():
    cfg = reduced_config(get_config("gemma2_27b"))
    assert cfg.local_window is not None
    from repro.models import layers as L
    p = L.init_attention(KEY, cfg)
    x = jax.random.normal(KEY, (1, 12, cfg.d_model)).astype(jnp.bfloat16)
    pos = jnp.arange(12, dtype=jnp.int32)[None]
    y_local = L.attention_fwd(p, x, pos, cfg, local=True)
    y_global = L.attention_fwd(p, x, pos, cfg, local=False)
    assert float(jnp.abs(y_local.astype(jnp.float32)
                         - y_global.astype(jnp.float32)).max()) > 1e-4


def test_chunked_xent_matches_full():
    cfg = reduced_config(get_config("yi_9b"))
    params = init_params(KEY, cfg)
    tokens, pos, kw, total = _inputs(cfg)
    h, _ = forward(params, tokens, pos, cfg, **kw)
    labels = jax.random.randint(KEY, (B, total), 0, cfg.vocab_size)
    loss_c = chunked_xent(params, h, labels, cfg, chunk=4)
    logits = logits_fwd(params, h, cfg).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    loss_full = (lse - gold).mean()
    np.testing.assert_allclose(float(loss_c), float(loss_full), rtol=1e-3)


def test_chunked_attention_matches_full():
    from repro.models import layers as L
    cfg = reduced_config(get_config("yi_9b"))
    p = L.init_attention(KEY, cfg)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model)).astype(jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(32, dtype=jnp.int32)[None], (2, 32))
    y_full = L.attention_fwd(p, x, pos, cfg, q_chunk=64)   # single block
    y_chunk = L.attention_fwd(p, x, pos, cfg, q_chunk=8)   # 4 chunks
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_full, np.float32),
                               atol=0.02, rtol=0.05)


def test_kv_cache_layouts_equivalent():
    """bksd and sbkd cache layouts produce identical decode logits —
    layout changes memory behavior, never math (paper invariant)."""
    cfg = reduced_config(get_config("qwen2_7b"))
    params = init_params(KEY, cfg)
    tokens, pos, kw, total = _inputs(cfg)
    outs = {}
    for layout in ("bksd", "sbkd"):
        lg, cache, _ = prefill(params, tokens, cfg, max_len=total + 2,
                               kv_layout=layout)
        lg2, _ = decode_step(params, cache,
                             jnp.argmax(lg, -1)[:, None].astype(jnp.int32),
                             jnp.int32(total), cfg, kv_layout=layout)
        outs[layout] = np.asarray(lg2)
    np.testing.assert_allclose(outs["bksd"], outs["sbkd"], atol=1e-3)


def test_masked_cache_update_matches_dus():
    cfg = reduced_config(get_config("yi_9b"))
    params = init_params(KEY, cfg)
    tokens, pos, kw, total = _inputs(cfg)
    lg, cache, _ = prefill(params, tokens, cfg, max_len=total + 2)
    tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
    lg_dus, c_dus = decode_step(params, cache, tok, jnp.int32(total), cfg,
                                kv_update="dus")
    lg_msk, c_msk = decode_step(params, cache, tok, jnp.int32(total), cfg,
                                kv_update="masked")
    np.testing.assert_allclose(np.asarray(lg_dus), np.asarray(lg_msk),
                               atol=1e-3)
    for a, b in zip(jax.tree.leaves(c_dus), jax.tree.leaves(c_msk)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-3)


def test_param_counts_match_published_sizes():
    from repro.models.registry import param_count
    expect = {"qwen2_7b": (7.0, 8.3), "yi_9b": (8.3, 9.5),
              "gemma2_27b": (26, 28.5), "dbrx_132b": (125, 135),
              "llama4_maverick_400b": (380, 410),
              "jamba_1p5_large_398b": (380, 410), "rwkv6_7b": (7.0, 8.2)}
    for arch, (lo, hi) in expect.items():
        n = param_count(get_config(arch)) / 1e9
        assert lo <= n <= hi, (arch, n)
