"""Test helpers: subprocess runner for tests needing N fake devices
(XLA device count locks at first jax init, so multi-device tests isolate)."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 4, timeout: int = 520) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=REPO)
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}")
    return out.stdout
