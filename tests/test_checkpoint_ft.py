"""Checkpointing + fault tolerance: atomicity, restore, auto-restart,
straggler detection, retention."""
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.runtime.fault_tolerance import (FaultTolerantRunner, StepFailure,
                                           StragglerWatchdog)


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "step": jnp.zeros((), jnp.int32)}}


def _abstract(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    t = _tree()
    ck.save(7, t)
    step, got = ck.restore(_abstract(t))
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_async_save_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=True)
    ck.save(1, _tree())
    ck.wait()
    assert ck.latest_step() == 1


def test_atomic_no_partial_dirs(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    for s in (1, 2, 3):
        ck.save(s, _tree())
    names = sorted(p.name for p in tmp_path.iterdir())
    assert all(n.startswith("step_") for n in names), names


def test_structure_mismatch_rejected(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    ck.save(1, _tree())
    bad = {"a": jnp.zeros((3, 4)), "z": jnp.zeros((5,))}
    with pytest.raises(ValueError):
        ck.restore(_abstract(bad))


def test_retention_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    for s in range(6):
        ck.save(s, _tree())
    ck.gc(keep=2)
    assert ck.latest_step() == 5
    assert len(list(tmp_path.glob("step_*"))) == 2


def test_ft_runner_recovers_from_injected_failures(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    runner = FaultTolerantRunner(ck, save_every=2, max_restarts=3)
    fail_at = {5}

    calls = {"n": 0}

    def step_fn(state, step):
        calls["n"] += 1
        if step in fail_at:
            fail_at.discard(step)          # fail once
            raise StepFailure("injected")
        return {"x": state["x"] + 1.0}, {"loss": float(state["x"])}

    state = {"x": jnp.zeros(())}
    end, state = runner.run(state, step_fn, total_steps=10)
    assert end == 10
    # one failure -> replay from step 4 checkpoint; value must be exactly 10
    assert float(state["x"]) == 10.0


def test_ft_runner_gives_up_after_max_restarts(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    runner = FaultTolerantRunner(ck, save_every=100, max_restarts=2)

    def step_fn(state, step):
        raise StepFailure("always")

    with pytest.raises(StepFailure):
        runner.run({"x": jnp.zeros(())}, step_fn, total_steps=3)


def test_straggler_watchdog_flags_outliers():
    wd = StragglerWatchdog(k_sigma=3.0, warmup=3)
    for i in range(20):
        wd.observe(i, 0.10 + 0.001 * (i % 3))
    assert not wd.flagged
    assert wd.observe(20, 1.0)             # 10x the mean
    assert wd.flagged and wd.flagged[0][0] == 20


def test_elastic_restore_changes_sharding(tmp_path):
    """Checkpoint written without mesh info restores onto any sharding."""
    from tests.util import run_with_devices
    run_with_devices(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.checkpointer import Checkpointer
from repro.launch.mesh import make_host_mesh

ck = Checkpointer(r"{tmp_path}", async_write=False)
mesh_a = make_host_mesh(4, 1)
x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh_a, P("data", None)))
ck.save(3, {{"w": x}})

mesh_b = make_host_mesh(2, 2)
sh = {{"w": NamedSharding(mesh_b, P("data", "model"))}}
abstract = {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
step, got = ck.restore(abstract, shardings=sh)
assert step == 3
np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(64.0).reshape(8, 8))
assert got["w"].sharding.spec == P("data", "model")
print("elastic ok")
""", n_devices=4)
