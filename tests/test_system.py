"""End-to-end behaviour tests for the paper's system.

The paper's claim structure, re-validated on this implementation:
  1. layouts are selected per layer by a calibrated heuristic (§IV.A);
  2. a network runs with mixed layouts + fast transforms and is numerically
     identical to any single-layout run (§IV.C/D);
  3. memory-bound layers (pool/softmax) use fused/reuse kernels (§V);
  4. the LM framework trains end-to-end with checkpoint/restart.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_paper_pipeline_end_to_end():
    """LeNet through the full §IV.D pipeline: calibrate -> assign ->
    execute with transforms -> train a few steps."""
    from repro.configs.cnn_networks import LENET
    from repro.cnn.layers import init_cnn
    from repro.cnn.network import (forward, init_velocity, make_train_step,
                                   plan_network)
    from repro.core import calibrate

    cfg = LENET.replace(batch=16)
    th = calibrate()
    assert th.Ct >= 16 and th.Nt >= 32          # sane hardware thresholds
    layouts = plan_network(cfg, "opt", thresholds=th)
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 1, 28, 28))
    probs, stats = forward(params, x, cfg, layouts)
    assert probs.shape == (16, 10)

    y = jax.random.randint(jax.random.PRNGKey(2), (16,), 0, 10)
    step = make_train_step(cfg, layouts, lr=0.02)
    vel = init_velocity(params)
    l0 = None
    for _ in range(10):
        params, vel, loss = step(params, vel, x, y)
        l0 = l0 or float(loss)
    assert float(loss) < l0


def test_lm_train_loop_end_to_end(tmp_path):
    """Reduced qwen2 trains ~30 steps with checkpointing; loss decreases."""
    from repro.launch.train import train
    out = train("qwen2_7b", reduced=True, steps=30, batch=8, seq=64,
                checkpoint_dir=str(tmp_path), log_every=100)
    losses = out["losses"]
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first, (first, last)
    # checkpoint exists and is resumable
    from repro.checkpoint.checkpointer import Checkpointer
    ck = Checkpointer(str(tmp_path))
    assert ck.latest_step() is not None


def test_lm_serve_end_to_end():
    """Batched prefill+decode through the Server scheduler."""
    from repro.launch.serve import Request, Server
    srv = Server("yi_9b", reduced=True, batch=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, srv.cfg.vocab_size, size=(6,),
                                    dtype=np.int32), max_new=4)
            for i in range(2)]
    out = srv.run(reqs)
    assert len(out) == 2
    assert all(len(v) == 4 for v in out.values())
    assert all(0 <= t < srv.cfg.vocab_size for v in out.values() for t in v)


def test_serve_greedy_deterministic():
    from repro.launch.serve import Request, Server
    srv = Server("phi3_mini_3p8b", reduced=True, batch=1, max_len=32)
    prompt = np.arange(5, dtype=np.int32)
    o1 = srv.run([Request(0, prompt.copy(), max_new=4)])
    o2 = srv.run([Request(0, prompt.copy(), max_new=4)])
    assert o1[0] == o2[0]


def test_dryrun_results_exist_and_pass():
    """The multi-pod dry-run artifacts: every applicable (arch x shape x
    mesh) cell compiled (no error entries)."""
    import glob
    import json
    from repro.configs import ARCH_IDS, get_config, shapes_for
    files = glob.glob("results/dryrun/*/*.json")
    if not files:
        pytest.skip("dry-run artifacts not generated in this environment")
    cells = {}
    for f in files:
        d = json.load(open(f))
        cells[(f.split("/")[-2], d.get("arch"), d.get("shape"))] = d
    n_err = sum(1 for d in cells.values() if "error" in d)
    assert n_err == 0, f"{n_err} dry-run cells failed"
    # every applicable cell present on both meshes
    for arch in ARCH_IDS:
        for shape in shapes_for(get_config(arch)):
            for mesh in ("single", "multi"):
                assert (mesh, arch, shape.name) in cells, (mesh, arch, shape.name)
