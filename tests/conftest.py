"""Shared pytest wiring.

``multidevice`` marker (ISSUE 10): tests that need ``jax.device_count() >
1`` in THIS process (mesh construction, in-process shard_map).  On a
1-device host they skip with an actionable reason instead of failing on
mesh construction; the ``mesh`` CI job runs them for real under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.  Subprocess-based
multi-device tests (``tests/util.run_with_devices``) set the flag
themselves and stay unmarked so tier-1 exercises them everywhere.
"""
from __future__ import annotations

import pytest


def _device_count() -> int:
    import jax
    return jax.device_count()


def pytest_collection_modifyitems(config, items):
    if _device_count() > 1:
        return
    skip = pytest.mark.skip(
        reason="needs >1 jax device; run under "
               "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    for item in items:
        if "multidevice" in item.keywords:
            item.add_marker(skip)


@pytest.fixture
def multi_devices():
    """Device count for multidevice-marked tests (skips defensively if a
    marked test is somehow collected on a 1-device host)."""
    n = _device_count()
    if n < 2:
        pytest.skip("needs >1 jax device")
    return n
