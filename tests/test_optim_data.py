"""Optimizer + data pipeline unit tests (incl. hypothesis properties)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

from repro.configs import TrainConfig
from repro.configs.registry import get_config, reduced_config
from repro.data.pipeline import DataConfig, ImageStream, TokenStream
from repro.configs.base import ShapeConfig
from repro.optim import adamw


def test_adamw_converges_on_quadratic():
    tc = TrainConfig(learning_rate=0.1, warmup_steps=1, total_steps=200,
                     weight_decay=0.0, grad_clip=10.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init(params)
    target = jnp.array([1.0, 1.0])
    for _ in range(150):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw.update(g, state, params, tc)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0], atol=0.15)


def test_grad_clip_caps_norm():
    g = {"w": jnp.full((10,), 100.0)}
    clipped, gn = adamw.clip_by_global_norm(g, 1.0)
    assert float(gn) > 100
    assert float(adamw.global_norm(clipped)) <= 1.0 + 1e-5


def _check_lr_bounds(step):
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=100, total_steps=1000)
    lr = float(adamw.lr_schedule(tc, jnp.int32(step)))
    assert 0.0 <= lr <= 1e-3 + 1e-9


if HAS_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(step=st.integers(0, 999))
    def test_lr_schedule_bounds(step):
        _check_lr_bounds(step)
else:
    def test_lr_schedule_bounds():
        for step in (0, 1, 50, 99, 100, 101, 500, 998, 999):
            _check_lr_bounds(step)


def test_lr_schedule_warmup_then_decay():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=100, total_steps=1000)
    lrs = [float(adamw.lr_schedule(tc, jnp.int32(s)))
           for s in (0, 50, 100, 500, 1000)]
    assert lrs[0] < lrs[1] < lrs[2]
    assert lrs[2] > lrs[3] > lrs[4]


def test_opt_state_dtype_configurable():
    p = {"w": jnp.zeros((4,), jnp.bfloat16)}
    st_ = adamw.init(p, jnp.bfloat16)
    assert jax.tree.leaves(st_.m)[0].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def _stream(host_index=0, host_count=1):
    cfg = reduced_config(get_config("yi_9b"))
    shape = ShapeConfig("t", "train", 32, 8)
    return TokenStream(cfg, shape, DataConfig(seed=7),
                       host_index=host_index, host_count=host_count)


def test_stream_deterministic_per_step():
    a = _stream().batch_at(5)
    b = _stream().batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = _stream().batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_stream_host_slices_disjoint_and_cover():
    full = _stream().batch_at(3)
    h0 = _stream(0, 2).batch_at(3)
    h1 = _stream(1, 2).batch_at(3)
    assert h0["tokens"].shape[0] == h1["tokens"].shape[0] == 4


def test_stream_labels_are_shifted_tokens():
    b = _stream().batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_stream_learnable_structure():
    """Tokens are mostly periodic: next-token is predictable from context."""
    b = _stream().batch_at(0)
    t, l = b["tokens"], b["labels"]
    # consecutive deltas are constant for non-noise positions
    d = (l[:, 1:].astype(np.int64) - l[:, :-1].astype(np.int64))
    match = 0
    for row in d:
        vals, counts = np.unique(row % 65536, return_counts=True)
        match += counts.max() / row.size
    assert match / d.shape[0] > 0.7


def test_vlm_stream_masks_image_positions():
    cfg = reduced_config(get_config("phi3_vision_4p2b"))
    shape = ShapeConfig("t", "train", 32, 4)
    s = TokenStream(cfg, shape)
    b = s.batch_at(0)
    front = cfg.frontend_tokens
    assert b["embeds"].shape == (4, front, 1024)
    assert b["mask"][:, :front].sum() == 0
    assert b["tokens"].shape == (4, 32 - front)


def test_image_stream_class_structure():
    s = ImageStream(16, 3, 16, 10, seed=0)
    x, y = s.batch_at(0)
    assert x.shape == (16, 3, 16, 16) and y.shape == (16,)
    x2, y2 = s.batch_at(0)
    np.testing.assert_array_equal(x, x2)
