"""Batch-adaptive serving subsystem (ISSUE 3): pow-2 bucketing, the plan
cache (hit/miss semantics, persistence), measured threshold calibration,
and bucketed-execution equivalence against exact-batch plans."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.cnn_networks import CNN_CONFIGS, LENET
from repro.cnn.layers import init_cnn
from repro.cnn.network import forward_fused, input_shape, plan_network_fused
from repro.configs.paper_table1 import ConvLayer
from repro.core.heuristic import Thresholds, calibrate, conv_cost
from repro.serve import (PlanCache, bucket_for, measured_thresholds,
                         network_id, pad_to_bucket, pallas_conv_measure)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------

def test_bucket_for_pow2():
    assert [bucket_for(b) for b in (1, 2, 3, 4, 5, 8, 9, 129, 256)] == \
        [1, 2, 4, 4, 8, 8, 16, 256, 256]
    for b in range(1, 300):
        bkt = bucket_for(b)
        assert bkt >= b and (bkt & (bkt - 1)) == 0


def test_bucket_for_caps_and_rejects():
    assert bucket_for(3, min_bucket=8) == 8
    assert bucket_for(200, max_bucket=256) == 256
    with pytest.raises(ValueError):
        bucket_for(0)
    with pytest.raises(ValueError):
        bucket_for(300, max_bucket=256)


def test_pad_to_bucket_pads_rows_only():
    x = jnp.ones((3, 1, 4, 4))
    xp = pad_to_bucket(x, 4)
    assert xp.shape == (4, 1, 4, 4)
    np.testing.assert_array_equal(np.asarray(xp[:3]), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(xp[3]), 0.0)
    assert pad_to_bucket(x, 3) is x
    with pytest.raises(ValueError):
        pad_to_bucket(x, 2)


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

def test_plan_cache_hits_within_bucket():
    cache = PlanCache(thresholds=calibrate(dtype_bytes=4))
    p3, b3, h3 = cache.fused_plan(LENET, 3)
    p4, b4, h4 = cache.fused_plan(LENET, 4)
    assert b3 == b4 == 4 and not h3 and h4
    assert cache.planner_calls == 1 and p3 is p4
    _, _, h128 = cache.fused_plan(LENET, 128)
    assert not h128 and cache.planner_calls == 2
    assert cache.stats.hits == 1 and cache.stats.misses == 2


def test_plan_cache_layout_flips_with_batch():
    """The paper's Nt threshold: the SAME network plans into different
    layouts at different batch buckets, which is the whole reason the cache
    is keyed on bucket."""
    cache = PlanCache(thresholds=calibrate(dtype_bytes=4))
    sig = {}
    for b in (4, 128):
        plan, _, _ = cache.fused_plan(LENET, b)
        sig[b] = tuple(op.layout for op in plan.ops if op.kind == "conv")
        # the cached plan IS the from-scratch plan at the bucket size
        direct = plan_network_fused(LENET.replace(batch=b))
        assert plan == direct
    assert sig[4] != sig[128]


def test_plan_cache_separate_keys_for_training():
    cache = PlanCache(thresholds=calibrate(dtype_bytes=4))
    cache.fused_plan(LENET, 4)
    _, _, hit = cache.fused_plan(LENET, 4, training=True)
    assert not hit and cache.planner_calls == 2


def test_plan_cache_persistence_roundtrip(tmp_path):
    path = str(tmp_path / "plans.json")
    cache = PlanCache(path=path, thresholds=calibrate(dtype_bytes=4))
    p1, _, _ = cache.fused_plan(LENET, 3)
    a1, _, _ = cache.assignment(LENET, 3)
    cache.save()

    loaded = PlanCache(path=path)
    assert loaded.thresholds == cache.thresholds
    p2, _, hit_f = loaded.fused_plan(LENET, 4)      # same bucket (4)
    a2, _, hit_a = loaded.assignment(LENET, 4)
    assert hit_f and hit_a and loaded.planner_calls == 0
    assert p2 == p1 and a2 == a1


def test_plan_cache_load_respects_constructor_settings(tmp_path):
    """Regression: persisted JSON must not override operator-supplied
    settings — a restart with --max-bucket 8 must not resurrect the old
    bucket cap (or stale thresholds) from disk."""
    path = str(tmp_path / "plans.json")
    PlanCache(path=path, thresholds=calibrate(dtype_bytes=4), max_bucket=64).save()
    fresh = Thresholds(Ct=1, Nt=1)
    c = PlanCache(path=path, thresholds=fresh, max_bucket=8)
    assert c.max_bucket == 8 and c.thresholds == fresh
    # unspecified settings DO come from disk
    c2 = PlanCache(path=path)
    assert c2.max_bucket == 64 and c2.thresholds == calibrate(dtype_bytes=4)


def test_plan_cache_rejects_degenerate_bound():
    """Regression: max_entries=0 used to evict the just-inserted plan and
    crash the read-back; degenerate bounds are rejected up front."""
    with pytest.raises(ValueError):
        PlanCache(max_entries=0)
    with pytest.raises(ValueError):
        PlanCache(max_entries=-1)
    assert PlanCache(max_entries=1).max_entries == 1


def test_plan_cache_lru_eviction_bound():
    """max_entries bounds the cache with least-recently-HIT eviction:
    touching a key refreshes it, and an evicted key replans on re-sight."""
    cache = PlanCache(max_entries=2)
    cache.fused_plan(LENET, 1)               # keys: b1
    cache.fused_plan(LENET, 2)               # keys: b1, b2
    cache.fused_plan(LENET, 1)               # hit refreshes b1 -> b2 is LRU
    cache.fused_plan(LENET, 4)               # evicts b2
    assert len(cache._fused) == 2 and cache.evictions == 1
    _, _, hit1 = cache.fused_plan(LENET, 1)
    assert hit1                              # refreshed key survived
    calls = cache.planner_calls
    _, _, hit2 = cache.fused_plan(LENET, 2)
    assert not hit2 and cache.planner_calls == calls + 1   # evicted: replans
    assert len(cache._fused) == 2


def test_plan_cache_lru_persists_across_restarts(tmp_path):
    """The bound AND the recency order survive a save/load cycle: the
    reloaded cache evicts the same key the unrestarted one would have."""
    path = str(tmp_path / "plans.json")
    cache = PlanCache(path=path, max_entries=2)
    cache.fused_plan(LENET, 1)
    cache.fused_plan(LENET, 2)
    cache.fused_plan(LENET, 1)               # recency: b2 (LRU), b1 (MRU)
    cache.save()

    loaded = PlanCache(path=path)
    assert loaded.max_entries == 2
    assert [k.bucket for k in loaded._fused] == [2, 1]     # order preserved
    loaded.fused_plan(LENET, 4)              # must evict b2, not b1
    buckets = {k.bucket for k in loaded._fused}
    assert buckets == {1, 4}
    # constructor-supplied bound wins over the persisted one
    assert PlanCache(path=path, max_entries=1).max_entries == 1
    # unbounded caches stay unbounded after reload
    unb = PlanCache(path=str(tmp_path / "unb.json"))
    assert unb.max_entries is None


def test_plan_cache_lru_load_trims_overflow(tmp_path):
    """Loading a larger persisted cache under a tighter bound keeps only
    the most-recently-hit entries."""
    path = str(tmp_path / "plans.json")
    big = PlanCache(path=path)
    for b in (1, 2, 4, 8):
        big.fused_plan(LENET, b)
    big.save()
    small = PlanCache(path=path, max_entries=2)
    assert len(small._fused) == 2
    assert {k.bucket for k in small._fused} == {4, 8}      # newest survive


def test_network_id_distinguishes_reduced_variants():
    full = CNN_CONFIGS["alexnet"]
    reduced = full.replace(image_hw=96)
    assert network_id(full) != network_id(reduced)
    assert network_id(full) == network_id(full.replace(batch=7))  # batch-free
    cache = PlanCache(thresholds=calibrate(dtype_bytes=4))
    cache.fused_plan(full, 2)
    _, _, hit = cache.fused_plan(reduced, 2)
    assert not hit                     # no cross-size collision


# ---------------------------------------------------------------------------
# measured calibration
# ---------------------------------------------------------------------------

def test_measured_calibration_persists(tmp_path):
    path = str(tmp_path / "thresholds.json")
    calls = []

    def fake_measure(l, lay):
        calls.append((l.N, l.Ci, lay))
        return conv_cost(l, lay).total_s

    th1 = measured_thresholds(path, measure=fake_measure)
    n = len(calls)
    assert n > 0 and th1 == calibrate()     # analytic measure == analytic sweep
    th2 = measured_thresholds(path, measure=fake_measure)
    assert len(calls) == n                  # loaded, not re-measured
    assert th2 == th1
    th3 = measured_thresholds(path, measure=fake_measure, force=True)
    assert len(calls) > n and th3 == th1


def test_pallas_measure_times_real_kernels():
    """The measure callback runs the actual Pallas engines and returns a
    positive wall time for both layouts."""
    measure = pallas_conv_measure(proxy_hw=6, proxy_co=8, reps=1)
    l = ConvLayer("T", 8, 8, 8, 3, 4, 1, "t")
    for lay in ("CHWN", "NCHW"):
        t = measure(l, lay)
        assert t > 0.0


# ---------------------------------------------------------------------------
# bucketed execution equivalence (acceptance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B", [1, 3, 6])
def test_bucketed_forward_matches_exact_batch(B):
    """forward_fused under the bucket's padded plan reproduces the
    exact-batch plan's outputs on the real rows (fused Pallas engine)."""
    cache = PlanCache(thresholds=calibrate(dtype_bytes=4))
    bkt = cache.bucket(B)
    bplan, _, _ = cache.fused_plan(LENET, B)
    eplan = plan_network_fused(LENET.replace(batch=B))
    params = init_cnn(KEY, LENET.replace(batch=B))
    x = jax.random.normal(jax.random.PRNGKey(B),
                          input_shape(LENET.replace(batch=B)), jnp.float32)
    yb, sb = forward_fused(params, pad_to_bucket(x, bkt),
                           LENET.replace(batch=bkt), bplan, impl="pallas")
    ye, _ = forward_fused(params, x, LENET.replace(batch=B), eplan,
                          impl="pallas")
    assert yb.shape[0] == bkt
    np.testing.assert_allclose(np.asarray(yb[:B]), np.asarray(ye), atol=1e-5)
    assert sb.transforms == 0


# ---------------------------------------------------------------------------
# the serving driver
# ---------------------------------------------------------------------------

def test_cnn_server_replans_zero_on_repeats(tmp_path):
    from repro.launch.cnn_serve import CNNServer, ImageRequest
    path = str(tmp_path / "lenet.plans.json")
    th = calibrate(dtype_bytes=4)
    rng = np.random.default_rng(0)

    def reqs(n, start=0):
        return [ImageRequest(start + i,
                             rng.standard_normal((1, 28, 28)).astype(np.float32))
                for i in range(n)]

    srv = CNNServer("lenet", max_bucket=8, impl="xla", thresholds=th,
                    cache_path=path)
    done = srv.run(reqs(20))                # drains as 8, 8, 4
    assert len(done) == 20
    assert all(v.shape == (10,) for v in done.values())
    assert srv.cache.planner_calls == 2     # buckets 8 and 4, once each
    rep8 = srv.reports[8]
    assert rep8.batches == 2 and rep8.hits == 1 and rep8.misses == 1
    assert srv.reports[4].misses == 1
    assert any("bucket=8" in ln for ln in srv.report_lines())

    # a restarted server loads the persisted plans: zero replanning
    srv2 = CNNServer("lenet", max_bucket=8, impl="xla", thresholds=th,
                     cache_path=path)
    srv2.run(reqs(16, start=100))           # drains as 8, 8
    assert srv2.cache.planner_calls == 0
    assert srv2.reports[8].hit_rate == 1.0


def test_cnn_server_report_survives_lru_eviction():
    """Regression: a bounded cache can evict a bucket's plan between its
    last execution and the report; report_lines must not crash (or replan)."""
    from repro.launch.cnn_serve import CNNServer, ImageRequest
    rng = np.random.default_rng(0)
    srv = CNNServer("lenet", max_bucket=8, impl="xla",
                    thresholds=calibrate(dtype_bytes=4), max_plans=1)
    srv.run([ImageRequest(i, rng.standard_normal((1, 28, 28))
                          .astype(np.float32)) for i in range(11)])
    # buckets 8 and 4 were both served but only one plan survives the bound
    calls = srv.cache.planner_calls
    lines = srv.report_lines()
    assert srv.cache.planner_calls == calls        # report didn't replan
    assert any("(evicted)" in ln for ln in lines)


def test_cnn_server_rejects_bad_shape():
    from repro.launch.cnn_serve import CNNServer, ImageRequest
    srv = CNNServer("lenet", impl="xla",
                    thresholds=calibrate(dtype_bytes=4))
    with pytest.raises(ValueError):
        srv.submit(ImageRequest(0, np.zeros((3, 28, 28), np.float32)))
