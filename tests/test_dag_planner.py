"""Graph-level fusion (DESIGN.md §11): DAG planner properties, residual
epilogues on the real Pallas kernels, and the branching-network acceptance
criteria (ResNet-18 / U-Net mini)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cnn.layers import fused_conv_block, init_cnn, layer_shapes
from repro.cnn.network import (forward, forward_fused, input_shape,
                               make_train_step_fused, init_velocity,
                               network_descs, plan_network,
                               plan_network_fused)
from repro.configs.cnn_networks import (ALEXNET, CNN_CONFIGS, LENET,
                                        build_resnet18, build_unet_mini,
                                        reduced_cnn)
from repro.core.selector import assign_layouts, plan_fused
from repro.serve.plan_cache import network_id

KEY = jax.random.PRNGKey(0)

RESNET18 = CNN_CONFIGS["resnet18"]
UNET_MINI = CNN_CONFIGS["unet_mini"]


# ---------------------------------------------------------------------------
# planner properties
# ---------------------------------------------------------------------------

def _op_sig(op):
    return (op.kind, op.index, op.name, op.layout, op.src_layout,
            op.dst_layout, op.relu, op.pool_index, op.src_dtype,
            op.dst_dtype, op.add_index, op.res_index)


@pytest.mark.parametrize("base", [LENET, ALEXNET])
@pytest.mark.parametrize("policy", ["uniform", "mixed"])
@pytest.mark.parametrize("training", [False, True])
def test_linear_graph_degenerates_to_chain_plan(base, policy, training):
    """On a linear network the frontier DP must reproduce the chain DP
    byte-identically: same layouts, dtypes, costs, and op stream."""
    descs = network_descs(base)
    kw = dict(input_layout="NCHW", input_shape=input_shape(base),
              dtype_policy=policy, training=training)
    chain = plan_fused(descs, **kw)
    graph = plan_fused(descs, _force_graph=True, **kw)
    assert graph.layouts == chain.layouts
    assert graph.dtypes == chain.dtypes
    assert graph.transforms == chain.transforms
    assert graph.fused_bytes == chain.fused_bytes
    assert graph.unfused_bytes == chain.unfused_bytes
    assert graph.total_s == pytest.approx(chain.total_s, rel=1e-9)
    assert [_op_sig(o) for o in graph.ops] == [_op_sig(o) for o in chain.ops]


@pytest.mark.parametrize("cfg", [RESNET18, UNET_MINI],
                         ids=["resnet18", "unet_mini"])
def test_dag_plan_never_worse_than_unfused(cfg):
    """Fused DAG plans dominate their own unfused linearization in both DP
    objectives (modeled seconds, modeled HBM bytes)."""
    plan = plan_network_fused(cfg)
    asg = assign_layouts(network_descs(cfg), input_layout="NCHW",
                         input_shape=input_shape(cfg))
    assert plan.fused_bytes <= plan.unfused_bytes
    assert plan.total_s <= asg.total_s * (1 + 1e-9)


def test_resnet18_plan_acceptance():
    """ISSUE 6 acceptance: zero standalone residual adds and >= 25% fewer
    modeled HBM bytes than the decomposed execution at float32."""
    plan = plan_network_fused(RESNET18)
    assert plan.standalone_adds == 0
    assert plan.fused_bytes <= 0.75 * plan.unfused_bytes
    # every residual add is folded into a conv epilogue
    adds = [i for i, s in enumerate(RESNET18.layers) if s.kind == "add"]
    folded = {op.add_index for op in plan.ops if op.add_index is not None}
    assert folded == set(adds)


def test_unet_plan_folds_merges():
    plan = plan_network_fused(UNET_MINI)
    assert plan.standalone_adds == 0
    assert plan.fused_bytes < plan.unfused_bytes
    # concat/upsample stay as explicit graph ops with edges attached
    kinds = {op.kind for op in plan.ops}
    assert "concat" in kinds and "upsample" in kinds
    for op in plan.ops:
        if op.kind == "concat":
            assert len(op.inputs) == 2


def test_mixed_merge_join_keeps_skip_producers_at_base_dtype():
    """Under --dtype-policy mixed, int8 storage may only appear on conv->conv
    main edges; any tensor consumed by a folded residual add (or a concat)
    must stay at the base float dtype — the skip is added raw in VMEM with
    no dequant hook."""
    plan = plan_network_fused(RESNET18, policy="mixed")
    assert "int8" in plan.dtypes            # the policy actually engages
    skip_srcs = {op.res_index for op in plan.ops if op.res_index is not None}
    for s in skip_srcs:
        assert plan.dtypes[s] == plan.base_dtype, (s, plan.dtypes[s])
    # compare dtype policies on equal footing: mixed plans never stack
    # (DESIGN.md §12 pairing gates), so hold stacking off on both sides
    uplan = plan_network_fused(RESNET18, policy="uniform", stack_policy="off")
    assert plan.fused_bytes <= uplan.fused_bytes

    cplan = plan_network_fused(UNET_MINI, policy="mixed")
    for op in cplan.ops:
        if op.kind == "concat":
            for p in op.inputs:
                assert cplan.dtypes[p] == cplan.base_dtype


# ---------------------------------------------------------------------------
# residual epilogue on the real Pallas kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["CHWN", "NCHW"])
@pytest.mark.parametrize("res_layout", ["CHWN", "NCHW"])
@pytest.mark.parametrize("pool", [None, (2, 2, "max")],
                         ids=["nopool", "pool"])
def test_residual_epilogue_matches_xla(layout, res_layout, pool):
    """conv+bias+residual+relu[+pool] as ONE Pallas kernel: forward and all
    four gradients (x, w, bias, skip) agree with the decomposed XLA
    reference, for both engines and both skip storage layouts."""
    N, Ci, H, Co, F = 2, 4, 6, 8, 3
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    x_nchw = jax.random.normal(k1, (N, Ci, H, H))
    w = jax.random.normal(k2, (Co, Ci, F, F)) * 0.2
    b = jax.random.normal(k3, (Co,)) * 0.1
    res_nchw = jax.random.normal(k4, (N, Co, H, H))

    def tr(t, lay):
        return jnp.transpose(t, (1, 2, 3, 0)) if lay == "CHWN" else t

    x, res = tr(x_nchw, layout), tr(res_nchw, res_layout)

    def run(impl):
        def f(x, w, b, res):
            y = fused_conv_block(x, w, layout, stride=1, pad=1, bias=b,
                                 relu=True, pool=pool, res=res,
                                 res_layout=res_layout, impl=impl)
            return jnp.sum(y * jnp.cos(y)), y
        (_, y), grads = jax.value_and_grad(
            f, argnums=(0, 1, 2, 3), has_aux=True)(x, w, b, res)
        return y, grads

    yp, gp = run("pallas")
    yx, gx = run("xla")
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yx), atol=1e-4)
    for a, b2 in zip(gp, gx):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b2), atol=1e-4)


# ---------------------------------------------------------------------------
# end-to-end branching execution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["resnet18", "unet_mini"])
def test_branching_network_pallas_fused_matches_xla_unfused(name):
    """ISSUE 6 acceptance: the fully fused Pallas execution of the branching
    networks reproduces the decomposed XLA reference to <= 1e-5."""
    cfg = reduced_cnn(CNN_CONFIGS[name], batch=4)
    params = init_cnn(KEY, cfg)
    x = jax.random.normal(KEY, input_shape(cfg))
    plan = plan_network_fused(cfg)
    got, stats = forward_fused(params, x, cfg, plan, impl="pallas")
    ref, sref = forward(params, x, cfg, plan_network(cfg, "cudnn"),
                        impl="xla")
    assert float(jnp.max(jnp.abs(got - ref))) <= 1e-5
    assert plan.standalone_adds == 0
    assert stats.hbm_bytes < sref.hbm_bytes


def test_resnet18_fused_training_decreases_loss():
    cfg = reduced_cnn(RESNET18, batch=4)
    params = init_cnn(KEY, cfg)
    x = jax.random.normal(KEY, input_shape(cfg))
    y = jax.random.randint(jax.random.PRNGKey(2), (4,), 0, cfg.num_classes)
    plan = plan_network_fused(cfg)
    step = make_train_step_fused(cfg, plan, lr=0.02)
    vel = init_velocity(params)
    losses = []
    for _ in range(3):
        params, vel, loss = step(params, vel, x, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


# ---------------------------------------------------------------------------
# config / cache plumbing
# ---------------------------------------------------------------------------

def test_network_id_folds_topology():
    """Edge-stripped configs must not collide with the real graph, while
    pre-DAG linear fingerprints stay byte-stable."""
    cfg = reduced_cnn(RESNET18, batch=4)
    stripped = cfg.replace(layers=tuple(
        dataclasses.replace(s, inputs=()) for s in cfg.layers))
    assert network_id(cfg) != network_id(stripped)
    # regression pins: legacy linear fingerprints from the pre-DAG planner
    assert network_id(ALEXNET) == "alexnet@f24092e5d5"
    assert network_id(LENET) == "lenet@674789fa69"


def test_cnn_server_reduces_branching_net_through_builder(tmp_path):
    """The serving driver's quick mode must shrink resnet18 through its
    builder — a bare replace(image_hw=96) zeroes out the 7x7 global pool
    and init_cnn divides by zero on the fc fan-in."""
    from repro.launch.cnn_serve import CNNServer
    srv = CNNServer(network="resnet18", calibration="analytic",
                    cache_path=str(tmp_path / "cache.json"))
    assert srv.cfg.image_hw <= 96
    shapes = layer_shapes(srv.cfg)
    assert shapes[-1] == (srv.cfg.batch, srv.cfg.num_classes)
    assert all(0 not in s for s in shapes)


@pytest.mark.parametrize("hw", [16, 32])
@pytest.mark.parametrize("name", ["resnet18", "unet_mini"])
def test_builders_keep_merge_shapes_consistent(name, hw):
    """reduced_cnn re-derives every skip edge through the builder, so merge
    nodes validate at any supported size (layer_shapes raises on mismatch)."""
    cfg = reduced_cnn(CNN_CONFIGS[name].replace(image_hw=hw), batch=2)
    shapes = layer_shapes(cfg)
    assert shapes[-1] == (2, cfg.num_classes)
    # builders at a non-reduced size too
    big = (build_resnet18(batch=2, image_hw=64, width=8) if name == "resnet18"
           else build_unet_mini(batch=2, image_hw=64, width=8))
    assert layer_shapes(big)[-1] == (2, big.num_classes)
