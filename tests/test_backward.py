"""Differential gradient suite (ISSUE 2): every (layout, stride, pad,
kernel-size) cell checks dgrad / wgrad / bias-grad of the Pallas backward
path against ``jax.grad`` of the pure-jnp oracles, in float32 to 1e-5
(relative to the gradient's own scale — wgrad sums O(N*Ho*Wo) f32 terms, so
absolute tolerances scale with magnitude)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv.ref import conv_chwn_ref, conv_nchw_ref
from repro.kernels.pool.ref import pool_ref

KEY = jax.random.PRNGKey(0)
K2 = jax.random.PRNGKey(3)
K3 = jax.random.PRNGKey(9)


def assert_grads_close(got, ref, tol=1e-5):
    got, ref = np.asarray(got, np.float64), np.asarray(ref, np.float64)
    scale = max(1.0, np.abs(ref).max())
    np.testing.assert_allclose(got, ref, rtol=tol, atol=tol * scale)


def _cotangent(shape):
    return jax.random.normal(K3, shape)


# --------------------------------------------------------------------------
# conv dgrad/wgrad: the (layout, stride, pad, kernel-size) grid
# --------------------------------------------------------------------------
CONV_GRID = [  # Ci, H, N, F, Co, S, pad
    (3, 12, 4, 3, 8, 1, 0),
    (3, 12, 4, 3, 8, 1, 1),
    (8, 13, 4, 5, 16, 1, 2),
    (8, 14, 4, 5, 16, 2, 2),
    (4, 11, 2, 3, 8, 2, 0),
    (1, 7, 2, 5, 8, 1, 0),      # small-output-height halo (Ho < ceil(F-S)/S)
    (2, 9, 2, 7, 4, 1, 0),      # Ho=3 < 6: whole-height fallback
    (4, 5, 2, 3, 8, 5, 0),      # Ho=1 with F<S: spurious-row slicing (ISSUE 7)
    (4, 4, 2, 4, 8, 4, 0),      # Ho=1 with F==S: exact single-block tiling
]


@pytest.mark.parametrize("Ci,H,N,F,Co,S,pad", CONV_GRID)
def test_conv_grads_nchw_engine(Ci, H, N, F, Co, S, pad):
    from repro.kernels.conv.ops import conv_im2col_nchw_fused
    x = jax.random.normal(KEY, (N, Ci, H, H))
    w = jax.random.normal(K2, (Co, Ci, F, F)) * 0.1
    r = _cotangent(conv_nchw_ref(x, w, S, pad).shape)
    gx_p, gw_p = jax.grad(
        lambda x, w: (conv_im2col_nchw_fused(x, w, stride=S, pad=pad)
                      * r).sum(), (0, 1))(x, w)
    gx_r, gw_r = jax.grad(
        lambda x, w: (conv_nchw_ref(x, w, S, pad) * r).sum(), (0, 1))(x, w)
    assert_grads_close(gx_p, gx_r)
    assert_grads_close(gw_p, gw_r)


@pytest.mark.parametrize("Ci,H,N,F,Co,S,pad", CONV_GRID)
def test_conv_grads_chwn_engine(Ci, H, N, F, Co, S, pad):
    from repro.kernels.conv.ops import conv_direct_chwn
    x = jax.random.normal(KEY, (Ci, H, H, N))
    w = jax.random.normal(K2, (Ci, F, F, Co)) * 0.1
    r = _cotangent(conv_chwn_ref(x, w, S, pad).shape)
    gx_p, gw_p = jax.grad(
        lambda x, w: (conv_direct_chwn(x, w, stride=S, pad=pad)
                      * r).sum(), (0, 1))(x, w)
    gx_r, gw_r = jax.grad(
        lambda x, w: (conv_chwn_ref(x, w, S, pad) * r).sum(), (0, 1))(x, w)
    assert_grads_close(gx_p, gx_r)
    assert_grads_close(gw_p, gw_r)


@pytest.mark.parametrize("Ci,Co", [(48, 16), (32, 130), (48, 130)])
def test_conv_grads_channels_not_tile_divisible(Ci, Co):
    """PR 1's zero-padded channel tiles must also round-trip through the
    backward engines (padded channels carry zero gradient)."""
    from repro.kernels.conv.ops import conv_direct_chwn, conv_im2col_nchw_fused
    x = jax.random.normal(KEY, (2, Ci, 8, 8))
    w = jax.random.normal(K2, (Co, Ci, 3, 3)) * 0.1
    r = _cotangent(conv_nchw_ref(x, w, 1, 1).shape)
    gx_p, gw_p = jax.grad(
        lambda x, w: (conv_im2col_nchw_fused(x, w, stride=1, pad=1)
                      * r).sum(), (0, 1))(x, w)
    gx_r, gw_r = jax.grad(
        lambda x, w: (conv_nchw_ref(x, w, 1, 1) * r).sum(), (0, 1))(x, w)
    assert_grads_close(gx_p, gx_r)
    assert_grads_close(gw_p, gw_r)
    xc, wc = jnp.transpose(x, (1, 2, 3, 0)), jnp.transpose(w, (1, 2, 3, 0))
    rc = jnp.transpose(r, (1, 2, 3, 0))
    gx_p, gw_p = jax.grad(
        lambda x, w: (conv_direct_chwn(x, w, stride=1, pad=1)
                      * rc).sum(), (0, 1))(xc, wc)
    gx_r, gw_r = jax.grad(
        lambda x, w: (conv_chwn_ref(x, w, 1, 1) * rc).sum(), (0, 1))(xc, wc)
    assert_grads_close(gx_p, gx_r)
    assert_grads_close(gw_p, gw_r)


# --------------------------------------------------------------------------
# dgrad / wgrad primitives, called directly
# --------------------------------------------------------------------------
@pytest.mark.parametrize("layout", ["CHWN", "NCHW"])
@pytest.mark.parametrize("S,pad", [(1, 0), (1, 1), (2, 2)])
def test_dgrad_wgrad_primitives(layout, S, pad):
    from repro.kernels.conv.backward import conv_dgrad, conv_wgrad
    Ci, H, N, F, Co = 4, 12, 4, 5, 8
    xn = jax.random.normal(KEY, (N, Ci, H, H))
    w = jax.random.normal(K2, (Co, Ci, F, F)) * 0.1
    rn = _cotangent(conv_nchw_ref(xn, w, S, pad).shape)
    gx_r, gw_r = jax.grad(
        lambda x, w: (conv_nchw_ref(x, w, S, pad) * rn).sum(), (0, 1))(xn, w)
    if layout == "CHWN":
        g = jnp.transpose(rn, (1, 2, 3, 0))
        x_l = jnp.transpose(xn, (1, 2, 3, 0))
        dx = conv_dgrad(g, w, (H, H), S, pad, layout=layout)
        dw = conv_wgrad(x_l, g, F, S, pad, x_layout="CHWN", g_layout="CHWN")
        assert_grads_close(jnp.transpose(dx, (3, 0, 1, 2)), gx_r)
    else:
        dx = conv_dgrad(rn, w, (H, H), S, pad, layout=layout)
        dw = conv_wgrad(xn, rn, F, S, pad, x_layout="NCHW", g_layout="NCHW")
        assert_grads_close(dx, gx_r)
    assert_grads_close(dw, gw_r)


@pytest.mark.parametrize("layout", ["CHWN", "NCHW"])
@pytest.mark.parametrize("H,F,S,pad", [(5, 3, 5, 0), (4, 4, 4, 0),
                                       (3, 3, 4, 1), (7, 5, 7, 1)])
def test_wgrad_single_output_row(layout, H, F, S, pad):
    """ISSUE 7 satellite: wgrad blocking at Ho==1 with F<=S.  The
    halo-extended input hands ``conv_out_hw`` a spurious extra output row
    and the single-row-block ``ibh`` override is active at its smallest
    legal size — the shared PR 2 invariant must still count exactly one row
    block per grid step (wrong counts show up as wrong dw, not crashes)."""
    from repro.kernels.conv.backward import conv_wgrad
    from repro.kernels.conv.ops import conv_blocking, conv_out_hw
    Ci, N, Co = 4, 2, 8
    Ho = conv_out_hw(H + 2 * pad, F, S)
    assert Ho == 1 and F <= S
    bho, IBH, n_ho = conv_blocking(Ho, F, S)
    assert bho == 1 and n_ho == 1
    xn = jax.random.normal(KEY, (N, Ci, H, H))
    w = jax.random.normal(K2, (Co, Ci, F, F)) * 0.1
    rn = _cotangent(conv_nchw_ref(xn, w, S, pad).shape)
    gw_r = jax.grad(
        lambda w: (conv_nchw_ref(xn, w, S, pad) * rn).sum())(w)
    if layout == "CHWN":
        x_l = jnp.transpose(xn, (1, 2, 3, 0))
        g = jnp.transpose(rn, (1, 2, 3, 0))
        dw = conv_wgrad(x_l, g, F, S, pad, x_layout="CHWN", g_layout="CHWN")
    else:
        dw = conv_wgrad(xn, rn, F, S, pad, x_layout="NCHW", g_layout="NCHW")
    assert_grads_close(dw, gw_r)


def test_dgrad_mixed_layouts_fold():
    """dgrad consumes g in the downstream layout and emits dx in the
    upstream layout — the reversed re-layout chain folds into its I/O."""
    from repro.kernels.conv.backward import conv_dgrad
    Ci, H, N, F, Co, S, pad = 3, 10, 4, 3, 8, 1, 1
    xn = jax.random.normal(KEY, (N, Ci, H, H))
    w = jax.random.normal(K2, (Co, Ci, F, F)) * 0.1
    rn = _cotangent(conv_nchw_ref(xn, w, S, pad).shape)
    gx_r = jax.grad(
        lambda x: (conv_nchw_ref(x, w, S, pad) * rn).sum())(xn)
    # compute in CHWN, consume NCHW gradient, emit NCHW dx
    dx = conv_dgrad(rn, w, (H, H), S, pad, layout="CHWN", g_layout="NCHW",
                    dst_layout="NCHW")
    assert_grads_close(dx, gx_r)


# --------------------------------------------------------------------------
# fused block: conv+bias+relu+pool as one kernel, grads end to end
# --------------------------------------------------------------------------
FUSED_GRID = [  # pool, S, pad
    ((2, 2, "max"), 1, 1),
    ((3, 2, "max"), 1, 1),      # overlapping windows
    ((2, 2, "avg"), 2, 2),
    (None, 1, 1),
]


@pytest.mark.parametrize("pool,S,pad", FUSED_GRID)
@pytest.mark.parametrize("layout", ["CHWN", "NCHW"])
def test_fused_block_grads(pool, S, pad, layout):
    from repro.cnn.layers import fused_conv_block
    Ci, H, N, F, Co = 3, 16, 8, 3, 16
    xn = jax.random.normal(KEY, (N, Ci, H, H))
    w = jax.random.normal(K2, (Co, Ci, F, F)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(5), (Co,)) * 0.5

    def loss(x, w, b, impl):
        y = fused_conv_block(x, w, layout, S, pad, bias=b, relu=True,
                             pool=pool, src_layout="NCHW",
                             dst_layout="NCHW", impl=impl)
        return (y * r).sum()

    r = _cotangent(jax.eval_shape(
        lambda x, w, b: fused_conv_block(x, w, layout, S, pad, bias=b,
                                         relu=True, pool=pool,
                                         src_layout="NCHW",
                                         dst_layout="NCHW",
                                         impl="xla"), xn, w, b).shape)
    gp = jax.grad(loss, (0, 1, 2))(xn, w, b, "pallas")
    gr = jax.grad(loss, (0, 1, 2))(xn, w, b, "xla")
    for a, c in zip(gp, gr):
        assert_grads_close(a, c)


# --------------------------------------------------------------------------
# pool backward: max-mask + avg-scatter, both layouts, overlapping windows
# --------------------------------------------------------------------------
POOL_GRID = [(2, 2), (3, 2), (3, 3)]


@pytest.mark.parametrize("F,S", POOL_GRID)
@pytest.mark.parametrize("op", ["max", "avg"])
def test_pool_backward_chwn(F, S, op):
    from repro.kernels.pool.ops import pool_chwn
    x = jax.random.normal(KEY, (6, 13, 13, 16))
    r = _cotangent(pool_ref(x, F, S, op, "CHWN").shape)
    g1 = jax.grad(lambda x: (pool_chwn(x, F, S, op) * r).sum())(x)
    g2 = jax.grad(lambda x: (pool_ref(x, F, S, op, "CHWN") * r).sum())(x)
    assert_grads_close(g1, g2)


@pytest.mark.parametrize("F,S", POOL_GRID)
@pytest.mark.parametrize("op", ["max", "avg"])
def test_pool_backward_nchw(F, S, op):
    from repro.kernels.pool.ops import pool_nchw
    x = jax.random.normal(KEY, (4, 16, 13, 13))
    r = _cotangent(pool_ref(x, F, S, op, "NCHW").shape)
    g1 = jax.grad(lambda x: (pool_nchw(x, F, S, op) * r).sum())(x)
    g2 = jax.grad(lambda x: (pool_ref(x, F, S, op, "NCHW") * r).sum())(x)
    assert_grads_close(g1, g2)


def test_pool_backward_dst_layout_fold():
    """The pool VJP consumes its cotangent in dst_layout directly."""
    from repro.kernels.pool.ops import pool_chwn
    x = jax.random.normal(KEY, (6, 12, 12, 16))
    rn = _cotangent((16, 6, 6, 6))           # NCHW cotangent
    g1 = jax.grad(lambda x: (pool_chwn(x, 2, 2, "max", dst_layout="NCHW")
                             * rn).sum())(x)
    g2 = jax.grad(lambda x: (jnp.transpose(pool_ref(x, 2, 2, "max", "CHWN"),
                                           (3, 0, 1, 2)) * rn).sum())(x)
    assert_grads_close(g1, g2)


def test_max_pool_backward_tie_breaking_matches_xla():
    """Constant slabs tie every window element: gradient must route to the
    FIRST maximal element per window (XLA select-and-scatter order)."""
    from repro.kernels.pool.ops import pool_chwn
    x = jnp.ones((2, 8, 8, 8))
    r = jnp.ones(pool_ref(x, 2, 2, "max", "CHWN").shape)
    g1 = jax.grad(lambda x: (pool_chwn(x, 2, 2, "max") * r).sum())(x)
    g2 = jax.grad(lambda x: (pool_ref(x, 2, 2, "max", "CHWN") * r).sum())(x)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


# --------------------------------------------------------------------------
# softmax VJP + the interpret-threading regression
# --------------------------------------------------------------------------
def test_softmax_vjp():
    from repro.kernels.softmax.ops import softmax
    x = jax.random.normal(KEY, (32, 50)) * 3
    r = _cotangent((32, 50))
    g1 = jax.grad(lambda x: (softmax(x) * r).sum())(x)
    g2 = jax.grad(lambda x: (jax.nn.softmax(x, -1) * r).sum())(x)
    assert_grads_close(g1, g2)


def test_softmax_forward_threads_interpret(monkeypatch):
    """Regression: ``softmax_forward`` must pass the engine-wide interpret
    flag down to the Pallas kernel, not hard-code it."""
    import repro.kernels.softmax.ops as sm_ops
    from repro.cnn.layers import softmax_forward
    seen = {}

    def fake_softmax(x, interpret=True):
        seen["interpret"] = interpret
        return x

    monkeypatch.setattr(sm_ops, "softmax", fake_softmax)
    x = jnp.zeros((4, 8))
    softmax_forward(x, impl="pallas", interpret=False)
    assert seen["interpret"] is False
    softmax_forward(x, impl="pallas", interpret=True)
    assert seen["interpret"] is True


# --------------------------------------------------------------------------
# end to end: the fused training engine (ISSUE 2 acceptance)
# --------------------------------------------------------------------------
def _small(cfg, batch=4):
    hw = 32 if cfg.image_hw <= 32 else 96
    return cfg.replace(batch=batch, image_hw=hw)


@pytest.mark.parametrize("name", [
    "lenet",
    pytest.param("alexnet", marks=pytest.mark.slow),  # 5-conv grid, ~37 s
])
def test_train_step_fused_matches_xla(name):
    """``train_step_fused`` (fused Pallas forward + custom-VJP backward)
    reproduces the XLA-autodiff ``train_step`` losses to 1e-4 over 5 steps,
    with strictly fewer modeled HBM bytes per training step."""
    from repro.configs.cnn_networks import CNN_CONFIGS
    from repro.cnn.layers import init_cnn
    from repro.cnn.network import (forward, forward_fused, init_velocity,
                                   input_shape, make_train_step,
                                   make_train_step_fused, plan_network,
                                   plan_network_fused)
    cfg = _small(CNN_CONFIGS[name])
    params = init_cnn(KEY, cfg)
    x = jax.random.normal(KEY, input_shape(cfg))
    y = jax.random.randint(jax.random.PRNGKey(1), (cfg.batch,), 0,
                           cfg.num_classes)
    layouts = plan_network(cfg, "opt")
    plan = plan_network_fused(cfg)
    step_ref = make_train_step(cfg, layouts)
    step_fused = make_train_step_fused(cfg, plan)
    p1, v1 = params, init_velocity(params)
    p2, v2 = params, init_velocity(params)
    for _ in range(5):
        p1, v1, l1 = step_ref(p1, v1, x, y)
        p2, v2, l2 = step_fused(p2, v2, x, y)
        assert abs(float(l1) - float(l2)) < 1e-4, (float(l1), float(l2))
    _, su = forward(params, x, cfg, layouts, impl="xla", training=True)
    _, sf = forward_fused(params, x, cfg, plan, impl="xla", training=True)
    assert sf.total_hbm_bytes < su.total_hbm_bytes
    assert sf.bwd_hbm_bytes > 0 and su.bwd_hbm_bytes > 0


def test_training_accounting_is_shape_only():
    """Backward RunStats must work under jax.eval_shape (the full-size
    benchmark path never executes the network)."""
    from repro.configs.cnn_networks import LENET
    from repro.cnn.layers import init_cnn
    from repro.cnn.network import (forward_fused, input_shape,
                                   plan_network_fused)
    cfg = LENET
    params = jax.eval_shape(lambda k: init_cnn(k, cfg), KEY)
    box = {}

    def f(p, x):
        y, st = forward_fused(p, x, cfg, plan_network_fused(cfg), impl="xla",
                              training=True)
        box["st"] = st
        return y

    jax.eval_shape(f, params,
                   jax.ShapeDtypeStruct(input_shape(cfg), jnp.float32))
    assert box["st"].bwd_hbm_bytes > 0
    assert box["st"].total_hbm_bytes > box["st"].hbm_bytes
