"""Serving-grade resilience (ISSUE 9 / DESIGN.md §14): deterministic fault
injection, the guarded degradation ladder (zero request loss, per-rung
bit-equality, quarantine without replanning), and crash-safe persisted
plan/calibration state (corruption matrix, quarantine-aside, restart)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.cnn_networks import LENET
from repro.cnn.network import forward_fused, plan_network_fused
from repro.core import heuristic as H
from repro.launch.cnn_serve import CNNServer, ImageRequest
from repro.perfmodel import calibrate
from repro.perfmodel.calibration import save_thresholds
from repro.runtime.fault_tolerance import FaultTolerantRunner, StepFailure
from repro.checkpoint.checkpointer import Checkpointer
from repro.runtime.resilience import (CHECKSUM_FIELD, CorruptStateError,
                                      FaultInjector, IncidentLog,
                                      InjectedKernelFault, ServingFault,
                                      atomic_json_dump, degradation_ladder,
                                      load_json_guarded, parse_inject_spec,
                                      verify_checksum, with_checksum)
from repro.serve import PlanCache, measured_thresholds, pad_to_bucket

TH4 = calibrate(dtype_bytes=4)


def make_requests(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    c, h = cfg.in_channels, cfg.image_hw
    return [ImageRequest(i, rng.standard_normal((c, h, h)).astype(np.float32))
            for i in range(n)]


def make_server(tmp_path=None, **kw):
    kw.setdefault("max_bucket", 8)
    kw.setdefault("impl", "xla")
    kw.setdefault("thresholds", TH4)
    kw.setdefault("calibration", "analytic")
    if tmp_path is not None:
        kw.setdefault("cache_path", str(tmp_path / "plans.json"))
    return CNNServer("lenet", **kw)


# ---------------------------------------------------------------------------
# fault injector: determinism, site qualifiers, spec parsing
# ---------------------------------------------------------------------------

def test_injector_deterministic_per_seed():
    a = FaultInjector(seed=7, rates={"kernel": 0.5})
    b = FaultInjector(seed=7, rates={"kernel": 0.5})
    draws_a = [a.fire("kernel", ("rung", "pol", "impl")) for _ in range(32)]
    draws_b = [b.fire("kernel", ("rung", "pol", "impl")) for _ in range(32)]
    assert draws_a == draws_b
    assert any(draws_a) and not all(draws_a)     # rate 0.5 actually draws
    c = FaultInjector(seed=8, rates={"kernel": 0.5})
    draws_c = [c.fire("kernel", ("rung", "pol", "impl")) for _ in range(32)]
    assert draws_a != draws_c                    # seed moves the sequence
    # independent sites draw from independent streams
    d = FaultInjector(seed=7, rates={"kernel": 0.5, "nan": 0.5})
    assert d.draws == {}
    d.fire("kernel", ()), d.fire("nan", ())
    assert set(d.draws) == {"kernel", "nan"}


def test_injector_site_qualifiers():
    inj = FaultInjector(seed=0, rates={"nan@mixed": 1.0})
    y = np.ones(4, np.float32)
    out = inj.maybe_poison(y, ("pallas-mixed", "mixed", "pallas"))
    assert np.isnan(out[0]) and np.isfinite(y).all()   # copy, not in place
    # a uniform-policy site never matches the @mixed qualifier
    out2 = inj.maybe_poison(y, ("pallas", "uniform", "pallas"))
    assert np.isfinite(out2).all()
    # rate-1.0 kernel site raises every time it matches
    inj2 = FaultInjector(seed=0, rates={"kernel@xla": 1.0})
    with pytest.raises(InjectedKernelFault):
        inj2.maybe_kernel_fault(("xla", "uniform", "xla"))
    inj2.maybe_kernel_fault(("pallas", "uniform", "pallas"))  # no match


def test_parse_inject_spec():
    assert parse_inject_spec("") is None
    inj = parse_inject_spec("kernel=0.1,nan@mixed=1.0", seed=3)
    assert inj.rates == {"kernel": 0.1, "nan@mixed": 1.0}
    assert inj.seed == 3
    with pytest.raises(ValueError):
        parse_inject_spec("kernel")
    with pytest.raises(ValueError):
        FaultInjector(rates={"kernel": 1.5})


def test_incident_log_rejects_unknown_kind():
    log = IncidentLog()
    log.record("kernel_fault")
    log.record("requeue", n=2)
    assert log.total == 3
    assert "kernel_fault:1" in log.summary()
    with pytest.raises(ValueError):
        log.record("typo_kind")


# ---------------------------------------------------------------------------
# the degradation ladder
# ---------------------------------------------------------------------------

def test_ladder_shapes():
    l = degradation_ladder("pallas", "mixed")
    assert [r.name for r in l] == ["pallas+stacks-mixed", "pallas-mixed",
                                   "pallas", "xla"]
    # terminal rung is always the decomposed-XLA ground truth
    t = l[-1]
    assert (t.impl, t.stack, t.policy) == ("xla", "off", "uniform")
    assert [r.name for r in degradation_ladder("xla", "uniform")] == \
        ["xla+stacks", "xla"]
    with pytest.raises(ValueError):
        degradation_ladder("cuda", "uniform")


# ---------------------------------------------------------------------------
# zero request loss (ISSUE 9 satellite: the step() re-queue fix)
# ---------------------------------------------------------------------------

def test_injected_fault_loses_zero_requests(tmp_path):
    """One injected kernel fault on the top rung: the batch completes on
    the fallback rung — every request served, none dropped."""
    srv = make_server(tmp_path, injector=FaultInjector(
        seed=0, rates={"kernel@xla+stacks": 1.0}))
    reqs = make_requests(srv.cfg, 16)
    done = srv.run(reqs)
    assert set(done) == {r.rid for r in reqs}
    for probs in done.values():
        assert np.isfinite(probs).all()
    assert srv.incidents.counts["kernel_fault"] >= 1
    assert srv.incidents.counts["degraded"] >= 1


def test_total_failure_requeues_in_original_order(tmp_path):
    """When EVERY rung fails, the admitted batch returns to the FRONT of
    the queue in its original order before ServingFault propagates."""
    srv = make_server(tmp_path, injector=FaultInjector(
        seed=0, rates={"kernel": 1.0}))
    reqs = make_requests(srv.cfg, 6)
    tail = make_requests(srv.cfg, 2, seed=9)
    for r in reqs:
        srv.submit(r)
    for i, r in enumerate(tail):                 # waiting behind the batch
        r.rid = 100 + i
        srv.submit(r)
    with pytest.raises(ServingFault):
        srv.step()
    # all 8 still queued: the failed batch back at the front, original
    # order, the untouched tail behind it
    assert [r.rid for r in srv.queue] == [0, 1, 2, 3, 4, 5, 100, 101]
    assert srv.incidents.counts["requeue"] == 1
    # lifting the injection serves the exact same queue to completion
    srv.injector = None
    srv._quarantine.clear()
    done = {}
    while srv.queue:
        for r in srv.step():
            done[r.rid] = r.probs
    assert set(done) == {0, 1, 2, 3, 4, 5, 100, 101}


def test_run_retries_through_step_failures(tmp_path):
    """run() absorbs fully-failed steps (bounded) — with the terminal rung
    clean, every request is eventually served."""
    srv = make_server(tmp_path, injector=FaultInjector(
        seed=0, rates={"kernel@xla+stacks": 1.0, "nan@xla": 0.3}))
    reqs = make_requests(srv.cfg, 24)
    done = srv.run(reqs)
    assert set(done) == {r.rid for r in reqs}


# ---------------------------------------------------------------------------
# per-rung differential: degraded output == fallback rung's direct execution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rung_idx", [0, 1, 2])
def test_degraded_output_bit_equal_to_rung(tmp_path, rung_idx):
    """Force failure of every rung above ``rung_idx``: the served batch
    must be BIT-EQUAL to executing the landing rung's own plan directly
    (mixed policy gives a 3-rung xla ladder)."""
    ladder = degradation_ladder("xla", "mixed")
    rates = {f"kernel@{ladder[i].name}": 1.0 for i in range(rung_idx)}
    srv = make_server(tmp_path, dtype_policy="mixed",
                      injector=FaultInjector(seed=0, rates=rates) if rates
                      else None)
    reqs = make_requests(srv.cfg, 5)
    done = srv.run(reqs)
    assert set(done) == {r.rid for r in reqs}
    rung = ladder[rung_idx]
    assert srv.reports[8].rung == rung.name
    # direct execution of the landing rung's plan — same planner inputs,
    # bypassing the server entirely
    bcfg = srv.cfg.replace(batch=8)
    plan = plan_network_fused(bcfg, dtype=srv.dtype, policy=rung.policy,
                              stack_policy=rung.stack)

    @jax.jit
    def direct(params, x):
        y, _ = forward_fused(params, x, bcfg, plan, impl=rung.impl,
                             interpret=srv.interpret)
        return y

    x = jnp.asarray(np.stack([r.image for r in reqs])).astype(srv._jdtype)
    y = np.asarray(direct(srv.params, pad_to_bucket(x, 8))
                   .astype(jnp.float32))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(done[r.rid], y[i])


# ---------------------------------------------------------------------------
# quarantine: skip straight to the known-good rung, planner_calls bounded
# ---------------------------------------------------------------------------

def test_quarantine_skips_without_replanning(tmp_path):
    """After the first batch quarantines the mixed rungs, later batches of
    the bucket start at the known-good rung: no new failures, no new
    planner calls — the fallback plan is a cache key, not a replan."""
    srv = make_server(tmp_path, dtype_policy="mixed",
                      injector=FaultInjector(seed=0,
                                             rates={"nan@mixed": 1.0}))
    srv.run(make_requests(srv.cfg, 8))           # one bucket-8 batch
    calls = srv.cache.planner_calls
    fails = srv.reports[8].failures
    assert calls == 3                            # the 3 distinct variants
    assert fails == 2                            # both mixed rungs, once
    assert len(srv._quarantine) == 2
    srv.run(make_requests(srv.cfg, 24, seed=1))  # three more batches
    assert srv.cache.planner_calls == calls      # zero replans
    assert srv.reports[8].failures == fails      # zero retries
    assert srv.reports[8].degraded == 4          # every batch, fallback rung


def test_clean_server_stays_on_top_rung(tmp_path):
    """No injector, no faults: rung 0 serves everything — the resilience
    layer is inert (plans/planner_calls identical to the unguarded path)."""
    srv = make_server(tmp_path)
    done = srv.run(make_requests(srv.cfg, 24))
    assert len(done) == 24
    assert srv.incidents.total == 0
    assert not srv._quarantine
    for rep in srv.reports.values():
        assert rep.rung == "xla+stacks" and rep.degraded == 0
    # one planner call per bucket seen, exactly as before §14
    assert srv.cache.planner_calls == len(srv.reports)
    assert "incidents=0" in srv.report_lines()[-1]


def test_watchdog_hook_wired_into_step(tmp_path):
    """Serving shares the training StragglerWatchdog: a flagged batch is a
    'straggler' incident and a report column."""
    class AlwaysFlag:
        flagged = [(1, 9.9)]

        def observe(self, step, dt):
            return True

    srv = make_server(tmp_path)
    srv._watchdogs[8] = AlwaysFlag()
    srv.run(make_requests(srv.cfg, 8))
    assert srv.incidents.counts["straggler"] == 1
    assert any("stragglers=1" in l for l in srv.report_lines())


# ---------------------------------------------------------------------------
# crash-safe persisted state: checksum + corruption matrix + restart
# ---------------------------------------------------------------------------

def test_checksum_roundtrip_and_tamper(tmp_path):
    obj = with_checksum({"version": 1, "rows": [1, 2, 3]})
    assert CHECKSUM_FIELD in obj
    verify_checksum(dict(obj))                   # intact: passes
    tampered = dict(obj)
    tampered["rows"] = [1, 2, 4]
    with pytest.raises(CorruptStateError):
        verify_checksum(tampered)
    legacy = {"version": 1, "rows": []}          # checksum-free: accepted
    verify_checksum(legacy)


def test_atomic_json_dump_and_guarded_load(tmp_path):
    path = str(tmp_path / "state.json")
    atomic_json_dump({"version": 1, "x": 5}, path)
    assert load_json_guarded(path, lambda o: None) == \
        with_checksum({"version": 1, "x": 5})
    assert not any(p.name.startswith("state.json.tmp")
                   for p in tmp_path.iterdir())
    # a validator rejection quarantines the file aside
    hits = []
    assert load_json_guarded(
        path, lambda o: (_ for _ in ()).throw(ValueError("bad")),
        on_corrupt=lambda dst, e: hits.append(dst)) is None
    assert hits and os.path.exists(hits[0])
    assert not os.path.exists(path)


CORRUPTIONS = ("truncate", "garbage", "version", "checksum")


@pytest.mark.parametrize("mode", CORRUPTIONS)
def test_plan_cache_corruption_matrix(tmp_path, mode):
    path = str(tmp_path / "plans.json")
    cache = PlanCache(path=path, thresholds=TH4)
    cache.fused_plan(LENET, 8)
    cache.save()
    FaultInjector.corrupt_json(path, mode)
    cache2 = PlanCache(path=path, thresholds=TH4)  # constructs, no raise
    assert cache2.corrupt_recoveries               # recovery recorded
    assert os.path.exists(path + ".corrupt")       # renamed aside
    _, _, hit = cache2.fused_plan(LENET, 8)
    assert not hit and cache2.planner_calls == 1   # rebuilt from scratch
    cache2.save()
    # restart after recovery: plans load, zero replanning
    cache3 = PlanCache(path=path, thresholds=TH4)
    _, _, hit = cache3.fused_plan(LENET, 8)
    assert hit and cache3.planner_calls == 0


@pytest.mark.parametrize("mode", CORRUPTIONS)
def test_thresholds_corruption_matrix(tmp_path, mode):
    path = str(tmp_path / "thresholds.json")
    calls = []

    def measure(l, lay):
        calls.append(1)
        return H.conv_cost(l, lay, 4).total_s

    th = measured_thresholds(path, dtype="float32", measure=measure)
    assert calls                                   # first sight: measured
    FaultInjector.corrupt_json(path, mode)
    calls.clear()
    hits = []
    th2 = measured_thresholds(path, dtype="float32", measure=measure,
                              on_corrupt=lambda dst, e: hits.append(dst))
    assert th2 == th                               # re-measured, same sweep
    assert calls                                   # corrupt row re-measured
    if mode != "version":
        # version-bump keeps valid JSON+checksum: handled as unknown
        # version (row missing), not quarantined
        assert hits and os.path.exists(hits[0])
    calls.clear()
    assert measured_thresholds(path, dtype="float32",
                               measure=measure) == th
    assert not calls                               # fresh file: loads clean


def test_server_recovers_from_corrupt_cache_and_restarts_clean(tmp_path):
    srv = make_server(tmp_path)
    srv.run(make_requests(srv.cfg, 16))
    buckets = sorted(srv.reports)
    FaultInjector.corrupt_json(str(tmp_path / "plans.json"), "garbage")
    srv2 = make_server(tmp_path)                   # constructs, no raise
    assert srv2.incidents.counts["corrupt_state"] == 1
    done = srv2.run(make_requests(srv2.cfg, 16))
    assert len(done) == 16
    assert srv2.cache.planner_calls == len(buckets)  # replanned once each
    # restart AFTER recovery: the rebuilt cache serves with zero planning
    srv3 = make_server(tmp_path)
    assert srv3.incidents.total == 0
    srv3.run(make_requests(srv3.cfg, 16))
    assert srv3.cache.planner_calls == 0


# ---------------------------------------------------------------------------
# FaultTolerantRunner restart fixes (ISSUE 9 satellite)
# ---------------------------------------------------------------------------

def _counting_step(fail_at):
    """Functional step: state['x'] += 1; fails ONCE at each step in
    ``fail_at`` (by attempt count)."""
    seen = {}

    def step_fn(state, step):
        if step in fail_at and not seen.get(step):
            seen[step] = True
            raise StepFailure(f"injected at {step}")
        return {"x": state["x"] + 1}, {}

    return step_fn


def test_runner_restart_without_checkpoint_resets_to_initial(tmp_path):
    """Nothing checkpointed when the step fails: replay must restart from
    the INITIAL state, not the partially-advanced binding (the pre-§14 bug
    produced x == total + progress-before-failure)."""
    runner = FaultTolerantRunner(Checkpointer(str(tmp_path),
                                              async_write=False),
                                 save_every=100)
    step, state = runner.run({"x": 0}, _counting_step({2}), total_steps=4)
    assert step == 4 and state["x"] == 4


def test_runner_restart_protects_against_inplace_mutation(tmp_path):
    """A step_fn that mutates state in place before failing must not
    poison the replay baseline (the snapshot is a deep copy)."""
    attempts = {"n": 0}

    def step_fn(state, step):
        if step == 0 and attempts["n"] == 0:
            attempts["n"] = 1
            state["x"] += 999                     # in-place, then fail —
            raise StepFailure("boom")             # hits the caller's dict
        return {"x": state["x"] + 10}, {}

    runner = FaultTolerantRunner(Checkpointer(str(tmp_path),
                                              async_write=False),
                                 save_every=100)
    _, state = runner.run({"x": 0}, step_fn, total_steps=3)
    assert state["x"] == 30                       # replayed from x=0


def test_runner_falls_back_to_next_oldest_checkpoint(tmp_path):
    """The latest checkpoint fails validation: restore walks back to the
    next-oldest instead of dying (latest-only was the pre-§14 behavior)."""
    ck = Checkpointer(str(tmp_path), async_write=False)
    runner = FaultTolerantRunner(ck, save_every=2, keep=5)
    step_fn = _counting_step({5})
    # seed two good checkpoints, then corrupt the newer one's manifest
    state = {"x": 0}
    for s in range(4):
        state, _ = step_fn(state, s)
        if (s + 1) % 2 == 0:
            ck.save(s + 1, state)
    (tmp_path / "step_0000000004" / "manifest.json").write_text("not json")
    step, state = runner.run(state, step_fn, total_steps=6, start_step=4)
    assert step == 6 and state["x"] == 6
    assert ck.steps()                              # store still usable


def test_checkpointer_steps_listing(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    assert ck.steps() == []
    for s in (4, 2, 8):
        ck.save(s, {"x": np.float32(s)})
    assert ck.steps() == [2, 4, 8]
    assert ck.latest_step() == 8
