"""Per-kernel allclose vs pure-jnp oracles: shape & dtype sweeps
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

KEY = jax.random.PRNGKey(42)


# --------------------------------------------------------------------------
# transpose (paper §IV.C)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(256, 384), (100, 130), (8, 4096),
                                   (31, 7), (1, 1), (129, 257)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_transpose2d(shape, dtype):
    from repro.kernels.transpose.ops import transpose2d
    from repro.kernels.transpose.ref import transpose2d_ref
    x = jax.random.normal(KEY, shape, jnp.float32).astype(dtype)
    np.testing.assert_array_equal(np.asarray(transpose2d(x)),
                                  np.asarray(transpose2d_ref(x)))


@pytest.mark.parametrize("shape", [(3, 50, 70), (2, 128, 128), (5, 17, 9)])
def test_transpose2d_batched(shape):
    from repro.kernels.transpose.ops import transpose2d_batched
    x = jax.random.normal(KEY, shape)
    np.testing.assert_array_equal(np.asarray(transpose2d_batched(x)),
                                  np.swapaxes(np.asarray(x), 1, 2))


def test_transpose_block_alignment():
    """Block picker honors dtype-native tiles (the float2 analogue)."""
    from repro.kernels.transpose.ops import pick_blocks
    bm32, _ = pick_blocks(4096, 4096, jnp.float32)
    bm16, _ = pick_blocks(4096, 4096, jnp.bfloat16)
    assert bm32 % 8 == 0 and bm16 % 16 == 0


# --------------------------------------------------------------------------
# fused softmax (paper §V.B)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n,c", [(128, 10), (64, 1000), (37, 513), (1, 10000),
                                 (128, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_softmax_fused(n, c, dtype):
    from repro.kernels.softmax.ops import softmax
    from repro.kernels.softmax.ref import softmax_5step_ref, softmax_ref
    x = (jax.random.normal(KEY, (n, c)) * 5).astype(dtype)
    got = np.asarray(softmax(x), np.float32)
    np.testing.assert_allclose(got, np.asarray(softmax_ref(x), np.float32),
                               atol=2e-3 if dtype == jnp.bfloat16 else 1e-6)
    # the fused kernel equals the paper's literal 5-step pipeline
    np.testing.assert_allclose(
        got, np.asarray(softmax_5step_ref(x), np.float32),
        atol=2e-3 if dtype == jnp.bfloat16 else 1e-6)
    # bf16 probabilities round to ~3 decimal digits; sums drift O(1e-2)
    np.testing.assert_allclose(got.sum(-1), 1.0,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-3)


@pytest.mark.parametrize("n,c", [(128, 10), (64, 1000)])
def test_softmax_xent(n, c):
    from repro.kernels.softmax.ops import softmax_xent
    from repro.kernels.softmax.ref import softmax_xent_ref
    x = jax.random.normal(KEY, (n, c)) * 3
    lab = jax.random.randint(KEY, (n,), 0, c)
    np.testing.assert_allclose(np.asarray(softmax_xent(x, lab)),
                               np.asarray(softmax_xent_ref(x, lab)), rtol=1e-5)


# --------------------------------------------------------------------------
# pooling (paper §V.A) — window reuse + both layouts
# --------------------------------------------------------------------------
POOL_CASES = [(16, 28, 28, 128, 2, 2, "max"), (64, 24, 24, 128, 3, 2, "avg"),
              pytest.param(96, 55, 55, 64, 3, 2, "max",
                           marks=pytest.mark.slow),   # paper-size PL5/PL8
              (16, 14, 14, 32, 2, 2, "avg"),
              (8, 13, 13, 32, 3, 2, "max")]


@pytest.mark.parametrize("C,H,W,N,F,S,op", POOL_CASES)
def test_pool_chwn(C, H, W, N, F, S, op):
    from repro.kernels.pool.ops import pool_chwn
    from repro.kernels.pool.ref import pool_ref
    x = jax.random.normal(KEY, (C, H, W, N))
    np.testing.assert_allclose(np.asarray(pool_chwn(x, F, S, op)),
                               np.asarray(pool_ref(x, F, S, op, "CHWN")),
                               atol=1e-5)


@pytest.mark.parametrize("C,H,W,N,F,S,op", POOL_CASES[:3])
def test_pool_nchw(C, H, W, N, F, S, op):
    from repro.kernels.pool.ops import pool_nchw
    from repro.kernels.pool.ref import pool_ref
    x = jax.random.normal(KEY, (N, C, H, W))
    np.testing.assert_allclose(np.asarray(pool_nchw(x, F, S, op)),
                               np.asarray(pool_ref(x, F, S, op, "NCHW")),
                               atol=1e-5)


def test_pool_autotune_hill_climb():
    """The §V.A hill climb stops at the first measured regression."""
    from repro.kernels.pool.ops import autotune_nt
    costs = {128: 10.0, 256: 8.0, 512: 6.0, 1024: 9.0}
    nt = autotune_nt(28, 28, 4096, 4, measure=lambda c: costs.get(c, 99.0))
    assert nt == 512


# --------------------------------------------------------------------------
# matmul
# --------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [(256, 256, 256), (100, 300, 50),
                                   (8, 1024, 128), (1, 7, 3)])
def test_matmul(m, k, n):
    from repro.kernels.matmul.ops import matmul
    from repro.kernels.matmul.ref import matmul_ref
    x = jax.random.normal(KEY, (m, k))
    y = jax.random.normal(jax.random.PRNGKey(7), (k, n))
    np.testing.assert_allclose(np.asarray(matmul(x, y)),
                               np.asarray(matmul_ref(x, y)),
                               rtol=2e-5, atol=2e-4)


# --------------------------------------------------------------------------
# direct conv (CHWN) + im2col (NCHW) + FFT
# --------------------------------------------------------------------------
CONV_CASES = [(1, 28, 28, 32, 5, 16, 1, 0), (16, 14, 14, 64, 5, 16, 1, 2),
              (3, 32, 32, 32, 3, 8, 2, 0), (8, 13, 13, 32, 3, 16, 1, 1)]


@pytest.mark.parametrize("Ci,H,W,N,F,Co,S,pad", CONV_CASES)
def test_conv_direct_chwn(Ci, H, W, N, F, Co, S, pad):
    from repro.kernels.conv.ops import conv_direct_chwn
    from repro.kernels.conv.ref import conv_chwn_ref
    x = jax.random.normal(KEY, (Ci, H, W, N))
    w = jax.random.normal(jax.random.PRNGKey(3), (Ci, F, F, Co)) * 0.1
    np.testing.assert_allclose(
        np.asarray(conv_direct_chwn(x, w, stride=S, pad=pad)),
        np.asarray(conv_chwn_ref(x, w, stride=S, pad=pad)),
        rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("Ci,H,W,N,F,Co,S,pad", CONV_CASES)
def test_conv_im2col_and_fft(Ci, H, W, N, F, Co, S, pad):
    from repro.kernels.conv.ops import conv_fft_nchw, conv_im2col_nchw
    from repro.kernels.conv.ref import conv_nchw_ref
    x = jax.random.normal(KEY, (N, Ci, H, W))
    w = jax.random.normal(jax.random.PRNGKey(3), (Co, Ci, F, F)) * 0.1
    ref = np.asarray(conv_nchw_ref(x, w, stride=S, pad=pad))
    np.testing.assert_allclose(
        np.asarray(conv_im2col_nchw(x, w, stride=S, pad=pad)), ref,
        rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(
        np.asarray(conv_fft_nchw(x, w, stride=S, pad=pad)), ref,
        rtol=1e-3, atol=1e-2)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize("bh,s,d,causal", [(4, 256, 64, True),
                                           (2, 128, 32, False),
                                           (6, 512, 128, True)])
def test_flash_attention(bh, s, d, causal):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    q = jax.random.normal(KEY, (bh, s, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (bh, s, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (bh, s, d))
    np.testing.assert_allclose(
        np.asarray(flash_attention(q, k, v, causal=causal, bq=64, bk=64)),
        np.asarray(attention_ref(q, k, v, causal=causal)),
        rtol=1e-4, atol=1e-4)


def test_flash_attention_4d():
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    q = jax.random.normal(KEY, (2, 3, 128, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 128, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 128, 64))
    got = flash_attention(q, k, v, causal=True)
    ref = attention_ref(q.reshape(6, 128, 64), k.reshape(6, 128, 64),
                        v.reshape(6, 128, 64), causal=True).reshape(2, 3, 128, 64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# fused cross entropy (streamed unembed)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("t,v,d,cap", [(64, 1000, 128, None),
                                       (128, 513, 64, None),
                                       (32, 2000, 96, 30.0),
                                       (16, 128, 32, None)])
def test_fused_xent(t, v, d, cap):
    from repro.kernels.crossentropy.ops import fused_xent
    from repro.kernels.crossentropy.ref import xent_ref
    h = jax.random.normal(KEY, (t, d))
    table = jax.random.normal(jax.random.PRNGKey(1), (v, d)) * 0.05
    lab = jax.random.randint(KEY, (t,), 0, v)
    np.testing.assert_allclose(
        np.asarray(fused_xent(h, table, lab, bv=256, softcap=cap)),
        np.asarray(xent_ref(h, table, lab, softcap=cap)),
        rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# fused execution engine (DESIGN.md §5): conv epilogues + layout-fused I/O
# --------------------------------------------------------------------------
def _fused_chwn_ref(x, w, S, pad, bias, relu, pool):
    """Unfused oracle: conv -> (+bias) -> (relu) -> (pool), all in CHWN."""
    from repro.kernels.conv.ref import conv_chwn_ref
    from repro.kernels.pool.ref import pool_ref
    y = conv_chwn_ref(x, w, stride=S, pad=pad).astype(jnp.float32)
    if bias is not None:
        y = y + bias[:, None, None, None]
    if relu:
        y = jnp.maximum(y, 0.0)
    if pool is not None:
        y = pool_ref(y, pool[0], pool[1], pool[2], "CHWN")
    return y


FUSED_CASES = [  # Ci, H, W, N, F, Co, S, pad, pool
    (3, 16, 16, 8, 3, 16, 1, 1, (2, 2, "max")),
    (3, 16, 16, 8, 3, 16, 1, 1, (3, 2, "max")),     # overlapping windows
    (16, 14, 14, 4, 5, 32, 2, 2, (2, 2, "avg")),    # stride-2 conv
    (8, 13, 13, 6, 3, 16, 1, 0, None),              # bias+relu only
]


@pytest.mark.parametrize("Ci,H,W,N,F,Co,S,pad,pool", FUSED_CASES)
@pytest.mark.parametrize("dst", ["CHWN", "NCHW"])
def test_conv_chwn_fused_epilogue(Ci, H, W, N, F, Co, S, pad, pool, dst):
    """conv+bias+relu(+pool) as ONE kernel == the unfused chain, and the
    dst_layout write equals apply_transform after the chain."""
    from repro.kernels.conv.ops import conv_direct_chwn
    x = jax.random.normal(KEY, (Ci, H, W, N))
    w = jax.random.normal(jax.random.PRNGKey(3), (Ci, F, F, Co)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(5), (Co,)) * 0.5
    ref = _fused_chwn_ref(x, w, S, pad, b, True, pool)
    got = conv_direct_chwn(x, w, stride=S, pad=pad, bias=b, relu=True,
                           pool=pool, dst_layout=dst)
    if dst == "NCHW":
        got = jnp.transpose(got, (1, 2, 3, 0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("Ci,H,W,N,F,Co,S,pad,pool", FUSED_CASES[:2])
def test_conv_chwn_src_layout_fusion(Ci, H, W, N, F, Co, S, pad, pool):
    """The CHWN kernel consumes NCHW input directly (the folded transform
    the network pays at its entry)."""
    from repro.kernels.conv.ops import conv_direct_chwn
    x = jax.random.normal(KEY, (Ci, H, W, N))
    w = jax.random.normal(jax.random.PRNGKey(3), (Ci, F, F, Co)) * 0.1
    ref = _fused_chwn_ref(x, w, S, pad, None, True, pool)
    got = conv_direct_chwn(jnp.transpose(x, (3, 0, 1, 2)), w, stride=S,
                           pad=pad, relu=True, pool=pool, src_layout="NCHW")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("Ci,H,W,N,F,Co,S,pad,pool", FUSED_CASES)
@pytest.mark.parametrize("dst", ["NCHW", "CHWN"])
def test_conv_nchw_native_fused(Ci, H, W, N, F, Co, S, pad, pool, dst):
    """The native im2col-MM NCHW Pallas conv (no XLA expansion) with the
    same epilogue protocol and layout-fused output."""
    from repro.kernels.conv.ops import conv_im2col_nchw_fused
    from repro.kernels.conv.ref import conv_nchw_ref
    from repro.kernels.pool.ref import pool_ref
    x = jax.random.normal(KEY, (N, Ci, H, W))
    w = jax.random.normal(jax.random.PRNGKey(3), (Co, Ci, F, F)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(5), (Co,)) * 0.5
    ref = conv_nchw_ref(x, w, stride=S, pad=pad).astype(jnp.float32)
    ref = jnp.maximum(ref + b[None, :, None, None], 0.0)
    if pool is not None:
        ref = pool_ref(ref, pool[0], pool[1], pool[2], "NCHW")
    got = conv_im2col_nchw_fused(x, w, stride=S, pad=pad, bias=b, relu=True,
                                 pool=pool, dst_layout=dst)
    if dst == "CHWN":
        got = jnp.transpose(got, (3, 0, 1, 2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_conv_nchw_native_matches_im2col_baseline():
    """Plain native NCHW conv == the seed's XLA-expansion im2col path."""
    from repro.kernels.conv.ops import conv_im2col_nchw, conv_im2col_nchw_fused
    x = jax.random.normal(KEY, (4, 8, 13, 13))
    w = jax.random.normal(jax.random.PRNGKey(3), (16, 8, 3, 3)) * 0.1
    np.testing.assert_allclose(
        np.asarray(conv_im2col_nchw_fused(x, w, stride=2, pad=1)),
        np.asarray(conv_im2col_nchw(x, w, stride=2, pad=1)),
        rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("C,H,W,N,F,S,op", POOL_CASES[:3])
def test_pool_dst_layout_fusion(C, H, W, N, F, S, op):
    """Pool kernels write directly in the consumer's layout: the fused
    output equals apply_transform after the unfused pool."""
    from repro.kernels.pool.ops import pool_chwn, pool_nchw
    from repro.kernels.pool.ref import pool_ref
    x = jax.random.normal(KEY, (C, H, W, N))
    got = pool_chwn(x, F, S, op, dst_layout="NCHW")
    ref = jnp.transpose(pool_ref(x, F, S, op, "CHWN"), (3, 0, 1, 2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
    xn = jax.random.normal(KEY, (N, C, H, W))
    got = pool_nchw(xn, F, S, op, dst_layout="CHWN")
    ref = jnp.transpose(pool_ref(xn, F, S, op, "NCHW"), (1, 2, 3, 0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_pool_tiles_block_gate():
    """The pool epilogue is only fused when its windows tile the conv-output
    row block (whole-height blocks always qualify)."""
    from repro.kernels.conv.conv import pool_tiles_block
    assert pool_tiles_block(4, 3, 2, 2)          # aligned, non-overlapping
    assert not pool_tiles_block(4, 3, 3, 2)      # overlapping, crosses seams
    assert pool_tiles_block(12, 1, 3, 2)         # one block: always tiles
    assert not pool_tiles_block(2, 3, 3, 2)      # window taller than block


@pytest.mark.parametrize("Ci,H,Co,F,S,pad", [
    (1, 7, 8, 5, 1, 0),      # Ho=3 < ceil((F-S)/S)=4: whole-height fallback
    (3, 9, 8, 7, 1, 0),      # Ho=3 < 6
    (2, 6, 4, 5, 2, 1),      # strided small-Ho case
])
def test_conv_small_output_height_halo(Ci, H, Co, F, S, pad):
    """Output heights below ceil((F-S)/S) force bho < min_bho; the widened
    input row block must still cover the window span (regression: the two
    stitched bho*S blocks were too short and the tap loop crashed)."""
    from repro.kernels.conv.ops import conv_direct_chwn, conv_im2col_nchw_fused
    from repro.kernels.conv.ref import conv_chwn_ref, conv_nchw_ref
    x = jax.random.normal(KEY, (2, Ci, H, H))
    w = jax.random.normal(jax.random.PRNGKey(3), (Co, Ci, F, F)) * 0.1
    np.testing.assert_allclose(
        np.asarray(conv_im2col_nchw_fused(x, w, stride=S, pad=pad)),
        np.asarray(conv_nchw_ref(x, w, stride=S, pad=pad)),
        rtol=1e-4, atol=1e-4)
    xc = jnp.transpose(x, (1, 2, 3, 0))
    wc = jnp.transpose(w, (1, 2, 3, 0))
    np.testing.assert_allclose(
        np.asarray(conv_direct_chwn(xc, wc, stride=S, pad=pad)),
        np.asarray(conv_chwn_ref(xc, wc, stride=S, pad=pad)),
        rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("Ci,H,F,Co,S,pad", [
    (3, 8, 1, 4, 1, 0),      # 1x1 conv
    (3, 8, 2, 4, 2, 0),      # patchify: F == S
    (3, 8, 1, 4, 2, 0),      # F < S
    (3, 9, 3, 4, 3, 0),
])
def test_conv_small_filter_no_spurious_rows(Ci, H, F, Co, S, pad):
    """F <= S convs: the halo row padding must not leak extra output row
    blocks (regression: the engines recomputed Ho from the padded input and
    the wrappers only sliced channels, returning garbage trailing rows)."""
    from repro.kernels.conv.ops import conv_direct_chwn, conv_im2col_nchw_fused
    from repro.kernels.conv.ref import conv_chwn_ref, conv_nchw_ref
    x = jax.random.normal(KEY, (2, Ci, H, H))
    w = jax.random.normal(jax.random.PRNGKey(3), (Co, Ci, F, F)) * 0.1
    ref = conv_nchw_ref(x, w, stride=S, pad=pad)
    got = conv_im2col_nchw_fused(x, w, stride=S, pad=pad)
    assert got.shape == ref.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    xc, wc = jnp.transpose(x, (1, 2, 3, 0)), jnp.transpose(w, (1, 2, 3, 0))
    refc = conv_chwn_ref(xc, wc, stride=S, pad=pad)
    gotc = conv_direct_chwn(xc, wc, stride=S, pad=pad)
    assert gotc.shape == refc.shape
    np.testing.assert_allclose(np.asarray(gotc), np.asarray(refc),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("Ci,Co", [(48, 16), (32, 200), (48, 200)])
def test_conv_channels_not_tile_divisible(Ci, Co):
    """Ci/Co that don't divide the channel tiles (32/128) are zero-padded,
    not silently truncated (regression: grid floor-division dropped them)."""
    from repro.kernels.conv.ops import conv_direct_chwn, conv_im2col_nchw_fused
    from repro.kernels.conv.ref import conv_chwn_ref, conv_nchw_ref
    x = jax.random.normal(KEY, (2, Ci, 8, 8))
    w = jax.random.normal(jax.random.PRNGKey(3), (Co, Ci, 3, 3)) * 0.1
    np.testing.assert_allclose(
        np.asarray(conv_im2col_nchw_fused(x, w, stride=1, pad=1)),
        np.asarray(conv_nchw_ref(x, w, stride=1, pad=1)),
        rtol=1e-4, atol=1e-4)
    xc = jnp.transpose(x, (1, 2, 3, 0))
    wc = jnp.transpose(w, (1, 2, 3, 0))
    np.testing.assert_allclose(
        np.asarray(conv_direct_chwn(xc, wc, stride=1, pad=1)),
        np.asarray(conv_chwn_ref(xc, wc, stride=1, pad=1)),
        rtol=1e-4, atol=1e-4)
