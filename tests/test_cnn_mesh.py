"""Multi-chip CNN serving mesh (ISSUE 10 / DESIGN.md §15): shard-batch
planning invariant, PlanCache ``devices`` keying + legacy-file roundtrip,
sharded-vs-single-device ``forward_fused`` differentials, and the sharded
server smoke.

Planner/cache tests are pure arithmetic and run on any host (tier-1).
The subprocess differential forces fake host devices via
``tests.util.run_with_devices`` so it ALSO runs on 1-device tier-1; the
in-process ``multidevice``-marked differentials and server smoke need the
mesh CI job (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.cnn_networks import LENET
from repro.cnn.layers import init_cnn
from repro.cnn.network import forward_fused, input_shape, plan_network_fused
from repro.distributed.cnn_mesh import (ShardPlanError, cnn_data_mesh,
                                        forward_fused_sharded,
                                        replicate_params, shard_batch_for,
                                        shard_flip, verify_shard_plan)
from repro.perfmodel import calibrate
from repro.serve import PlanCache, pad_to_bucket
from tests.util import run_with_devices


# ---------------------------------------------------------------------------
# shard-batch planning invariant (pure planner arithmetic, tier-1)
# ---------------------------------------------------------------------------

def test_shard_batch_for_ceil_and_validation():
    assert shard_batch_for(128, 8) == 16
    assert shard_batch_for(9, 4) == 3          # ceil: last shard padded
    assert shard_batch_for(1, 1) == 1
    assert shard_batch_for(7, 8) == 1
    with pytest.raises(ValueError):
        shard_batch_for(0, 1)
    with pytest.raises(ValueError):
        shard_batch_for(8, 0)


def test_per_shard_nt_flip_is_taken_not_inherited():
    """The property at the heart of §15: a global batch above Nt whose
    per-shard batch falls below it MUST replan — lenet at fp32 has Nt=64,
    so 128 globally is CHWN-side while 128/8=16 per shard is NCHW-side."""
    gsig, ssig = shard_flip(LENET, 128, 8)
    assert gsig != ssig, "expected sharding to flip the layout choice"
    splan = plan_network_fused(LENET.replace(batch=16))
    verify_shard_plan(splan, LENET, 16)        # the shard-batch plan passes
    gplan = plan_network_fused(LENET.replace(batch=128))
    with pytest.raises(ShardPlanError):
        verify_shard_plan(gplan, LENET, 16)    # the leaked global plan fails


def test_plan_cache_devices_key_hit_miss():
    cache = PlanCache(thresholds=calibrate(dtype_bytes=4))
    # sharded admission plans the PER-SHARD bucket
    p1, b1, hit1 = cache.fused_plan(LENET, 128, devices=8)
    assert b1 == 16 and not hit1 and cache.planner_calls == 1
    # re-admission at the same (bucket, devices) hits — compile once
    p2, b2, hit2 = cache.fused_plan(LENET, 128, devices=8)
    assert hit2 and b2 == 16 and cache.planner_calls == 1
    assert p2.conv_signature == p1.conv_signature
    # the pre-sharded entry point (callers already holding the per-shard
    # batch) must resolve to the SAME key the global-batch call planned —
    # dividing by devices twice would miss into a bogus bucket-2 key
    p1s, b1s, hit1s = cache.fused_plan(LENET, 16, devices=8,
                                       pre_sharded=True)
    assert hit1s and b1s == 16 and cache.planner_calls == 1
    assert p1s is p1
    assert cache.peek_fused(LENET, 16, devices=8, pre_sharded=True) is p1
    # same shard bucket at a DIFFERENT mesh width is its own key: an
    # 8-chip row must not silently serve from the 4-chip entry
    _, b3, hit3 = cache.fused_plan(LENET, 64, devices=4)
    assert b3 == 16 and not hit3 and cache.planner_calls == 2
    # unsharded admission of the same global batch plans the global
    # bucket — and takes the other side of the Nt flip
    p4, b4, hit4 = cache.fused_plan(LENET, 128)
    assert b4 == 128 and not hit4
    assert p4.conv_signature != p1.conv_signature
    with pytest.raises(ValueError):
        cache.fused_plan(LENET, 16, devices=0)


def test_plan_cache_devices_legacy_file_roundtrip(tmp_path):
    path = str(tmp_path / "plans.json")
    cache = PlanCache(path=path, thresholds=calibrate(dtype_bytes=4))
    cache.fused_plan(LENET, 8)                  # single-chip (legacy) key
    cache.fused_plan(LENET, 64, devices=4)      # mesh key
    cache.save()
    # single-chip keys serialize WITHOUT the devices field, so a cache
    # holding only devices=1 plans is byte-compatible with pre-§15 files;
    # the mesh key carries devices=4 explicitly
    payload = json.load(open(path))
    keys = [e["key"] for e in payload["fused"]]
    assert sum("devices" in k for k in keys) == 1
    assert {k.get("devices", 1) for k in keys} == {1, 4}
    loaded = PlanCache(path=path)
    _, _, h1 = loaded.fused_plan(LENET, 8)
    _, _, h2 = loaded.fused_plan(LENET, 64, devices=4)
    assert h1 and h2 and loaded.planner_calls == 0


# ---------------------------------------------------------------------------
# sharded-vs-single differential, subprocess (runs on 1-device tier-1 too)
# ---------------------------------------------------------------------------

def test_sharded_forward_matches_unsharded_subprocess():
    out = run_with_devices("""
import jax, jax.numpy as jnp
from repro.configs.cnn_networks import LENET
from repro.cnn.layers import init_cnn
from repro.cnn.network import forward_fused, input_shape, plan_network_fused
from repro.distributed.cnn_mesh import (cnn_data_mesh, forward_fused_sharded,
                                        replicate_params, verify_shard_plan)

D, shard = 4, 4
cfg = LENET.replace(batch=shard * D)
scfg = LENET.replace(batch=shard)
plan = plan_network_fused(scfg)
verify_shard_plan(plan, LENET, shard)
params = init_cnn(jax.random.PRNGKey(0), scfg)
x = jax.random.normal(jax.random.PRNGKey(1), input_shape(cfg), jnp.float32)

mesh = cnn_data_mesh(D)
ys = forward_fused_sharded(replicate_params(params, mesh), x, scfg, plan,
                           mesh, impl="pallas", interpret=True)
# unsharded reference: the same per-shard plan applied shard by shard
# (bit-identical blocking), and the global-batch plan (numerical check)
yr = jnp.concatenate([forward_fused(params, x[i*shard:(i+1)*shard], scfg,
                                    plan, impl="pallas", interpret=True)[0]
                      for i in range(D)])
yg, _ = forward_fused(params, x, cfg, plan_network_fused(cfg), impl="pallas",
                      interpret=True)
print("maxdiff_shardplan=%.3e" % float(jnp.abs(ys - yr).max()))
print("maxdiff_globalplan=%.3e" % float(jnp.abs(ys - yg).max()))
""", n_devices=4)
    diffs = dict(line.split("=") for line in out.split() if "=" in line)
    assert float(diffs["maxdiff_shardplan"]) <= 1e-5
    assert float(diffs["maxdiff_globalplan"]) <= 1e-5


# ---------------------------------------------------------------------------
# in-process multi-device tier (mesh CI job)
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
@pytest.mark.parametrize("devices", [2, 4, 8])
@pytest.mark.parametrize("policy", ["uniform", "mixed"])
def test_sharded_matches_unsharded(multi_devices, devices, policy):
    if devices > multi_devices:
        pytest.skip(f"host exposes {multi_devices} devices, need {devices}")
    shard = 2
    scfg = LENET.replace(batch=shard)
    cfg = LENET.replace(batch=shard * devices)
    plan = plan_network_fused(scfg, policy=policy)
    params = init_cnn(jax.random.PRNGKey(0), scfg)
    x = jax.random.normal(jax.random.PRNGKey(devices), input_shape(cfg),
                          jnp.float32)
    mesh = cnn_data_mesh(devices)
    ys = forward_fused_sharded(replicate_params(params, mesh), x, scfg,
                               plan, mesh, impl="xla")
    yr = jnp.concatenate([
        forward_fused(params, x[i * shard:(i + 1) * shard], scfg, plan,
                      impl="xla")[0] for i in range(devices)])
    assert float(jnp.abs(ys - yr).max()) <= 1e-5
    assert ys.shape == (shard * devices, LENET.num_classes)


@pytest.mark.multidevice
def test_sharded_server_smoke(multi_devices, tmp_path):
    """CNNServer --devices path end to end: per-shard bucket admission,
    zero drops, zero repeat replans, per-chip accounting populated."""
    from repro.launch.cnn_serve import CNNServer, ImageRequest
    d = min(multi_devices, 4)
    srv = CNNServer("lenet", max_bucket=8, impl="xla",
                    calibration="analytic", devices=d,
                    cache_path=str(tmp_path / "plans.json"))
    rng = np.random.default_rng(0)
    c, h = srv.cfg.in_channels, srv.cfg.image_hw
    reqs = [ImageRequest(i, rng.standard_normal((c, h, h)).astype(np.float32))
            for i in range(3 * d + 1)]
    done = srv.run(reqs)
    assert len(done) == len(reqs)
    rr = sum(max(0, st.misses - 1) for st in srv.cache.per_key.values())
    assert rr == 0
    assert all(k.devices == d for k in srv.cache.per_key)
    # every cached key's bucket is an ADMITTED shard bucket — a planner or
    # executor dividing by devices twice would mint a bogus smaller key
    # (devices=d, one miss each), invisible to the rr/devices checks above
    assert {k.bucket for k in srv.cache.per_key} == set(srv.reports)
    assert any(rep.per_chip_bytes > 0 for rep in srv.reports.values())
    # every executed global batch is shard_bucket * devices wide, and the
    # plan the executor ran IS the shard-batch plan: the global-batch and
    # pre-sharded cache entry points resolve to one entry, and that plan
    # passes the §15 shard invariant at the executed shard bucket
    for b, rep in srv.reports.items():
        assert rep.hbm_bytes == rep.per_chip_bytes * d
        plan = srv.cache.peek_fused(srv.cfg, b, dtype=srv.dtype,
                                    policy=srv.dtype_policy, devices=d,
                                    pre_sharded=True)
        assert plan is not None
        assert plan is srv.cache.peek_fused(srv.cfg, b * d,
                                            dtype=srv.dtype,
                                            policy=srv.dtype_policy,
                                            devices=d)
        verify_shard_plan(plan, srv.cfg, b, dtype=srv.dtype,
                          policy=srv.dtype_policy)
