"""Per-layer mixed-dtype planning + int8 storage engine (ISSUE 5).

Covers the (layout, dtype) DP (dtype as a third DP state dimension), the
int8 sublane/tile model, cast-edge pricing, the straight-through int8
training path, the real-int8 fused inference path on the Pallas engines,
and the policy-keyed plan cache.  The small int8 fused-forward differential
doubles as the tier-1 CI smoke for quantization regressions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CNNConfig, ConvSpec
from repro.configs.cnn_networks import ALEXNET, CNN_CONFIGS, LENET, VGG16
from repro.cnn.layers import init_cnn
from repro.cnn.network import (forward_fused, init_velocity, input_shape,
                               make_train_step_fused, network_descs,
                               plan_network_fused)
from repro.core import heuristic as H
from repro.core.heuristic import cast_bytes, cast_cost
from repro.core.selector import assign_layouts, plan_fused
from repro.dtypes import canon_dtype, dtype_bytes, is_float_dtype, jnp_dtype
from repro.quant import (INT8_FORWARD_ATOL, dequantize, fake_quant,
                         fold_scale_into_weights, quantize)
from repro.serve import PlanCache, measured_thresholds
from repro.serve.calibration import load_thresholds

KEY = jax.random.PRNGKey(0)


def _conv(name, co, k, s=1, p=0):
    return ConvSpec(name, "conv", out_channels=co, kernel=k, stride=s, pad=p)


def _pool(name, k, s, op="max"):
    return ConvSpec(name, "pool", kernel=k, stride=s, pool_op=op)


# three conv chains: the middle one's output is int8-eligible (producer and
# consumer are both conv chains, and it is not the first chain)
NET3 = CNNConfig(
    name="net3", batch=2, in_channels=3, image_hw=16, num_classes=10,
    layers=(
        _conv("conv1", 16, 3, 1, 1), ConvSpec("relu1", "relu"),
        _pool("pool1", 2, 2),
        _conv("conv2", 32, 3, 1, 1), ConvSpec("relu2", "relu"),
        _conv("conv3", 32, 3, 1, 1), ConvSpec("relu3", "relu"),
        _pool("pool2", 2, 2),
        ConvSpec("flatten", "flatten"),
        ConvSpec("fc1", "fc", fc_out=10),
        ConvSpec("softmax", "softmax"),
    ))


# ---------------------------------------------------------------------------
# int8 plumbing: dtype table, sublanes, tile utilization, cast edges
# ---------------------------------------------------------------------------

def test_int8_dtype_table():
    assert canon_dtype("int8") == canon_dtype("i8") == "int8"
    assert dtype_bytes("int8") == 1
    assert jnp_dtype("int8") == jnp.int8
    assert not is_float_dtype("int8") and is_float_dtype("bf16")


def test_int8_sublane_table():
    """1-byte elements pack 32 sublanes per tile (4 -> 8, 2 -> 16, 1 -> 32),
    so the same shape utilizes tiles differently per storage dtype."""
    assert H._sublanes(4) == 8 and H._sublanes(2) == 16
    assert H._sublanes(1) == 32
    assert H.tile_utilization((32, 128), 1) == 1.0
    assert H.tile_utilization((16, 128), 1) == 0.5
    assert H.tile_utilization((16, 128), 2) == 1.0
    assert H.tile_utilization((8, 128), 1) == 0.25
    assert H.tile_utilization((8, 128), 4) == 1.0


def test_cast_edge_cost_symmetry():
    """A standalone cast pass reads src + writes dst: symmetric in (src,
    dst) — quantize costs exactly what its dequantize costs."""
    shape = (8, 64, 13, 13)
    n = int(np.prod(shape))
    for a, b in ((4, 1), (2, 1), (4, 2)):
        assert cast_bytes(shape, a, b) == cast_bytes(shape, b, a) == \
            n * (a + b)
        assert cast_cost(shape, a, b) == cast_cost(shape, b, a) > 0.0
    assert cast_bytes((), 4, 1) == 0


# ---------------------------------------------------------------------------
# quantization helpers
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(KEY, (16, 8, 8, 4), jnp.float32) * 3.0
    q, scale = quantize(x, 0)
    assert q.dtype == jnp.int8 and scale.shape == (16,)
    xr = dequantize(q, scale, 0)
    # per-channel bound: |x - deq(q(x))| <= scale/2
    bound = np.asarray(scale)[:, None, None, None] / 2 + 1e-7
    assert np.all(np.abs(np.asarray(xr - x)) <= bound)


def test_fold_scale_into_weights_exact():
    """conv(q * s[ci], w) == conv(q, s[ci] * w[ci]) — the per-channel scale
    factors out of the channel contraction exactly."""
    from repro.cnn.layers import conv_forward
    x = jax.random.normal(KEY, (2, 8, 6, 6), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 3, 3), jnp.float32)
    q, scale = quantize(x, 1)
    y_deq = conv_forward(dequantize(q, scale, 1), w, "NCHW", impl="xla")
    y_fold = conv_forward(q, fold_scale_into_weights(w, scale), "NCHW",
                          impl="xla")
    np.testing.assert_allclose(np.asarray(y_fold), np.asarray(y_deq),
                               atol=1e-5)


def test_fake_quant_straight_through_gradient():
    x = jax.random.normal(KEY, (4, 3, 5, 5), jnp.float32)
    g = jax.grad(lambda t: jnp.sum(fake_quant(t, 1) ** 2))(x)
    # STE: d/dx sum(fq(x)^2) == 2*fq(x) exactly (identity through the cast)
    np.testing.assert_allclose(np.asarray(g),
                               np.asarray(2 * fake_quant(x, 1)), atol=1e-6)


# ---------------------------------------------------------------------------
# the (layout, dtype) DP
# ---------------------------------------------------------------------------

def test_mixed_dp_never_worse_than_uniform():
    """Mixed plan cost/bytes <= every uniform FLOAT plan on the paper
    networks: the uniform-base path is in the mixed search space, and fp32
    can never beat a bf16-based mixed plan on bytes.  Uniform int8 is NOT a
    feasible execution (host input and classifier head cannot store int8),
    so it enters as the unreachable LOWER bound the mixed plan must stay
    above — the DP is sandwiched, never magical."""
    for cfg in CNN_CONFIGS.values():
        m = plan_network_fused(cfg, dtype="bf16", policy="mixed")
        # stacking (DESIGN.md §12) is gated OUT of the mixed search space,
        # so the dominance claim is over stack-off uniform plans
        u16 = plan_network_fused(cfg, dtype="bf16", stack_policy="off")
        u32 = plan_network_fused(cfg, dtype="float32", stack_policy="off")
        u8 = plan_network_fused(cfg, dtype="int8", stack_policy="off")
        assert m.total_s <= min(u16.total_s, u32.total_s), cfg.name
        assert m.fused_bytes <= min(u16.fused_bytes, u32.fused_bytes), \
            cfg.name
        assert u8.fused_bytes <= m.fused_bytes, cfg.name


def test_mixed_dp_places_int8_interior():
    """AlexNet/VGG16 acceptance: >= 2 distinct storage dtypes across conv
    layers, int8 strictly interior (first chain and the classifier-feeding
    chain stay at base), bytes strictly below uniform bf16."""
    for cfg, n_int8 in ((ALEXNET, 3), (VGG16, 11)):
        m = plan_network_fused(cfg, dtype="bf16", policy="mixed")
        # mixed plans never stack; compare against the stack-off uniform
        # plan (a stack can legitimately move a conv's layout)
        u16 = plan_network_fused(cfg, dtype="bf16", stack_policy="off")
        sig = m.dtype_signature
        assert m.distinct_conv_dtypes >= 2, sig
        assert sig.count("8") == n_int8, sig
        assert sig[0] == "b" and sig[-1] == "b", sig
        assert m.fused_bytes < u16.fused_bytes
        assert m.conv_signature == u16.conv_signature  # layouts unchanged


def test_mixed_uniform_networks_degenerate():
    """Two-conv networks (lenet) have no int8-eligible edge (first chain
    guarded, second feeds the classifier): the mixed plan IS the uniform
    plan."""
    m = plan_network_fused(LENET, dtype="bf16", policy="mixed")
    u = plan_network_fused(LENET, dtype="bf16")
    assert m.dtype_signature == "bb"
    assert m.fused_bytes == u.fused_bytes
    assert m.layouts == u.layouts


def test_unfused_product_dp_rejects_int8():
    """assign_layouts searches the same product space, but without fused
    epilogues every dtype boundary pays a standalone cast pass — the DP
    must conclude uniform (the fold IS the win)."""
    for cfg in (ALEXNET, VGG16):
        descs = network_descs(cfg, "bf16")
        kw = dict(input_layout="NCHW", input_shape=input_shape(cfg))
        u = assign_layouts(descs, **kw)
        m = assign_layouts(descs, dtype_policy="mixed", base_dtype="bf16",
                           **kw)
        assert m.layouts == u.layouts
        assert m.total_s == u.total_s
        assert set(m.dtypes) == {"bfloat16"}
    with pytest.raises(ValueError):
        assign_layouts(network_descs(LENET, "bf16"), dtype_policy="int4")
    with pytest.raises(ValueError):
        plan_fused(network_descs(LENET, "bf16"), dtype_policy="int4")


def test_mixed_plan_roundtrips_through_ops():
    """Every op carries consistent src/dst storage dtypes: the chain of
    dst -> next src is gap-free, starts and ends at base."""
    m = plan_network_fused(ALEXNET, dtype="bf16", policy="mixed")
    assert m.base_dtype == "bfloat16"
    cur = "bfloat16"
    for op in m.ops:
        assert op.src_dtype == cur, (op.name, op.src_dtype, cur)
        cur = op.dst_dtype
    assert cur == "bfloat16"


# ---------------------------------------------------------------------------
# int8 execution: fused forward differential (tier-1 CI smoke) + training
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_int8_fused_forward_matches_fp32(impl):
    """Mixed plan at base fp32 isolates the quantization error: softmax
    outputs must track the uniform fp32 reference within the documented
    INT8_FORWARD_ATOL on the real engines (int8 carriers + VMEM dequant via
    scale-folded weights on the Pallas path).  The uniform reference holds
    stacking off so the mixed-vs-uniform byte delta is the int8 boundary
    alone (DESIGN.md §12)."""
    plan_u = plan_network_fused(NET3, stack_policy="off")
    plan_m = plan_network_fused(NET3, policy="mixed")
    assert plan_m.dtype_signature == "f8f"     # conv2's output stores int8
    params = init_cnn(KEY, NET3)
    x = jax.random.normal(jax.random.PRNGKey(1), input_shape(NET3),
                          jnp.float32)
    yu, su = forward_fused(params, x, NET3, plan_u, impl=impl)
    ym, sm = forward_fused(params, x, NET3, plan_m, impl=impl)
    diff = float(jnp.abs(ym - yu).max())
    assert diff <= INT8_FORWARD_ATOL, diff
    assert diff > 0.0                          # int8 really on the path
    # the stored boundary is priced at 1 byte/element in the byte model
    assert sm.hbm_bytes < su.hbm_bytes


def test_int8_modeled_bytes_match_plan_shape():
    """Executor accounting and planner agree on WHAT shrinks: exactly the
    int8 boundary tensor's bytes (x3/4 at fp32 base) separate mixed from
    uniform in the forward byte model.  Stacking held off on the uniform
    side: it removes a different set of bytes (the mid round trip)."""
    plan_u = plan_network_fused(NET3, stack_policy="off")
    plan_m = plan_network_fused(NET3, policy="mixed")
    params = init_cnn(KEY, NET3)
    x = jax.random.normal(KEY, input_shape(NET3), jnp.float32)
    _, su = forward_fused(params, x, NET3, plan_u, impl="xla")
    _, sm = forward_fused(params, x, NET3, plan_m, impl="xla")
    # conv2 output: [2, 32, 8, 8] stored at 1 vs 4 bytes, and it crosses
    # HBM twice — conv2's epilogue write + conv3's read
    boundary = 2 * 32 * 8 * 8
    assert su.hbm_bytes - sm.hbm_bytes == 2 * 3 * boundary


def test_int8_train_step_differentiable():
    """5 steps of the fused mixed-dtype training engine (straight-through
    int8 boundaries): loss decreases, params stay finite/base-dtype."""
    plan = plan_network_fused(NET3, policy="mixed")
    params = init_cnn(KEY, NET3)
    x = jax.random.normal(jax.random.PRNGKey(1), input_shape(NET3),
                          jnp.float32)
    y = jax.random.randint(jax.random.PRNGKey(2), (NET3.batch,), 0,
                           NET3.num_classes)
    step = make_train_step_fused(NET3, plan, impl="pallas")
    p, v = params, init_velocity(params)
    losses = []
    for _ in range(5):
        p, v, loss = step(p, v, x, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(losses)), losses
    assert jax.tree.leaves(p)[0].dtype == jnp.float32


# ---------------------------------------------------------------------------
# policy-keyed plan cache + int8 calibration row
# ---------------------------------------------------------------------------

def test_plan_cache_policy_keyed_hit_miss():
    cache = PlanCache()
    pu, _, h0 = cache.fused_plan(ALEXNET, 32, dtype="bf16")
    pm, _, h1 = cache.fused_plan(ALEXNET, 32, dtype="bf16", policy="mixed")
    assert not h0 and not h1 and cache.planner_calls == 2
    assert pm.dtype_signature != pu.dtype_signature
    # same (bucket, dtype) hits within its policy, never across
    _, _, h2 = cache.fused_plan(ALEXNET, 32, dtype="bf16", policy="mixed")
    _, _, h3 = cache.fused_plan(ALEXNET, 32, dtype="bf16")
    assert h2 and h3 and cache.planner_calls == 2
    with pytest.raises(ValueError):
        cache.fused_plan(ALEXNET, 32, dtype="bf16", policy="int8")


def test_plan_cache_mixed_persistence_roundtrip(tmp_path):
    path = str(tmp_path / "plans.json")
    cache = PlanCache(path=path)
    pm, _, _ = cache.fused_plan(ALEXNET, 16, dtype="bf16", policy="mixed")
    cache.assignment(ALEXNET, 16, dtype="bf16", policy="mixed")
    cache.save()
    loaded = PlanCache(path=path)
    qm, _, hit = loaded.fused_plan(ALEXNET, 16, dtype="bf16",
                                   policy="mixed")
    assert hit and loaded.planner_calls == 0
    assert qm == pm                       # dtypes/base_dtype survive JSON
    assert qm.dtype_signature == pm.dtype_signature
    # uniform key is untouched: same bucket/dtype misses under "uniform"
    _, _, hu = loaded.fused_plan(ALEXNET, 16, dtype="bf16")
    assert not hu and loaded.planner_calls == 1


def test_pre_policy_cache_entries_still_load(tmp_path):
    """Entries persisted before ISSUE 5 lack the policy key field and the
    plan dtype fields — they must load as uniform plans (defaults), not
    raise."""
    import json
    path = str(tmp_path / "plans.json")
    cache = PlanCache(path=path)
    p, _, _ = cache.fused_plan(LENET, 8)
    cache.save()
    with open(path) as f:
        obj = json.load(f)
    obj.pop("checksum", None)             # pre-§14 files carry no checksum
    for ent in obj["fused"]:              # strip the ISSUE 5 fields
        ent["key"].pop("policy")
        ent["plan"].pop("dtypes")
        ent["plan"].pop("base_dtype")
        for op in ent["plan"]["ops"]:
            op.pop("src_dtype")
            op.pop("dst_dtype")
    with open(path, "w") as f:
        json.dump(obj, f)
    loaded = PlanCache(path=path)
    q, _, hit = loaded.fused_plan(LENET, 8)
    assert hit and loaded.planner_calls == 0
    assert q.layouts == p.layouts and q.fused_bytes == p.fused_bytes
    assert all(op.src_dtype == "" for op in q.ops)


def test_int8_calibration_row_roundtrip(tmp_path):
    """The 1-byte threshold row calibrates at int8's element size and
    persists next to the float rows."""
    path = str(tmp_path / "thresholds.json")
    calls = []

    def fake_measure(db):
        def measure(l, lay):
            calls.append(db)
            return H.conv_cost(l, lay, db).total_s
        return measure

    th8 = measured_thresholds(path, dtype="int8", measure=fake_measure(1))
    assert th8 == H.calibrate(dtype_bytes=1)
    # the int8 row must be its OWN calibration, not a reused float row:
    # Nt quadruples vs fp32 (the 256-byte coalescing span needs 4x the
    # 1-byte elements) and Ct collapses — im2col wins almost immediately
    # at int8's cheap expansion bytes (ISSUE 7 satellite).
    assert th8 == H.Thresholds(Ct=8, Nt=256)
    th32, th16_a = H.calibrate(dtype_bytes=4), H.calibrate(dtype_bytes=2)
    assert th8.Nt == 4 * th32.Nt == 2 * th16_a.Nt
    assert th8 not in (th32, th16_a)
    th16 = measured_thresholds(path, dtype="bf16", measure=fake_measure(2))
    n = len(calls)
    assert measured_thresholds(path, dtype="i8") == th8     # no re-measure
    assert measured_thresholds(path, dtype="bfloat16") == th16
    assert len(calls) == n
    assert load_thresholds(path, "int8") == th8
