"""Mixed-precision (bf16) engine (ISSUE 4): dtype-generic kernels with f32
accumulation, dtype-aware planning end-to-end, per-dtype calibration rows,
and the dtype-keyed plan cache.

The small fused-forward equivalence case doubles as the tier-1 CI smoke for
dtype regressions (cheap: one lenet-sized batch through the real Pallas
engine).
"""
import inspect
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.cnn_networks import LENET
from repro.cnn.layers import init_cnn
from repro.cnn.network import (forward_fused, init_velocity, input_shape,
                               make_train_step_fused, network_descs,
                               plan_network_fused)
from repro.core import heuristic as H
from repro.core.heuristic import DEFAULT_DTYPE_BYTES, Thresholds, calibrate
from repro.dtypes import canon_dtype, dtype_bytes, jnp_dtype
from repro.serve import PlanCache, measured_thresholds
from repro.serve.calibration import load_thresholds, save_thresholds

KEY = jax.random.PRNGKey(0)
BF16_EPS = float(jnp.finfo(jnp.bfloat16).eps)          # 2**-8


# ---------------------------------------------------------------------------
# dtype plumbing
# ---------------------------------------------------------------------------

def test_canon_dtype_aliases():
    assert canon_dtype("bf16") == canon_dtype("bfloat16") == "bfloat16"
    assert canon_dtype("fp32") == canon_dtype("float32") == "float32"
    assert dtype_bytes("bf16") == 2 and dtype_bytes("float32") == 4
    assert jnp_dtype("bf16") == jnp.bfloat16
    with pytest.raises(ValueError):
        canon_dtype("int7")


def test_dtype_bytes_defaults_unified():
    """Regression (ISSUE 4 satellite): every cost/byte model in
    core.heuristic must share ONE dtype_bytes default — conv_cost used to
    default to 2 while the chain/backward byte models defaulted to 4, so
    mixed default-arg calls priced compute and memory at different element
    sizes."""
    fns = [H.tile_utilization, H.conv_cost, H.chain_bytes,
           H.fusion_saved_bytes, H.fused_chain_cost, H.dgrad_bytes,
           H.wgrad_bytes, H.conv_backward_bytes, H.train_chain_bytes,
           H.conv_backward_cost, H.calibrate]
    for fn in fns:
        default = inspect.signature(fn).parameters["dtype_bytes"].default
        assert default == DEFAULT_DTYPE_BYTES, fn.__name__


# ---------------------------------------------------------------------------
# dtype-aware planning: thresholds and plans move with the element size
# ---------------------------------------------------------------------------

def test_thresholds_shift_with_element_size():
    """Halving the element size halves every byte term and doubles the
    sublane width, so the calibrated (Ct, Nt) crossover row must move —
    bf16 is NOT just fp32 with smaller tensors."""
    th4 = calibrate(dtype_bytes=4)
    th2 = calibrate(dtype_bytes=2)
    assert th2 != th4
    assert th2.Nt >= th4.Nt          # CHWN needs a larger batch at bf16


def test_plan_flips_with_dtype():
    """At least one (network, batch) point is assigned different conv
    layouts under bf16 than fp32 (the acceptance criterion: the crossover
    shifts, the bytes don't just scale)."""
    cfg = LENET.replace(batch=32)
    p32 = plan_network_fused(cfg)
    p16 = plan_network_fused(cfg, dtype="bfloat16")
    assert p32.conv_signature != p16.conv_signature


def test_modeled_bytes_halve_under_bf16():
    for batch in (4, 128):
        cfg = LENET.replace(batch=batch)
        p32 = plan_network_fused(cfg)
        p16 = plan_network_fused(cfg, dtype="bf16")
        ratio = p32.fused_bytes / p16.fused_bytes
        assert ratio >= 1.8, ratio


def test_network_descs_carry_dtype_bytes():
    for dtype, db in (("float32", 4), ("bf16", 2)):
        assert all(d.dtype_bytes == db for d in network_descs(LENET, dtype))


# ---------------------------------------------------------------------------
# plan cache: the dtype key selects dtype-specific plans and thresholds
# ---------------------------------------------------------------------------

def test_plan_cache_dtype_keyed_hit_miss():
    cache = PlanCache()
    p32, _, h0 = cache.fused_plan(LENET, 32)
    _, _, h1 = cache.fused_plan(LENET, 32, dtype="bfloat16")
    assert not h0 and not h1 and cache.planner_calls == 2
    # aliases canonicalize into the SAME key: "bf16" hits "bfloat16"
    p16, _, h2 = cache.fused_plan(LENET, 32, dtype="bf16")
    assert h2 and cache.planner_calls == 2
    # and the cached bf16 plan is the real bf16 plan, not a relabeled fp32 one
    assert p16 == plan_network_fused(LENET.replace(batch=32),
                                     dtype="bfloat16")
    assert p16.conv_signature != p32.conv_signature


def test_plan_cache_dtype_plans_persist(tmp_path):
    path = str(tmp_path / "plans.json")
    cache = PlanCache(path=path)
    p16, _, _ = cache.fused_plan(LENET, 32, dtype="bf16")
    cache.save()
    loaded = PlanCache(path=path)
    q16, _, hit = loaded.fused_plan(LENET, 32, dtype="bfloat16")
    assert hit and loaded.planner_calls == 0 and q16 == p16


def test_plan_cache_per_dtype_threshold_rows(tmp_path):
    th32, th16 = Thresholds(Ct=512, Nt=64), Thresholds(Ct=64, Nt=128)
    path = str(tmp_path / "plans.json")
    cache = PlanCache(path=path, thresholds={"fp32": th32, "bf16": th16})
    assert cache.thresholds_for("float32") == th32
    assert cache.thresholds_for("bf16") == th16
    assert cache.thresholds == th32          # legacy accessor = fp32 row
    lay32 = cache.heuristic_layouts(LENET, 32)
    lay16 = cache.heuristic_layouts(LENET, 32, dtype="bf16")
    assert len(lay32) == len(LENET.layers) and len(lay16) == len(lay32)
    cache.save()
    loaded = PlanCache(path=path)
    assert loaded.thresholds_for("bfloat16") == th16
    assert loaded.thresholds_for("float32") == th32
    with pytest.raises(ValueError):
        PlanCache().heuristic_layouts(LENET, 32, dtype="bf16")


# ---------------------------------------------------------------------------
# per-dtype calibration persistence
# ---------------------------------------------------------------------------

def test_per_dtype_calibration_roundtrip(tmp_path):
    path = str(tmp_path / "thresholds.json")
    calls = []

    def fake_measure(db):
        def measure(l, lay):
            calls.append(db)
            return H.conv_cost(l, lay, db).total_s
        return measure

    th32 = measured_thresholds(path, dtype="float32",
                               measure=fake_measure(4))
    n32 = len(calls)
    th16 = measured_thresholds(path, dtype="bf16", measure=fake_measure(2))
    assert len(calls) > n32                  # bf16 row measured separately
    assert th32 == calibrate(dtype_bytes=4)
    assert th16 == calibrate(dtype_bytes=2)
    assert th16 != th32
    n = len(calls)
    # both rows load from the SAME file without re-measuring
    assert measured_thresholds(path, dtype="float32") == th32
    assert measured_thresholds(path, dtype="bfloat16") == th16
    assert len(calls) == n
    assert load_thresholds(path, "bf16") == th16


def test_calibration_reads_legacy_single_row_file(tmp_path):
    """Pre-dtype files (flat {Ct, Nt}) are one float32 row."""
    path = str(tmp_path / "thresholds.json")
    with open(path, "w") as f:
        json.dump({"Ct": 7, "Nt": 33, "source": "measured"}, f)
    assert load_thresholds(path) == Thresholds(Ct=7, Nt=33)
    with pytest.raises(KeyError):
        load_thresholds(path, "bf16")
    # merging a bf16 row keeps the legacy fp32 row
    save_thresholds(Thresholds(Ct=1, Nt=2), path, dtype="bf16")
    assert load_thresholds(path) == Thresholds(Ct=7, Nt=33)
    assert load_thresholds(path, "bfloat16") == Thresholds(Ct=1, Nt=2)


# ---------------------------------------------------------------------------
# bf16 numerics: fused forward differential + training (tier-1 CI smoke)
# ---------------------------------------------------------------------------

def _bf16_params(cfg):
    return jax.tree.map(lambda a: a.astype(jnp.bfloat16),
                        init_cnn(KEY, cfg))


@pytest.mark.parametrize("batch", [2, 6])
def test_bf16_fused_forward_matches_fp32(batch):
    """bf16 storage + f32 accumulation through the real fused Pallas engine
    tracks the fp32 reference to bf16-appropriate tolerance (outputs are
    softmax probabilities in [0, 1])."""
    cfg = LENET.replace(batch=batch)
    p32 = init_cnn(KEY, cfg)
    x32 = jax.random.normal(jax.random.PRNGKey(batch), input_shape(cfg),
                            jnp.float32)
    y32, _ = forward_fused(p32, x32, cfg, plan_network_fused(cfg),
                           impl="pallas")
    p16 = jax.tree.map(lambda a: a.astype(jnp.bfloat16), p32)
    plan16 = plan_network_fused(cfg, dtype="bf16")
    y16, st = forward_fused(p16, x32.astype(jnp.bfloat16), cfg, plan16,
                            impl="pallas")
    assert y16.dtype == jnp.bfloat16
    assert st.transforms == 0                # bf16 plan still fully folded
    np.testing.assert_allclose(np.asarray(y16.astype(jnp.float32)),
                               np.asarray(y32), atol=8 * BF16_EPS)


def test_bf16_train_step_loss_decreases():
    """5 steps of the fused bf16 training engine (bf16 storage everywhere,
    f32 accumulation inside the kernels): the loss must decrease."""
    cfg = LENET.replace(batch=2)
    plan = plan_network_fused(cfg, dtype="bf16")
    params = _bf16_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), input_shape(cfg),
                          jnp.bfloat16)
    y = jax.random.randint(jax.random.PRNGKey(2), (cfg.batch,), 0,
                           cfg.num_classes)
    step = make_train_step_fused(cfg, plan, impl="pallas")
    p, v = params, init_velocity(params)
    losses = []
    for _ in range(5):
        p, v, loss = step(p, v, x, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(losses)), losses
    assert jax.tree.leaves(p)[0].dtype == jnp.bfloat16
