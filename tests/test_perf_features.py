"""Beyond-paper perf features: chunk-parallel WKV, window KV caches,
remat policies, HLO analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

from repro.configs import get_config, reduced_config


# ---------------------------------------------------------------------------
# chunk-parallel WKV == sequential scan (EXPERIMENTS §Perf cell 1)
# ---------------------------------------------------------------------------
def _check_wkv_chunked(seed, chunk):
    from repro.models.rwkv import _wkv_chunked_parallel, _wkv_scan
    B, S, H, N = 2, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = jax.random.normal(ks[0], (B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, N))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, N)) * 0.5 - 2))
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    s0 = jax.random.normal(ks[5], (B, H, N, N)) * 0.2
    y1, st1 = _wkv_scan(r, k, v, w, u, s0, chunk=chunk)
    y2, st2 = _wkv_chunked_parallel(r, k, v, w, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=5e-3, rtol=5e-3)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2),
                               atol=5e-3, rtol=5e-3)


if HAS_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), chunk=st.sampled_from([4, 8, 16]))
    def test_wkv_chunked_parallel_matches_sequential(seed, chunk):
        _check_wkv_chunked(seed, chunk)
else:
    def test_wkv_chunked_parallel_matches_sequential():
        for seed, chunk in ((0, 4), (1, 8), (1234, 16)):
            _check_wkv_chunked(seed, chunk)


def test_rwkv_chunked_config_end_to_end():
    from repro.models import forward, init_params
    cfg = reduced_config(get_config("rwkv6_7b"))
    cfg_c = cfg.replace(rwkv_chunked=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32)[None], (2, 16))
    h1, _ = forward(params, tokens, pos, cfg)
    h2, _ = forward(params, tokens, pos, cfg_c)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32),
                               atol=0.05, rtol=0.05)


# ---------------------------------------------------------------------------
# window KV ring cache (EXPERIMENTS §Perf cell 3)
# ---------------------------------------------------------------------------
def test_window_cache_decode_matches_forward():
    """gemma2 with window ring-caches decodes identically to teacher-forced
    forward (the window >= reduced local_window so no information is lost)."""
    from repro.models import decode_step, forward, init_params, logits_fwd, prefill
    cfg = reduced_config(get_config("gemma2_27b"))
    assert cfg.local_window == 8
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    h, _ = forward(params, tokens, pos, cfg)
    full_logits = logits_fwd(params, h, cfg)

    n_prompt = S - 3
    lg, cache, _ = prefill(params, tokens[:, :n_prompt], cfg,
                           max_len=S + 2, kv_window=True)
    # local-layer caches are window-sized
    k_local = cache["b0"]["k"]     # b0 = attn_local for gemma2
    assert k_local.shape[3] == cfg.local_window     # [P,B,K,S_cache,Dh]
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(full_logits[:, n_prompt - 1]),
                               atol=0.15, rtol=0.05)
    cl = n_prompt
    for t in range(n_prompt, S):
        lg, cache = decode_step(params, cache, tokens[:, t:t + 1],
                                jnp.int32(cl), cfg, kv_window=True)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full_logits[:, t]),
                                   atol=0.15, rtol=0.05)
        cl += 1


def test_window_cache_smaller_than_full():
    from repro.models import abstract_cache
    cfg = reduced_config(get_config("gemma2_27b"))
    full = abstract_cache(cfg, 2, 64)
    win = abstract_cache(cfg, 2, 64, kv_window=True)
    nb = lambda t: sum(np.prod(l.shape) for l in jax.tree.leaves(t))
    assert nb(win) < nb(full)


# ---------------------------------------------------------------------------
# HLO analyzer (the roofline engine)
# ---------------------------------------------------------------------------
def test_hlo_analyzer_counts_scan_trip_counts():
    from repro.launch.hlo_analysis import analyze

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, ws)
        return c.sum()

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                         jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
                         ).compile()
    cost = analyze(c.as_text())
    exp = 7 * 2 * 64 ** 3
    assert 0.9 * exp <= cost.flops <= 1.3 * exp
    # stock cost_analysis undercounts (documents the motivation)
    ca = c.cost_analysis()
    if isinstance(ca, list):         # older jax returns a one-element list
        ca = ca[0]
    raw = ca["flops"]
    assert raw < 0.5 * cost.flops


def test_hlo_analyzer_nested_scans():
    from repro.launch.hlo_analysis import analyze

    def g(x, ws):
        def outer(c, wpair):
            def inner(ci, w):
                return jnp.tanh(ci @ w), None
            ci, _ = jax.lax.scan(inner, c, wpair)
            return ci, None
        c, _ = jax.lax.scan(outer, x, ws)
        return c.sum()

    c = jax.jit(g).lower(jax.ShapeDtypeStruct((32, 32), jnp.float32),
                         jax.ShapeDtypeStruct((3, 4, 32, 32), jnp.float32)
                         ).compile()
    cost = analyze(c.as_text())
    exp = 12 * 2 * 32 ** 3
    assert 0.9 * exp <= cost.flops <= 1.5 * exp


def test_hlo_analyzer_collective_ring_model():
    from repro.launch.hlo_analysis import analyze
    import os
    if jax.device_count() < 2:
        pytest.skip("needs >1 device")


def test_analyze_by_op_sums_to_total():
    from repro.launch.hlo_analysis import analyze, analyze_by_op

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, ws)
        return c.sum()

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                         jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
                         ).compile()
    txt = c.as_text()
    total = analyze(txt)
    by = analyze_by_op(txt)
    assert abs(sum(b for b, _ in by.values()) - total.bytes) / max(total.bytes, 1) < 0.05
