"""Multi-device (fake-host-device) integration tests: sharded train parity,
a2a MoE, gradient compression, SP constraints, end-to-end FT training.
Each test runs in a subprocess so the device count can differ."""
import pytest

from tests.util import run_with_devices


def test_sharded_train_step_matches_single_device():
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced_config, ParallelConfig, TrainConfig, ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.launch import specs as S
from repro.models import transformer as T
from repro.optim import adamw
from repro.train.steps import make_train_step
from repro.distributed.sharding import param_specs, named

cfg = reduced_config(get_config("yi_9b"))
tc = TrainConfig()
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1),
         "mask": jnp.ones((8, 32), jnp.float32)}
params = T.init_params(jax.random.PRNGKey(0), cfg)
opt = adamw.init(params)

# single device
mesh1 = make_host_mesh(1, 1)
with mesh1:
    step1 = jax.jit(make_train_step(cfg, mesh1, ParallelConfig(fsdp=False, seq_shard_saved=False), tc))
    p1, o1, m1 = step1(params, opt, batch)

# 2x2 mesh, fsdp+TP+SP
mesh = make_host_mesh(2, 2)
parallel = ParallelConfig(fsdp=True, seq_shard_saved=True)
psh = named(mesh, param_specs(cfg, mesh, parallel))
with mesh:
    params_s = jax.device_put(params, psh)
    opt_s = adamw.init(params_s)
    step = jax.jit(make_train_step(cfg, mesh, parallel, tc))
    p2, o2, m2 = step(params_s, opt_s, batch)

print("loss1", float(m1["loss"]), "loss2", float(m2["loss"]))
assert abs(float(m1["loss"]) - float(m2["loss"])) < 0.05
d = max(float(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)).max())
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
print("max param diff", d)
# bf16 params: sharded reductions reorder sums; a few bf16 quanta of drift
# around near-zero adam v values is expected after one step
assert d < 0.2
print("parity ok")
""", n_devices=4)


def test_moe_a2a_matches_reference():
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced_config
from repro.models import layers as L
from repro.models.transformer import ShardCtx
from repro.launch.mesh import make_host_mesh

for arch in ("dbrx_132b", "llama4_maverick_400b"):
    cfg = reduced_config(get_config(arch)).replace(capacity_factor=8.0)
    mesh = make_host_mesh(2, 2)
    p = L.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model)).astype(jnp.bfloat16)
    ctx = ShardCtx(batch_axes=("data",), model_axis="model", model_size=2,
                   fsdp_axes=("data",), moe_a2a=True, mesh=mesh)
    y_ref, _ = L.moe_fwd(p, x, cfg)
    with mesh:
        y_a2a, _ = jax.jit(lambda p, x: L.moe_fwd_a2a(p, x, cfg, ctx))(p, x)
    d = np.abs(np.asarray(y_ref, np.float32) - np.asarray(y_a2a, np.float32)).max()
    assert d < 0.02, (arch, d)
    print(arch, "a2a ok", d)
""", n_devices=4)


def test_moe_a2a_gradients_flow():
    run_with_devices("""
import jax, jax.numpy as jnp
from repro.configs import get_config, reduced_config
from repro.models import layers as L
from repro.models.transformer import ShardCtx
from repro.launch.mesh import make_host_mesh

cfg = reduced_config(get_config("dbrx_132b"))
mesh = make_host_mesh(2, 2)
p = L.init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model)).astype(jnp.bfloat16)
ctx = ShardCtx(batch_axes=("data",), model_axis="model", model_size=2,
               fsdp_axes=("data",), moe_a2a=True, mesh=mesh)
def lf(p):
    y, aux = L.moe_fwd_a2a(p, x, cfg, ctx)
    return jnp.sum(y.astype(jnp.float32) ** 2) + 0.01 * aux
with mesh:
    g = jax.jit(jax.grad(lf))(p)
gn = sum(float(jnp.abs(t.astype(jnp.float32)).sum()) for t in jax.tree.leaves(g))
assert gn > 0
print("moe grads ok", gn)
""", n_devices=4)


@pytest.mark.parametrize("mode", ["bf16", "int8"])
def test_gradient_compression_close_to_exact(mode):
    run_with_devices(f"""
import jax, jax.numpy as jnp, numpy as np
from repro.optim.compression import compress_psum
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh(2, 1, pod=2)
g = jax.random.normal(jax.random.PRNGKey(0), (4, 64, 64)) * 0.01

def red(gl, mode):
    return compress_psum({{"w": gl}}, "pod", mode)["w"]

from repro.compat import shard_map
f = shard_map(lambda gl: red(gl, "{mode}"), mesh=mesh,
              in_specs=P("pod", None, None), out_specs=P("pod", None, None),
              axis_names={{"pod", "data", "model"}}, check_vma=False)
with mesh:
    got = f(g)
exact = jnp.mean(g.reshape(2, 2, 64, 64), axis=0)
exact = jnp.concatenate([exact, exact], 0)
err = float(jnp.abs(got - exact).max())
tol = 5e-4 if "{mode}" == "bf16" else 1e-3
print("compression err", err)
assert err < tol
""", n_devices=4)


def test_train_driver_with_failure_injection_resumes():
    run_with_devices("""
import logging, tempfile
logging.basicConfig(level=logging.WARNING)
from repro.launch.train import train
from repro.launch.mesh import make_host_mesh
d = tempfile.mkdtemp()
mesh = make_host_mesh(2, 2)
out = train("phi3_mini_3p8b", reduced=True, steps=8, batch=4, seq=32,
            mesh=mesh, checkpoint_dir=d, inject_failure_at=5)
assert out["steps"] == 8
print("ft train ok, losses", out["losses"][:2], "->", out["losses"][-1])
""", n_devices=4)


def test_param_specs_sanitized_for_all_archs_on_production_shapes():
    run_with_devices("""
import jax, numpy as np
from repro.configs import ARCH_IDS, get_config, ParallelConfig
from repro.distributed.sharding import param_specs
from repro.models.transformer import abstract_params
from repro.launch.mesh import make_host_mesh

# host mesh stands in; fit_spec math only uses mesh axis SIZES, so use
# an abstract mesh with the production sizes
from repro.compat import abstract_mesh
mesh = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
for arch in ARCH_IDS:
    cfg = get_config(arch)
    specs = param_specs(cfg, mesh, ParallelConfig(fsdp=True, fsdp_pod=True))
    tree = abstract_params(cfg)
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_leaves_with_path(tree),
            jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))):
        for dim, entry in zip(leaf.shape, spec):
            if entry is None: continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0, (arch, path, leaf.shape, spec)
print("all specs divide evenly")
""", n_devices=1)
