"""Core layout system: transform planner, heuristic, selector.
Includes hypothesis property tests on the system's invariants (skipped when
hypothesis is not installed — see requirements-dev.txt)."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

from repro.configs.paper_table1 import (CONV_LAYERS, PAPER_PREFERRED_CONV_LAYOUT,
                                        POOL_LAYERS, ConvLayer)
from repro.core import (Thresholds, apply_transform, assign_layouts,
                        calibrate, conv_cost, naive_transform,
                        paper_heuristic_layouts, plan_transform,
                        select_conv_layout, select_kv_layout,
                        select_pool_layout, tile_utilization)
from repro.core.selector import LayerDesc

# ---------------------------------------------------------------------------
# transform planner
# ---------------------------------------------------------------------------

def test_chwn_nchw_collapses_to_2d():
    plan = plan_transform("CHWN", "NCHW")
    assert plan.groups_src == ("CHW", "N")
    assert plan.is_2d_transpose


def test_nchw_nhwc_is_batched_transpose():
    plan = plan_transform("NCHW", "NHWC")
    assert plan.groups_src == ("N", "C", "HW")
    assert plan.perm == (0, 2, 1)


if HAS_HYPOTHESIS:
    LAYOUT_STRATEGY = st.permutations("NCHW").map("".join)

    @settings(max_examples=40, deadline=None)
    @given(src=LAYOUT_STRATEGY, dst=LAYOUT_STRATEGY,
           dims=st.tuples(*[st.integers(1, 5)] * 4))
    def test_transform_matches_naive_4d_transpose(src, dst, dims):
        """Property: collapsed transform == naive full 4-D transpose."""
        shape = dict(zip("NCHW", dims))
        x = jnp.arange(int(np.prod(dims)), dtype=jnp.float32).reshape(
            tuple(shape[d] for d in src))
        got = apply_transform(x, src, dst)
        ref = naive_transform(x, src, dst)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    @settings(max_examples=25, deadline=None)
    @given(src=LAYOUT_STRATEGY, dst=LAYOUT_STRATEGY,
           dims=st.tuples(*[st.integers(1, 4)] * 4))
    def test_transform_roundtrip_identity(src, dst, dims):
        shape = dict(zip("NCHW", dims))
        x = jax.random.normal(jax.random.PRNGKey(0),
                              tuple(shape[d] for d in src))
        y = apply_transform(apply_transform(x, src, dst), dst, src)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))

    @settings(max_examples=30, deadline=None)
    @given(src=LAYOUT_STRATEGY, dst=LAYOUT_STRATEGY)
    def test_plan_never_more_groups_than_dims(src, dst):
        plan = plan_transform(src, dst)
        assert 1 <= len(plan.groups_src) <= 4
        # groups partition the source layout exactly
        assert "".join(plan.groups_src) == src
else:
    def test_property_suite_requires_hypothesis():
        pytest.importorskip("hypothesis")


def test_transform_matches_naive_all_layout_pairs():
    """Deterministic fallback for the property test: every 4-D layout pair."""
    dims = dict(zip("NCHW", (2, 3, 4, 5)))
    for src in map("".join, itertools.permutations("NCHW")):
        x = jnp.arange(120, dtype=jnp.float32).reshape(
            tuple(dims[d] for d in src))
        for dst in map("".join, itertools.permutations("NCHW")):
            np.testing.assert_array_equal(
                np.asarray(apply_transform(x, src, dst)),
                np.asarray(naive_transform(x, src, dst)))


def test_transform_uses_pallas_kernel_path():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 6, 5, 32))  # CHWN
    got = apply_transform(x, "CHWN", "NCHW", use_pallas=True)
    ref = naive_transform(x, "CHWN", "NCHW")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# heuristic (paper §IV.A) — fidelity to Table 1
# ---------------------------------------------------------------------------

def test_calibrated_heuristic_matches_paper_all_12_conv_layers():
    th = calibrate()
    for l in CONV_LAYERS:
        assert select_conv_layout(l, th) == PAPER_PREFERRED_CONV_LAYOUT[l.name], l.name


def test_pooling_always_chwn():
    for l in POOL_LAYERS:
        assert select_pool_layout(l) == "CHWN"


def test_cost_model_mostly_agrees_with_paper():
    from repro.core import select_conv_layout_cost
    agree = sum(select_conv_layout_cost(l) == PAPER_PREFERRED_CONV_LAYOUT[l.name]
                for l in CONV_LAYERS)
    assert agree >= 10   # CV6 is borderline in the paper too


def test_heuristic_sensitivity_direction():
    """Paper Fig. 4: CHWN wins at large N; NCHW wins at big C, small N."""
    th = calibrate()
    big_n = ConvLayer("X", 256, 64, 14, 3, 256, 1, "t")
    small_n_big_c = ConvLayer("Y", 32, 64, 14, 3, 512, 1, "t")
    assert select_conv_layout(big_n, th) == "CHWN"
    assert select_conv_layout(small_n_big_c, th) == "NCHW"


if HAS_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(lane=st.integers(1, 512), sub=st.integers(1, 64))
    def test_tile_utilization_bounds(lane, sub):
        u = tile_utilization((sub, lane), 4)
        assert 0.0 < u <= 1.0
        if lane % 128 == 0 and sub % 8 == 0:
            assert u == 1.0
else:
    def test_tile_utilization_bounds():
        for lane, sub in [(1, 1), (7, 3), (128, 8), (256, 16), (512, 64),
                          (129, 9)]:
            u = tile_utilization((sub, lane), 4)
            assert 0.0 < u <= 1.0
            if lane % 128 == 0 and sub % 8 == 0:
                assert u == 1.0


# ---------------------------------------------------------------------------
# network-level selector (paper §IV.D)
# ---------------------------------------------------------------------------

def _alexnet_descs():
    from repro.configs.cnn_networks import ALEXNET
    from repro.cnn.network import network_descs
    return network_descs(ALEXNET)


def test_dp_no_worse_than_fixed_layouts():
    descs = _alexnet_descs()
    a = assign_layouts(descs)
    from repro.core.selector import layer_cost, transform_cost
    def total(layouts):
        t, cur = 0.0, "NCHW"
        for i, (l, lay) in enumerate(zip(descs, layouts)):
            if lay != cur:
                shape = descs[i - 1].out_shape if i else descs[0].out_shape
                t += transform_cost(shape, l.dtype_bytes)
                cur = lay
            t += layer_cost(l, lay)
        return t
    assert a.total_s <= total(["CHWN"] * len(descs)) + 1e-9
    assert a.total_s <= total(["NCHW"] * len(descs)) + 1e-9


def test_selector_inserts_transforms_only_on_change():
    descs = _alexnet_descs()
    a = assign_layouts(descs)
    cur = "NCHW"
    expected = []
    for i, lay in enumerate(a.layouts):
        if lay != cur:
            expected.append(i)
            cur = lay
    assert a.transforms == expected


def test_paper_heuristic_network_pass():
    th = calibrate()
    descs = _alexnet_descs()
    layouts = paper_heuristic_layouts(descs, th)
    assert len(layouts) == len(descs)
    conv_layouts = {d.name: l for d, l in zip(descs, layouts)
                    if d.kind == "conv"}
    # AlexNet conv1 (C=3) must be CHWN; with N=128 >= Nt the paper's rule (2)
    # keeps CHWN for the rest too (cf. Fig. 3: CV1-CV4 all prefer CHWN at
    # N=128).  The NCHW case needs small N: VGG (N=32).
    assert conv_layouts["conv1"] == "CHWN"
    from repro.configs.cnn_networks import VGG16
    from repro.cnn.network import network_descs
    vgg_descs = network_descs(VGG16)
    vgg_layouts = paper_heuristic_layouts(vgg_descs, th)
    vgg_conv = {d.name: l for d, l in zip(vgg_descs, vgg_layouts)
                if d.kind == "conv"}
    assert vgg_conv["conv1_1"] == "CHWN"     # C=3
    assert vgg_conv["conv3_1"] == "NCHW"     # C=128, N=32
    # pooling layers always CHWN
    for d, l in zip(descs, layouts):
        if d.kind == "pool":
            assert l == "CHWN"


# ---------------------------------------------------------------------------
# KV-cache layout selection (paper principle on serving)
# ---------------------------------------------------------------------------

def test_kv_layout_big_batch_prefers_sbkd():
    # many (b,k) rows: bksd updates pad one (sublane x lane) tile PER (b,k),
    # while sbkd writes one contiguous row -> sbkd wins (update-side)
    assert select_kv_layout(batch=8, kv_heads=8, seq=32768, head_dim=128,
                            steps_per_read=0.0) == "sbkd"


def test_kv_layout_small_row_prefers_bksd():
    # B*K*Dh far below one native tile: sbkd reads are mostly padding ->
    # bksd wins once reads matter
    assert select_kv_layout(batch=1, kv_heads=1, seq=32768, head_dim=64,
                            steps_per_read=4.0) == "bksd"
