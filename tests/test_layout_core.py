"""Core layout system: transform planner, heuristic, selector.
Includes hypothesis property tests on the system's invariants (skipped when
hypothesis is not installed — see requirements-dev.txt)."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

from repro.configs.paper_table1 import (CONV_LAYERS, PAPER_PREFERRED_CONV_LAYOUT,
                                        POOL_LAYERS, ConvLayer, PoolLayer)
from repro.core import (Thresholds, apply_transform, assign_layouts,
                        calibrate, conv_cost, naive_transform,
                        paper_heuristic_layouts, plan_fused, plan_transform,
                        select_conv_layout, select_kv_layout,
                        select_pool_layout, tile_utilization,
                        train_chain_bytes)
from repro.core.selector import LayerDesc

# ---------------------------------------------------------------------------
# transform planner
# ---------------------------------------------------------------------------

def test_chwn_nchw_collapses_to_2d():
    plan = plan_transform("CHWN", "NCHW")
    assert plan.groups_src == ("CHW", "N")
    assert plan.is_2d_transpose


def test_nchw_nhwc_is_batched_transpose():
    plan = plan_transform("NCHW", "NHWC")
    assert plan.groups_src == ("N", "C", "HW")
    assert plan.perm == (0, 2, 1)


if HAS_HYPOTHESIS:
    LAYOUT_STRATEGY = st.permutations("NCHW").map("".join)

    @settings(max_examples=40, deadline=None)
    @given(src=LAYOUT_STRATEGY, dst=LAYOUT_STRATEGY,
           dims=st.tuples(*[st.integers(1, 5)] * 4))
    def test_transform_matches_naive_4d_transpose(src, dst, dims):
        """Property: collapsed transform == naive full 4-D transpose."""
        shape = dict(zip("NCHW", dims))
        x = jnp.arange(int(np.prod(dims)), dtype=jnp.float32).reshape(
            tuple(shape[d] for d in src))
        got = apply_transform(x, src, dst)
        ref = naive_transform(x, src, dst)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    @settings(max_examples=25, deadline=None)
    @given(src=LAYOUT_STRATEGY, dst=LAYOUT_STRATEGY,
           dims=st.tuples(*[st.integers(1, 4)] * 4))
    def test_transform_roundtrip_identity(src, dst, dims):
        shape = dict(zip("NCHW", dims))
        x = jax.random.normal(jax.random.PRNGKey(0),
                              tuple(shape[d] for d in src))
        y = apply_transform(apply_transform(x, src, dst), dst, src)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))

    @settings(max_examples=30, deadline=None)
    @given(src=LAYOUT_STRATEGY, dst=LAYOUT_STRATEGY)
    def test_plan_never_more_groups_than_dims(src, dst):
        plan = plan_transform(src, dst)
        assert 1 <= len(plan.groups_src) <= 4
        # groups partition the source layout exactly
        assert "".join(plan.groups_src) == src
else:
    def test_property_suite_requires_hypothesis():
        pytest.importorskip("hypothesis")


@pytest.mark.slow
def test_transform_matches_naive_all_layout_pairs():
    """Deterministic fallback for the property test: every 4-D layout pair
    (24 x 24 grid — slow tier; the hypothesis property covers tier-1)."""
    dims = dict(zip("NCHW", (2, 3, 4, 5)))
    for src in map("".join, itertools.permutations("NCHW")):
        x = jnp.arange(120, dtype=jnp.float32).reshape(
            tuple(dims[d] for d in src))
        for dst in map("".join, itertools.permutations("NCHW")):
            np.testing.assert_array_equal(
                np.asarray(apply_transform(x, src, dst)),
                np.asarray(naive_transform(x, src, dst)))


def test_transform_uses_pallas_kernel_path():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 6, 5, 32))  # CHWN
    got = apply_transform(x, "CHWN", "NCHW", use_pallas=True)
    ref = naive_transform(x, "CHWN", "NCHW")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# heuristic (paper §IV.A) — fidelity to Table 1
# ---------------------------------------------------------------------------

def test_calibrated_heuristic_matches_paper_all_12_conv_layers():
    th = calibrate()
    for l in CONV_LAYERS:
        assert select_conv_layout(l, th) == PAPER_PREFERRED_CONV_LAYOUT[l.name], l.name


def test_pooling_always_chwn():
    for l in POOL_LAYERS:
        assert select_pool_layout(l) == "CHWN"


def test_cost_model_mostly_agrees_with_paper():
    from repro.core import select_conv_layout_cost
    agree = sum(select_conv_layout_cost(l) == PAPER_PREFERRED_CONV_LAYOUT[l.name]
                for l in CONV_LAYERS)
    assert agree >= 10   # CV6 is borderline in the paper too


def test_heuristic_sensitivity_direction():
    """Paper Fig. 4: CHWN wins at large N; NCHW wins at big C, small N."""
    th = calibrate()
    big_n = ConvLayer("X", 256, 64, 14, 3, 256, 1, "t")
    small_n_big_c = ConvLayer("Y", 32, 64, 14, 3, 512, 1, "t")
    assert select_conv_layout(big_n, th) == "CHWN"
    assert select_conv_layout(small_n_big_c, th) == "NCHW"


if HAS_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(lane=st.integers(1, 512), sub=st.integers(1, 64))
    def test_tile_utilization_bounds(lane, sub):
        u = tile_utilization((sub, lane), 4)
        assert 0.0 < u <= 1.0
        if lane % 128 == 0 and sub % 8 == 0:
            assert u == 1.0
else:
    def test_tile_utilization_bounds():
        for lane, sub in [(1, 1), (7, 3), (128, 8), (256, 16), (512, 64),
                          (129, 9)]:
            u = tile_utilization((sub, lane), 4)
            assert 0.0 < u <= 1.0
            if lane % 128 == 0 and sub % 8 == 0:
                assert u == 1.0


# ---------------------------------------------------------------------------
# network-level selector (paper §IV.D)
# ---------------------------------------------------------------------------

def _alexnet_descs():
    from repro.configs.cnn_networks import ALEXNET
    from repro.cnn.network import network_descs
    return network_descs(ALEXNET)


def test_dp_no_worse_than_fixed_layouts():
    descs = _alexnet_descs()
    a = assign_layouts(descs)
    from repro.core.selector import layer_cost, transform_cost
    def total(layouts):
        t, cur = 0.0, "NCHW"
        for i, (l, lay) in enumerate(zip(descs, layouts)):
            if lay != cur:
                shape = descs[i - 1].out_shape if i else descs[0].out_shape
                t += transform_cost(shape, l.dtype_bytes)
                cur = lay
            t += layer_cost(l, lay)
        return t
    assert a.total_s <= total(["CHWN"] * len(descs)) + 1e-9
    assert a.total_s <= total(["NCHW"] * len(descs)) + 1e-9


def test_selector_inserts_transforms_only_on_change():
    descs = _alexnet_descs()
    a = assign_layouts(descs)
    cur = "NCHW"
    expected = []
    for i, lay in enumerate(a.layouts):
        if lay != cur:
            expected.append(i)
            cur = lay
    assert a.transforms == expected


def test_paper_heuristic_network_pass():
    th = calibrate()
    descs = _alexnet_descs()
    layouts = paper_heuristic_layouts(descs, th)
    assert len(layouts) == len(descs)
    conv_layouts = {d.name: l for d, l in zip(descs, layouts)
                    if d.kind == "conv"}
    # AlexNet conv1 (C=3) must be CHWN; with N=128 >= Nt the paper's rule (2)
    # keeps CHWN for the rest too (cf. Fig. 3: CV1-CV4 all prefer CHWN at
    # N=128).  The NCHW case needs small N: VGG (N=32).
    assert conv_layouts["conv1"] == "CHWN"
    from repro.configs.cnn_networks import VGG16
    from repro.cnn.network import network_descs
    vgg_descs = network_descs(VGG16)
    vgg_layouts = paper_heuristic_layouts(vgg_descs, th)
    vgg_conv = {d.name: l for d, l in zip(vgg_descs, vgg_layouts)
                if d.kind == "conv"}
    assert vgg_conv["conv1_1"] == "CHWN"     # C=3
    assert vgg_conv["conv3_1"] == "NCHW"     # C=128, N=32
    # pooling layers always CHWN
    for d, l in zip(descs, layouts):
        if d.kind == "pool":
            assert l == "CHWN"


# ---------------------------------------------------------------------------
# fused planning with the backward direction (ISSUE 2)
# ---------------------------------------------------------------------------

def _chain_descs(N, hw, ci, blocks):
    """Build a LayerDesc chain from (F, S, pad, co, relu, pool) specs,
    skipping blocks that would shrink the map below 1 pixel."""
    descs = []
    in_shape = (N, ci, hw, hw)
    for b, (F, S, pad, co, relu, pool) in enumerate(blocks):
        if hw + 2 * pad < F:
            continue
        hw2 = (hw + 2 * pad - F) // S + 1
        if hw2 < 1:
            continue
        conv = ConvLayer(f"c{b}", N, co, hw, F, ci, S, "t", pad=pad)
        hw, ci = hw2, co
        descs.append(LayerDesc(f"c{b}", "conv", conv=conv,
                               out_shape=(N, ci, hw, hw), dtype_bytes=4))
        if relu:
            descs.append(LayerDesc(f"r{b}", "act",
                                   out_shape=(N, ci, hw, hw), dtype_bytes=4))
        if pool and hw >= 2:
            pl = PoolLayer(f"p{b}", N, ci, hw, 2, 2, "t")
            hw = (hw - 2) // 2 + 1
            descs.append(LayerDesc(f"p{b}", "pool", pool=pl,
                                   out_shape=(N, ci, hw, hw), dtype_bytes=4))
    return in_shape, descs


def _check_training_monotone(in_shape, descs):
    pf = plan_fused(descs, input_layout="NCHW", input_shape=in_shape)
    pt = plan_fused(descs, input_layout="NCHW", input_shape=in_shape,
                    training=True)
    # the fusion win survives adding the backward direction...
    assert pt.fused_bytes <= pt.unfused_bytes
    # ...and adding a direction never removes bytes from either side
    assert pt.fused_bytes >= pf.fused_bytes
    assert pt.unfused_bytes >= pf.unfused_bytes
    # per-chain: fused fwd+bwd chain bytes never exceed the decomposed ones
    for d in descs:
        if d.kind != "conv":
            continue
        for lay in ("CHWN", "NCHW"):
            for relu in (False, True):
                for pool in (None, (2, 2)):
                    if pool and d.conv.out_hw < pool[0]:
                        continue
                    fused_b = train_chain_bytes(d.conv, lay, 4, relu=relu,
                                                pool=pool, fused=True)
                    unfused_b = train_chain_bytes(d.conv, lay, 4, relu=relu,
                                                  pool=pool, fused=False)
                    assert fused_b <= unfused_b, (d.name, lay, relu, pool)


def _check_roundtrip(in_shape, descs):
    """Forward+backward layout assignments round-trip: every folded
    re-layout in the training plan is exactly invertible."""
    pt = plan_fused(descs, input_layout="NCHW", input_shape=in_shape,
                    training=True)
    dims = {"N": 2, "C": 3, "H": 4, "W": 5}
    for op in pt.ops:
        for src, dst in ((op.src_layout, op.layout),
                         (op.layout, op.dst_layout)):
            if len(src) != 4 or len(dst) != 4:
                continue
            x = jnp.arange(120, dtype=jnp.float32).reshape(
                tuple(dims[d] for d in src))
            y = apply_transform(apply_transform(x, src, dst), dst, src)
            np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


FIXED_CHAINS = [
    (4, 16, 3, [(3, 1, 1, 8, True, True), (5, 1, 2, 16, True, False)]),
    (16, 20, 1, [(5, 2, 0, 8, False, True), (3, 1, 1, 8, True, True)]),
    (64, 14, 8, [(3, 1, 0, 32, True, False)]),
]

if HAS_HYPOTHESIS:
    BLOCK = st.tuples(st.sampled_from([3, 5]), st.sampled_from([1, 2]),
                      st.integers(0, 2), st.sampled_from([8, 16, 32]),
                      st.booleans(), st.booleans())
    CHAIN = st.tuples(st.sampled_from([4, 16, 64]), st.integers(8, 24),
                      st.sampled_from([1, 3, 8]),
                      st.lists(BLOCK, min_size=1, max_size=3))

    @settings(max_examples=20, deadline=None)
    @given(chain=CHAIN)
    def test_plan_fused_training_never_loses_to_unfused(chain):
        in_shape, descs = _chain_descs(*chain)
        if descs:
            _check_training_monotone(in_shape, descs)

    @settings(max_examples=10, deadline=None)
    @given(chain=CHAIN)
    def test_plan_fused_training_layouts_roundtrip(chain):
        in_shape, descs = _chain_descs(*chain)
        if descs:
            _check_roundtrip(in_shape, descs)
else:
    def test_plan_fused_training_never_loses_to_unfused():
        for chain in FIXED_CHAINS:
            in_shape, descs = _chain_descs(*chain)
            _check_training_monotone(in_shape, descs)

    def test_plan_fused_training_layouts_roundtrip():
        for chain in FIXED_CHAINS:
            in_shape, descs = _chain_descs(*chain)
            _check_roundtrip(in_shape, descs)


def test_assign_layouts_training_doubles_transform_edges():
    """The unfused DP pays each re-layout twice when training (the gradient
    re-layouts back), so the training plan never has more transforms."""
    descs = _alexnet_descs()
    from repro.cnn.network import input_shape
    from repro.configs.cnn_networks import ALEXNET
    a_f = assign_layouts(descs, input_shape=input_shape(ALEXNET))
    a_t = assign_layouts(descs, input_shape=input_shape(ALEXNET),
                         training=True)
    assert a_t.total_s >= a_f.total_s
    assert len(a_t.transforms) <= len(a_f.transforms)


# ---------------------------------------------------------------------------
# planner/executor agreement (ISSUE 3 bugfixes)
# ---------------------------------------------------------------------------

def test_planner_rejects_unexecutable_kinds():
    """Regression: ``layer_cost`` used to price ``lrn`` as a cheap
    elementwise op while the executors raise on it — the planner happily
    produced plans the engine then rejected.  Planning now fails loudly."""
    from repro.core.selector import layer_cost
    conv = LayerDesc("c0", "conv",
                     conv=ConvLayer("c0", 4, 8, 8, 3, 3, 1, "t", pad=1),
                     out_shape=(4, 8, 8, 8), dtype_bytes=4)
    lrn = LayerDesc("lrn1", "lrn", out_shape=(4, 8, 8, 8), dtype_bytes=4)
    with pytest.raises(ValueError, match="lrn"):
        layer_cost(lrn, "CHWN")
    with pytest.raises(ValueError, match="lrn"):
        assign_layouts([conv, lrn])
    with pytest.raises(ValueError, match="lrn"):
        plan_fused([conv, lrn])
    # supported kinds still plan fine
    assert layer_cost(conv, "CHWN") > 0.0


def test_pool_output_size_single_source_of_truth():
    """Selector byte model, heuristic chain model, and the pool kernels all
    derive Ho from ``repro.shapes.pool_out_hw`` — check they agree with the
    kernel's actual output shape."""
    from repro.core.selector import _pool_io_bytes
    from repro.kernels.pool.ops import pool_chwn
    from repro.shapes import pool_out_hw
    for hw, F, S in [(13, 3, 2), (12, 2, 2), (9, 3, 3), (7, 3, 2)]:
        ho = pool_out_hw(hw, F, S)
        x = jnp.zeros((2, hw, hw, 8))
        y = pool_chwn(x, F, S, "max")
        assert y.shape == (2, ho, ho, 8)
        pl_ = PoolLayer("P", 8, 2, hw, F, S, "t")
        desc = LayerDesc("P", "pool", pool=pl_,
                         out_shape=(8, 2, ho, ho), dtype_bytes=4)
        in_b, out_b = _pool_io_bytes(desc)
        assert out_b == 8 * 2 * ho * ho * 4


# ---------------------------------------------------------------------------
# KV-cache layout selection (paper principle on serving)
# ---------------------------------------------------------------------------

def test_kv_layout_big_batch_prefers_sbkd():
    # many (b,k) rows: bksd updates pad one (sublane x lane) tile PER (b,k),
    # while sbkd writes one contiguous row -> sbkd wins (update-side)
    assert select_kv_layout(batch=8, kv_heads=8, seq=32768, head_dim=128,
                            steps_per_read=0.0) == "sbkd"


def test_kv_layout_small_row_prefers_bksd():
    # B*K*Dh far below one native tile: sbkd reads are mostly padding ->
    # bksd wins once reads matter
    assert select_kv_layout(batch=1, kv_heads=1, seq=32768, head_dim=64,
                            steps_per_read=4.0) == "bksd"
