"""Cross-layer halo fusion (DESIGN.md §12): differential tests of the
conv->conv stack kernel against the decomposed XLA reference, planner
property tests (VMEM gating, byte dominance, degeneracy to PR-6 plans),
end-to-end stacked execution, and PlanCache schema compatibility."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cnn.layers import fused_conv_stack, init_cnn
from repro.cnn.network import (forward_fused, input_shape,
                               plan_network_fused)
from repro.configs.base import CNNConfig, ConvSpec
from repro.configs.cnn_networks import CNN_CONFIGS, LENET, reduced_cnn
from repro.configs.paper_table1 import ConvLayer
from repro.core.heuristic import (STACK_VMEM_BUDGET, stack_nt,
                                  stack_vmem_bytes)
from repro.core.selector import FusedOp, FusedPlan
from repro.serve.plan_cache import _plan_from_obj, _plan_to_obj

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# stack kernel vs decomposed XLA: forward differential
# ---------------------------------------------------------------------------

# (H, Ci, Cm, Co, F1, S1, P1, F2, S2, P2, pool, res) — channel counts are
# deliberately NOT multiples of the engine tile widths
CASES = {
    "base_3x3":      (8, 3, 5, 7, 3, 1, 1, 3, 1, 1, None, False),
    "stride1_2":     (9, 3, 5, 7, 3, 2, 1, 3, 1, 1, None, False),
    "stride2_2":     (9, 3, 5, 7, 3, 1, 1, 3, 2, 1, None, False),
    "f5_then_f1":    (9, 4, 6, 5, 5, 1, 2, 1, 1, 0, None, False),
    "ho_eq_1":       (5, 3, 5, 7, 3, 1, 0, 3, 1, 0, None, False),
    "pool_tail":     (8, 3, 5, 7, 3, 1, 1, 3, 1, 1, (2, 2, "max"), False),
    "residual":      (8, 3, 5, 7, 3, 1, 1, 3, 1, 1, None, True),
    "res_and_pool":  (8, 3, 5, 7, 3, 1, 1, 3, 1, 1, (2, 2, "max"), True),
}


def _stack_case(layout, case, dtype=jnp.float32):
    H, Ci, Cm, Co, F1, S1, P1, F2, S2, P2, pool, want_res = CASES[case]
    N = 2
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    x_nchw = jax.random.normal(k1, (N, Ci, H, H), dtype)
    w1 = jax.random.normal(k2, (Cm, Ci, F1, F1), dtype) * 0.2
    w2 = jax.random.normal(k3, (Co, Cm, F2, F2), dtype) * 0.2
    x = jnp.transpose(x_nchw, (1, 2, 3, 0)) if layout == "CHWN" else x_nchw
    res = None
    if want_res:
        Ho1 = (H + 2 * P1 - F1) // S1 + 1
        Ho2 = (Ho1 + 2 * P2 - F2) // S2 + 1
        shp = ((Co, Ho2, Ho2, N) if layout == "CHWN"
               else (N, Co, Ho2, Ho2))
        res = jax.random.normal(k4, shp, dtype)
    return x, w1, w2, res, (S1, P1, S2, P2, pool)


@pytest.mark.parametrize("layout", ["CHWN", "NCHW"])
@pytest.mark.parametrize("case", sorted(CASES))
def test_stack_kernel_matches_xla(layout, case):
    """ISSUE 7 acceptance: one-kernel conv->conv stack (mid staged in VMEM)
    reproduces the two-kernel XLA reference to <= 1e-5 across strides,
    pads, filter sizes, the Ho==1 halo edge, non-tile-divisible channels,
    both engines, and a residual folded onto the second conv."""
    x, w1, w2, res, (S1, P1, S2, P2, pool) = _stack_case(layout, case)
    kw = dict(stride1=S1, pad1=P1, stride2=S2, pad2=P2, relu1=True,
              relu2=True, pool=pool, res=res, res_layout=layout, nt=2)
    yp = fused_conv_stack(x, w1, w2, layout, impl="pallas", **kw)
    yx = fused_conv_stack(x, w1, w2, layout, impl="xla", **kw)
    assert yp.shape == yx.shape
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yx), atol=1e-5)


@pytest.mark.parametrize("layout", ["CHWN", "NCHW"])
def test_stack_kernel_gradients_match_xla(layout):
    """The stack's custom VJP (unfused replay) agrees with differentiating
    the decomposed reference: d/dx, d/dw1, d/dw2, d/dres."""
    x, w1, w2, res, (S1, P1, S2, P2, pool) = _stack_case(layout, "residual")

    def run(impl):
        def f(x, w1, w2, res):
            y = fused_conv_stack(x, w1, w2, layout, stride1=S1, pad1=P1,
                                 stride2=S2, pad2=P2, relu1=True, relu2=True,
                                 pool=pool, res=res, res_layout=layout,
                                 nt=2, impl=impl)
            return jnp.sum(y * jnp.cos(y))
        return jax.grad(f, argnums=(0, 1, 2, 3))(x, w1, w2, res)

    for a, b in zip(run("pallas"), run("xla")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ---------------------------------------------------------------------------
# planner properties
# ---------------------------------------------------------------------------

def _n_stacks(plan):
    return sum(1 for op in plan.ops if op.stack_index is not None)


def _big_pair():
    """Two 512-channel 3x3 convs: the weights alone (~18.9 MB fp32) blow the
    stack VMEM budget in every layout, at every N tile."""
    l1 = ConvLayer("c1", 64, 512, 14, 3, 512, 1, "t", pad=1)
    l2 = ConvLayer("c2", 64, 512, 14, 3, 512, 1, "t", pad=1)
    return l1, l2


def test_stack_nt_zero_when_vmem_exceeded():
    l1, l2 = _big_pair()
    for lay in ("CHWN", "NCHW"):
        assert stack_vmem_bytes(l1, l2, lay, 4, nt=1) > STACK_VMEM_BUDGET
        assert stack_nt(l1, l2, lay, 4) == 0


def test_planner_never_stacks_past_vmem_bound():
    """A network built from the over-budget pair plans with zero stacks even
    though the pair is structurally stackable."""
    cfg = CNNConfig(
        name="bigpair", batch=64, in_channels=512, image_hw=14,
        num_classes=10,
        layers=(ConvSpec("c1", "conv", 512, 3, 1, 1),
                ConvSpec("r1", "relu"),
                ConvSpec("c2", "conv", 512, 3, 1, 1),
                ConvSpec("r2", "relu"),
                ConvSpec("flatten", "flatten"),
                ConvSpec("fc", "fc", fc_out=10),
                ConvSpec("softmax", "softmax")))
    plan = plan_network_fused(cfg, "float32")
    assert _n_stacks(plan) == 0
    # ... and the missed round trip is NOT charged to the fusion report:
    # the pair fails the gates, so it is not a planner regression
    assert plan.intermediate_roundtrip_bytes == 0


@pytest.mark.parametrize("name", sorted(CNN_CONFIGS))
def test_stacked_plans_never_cost_more_bytes(name):
    """ISSUE 7 property: for every network, the auto plan's modeled HBM
    bytes are <= the stack-off plan's (stacking only fires when the byte
    model strictly drops), and profitable pairs are never left unfused."""
    auto = plan_network_fused(CNN_CONFIGS[name], "float32")
    off = plan_network_fused(CNN_CONFIGS[name], "float32",
                             stack_policy="off")
    assert auto.fused_bytes <= off.fused_bytes
    assert auto.intermediate_roundtrip_bytes == 0
    if _n_stacks(auto):
        assert auto.fused_bytes < off.fused_bytes


def test_issue7_acceptance_byte_drops():
    """AlexNet and ResNet-18 fused-forward modeled HBM bytes drop >= 10%
    once stacks fuse (the committed PR-6 trajectory equals the
    stack_policy="off" plan, see test below)."""
    for name in ("alexnet", "resnet18"):
        auto = plan_network_fused(CNN_CONFIGS[name], "float32")
        off = plan_network_fused(CNN_CONFIGS[name], "float32",
                                 stack_policy="off")
        assert _n_stacks(auto) >= 1
        assert auto.fused_bytes <= 0.9 * off.fused_bytes, name


def test_no_profitable_stack_degenerates_to_pr6_plan():
    """LeNet (5x5 convs separated by pools — no adjacent conv pair) must
    plan byte-identically with stacking on or off: same layouts, bytes,
    seconds, and op stream."""
    auto = plan_network_fused(LENET, "float32")
    off = plan_network_fused(LENET, "float32", stack_policy="off")
    assert _n_stacks(auto) == 0
    assert auto.layouts == off.layouts
    assert auto.fused_bytes == off.fused_bytes
    assert auto.total_s == pytest.approx(off.total_s, rel=1e-12)
    assert ([dataclasses.astuple(o) for o in auto.ops]
            == [dataclasses.astuple(o) for o in off.ops])


def test_mixed_and_training_plans_never_stack():
    """Stacking is gated to uniform-dtype inference plans: mixed-dtype and
    training plans must be untouched (their signatures are pinned by the
    PR-5 trajectory)."""
    mixed = plan_network_fused(CNN_CONFIGS["alexnet"], policy="mixed")
    assert _n_stacks(mixed) == 0
    from repro.cnn.network import network_descs
    from repro.core.selector import plan_fused
    cfg = CNN_CONFIGS["alexnet"]
    train = plan_fused(network_descs(cfg, "float32"), input_layout="NCHW",
                       input_shape=input_shape(cfg), training=True,
                       base_dtype="float32")
    assert _n_stacks(train) == 0


def test_stack_signature_letters_double():
    """conv_signature/dtype_signature emit two letters per stacked op so the
    per-conv-LAYER signature length is stable across stacking."""
    plan = plan_network_fused(CNN_CONFIGS["resnet18"], "float32")
    off = plan_network_fused(CNN_CONFIGS["resnet18"], "float32",
                             stack_policy="off")
    assert _n_stacks(plan) >= 1
    assert len(plan.conv_signature) == len(off.conv_signature)
    assert len(plan.dtype_signature) == len(off.dtype_signature)


# ---------------------------------------------------------------------------
# end-to-end stacked execution
# ---------------------------------------------------------------------------

def test_stacked_forward_pallas_matches_xla_and_saves_bytes():
    """ISSUE 7 acceptance on a real branching network: the stacked Pallas
    execution reproduces the un-stacked XLA decomposition to <= 1e-5, and
    the stacked run models strictly fewer HBM bytes (the mid tensors never
    round-trip)."""
    cfg = reduced_cnn(CNN_CONFIGS["resnet18"], batch=4)
    params = init_cnn(KEY, cfg)
    x = jax.random.normal(KEY, input_shape(cfg))
    auto = plan_network_fused(cfg, "float32")
    off = plan_network_fused(cfg, "float32", stack_policy="off")
    assert _n_stacks(auto) >= 1
    got, s_auto = forward_fused(params, x, cfg, auto, impl="pallas")
    ref, s_off = forward_fused(params, x, cfg, off, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
    assert s_auto.hbm_bytes < s_off.hbm_bytes


# ---------------------------------------------------------------------------
# PlanCache schema compatibility
# ---------------------------------------------------------------------------

def test_plan_cache_roundtrips_stacked_plan():
    plan = plan_network_fused(CNN_CONFIGS["resnet18"], "float32")
    assert _n_stacks(plan) >= 1
    back = _plan_from_obj(json.loads(json.dumps(_plan_to_obj(plan))))
    assert back == plan


def test_plan_cache_loads_legacy_plan_without_stack_fields():
    """Pre-ISSUE-7 cache entries carry no stack_index / stack_relu /
    intermediate_roundtrip_bytes keys; they must deserialize to exactly the
    un-stacked semantics."""
    plan = plan_network_fused(LENET, "float32")
    obj = json.loads(json.dumps(_plan_to_obj(plan)))
    obj.pop("intermediate_roundtrip_bytes")
    for op in obj["ops"]:
        op.pop("stack_index")
        op.pop("stack_relu")
    back = _plan_from_obj(obj)
    assert back == plan
    assert back.intermediate_roundtrip_bytes == 0
    assert all(op.stack_index is None for op in back.ops)
