"""perfmodel subsystem tests (DESIGN.md §13).

Covers the §13 contracts end to end:

  * the three-way byte agreement — ``plan.fused_bytes`` (planner) ==
    ``CostModel.plan_bytes`` (predictor) == ``RunStats.hbm_bytes``
    (executor) — over every registered network x dtype policy x stack
    policy;
  * byte-identity of post-refactor plans against pre-refactor golden
    fingerprints (the shim refactor must not move a single byte);
  * hardware-versioned threshold persistence (v3 roundtrip, legacy v1/v2
    files loading as the unversioned default row, lookup fallback) in both
    the standalone file and the plan cache;
  * the cross-validation loop + ``CalibratedCostModel`` overlay;
  * the satellites: ``sublanes`` raising on unknown element sizes, the HLO
    dtype-bytes table agreeing with the storage table, and the boundary
    lint catching deprecated-shim imports.
"""
import dataclasses
import hashlib
import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.cnn.layers import init_cnn
from repro.cnn.network import (forward_fused, input_shape, network_descs,
                               plan_network_fused)
from repro.configs.cnn_networks import CNN_CONFIGS
from repro.configs.paper_table1 import ConvLayer
from repro.core.selector import assign_layouts
from repro.dtypes import HLO_DTYPE_BYTES, dtype_bytes
from repro.perfmodel import (DEFAULT_HARDWARE, AnalyticCostModel,
                             CalibratedCostModel, Thresholds, conv_cost,
                             cross_validate, default_cost_model,
                             load_thresholds, save_thresholds, sublanes)
from repro.perfmodel.calibration import proxied_layer

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))


# ---------------------------------------------------------------------------
# satellite 1: sublanes raises on unknown element sizes
# ---------------------------------------------------------------------------

def test_sublanes_known_widths():
    assert sublanes(4) == 8
    assert sublanes(2) == 16
    assert sublanes(1) == 32


def test_sublanes_unknown_dtype_bytes_raises():
    """The old ``_sublanes`` silently returned 8 for any unknown element
    size, quietly mispricing every tile-utilization term downstream."""
    for bad in (0, 3, 8, 16, -1):
        with pytest.raises(ValueError, match="sublane"):
            sublanes(bad)
    # the deprecated shim alias raises identically
    from repro.core.heuristic import _sublanes
    with pytest.raises(ValueError):
        _sublanes(8)


# ---------------------------------------------------------------------------
# satellite 2: one dtype-bytes table
# ---------------------------------------------------------------------------

def test_hlo_dtype_bytes_agrees_with_storage_table():
    """The HLO-name table and the storage-dtype table are views of one
    fact; roofline imports the HLO table rather than hand-rolling it."""
    for storage, hlo in (("float32", "f32"), ("bfloat16", "bf16"),
                         ("float16", "f16"), ("int8", "s8")):
        assert HLO_DTYPE_BYTES[hlo] == dtype_bytes(storage)
    from repro.launch import roofline
    assert roofline._DTYPE_BYTES is HLO_DTYPE_BYTES


# ---------------------------------------------------------------------------
# satellite 3a: the three-way byte agreement property
# ---------------------------------------------------------------------------

def _executor_bytes(cfg, plan, dtype="float32"):
    """RunStats.hbm_bytes under jax.eval_shape (accounting is shape-only)."""
    from repro.dtypes import jnp_dtype
    jdt = jnp_dtype(dtype)
    params = jax.eval_shape(lambda k: init_cnn(k, cfg, dtype=jdt),
                            jax.random.PRNGKey(0))
    box = {}

    def f(p, x):
        y, st = forward_fused(p, x, cfg, plan, impl="xla")
        box["st"] = st
        return y

    jax.eval_shape(f, params,
                   jax.ShapeDtypeStruct(input_shape(cfg), jdt))
    return box["st"].hbm_bytes


@pytest.mark.parametrize("net", list(CNN_CONFIGS))
@pytest.mark.parametrize("policy", ["uniform", "mixed"])
@pytest.mark.parametrize("stack", ["auto", "off"])
def test_plan_bytes_matches_planner_and_executor(net, policy, stack):
    """planner emission == CostModel.plan_bytes replay == executor tally,
    EXACTLY, for every registered network x dtype policy x stack policy."""
    cfg = CNN_CONFIGS[net]
    plan = plan_network_fused(cfg, policy=policy, stack_policy=stack)
    cm = default_cost_model()
    predicted = cm.plan_bytes(network_descs(cfg), plan,
                              input_shape=input_shape(cfg))
    assert predicted == plan.fused_bytes
    assert _executor_bytes(cfg, plan) == plan.fused_bytes


# ---------------------------------------------------------------------------
# satellite 3b: plans byte-identical to pre-refactor
# ---------------------------------------------------------------------------

def _fp(obj) -> str:
    js = json.dumps(obj, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(js.encode()).hexdigest()[:16]


# sha256[:16] of the canonical plan JSON captured on the pre-perfmodel tree
# (PR 7).  The refactor routes every consumer through CostModel; these pins
# prove not one byte of planner output moved.
GOLDEN = {
    "lenet/uniform/auto": "76841a6744ac1df7",
    "lenet/uniform/off": "76841a6744ac1df7",
    "lenet/mixed/auto": "76841a6744ac1df7",
    "lenet/mixed/off": "76841a6744ac1df7",
    "alexnet/uniform/auto": "b226b9bda5f104ba",
    "alexnet/uniform/off": "821574aeb9c19590",
    "alexnet/mixed/auto": "3b854c49d60edb63",
    "alexnet/mixed/off": "3b854c49d60edb63",
    "resnet18/uniform/auto": "be7a132520e6dcbb",
    "resnet18/uniform/off": "6860daa975d58384",
    "resnet18/mixed/auto": "e873385212ee4d1b",
    "resnet18/mixed/off": "e873385212ee4d1b",
    "lenet/assign/infer": "6777c75489f509f3",
    "lenet/assign/train": "7da02765d8529eb0",
    "alexnet/assign/infer": "19a83f54736037b4",
    "alexnet/assign/train": "3058d11063f55b66",
    "resnet18/assign/infer": "8d388022ad485d76",
    "resnet18/assign/train": "0e119002ed9485cd",
}


@pytest.mark.parametrize("net", ["lenet", "alexnet", "resnet18"])
def test_fused_plans_byte_identical_to_pre_refactor(net):
    cfg = CNN_CONFIGS[net]
    for policy in ("uniform", "mixed"):
        for stack in ("auto", "off"):
            plan = plan_network_fused(cfg, policy=policy, stack_policy=stack)
            assert _fp(dataclasses.asdict(plan)) == \
                GOLDEN[f"{net}/{policy}/{stack}"], (net, policy, stack)


@pytest.mark.parametrize("net", ["lenet", "alexnet", "resnet18"])
def test_assignments_byte_identical_to_pre_refactor(net):
    cfg = CNN_CONFIGS[net]
    for training in (False, True):
        asn = assign_layouts(network_descs(cfg), input_layout="NCHW",
                             input_shape=input_shape(cfg), training=training)
        key = f"{net}/assign/{'train' if training else 'infer'}"
        assert _fp(dataclasses.asdict(asn)) == GOLDEN[key], key


# ---------------------------------------------------------------------------
# hardware-versioned threshold rows
# ---------------------------------------------------------------------------

def test_threshold_rows_roundtrip_by_hardware(tmp_path):
    path = str(tmp_path / "th.json")
    save_thresholds(Thresholds(32, 64), path, dtype="f32",
                    hardware="TPU v4/interpret")
    save_thresholds(Thresholds(16, 128), path, dtype="f32",
                    hardware="TPU v5e")
    save_thresholds(Thresholds(8, 256), path, dtype="bf16",
                    hardware="TPU v4/interpret")
    assert load_thresholds(path, "f32",
                           hardware="TPU v4/interpret") == Thresholds(32, 64)
    assert load_thresholds(path, "f32", hardware="TPU v5e") == \
        Thresholds(16, 128)
    assert load_thresholds(path, "bf16",
                           hardware="TPU v4/interpret") == Thresholds(8, 256)
    # v3 on disk
    obj = json.load(open(path))
    assert obj["version"] == 3
    assert set(obj["hardware"]) == {"TPU v4/interpret", "TPU v5e"}


def test_legacy_threshold_files_load_as_default_row(tmp_path):
    # v1: flat {Ct, Nt}
    p1 = str(tmp_path / "v1.json")
    json.dump({"Ct": 32, "Nt": 64}, open(p1, "w"))
    assert load_thresholds(p1, "f32") == Thresholds(32, 64)
    assert load_thresholds(p1, "f32", hardware="anything") == \
        Thresholds(32, 64)      # unknown hardware falls back to default
    # v2: per-dtype rows, no hardware
    p2 = str(tmp_path / "v2.json")
    json.dump({"version": 2, "rows": {"bf16": {"Ct": 16, "Nt": 128}}},
              open(p2, "w"))
    assert load_thresholds(p2, "bfloat16") == Thresholds(16, 128)
    with pytest.raises(KeyError):
        load_thresholds(p2, "f32")
    # merging a hardware row PRESERVES the legacy default row
    save_thresholds(Thresholds(4, 512), p1, dtype="f32", hardware="hw-x")
    assert load_thresholds(p1, "f32", hardware="hw-x") == Thresholds(4, 512)
    assert load_thresholds(p1, "f32", hardware="hw-y") == Thresholds(32, 64)


def test_plan_cache_thresholds_keyed_by_hardware(tmp_path):
    from repro.serve.plan_cache import PlanCache
    path = str(tmp_path / "cache.json")
    # legacy cache JSON: unversioned thresholds = default-hardware row
    json.dump({"version": 2,
               "thresholds": {"f32": {"Ct": 32, "Nt": 64}},
               "fused": [], "unfused": []}, open(path, "w"))
    c = PlanCache(path)
    assert c.thresholds_for("f32") == Thresholds(32, 64)
    assert c.thresholds_for("f32", "TPU v9") == Thresholds(32, 64)  # fallbk
    c.set_thresholds(Thresholds(16, 128), "f32", hardware="TPU v9")
    assert c.thresholds_for("f32", "TPU v9") == Thresholds(16, 128)
    assert c.thresholds_for("f32") == Thresholds(32, 64)  # default intact
    c.save()
    c2 = PlanCache(path)
    assert c2.thresholds_for("f32", "TPU v9") == Thresholds(16, 128)
    assert c2.thresholds_for("f32") == Thresholds(32, 64)
    # the legacy field keeps its legacy shape on disk
    obj = json.load(open(path))
    assert obj["thresholds"] == {"float32": {"Ct": 32, "Nt": 64}}
    assert obj["thresholds_hw"] == {
        "TPU v9": {"float32": {"Ct": 16, "Nt": 128}}}


# ---------------------------------------------------------------------------
# cross-validation + CalibratedCostModel
# ---------------------------------------------------------------------------

def _fake_measure(scale=3.0):
    """A 'measurement' that is exactly scale x the analytic model on the
    proxied layer — the overlay fit must recover it with ~zero residual."""
    def measure(l: ConvLayer, layout: str) -> float:
        return scale * conv_cost(proxied_layer(l), layout, 4).total_s
    return measure


def test_cross_validate_recovers_exact_overlay():
    cv = cross_validate(_fake_measure(3.0), hardware="fake-hw")
    assert cv.hardware == "fake-hw"
    assert len(cv.points) == 12                  # 6 sweep points x 2 layouts
    assert cv.mean_rel_err < 1e-9
    assert cv.max_rel_err < 1e-9
    for a, b in cv.scales.values():
        assert a == pytest.approx(3.0, rel=1e-6)
        assert b == pytest.approx(1.0, abs=1e-9)
    for p in cv.points:
        assert p.predicted_s == pytest.approx(p.measured_s, rel=1e-9)
        assert p.analytic_s > 0


def test_calibrated_cost_model_overlays_seconds_not_bytes():
    cv = cross_validate(_fake_measure(3.0), hardware="fake-hw")
    cal = CalibratedCostModel(cv)
    ana = AnalyticCostModel()
    l = ConvLayer("T", 64, 32, 14, 3, 16, 1, "t")
    for lay in ("CHWN", "NCHW"):
        c0 = ana.conv_cost(l, lay, 4)
        c1 = cal.conv_cost(l, lay, 4)
        assert c1.total_s == pytest.approx(3.0 * c0.total_s, rel=1e-6)
        # the overlay preserves the compute/memory balance
        assert c1.compute_s * c0.memory_s == pytest.approx(
            c0.compute_s * c1.memory_s, rel=1e-6)
        assert cal.predict_seconds(c0.total_s, lay) == pytest.approx(
            3.0 * c0.total_s, rel=1e-6)
    # byte models pass through untouched
    assert cal.chain_bytes(l, 4) == ana.chain_bytes(l, 4)
    assert cal.conv_backward_bytes(l, "CHWN", 4) == \
        ana.conv_backward_bytes(l, "CHWN", 4)


def test_calibrated_plans_match_analytic_plans():
    """A pure multiplicative overlay rescales every candidate identically,
    so the DP's argmin — the plan — must not move."""
    cv = cross_validate(_fake_measure(2.5), hardware="fake-hw")
    cal = CalibratedCostModel(cv)
    cfg = CNN_CONFIGS["alexnet"]
    base = plan_network_fused(cfg)
    from repro.core.selector import plan_fused
    calibrated = plan_fused(network_descs(cfg), input_layout="NCHW",
                            input_shape=input_shape(cfg), cost_model=cal)
    assert calibrated.layouts == base.layouts
    assert calibrated.fused_bytes == base.fused_bytes
    assert [dataclasses.asdict(op) for op in calibrated.ops] == \
        [dataclasses.asdict(op) for op in base.ops]
    # conv legs scale by exactly 2.5; pool/fc/cast legs are not overlaid
    # (the overlay calibrates the CONV kernels), so the plan total lands
    # between the analytic total and a uniform 2.5x
    assert base.total_s < calibrated.total_s <= 2.5 * base.total_s + 1e-12


# ---------------------------------------------------------------------------
# satellite 5: boundary lint
# ---------------------------------------------------------------------------

def test_boundary_lint_passes_on_tree():
    import check_perfmodel_boundary as lint
    assert lint.main() == 0


def test_boundary_lint_flags_shim_imports(tmp_path):
    import check_perfmodel_boundary as lint
    bad = tmp_path / "bad.py"
    bad.write_text("from repro.core.heuristic import chain_bytes\n")
    assert lint._check_file(bad)
    bad2 = tmp_path / "bad2.py"
    bad2.write_text("from repro.core import heuristic as H\n"
                    "x = H.conv_cost(None, 'CHWN')\n")
    assert lint._check_file(bad2)
    bad3 = tmp_path / "bad3.py"
    bad3.write_text("from repro.core import conv_backward_bytes\n")
    assert lint._check_file(bad3)
    ok = tmp_path / "ok.py"
    ok.write_text("from repro.perfmodel import chain_bytes\n"
                  "from repro.core import Thresholds, plan_fused\n")
    assert not lint._check_file(ok)
