"""CNN substrate: layout-polymorphic execution, mode consistency, training,
and the paper's end-to-end integration behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.cnn_networks import (ALEXNET, CIFARNET, CNN_CONFIGS,
                                        LENET, VGG16, ZFNET)
from repro.cnn.layers import init_cnn, layer_shapes
from repro.cnn.network import (forward, init_velocity, make_train_step,
                               network_descs, plan_network)

KEY = jax.random.PRNGKey(0)


def _small(cfg, batch=8, hw=None):
    # deep nets (alexnet/zfnet/vgg) downsample ~32x: keep >= 96 px
    default = 32 if cfg.image_hw <= 32 else 96
    return cfg.replace(batch=batch,
                       image_hw=hw or min(cfg.image_hw, default))


@pytest.mark.parametrize("name", list(CNN_CONFIGS))
def test_all_networks_forward_all_modes_agree(name):
    cfg = _small(CNN_CONFIGS[name])
    params = init_cnn(KEY, cfg)
    x = jax.random.normal(KEY, (cfg.batch, cfg.in_channels,
                                cfg.image_hw, cfg.image_hw))
    outs = {}
    for mode in ("cuda-convnet", "cudnn", "opt"):
        layouts = plan_network(cfg, mode)
        probs, stats = forward(params, x, cfg, layouts)
        assert probs.shape == (cfg.batch, cfg.num_classes)
        assert not bool(jnp.isnan(probs).any())
        np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, atol=1e-4)
        outs[mode] = np.asarray(probs)
    np.testing.assert_allclose(outs["cuda-convnet"], outs["cudnn"], atol=3e-4)
    np.testing.assert_allclose(outs["opt"], outs["cudnn"], atol=3e-4)


def test_lenet_training_decreases_loss():
    cfg = _small(LENET, batch=16, hw=28)
    layouts = plan_network(cfg, "opt")
    params = init_cnn(KEY, cfg)
    from repro.data.pipeline import ImageStream
    stream = ImageStream(cfg.batch, cfg.in_channels, cfg.image_hw,
                         cfg.num_classes, seed=1)
    step = make_train_step(cfg, layouts, lr=0.02)
    vel = init_velocity(params)
    x, y = stream.batch_at(0)
    first = None
    for i in range(30):
        params, vel, loss = step(params, vel, jnp.asarray(x), jnp.asarray(y))
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.7, (first, float(loss))


def test_pallas_engine_matches_xla_engine():
    cfg = _small(LENET, batch=8, hw=28)
    layouts = plan_network(cfg, "opt")
    params = init_cnn(KEY, cfg)
    x = jax.random.normal(KEY, (8, 1, 28, 28))
    px, _ = forward(params, x, cfg, layouts, impl="xla")
    pp, _ = forward(params, x, cfg, layouts, impl="pallas",
                    use_pallas_transform=True)
    np.testing.assert_allclose(np.asarray(px), np.asarray(pp), atol=2e-4)


def test_transform_count_reported():
    cfg = _small(ALEXNET)
    layouts = plan_network(cfg, "opt")
    params = init_cnn(KEY, cfg)
    x = jax.random.normal(KEY, (cfg.batch, 3, cfg.image_hw, cfg.image_hw))
    _, stats = forward(params, x, cfg, layouts)
    changes = sum(1 for a, b in zip(["NCHW"] + layouts, layouts) if a != b
                  )
    assert stats.transforms <= max(changes, 1)
    assert stats.transforms >= 1 or all(l == "NCHW" for l in layouts)


def test_layer_shapes_propagation():
    shapes = layer_shapes(LENET)
    assert shapes[0] == (128, 16, 28, 28)      # conv1 (pad=2 keeps 28)
    assert shapes[-1] == (128, 10)
