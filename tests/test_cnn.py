"""CNN substrate: layout-polymorphic execution, mode consistency, training,
and the paper's end-to-end integration behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.cnn_networks import (ALEXNET, CIFARNET, CNN_CONFIGS,
                                        LENET, VGG16, ZFNET)
from repro.cnn.layers import init_cnn, layer_shapes
from repro.cnn.network import (forward, init_velocity, make_train_step,
                               network_descs, plan_network)

KEY = jax.random.PRNGKey(0)


def _small(cfg, batch=8, hw=None):
    # branching nets go through their builder so skip edges re-derive at the
    # small size (a bare replace() would break merge shapes / the gap pool)
    from repro.configs.cnn_networks import CNN_BUILDERS
    builder = CNN_BUILDERS.get(cfg.name)
    if builder is not None:
        return builder(batch=batch, image_hw=hw or 32,
                       num_classes=cfg.num_classes, width=16)
    # deep nets (alexnet/zfnet/vgg) downsample ~32x: keep >= 96 px
    default = 32 if cfg.image_hw <= 32 else 96
    return cfg.replace(batch=batch,
                       image_hw=hw or min(cfg.image_hw, default))


@pytest.mark.parametrize("name", list(CNN_CONFIGS))
def test_all_networks_forward_all_modes_agree(name):
    cfg = _small(CNN_CONFIGS[name])
    params = init_cnn(KEY, cfg)
    x = jax.random.normal(KEY, (cfg.batch, cfg.in_channels,
                                cfg.image_hw, cfg.image_hw))
    outs = {}
    for mode in ("cuda-convnet", "cudnn", "opt"):
        layouts = plan_network(cfg, mode)
        probs, stats = forward(params, x, cfg, layouts)
        assert probs.shape == (cfg.batch, cfg.num_classes)
        assert not bool(jnp.isnan(probs).any())
        np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, atol=1e-4)
        outs[mode] = np.asarray(probs)
    np.testing.assert_allclose(outs["cuda-convnet"], outs["cudnn"], atol=3e-4)
    np.testing.assert_allclose(outs["opt"], outs["cudnn"], atol=3e-4)


def test_lenet_training_decreases_loss():
    cfg = _small(LENET, batch=16, hw=28)
    layouts = plan_network(cfg, "opt")
    params = init_cnn(KEY, cfg)
    from repro.data.pipeline import ImageStream
    stream = ImageStream(cfg.batch, cfg.in_channels, cfg.image_hw,
                         cfg.num_classes, seed=1)
    step = make_train_step(cfg, layouts, lr=0.02)
    vel = init_velocity(params)
    x, y = stream.batch_at(0)
    first = None
    for i in range(30):
        params, vel, loss = step(params, vel, jnp.asarray(x), jnp.asarray(y))
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.7, (first, float(loss))


def test_pallas_engine_matches_xla_engine():
    cfg = _small(LENET, batch=8, hw=28)
    layouts = plan_network(cfg, "opt")
    params = init_cnn(KEY, cfg)
    x = jax.random.normal(KEY, (8, 1, 28, 28))
    px, _ = forward(params, x, cfg, layouts, impl="xla")
    pp, _ = forward(params, x, cfg, layouts, impl="pallas",
                    use_pallas_transform=True)
    np.testing.assert_allclose(np.asarray(px), np.asarray(pp), atol=2e-4)


def test_transform_count_reported():
    cfg = _small(ALEXNET)
    layouts = plan_network(cfg, "opt")
    params = init_cnn(KEY, cfg)
    x = jax.random.normal(KEY, (cfg.batch, 3, cfg.image_hw, cfg.image_hw))
    _, stats = forward(params, x, cfg, layouts)
    changes = sum(1 for a, b in zip(["NCHW"] + layouts, layouts) if a != b
                  )
    assert stats.transforms <= max(changes, 1)
    assert stats.transforms >= 1 or all(l == "NCHW" for l in layouts)


def test_layer_shapes_propagation():
    shapes = layer_shapes(LENET)
    assert shapes[0] == (128, 16, 28, 28)      # conv1 (pad=2 keeps 28)
    assert shapes[-1] == (128, 10)


def test_fused_engine_matches_unfused_reference():
    """The fused plan (one kernel per conv->relu->pool chain, layout-fused
    I/O) reproduces the unfused forward with ZERO standalone transforms and
    strictly less modeled HBM traffic."""
    from repro.cnn.network import forward_fused, plan_network_fused
    for base in (LENET, CIFARNET, ALEXNET):
        cfg = _small(base)
        params = init_cnn(KEY, cfg)
        x = jax.random.normal(KEY, (cfg.batch, cfg.in_channels,
                                    cfg.image_hw, cfg.image_hw))
        layouts = plan_network(cfg, "opt")
        ref, sref = forward(params, x, cfg, layouts, impl="xla")
        plan = plan_network_fused(cfg)
        got, stats = forward_fused(params, x, cfg, plan, impl="pallas")
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-4)
        assert stats.transforms == 0
        assert stats.fused_ops == sum(1 for op in plan.ops
                                      if op.kind in ("conv", "pool")
                                      and op.is_fused)
        assert stats.fused_ops > 0
        assert stats.hbm_bytes < sref.hbm_bytes
        assert plan.saved_bytes > 0


def test_fused_plan_folds_conv_relu_pool_chains():
    from repro.cnn.network import plan_network_fused
    plan = plan_network_fused(_small(ALEXNET))
    convs = [op for op in plan.ops if op.kind == "conv"]
    assert len(convs) == 5
    assert all(op.relu for op in convs)          # every conv folds its relu
    assert sum(op.pool_index is not None for op in convs) == 3
    assert plan.transforms == []                 # nothing left standalone
    # the op stream never revisits folded layers
    seen = [op.index for op in plan.ops]
    assert seen == sorted(seen)


def test_runstats_counts_only_real_transforms():
    """Identity re-layouts must not inflate the transform count: all-NCHW
    execution of an NCHW input performs zero transforms."""
    cfg = _small(LENET, batch=4, hw=28)
    params = init_cnn(KEY, cfg)
    x = jax.random.normal(KEY, (4, 1, 28, 28))
    _, stats = forward(params, x, cfg, ["NCHW"] * len(cfg.layers))
    assert stats.transforms == 0 and stats.transform_bytes == 0
