"""Unit tests for the bench-trajectory CI gate's per-field direction table
(ISSUE 7 satellite): higher-is-better fields (``saving``, ``bytes_ratio``,
``hit_rate``) must fail on SHRINKAGE, ``*_bytes`` fields on growth, the
exact counters (``standalone_adds``, ``intermediate_roundtrip_bytes``,
``dropped_requests``) on any growth at all, and the scale-row fields
(ISSUE 10: ``per_chip_bytes`` lower-is-better, ``devices`` exact match
both directions) — each probed with a doctored trajectory both ways."""
from __future__ import annotations

import copy
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.check_trajectory import (COUNT_FIELDS, EXACT_MATCH_FIELDS,
                                         FIELD_DIRECTION, compare,
                                         schema_errors)

BASE = {
    "table": "fusion",
    "quick": True,
    "records": [
        {"name": "fusion/alexnet/traffic", "network": "alexnet",
         "dtype": "float32", "seed_bytes": 1000, "fused_bytes": 400,
         "saving": 0.60, "bytes_ratio": 0.40, "hit_rate": 1.0,
         "standalone_adds": 0, "intermediate_roundtrip_bytes": 0,
         "dropped_requests": 0},
    ],
}

TOL = 0.05


def _doctor(**fields):
    cand = copy.deepcopy(BASE)
    cand["records"][0].update(fields)
    return cand


def test_clean_candidate_passes():
    assert compare(BASE, copy.deepcopy(BASE), "fusion", TOL) == []


def test_direction_table_covers_issue_fields():
    for k in ("saving", "bytes_ratio", "hit_rate"):
        assert FIELD_DIRECTION[k] > 0
    assert "standalone_adds" in COUNT_FIELDS
    assert "intermediate_roundtrip_bytes" in COUNT_FIELDS


def test_bytes_growth_fails_shrink_passes():
    errs = compare(BASE, _doctor(fused_bytes=600), "fusion", TOL)
    assert any("fused_bytes" in e for e in errs)
    # shrink is an improvement, not a regression
    assert compare(BASE, _doctor(fused_bytes=200, saving=0.8),
                   "fusion", TOL) == []


def test_higher_is_better_fields_fail_on_shrink_not_growth():
    for k, worse, better in (("saving", 0.40, 0.90),
                             ("bytes_ratio", 0.20, 0.90),
                             ("hit_rate", 0.50, 1.0)):
        errs = compare(BASE, _doctor(**{k: worse}), "fusion", TOL)
        assert any(k in e for e in errs), (k, errs)
        errs = compare(BASE, _doctor(**{k: better}), "fusion", TOL)
        assert not any(k in e and "regressed" in e for e in errs), (k, errs)


def test_higher_is_better_tolerance():
    # a dip within the absolute tolerance is absorbed
    assert compare(BASE, _doctor(saving=0.57), "fusion", TOL) == []
    assert compare(BASE, _doctor(saving=0.54), "fusion", TOL) != []


def test_exact_counters_zero_tolerance_both_ways():
    for k in COUNT_FIELDS:
        if k in EXACT_MATCH_FIELDS:
            continue  # probed separately: any change fails, not just growth
        errs = compare(BASE, _doctor(**{k: 1}), "fusion", TOL)
        assert any(k in e and "no tolerance" in e for e in errs), (k, errs)
    # an exact counter at/below committed passes even when *_bytes suffixed
    base2 = _doctor(intermediate_roundtrip_bytes=500, standalone_adds=2)
    assert compare(base2, _doctor(intermediate_roundtrip_bytes=500,
                                  standalone_adds=1), "fusion", TOL) == []
    # ...and does NOT get the 5% bytes growth allowance
    errs = compare(base2, _doctor(intermediate_roundtrip_bytes=510,
                                  standalone_adds=2), "fusion", TOL)
    assert any("intermediate_roundtrip_bytes" in e for e in errs)


def test_scale_row_fields_gate():
    # ISSUE 10: a weak-scaling row — per-chip bytes must stay flat (lower
    # is fine, growth past tolerance fails) and the device count may not
    # change in EITHER direction
    assert FIELD_DIRECTION["per_chip_bytes"] < 0
    assert "devices" in COUNT_FIELDS and "devices" in EXACT_MATCH_FIELDS
    base = copy.deepcopy(BASE)
    base["records"][0].update(devices=4, per_chip_bytes=1000)

    def doctor(**fields):
        cand = copy.deepcopy(base)
        cand["records"][0].update(fields)
        return cand

    assert compare(base, doctor(), "serve", TOL) == []
    # per-chip growth past tolerance fails; shrink passes
    errs = compare(base, doctor(per_chip_bytes=1100), "serve", TOL)
    assert any("per_chip_bytes" in e for e in errs)
    assert compare(base, doctor(per_chip_bytes=900), "serve", TOL) == []
    # devices: exact match, both directions fail
    for d in (2, 8):
        errs = compare(base, doctor(devices=d), "serve", TOL)
        assert any("devices" in e and "exact match" in e
                   for e in errs), (d, errs)


def test_dropped_record_and_schema_still_gate():
    cand = copy.deepcopy(BASE)
    cand["records"] = []
    errs = compare(BASE, cand, "fusion", TOL)
    assert any("missing" in e for e in errs)
    bad = copy.deepcopy(BASE)
    bad["records"][0]["extra"] = {"nested": 1}
    assert schema_errors(bad, "BENCH_fusion.json")
