"""The paper's own workload: train LeNet with automatic layout selection.

  PYTHONPATH=src python examples/train_cnn_paper.py --net lenet --steps 60

Shows the §IV.D pipeline end to end: calibrate -> per-layer layouts ->
transforms only where layers disagree -> train (and the same network run in
the fixed cuda-convnet / cuDNN layouts for comparison).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.cnn_networks import CNN_CONFIGS
from repro.cnn.layers import init_cnn
from repro.cnn.network import (forward, init_velocity, make_train_step,
                               plan_network)
from repro.core import calibrate
from repro.data.pipeline import ImageStream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="lenet", choices=list(CNN_CONFIGS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()

    cfg = CNN_CONFIGS[args.net].replace(batch=args.batch)
    if cfg.image_hw > 96:
        cfg = cfg.replace(image_hw=96)

    th = calibrate()
    print(f"thresholds Ct={th.Ct} Nt={th.Nt}")
    for mode in ("cuda-convnet", "cudnn", "opt"):
        layouts = plan_network(cfg, mode, thresholds=th)
        convs = [l for l, s in zip(layouts, cfg.layers) if s.kind == "conv"]
        print(f"{mode:13s} conv layouts: {convs}")

    layouts = plan_network(cfg, "opt", thresholds=th)
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    stream = ImageStream(cfg.batch, cfg.in_channels, cfg.image_hw,
                         cfg.num_classes, seed=0)
    step = make_train_step(cfg, layouts, lr=0.02)
    vel = init_velocity(params)

    t0 = time.time()
    for i in range(args.steps):
        x, y = stream.batch_at(i)
        params, vel, loss = step(params, vel, jnp.asarray(x), jnp.asarray(y))
        if i % 10 == 0:
            print(f"step {i:4d} loss {float(loss):.4f}")
    print(f"final loss {float(loss):.4f} "
          f"({(time.time()-t0)/args.steps*1e3:.0f} ms/step CPU)")

    x, _ = stream.batch_at(0)
    _, stats = forward(params, jnp.asarray(x), cfg, layouts)
    print(f"layout transforms per forward: {stats.transforms}")


if __name__ == "__main__":
    main()
