"""Serving example: batched prefill + decode with layout-selected KV cache.

  PYTHONPATH=src python examples/serve_lm.py --arch gemma2_27b --requests 4

Uses the production Server (one static batch per run, greedy decode); the
KV-cache layout (bksd vs sbkd) is picked per run by the paper-derived
selector from the ACTUAL request count, unless --kv-layout forces one.
"""
import argparse
import time

import numpy as np

from repro.launch.serve import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_27b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--kv-layout", default="auto",
                    choices=["auto", "bksd", "sbkd"])
    args = ap.parse_args()

    srv = Server(args.arch, reduced=True, batch=args.requests,
                 max_len=args.max_len, kv_layout=args.kv_layout)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, srv.cfg.vocab_size,
                                    size=(6 + 2 * i,), dtype=np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    out = srv.run(reqs)
    dt = time.time() - t0
    n = sum(len(v) for v in out.values())
    print(f"arch={args.arch} (reduced) kv_layout={srv.kv_layout}")
    print(f"generated {n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s, CPU)")
    for rid in sorted(out):
        print(f"  request {rid}: {out[rid]}")


if __name__ == "__main__":
    main()
