"""End-to-end driver: train a ~100M-parameter qwen2-family model.

Full deliverable scale:
  PYTHONPATH=src python examples/train_lm_100m.py --steps 300 --full

CPU-friendly demo (same code path, ~25M params):
  PYTHONPATH=src python examples/train_lm_100m.py --steps 30

Multi-(fake-)device data+tensor parallel:
  XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \\
      python examples/train_lm_100m.py --steps 30 --mesh 2x2

Runs the production trainer: sharded params/optimizer, fault-tolerant loop,
async checkpoints, straggler watchdog, synthetic-but-learnable data.
"""
import argparse
import logging

import jax

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.train import train
from repro.models.registry import param_count


def config_100m(full: bool):
    base = get_config("qwen2_7b")
    if full:
        cfg = base.replace(name="qwen2_100m", num_layers=8, d_model=640,
                           num_heads=10, num_kv_heads=2, head_dim=64,
                           d_ff=1792, vocab_size=32064)
    else:
        cfg = base.replace(name="qwen2_25m", num_layers=4, d_model=384,
                           num_heads=6, num_kv_heads=2, head_dim=64,
                           d_ff=1024, vocab_size=16032)
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--ckpt", default="/tmp/repro_lm100m")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")

    cfg = config_100m(args.full)
    print(f"model: {cfg.name} = {param_count(cfg)/1e6:.1f}M params")

    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_host_mesh(d, m)

    import repro.launch.train as TR

    # monkey-patch the arch resolution to use our custom config
    orig = TR.build

    def build(arch, **kw):
        c, shape, mesh_, parallel, tc = orig(arch, **kw)
        return cfg, shape, mesh_, parallel, tc
    TR.build = build
    try:
        out = train("qwen2_7b", reduced=False, steps=args.steps,
                    batch=args.batch, seq=args.seq, mesh=mesh,
                    checkpoint_dir=args.ckpt, log_every=10)
    finally:
        TR.build = orig
    losses = out["losses"]
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} over {len(losses)} steps"
          f" ({'decreasing OK' if losses[-1] < losses[0] else 'NOT decreasing'})")


if __name__ == "__main__":
    main()
