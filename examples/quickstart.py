"""Quickstart: the paper's memory-efficiency system in eight snippets.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

# 1) Calibrate the layout heuristic for this hardware (paper §IV.A, Fig. 4)
from repro.core import calibrate, select_conv_layout, select_kv_layout
from repro.configs.paper_table1 import CONV_LAYERS

th = calibrate()
print(f"[1] calibrated thresholds: Ct={th.Ct} Nt={th.Nt}")
for l in CONV_LAYERS[:4]:
    print(f"    {l.name}: N={l.N} C={l.Ci} -> {select_conv_layout(l, th)}")

# 2) Assign per-layer layouts to a whole network + count transforms (§IV.D)
from repro.configs.cnn_networks import ALEXNET
from repro.cnn.network import network_descs, plan_network
from repro.core import assign_layouts

layouts = plan_network(ALEXNET.replace(batch=64), "opt", thresholds=th)
a = assign_layouts(network_descs(ALEXNET))
print(f"[2] AlexNet layouts: {layouts[:8]}... "
      f"(DP modeled step {a.total_s*1e3:.2f} ms, transforms at {a.transforms})")

# 3) Fast layout transform: collapse 4D->2D + tiled Pallas transpose (§IV.C)
from repro.core import apply_transform

x = jax.random.normal(jax.random.PRNGKey(0), (16, 28, 28, 64))  # CHWN
y = apply_transform(x, "CHWN", "NCHW", use_pallas=True)
print(f"[3] CHWN{x.shape} -> NCHW{y.shape} via collapsed 2-D tiled transpose")

# 4) Fused memory-bound kernels (§V): softmax 5-steps-in-1, pooling w/ reuse
from repro.kernels.softmax.ops import softmax
from repro.kernels.pool.ops import pool_chwn

sm = softmax(jax.random.normal(jax.random.PRNGKey(1), (128, 1000)))
pooled = pool_chwn(x, 3, 2, "max")
print(f"[4] fused softmax {sm.shape}, window-reuse pool {pooled.shape}")

# 5) Graph-level fusion (§11): plan a branching network — residual adds
#    fold into the producing conv's epilogue, skips join in any layout
from repro.configs.cnn_networks import CNN_CONFIGS, reduced_cnn
from repro.cnn.layers import init_cnn
from repro.cnn.network import forward_fused, input_shape, plan_network_fused

rn = reduced_cnn(CNN_CONFIGS["resnet18"], batch=4)
plan = plan_network_fused(rn)
params = init_cnn(jax.random.PRNGKey(3), rn)
xr = jax.random.normal(jax.random.PRNGKey(4), input_shape(rn))
yr, stats = forward_fused(params, xr, rn, plan, impl="xla")
print(f"[5] resnet18 (reduced): standalone_adds={plan.standalone_adds}, "
      f"fused/unfused bytes={plan.fused_bytes / plan.unfused_bytes:.2f}, "
      f"layouts={plan.conv_signature}")

# 6) The same principles on an assigned LM architecture
from repro.configs import get_config, reduced_config
from repro.models import init_params, forward, chunked_xent

cfg = reduced_config(get_config("qwen2_7b"))
params = init_params(jax.random.PRNGKey(0), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg.vocab_size)
pos = jnp.broadcast_to(jnp.arange(32, dtype=jnp.int32)[None], (2, 32))
h, _ = forward(params, tokens, pos, cfg)
loss = chunked_xent(params, h, tokens, cfg, chunk=8)  # fused head, no [B,S,V]
kv = select_kv_layout(batch=8, kv_heads=cfg.num_kv_heads, seq=32768,
                      head_dim=cfg.head_dim)
print(f"[6] qwen2 (reduced) loss={float(loss):.3f}; "
      f"selected KV-cache layout for serving: {kv}")

# 7) Serving-grade resilience (§14): guarded execution under seeded fault
#    injection — kernel faults degrade down the ladder, zero requests lost.
#    CLI equivalent:
#      python -m repro.launch.cnn_serve --inject "kernel=0.5,nan@mixed=1.0"
from repro.launch.cnn_serve import CNNServer, ImageRequest
from repro.perfmodel import calibrate as pm_calibrate
from repro.runtime.resilience import parse_inject_spec

srv = CNNServer("lenet", max_bucket=8, impl="xla",
                thresholds=pm_calibrate(dtype_bytes=4),
                injector=parse_inject_spec("kernel=0.5", seed=0))
rng = np.random.default_rng(0)
reqs = [ImageRequest(i, rng.standard_normal((1, 28, 28)).astype(np.float32))
        for i in range(16)]
done = srv.run(reqs)
print(f"[7] served {len(done)}/{len(reqs)} under injected kernel faults: "
      f"{srv.incidents.summary()}")

# 8) Multi-chip serving mesh (§15): the planner plans for the SHARD batch —
#    per-shard N can cross under Nt and flip the layout the global batch
#    would have picked.  CLI equivalent:
#      XLA_FLAGS=--xla_force_host_platform_device_count=8 \
#        python -m repro.launch.cnn_serve --devices 4
from repro.configs.cnn_networks import LENET
from repro.distributed.cnn_mesh import (cnn_data_mesh, forward_fused_sharded,
                                        replicate_params, shard_batch_for,
                                        shard_flip)

gsig, ssig = shard_flip(LENET, 128, 8)
print(f"[8] lenet batch 128: one chip plans {gsig}; 8 chips plan the "
      f"{shard_batch_for(128, 8)}-image shard -> {ssig}")
nd = jax.device_count()
if nd >= 2:
    shard = 2
    scfg = LENET.replace(batch=shard)
    mplan = plan_network_fused(scfg)
    mparams = init_cnn(jax.random.PRNGKey(5), scfg)
    xm = jax.random.normal(jax.random.PRNGKey(6),
                           input_shape(scfg.replace(batch=shard * nd)))
    mesh = cnn_data_mesh(nd)
    ym = forward_fused_sharded(replicate_params(mparams, mesh), xm, scfg,
                               mplan, mesh, impl="xla")
    print(f"    sharded forward over {nd} devices: y{ym.shape}, "
          f"per-shard plan {mplan.conv_signature}")
else:
    print("    (single jax device: set XLA_FLAGS=--xla_force_host_platform_"
          "device_count=8 to run the sharded forward here)")
print("done.")
