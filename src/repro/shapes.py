"""Single source of truth for conv/pool output spatial sizes.

The selector's byte accounting, the heuristic cost model, and the Pallas
kernels all need "how many output rows does this window op produce"; before
this module each re-derived the floor formula locally, which let the cost
model and the kernels disagree (ISSUE 3).  Every call site now shares these
two functions, so a mismatch is impossible by construction.

Deliberately dependency-free (stdlib only): imported by configs, core,
kernels, and cnn without any cycle risk.
"""
from __future__ import annotations


def conv_out_hw(hw: int, F: int, S: int, pad: int = 0) -> int:
    """Output rows/cols of an F x F convolution over ``hw`` x ``hw`` input
    with stride ``S`` and symmetric padding ``pad``."""
    return (hw + 2 * pad - F) // S + 1


def pool_out_hw(hw: int, F: int, S: int) -> int:
    """Output rows/cols of an F x F pooling window over ``hw`` x ``hw``
    input with stride ``S`` (pooling layers are unpadded everywhere in the
    paper's networks)."""
    return (hw - F) // S + 1
