from repro.optim import adamw  # noqa: F401
from repro.optim.compression import compress_psum  # noqa: F401
