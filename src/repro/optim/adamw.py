"""AdamW with fully-sharded state (state leaves mirror param sharding).

State dtype is configurable (``ModelConfig.opt_state_dtype``): the >=300B
assigned configs store first/second moments in bf16 so params+opt fit the
16 GB/chip v5e budget (DESIGN.md §5); moments are computed in f32 and cast on
store.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray          # i32 scalar
    m: dict
    v: dict


def init(params, state_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def abstract_state(abstract_params, state_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, state_dtype)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      m=jax.tree.map(zeros, abstract_params),
                      v=jax.tree.map(zeros, abstract_params))


def state_specs(param_spec_tree):
    from jax.sharding import PartitionSpec as P
    return AdamWState(step=P(),
                      m=param_spec_tree,
                      v=param_spec_tree)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def lr_schedule(tc: TrainConfig, step):
    """Linear warmup -> cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = tc.learning_rate * step / max(1, tc.warmup_steps)
    t = jnp.clip((step - tc.warmup_steps)
                 / max(1, tc.total_steps - tc.warmup_steps), 0.0, 1.0)
    cos = tc.learning_rate * (0.1 + 0.45 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < tc.warmup_steps, warm, cos)


def update(grads, state: AdamWState, params, tc: TrainConfig):
    """Returns (new_params, new_state, stats).  grads may be any float dtype;
    math runs in f32."""
    grads, gn = clip_by_global_norm(grads, tc.grad_clip)
    step = state.step + 1
    lr = lr_schedule(tc, step)
    b1, b2 = tc.b1, tc.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def one(p, g, m, v):
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * g
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mh = mf / bc1
        vh = vf / bc2
        upd = mh / (jnp.sqrt(vh) + 1e-8) + tc.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return newp, mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [one(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    stats = {"grad_norm": gn, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), stats
