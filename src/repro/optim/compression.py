"""Cross-pod gradient compression (distributed-optimization trick).

With pure GSPMD data parallelism the gradient all-reduce crosses the slow
inter-pod links at full f32/bf16 width.  When ``ParallelConfig.
grad_compression`` is set, the train step computes *pod-local* gradients
under a ``shard_map`` over the "pod" axis (data/model stay GSPMD-auto) and
reduces them explicitly through one of:

  * ``bf16`` — cast to bf16, psum, cast back (2x link-byte reduction);
  * ``int8`` — per-tensor max-abs scale, int8 quantize, int32-accumulate
    psum, dequantize (4x reduction; stochastic-rounding-free, documented).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_psum(grads, axis: str, mode: str):
    npods = jax.lax.psum(1.0, axis)

    if mode == "bf16":
        def red(g):
            return jax.lax.psum(g.astype(jnp.bfloat16), axis).astype(
                jnp.float32) / npods
        return jax.tree.map(red, grads)

    if mode == "int8":
        def red(g):
            g32 = g.astype(jnp.float32)
            scale = jnp.max(jnp.abs(g32)) / 127.0
            # scales differ per pod: reduce the max scale first (cheap scalar)
            scale = jax.lax.pmax(scale, axis)
            scale = jnp.maximum(scale, 1e-20)
            q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
            acc = jax.lax.psum(q.astype(jnp.int32), axis)
            return acc.astype(jnp.float32) * scale / npods
        return jax.tree.map(red, grads)

    if mode in ("none", None):
        return jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
    raise ValueError(mode)
