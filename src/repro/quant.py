"""Per-channel symmetric int8 quantization for activation storage (ISSUE 5).

The mixed-dtype planner (DESIGN.md §9) stores precision-tolerant interior
activations as int8: the producing conv's epilogue quantizes the f32 VMEM
accumulator on its way out, and the consuming conv dequantizes in VMEM.
Because the scale is **per channel** and a convolution contracts over the
input-channel dim, the dequant folds *exactly* into the weights:

    conv(q * s[ci], w)[co] = sum_ci s[ci] * q[ci] * w[ci, co]
                           = conv(q, s[ci] * w[ci, co])

so the kernel consumes raw int8 values, casts them to f32 in VMEM, and the
scale rides the (tiny) weight tensor — no extra per-element multiply and no
extra HBM traffic.  This is the ZeroQuant/AWQ-style dynamic activation
quantization specialized to the conv chain.

Training keeps the carrier in the float storage dtype and uses the
straight-through estimator (``fake_quant``): the forward value is the
dequantized quantization of x, the gradient passes through unchanged — the
plan's byte model still prices the boundary at 1 byte/element because that
is what the serving engine stores.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

QMAX = 127.0

# Documented acceptance tolerance (ISSUE 5 / DESIGN.md §9) for int8-storage
# fused forwards vs the fp32 reference, measured on SOFTMAX OUTPUTS (so it
# is dimensionless and network-independent).  Rationale: per-channel
# symmetric quantization bounds each stored activation's error by scale/2 =
# max|a|/254 (~0.4% of the channel range); one int8 boundary per interior
# chain and the f32 accumulation keep the end-to-end drift two orders below
# this bound in practice (measured: <=1.3e-3 on the 3-conv acceptance net,
# <=1.3e-5 on AlexNet-96).  2e-2 leaves an order of magnitude of headroom
# without ever excusing a broken dequant (which shows up as O(1) error).
INT8_FORWARD_ATOL = 2e-2


def _reduce_axes(ndim: int, channel_axis: int) -> Tuple[int, ...]:
    return tuple(a for a in range(ndim) if a != channel_axis % ndim)


def channel_scale(x, channel_axis: int):
    """Per-channel symmetric scale: max|x| over all non-channel dims / 127.
    Returns an f32 vector of length ``x.shape[channel_axis]`` (never zero —
    all-zero channels get scale 1 so dequant(quant(0)) == 0 exactly)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)),
                   axis=_reduce_axes(x.ndim, channel_axis))
    return jnp.where(amax > 0, amax / QMAX, 1.0)


def _broadcast(scale, ndim: int, channel_axis: int):
    shape = [1] * ndim
    shape[channel_axis % ndim] = -1
    return scale.reshape(shape)


def quantize(x, channel_axis: int):
    """x (float) -> (int8 values, f32 per-channel scale).  The serving-path
    storage cast: what the conv epilogue emits to HBM."""
    scale = channel_scale(x, channel_axis)
    q = jnp.round(x.astype(jnp.float32) / _broadcast(scale, x.ndim,
                                                     channel_axis))
    return jnp.clip(q, -QMAX, QMAX).astype(jnp.int8), scale


def dequantize(q, scale, channel_axis: int, dtype=jnp.float32):
    """int8 values + per-channel scale -> float tensor (the generic VMEM
    dequant; conv consumers fold ``scale`` into weights instead)."""
    y = q.astype(jnp.float32) * _broadcast(scale, q.ndim, channel_axis)
    return y.astype(dtype)


def fold_scale_into_weights(w_oihw, scale):
    """Fold a per-input-channel activation scale into canonical [Co,Ci,F,F]
    weights (exact — see module docstring); result keeps w's dtype."""
    s = scale.reshape(1, -1, 1, 1)
    return (w_oihw.astype(jnp.float32) * s).astype(w_oihw.dtype)


def fake_quant(x, channel_axis: int):
    """Straight-through quantize->dequantize: forward value is the int8
    round trip (same numerics the serving engine stores), gradient is the
    identity — keeps ``forward_fused``/``make_train_step_fused``
    differentiable through int8 storage boundaries."""
    q, scale = quantize(x, channel_axis)
    xq = dequantize(q, scale, channel_axis, x.dtype)
    return x + jax.lax.stop_gradient(xq - x)
