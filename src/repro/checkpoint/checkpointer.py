"""Mesh-agnostic, atomic, async checkpointing.

Design for 1000+-node operation (scaled down to this container):
  * **atomic**: write to ``<dir>/tmp.<step>`` then ``os.replace`` — a crash
    mid-write never corrupts the latest checkpoint;
  * **mesh-agnostic**: leaves are saved as full logical arrays (gathered to
    host), so a restore may use a different mesh/pod count — elastic
    restarts re-shard on load (``restore(..., shardings=...)``);
  * **async**: serialization runs on a writer thread; the train loop only
    blocks on the previous write (one outstanding checkpoint, bounded RAM);
  * **self-describing**: a JSON manifest stores the tree structure, dtypes
    and step, validated on restore.

On a real cluster the np.savez writer is replaced by a per-host sharded
writer (same interface); the atomicity/manifest/restore logic is unchanged.
"""
from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    paths = [jax.tree_util.keystr(p)
             for p, _ in jax.tree_util.tree_leaves_with_path(tree)]
    return flat, paths, treedef


class Checkpointer:
    def __init__(self, directory: str, async_write: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, block: bool = False):
        self.wait()                     # one outstanding write max
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def write():
            try:
                self._write_sync(step, host_tree)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        if self.async_write and not block:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
            self._raise_if_failed()

    def _write_sync(self, step: int, host_tree):
        flat, paths, _ = _flatten_with_names(host_tree)
        tmp = self.dir / f"tmp.{step}.{os.getpid()}"
        tmp.mkdir(parents=True, exist_ok=True)
        # npz can't round-trip ml_dtypes (bfloat16 etc.): store a uint view;
        # the manifest's dtype list restores the logical type
        def storable(x):
            a = np.asarray(x)
            if a.dtype.kind == "V" or str(a.dtype) in ("bfloat16", "float8_e4m3fn",
                                                       "float8_e5m2"):
                return a.view(np.uint8 if a.dtype.itemsize == 1 else np.uint16)
            return a
        arrays = {f"a{i}": storable(x) for i, x in enumerate(flat)}
        np.savez(tmp / "arrays.npz", **arrays)
        manifest = {
            "step": step,
            "paths": paths,
            "dtypes": [str(np.asarray(x).dtype) for x in flat],
            "shapes": [list(np.asarray(x).shape) for x in flat],
            "time": time.time(),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = self.dir / f"step_{step:010d}"
        if final.exists():
            import shutil
            shutil.rmtree(final)
        os.replace(tmp, final)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError(f"async checkpoint write failed: {e}") from e

    # -- restore ---------------------------------------------------------------
    def steps(self) -> list:
        """All on-disk checkpoint steps, ascending.  Restart logic walks
        this list newest-first so a checkpoint that fails validation can
        fall back to the next-oldest one (DESIGN.md §14)."""
        return sorted(int(p.name.split("_")[1])
                      for p in self.dir.glob("step_*"))

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, abstract_tree: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into the structure of ``abstract_tree``; re-shards onto
        ``shardings`` (any mesh) when given.  Returns (step, tree)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "arrays.npz")
        flat_abs, paths, treedef = _flatten_with_names(abstract_tree)
        if paths != manifest["paths"]:
            missing = set(manifest["paths"]) ^ set(paths)
            raise ValueError(f"checkpoint/tree structure mismatch: {sorted(missing)[:5]}")
        flat = [data[f"a{i}"] for i in range(len(flat_abs))]

        def restore_one(a, b, stored_dtype):
            target = np.dtype(b.dtype)
            if a.dtype != target and a.dtype.kind == "u" and \
                    a.dtype.itemsize == target.itemsize:
                return a.view(target)            # bf16 stored as uint16
            return np.asarray(a).astype(target)
        flat = [restore_one(a, b, d) for a, b, d in
                zip(flat, flat_abs, manifest["dtypes"])]
        tree = jax.tree_util.tree_unflatten(treedef, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(jnp.asarray(x), s), tree, shardings)
        else:
            tree = jax.tree.map(jnp.asarray, tree)
        return step, tree

    # -- retention ---------------------------------------------------------------
    def gc(self, keep: int = 3):
        import shutil
        steps = sorted(self.dir.glob("step_*"))
        for p in steps[:-keep]:
            shutil.rmtree(p, ignore_errors=True)
