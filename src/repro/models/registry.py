"""Model-level accounting: parameter counts and analytical MODEL_FLOPS."""
from __future__ import annotations

import math
from typing import Optional

import jax

from repro.configs.base import ModelConfig, ShapeConfig


def _leaf_sizes(cfg: ModelConfig):
    from repro.models.transformer import abstract_params
    tree = abstract_params(cfg)
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        yield jax.tree_util.keystr(path), math.prod(leaf.shape)


def param_count(cfg: ModelConfig, active_only: bool = False,
                include_embed: bool = True) -> int:
    """Exact parameter count from the abstract param tree.

    ``active_only``: MoE expert tensors are scaled by k/E (top-k routing).
    """
    total = 0.0
    frac = (cfg.experts_per_token / cfg.num_experts) if cfg.num_experts else 1.0
    for key, n in _leaf_sizes(cfg):
        if not include_embed and ("'embed'" in key or "'unembed'" in key):
            continue
        if active_only and "'moe'" in key and any(
                w in key for w in ("w_gate", "w_up", "w_down")) \
                and "'shared'" not in key:
            n = n * frac
        total += n
    return int(total)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytical 'useful' FLOPs for one step of the given shape.

    Dense/MoE LM convention: 6·N_active·tokens for training (fwd+bwd),
    2·N_active·tokens for inference, plus the attention score/AV term
    (12·S·q_dim per token per attention layer for causal training).
    N excludes the embedding *lookup* but includes the unembed matmul.
    """
    n_active = param_count(cfg, active_only=True, include_embed=False)
    # unembed/tied-head matmul counts as compute
    n_active += cfg.vocab_size * cfg.d_model
    tokens = shape.tokens if shape.kind != "decode" else shape.global_batch
    mult = 6.0 if shape.kind == "train" else 2.0
    flops = mult * n_active * tokens

    # attention quadratic term
    n_attn = sum(1 for k in cfg.block_pattern if k.startswith("attn"))
    n_attn_layers = n_attn * cfg.num_periods
    if cfg.family == "encdec":
        n_attn_layers += cfg.encoder_layers
    qk_dim = cfg.num_heads * cfg.head_dim
    if shape.kind == "train":
        # causal: ~S/2 context per token, fwd+bwd(2x) for QK^T and AV
        flops += 6.0 * 2 * qk_dim * (shape.seq_len / 2) * tokens * n_attn_layers / 1
    elif shape.kind == "prefill":
        flops += 2.0 * 2 * qk_dim * (shape.seq_len / 2) * tokens * n_attn_layers
    else:  # decode: each new token attends to full cache
        flops += 2.0 * 2 * qk_dim * shape.seq_len * tokens * n_attn_layers
    return flops
