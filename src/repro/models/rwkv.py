"""RWKV-6 "Finch" block: time mixing with data-dependent decay + channel mix.

Faithful to arXiv:2404.05892 in structure (ddlerp token-shift with low-rank
data-dependent mixes, per-channel data-dependent decay w_t, bonus u, per-head
WKV state [N_key, N_value], group-norm over heads, gated output; squared-ReLU
channel mix).  The recurrence uses the same chunked-scan machinery as the
Mamba block (outer scan saves only chunk-boundary states; inner steps remat).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, _dtype

LORA_R = 32     # low-rank size of the ddlerp / decay adapters
GATE_R = 64


def _heads(cfg: ModelConfig):
    N = cfg.rwkv_head_dim
    H = cfg.d_model // N
    return H, N


def init_rwkv_time(key, cfg: ModelConfig):
    ks = jax.random.split(key, 12)
    dt = _dtype(cfg)
    D = cfg.d_model
    H, N = _heads(cfg)
    return {
        # ddlerp base mixes (mu) for x and the five streams
        "mu_x": jnp.zeros((D,), jnp.float32),
        "mu_rkvwg": jnp.zeros((5, D), jnp.float32),
        "lora_a": dense_init(ks[0], (D, 5 * LORA_R), 0, jnp.float32),
        "lora_b": dense_init(ks[1], (5, LORA_R, D), 1, jnp.float32),
        # decay: w = exp(-exp(w0 + tanh(xw @ wa) @ wb))
        "w0": jnp.full((D,), -6.0, jnp.float32),
        "wa": dense_init(ks[2], (D, GATE_R), 0, jnp.float32),
        "wb": dense_init(ks[3], (GATE_R, D), 0, jnp.float32),
        "u": jnp.zeros((H, N), jnp.float32),          # bonus
        "wr": dense_init(ks[4], (D, D), 0, dt),
        "wk": dense_init(ks[5], (D, D), 0, dt),
        "wv": dense_init(ks[6], (D, D), 0, dt),
        "wg": dense_init(ks[7], (D, D), 0, dt),
        "wo": dense_init(ks[8], (D, D), 0, dt),
        "ln_scale": jnp.ones((D,), jnp.float32),      # group-norm over heads
        "ln_bias": jnp.zeros((D,), jnp.float32),
    }


def init_rwkv_channel(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    dt = _dtype(cfg)
    D, F = cfg.d_model, cfg.d_ff
    return {
        "mu_k": jnp.zeros((D,), jnp.float32),
        "mu_r": jnp.zeros((D,), jnp.float32),
        "wk": dense_init(ks[0], (D, F), 0, dt),
        "wv": dense_init(ks[1], (F, D), 0, dt),
        "wr": dense_init(ks[2], (D, D), 0, dt),
    }


def _token_shift(x, last: Optional[jnp.ndarray]):
    """sx[t] = x[t-1]; last: [B,1,D] carried context (None -> zeros)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _ddlerp(p, x, sx):
    """Data-dependent lerp producing the five mixed streams [5][B,S,D]."""
    dx = (sx - x).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    xxx = xf + dx * p["mu_x"]
    lo = jnp.tanh(xxx @ p["lora_a"])                   # [B,S,5R]
    B, S, _ = lo.shape
    lo = lo.reshape(B, S, 5, LORA_R)
    mix = jnp.einsum("bsfr,frd->fbsd", lo, p["lora_b"])  # [5,B,S,D]
    mus = p["mu_rkvwg"][:, None, None, :]
    return xf[None] + dx[None] * (mus + mix)           # [5,B,S,D]


def _state_constrain(ctx):
    """Carry constraint: heads over the model axis, batch over DP.  Without
    it GSPMD unifies the wkv while-loop state to replicated (zero init) and
    the backward saves per-step [B,H,N,N] states unsharded (dry-run showed
    17 GiB/chip for rwkv6-7b train)."""
    if ctx is None or ctx.model_axis is None:
        return None
    import jax as _jax
    ba = ctx.batch_axes if ctx.batch_axes else None
    spec = _jax.sharding.PartitionSpec(ba, ctx.model_axis, None, None)

    def cfn(h):
        try:
            return lax.with_sharding_constraint(h, spec)
        except (ValueError, RuntimeError):
            return h
    return cfn


def _wkv_chunked_parallel(r, k, v, w, u, state0, chunk: int, constrain=None):
    """Chunk-parallel WKV (beyond-paper §Perf optimization).

    The sequential scan round-trips the [B,H,N,N] state through HBM at every
    token (the dry-run's dominant memory term for rwkv6).  Rewriting the
    recurrence per chunk of c tokens turns it into dense matmuls:

      y_t = r_t (S_in ⊙ e^{L_{t-1}}) + Σ_{s<t} (r_t e^{L_{t-1}-L_s}) k_s v_s
            + (r_t ⊙ u ⊙ k_t) v_t
      S_out = S_in ⊙ e^{L_c} + Σ_s (k_s e^{L_c - L_s}) v_s

    with L the per-channel cumulative log decay inside the chunk.  State
    traffic drops from O(S) to O(S/c) round trips; the intra-chunk term is
    MXU work.  Matches the sequential scan to ~1e-3 (f32; the e^{±L} factors
    are renormalized per chunk by construction since L is chunk-local).
    """
    B, S, H, N = r.shape
    c = min(chunk, S)
    n = max(1, S // c)
    assert S % c == 0
    cfn = constrain or (lambda h: h)

    def chunk_body(Sm, xs):
        rc, kc, vc, wc = xs                     # [c,B,H,N] (f32)
        logw = jnp.log(jnp.maximum(wc, 1e-30))  # [c,B,H,N]
        L = jnp.cumsum(logw, axis=0)            # L_t = sum_{u<=t} log w_u
        # decay from chunk start to just BEFORE token t: L_{t-1}
        Lprev = L - logw                        # L_{t-1} (L_0 = 0)
        r_hat = rc * jnp.exp(Lprev)             # r'_t
        k_hat = kc * jnp.exp(-L)                # k'_s  (uses L_s)
        # intra-chunk attention-like term: A[t,s] = sum_n r'_t k'_s (s < t)
        A = jnp.einsum("tbhn,sbhn->bhts", r_hat, k_hat)
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
        A = jnp.where(mask[None, None], A, 0.0)
        y_intra = jnp.einsum("bhts,sbhm->tbhm", A, vc)
        # diagonal (bonus-u) term
        y_diag = jnp.einsum("tbhn,tbhn,tbhm->tbhm",
                            rc * u[None, None], kc, vc)
        # inter-chunk: state contribution
        y_state = jnp.einsum("tbhn,bhnm->tbhm", r_hat, Sm)
        # state update to chunk end: decay to L_c
        Lc = L[-1]                              # [B,H,N]
        k_tail = kc * jnp.exp(Lc[None] - L)     # k_s e^{L_c - L_s}
        S_new = Sm * jnp.exp(Lc)[..., None] + \
            jnp.einsum("sbhn,sbhm->bhnm", k_tail, vc)
        return cfn(S_new), y_intra + y_diag + y_state

    def to_chunks(x):                           # [B,S,H,N] -> [n,c,B,H,N]
        x = jnp.moveaxis(x, 1, 0)
        return x.reshape((n, c) + x.shape[1:])

    xs = tuple(to_chunks(t.astype(jnp.float32)) for t in (r, k, v, w))
    ST, ys = lax.scan(jax.remat(chunk_body), cfn(state0), xs)
    y = jnp.moveaxis(ys.reshape(S, B, H, N), 0, 1)
    return y, ST


def _wkv_scan(r, k, v, w, u, state0, chunk: int, constrain=None):
    """r,k,v: [B,S,H,N]; w: [B,S,H,N] decay in (0,1); u: [H,N].
    state: [B,H,N,N].  Returns (y [B,S,H,N], stateT)."""
    B, S, H, N = r.shape
    n = max(1, S // chunk)
    assert S % n == 0
    c = S // n
    cfn = constrain or (lambda h: h)

    def step(Sm, inp):
        r_t, k_t, v_t, w_t = inp                       # [B,H,N]
        a = k_t[..., :, None] * v_t[..., None, :]      # [B,H,N,N]
        y = jnp.einsum("bhn,bhnm->bhm", r_t, Sm + u[..., :, None] * a)
        Sm = cfn(w_t[..., :, None] * Sm + a)
        return Sm, y

    def chunk_body(Sm, xs):
        return lax.scan(step, Sm, xs)

    def to_chunks(x):                                  # [B,S,H,N] -> [n,c,B,H,N]
        x = jnp.moveaxis(x, 1, 0)
        return x.reshape((n, c) + x.shape[1:])

    xs = tuple(to_chunks(t.astype(jnp.float32)) for t in (r, k, v, w))
    ST, ys = lax.scan(jax.remat(chunk_body), cfn(state0), xs)
    y = jnp.moveaxis(ys.reshape(S, B, H, N), 0, 1)
    return y, ST


def _group_norm(p, y, H, N, eps=1e-5):
    """Per-head layer norm (RWKV 'ln_x').  y: [B,S,H,N]."""
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    yn = (y - mu) * lax.rsqrt(var + eps)
    B, S = y.shape[:2]
    yn = yn.reshape(B, S, H * N) * p["ln_scale"] + p["ln_bias"]
    return yn


def rwkv_time_fwd(p, x, cfg: ModelConfig, *, chunk: int = 128,
                  state: Optional[dict] = None, return_state: bool = False,
                  ctx=None):
    """x: [B,S,D] -> [B,S,D].  state: {"shift": [B,1,D], "wkv": [B,H,N,N]}."""
    B, S, D = x.shape
    H, N = _heads(cfg)
    sx = _token_shift(x, None if state is None else state["shift"])
    xr, xk, xv, xw, xg = _ddlerp(p, x, sx)

    r = (xr.astype(x.dtype) @ p["wr"]).reshape(B, S, H, N)
    k = (xk.astype(x.dtype) @ p["wk"]).reshape(B, S, H, N)
    v = (xv.astype(x.dtype) @ p["wv"]).reshape(B, S, H, N)
    g = jax.nn.silu(xg.astype(x.dtype) @ p["wg"])
    w = jnp.exp(-jnp.exp(p["w0"] + jnp.tanh(xw @ p["wa"]) @ p["wb"]))
    w = w.reshape(B, S, H, N)

    s0 = (jnp.zeros((B, H, N, N), jnp.float32) if state is None
          else state["wkv"].astype(jnp.float32))
    scan_fn = _wkv_chunked_parallel if cfg.rwkv_chunked else _wkv_scan
    y, sT = scan_fn(r, k, v, w, p["u"], s0, chunk,
                    constrain=_state_constrain(ctx))
    y = _group_norm(p, y, H, N).astype(x.dtype)
    out = (y * g) @ p["wo"]
    if return_state:
        return out, {"shift": x[:, -1:], "wkv": sT}
    return out


def rwkv_channel_fwd(p, x, cfg: ModelConfig, *,
                     state: Optional[dict] = None, return_state: bool = False):
    sx = _token_shift(x, None if state is None else state["shift"])
    dx = (sx - x).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    xk = (xf + dx * p["mu_k"]).astype(x.dtype)
    xr = (xf + dx * p["mu_r"]).astype(x.dtype)
    h = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(xr @ p["wr"]) * (h @ p["wv"])
    if return_state:
        return out, {"shift": x[:, -1:]}
    return out


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    H, N = _heads(cfg)
    return {
        "tm_shift": jnp.zeros((batch, 1, cfg.d_model), dtype),
        "cm_shift": jnp.zeros((batch, 1, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, H, N, N), jnp.float32),
    }
