"""Core LM layers: norms, RoPE, (GQA/local/softcap) attention, MLP, MoE.

Pure functional style: ``init_*`` builds a param pytree, ``*_fwd`` applies it.
All matmuls run in the config compute dtype (bf16 by default); softmax,
normalization and reductions accumulate in float32.

Attention supports three execution paths:
  * full        — one einsum, for short sequences;
  * chunked     — lax.scan over query chunks (bounded score memory; the
                  paper-§V.B "fused softmax" discipline applied to attention);
  * decode      — single-token query against a laid-out KV cache.

The KV cache supports two layouts (paper §IV data-layout selection applied to
serving): ``bksd`` = [B, K, S, Dh] (read-friendly) and ``sbkd`` = [S, B, K, Dh]
(update-friendly: a decode step writes a [1, B, K, Dh] row — full native tiles
— instead of B*K strided size-1-sublane slices).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, in_axis=0, dtype=jnp.bfloat16):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dim: Optional[int] = None):
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_fwd(p, x, cfg: ModelConfig, eps: Optional[float] = None):
    eps = eps or cfg.norm_eps
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x, positions, theta: float):
    """x: [..., S, n_heads, head_dim]; positions: [..., S] int32."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                       # [half]
    ang = positions[..., None].astype(jnp.float32) * freqs       # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                             # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(x.dtype)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    D, Q, KV = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": dense_init(ks[0], (D, Q), 0, dt),
        "wk": dense_init(ks[1], (D, KV), 0, dt),
        "wv": dense_init(ks[2], (D, KV), 0, dt),
        "wo": dense_init(ks[3], (Q, D), 0, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Q,), dt)
        p["bk"] = jnp.zeros((KV,), dt)
        p["bv"] = jnp.zeros((KV,), dt)
    return p


def _qkv(p, x, cfg: ModelConfig):
    B, S, _ = x.shape
    H, K, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, S, H, Dh), k.reshape(B, S, K, Dh),
            v.reshape(B, S, K, Dh))


def _scores_mask(q_pos, k_pos, local_window):
    """[Sq, Sk] bool mask: causal, optionally sliding-window."""
    m = k_pos[None, :] <= q_pos[:, None]
    if local_window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < local_window
    return m


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """q: [B,Sq,H,Dh], k/v: [B,Sk,K,Dh], mask: [Sq,Sk] or [B,1,1,Sq,Sk]."""
    B, Sq, H, Dh = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, Dh)
    scale = 1.0 / math.sqrt(Dh)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, cfg.attn_logit_softcap)
    if mask.ndim == 2:
        mask = mask[None, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return o.reshape(B, Sq, H, Dh)


def attention_fwd(p, x, positions, cfg: ModelConfig, *, local: bool = False,
                  q_chunk: int = 1024, cross_kv=None):
    """Training/prefill attention.  Returns [B,S,D].

    Chunked over queries when S > q_chunk: each chunk computes a bounded
    [B,H,Cq,S] score block (fused-softmax discipline; no [S,S] residency).
    ``cross_kv``: optional (k, v) ([B,T,K,Dh]) for encoder-decoder cross
    attention (no causal mask).
    """
    B, S, D = x.shape
    window = cfg.local_window if local else None
    if cross_kv is not None:
        q = (x @ p["wq"]).reshape(B, S, cfg.num_heads, cfg.head_dim)
        if cfg.qkv_bias:
            q = q + p["bq"].reshape(cfg.num_heads, cfg.head_dim)
        k, v = cross_kv
        Sk = k.shape[1]
        mask = jnp.ones((S, Sk), bool)
        o = _sdpa(q, k, v, mask, cfg)
        return o.reshape(B, S, cfg.q_dim) @ p["wo"]

    q, k, v = _qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if S <= q_chunk:
        mask = _scores_mask(positions[0], positions[0], window)
        o = _sdpa(q, k, v, mask, cfg)
        return o.reshape(B, S, cfg.q_dim) @ p["wo"]

    # chunked: scan over query blocks, K/V stay resident.
    n_chunks = S // q_chunk
    assert S % q_chunk == 0, (S, q_chunk)
    k_pos = positions[0]

    def chunk_body(_, qc_i):
        qc, qpos = qc_i
        mask = _scores_mask(qpos, k_pos, window)
        return None, _sdpa(qc, k, v, mask, cfg)

    q_chunks = q.reshape(B, n_chunks, q_chunk, cfg.num_heads, cfg.head_dim)
    q_chunks = jnp.moveaxis(q_chunks, 1, 0)                 # [n,B,Cq,H,Dh]
    pos_chunks = positions[0].reshape(n_chunks, q_chunk)
    _, o = lax.scan(jax.remat(chunk_body), None, (q_chunks, pos_chunks))
    o = jnp.moveaxis(o, 0, 1).reshape(B, S, cfg.q_dim)
    return o @ p["wo"]


# -- KV cache ----------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  layout: str = "bksd", dtype=jnp.bfloat16):
    K, Dh = cfg.num_kv_heads, cfg.head_dim
    shape = ((batch, K, max_len, Dh) if layout == "bksd"
             else (max_len, batch, K, Dh))
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _cache_write_masked(cache, k_new, v_new, pos, layout: str):
    """Single-token cache write via a one-hot select along S.

    Used when the cache's sequence dim is sharded over the mesh: a
    dynamic-update-slice on a sharded dim forces GSPMD into involuntary full
    rematerialization (observed in the dry-run), whereas a select/where is a
    purely local elementwise op.  Costs one extra cache-sized write — picked
    per sharding by the steps factory (the paper's layout-vs-access-pattern
    arbitration applied to serving)."""
    assert k_new.shape[1] == 1, "masked write is decode-only"
    if layout == "bksd":
        S = cache["k"].shape[2]
        hit = (jnp.arange(S, dtype=jnp.int32) == pos % S)[None, None, :, None]
        kn = jnp.moveaxis(k_new, 1, 2).astype(cache["k"].dtype)
        vn = jnp.moveaxis(v_new, 1, 2).astype(cache["v"].dtype)
    else:  # sbkd
        S = cache["k"].shape[0]
        hit = (jnp.arange(S, dtype=jnp.int32) == pos % S)[:, None, None, None]
        kn = jnp.moveaxis(k_new, 0, 1).astype(cache["k"].dtype)
        vn = jnp.moveaxis(v_new, 0, 1).astype(cache["v"].dtype)
    return {"k": jnp.where(hit, kn, cache["k"]),
            "v": jnp.where(hit, vn, cache["v"])}


def _cache_write(cache, k_new, v_new, pos, layout: str):
    """k_new/v_new: [B, S_new, K, Dh]; pos: int32 scalar start index
    (taken modulo the cache capacity -> ring-buffer semantics for window
    caches; a full-length cache is unaffected since pos < capacity)."""
    cap = cache["k"].shape[2] if layout == "bksd" else cache["k"].shape[0]
    pos = pos % cap
    if layout == "bksd":
        kn = jnp.moveaxis(k_new, 1, 2)     # [B,K,S_new,Dh]
        vn = jnp.moveaxis(v_new, 1, 2)
        k = lax.dynamic_update_slice(cache["k"], kn.astype(cache["k"].dtype),
                                     (0, 0, pos, 0))
        v = lax.dynamic_update_slice(cache["v"], vn.astype(cache["v"].dtype),
                                     (0, 0, pos, 0))
    else:  # sbkd
        kn = jnp.moveaxis(k_new, 0, 1)     # [S_new,B,K,Dh]
        vn = jnp.moveaxis(v_new, 0, 1)
        k = lax.dynamic_update_slice(cache["k"], kn.astype(cache["k"].dtype),
                                     (pos, 0, 0, 0))
        v = lax.dynamic_update_slice(cache["v"], vn.astype(cache["v"].dtype),
                                     (pos, 0, 0, 0))
    return {"k": k, "v": v}


def attention_decode(p, x, cache, cache_len, cfg: ModelConfig, *,
                     layout: str = "bksd", local: bool = False,
                     cross: bool = False, update: str = "dus",
                     windowed: bool = False):
    """One-token decode.  x: [B,1,D]; cache_len: int32 scalar (tokens already
    in cache).  ``update``: "dus" (dynamic-update-slice; cheap when the S dim
    is unsharded) or "masked" (sharded-S-safe select).
    Returns (y [B,1,D], new_cache)."""
    B = x.shape[0]
    H, K, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // K
    if cross:
        q = (x @ p["wq"]).reshape(B, 1, H, Dh)
        new_cache = cache
    else:
        q, k_new, v_new = _qkv(p, x, cfg)
        pos = jnp.full((B, 1), cache_len, jnp.int32)
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)
        writer = _cache_write_masked if update == "masked" else _cache_write
        new_cache = writer(cache, k_new, v_new, cache_len, layout)

    kc, vc = new_cache["k"], new_cache["v"]
    S = kc.shape[2] if layout == "bksd" else kc.shape[0]
    qg = q.reshape(B, K, G, Dh)
    scale = 1.0 / math.sqrt(Dh)
    if layout == "bksd":
        s = jnp.einsum("bkgd,bksd->bkgs", qg, kc,
                       preferred_element_type=jnp.float32) * scale
    else:
        s = jnp.einsum("bkgd,sbkd->bkgs", qg, kc,
                       preferred_element_type=jnp.float32) * scale
    s = softcap(s, cfg.attn_logit_softcap)
    k_pos = jnp.arange(S)
    if cross:
        valid = k_pos >= 0
    elif windowed:
        # ring-buffer window cache: every filled slot is in-window
        valid = k_pos < jnp.minimum(cache_len + 1, S)
    else:
        valid = k_pos <= cache_len
        if local and cfg.local_window is not None:
            valid &= (cache_len - k_pos) < cfg.local_window
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    if layout == "bksd":
        o = jnp.einsum("bkgs,bksd->bkgd", pr, vc)
    else:
        o = jnp.einsum("bkgs,sbkd->bkgd", pr, vc)
    y = o.reshape(B, 1, cfg.q_dim) @ p["wo"]
    return y, new_cache


def attention_prefill(p, x, positions, cfg: ModelConfig, max_len: int, *,
                      layout: str = "bksd", local: bool = False,
                      q_chunk: int = 1024):
    """Prefill: full forward + populate a KV cache of capacity ``max_len``."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    cache = init_kv_cache(cfg, B, max_len, layout, x.dtype)
    if S > max_len:
        # window cache keeps the last `max_len` tokens, ring-rolled so that
        # token t lives in slot t %% max_len
        shift = (S - max_len) % max_len
        kw = jnp.roll(k[:, S - max_len:], shift, axis=1)
        vw = jnp.roll(v[:, S - max_len:], shift, axis=1)
        cache = _cache_write(cache, kw, vw, jnp.int32(0), layout)
    else:
        cache = _cache_write(cache, k, v, jnp.int32(0), layout)
    window = cfg.local_window if local else None
    if S <= q_chunk:
        mask = _scores_mask(positions[0], positions[0], window)
        o = _sdpa(q, k, v, mask, cfg)
    else:
        n = S // q_chunk
        qc = jnp.moveaxis(q.reshape(B, n, q_chunk, cfg.num_heads, cfg.head_dim), 1, 0)
        pc = positions[0].reshape(n, q_chunk)

        def body(_, qi):
            qq, pp = qi
            m = _scores_mask(pp, positions[0], window)
            return None, _sdpa(qq, k, v, m, cfg)

        _, o = lax.scan(jax.remat(body), None, (qc, pc))
        o = jnp.moveaxis(o, 0, 1).reshape(B, S, cfg.num_heads, cfg.head_dim)
    y = o.reshape(B, S, cfg.q_dim) @ p["wo"]
    return y, cache


# ---------------------------------------------------------------------------
# dense MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    ks = jax.random.split(key, 3)
    dt = _dtype(cfg)
    F = d_ff or cfg.d_ff
    return {
        "w_gate": dense_init(ks[0], (cfg.d_model, F), 0, dt),
        "w_up": dense_init(ks[1], (cfg.d_model, F), 0, dt),
        "w_down": dense_init(ks[2], (F, cfg.d_model), 0, dt),
    }


def _act(cfg: ModelConfig):
    return jax.nn.gelu if cfg.act == "gelu" else jax.nn.silu


def mlp_fwd(p, x, cfg: ModelConfig):
    g = _act(cfg)(x @ p["w_gate"])
    return (g * (x @ p["w_up"])) @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE (top-k, capacity-bounded, scatter/gather dispatch)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    dt = _dtype(cfg)
    E, D, F = cfg.num_experts, cfg.d_model, cfg.expert_d_ff
    p = {
        "router": dense_init(ks[0], (D, E), 0, jnp.float32),
        "w_gate": dense_init(ks[1], (E, D, F), 1, dt),
        "w_up": dense_init(ks[2], (E, D, F), 1, dt),
        "w_down": dense_init(ks[3], (E, F, D), 1, dt),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, cfg.expert_d_ff * cfg.num_shared_experts)
    return p


def moe_fwd(p, x, cfg: ModelConfig):
    """Capacity-bounded top-k MoE with scatter dispatch / gather combine.

    Dispatch avoids the O(T*E*C*D) one-hot einsum: tokens are scattered into a
    per-expert buffer [E*C, D] (memory-bound, zero matmul FLOPs) and results
    gathered back — the MoE analogue of the paper's redundant-access removal.
    """
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    cap = int(cfg.capacity_factor * T * k / E)
    cap = max(8, min(cap, T))
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"])          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, sel = lax.top_k(probs, k)                       # [T, k]
    weights = weights / jnp.clip(weights.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) inside its expert's buffer
    onehot = jax.nn.one_hot(sel, E, dtype=jnp.int32)         # [T, k, E]
    flat = onehot.reshape(T * k, E)
    pos_in_e = jnp.cumsum(flat, axis=0) - flat               # [T*k, E]
    pos = (pos_in_e * flat).sum(-1).reshape(T, k)            # [T, k]
    keep = pos < cap
    slot = jnp.where(keep, sel * cap + pos, E * cap)         # overflow -> dropped

    buf = jnp.zeros((E * cap + 1, D), x.dtype)
    idx = slot.reshape(T * k, 1)
    buf = buf.at[idx[:, 0]].set(jnp.repeat(xt, k, axis=0), mode="drop",
                                unique_indices=False)
    expert_in = buf[:E * cap].reshape(E, cap, D)

    h = _act(cfg)(jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"])
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])  # [E, cap, D]

    flat_out = jnp.concatenate(
        [expert_out.reshape(E * cap, D), jnp.zeros((1, D), x.dtype)], 0)
    gathered = flat_out[slot.reshape(-1)].reshape(T, k, D)
    y = (gathered * (weights * keep).astype(x.dtype)[..., None]).sum(1)

    if cfg.num_shared_experts:
        y = y + mlp_fwd(p["shared"], xt, cfg)

    # auxiliary load-balance loss (Switch-style), returned via aux
    density = jnp.mean(jax.nn.one_hot(sel[:, 0], E, dtype=jnp.float32), 0)
    router_prob = jnp.mean(probs, 0)
    aux = E * jnp.sum(density * router_prob)
    return y.reshape(B, S, D), aux


# -- expert-parallel MoE (manual all-to-all under shard_map) -----------------
#
# The scatter/gather dispatch above does not partition under GSPMD (the
# scatter breaks sharding propagation and every expert tensor replicates —
# observed as 100s of GiB/chip of temps in the dry-run).  The production path
# is the classic Switch pipeline, written manually over the mesh:
#
#   tokens sharded over (pod, data, model·seq)  --local scatter-->
#   per-expert buffers [E, C_loc, D]            --all_to_all(model)-->
#   expert shards compute their experts         --all_to_all(model)-->
#   local gather/combine.
#
# Expert weights are EP-sharded over "model" and (optionally) FSDP-sharded
# over data/pod on d_model; the FSDP all-gather is explicit here.

def _moe_local_dispatch(xt, p, cfg: ModelConfig, cap: int):
    """Local top-k routing + scatter into per-expert buffers.
    xt: [T,D] (shard-local).  Returns (buf [E,cap,D], slot, weights, keep, aux)."""
    T, D = xt.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, sel = lax.top_k(probs, k)
    weights = weights / jnp.clip(weights.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(sel, E, dtype=jnp.int32)
    flat = onehot.reshape(T * k, E)
    pos_in_e = jnp.cumsum(flat, axis=0) - flat
    pos = (pos_in_e * flat).sum(-1).reshape(T, k)
    keep = pos < cap
    slot = jnp.where(keep, sel * cap + pos, E * cap)
    buf = jnp.zeros((E * cap + 1, D), xt.dtype)
    buf = buf.at[slot.reshape(-1)].set(jnp.repeat(xt, k, axis=0), mode="drop")
    density = jnp.mean(jax.nn.one_hot(sel[:, 0], E, dtype=jnp.float32), 0)
    aux = E * jnp.sum(density * jnp.mean(probs, 0))
    return buf[:E * cap].reshape(E, cap, D), slot, weights, keep, aux


def moe_fwd_a2a(p, x, cfg: ModelConfig, ctx):
    """Expert-parallel MoE for train/prefill (S sharded over the model axis).

    Must run under ``shard_map`` with manual mesh axes — ``ctx`` (a
    transformer.ShardCtx) provides axis names.  Capacity is per
    (expert, source shard).
    """
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    tp = ctx.model_axis
    M = ctx.model_size
    fsdp_axes = ctx.fsdp_axes

    def body(xb, router, wg, wu, wd, *rest):
        shared = rest if rest else None
        if fsdp_axes:
            router = lax.all_gather(router, fsdp_axes, axis=0, tiled=True)
            wg = lax.all_gather(wg, fsdp_axes, axis=1, tiled=True)
            wu = lax.all_gather(wu, fsdp_axes, axis=1, tiled=True)
            wd = lax.all_gather(wd, fsdp_axes, axis=2, tiled=True)
        Bl, Sl, _ = xb.shape
        T = Bl * Sl
        xt = xb.reshape(T, D)
        cap = max(4, int(cfg.capacity_factor * T * k / E))
        pp = {"router": router}
        buf, slot, weights, keep, aux = _moe_local_dispatch(xt, pp, cfg, cap)
        # exchange: every model shard keeps its E/M experts from all shards
        buf = lax.all_to_all(buf, tp, split_axis=0, concat_axis=1, tiled=True)
        h = _act(cfg)(jnp.einsum("ecd,edf->ecf", buf, wg))
        h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
        out = jnp.einsum("ecf,efd->ecd", h, wd)        # [E/M, cap*M, D]
        out = lax.all_to_all(out, tp, split_axis=1, concat_axis=0, tiled=True)
        flat_out = jnp.concatenate(
            [out.reshape(E * cap, D), jnp.zeros((1, D), x.dtype)], 0)
        y = flat_out[slot.reshape(-1)].reshape(T, k, D)
        y = (y * (weights * keep).astype(x.dtype)[..., None]).sum(1)
        if shared is not None:
            sg, su, sd = shared
            if fsdp_axes:
                sg = lax.all_gather(sg, fsdp_axes, axis=0, tiled=True)
                su = lax.all_gather(su, fsdp_axes, axis=0, tiled=True)
                sd = lax.all_gather(sd, fsdp_axes, axis=1, tiled=True)
            y = y + (_act(cfg)(xt @ sg) * (xt @ su)) @ sd
        manual = tuple(ctx.batch_axes) + (tp,)
        aux = lax.pmean(aux, manual)
        return y.reshape(Bl, Sl, D), aux

    from jax.sharding import PartitionSpec as P
    F = ctx.fsdp_axes if ctx.fsdp_axes else None
    ba = ctx.batch_axes if ctx.batch_axes else None
    x_spec = P(ba, tp, None)
    router_spec = P(F, None)
    w_in_spec = P(tp, F, None)      # [E, D, F]
    w_out_spec = P(tp, None, F)     # [E, F, D]
    args = [x, p["router"], p["w_gate"], p["w_up"], p["w_down"]]
    in_specs = [x_spec, router_spec, w_in_spec, w_in_spec, w_out_spec]
    if cfg.num_shared_experts:
        args += [p["shared"]["w_gate"], p["shared"]["w_up"],
                 p["shared"]["w_down"]]
        in_specs += [P(F, None), P(F, None), P(None, F)]

    manual_axes = set(a for a in (ctx.batch_axes or ())) | {tp}
    from repro.compat import shard_map as _shard_map
    y, aux = _shard_map(
        body, mesh=ctx.mesh,
        in_specs=tuple(in_specs),
        out_specs=(x_spec, P()),
        axis_names=manual_axes,
        check_vma=False,
    )(*args)
    return y, aux
