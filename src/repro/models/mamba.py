"""Mamba (S6) mixer for the Jamba hybrid architecture.

Selective state-space model with input-dependent (dt, B, C).  The sequential
recurrence is evaluated as a *chunked* scan: an outer ``lax.scan`` over
sequence chunks (whose boundary states are the only saved activations) with a
rematerialized inner step scan.  The [B, d_inner, d_state] carry is sharded
over the model axis on d_inner, so checkpointed state memory is
O(S/chunk * B * d_inner/TP * d_state) — see DESIGN.md §5.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, _dtype


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def init_mamba(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    dt = _dtype(cfg)
    D, dI, dS, dC = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    R = dt_rank(cfg)
    # S4D-real initialization for A
    A = jnp.tile(jnp.arange(1, dS + 1, dtype=jnp.float32)[None, :], (dI, 1))
    return {
        "in_proj": dense_init(ks[0], (D, 2 * dI), 0, dt),
        "conv_w": dense_init(ks[1], (dC, dI), 0, jnp.float32),
        "conv_b": jnp.zeros((dI,), jnp.float32),
        "x_proj": dense_init(ks[2], (dI, R + 2 * dS), 0, dt),
        "dt_proj_w": dense_init(ks[3], (R, dI), 0, jnp.float32),
        "dt_proj_b": jnp.full((dI,), math.log(math.e - 1) * 0.01, jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((dI,), jnp.float32),
        "out_proj": dense_init(ks[5], (dI, D), 0, dt),
    }


def _ssm_inputs(p, u, cfg: ModelConfig):
    """u: [B,S,dI] post-conv activations -> (dt [B,S,dI], Bm [B,S,dS], Cm)."""
    dS = cfg.mamba_d_state
    R = dt_rank(cfg)
    proj = u @ p["x_proj"]                                    # [B,S,R+2dS]
    dt_r, Bm, Cm = jnp.split(proj, [R, R + dS], axis=-1)
    dt = jax.nn.softplus(dt_r.astype(jnp.float32) @ p["dt_proj_w"]
                         + p["dt_proj_b"])                    # [B,S,dI]
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def _scan_chunked(dt, Bm, Cm, u, A, h0, chunk: int, constrain=None):
    """Sequential SSM scan.  dt,u: [B,S,dI]; Bm,Cm: [B,S,dS]; A: [dI,dS];
    h0: [B,dI,dS].  Returns (y [B,S,dI], hT).

    ``constrain`` (optional): sharding constraint applied to the carry every
    step.  Without it GSPMD unifies the while-loop state to REPLICATED (the
    zero-init carry has no sharding), and the backward pass then saves
    per-step [B,dI,dS] states unsharded — observed as tens of GiB/chip in
    the dry-run.  The constraint keeps d_inner sharded over the model axis.
    """
    B, S, dI = u.shape
    dS = A.shape[1]
    n = max(1, S // chunk)
    assert S % n == 0
    c = S // n
    cfn = constrain or (lambda h: h)

    def step(h, inp):
        dt_t, B_t, C_t, u_t = inp                     # [B,dI],[B,dS],[B,dS],[B,dI]
        dA = jnp.exp(dt_t[..., None] * (-jnp.exp(A))[None])      # [B,dI,dS]
        dBu = (dt_t * u_t)[..., None] * B_t[:, None, :]          # [B,dI,dS]
        h = cfn(dA * h + dBu)
        y = jnp.einsum("bds,bs->bd", h, C_t)
        return h, y

    def chunk_body(h, xs):
        dt_c, B_c, C_c, u_c = xs                      # [c,B,...]
        h, y = lax.scan(step, h, (dt_c, B_c, C_c, u_c))
        return h, y

    def to_chunks(x):                                  # [B,S,...] -> [n,c,B,...]
        x = jnp.moveaxis(x, 1, 0)                      # [S,B,...]
        return x.reshape((n, c) + x.shape[1:])

    xs = tuple(to_chunks(x) for x in
               (dt.astype(jnp.float32), Bm, Cm, u.astype(jnp.float32)))
    hT, ys = lax.scan(jax.remat(chunk_body), cfn(h0), xs)   # ys: [n,c,B,dI]
    y = jnp.moveaxis(ys.reshape(S, B, dI), 0, 1)
    return y, hT


def _causal_conv(u, w, b, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv along S.  u: [B,S,dI]; w: [dC,dI].
    state: [B,dC-1,dI] trailing context (for decode/prefill continuation)."""
    dC = w.shape[0]
    uf = u.astype(jnp.float32)
    if state is None:
        pad = jnp.zeros((u.shape[0], dC - 1, u.shape[2]), jnp.float32)
    else:
        pad = state.astype(jnp.float32)
    x = jnp.concatenate([pad, uf], axis=1)             # [B, S+dC-1, dI]
    y = sum(x[:, i:i + u.shape[1], :] * w[i] for i in range(dC))
    new_state = x[:, -(dC - 1):, :] if dC > 1 else jnp.zeros_like(pad)
    return (y + b), new_state


def _state_constrain(ctx):
    """Carry constraint: d_inner over the model axis, batch over DP."""
    if ctx is None or ctx.model_axis is None:
        return None
    import jax as _jax
    ba = ctx.batch_axes if ctx.batch_axes else None
    spec = _jax.sharding.PartitionSpec(ba, ctx.model_axis, None)

    def cfn(h):
        try:
            return lax.with_sharding_constraint(h, spec)
        except (ValueError, RuntimeError):
            return h
    return cfn


def _seq_constrain(ctx):
    """Pin mixer activations to the dI-TP scheme: [B, S(full), dI(model)].

    Without this GSPMD mixes the residual's sequence sharding with the
    state's d_inner sharding and resolves the conflict by fully gathering
    BOTH the weights and the [B,S,D] residual per block (dry-run: 2.1 GiB
    f32 buffers x O(100) for jamba).  The constraint makes the SP->TP
    transition one all-to-all at the mixer boundary instead."""
    if ctx is None or ctx.model_axis is None:
        return lambda t: t
    import jax as _jax
    ba = ctx.batch_axes if ctx.batch_axes else None
    spec = _jax.sharding.PartitionSpec(ba, None, ctx.model_axis)

    def cfn(t):
        try:
            return lax.with_sharding_constraint(t, spec)
        except (ValueError, RuntimeError):
            return t
    return cfn


def mamba_fwd(p, x, cfg: ModelConfig, *, chunk: int = 256,
              state: Optional[dict] = None, return_state: bool = False,
              ctx=None):
    """Full-sequence mamba mixer.  x: [B,S,D] -> [B,S,D].

    ``state`` (optional): {"conv": [B,dC-1,dI], "ssm": [B,dI,dS]} carried
    across segments; returned updated when ``return_state``.
    """
    B, S, D = x.shape
    dI, dS = cfg.mamba_d_inner, cfg.mamba_d_state
    seqc = _seq_constrain(ctx)
    xz = seqc(x @ p["in_proj"])
    u, z = jnp.split(xz, 2, axis=-1)                   # [B,S,dI] each

    conv_state = None if state is None else state["conv"]
    u_c, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state)
    u_c = seqc(jax.nn.silu(u_c).astype(x.dtype))

    dt, Bm, Cm = _ssm_inputs(p, u_c, cfg)
    dt = seqc(dt)
    h0 = (jnp.zeros((B, dI, dS), jnp.float32) if state is None
          else state["ssm"].astype(jnp.float32))
    y, hT = _scan_chunked(dt, Bm, Cm, u_c, p["A_log"], h0, chunk,
                          constrain=_state_constrain(ctx))
    y = y + u_c.astype(jnp.float32) * p["D"]
    y = seqc((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype))
    out = y @ p["out_proj"]
    if return_state:
        return out, {"conv": new_conv.astype(x.dtype), "ssm": hT}
    return out


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    dI, dS, dC = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return {"conv": jnp.zeros((batch, dC - 1, dI), dtype),
            "ssm": jnp.zeros((batch, dI, dS), jnp.float32)}


def mamba_decode(p, x, state, cfg: ModelConfig):
    """Single-token decode.  x: [B,1,D]."""
    B = x.shape[0]
    dC = cfg.mamba_d_conv
    xz = x @ p["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)                   # [B,1,dI]
    u_c, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"], state["conv"])
    u_c = jax.nn.silu(u_c).astype(x.dtype)
    dt, Bm, Cm = _ssm_inputs(p, u_c, cfg)
    A = p["A_log"]
    dt0, B0, C0, u0 = dt[:, 0], Bm[:, 0], Cm[:, 0], u_c[:, 0].astype(jnp.float32)
    dA = jnp.exp(dt0[..., None] * (-jnp.exp(A))[None])
    dBu = (dt0 * u0)[..., None] * B0[:, None, :]
    h = dA * state["ssm"] + dBu
    y = jnp.einsum("bds,bs->bd", h, C0) + u0 * p["D"]
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"conv": new_conv.astype(x.dtype), "ssm": h}
