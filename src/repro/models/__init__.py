from repro.models.transformer import (  # noqa: F401
    ShardCtx, NO_SHARD, init_params, abstract_params, init_cache,
    abstract_cache, forward, prefill, decode_step, chunked_xent, logits_fwd)
