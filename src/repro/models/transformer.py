"""The LM stack: embedding -> scanned super-blocks -> norm -> (fused) head.

One code path serves all ten assigned architectures.  The layer stack is
``cfg.num_periods`` repetitions of ``cfg.block_pattern`` executed under a
single ``lax.scan`` whose xs are the period-stacked block params; with
``remat="block"`` only the per-period residual stream is saved (and, under
sequence-parallel sharding, saved *sharded* over the model axis).

Decode carries a per-period cache pytree scanned alongside the params.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import (ATTN, ATTN_LOCAL, ATTN_MOE, MAMBA, MAMBA_MOE,
                                RWKV, ModelConfig)
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import rwkv as R

CLIP_DIM = 1024   # stubbed vision-tower output width


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Static sharding hints threaded through the forward pass."""
    batch_axes: Tuple[str, ...] = ()     # residual batch dim axes ("pod","data")
    model_axis: Optional[str] = None     # TP axis name
    seq_shard_saved: bool = True         # SP on the scanned residual carry
    fsdp_axes: Tuple[str, ...] = ()      # param-sharding axes (ZeRO-3)
    model_size: int = 1                  # size of the TP axis
    moe_a2a: bool = False                # expert-parallel all-to-all MoE
    mesh: Optional[object] = None        # mesh for manual shard_map regions

    def residual_spec(self):
        ba = self.batch_axes if self.batch_axes else None
        if self.seq_shard_saved and self.model_axis:
            return jax.sharding.PartitionSpec(ba, self.model_axis, None)
        return jax.sharding.PartitionSpec(ba, None, None)


NO_SHARD = ShardCtx(batch_axes=(), model_axis=None, seq_shard_saved=False)

# Optional barrier on each scan iteration's xs slice (params / cache).
# Historical note: XLA-CPU float normalization + WLICM hoist whole-stack
# bf16->f32 converts of scanned weights/caches into the while-loop carry,
# inflating per-device memory 2-4x vs the TPU target; the barrier alone did
# NOT survive the optimizer, so the dry-run disables the WLICM pass instead
# (see launch/dryrun.py XLA_FLAGS).  Kept off: barriers would inhibit the
# weight-prefetch overlap we want on real hardware.
BARRIER_SCAN_XS = False


def _xs_barrier(xs):
    if not BARRIER_SCAN_XS:
        return xs
    return jax.lax.optimization_barrier(xs)


def _constrain(x, ctx: Optional[ShardCtx]):
    if ctx is None or (not ctx.batch_axes and ctx.model_axis is None):
        return x
    try:
        return lax.with_sharding_constraint(x, ctx.residual_spec())
    except (ValueError, RuntimeError):   # no mesh context (pure-CPU tests)
        return x


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, kind: str, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": L.init_norm(cfg)}
    if kind in (ATTN, ATTN_LOCAL, ATTN_MOE):
        p["attn"] = L.init_attention(ks[0], cfg)
    elif kind in (MAMBA, MAMBA_MOE):
        p["mamba"] = M.init_mamba(ks[0], cfg)
    elif kind == RWKV:
        p["time"] = R.init_rwkv_time(ks[0], cfg)
    else:
        raise ValueError(kind)
    p["norm2"] = L.init_norm(cfg)
    if kind in (ATTN_MOE, MAMBA_MOE):
        p["moe"] = L.init_moe(ks[1], cfg)
    elif kind == RWKV:
        p["channel"] = R.init_rwkv_channel(ks[1], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg)
    if cfg.post_norm:
        p["post_norm1"] = L.init_norm(cfg)
        p["post_norm2"] = L.init_norm(cfg)
    return p


def _init_period(key, cfg: ModelConfig):
    ks = jax.random.split(key, len(cfg.block_pattern))
    return {f"b{i}": _init_block(ks[i], kind, cfg)
            for i, kind in enumerate(cfg.block_pattern)}


def init_params(key, cfg: ModelConfig):
    k_embed, k_blocks, k_head, k_front, k_enc = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.param_dtype)
    params: Dict[str, Any] = {
        "embed": {"table": L.dense_init(k_embed, (cfg.vocab_size, cfg.d_model),
                                        1, dt)},
        "final_norm": L.init_norm(cfg),
    }
    # stacked super-blocks
    pks = jax.random.split(k_blocks, cfg.num_periods)
    periods = [_init_period(pk, cfg) for pk in pks]
    params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *periods)
    if not cfg.tie_embeddings:
        params["unembed"] = {"table": L.dense_init(
            k_head, (cfg.vocab_size, cfg.d_model), 1, dt)}
    if cfg.frontend == "clip_stub":
        params["frontend"] = {"proj": L.dense_init(
            k_front, (CLIP_DIM, cfg.d_model), 0, dt)}
    if cfg.family == "encdec":
        eks = jax.random.split(k_enc, cfg.encoder_layers + 1)
        enc_cfg = cfg  # same widths
        enc_blocks = [
            {"norm1": L.init_norm(cfg),
             "attn": L.init_attention(eks[i], cfg),
             "norm2": L.init_norm(cfg),
             "mlp": L.init_mlp(jax.random.fold_in(eks[i], 1), cfg)}
            for i in range(cfg.encoder_layers)]
        params["encoder"] = {
            "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_blocks),
            "final_norm": L.init_norm(cfg),
        }
        # per-decoder-layer cross attention
        cks = jax.random.split(jax.random.fold_in(k_enc, 7), cfg.num_periods)
        cross = [{"norm": L.init_norm(cfg),
                  "attn": L.init_attention(ck, cfg)} for ck in cks]
        params["cross"] = jax.tree.map(lambda *xs: jnp.stack(xs), *cross)
    return params


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


# ---------------------------------------------------------------------------
# block forward (shared by train / prefill / decode)
# ---------------------------------------------------------------------------

def _apply_sub(x, sub_out, post_norm_p, cfg):
    if cfg.post_norm and post_norm_p is not None:
        sub_out = L.norm_fwd(post_norm_p, sub_out, cfg)
    return x + sub_out


def _block_fwd(bp, kind: str, x, positions, cfg: ModelConfig,
               mode: str, cache=None, cache_len=None, cross_kv=None,
               kv_layout: str = "bksd", max_len: int = 0,
               ctx: Optional[ShardCtx] = None, kv_update: str = "dus",
               kv_window: bool = False):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache
    h = L.norm_fwd(bp["norm1"], x, cfg)
    local = kind == ATTN_LOCAL

    if kind in (ATTN, ATTN_LOCAL, ATTN_MOE):
        if mode == "train":
            y = L.attention_fwd(bp["attn"], h, positions, cfg, local=local)
        elif mode == "prefill":
            cap = max_len
            if kv_window and local and cfg.local_window:
                cap = min(max_len, cfg.local_window)
            y, new_cache = L.attention_prefill(
                bp["attn"], h, positions, cfg, cap, layout=kv_layout,
                local=local)
        else:  # decode
            win = kv_window and local and cfg.local_window is not None
            y, new_cache = L.attention_decode(
                bp["attn"], h, cache, cache_len, cfg, layout=kv_layout,
                local=local, update=kv_update, windowed=win)
    elif kind in (MAMBA, MAMBA_MOE):
        if mode == "decode":
            y, new_cache = M.mamba_decode(bp["mamba"], h, cache, cfg)
        elif mode == "prefill":
            y, new_cache = M.mamba_fwd(bp["mamba"], h, cfg, return_state=True,
                                       ctx=ctx)
        else:
            y = M.mamba_fwd(bp["mamba"], h, cfg, ctx=ctx)
    elif kind == RWKV:
        if mode == "decode":
            y, tm = R.rwkv_time_fwd(bp["time"], h, cfg,
                                    state={"shift": cache["tm_shift"],
                                           "wkv": cache["wkv"]},
                                    return_state=True, ctx=ctx)
        elif mode == "prefill":
            y, tm = R.rwkv_time_fwd(bp["time"], h, cfg, return_state=True,
                                    ctx=ctx)
        else:
            y = R.rwkv_time_fwd(bp["time"], h, cfg, ctx=ctx)
    else:
        raise ValueError(kind)
    x = _apply_sub(x, y, bp.get("post_norm1"), cfg)
    x = _constrain(x, ctx)

    # cross attention (encoder-decoder only)
    if cross_kv is not None:
        hc = L.norm_fwd(cross_kv["norm"], x, cfg)
        if mode == "decode":
            yc, _ = L.attention_decode(cross_kv["attn"], hc, cross_kv["kv"],
                                       cache_len, cfg, cross=True,
                                       layout="bksd")
        else:
            # cross KV is stored decode-friendly [B,K,T,Dh]; full-seq
            # attention wants [B,T,K,Dh]
            ck_ = jnp.swapaxes(cross_kv["kv"]["k"], 1, 2)
            cv_ = jnp.swapaxes(cross_kv["kv"]["v"], 1, 2)
            yc = L.attention_fwd(cross_kv["attn"], hc, positions, cfg,
                                 cross_kv=(ck_, cv_))
        x = x + yc

    h2 = L.norm_fwd(bp["norm2"], x, cfg)
    if kind in (ATTN_MOE, MAMBA_MOE):
        use_a2a = (ctx is not None and ctx.moe_a2a and mode != "decode"
                   and h2.shape[1] % max(ctx.model_size, 1) == 0
                   and h2.shape[1] >= ctx.model_size)
        if use_a2a:
            y2, aux = L.moe_fwd_a2a(bp["moe"], h2, cfg, ctx)
        else:
            y2, aux = L.moe_fwd(bp["moe"], h2, cfg)
        # name the MoE output so remat_policy="save_moe" can keep it in the
        # backward instead of re-running the expert gathers + all-to-alls
        from jax.ad_checkpoint import checkpoint_name
        y2 = checkpoint_name(y2, "moe_out")
    elif kind == RWKV:
        if mode in ("decode", "prefill"):
            y2, cm = R.rwkv_channel_fwd(bp["channel"], h2, cfg,
                                        state=None if mode == "prefill"
                                        else {"shift": cache["cm_shift"]},
                                        return_state=True)
        else:
            y2 = R.rwkv_channel_fwd(bp["channel"], h2, cfg)
    else:
        y2 = L.mlp_fwd(bp["mlp"], h2, cfg)
    x = _apply_sub(x, y2, bp.get("post_norm2"), cfg)
    x = _constrain(x, ctx)

    if kind == RWKV and mode in ("decode", "prefill"):
        new_cache = {"tm_shift": tm["shift"], "wkv": tm["wkv"],
                     "cm_shift": cm["shift"]}
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               kv_layout: str = "bksd", dtype=jnp.bfloat16,
               kv_window: bool = False):
    """Per-period cache pytree with leaves stacked over periods.  With
    ``kv_window``, sliding-window layers allocate only the window (ring
    buffer) — the per-layer heterogeneous capacity the paper's per-layer
    layout story implies."""
    def one_block(kind):
        if kind in (ATTN, ATTN_LOCAL, ATTN_MOE):
            cap = max_len
            if kv_window and kind == ATTN_LOCAL and cfg.local_window:
                cap = min(max_len, cfg.local_window)
            return L.init_kv_cache(cfg, batch, cap, kv_layout, dtype)
        if kind in (MAMBA, MAMBA_MOE):
            return M.init_mamba_state(cfg, batch, dtype)
        if kind == RWKV:
            return R.init_rwkv_state(cfg, batch, dtype)
        raise ValueError(kind)

    period = {f"b{i}": one_block(k) for i, k in enumerate(cfg.block_pattern)}
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.num_periods,) + x.shape), period)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int,
                   kv_layout: str = "bksd", dtype=jnp.bfloat16,
                   kv_window: bool = False):
    return jax.eval_shape(
        partial(init_cache, cfg, batch, max_len, kv_layout, dtype, kv_window))


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def _embed_lookup(table, tokens, grad_spec):
    """Embedding gather with a sharding-constrained gradient.

    The VJP of a plain gather is a scatter-add into a zeros[V, D] — GSPMD
    replicates it (dry-run: 6x 1 GiB f32 buffers for a 65k vocab, 4 GiB for
    202k).  Constraining the zeros on the D dim partitions the scatter
    trivially (indices touch dim 0 only).
    """
    shape, dtype = table.shape, table.dtype

    @jax.custom_vjp
    def lookup(t, tok):
        return t[tok]

    def fwd(t, tok):
        return t[tok], tok

    def bwd(tok, g):
        zeros = jnp.zeros(shape, jnp.float32)
        if grad_spec is not None:
            try:
                zeros = lax.with_sharding_constraint(zeros, grad_spec)
            except (ValueError, RuntimeError):
                pass
        dt = zeros.at[tok].add(g.astype(jnp.float32))
        import numpy as _np
        return (dt.astype(dtype), _np.zeros(tok.shape, jax.dtypes.float0))

    lookup.defvjp(fwd, bwd)
    return lookup(table, tokens)


def embed_tokens(params, tokens, cfg: ModelConfig,
                 ctx: Optional[ShardCtx] = None):
    grad_spec = None
    if ctx is not None and ctx.fsdp_axes:
        grad_spec = jax.sharding.PartitionSpec(None, ctx.fsdp_axes)
    e = _embed_lookup(params["embed"]["table"], tokens, grad_spec)
    if cfg.tie_embeddings:          # gemma-style scaled embeddings
        e = e * jnp.asarray(cfg.d_model ** 0.5, e.dtype)
    return e


def unembed_table(params, cfg: ModelConfig):
    return (params["embed"]["table"] if cfg.tie_embeddings
            else params["unembed"]["table"])


def logits_fwd(params, h, cfg: ModelConfig):
    t = unembed_table(params, cfg)
    lg = jnp.einsum("...d,vd->...v", h, t,
                    preferred_element_type=jnp.float32)
    return L.softcap(lg, cfg.final_logit_softcap)


def chunked_xent(params, h, labels, cfg: ModelConfig, *, chunk: int = 512,
                 mask=None):
    """Fused unembed+softmax+CE, scanned over sequence chunks so the full
    [B,S,V] logits never exist (paper §V.B fusion applied to the LM head)."""
    B, S, D = h.shape
    t = unembed_table(params, cfg)
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    chunk = min(chunk, S)
    n = S // chunk
    assert S % chunk == 0, (S, chunk)

    hc = jnp.moveaxis(h.reshape(B, n, chunk, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0)

    def body(acc, xs):
        hcc, lcc, mcc = xs
        lg = jnp.einsum("bcd,vd->bcv", hcc, t,
                        preferred_element_type=jnp.float32)
        lg = L.softcap(lg, cfg.final_logit_softcap)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, lcc[..., None], axis=-1)[..., 0]
        loss = (lse - gold) * mcc
        return (acc[0] + loss.sum(), acc[1] + mcc.sum()), None

    (tot, cnt), _ = lax.scan(jax.remat(body),
                             (jnp.zeros((), jnp.float32),
                              jnp.zeros((), jnp.float32)),
                             (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# full forward passes
# ---------------------------------------------------------------------------

def _encoder_fwd(params, frames, cfg: ModelConfig, ctx=None):
    """Whisper encoder: frames [B,T,D] (stub embeddings) -> [B,T,D]."""
    B, T, D = frames.shape
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    x = frames

    def body(x, bp):
        bp = _xs_barrier(bp)
        h = L.norm_fwd(bp["norm1"], x, cfg)
        q = (h @ bp["attn"]["wq"]).reshape(B, T, cfg.num_heads, cfg.head_dim)
        k = (h @ bp["attn"]["wk"]).reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
        v = (h @ bp["attn"]["wv"]).reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
        mask = jnp.ones((T, T), bool)       # bidirectional
        o = L._sdpa(q, k, v, mask, cfg).reshape(B, T, cfg.q_dim)
        x = x + o @ bp["attn"]["wo"]
        h2 = L.norm_fwd(bp["norm2"], x, cfg)
        x = x + L.mlp_fwd(bp["mlp"], h2, cfg)
        return x, None

    x, _ = lax.scan(jax.remat(body), x, params["encoder"]["blocks"])
    return L.norm_fwd(params["encoder"]["final_norm"], x, cfg)


def _cross_kv_from_encoder(params, enc_out, cfg: ModelConfig):
    """Precompute per-decoder-layer cross K/V (stacked over periods)."""
    B, T, _ = enc_out.shape

    def one(cp):
        k = (enc_out @ cp["attn"]["wk"]).reshape(B, T, cfg.num_kv_heads,
                                                 cfg.head_dim)
        v = (enc_out @ cp["attn"]["wv"]).reshape(B, T, cfg.num_kv_heads,
                                                 cfg.head_dim)
        # store in decode-friendly bksd layout
        return {"k": jnp.moveaxis(k, 1, 2), "v": jnp.moveaxis(v, 1, 2)}

    return jax.vmap(one)(params["cross"])


def _remat_policy(name: str):
    if name == "save_moe":
        from jax.ad_checkpoint import checkpoint_policies as cp
        return cp.save_only_these_names("moe_out")
    return None


def forward(params, tokens, positions, cfg: ModelConfig, *,
            embeds: Optional[jnp.ndarray] = None,
            frames: Optional[jnp.ndarray] = None,
            ctx: Optional[ShardCtx] = None,
            remat_blocks: bool = True, remat_policy: str = "none"):
    """Training forward -> final hidden states [B,S,D].

    ``embeds``: optional [B,T_front,D_clip] stubbed patch embeddings (VLM),
    prepended to the token embeddings.
    ``frames``: optional [B,T_enc,D] stubbed audio frames (enc-dec).
    """
    x = embed_tokens(params, tokens, cfg, ctx)
    if embeds is not None and cfg.frontend == "clip_stub":
        pe = (embeds @ params["frontend"]["proj"]).astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
    x = _constrain(x, ctx)
    B, S, _ = x.shape
    if positions.shape[1] != S:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))

    cross = None
    if cfg.family == "encdec":
        enc = _encoder_fwd(params, frames, cfg, ctx)
        cross = _cross_kv_from_encoder(params, enc, cfg)

    pattern = cfg.block_pattern

    # two-level checkpointing: the scan saves only the per-period residual;
    # multi-block periods (jamba: 8, gemma2/llama4: 2) additionally remat
    # each block so the backward holds ONE block's internals at a time.
    inner_remat = remat_blocks and len(pattern) > 1

    def period_body(carry, xs):
        x, aux = carry
        xs = _xs_barrier(xs)
        if cross is not None:
            bp, ckv = xs
        else:
            bp, ckv = xs, None
        for i, kind in enumerate(pattern):
            ck = None
            if ckv is not None:
                ck = {"norm": ckv["norm"], "attn": ckv["attn"],
                      "kv": ckv["kv"]}

            def run_block(bp_i, x_i, ck_i, _kind=kind):
                xo, _, a = _block_fwd(bp_i, _kind, x_i, positions, cfg,
                                      "train", cross_kv=ck_i, ctx=ctx)
                return xo, a

            if inner_remat:
                run_block = jax.remat(run_block,
                                      policy=_remat_policy(remat_policy))
            x, a = run_block(bp[f"b{i}"], x, ck)
            aux = aux + a
        return (x, aux), None

    body = (jax.remat(period_body, policy=_remat_policy(remat_policy))
            if remat_blocks else period_body)
    if cross is not None:
        ckv_in = {"norm": params["cross"]["norm"],
                  "attn": params["cross"]["attn"], "kv": cross}
        (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (params["blocks"], ckv_in))
    else:
        (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])

    x = L.norm_fwd(params["final_norm"], x, cfg)
    return x, aux / cfg.num_layers


def prefill(params, tokens, cfg: ModelConfig, max_len: int, *,
            kv_layout: str = "bksd",
            embeds: Optional[jnp.ndarray] = None,
            frames: Optional[jnp.ndarray] = None,
            ctx: Optional[ShardCtx] = None, kv_window: bool = False):
    """Process a prompt, returning (last-token logits, cache, enc_cross_kv)."""
    x = embed_tokens(params, tokens, cfg, ctx)
    if embeds is not None and cfg.frontend == "clip_stub":
        pe = (embeds @ params["frontend"]["proj"]).astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
    x = _constrain(x, ctx)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    cross = None
    if cfg.family == "encdec":
        enc = _encoder_fwd(params, frames, cfg, ctx)
        cross = _cross_kv_from_encoder(params, enc, cfg)

    pattern = cfg.block_pattern

    def period_body(x, xs):
        xs = _xs_barrier(xs)
        if cross is not None:
            bp, ckv = xs
        else:
            bp, ckv = xs, None
        caches = {}
        for i, kind in enumerate(pattern):
            ck = None
            if ckv is not None:
                ck = {"norm": ckv["norm"], "attn": ckv["attn"], "kv": ckv["kv"]}
            x, c, _ = _block_fwd(bp[f"b{i}"], kind, x, positions, cfg,
                                 "prefill", kv_layout=kv_layout,
                                 max_len=max_len, cross_kv=ck, ctx=ctx,
                                 kv_window=kv_window)
            caches[f"b{i}"] = c
        return x, caches

    if cross is not None:
        ckv_in = {"norm": params["cross"]["norm"],
                  "attn": params["cross"]["attn"], "kv": cross}
        x, cache = lax.scan(period_body, x, (params["blocks"], ckv_in))
    else:
        x, cache = lax.scan(period_body, x, params["blocks"])

    x = L.norm_fwd(params["final_norm"], x, cfg)
    logits = logits_fwd(params, x[:, -1:, :], cfg)[:, 0]
    return logits, cache, cross


def decode_step(params, cache, token, cache_len, cfg: ModelConfig, *,
                kv_layout: str = "bksd", cross=None,
                ctx: Optional[ShardCtx] = None, kv_update: str = "dus",
                kv_window: bool = False):
    """One decode step.  token: [B,1] int32; cache_len: int32 scalar.
    Returns (logits [B,V], new_cache)."""
    x = embed_tokens(params, token, cfg, ctx)
    B = x.shape[0]
    pattern = cfg.block_pattern

    def period_body(x, xs):
        xs = _xs_barrier(xs)
        if cross is not None:
            bp, pc, ckv = xs
        else:
            (bp, pc), ckv = xs, None
        new_pc = {}
        for i, kind in enumerate(pattern):
            ck = None
            if ckv is not None:
                ck = {"norm": ckv["norm"], "attn": ckv["attn"], "kv": ckv["kv"]}
            x, c, _ = _block_fwd(bp[f"b{i}"], kind, x, None, cfg, "decode",
                                 cache=pc[f"b{i}"], cache_len=cache_len,
                                 kv_layout=kv_layout, cross_kv=ck, ctx=ctx,
                                 kv_update=kv_update, kv_window=kv_window)
            new_pc[f"b{i}"] = c
        return x, new_pc

    if cross is not None:
        ckv_in = {"norm": params["cross"]["norm"],
                  "attn": params["cross"]["attn"], "kv": cross}
        x, new_cache = lax.scan(period_body, x,
                                (params["blocks"], cache, ckv_in))
    else:
        x, new_cache = lax.scan(period_body, x, (params["blocks"], cache))

    x = L.norm_fwd(params["final_norm"], x, cfg)
    logits = logits_fwd(params, x[:, 0, :], cfg)
    return logits, new_cache
