"""Data pipeline: deterministic, shardable, restart-safe token streams.

Production posture: each host materializes only its slice of the global
batch (``host_batch_slice``) and the stream is a pure function of
(seed, step), so a restarted job resumes mid-epoch with zero coordination —
the checkpoint only needs the step counter.  Synthetic sources stand in for
the tokenized corpus (same interface a file-backed loader implements).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    kind: str = "synthetic_lm"      # synthetic_lm | synthetic_images | file
    path: Optional[str] = None


def host_batch_slice(global_batch: int, host_index: int, host_count: int):
    per = global_batch // host_count
    return slice(host_index * per, (host_index + 1) * per)


class TokenStream:
    """Deterministic synthetic LM stream: structured (markov-ish) tokens so
    the loss actually decreases during the examples' training runs."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 data: DataConfig = DataConfig(), host_index: int = 0,
                 host_count: int = 1):
        self.cfg = cfg
        self.shape = shape
        self.data = data
        self.sl = host_batch_slice(shape.global_batch, host_index, host_count)
        self.local_batch = self.sl.stop - self.sl.start

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        V = self.cfg.vocab_size
        B, S = self.local_batch, self.shape.seq_len
        rng = np.random.default_rng(
            np.random.SeedSequence([self.data.seed, step, self.sl.start]))
        # periodic structure + noise -> learnable
        base = rng.integers(0, V, size=(B, 1), dtype=np.int32)
        t = np.arange(S + 1, dtype=np.int32)[None, :]
        seq = (base + t * (1 + base % 7)) % V
        noise = rng.integers(0, V, size=(B, S + 1), dtype=np.int32)
        mask_noise = rng.random((B, S + 1)) < 0.05
        seq = np.where(mask_noise, noise, seq).astype(np.int32)
        out = {"tokens": seq[:, :-1], "labels": seq[:, 1:],
               "mask": np.ones((B, S), np.float32)}
        front = getattr(self.cfg, "frontend_tokens", 0)
        if self.cfg.frontend == "clip_stub" and front:
            out["tokens"] = out["tokens"][:, :S - front]
            out["embeds"] = rng.standard_normal(
                (B, front, 1024)).astype(np.float32)
            out["mask"][:, :front] = 0.0
        if self.cfg.family == "encdec":
            out["frames"] = rng.standard_normal(
                (B, self.cfg.encoder_seq, self.cfg.d_model)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class ImageStream:
    """Synthetic labeled images for the paper's CNNs (NCHW host layout)."""

    def __init__(self, batch: int, channels: int, hw: int, classes: int,
                 seed: int = 0):
        self.batch, self.channels, self.hw, self.classes = \
            batch, channels, hw, classes
        self.seed = seed

    def batch_at(self, step: int):
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        y = rng.integers(0, self.classes, size=(self.batch,), dtype=np.int32)
        # class-dependent blobs so training converges
        x = rng.standard_normal(
            (self.batch, self.channels, self.hw, self.hw)).astype(np.float32)
        cy = (y % self.hw).astype(np.int32)
        for i in range(self.batch):
            x[i, :, cy[i], :] += 3.0
            x[i, :, :, (y[i] // self.hw) % self.hw] += 2.0
        return x, y


def device_put_batch(batch: Dict[str, np.ndarray], shardings=None):
    if shardings is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    return {k: jax.device_put(jnp.asarray(v), shardings[k])
            for k, v in batch.items()}
