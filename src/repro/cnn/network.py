"""Layout-aware CNN executor (the paper's §IV.D integration, end to end).

``plan_network`` turns a CNNConfig into selector LayerDescs, assigns a layout
per layer (heuristic or DP), and ``forward`` executes the stack natively in
those layouts, inserting the fast layout transform wherever consecutive
layers disagree (counting them, as the paper reports for AlexNet: 4).

``plan_network_fused`` / ``forward_fused`` are the fused execution engine
(DESIGN.md §5): conv->relu->pool chains run as ONE Pallas kernel with the
intermediate living in VMEM scratch, and every re-layout folds into a
producer's output write (or the first conv's input read), so no standalone
transform pass remains.  ``forward`` is kept as the unfused correctness
reference; both report HBM traffic through RunStats.

Modes reproduce the paper's §VI mechanisms:
  * "cuda-convnet": every layer CHWN (+ direct conv);
  * "cudnn":        every layer NCHW (+ im2col-MM conv);
  * "opt":          per-layer selection + fast transforms (ours/the paper's).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import CNNConfig
from repro.configs.paper_table1 import ConvLayer, PoolLayer
from repro.core import (FusedPlan, Thresholds, apply_transform,
                        assign_layouts, calibrate, paper_heuristic_layouts,
                        plan_fused)
from repro.core.selector import LayerDesc
from repro.perfmodel import CostModel, default_cost_model
from repro.cnn import layers as CL
from repro.dtypes import DEFAULT_DTYPE, INT8_DTYPE, canon_dtype, dtype_bytes
from repro.quant import (dequantize, fake_quant, fold_scale_into_weights,
                         quantize)
from repro.shapes import conv_out_hw, pool_out_hw


def network_descs(cfg: CNNConfig,
                  dtype: str = DEFAULT_DTYPE) -> List[LayerDesc]:
    """Selector LayerDescs for ``cfg`` at a storage ``dtype``: every desc
    carries the element size so the planner's byte models and sublane widths
    track the dtype the network will actually run in.  Graph configs
    (DESIGN.md §11) resolve their name-based ``inputs`` edges to layer
    indices here; linear configs emit descs with no explicit edges, so the
    planners take the original chain code path untouched."""
    db = dtype_bytes(dtype)
    descs = []
    rins = CL.resolved_cfg_inputs(cfg)
    shapes = CL.layer_shapes(cfg)
    in_shp = input_shape(cfg)
    for i, (spec, shp) in enumerate(zip(cfg.layers, shapes)):
        s0 = in_shp if rins[i][0] < 0 else shapes[rins[i][0]]
        # explicit edges only where they differ from the linear default —
        # keeps linear descs byte-identical to the pre-DAG planner's input
        lin = (i - 1,) if i else (-1,)
        ins = () if rins[i] == lin else rins[i]
        if spec.kind == "conv":
            conv = ConvLayer(spec.name, cfg.batch, spec.out_channels, s0[2],
                             spec.kernel, s0[1], spec.stride, cfg.name,
                             pad=spec.pad)
            descs.append(LayerDesc(spec.name, "conv", conv=conv,
                                   out_shape=shp, dtype_bytes=db,
                                   inputs=ins))
        elif spec.kind == "pool":
            pool = PoolLayer(spec.name, cfg.batch, s0[1], s0[2], spec.kernel,
                             spec.stride, cfg.name)
            descs.append(LayerDesc(spec.name, "pool", pool=pool,
                                   out_shape=shp, dtype_bytes=db,
                                   inputs=ins))
        else:
            # only ReLU may fold as a conv epilogue ("act"): reject unknown
            # kinds loudly rather than silently folding/skipping them
            if spec.kind not in ("relu", "fc", "softmax", "flatten",
                                 "add", "concat", "upsample"):
                raise ValueError(f"unsupported layer kind: {spec.kind!r}")
            kind = "act" if spec.kind == "relu" else spec.kind
            descs.append(LayerDesc(spec.name, kind, out_shape=shp,
                                   dtype_bytes=db, inputs=ins))
    return descs


def input_shape(cfg: CNNConfig) -> Tuple[int, int, int, int]:
    return (cfg.batch, cfg.in_channels, cfg.image_hw, cfg.image_hw)


def plan_network(cfg: CNNConfig, mode: str = "opt",
                 thresholds: Optional[Thresholds] = None,
                 use_dp: bool = True,
                 dtype: str = DEFAULT_DTYPE) -> List[str]:
    """Per-layer layout list, planned at the storage ``dtype``."""
    descs = network_descs(cfg, dtype)
    if mode == "cuda-convnet":
        return ["CHWN"] * len(descs)
    if mode == "cudnn":
        return ["NCHW"] * len(descs)
    if use_dp:
        return assign_layouts(descs, input_layout="NCHW",
                              input_shape=input_shape(cfg)).layouts
    th = thresholds or calibrate(dtype_bytes=dtype_bytes(dtype))
    return paper_heuristic_layouts(descs, th)


def plan_network_fused(cfg: CNNConfig, dtype: str = DEFAULT_DTYPE,
                       policy: str = "uniform",
                       stack_policy: str = "auto") -> FusedPlan:
    """Fused execution plan: layout DP with fold-aware edges + chain fusion.
    ``dtype`` is the storage dtype the network runs in — it scales every
    byte model and shifts the layout crossovers (sublane width doubles at
    2-byte elements), so bf16 plans can differ from fp32 plans.

    ``policy="mixed"`` (DESIGN.md §9) makes the DP search per-layer
    (layout, storage dtype) states: interior conv chains may store their
    output as int8 (quantize folded into the epilogue, dequantize into the
    consumer conv's VMEM read), while the host input, the first conv chain,
    and the classifier head stay at the base ``dtype``.

    ``stack_policy="auto"`` (DESIGN.md §12) additionally fuses profitable
    conv->conv stacks into single halo-recomputing kernels; ``"off"``
    reproduces the single-conv-node plans byte for byte."""
    return plan_fused(network_descs(cfg, dtype), input_layout="NCHW",
                      input_shape=input_shape(cfg), dtype_policy=policy,
                      base_dtype=dtype, stack_policy=stack_policy)


@dataclass
class RunStats:
    transforms: int = 0             # STANDALONE re-layout passes executed
    transform_bytes: int = 0        # HBM bytes those passes moved
    fused_ops: int = 0              # kernels that folded an epilogue/layout
    hbm_bytes: int = 0              # modeled forward HBM traffic of the run
    bwd_hbm_bytes: int = 0          # modeled backward traffic (training=True)

    @property
    def total_hbm_bytes(self) -> int:
        return self.hbm_bytes + self.bwd_hbm_bytes


def _nbytes(x) -> int:
    return x.size * x.dtype.itemsize


def _is_int8(dtype_name: str) -> bool:
    return bool(dtype_name) and canon_dtype(dtype_name) == INT8_DTYPE


def _stored_nbytes(x, dtype_name: str) -> int:
    """HBM bytes of ``x`` as STORED under the plan's declared dtype.  The
    training path carries int8 boundaries as straight-through floats, so the
    array's own itemsize over-prices what the serving engine stores; the
    declared int8 wins.  (Per-channel scale vectors — one f32 per channel —
    are negligible and not modeled; DESIGN.md §9.)"""
    if _is_int8(dtype_name):
        return x.size
    return _nbytes(x)


def _channel_axis(layout: str) -> int:
    return 0 if layout == "CHWN" else 1


def _spatial(x, layout: str) -> int:
    return x.shape[2] if layout == "NCHW" else x.shape[1]


def _channels(x, layout: str) -> int:
    return x.shape[1] if layout == "NCHW" else x.shape[0]


def _conv_desc(spec, x, layout: str, batch: int, net: str) -> ConvLayer:
    """Reconstruct the cost-model ConvLayer from runtime shapes so the
    executor's backward accounting and ``core.heuristic`` agree exactly."""
    return ConvLayer(spec.name, batch, spec.out_channels, _spatial(x, layout),
                     spec.kernel, _channels(x, layout), spec.stride, net,
                     pad=spec.pad)


# Shared per-kind traffic accounting: both executors MUST price these layers
# identically or the fused-vs-seed savings become an artifact of the model.
def _acct(stats: "RunStats", fwd_b: int, bwd_b: int, training: bool):
    stats.hbm_bytes += fwd_b
    if training:
        stats.bwd_hbm_bytes += bwd_b


def _acct_eltwise(stats, x, training):
    """relu / softmax: fwd read+write; bwd read g + read mask/out + write."""
    _acct(stats, 2 * _nbytes(x), 3 * _nbytes(x), training)


def _acct_flatten(stats, x, cur_layout, training):
    b = 2 * _nbytes(x) if cur_layout == "CHWN" else 0
    _acct(stats, b, b, training)


def _acct_fc(stats, io_b, training):
    """bwd dx = g W^T, dW = x^T g, db: same traffic again."""
    _acct(stats, io_b, io_b, training)


def _acct_pool(stats, in_b, out_b, training):
    """bwd: read g + read input (max mask) + write dx."""
    _acct(stats, in_b + out_b, 2 * in_b + out_b, training)


def forward(params: Dict, x_nchw, cfg: CNNConfig, layouts: List[str],
            impl: str = "xla", interpret: bool = True,
            use_pallas_transform: bool = False, training: bool = False,
            cost_model: Optional[CostModel] = None
            ) -> Tuple[jnp.ndarray, RunStats]:
    """Run the network unfused; x enters as NCHW (the host data layout).
    Returns (class probabilities [N, classes], stats).  ``training`` also
    accounts the XLA-decomposed backward pass in ``stats.bwd_hbm_bytes``
    (shape-only arithmetic — works under ``jax.eval_shape``).  RunStats byte
    accounting delegates to ``cost_model`` (DESIGN.md §13) so the executor
    and the planner price traffic through the same oracle."""
    cm = cost_model or default_cost_model()
    stats = RunStats()
    rins = CL.resolved_cfg_inputs(cfg)
    last_use: Dict[int, int] = {}
    for i, ins in enumerate(rins):
        for p in ins:
            last_use[p] = i
    # produced tensors by layer index (-1 = the network input); a write is
    # counted once at its producer, every consumer counts its own read
    outs: Dict[int, Tuple[jnp.ndarray, str]] = {-1: (x_nchw, "NCHW")}
    flat = False
    x = x_nchw

    def _retuned(t, t_lay, lay):
        """Re-layout ``t`` into ``lay``, counting the standalone pass."""
        if t_lay == lay:
            return t
        stats.transforms += 1
        stats.transform_bytes += 2 * _nbytes(t)
        stats.hbm_bytes += 2 * _nbytes(t)
        if training:                 # the gradient re-layouts back
            stats.bwd_hbm_bytes += 2 * _nbytes(t)
        return apply_transform(t, t_lay, lay,
                               use_pallas=use_pallas_transform,
                               interpret=interpret)

    for i, (spec, lay) in enumerate(zip(cfg.layers, layouts)):
        x, cur_layout = outs[rins[i][0]]
        if spec.kind in ("conv", "pool") and lay != cur_layout and not flat:
            # distinct layouts always mean a real (non-identity) re-layout,
            # so every pass counted here moves bytes
            x = _retuned(x, cur_layout, lay)
            cur_layout = lay
        if spec.kind == "conv":
            w = params[spec.name]["w"]
            in_b = _nbytes(x)
            if training:
                desc = _conv_desc(spec, x, cur_layout, cfg.batch, cfg.name)
                stats.bwd_hbm_bytes += cm.conv_backward_bytes(
                    desc, cur_layout, x.dtype.itemsize, fused=False)
            x = CL.conv_forward(x, w, cur_layout,
                                spec.stride, spec.pad, impl=impl,
                                interpret=interpret)
            stats.hbm_bytes += in_b + _nbytes(w) + _nbytes(x)
        elif spec.kind == "pool":
            in_b = _nbytes(x)
            x = CL.pool_forward(x, cur_layout, spec.kernel, spec.stride,
                                spec.pool_op, impl=impl, interpret=interpret)
            _acct_pool(stats, in_b, _nbytes(x), training)
        elif spec.kind == "relu":
            x = CL.relu_forward(x)
            _acct_eltwise(stats, x, training)
        elif spec.kind == "flatten":
            _acct_flatten(stats, x, cur_layout, training)
            x = CL.flatten_forward(x, cur_layout)
            flat = True
        elif spec.kind == "fc":
            p = params[spec.name]
            in_b = _nbytes(x)
            x = CL.fc_forward(x, p["w"], p["b"])
            _acct_fc(stats, in_b + _nbytes(p["w"]) + _nbytes(p["b"])
                     + _nbytes(x), training)
        elif spec.kind == "softmax":
            x = CL.softmax_forward(x, impl=impl, interpret=interpret)
            _acct_eltwise(stats, x, training)
        elif spec.kind == "add":
            b2, b_lay = outs[rins[i][1]]
            x = _retuned(x, cur_layout, lay) + _retuned(b2, b_lay, lay)
            cur_layout = lay
            # fwd: read both operands + write; bwd: pure gradient fan-out
            _acct(stats, 3 * _nbytes(x), 0, training)
        elif spec.kind == "concat":
            parts = [_retuned(x, cur_layout, lay)]
            parts += [_retuned(*outs[p], lay) for p in rins[i][1:]]
            x = CL.concat_forward(parts, lay)
            cur_layout = lay
            # fwd read+write; bwd: slice the gradient back per branch
            _acct(stats, 2 * _nbytes(x), 2 * _nbytes(x), training)
        elif spec.kind == "upsample":
            x = CL.upsample_forward(_retuned(x, cur_layout, lay), lay,
                                    spec.kernel)
            cur_layout = lay
            # priced like a stream copy at the OUTPUT size both ways
            _acct(stats, 2 * _nbytes(x), 2 * _nbytes(x), training)
        outs[i] = (x, cur_layout)
        for p in set(rins[i]):
            if last_use[p] == i:
                outs.pop(p, None)
    return x, stats


def forward_fused(params: Dict, x_nchw, cfg: CNNConfig, plan: FusedPlan,
                  impl: str = "pallas", interpret: bool = True,
                  training: bool = False,
                  cost_model: Optional[CostModel] = None
                  ) -> Tuple[jnp.ndarray, RunStats]:
    """Run the network through the fused plan; x enters as NCHW.

    ``impl="pallas"`` executes each FusedOp as one kernel; ``impl="xla"``
    decomposes them (correctness reference).  RunStats uses the same traffic
    model as ``forward``, so the two are directly comparable.  ``training``
    accounts the custom-VJP backward (activation stash, one-kernel pool+mask
    backward, native dgrad/wgrad, folded re-layouts) in
    ``stats.bwd_hbm_bytes``.

    Mixed-dtype plans (DESIGN.md §9) store int8 boundaries between conv
    chains.  Inference carries REAL int8 tensors: the producing chain's
    output is quantized per channel, and the consuming conv folds the scale
    into its weights and dequantizes in VMEM (an exact rewrite — the scale
    factors out of the channel contraction).  Training keeps the carrier in
    the base float dtype with a straight-through quantize->dequantize at
    each boundary (same forward numerics the server stores, identity
    gradient), so ``make_train_step_fused`` stays differentiable; the byte
    model still prices those boundaries at 1 byte/element.
    """
    cm = cost_model or default_cost_model()
    stats = RunStats()
    # Graph plans (DESIGN.md §11) address tensors by PRODUCER layer index
    # (op.inputs / op.out_index); legacy linear plans carry no edges and
    # chain through the previous op's output.  Tensors are refcounted so a
    # branch buffer lives exactly until its last consumer (and its write is
    # counted once, at the producer).
    nref: Dict[int, int] = {}
    for op in plan.ops:
        for p in op.inputs:
            nref[p] = nref.get(p, 0) + 1
        if op.res_index is not None:
            nref[op.res_index] = nref.get(op.res_index, 0) + 1
    # producer index -> (tensor, layout, per-channel int8 scale or None)
    outs: Dict[int, Tuple[jnp.ndarray, str, Optional[jnp.ndarray]]] = {
        -1: (x_nchw, "NCHW", None)}
    prev_key = -1
    x = x_nchw

    def take(p: int):
        t, t_lay, qs = outs[p]
        left = nref.get(p, 1) - 1    # legacy plans: single consumer
        nref[p] = left
        if left <= 0:
            outs.pop(p, None)
        return t, t_lay, qs

    def _retuned(t, t_lay, lay):
        """Standalone re-layout (no kernel absorbed it), with accounting."""
        if t_lay == lay:
            return t
        stats.transforms += 1
        stats.transform_bytes += 2 * _nbytes(t)
        stats.hbm_bytes += 2 * _nbytes(t)
        if training:
            stats.bwd_hbm_bytes += 2 * _nbytes(t)
        return apply_transform(t, t_lay, lay, interpret=interpret)

    for op in plan.ops:
        spec = cfg.layers[op.index]
        x, cur, qscale = take(op.inputs[0] if op.inputs else prev_key)
        out_q = None                 # per-channel scale of an int8 output
        if op.kind != "conv" and x.dtype == jnp.int8:
            # defensive: plans never route int8 into non-conv ops, but a
            # hand-built plan must not silently feed int8 to float kernels
            x = dequantize(x, qscale, _channel_axis(cur),
                           jnp.dtype(plan.base_dtype or "float32"))
            qscale = None
        if op.kind == "conv" and op.stack_index is not None:
            # Cross-layer stack (DESIGN.md §12): ``op.index`` is conv1 and
            # ``op.stack_index`` conv2; the mid activation between them is
            # staged in VMEM and NEVER touches HBM, so the byte model below
            # charges input + both weights + final output only.
            spec2 = cfg.layers[op.stack_index]
            p1, p2 = params[spec.name], params[spec2.name]
            pool = None
            if op.pool_index is not None:
                ps = cfg.layers[op.pool_index]
                pool = (ps.kernel, ps.stride, ps.pool_op)
            res = res_lay = None
            if op.res_index is not None:   # residual folds into conv2
                res, res_lay, _ = take(op.res_index)
                stats.hbm_bytes += _nbytes(res)
            in_b = _stored_nbytes(x, op.src_dtype)
            d1 = _conv_desc(spec, x, cur, cfg.batch, cfg.name)
            d2 = ConvLayer(spec2.name, cfg.batch, spec2.out_channels,
                           d1.out_hw, spec2.kernel, spec.out_channels,
                           spec2.stride, cfg.name, pad=spec2.pad)
            # the planner only emits stacks its VMEM bound admits; recompute
            # the same N tile here so executor and cost model agree
            nt = cm.stack_nt(d1, d2, op.layout, x.dtype.itemsize,
                             pool=pool[:2] if pool else None,
                             residual=res is not None) or 1
            if training:
                # stacks are inference-only plans; a training run over one
                # replays the unfused composition, so price both convs plus
                # the rematerialized mid round trip.
                mid_b = (cfg.batch * spec.out_channels * d1.out_hw ** 2
                         * x.dtype.itemsize)
                stats.bwd_hbm_bytes += (
                    cm.conv_backward_bytes(d1, op.layout, x.dtype.itemsize,
                                           relu=op.stack_relu, fused=True)
                    + cm.conv_backward_bytes(d2, op.layout,
                                             x.dtype.itemsize, relu=op.relu,
                                             pool=pool[:2] if pool else None,
                                             fused=True,
                                             residual=res is not None)
                    + 2 * mid_b)
            x = CL.fused_conv_stack(x, p1["w"], p2["w"], op.layout,
                                    spec.stride, spec.pad, spec2.stride,
                                    spec2.pad, relu1=op.stack_relu,
                                    relu2=op.relu, pool=pool, res=res,
                                    res_layout=res_lay, src_layout=cur,
                                    dst_layout=op.dst_layout, nt=nt,
                                    impl=impl, interpret=interpret)
            stats.hbm_bytes += (in_b + _nbytes(p1["w"]) + _nbytes(p2["w"])
                                + _stored_nbytes(x, op.dst_dtype))
            stats.fused_ops += 1
            cur = op.dst_layout
        elif op.kind == "conv":
            p = params[spec.name]
            pool = None
            if op.pool_index is not None:
                ps = cfg.layers[op.pool_index]
                pool = (ps.kernel, ps.stride, ps.pool_op)
            res = res_lay = None
            if op.res_index is not None:   # folded residual add: the skip
                res, res_lay, _ = take(op.res_index)
                stats.hbm_bytes += _nbytes(res)   # epilogue's second read
            in_b = _stored_nbytes(x, op.src_dtype)
            if training:
                desc = _conv_desc(spec, x, cur, cfg.batch, cfg.name)
                stats.bwd_hbm_bytes += cm.conv_backward_bytes(
                    desc, op.layout, x.dtype.itemsize, relu=op.relu,
                    pool=pool[:2] if pool else None, bias="b" in p,
                    fused=True, residual=res is not None)
            w = p["w"]
            if x.dtype == jnp.int8:  # dequant folds into the weights
                w = fold_scale_into_weights(w, qscale)
                qscale = None
            x = CL.fused_conv_block(x, w, op.layout, spec.stride,
                                    spec.pad, bias=p.get("b"), relu=op.relu,
                                    pool=pool, res=res, res_layout=res_lay,
                                    src_layout=cur, dst_layout=op.dst_layout,
                                    impl=impl, interpret=interpret)
            if _is_int8(op.dst_dtype):   # epilogue storage cast
                if training:             # straight-through float carrier
                    x = fake_quant(x, _channel_axis(op.dst_layout))
                else:                    # real int8 storage
                    x, out_q = quantize(x, _channel_axis(op.dst_layout))
            stats.hbm_bytes += (in_b + _nbytes(p["w"]) +
                                _stored_nbytes(x, op.dst_dtype))
            if "b" in p:
                stats.hbm_bytes += _nbytes(p["b"])
            if op.is_fused:          # folded an epilogue or a re-layout
                stats.fused_ops += 1
            cur = op.dst_layout
        elif op.kind == "pool":
            x = _retuned(x, cur, op.layout)   # no producer absorbed it
            cur = op.layout
            in_b = _nbytes(x)
            x = CL.pool_forward(x, cur, spec.kernel, spec.stride,
                                spec.pool_op, impl=impl, interpret=interpret,
                                dst_layout=op.dst_layout)
            _acct_pool(stats, in_b, _nbytes(x), training)
            if op.dst_layout != op.layout:
                stats.fused_ops += 1
            cur = op.dst_layout
        elif spec.kind == "relu":    # un-folded act (post-flatten)
            x = CL.relu_forward(x)
            _acct_eltwise(stats, x, training)
        elif op.kind == "flatten":
            _acct_flatten(stats, x, cur, training)
            x = CL.flatten_forward(x, cur)
        elif op.kind == "fc":
            p = params[spec.name]
            in_b = _nbytes(x)
            x = CL.fc_forward(x, p["w"], p["b"])
            _acct_fc(stats, in_b + _nbytes(p["w"]) + _nbytes(p["b"])
                     + _nbytes(x), training)
        elif op.kind == "softmax":
            x = CL.softmax_forward(x, impl=impl, interpret=interpret)
            _acct_eltwise(stats, x, training)
        elif op.kind == "add":       # standalone residual add (un-folded)
            b2, b_lay, _ = take(op.inputs[1])
            x = _retuned(x, cur, op.layout) + _retuned(b2, b_lay, op.layout)
            cur = op.layout
            # fwd: read both operands + write; bwd: pure gradient fan-out
            _acct(stats, 3 * _nbytes(x), 0, training)
        elif op.kind == "concat":
            parts = [_retuned(x, cur, op.layout)]
            parts += [_retuned(*take(p)[:2], op.layout)
                      for p in op.inputs[1:]]
            x = CL.concat_forward(parts, op.layout)
            cur = op.layout
            _acct(stats, 2 * _nbytes(x), 2 * _nbytes(x), training)
        elif op.kind == "upsample":
            x = CL.upsample_forward(_retuned(x, cur, op.layout), op.layout,
                                    spec.kernel)
            cur = op.layout
            _acct(stats, 2 * _nbytes(x), 2 * _nbytes(x), training)
        prev_key = op.out_index if op.out_index >= 0 else op.index
        outs[prev_key] = (x, cur, out_q)
    return x, stats


def batch_output_ok(y) -> jnp.ndarray:
    """Cheap finite-check hook on the batch output (DESIGN.md §14): one
    fused all-finite reduction over the class probabilities — a scalar bool
    the guarded serving path folds into the jitted forward, so detecting a
    poisoned batch (int8 saturation, a bad kernel, injected NaN/Inf) costs
    one [N, classes] pass, negligible next to the conv stack.  The cast
    keeps the reduction exact for bf16/f32 outputs alike."""
    return jnp.all(jnp.isfinite(y.astype(jnp.float32)))


def loss_fn(params, x_nchw, labels, cfg: CNNConfig, layouts: List[str]):
    """Differentiable NLL (training uses the xla engine)."""
    probs, _ = forward(params, x_nchw, cfg, layouts, impl="xla")
    logp = jnp.log(jnp.clip(probs.astype(jnp.float32), 1e-20))
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    return nll


def make_train_step(cfg: CNNConfig, layouts: List[str], lr: float = 0.01,
                    momentum: float = 0.9):
    grad_fn = jax.value_and_grad(
        lambda p, x, y: loss_fn(p, x, y, cfg, layouts))

    @jax.jit
    def step(params, vel, x, y):
        loss, grads = grad_fn(params, x, y)
        new_vel = jax.tree.map(lambda v, g: momentum * v - lr * g, vel, grads)
        new_params = jax.tree.map(lambda p, v: p + v, params, new_vel)
        return new_params, new_vel, loss

    return step


def loss_fn_fused(params, x_nchw, labels, cfg: CNNConfig, plan: FusedPlan,
                  impl: str = "pallas", interpret: bool = True):
    """Differentiable NLL over the FUSED engine: the forward runs the fused
    Pallas kernels and the backward flows through their custom VJPs
    (layout-aware dgrad/wgrad, one-kernel pool+mask backward)."""
    probs, _ = forward_fused(params, x_nchw, cfg, plan, impl=impl,
                             interpret=interpret)
    logp = jnp.log(jnp.clip(probs.astype(jnp.float32), 1e-20))
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    return nll


def make_train_step_fused(cfg: CNNConfig, plan: FusedPlan, lr: float = 0.01,
                          momentum: float = 0.9, impl: str = "pallas",
                          interpret: bool = True):
    """SGD+momentum step over the fused training engine — the layout-aware
    twin of ``make_train_step`` (which autodiffs the unfused XLA forward)."""
    grad_fn = jax.value_and_grad(
        lambda p, x, y: loss_fn_fused(p, x, y, cfg, plan, impl, interpret))

    @jax.jit
    def step(params, vel, x, y):
        loss, grads = grad_fn(params, x, y)
        new_vel = jax.tree.map(lambda v, g: momentum * v - lr * g, vel, grads)
        new_params = jax.tree.map(lambda p, v: p + v, params, new_vel)
        return new_params, new_vel, loss

    return step


def init_velocity(params):
    return jax.tree.map(jnp.zeros_like, params)
