"""Layout-aware CNN executor (the paper's §IV.D integration, end to end).

``plan_network`` turns a CNNConfig into selector LayerDescs, assigns a layout
per layer (heuristic or DP), and ``forward`` executes the stack natively in
those layouts, inserting the fast layout transform wherever consecutive
layers disagree (counting them, as the paper reports for AlexNet: 4).

``plan_network_fused`` / ``forward_fused`` are the fused execution engine
(DESIGN.md §5): conv->relu->pool chains run as ONE Pallas kernel with the
intermediate living in VMEM scratch, and every re-layout folds into a
producer's output write (or the first conv's input read), so no standalone
transform pass remains.  ``forward`` is kept as the unfused correctness
reference; both report HBM traffic through RunStats.

Modes reproduce the paper's §VI mechanisms:
  * "cuda-convnet": every layer CHWN (+ direct conv);
  * "cudnn":        every layer NCHW (+ im2col-MM conv);
  * "opt":          per-layer selection + fast transforms (ours/the paper's).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import CNNConfig
from repro.configs.paper_table1 import ConvLayer, PoolLayer
from repro.core import (FusedPlan, Thresholds, apply_transform,
                        assign_layouts, calibrate, paper_heuristic_layouts,
                        plan_fused)
from repro.core.selector import LayerDesc
from repro.cnn import layers as CL


def network_descs(cfg: CNNConfig) -> List[LayerDesc]:
    descs = []
    hw, ci = cfg.image_hw, cfg.in_channels
    shapes = CL.layer_shapes(cfg)
    for spec, shp in zip(cfg.layers, shapes):
        if spec.kind == "conv":
            conv = ConvLayer(spec.name, cfg.batch, spec.out_channels, hw,
                             spec.kernel, ci, spec.stride, cfg.name,
                             pad=spec.pad)
            descs.append(LayerDesc(spec.name, "conv", conv=conv,
                                   out_shape=shp, dtype_bytes=4))
            hw = (hw + 2 * spec.pad - spec.kernel) // spec.stride + 1
            ci = spec.out_channels
        elif spec.kind == "pool":
            pool = PoolLayer(spec.name, cfg.batch, ci, hw, spec.kernel,
                             spec.stride, cfg.name)
            descs.append(LayerDesc(spec.name, "pool", pool=pool,
                                   out_shape=shp, dtype_bytes=4))
            hw = (hw - spec.kernel) // spec.stride + 1
        else:
            descs.append(LayerDesc(spec.name, spec.kind if spec.kind in
                                   ("fc", "softmax", "flatten") else "act",
                                   out_shape=shp, dtype_bytes=4))
    return descs


def input_shape(cfg: CNNConfig) -> Tuple[int, int, int, int]:
    return (cfg.batch, cfg.in_channels, cfg.image_hw, cfg.image_hw)


def plan_network(cfg: CNNConfig, mode: str = "opt",
                 thresholds: Optional[Thresholds] = None,
                 use_dp: bool = True) -> List[str]:
    """Per-layer layout list."""
    descs = network_descs(cfg)
    if mode == "cuda-convnet":
        return ["CHWN"] * len(descs)
    if mode == "cudnn":
        return ["NCHW"] * len(descs)
    th = thresholds or calibrate()
    if use_dp:
        return assign_layouts(descs, input_layout="NCHW",
                              input_shape=input_shape(cfg)).layouts
    return paper_heuristic_layouts(descs, th)


def plan_network_fused(cfg: CNNConfig) -> FusedPlan:
    """Fused execution plan: layout DP with fold-aware edges + chain fusion."""
    return plan_fused(network_descs(cfg), input_layout="NCHW",
                      input_shape=input_shape(cfg))


@dataclass
class RunStats:
    transforms: int = 0             # STANDALONE re-layout passes executed
    transform_bytes: int = 0        # HBM bytes those passes moved
    fused_ops: int = 0              # kernels that folded an epilogue/layout
    hbm_bytes: int = 0              # modeled total HBM traffic of the run


def _nbytes(x) -> int:
    return x.size * x.dtype.itemsize


def forward(params: Dict, x_nchw, cfg: CNNConfig, layouts: List[str],
            impl: str = "xla", interpret: bool = True,
            use_pallas_transform: bool = False
            ) -> Tuple[jnp.ndarray, RunStats]:
    """Run the network unfused; x enters as NCHW (the host data layout).
    Returns (class probabilities [N, classes], stats)."""
    stats = RunStats()
    cur_layout = "NCHW"
    x = x_nchw
    flat = False
    for spec, lay in zip(cfg.layers, layouts):
        if spec.kind in ("conv", "pool") and lay != cur_layout and not flat:
            # distinct layouts always mean a real (non-identity) re-layout,
            # so every pass counted here moves bytes
            stats.transforms += 1
            stats.transform_bytes += 2 * _nbytes(x)
            stats.hbm_bytes += 2 * _nbytes(x)
            x = apply_transform(x, cur_layout, lay,
                                use_pallas=use_pallas_transform,
                                interpret=interpret)
            cur_layout = lay
        if spec.kind == "conv":
            w = params[spec.name]["w"]
            in_b = _nbytes(x)
            x = CL.conv_forward(x, w, cur_layout,
                                spec.stride, spec.pad, impl=impl,
                                interpret=interpret)
            stats.hbm_bytes += in_b + _nbytes(w) + _nbytes(x)
        elif spec.kind == "pool":
            in_b = _nbytes(x)
            x = CL.pool_forward(x, cur_layout, spec.kernel, spec.stride,
                                spec.pool_op, impl=impl, interpret=interpret)
            stats.hbm_bytes += in_b + _nbytes(x)
        elif spec.kind == "relu":
            x = CL.relu_forward(x)
            stats.hbm_bytes += 2 * _nbytes(x)
        elif spec.kind == "flatten":
            stats.hbm_bytes += 2 * _nbytes(x) if cur_layout == "CHWN" else 0
            x = CL.flatten_forward(x, cur_layout)
            flat = True
        elif spec.kind == "fc":
            p = params[spec.name]
            in_b = _nbytes(x)
            x = CL.fc_forward(x, p["w"], p["b"])
            stats.hbm_bytes += (in_b + _nbytes(p["w"]) + _nbytes(p["b"]) +
                                _nbytes(x))
        elif spec.kind == "softmax":
            x = CL.softmax_forward(x, impl=impl, interpret=interpret)
            stats.hbm_bytes += 2 * _nbytes(x)
    return x, stats


def forward_fused(params: Dict, x_nchw, cfg: CNNConfig, plan: FusedPlan,
                  impl: str = "pallas", interpret: bool = True
                  ) -> Tuple[jnp.ndarray, RunStats]:
    """Run the network through the fused plan; x enters as NCHW.

    ``impl="pallas"`` executes each FusedOp as one kernel; ``impl="xla"``
    decomposes them (correctness reference).  RunStats uses the same traffic
    model as ``forward``, so the two are directly comparable.
    """
    stats = RunStats()
    cur = "NCHW"
    x = x_nchw
    for op in plan.ops:
        spec = cfg.layers[op.index]
        if op.kind == "conv":
            p = params[spec.name]
            pool = None
            if op.pool_index is not None:
                ps = cfg.layers[op.pool_index]
                pool = (ps.kernel, ps.stride, ps.pool_op)
            in_b = _nbytes(x)
            x = CL.fused_conv_block(x, p["w"], op.layout, spec.stride,
                                    spec.pad, bias=p.get("b"), relu=op.relu,
                                    pool=pool, src_layout=cur,
                                    dst_layout=op.dst_layout, impl=impl,
                                    interpret=interpret)
            stats.hbm_bytes += in_b + _nbytes(p["w"]) + _nbytes(x)
            if "b" in p:
                stats.hbm_bytes += _nbytes(p["b"])
            if op.is_fused:          # folded an epilogue or a re-layout
                stats.fused_ops += 1
            cur = op.dst_layout
        elif op.kind == "pool":
            if cur != op.layout:     # no producer absorbed it: standalone
                stats.transforms += 1
                stats.transform_bytes += 2 * _nbytes(x)
                stats.hbm_bytes += 2 * _nbytes(x)
                x = apply_transform(x, cur, op.layout, interpret=interpret)
                cur = op.layout
            in_b = _nbytes(x)
            x = CL.pool_forward(x, cur, spec.kernel, spec.stride,
                                spec.pool_op, impl=impl, interpret=interpret,
                                dst_layout=op.dst_layout)
            stats.hbm_bytes += in_b + _nbytes(x)
            if op.dst_layout != op.layout:
                stats.fused_ops += 1
            cur = op.dst_layout
        elif spec.kind == "relu":    # un-folded act (post-flatten)
            x = CL.relu_forward(x)
            stats.hbm_bytes += 2 * _nbytes(x)
        elif op.kind == "flatten":
            stats.hbm_bytes += 2 * _nbytes(x) if cur == "CHWN" else 0
            x = CL.flatten_forward(x, cur)
        elif op.kind == "fc":
            p = params[spec.name]
            in_b = _nbytes(x)
            x = CL.fc_forward(x, p["w"], p["b"])
            stats.hbm_bytes += (in_b + _nbytes(p["w"]) + _nbytes(p["b"]) +
                                _nbytes(x))
        elif op.kind == "softmax":
            x = CL.softmax_forward(x, impl=impl, interpret=interpret)
            stats.hbm_bytes += 2 * _nbytes(x)
    return x, stats


def loss_fn(params, x_nchw, labels, cfg: CNNConfig, layouts: List[str]):
    """Differentiable NLL (training uses the xla engine)."""
    probs, _ = forward(params, x_nchw, cfg, layouts, impl="xla")
    logp = jnp.log(jnp.clip(probs.astype(jnp.float32), 1e-20))
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    return nll


def make_train_step(cfg: CNNConfig, layouts: List[str], lr: float = 0.01,
                    momentum: float = 0.9):
    grad_fn = jax.value_and_grad(
        lambda p, x, y: loss_fn(p, x, y, cfg, layouts))

    @jax.jit
    def step(params, vel, x, y):
        loss, grads = grad_fn(params, x, y)
        new_vel = jax.tree.map(lambda v, g: momentum * v - lr * g, vel, grads)
        new_params = jax.tree.map(lambda p, v: p + v, params, new_vel)
        return new_params, new_vel, loss

    return step


def init_velocity(params):
    return jax.tree.map(jnp.zeros_like, params)
