"""Layout-polymorphic CNN layers (the paper's substrate).

Every op executes *natively in its assigned layout* — no hidden transposes.
``impl`` selects the engine:
  * "xla"    — lax convolution/reduce_window with layout-matching
               dimension_numbers (differentiable; used for training);
  * "pallas" — the Pallas kernels (direct-CHWN conv, im2col+MXU matmul for
               NCHW, window-reuse pooling, fused softmax) — the paper's
               optimized inference engines, validated in interpret mode;
  * "fft"    — frequency-domain conv (NCHW; the cuDNN-FFT analogue).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import CNNConfig, ConvSpec
from repro.shapes import conv_out_hw, pool_out_hw

# dimension_numbers per layout: (lhs, rhs, out)
_DIMNUMS = {
    "NCHW": ("NCHW", "OIHW", "NCHW"),
    "CHWN": ("CHWN", "IHWO", "CHWN"),
    "NHWC": ("NHWC", "HWIO", "NHWC"),
}


def conv_forward(x, w, layout: str, stride: int = 1, pad: int = 0,
                 impl: str = "xla", interpret: bool = True):
    """x in ``layout``; w canonical [Co, Ci, F, F].

    int8 ``x`` (mixed-dtype storage, DESIGN.md §9) is consumed natively by
    the Pallas engines (cast to f32 in VMEM; the caller folded the
    per-channel dequant scale into ``w``, so weights keep their float dtype
    and the result comes out in it).  The XLA reference path dequantizes by
    casting up front — same arithmetic, without the 1-byte HBM read.
    """
    cdt = w.dtype if x.dtype == jnp.int8 else x.dtype  # compute/out dtype
    if impl == "xla":
        lhs, rhs, out = _DIMNUMS[layout]
        if rhs == "IHWO":
            wr = jnp.transpose(w, (1, 2, 3, 0))     # [Ci,F,F,Co]
        elif rhs == "HWIO":
            wr = jnp.transpose(w, (2, 3, 1, 0))
        else:
            wr = w
        return lax.conv_general_dilated(
            x.astype(cdt), wr.astype(cdt), (stride, stride),
            [(pad, pad), (pad, pad)], dimension_numbers=(lhs, rhs, out),
            preferred_element_type=jnp.float32).astype(cdt)
    if impl == "pallas":
        if layout == "CHWN":
            from repro.kernels.conv.ops import conv_direct_chwn
            wr = jnp.transpose(w, (1, 2, 3, 0))
            return conv_direct_chwn(x, wr.astype(cdt), stride=stride,
                                    pad=pad, interpret=interpret)
        from repro.kernels.conv.ops import conv_im2col_nchw_fused
        return conv_im2col_nchw_fused(x, w.astype(cdt), stride=stride,
                                      pad=pad, interpret=interpret)
    if impl == "fft":
        assert layout == "NCHW", "FFT conv is bound to NCHW (paper §IV.A)"
        from repro.kernels.conv.ops import conv_fft_nchw
        return conv_fft_nchw(x.astype(cdt), w.astype(cdt), stride=stride,
                             pad=pad)
    raise ValueError(impl)


def pool_forward(x, layout: str, F: int, S: int, op: str = "max",
                 impl: str = "xla", interpret: bool = True,
                 dst_layout: Optional[str] = None):
    dst = dst_layout or layout
    if impl == "pallas":
        from repro.kernels.pool.ops import pool_chwn, pool_nchw
        if layout == "CHWN":
            return pool_chwn(x, F, S, op, dst_layout=dst, interpret=interpret)
        return pool_nchw(x, F, S, op, dst_layout=dst, interpret=interpret)
    from repro.kernels.pool.ref import pool_ref
    y = pool_ref(x, F, S, op, layout)
    if dst != layout:
        from repro.core.transform import apply_transform
        y = apply_transform(y, layout, dst)
    return y


def fused_conv_block(x, w, layout: str, stride: int = 1, pad: int = 0, *,
                     bias=None, relu: bool = False,
                     pool: Optional[Tuple[int, int, str]] = None,
                     res=None, res_layout: Optional[str] = None,
                     src_layout: Optional[str] = None,
                     dst_layout: Optional[str] = None,
                     impl: str = "pallas", interpret: bool = True):
    """One fused-engine node: conv[+bias][+residual add][+relu][+pool]
    executed natively in ``layout``, consuming ``src_layout`` input and
    producing ``dst_layout`` output.  ``res`` is the skip tensor of a folded
    residual add (stored in ``res_layout``): it is added onto the conv
    accumulator BEFORE the ReLU, matching the ResNet epilogue order.
    ``impl="pallas"`` runs it as ONE kernel (the chain intermediate never
    leaves VMEM; the skip is read through a second, layout-folding
    BlockSpec); ``impl="xla"`` is the decomposed reference."""
    src = src_layout or layout
    dst = dst_layout or layout
    cdt = w.dtype if x.dtype == jnp.int8 else x.dtype  # compute/out dtype
    if impl == "pallas":
        if layout == "CHWN":
            from repro.kernels.conv.ops import conv_direct_chwn
            wr = jnp.transpose(w, (1, 2, 3, 0)).astype(cdt)
            return conv_direct_chwn(x, wr, stride=stride, pad=pad,
                                    interpret=interpret, bias=bias, relu=relu,
                                    pool=pool, res=res,
                                    res_layout=res_layout or layout,
                                    src_layout=src, dst_layout=dst)
        from repro.kernels.conv.ops import conv_im2col_nchw_fused
        return conv_im2col_nchw_fused(x, w.astype(cdt), stride=stride,
                                      pad=pad, interpret=interpret, bias=bias,
                                      relu=relu, pool=pool, res=res,
                                      res_layout=res_layout or layout,
                                      src_layout=src, dst_layout=dst)
    from repro.core.transform import apply_transform
    y = apply_transform(x.astype(cdt), src, layout)
    y = conv_forward(y, w, layout, stride, pad, impl="xla")
    if bias is not None:
        b = bias.astype(y.dtype)
        y = y + (b[:, None, None, None] if layout == "CHWN"
                 else b[None, :, None, None])
    if res is not None:
        y = y + apply_transform(res.astype(y.dtype),
                                res_layout or layout, layout)
    if relu:
        y = jax.nn.relu(y)
    if pool is not None:
        y = pool_forward(y, layout, pool[0], pool[1], pool[2], impl="xla")
    return apply_transform(y, layout, dst)


def fused_conv_stack(x, w1, w2, layout: str, stride1: int = 1, pad1: int = 0,
                     stride2: int = 1, pad2: int = 0, *,
                     relu1: bool = False, relu2: bool = False,
                     pool: Optional[Tuple[int, int, str]] = None,
                     res=None, res_layout: Optional[str] = None,
                     src_layout: Optional[str] = None,
                     dst_layout: Optional[str] = None, nt: int = 8,
                     impl: str = "pallas", interpret: bool = True):
    """Cross-layer stack node (DESIGN.md §12): conv1[+relu]->conv2[+residual
    add][+relu][+pool] executed natively in ``layout`` as ONE kernel — the
    intermediate activation between the convs is staged in VMEM and never
    written to HBM.  ``w1``/``w2`` are canonical [Co, Ci, F, F]; ``nt`` is
    the N tile the planner's VMEM bound admitted (``heuristic.stack_nt``).
    ``impl="xla"`` decomposes into two conv blocks (correctness reference);
    both paths are differentiable (the Pallas stack's custom VJP replays the
    unfused composition)."""
    src = src_layout or layout
    dst = dst_layout or layout
    if impl == "pallas":
        if layout == "CHWN":
            from repro.kernels.conv.ops import conv_stack_chwn
            w1r = jnp.transpose(w1, (1, 2, 3, 0))    # [Ci,F1,F1,Cm]
            w2r = jnp.transpose(w2, (1, 2, 3, 0))    # [Cm,F2,F2,Co]
            return conv_stack_chwn(x, w1r, w2r, stride1, pad1, stride2,
                                   pad2, nt, interpret, relu1=relu1,
                                   relu2=relu2, pool=pool, res=res,
                                   res_layout=res_layout or layout,
                                   src_layout=src, dst_layout=dst)
        from repro.kernels.conv.ops import conv_stack_nchw
        return conv_stack_nchw(x, w1, w2, stride1, pad1, stride2, pad2,
                               interpret, relu1=relu1, relu2=relu2,
                               pool=pool, res=res,
                               res_layout=res_layout or layout,
                               src_layout=src, dst_layout=dst)
    y = fused_conv_block(x, w1, layout, stride1, pad1, relu=relu1,
                         src_layout=src, impl="xla")
    return fused_conv_block(y, w2, layout, stride2, pad2, relu=relu2,
                            pool=pool, res=res, res_layout=res_layout,
                            dst_layout=dst, impl="xla")


def flatten_forward(x, layout: str):
    """-> [N, features] regardless of layout."""
    if layout == "CHWN":
        C, H, W, N = x.shape
        return x.reshape(C * H * W, N).T
    N = x.shape[0]
    return x.reshape(N, -1)


def fc_forward(x2d, w, b):
    """y = xW + b with f32 MXU accumulation, emitted in the storage dtype
    (the cuDNN mixed-precision recipe: narrow storage, wide accumulate)."""
    y = jnp.dot(x2d, w, preferred_element_type=jnp.float32)
    return (y + b.astype(jnp.float32)).astype(x2d.dtype)


def softmax_forward(x2d, impl: str = "xla", interpret: bool = True):
    if impl == "pallas":
        from repro.kernels.softmax.ops import softmax as softmax_fused
        return softmax_fused(x2d, interpret=interpret)
    return jax.nn.softmax(x2d.astype(jnp.float32), axis=-1).astype(x2d.dtype)


def relu_forward(x):
    return jax.nn.relu(x)


def concat_forward(xs: Sequence, layout: str):
    """Channel concat of the merge inputs (U-Net skip join)."""
    return jnp.concatenate(list(xs), axis=0 if layout == "CHWN" else 1)


def upsample_forward(x, layout: str, factor: int):
    """Nearest-neighbour spatial x``factor`` (the U-Net decoder expand)."""
    ha, wa = (1, 2) if layout == "CHWN" else (2, 3)
    return jnp.repeat(jnp.repeat(x, factor, axis=ha), factor, axis=wa)


# ---------------------------------------------------------------------------
# parameter init + shape propagation (graph-aware, DESIGN.md §11)
# ---------------------------------------------------------------------------

def resolved_cfg_inputs(cfg: CNNConfig) -> List[Tuple[int, ...]]:
    """Per-layer producer INDICES from the config's name-based ``inputs``
    edges (-1 is the network input; empty means "the previous layer").
    Every graph consumer resolves edges through this one function, so the
    planner and the executors can never disagree on the topology."""
    idx = {spec.name: i for i, spec in enumerate(cfg.layers)}
    rins: List[Tuple[int, ...]] = []
    for i, spec in enumerate(cfg.layers):
        if spec.inputs:
            try:
                ins = tuple(idx[nm] for nm in spec.inputs)
            except KeyError as e:
                raise ValueError(
                    f"layer {spec.name!r}: unknown input layer {e.args[0]!r}")
            for p in ins:
                if p >= i:
                    raise ValueError(
                        f"layer {spec.name!r}: input {cfg.layers[p].name!r} "
                        "is not an earlier layer (layers must be "
                        "topologically ordered)")
        else:
            ins = (i - 1,) if i else (-1,)
        rins.append(ins)
    return rins


def layer_shapes(cfg: CNNConfig):
    """Logical NCHW output shape after each layer (for the selector),
    propagated along the graph edges; merge nodes validate that their
    branches meet at consistent shapes."""
    rins = resolved_cfg_inputs(cfg)
    in_shape = (cfg.batch, cfg.in_channels, cfg.image_hw, cfg.image_hw)
    out: List[Tuple[int, ...]] = []

    def shp(p: int) -> Tuple[int, ...]:
        return in_shape if p < 0 else out[p]

    for i, spec in enumerate(cfg.layers):
        s0 = shp(rins[i][0])
        if spec.kind == "conv":
            hw = conv_out_hw(s0[2], spec.kernel, spec.stride, spec.pad)
            out.append((cfg.batch, spec.out_channels, hw, hw))
        elif spec.kind == "pool":
            hw = pool_out_hw(s0[2], spec.kernel, spec.stride)
            out.append((s0[0], s0[1], hw, hw))
        elif spec.kind == "flatten":
            out.append((s0[0], int(math.prod(s0[1:]))))
        elif spec.kind == "fc":
            out.append((cfg.batch, spec.fc_out))
        elif spec.kind == "add":
            shs = [shp(p) for p in rins[i]]
            if any(s != shs[0] for s in shs):
                raise ValueError(f"{spec.name}: add operands disagree "
                                 f"({shs})")
            out.append(shs[0])
        elif spec.kind == "concat":
            shs = [shp(p) for p in rins[i]]
            if any(s[0] != shs[0][0] or s[2:] != shs[0][2:] for s in shs):
                raise ValueError(f"{spec.name}: concat operands disagree "
                                 f"on batch/spatial dims ({shs})")
            out.append((shs[0][0], sum(s[1] for s in shs)) + shs[0][2:])
        elif spec.kind == "upsample":
            f = spec.kernel
            out.append((s0[0], s0[1], s0[2] * f, s0[3] * f))
        else:                            # act/softmax inherit their input
            out.append(s0)
    return out


def init_cnn(key, cfg: CNNConfig, dtype=jnp.float32) -> Dict:
    params = {}
    rins = resolved_cfg_inputs(cfg)
    shapes = layer_shapes(cfg)

    def in_dim(i: int) -> int:           # channels (4-D) or features (2-D)
        p = rins[i][0]
        return cfg.in_channels if p < 0 else shapes[p][1]

    for i, spec in enumerate(cfg.layers):
        key, sub = jax.random.split(key)
        if spec.kind == "conv":
            ci = in_dim(i)
            std = 1.0 / math.sqrt(ci * spec.kernel * spec.kernel)
            params[spec.name] = {
                "w": jax.random.normal(
                    sub, (spec.out_channels, ci, spec.kernel, spec.kernel),
                    dtype) * std,
            }
        elif spec.kind == "fc":
            feat = in_dim(i)
            std = 1.0 / math.sqrt(feat)
            params[spec.name] = {
                "w": jax.random.normal(sub, (feat, spec.fc_out), dtype) * std,
                "b": jnp.zeros((spec.fc_out,), dtype),
            }
    return params
