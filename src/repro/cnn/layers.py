"""Layout-polymorphic CNN layers (the paper's substrate).

Every op executes *natively in its assigned layout* — no hidden transposes.
``impl`` selects the engine:
  * "xla"    — lax convolution/reduce_window with layout-matching
               dimension_numbers (differentiable; used for training);
  * "pallas" — the Pallas kernels (direct-CHWN conv, im2col+MXU matmul for
               NCHW, window-reuse pooling, fused softmax) — the paper's
               optimized inference engines, validated in interpret mode;
  * "fft"    — frequency-domain conv (NCHW; the cuDNN-FFT analogue).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import CNNConfig, ConvSpec
from repro.shapes import conv_out_hw, pool_out_hw

# dimension_numbers per layout: (lhs, rhs, out)
_DIMNUMS = {
    "NCHW": ("NCHW", "OIHW", "NCHW"),
    "CHWN": ("CHWN", "IHWO", "CHWN"),
    "NHWC": ("NHWC", "HWIO", "NHWC"),
}


def conv_forward(x, w, layout: str, stride: int = 1, pad: int = 0,
                 impl: str = "xla", interpret: bool = True):
    """x in ``layout``; w canonical [Co, Ci, F, F].

    int8 ``x`` (mixed-dtype storage, DESIGN.md §9) is consumed natively by
    the Pallas engines (cast to f32 in VMEM; the caller folded the
    per-channel dequant scale into ``w``, so weights keep their float dtype
    and the result comes out in it).  The XLA reference path dequantizes by
    casting up front — same arithmetic, without the 1-byte HBM read.
    """
    cdt = w.dtype if x.dtype == jnp.int8 else x.dtype  # compute/out dtype
    if impl == "xla":
        lhs, rhs, out = _DIMNUMS[layout]
        if rhs == "IHWO":
            wr = jnp.transpose(w, (1, 2, 3, 0))     # [Ci,F,F,Co]
        elif rhs == "HWIO":
            wr = jnp.transpose(w, (2, 3, 1, 0))
        else:
            wr = w
        return lax.conv_general_dilated(
            x.astype(cdt), wr.astype(cdt), (stride, stride),
            [(pad, pad), (pad, pad)], dimension_numbers=(lhs, rhs, out),
            preferred_element_type=jnp.float32).astype(cdt)
    if impl == "pallas":
        if layout == "CHWN":
            from repro.kernels.conv.ops import conv_direct_chwn
            wr = jnp.transpose(w, (1, 2, 3, 0))
            return conv_direct_chwn(x, wr.astype(cdt), stride=stride,
                                    pad=pad, interpret=interpret)
        from repro.kernels.conv.ops import conv_im2col_nchw_fused
        return conv_im2col_nchw_fused(x, w.astype(cdt), stride=stride,
                                      pad=pad, interpret=interpret)
    if impl == "fft":
        assert layout == "NCHW", "FFT conv is bound to NCHW (paper §IV.A)"
        from repro.kernels.conv.ops import conv_fft_nchw
        return conv_fft_nchw(x.astype(cdt), w.astype(cdt), stride=stride,
                             pad=pad)
    raise ValueError(impl)


def pool_forward(x, layout: str, F: int, S: int, op: str = "max",
                 impl: str = "xla", interpret: bool = True,
                 dst_layout: Optional[str] = None):
    dst = dst_layout or layout
    if impl == "pallas":
        from repro.kernels.pool.ops import pool_chwn, pool_nchw
        if layout == "CHWN":
            return pool_chwn(x, F, S, op, dst_layout=dst, interpret=interpret)
        return pool_nchw(x, F, S, op, dst_layout=dst, interpret=interpret)
    from repro.kernels.pool.ref import pool_ref
    y = pool_ref(x, F, S, op, layout)
    if dst != layout:
        from repro.core.transform import apply_transform
        y = apply_transform(y, layout, dst)
    return y


def fused_conv_block(x, w, layout: str, stride: int = 1, pad: int = 0, *,
                     bias=None, relu: bool = False,
                     pool: Optional[Tuple[int, int, str]] = None,
                     src_layout: Optional[str] = None,
                     dst_layout: Optional[str] = None,
                     impl: str = "pallas", interpret: bool = True):
    """One fused-engine node: conv[+bias][+relu][+pool] executed natively in
    ``layout``, consuming ``src_layout`` input and producing ``dst_layout``
    output.  ``impl="pallas"`` runs it as ONE kernel (the chain intermediate
    never leaves VMEM); ``impl="xla"`` is the decomposed reference."""
    src = src_layout or layout
    dst = dst_layout or layout
    cdt = w.dtype if x.dtype == jnp.int8 else x.dtype  # compute/out dtype
    if impl == "pallas":
        if layout == "CHWN":
            from repro.kernels.conv.ops import conv_direct_chwn
            wr = jnp.transpose(w, (1, 2, 3, 0)).astype(cdt)
            return conv_direct_chwn(x, wr, stride=stride, pad=pad,
                                    interpret=interpret, bias=bias, relu=relu,
                                    pool=pool, src_layout=src,
                                    dst_layout=dst)
        from repro.kernels.conv.ops import conv_im2col_nchw_fused
        return conv_im2col_nchw_fused(x, w.astype(cdt), stride=stride,
                                      pad=pad, interpret=interpret, bias=bias,
                                      relu=relu, pool=pool, src_layout=src,
                                      dst_layout=dst)
    from repro.core.transform import apply_transform
    y = apply_transform(x.astype(cdt), src, layout)
    y = conv_forward(y, w, layout, stride, pad, impl="xla")
    if bias is not None:
        b = bias.astype(y.dtype)
        y = y + (b[:, None, None, None] if layout == "CHWN"
                 else b[None, :, None, None])
    if relu:
        y = jax.nn.relu(y)
    if pool is not None:
        y = pool_forward(y, layout, pool[0], pool[1], pool[2], impl="xla")
    return apply_transform(y, layout, dst)


def flatten_forward(x, layout: str):
    """-> [N, features] regardless of layout."""
    if layout == "CHWN":
        C, H, W, N = x.shape
        return x.reshape(C * H * W, N).T
    N = x.shape[0]
    return x.reshape(N, -1)


def fc_forward(x2d, w, b):
    """y = xW + b with f32 MXU accumulation, emitted in the storage dtype
    (the cuDNN mixed-precision recipe: narrow storage, wide accumulate)."""
    y = jnp.dot(x2d, w, preferred_element_type=jnp.float32)
    return (y + b.astype(jnp.float32)).astype(x2d.dtype)


def softmax_forward(x2d, impl: str = "xla", interpret: bool = True):
    if impl == "pallas":
        from repro.kernels.softmax.ops import softmax as softmax_fused
        return softmax_fused(x2d, interpret=interpret)
    return jax.nn.softmax(x2d.astype(jnp.float32), axis=-1).astype(x2d.dtype)


def relu_forward(x):
    return jax.nn.relu(x)


# ---------------------------------------------------------------------------
# parameter init + shape propagation
# ---------------------------------------------------------------------------

def init_cnn(key, cfg: CNNConfig, dtype=jnp.float32) -> Dict:
    params = {}
    hw, ci = cfg.image_hw, cfg.in_channels
    feat = None
    for spec in cfg.layers:
        key, sub = jax.random.split(key)
        if spec.kind == "conv":
            std = 1.0 / math.sqrt(ci * spec.kernel * spec.kernel)
            params[spec.name] = {
                "w": jax.random.normal(
                    sub, (spec.out_channels, ci, spec.kernel, spec.kernel),
                    dtype) * std,
            }
            hw = conv_out_hw(hw, spec.kernel, spec.stride, spec.pad)
            ci = spec.out_channels
        elif spec.kind == "pool":
            hw = pool_out_hw(hw, spec.kernel, spec.stride)
        elif spec.kind == "flatten":
            feat = ci * hw * hw
        elif spec.kind == "fc":
            std = 1.0 / math.sqrt(feat)
            params[spec.name] = {
                "w": jax.random.normal(sub, (feat, spec.fc_out), dtype) * std,
                "b": jnp.zeros((spec.fc_out,), dtype),
            }
            feat = spec.fc_out
    return params


def layer_shapes(cfg: CNNConfig):
    """Logical NCHW output shape after each layer (for the selector)."""
    hw, ci = cfg.image_hw, cfg.in_channels
    feat = None
    out = []
    for spec in cfg.layers:
        if spec.kind == "conv":
            hw = conv_out_hw(hw, spec.kernel, spec.stride, spec.pad)
            ci = spec.out_channels
            out.append((cfg.batch, ci, hw, hw))
        elif spec.kind == "pool":
            hw = pool_out_hw(hw, spec.kernel, spec.stride)
            out.append((cfg.batch, ci, hw, hw))
        elif spec.kind == "flatten":
            feat = ci * hw * hw
            out.append((cfg.batch, feat))
        elif spec.kind == "fc":
            feat = spec.fc_out
            out.append((cfg.batch, feat))
        elif feat is not None:           # act/softmax after flatten: 2-D
            out.append((cfg.batch, feat))
        else:
            out.append((cfg.batch, ci, hw, hw))
    return out
