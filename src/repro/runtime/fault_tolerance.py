"""Fault tolerance: auto-restart, straggler watchdog, elastic re-mesh.

At 1000+-node scale the dominant failure modes are (a) hard node loss
(process dies / ICI link down -> the whole step fails), (b) stragglers
(a slow host stretches every synchronous step), and (c) planned resizes.
This module provides the single-controller-side machinery:

  * ``FaultTolerantRunner`` — wraps the step loop; on exception it restores
    the latest checkpoint and replays from there (bounded retries with
    exponential backoff).  Failure injection for tests via ``inject``.
  * ``StragglerWatchdog`` — EMA/variance tracker of step wall time; flags
    steps beyond k sigma and exposes a callback hook (real deployment: swap
    in a hot-spare host group and re-init collectives; here: logged +
    counted, test-covered).
  * elastic restore — checkpoints are mesh-agnostic (see checkpointer);
    ``FaultTolerantRunner.restore`` takes the *current* shardings, so a
    restart onto a different device count resumes seamlessly.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

log = logging.getLogger("repro.ft")


def _snapshot(state):
    """Best-effort deep copy of the initial train state.  jax array leaves
    are immutable (sharing them is safe); host-side containers and numpy
    leaves are copied so an in-place-mutating ``step_fn`` can't poison the
    replay baseline.  Falls back to the bare reference when a leaf refuses
    to deepcopy (e.g. a closed-over handle)."""
    import copy
    try:
        return copy.deepcopy(state)
    except Exception:  # noqa: BLE001 — snapshot is best-effort by contract
        return state


@dataclass
class StragglerWatchdog:
    k_sigma: float = 4.0
    warmup: int = 5
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    _n: int = 0
    _mean: float = 0.0
    _m2: float = 0.0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True when the step is a straggler."""
        self._n += 1
        delta = dt - self._mean
        self._mean += delta / self._n
        self._m2 += delta * (dt - self._mean)
        if self._n <= self.warmup:
            return False
        var = self._m2 / max(self._n - 1, 1)
        sigma = max(var ** 0.5, 1e-9)
        if dt > self._mean + self.k_sigma * sigma and dt > 1.5 * self._mean:
            self.flagged.append((step, dt))
            log.warning("straggler: step %d took %.3fs (mean %.3fs)",
                        step, dt, self._mean)
            if self.on_straggler:
                self.on_straggler(step, dt, self._mean)
            return True
        return False


class StepFailure(RuntimeError):
    pass


@dataclass
class FaultTolerantRunner:
    """Runs ``total_steps`` of ``step_fn(state, step) -> state`` with
    checkpoint/restart semantics."""
    checkpointer: Any
    save_every: int = 100
    max_restarts: int = 5
    backoff_s: float = 0.0            # real clusters: seconds; tests: 0
    keep: int = 3
    watchdog: StragglerWatchdog = field(default_factory=StragglerWatchdog)

    def run(self, state, step_fn: Callable, total_steps: int,
            start_step: int = 0, shardings: Any = None,
            abstract_state: Any = None,
            on_metrics: Optional[Callable[[int, Dict], None]] = None):
        # snapshot of the INITIAL state: a restart with nothing checkpointed
        # must replay from here, not from whatever post-step value ``state``
        # was rebound to before the failing step (jax leaves are immutable,
        # but the binding advances on every successful step)
        initial_state = _snapshot(state)
        step = start_step
        restarts = 0
        while step < total_steps:
            try:
                t0 = time.time()
                state, metrics = step_fn(state, step)
                self.watchdog.observe(step, time.time() - t0)
                if on_metrics:
                    on_metrics(step, metrics)
                step += 1
                if step % self.save_every == 0 or step == total_steps:
                    self.checkpointer.save(step, state)
                    self.checkpointer.gc(self.keep)
            except (StepFailure, RuntimeError, ValueError) as e:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                log.warning("step %d failed (%s); restart %d/%d from latest "
                            "checkpoint", step, e, restarts, self.max_restarts)
                if self.backoff_s:
                    time.sleep(min(self.backoff_s * 2 ** restarts, 60.0))
                ref = abstract_state if abstract_state is not None else state
                # newest-first over ALL on-disk checkpoints: a latest
                # checkpoint that fails validation (torn write, stale
                # manifest) falls back to the next-oldest instead of
                # killing the restart (§14)
                restored = False
                for s in reversed(self.checkpointer.steps()):
                    try:
                        step, state = self.checkpointer.restore(
                            ref, step=s, shardings=shardings)
                        restored = True
                        break
                    except Exception as restore_err:  # noqa: BLE001
                        log.warning(
                            "checkpoint step %d unusable (%s); trying "
                            "next-oldest", s, restore_err)
                if not restored:
                    # hand out a fresh copy, not the snapshot itself — an
                    # in-place-mutating step_fn must not poison the
                    # baseline for a LATER reset
                    step, state = start_step, _snapshot(initial_state)
        self.checkpointer.wait()
        return step, state
