"""Serving-grade resilience: fault injection, degradation ladder, crash-safe
persisted state (DESIGN.md §14).

The planner's optimality story (layout DP, stack fusion, int8 boundaries)
silently assumes every plan that prices well also *executes* well.  In a
serving process that assumption breaks three ways: a kernel can fail at
execution time (VMEM-bound stack shapes, interpreter edge cases), a batch
can come back non-finite (int8 numerics, bad weights), and the persisted
plan/threshold state can be torn by a mid-write crash.  This module holds
the machinery the serving driver (``launch.cnn_serve``) wires in:

  * ``FaultInjector`` — a deterministic, seeded harness that injects kernel
    exceptions, NaN outputs, and artificial slow steps at configurable
    per-site rates, and corrupts persisted JSON on request.  Every injected
    fault is counted, so tests and CI can assert on exact incident totals.
  * ``degradation_ladder`` — the ordered list of execution variants
    (``Rung``: impl × stack policy × dtype policy) a guarded server walks
    down when a batch fails: pallas+stacks → pallas stacks-off →
    mixed→uniform dtype → decomposed XLA.  Every rung maps to a
    ``PlanCache`` key (never an ad-hoc replan), so the fallback plan is the
    same plan the planner would have produced for that variant.
  * ``IncidentLog`` — the taxonomy (``kernel_fault`` / ``nonfinite`` /
    ``quarantine`` / ``requeue`` / ``corrupt_state`` / ``straggler`` /
    ``degraded``) counted across the server's lifetime and surfaced in
    ``report_lines()``.
  * crash-safe JSON persistence — ``atomic_json_dump`` (payload checksum +
    fsync-before-replace: a mid-write crash never loses the previous
    generation), ``load_json_guarded`` (schema/checksum validation; an
    unreadable file is renamed aside as ``*.corrupt`` and the caller
    rebuilds instead of raising), ``quarantine_file``.

Nothing here imports the serving or CNN stacks — the ladder and the
injector are plain data/state machines, so the training side can reuse
them (``runtime.fault_tolerance`` already shares ``StragglerWatchdog``
in the other direction).
"""
from __future__ import annotations

import hashlib
import json
import logging
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

log = logging.getLogger("repro.resilience")

CHECKSUM_FIELD = "checksum"


class InjectedKernelFault(RuntimeError):
    """A fault-injection kernel exception (stands in for a real execution
    failure: VMEM OOM in a stack kernel, interpreter crash, device loss)."""


class ServingFault(RuntimeError):
    """Every rung of the degradation ladder failed for one batch.  The
    in-flight requests have been re-queued (front of the queue, original
    order) before this is raised — nothing is lost, the step just did not
    complete."""


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------

class FaultInjector:
    """Seeded, per-site Bernoulli fault injection.

    ``rates`` maps site names to firing probabilities in [0, 1].  A site is
    a fault kind (``"kernel"``, ``"nan"``, ``"slow"``) optionally qualified
    as ``"kind@qualifier"`` — the serving driver passes the executing rung's
    name / dtype policy / impl as qualifiers, so ``{"nan@mixed": 1.0}``
    poisons only the mixed-dtype path while ``{"kernel": 0.1}`` hits every
    rung.  The most specific matching rate wins (first qualifier in the
    caller's order, then the bare kind).

    Determinism: each site key draws from its own ``np.random.Generator``
    seeded by (seed, site key), so the fire/no-fire sequence per site is a
    pure function of the seed and that site's call count — independent of
    how other sites interleave.  Two runs with the same seed and the same
    per-site call sequence inject identical faults.
    """

    def __init__(self, seed: int = 0,
                 rates: Optional[Dict[str, float]] = None,
                 slow_s: float = 0.05):
        self.seed = seed
        self.rates = dict(rates or {})
        for site, r in self.rates.items():
            if not 0.0 <= r <= 1.0:
                raise ValueError(f"rate for {site!r} must be in [0,1], "
                                 f"got {r}")
        self.slow_s = slow_s
        self.counts: Dict[str, int] = {}       # fired, by resolved site key
        self.draws: Dict[str, int] = {}        # total draws, by site key
        self._rngs: Dict[str, np.random.Generator] = {}

    @property
    def fired(self) -> int:
        return sum(self.counts.values())

    def _resolve(self, kind: str,
                 quals: Sequence[str]) -> Optional[Tuple[str, float]]:
        for q in quals:
            key = f"{kind}@{q}"
            if key in self.rates:
                return key, self.rates[key]
        if kind in self.rates:
            return kind, self.rates[kind]
        return None

    def _rng(self, key: str) -> np.random.Generator:
        if key not in self._rngs:
            digest = hashlib.sha256(f"{self.seed}:{key}".encode()).digest()
            self._rngs[key] = np.random.default_rng(
                int.from_bytes(digest[:8], "little"))
        return self._rngs[key]

    def fire(self, kind: str, quals: Sequence[str] = ()) -> bool:
        """Deterministic Bernoulli draw for ``kind`` under ``quals``; counts
        the draw and (when it fires) the incident."""
        hit = self._resolve(kind, quals)
        if hit is None:
            return False
        key, rate = hit
        self.draws[key] = self.draws.get(key, 0) + 1
        if rate <= 0.0:
            return False
        fired = rate >= 1.0 or bool(self._rng(key).random() < rate)
        if fired:
            self.counts[key] = self.counts.get(key, 0) + 1
        return fired

    # -- the three execution-time sites --------------------------------------

    def maybe_kernel_fault(self, quals: Sequence[str] = ()) -> None:
        """Raises ``InjectedKernelFault`` when the kernel site fires."""
        if self.fire("kernel", quals):
            raise InjectedKernelFault(
                f"injected kernel fault (site=kernel, quals={list(quals)})")

    def maybe_slow(self, quals: Sequence[str] = ()) -> float:
        """Sleeps ``slow_s`` when the slow site fires; returns the injected
        delay (0.0 when it did not fire) so callers can log it."""
        if self.fire("slow", quals):
            time.sleep(self.slow_s)
            return self.slow_s
        return 0.0

    def maybe_poison(self, y: np.ndarray,
                     quals: Sequence[str] = ()) -> np.ndarray:
        """Returns ``y`` with its first element overwritten by NaN when the
        nan site fires (the cheap-finite-check must catch it downstream)."""
        if self.fire("nan", quals) and y.size:
            y = np.array(y, dtype=np.float32, copy=True)
            y.flat[0] = np.nan
        return y

    # -- persisted-state corruption (test/CI harness side) -------------------

    @staticmethod
    def corrupt_json(path: str, mode: str = "truncate") -> str:
        """Corrupt a persisted JSON file in place.  Modes:

        * ``truncate``  — cut the file mid-payload (torn write);
        * ``garbage``   — overwrite with non-JSON bytes;
        * ``version``   — bump the schema version to an unknown value;
        * ``checksum``  — flip payload bytes under a stale checksum.
        """
        if mode == "truncate":
            size = os.path.getsize(path)
            with open(path, "r+b") as f:
                f.truncate(max(size // 2, 1))
        elif mode == "garbage":
            with open(path, "wb") as f:
                f.write(b"\x00\xffnot json {]")
        elif mode == "version":
            with open(path) as f:
                obj = json.load(f)
            obj["version"] = 999999
            with open(path, "w") as f:
                json.dump(obj, f)
        elif mode == "checksum":
            with open(path) as f:
                obj = json.load(f)
            if CHECKSUM_FIELD not in obj:
                raise ValueError(f"{path} carries no checksum to violate")
            # mutate the payload without refreshing the checksum
            obj["_tampered"] = True
            with open(path, "w") as f:
                json.dump(obj, f)
        else:
            raise ValueError(f"unknown corruption mode {mode!r}")
        return path


def parse_inject_spec(spec: str, seed: int = 0,
                      slow_s: float = 0.05) -> Optional[FaultInjector]:
    """CLI front end: ``"kernel=0.1,nan@mixed=1.0,slow=0.05"`` -> injector.
    Empty/None spec returns None (injection disabled)."""
    if not spec:
        return None
    rates: Dict[str, float] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        site, _, rate = part.partition("=")
        if not rate:
            raise ValueError(f"--inject entry {part!r} is not site=rate")
        rates[site.strip()] = float(rate)
    return FaultInjector(seed=seed, rates=rates, slow_s=slow_s)


# ---------------------------------------------------------------------------
# the degradation ladder
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Rung:
    """One execution variant of the fused serving stack.  ``(policy,
    stack)`` are PlanCache key dimensions — every rung's plan is the
    planner's own plan for that variant, pulled from (or planned once into)
    the cache, never an ad-hoc replan."""
    name: str
    impl: str                     # "pallas" | "xla"
    stack: str                    # stack_policy: "auto" | "off"
    policy: str                   # dtype policy: "uniform" | "mixed"

    @property
    def plan_key(self) -> Tuple[str, str]:
        """The (policy, stack) PlanCache key coordinates of this rung."""
        return (self.policy, self.stack)


def _rung_name(impl: str, stack: str, policy: str) -> str:
    name = impl + ("+stacks" if stack == "auto" else "")
    if policy == "mixed":
        name += "-mixed"
    return name


def degradation_ladder(impl: str, policy: str,
                       stack: str = "auto") -> List[Rung]:
    """The guarded server's fallback chain, most capable first:

      pallas+stacks → pallas stacks-off → mixed→uniform dtype → xla
      decomposed (uniform, stacks-off)

    Built FROM the server's configured operating point by relaxing one
    lever per rung — stack fusion, then the mixed-dtype storage, then the
    fused Pallas engine itself — so a server already running a lower rung
    gets only the rungs at or below it (a uniform/xla server has a one-rung
    ladder) and equivalent variants dedupe.  The terminal rung is always
    decomposed XLA at the uniform dtype: the engine every differential test
    in the repo treats as ground truth."""
    if impl not in ("pallas", "xla"):
        raise ValueError(f"unknown impl {impl!r}")
    if policy not in ("uniform", "mixed"):
        raise ValueError(f"unknown dtype policy {policy!r}")
    if stack not in ("auto", "off"):
        raise ValueError(f"unknown stack policy {stack!r}")
    coords = [
        (impl, stack, policy),            # configured operating point
        (impl, "off", policy),            # stack fusion off
        (impl, "off", "uniform"),         # mixed -> uniform dtype
        ("xla", "off", "uniform"),        # decomposed ground truth
    ]
    rungs: List[Rung] = []
    for i, s, p in coords:
        if all((i, s, p) != (r.impl, r.stack, r.policy) for r in rungs):
            rungs.append(Rung(_rung_name(i, s, p), i, s, p))
    return rungs


# ---------------------------------------------------------------------------
# incident accounting
# ---------------------------------------------------------------------------

# the incident taxonomy (DESIGN.md §14); report_lines() prints these in a
# stable order so CI logs diff cleanly
INCIDENT_KINDS = ("kernel_fault", "nonfinite", "quarantine", "requeue",
                  "corrupt_state", "straggler", "degraded")


@dataclass
class IncidentLog:
    """Counts every resilience event over a server's lifetime.  ``record``
    takes one of ``INCIDENT_KINDS`` (unknown kinds are rejected loudly —
    a typo must not silently open a new taxonomy bucket)."""
    counts: Dict[str, int] = field(default_factory=dict)

    def record(self, kind: str, detail: str = "", n: int = 1) -> None:
        if kind not in INCIDENT_KINDS:
            raise ValueError(f"unknown incident kind {kind!r} "
                             f"(taxonomy: {INCIDENT_KINDS})")
        self.counts[kind] = self.counts.get(kind, 0) + n
        if detail:
            log.warning("incident %s: %s", kind, detail)

    @property
    def total(self) -> int:
        return sum(self.counts.values())

    def summary(self) -> str:
        if not self.counts:
            return "incidents=0"
        parts = [f"{k}:{self.counts[k]}" for k in INCIDENT_KINDS
                 if k in self.counts]
        return f"incidents={self.total} ({','.join(parts)})"


# ---------------------------------------------------------------------------
# crash-safe JSON persistence (checksum + fsync + quarantine-aside)
# ---------------------------------------------------------------------------

def payload_checksum(obj: Dict[str, Any]) -> str:
    """sha256 over the canonical (sorted-key) JSON of ``obj`` minus the
    checksum field itself."""
    payload = {k: v for k, v in obj.items() if k != CHECKSUM_FIELD}
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def with_checksum(obj: Dict[str, Any]) -> Dict[str, Any]:
    return {**obj, CHECKSUM_FIELD: payload_checksum(obj)}


class CorruptStateError(ValueError):
    """A persisted state file failed schema or checksum validation."""


def verify_checksum(obj: Dict[str, Any], path: str = "<mem>") -> None:
    """Raises ``CorruptStateError`` on mismatch.  Files written before the
    checksum era (no field) pass — their integrity is vouched for only by
    JSON well-formedness, exactly as before."""
    stored = obj.get(CHECKSUM_FIELD)
    if stored is None:
        return
    actual = payload_checksum(obj)
    if stored != actual:
        raise CorruptStateError(
            f"{path}: payload checksum mismatch "
            f"(stored {stored[:12]}…, actual {actual[:12]}…)")


def atomic_json_dump(obj: Dict[str, Any], path: str, *,
                     checksum: bool = True, indent: int = 1) -> str:
    """Write ``obj`` to ``path`` crash-safely: checksum stamped into the
    payload, contents fsynced BEFORE the atomic rename (a crash between
    write and replace leaves the previous generation intact; a crash after
    replace leaves the new one — never a torn file)."""
    if checksum:
        obj = with_checksum(obj)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=indent)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    # fsync the directory so the rename itself survives a power cut
    dfd = os.open(os.path.dirname(os.path.abspath(path)) or ".", os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)
    return path


def quarantine_file(path: str) -> str:
    """Rename an unreadable state file aside as ``<path>.corrupt`` (never
    clobbering an earlier quarantined generation: ``.corrupt.1``, ...) so
    the caller can rebuild while the evidence survives for post-mortem."""
    dst = f"{path}.corrupt"
    n = 0
    while os.path.exists(dst):
        n += 1
        dst = f"{path}.corrupt.{n}"
    os.replace(path, dst)
    return dst


def load_json_guarded(path: str,
                      validate: Optional[Callable[[Dict[str, Any]], None]]
                      = None,
                      on_corrupt: Optional[Callable[[str, Exception], None]]
                      = None) -> Optional[Dict[str, Any]]:
    """Load a persisted JSON state file, or recover from its corruption.

    Returns the parsed object on success.  On ANY validation failure —
    unreadable bytes, truncated/garbage JSON, checksum mismatch, or a
    ``validate(obj)`` callback raising — the file is renamed aside via
    ``quarantine_file`` and None is returned: the caller rebuilds (replan /
    recalibrate) instead of crashing.  Missing files also return None
    (nothing to quarantine)."""
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            obj = json.load(f)
        if not isinstance(obj, dict):
            raise CorruptStateError(f"{path}: top level is not an object")
        verify_checksum(obj, path)
        if validate is not None:
            validate(obj)
        return obj
    except (json.JSONDecodeError, UnicodeDecodeError, OSError, ValueError,
            KeyError, TypeError) as e:
        dst = quarantine_file(path)
        log.warning("corrupt state file %s (%s) — renamed aside to %s; "
                    "rebuilding", path, e, dst)
        if on_corrupt is not None:
            on_corrupt(dst, e)
        return None
