"""LM serving driver: batched prefill + decode with a laid-out KV cache.

The scheduler is deliberately simple but real: a request queue, ONE static
batch per ``run`` call (all admitted requests prefill together, then decode
in lockstep — there is no continuous batching / rolling admission yet; see
ROADMAP).  The KV-cache layout is chosen by the paper-derived selector
(``perfmodel.select_kv_layout``) per run, from the ACTUAL number of
admitted requests — not the configured capacity — because the selector's
update-vs-read arbitration is batch-dependent; the decode step is jitted
once per distinct layout and reused.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ParallelConfig, get_config, reduced_config
from repro.perfmodel import select_kv_layout
from repro.distributed.sharding import named, param_specs
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.train.steps import make_decode_step, make_prefill_step

log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                # [S] int32
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)


class Server:
    def __init__(self, arch: str, *, reduced: bool = True, batch: int = 4,
                 max_len: int = 256, mesh=None, kv_layout: str = "auto"):
        cfg = get_config(arch)
        if reduced:
            cfg = reduced_config(cfg)
        self.cfg = cfg
        self.mesh = mesh or make_host_mesh(1, 1)
        self.batch = batch                 # admission capacity, not the
        self.max_len = max_len             # layout-selection batch
        self._kv_mode = kv_layout          # "auto" | forced layout
        self.kv_layout: Optional[str] = (None if kv_layout == "auto"
                                         else kv_layout)
        self.parallel = ParallelConfig(fsdp=False, seq_shard_saved=False)
        self._decode_by_layout: Dict[str, object] = {}
        with self.mesh:
            psh = named(self.mesh, param_specs(cfg, self.mesh, self.parallel))
            self.params = jax.jit(lambda k: T.init_params(k, cfg),
                                  out_shardings=psh)(jax.random.PRNGKey(0))

    def _layout_for(self, B: int) -> str:
        """KV layout for an ACTUAL batch of ``B`` requests.  The selector's
        update-waste term scales with B*K, so feeding it the configured
        capacity instead of the real batch picked the wrong layout for
        underfull batches (ISSUE 3 bugfix)."""
        if self._kv_mode != "auto":
            return self._kv_mode
        return select_kv_layout(B, self.cfg.num_kv_heads, self.max_len,
                                self.cfg.head_dim)

    def _decode_for(self, layout: str):
        """Decode step, jitted once per distinct KV layout and reused."""
        if layout not in self._decode_by_layout:
            self._decode_by_layout[layout] = jax.jit(make_decode_step(
                self.cfg, self.mesh, self.parallel, layout,
                with_cross=self.cfg.family == "encdec"))
        return self._decode_by_layout[layout]

    def _prefill_batch(self, prompts: np.ndarray, kv_layout: str):
        """prompts: [B, S0] -> (cache, first tokens, cross)."""
        cfg = self.cfg
        kw = {}
        B, S0 = prompts.shape
        if cfg.frontend == "clip_stub":
            kw["embeds"] = jnp.zeros((B, cfg.frontend_tokens, 1024),
                                     jnp.bfloat16)
        if cfg.family == "encdec":
            kw["frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                     jnp.bfloat16)
        with self.mesh:
            logits, cache, cross = T.prefill(
                self.params, jnp.asarray(prompts), cfg, max_len=self.max_len,
                kv_layout=kv_layout, **kw)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return cache, tok, cross

    def run(self, requests: List[Request], greedy: bool = True):
        """One static batch of generation; returns {rid: token list}."""
        assert len(requests) <= self.batch
        B = len(requests)
        kv_layout = self._layout_for(B)
        self.kv_layout = kv_layout         # last-used, for reporting
        decode = self._decode_for(kv_layout)
        S0 = max(len(r.prompt) for r in requests)
        prompts = np.zeros((B, S0), np.int32)
        for i, r in enumerate(requests):
            prompts[i, S0 - len(r.prompt):] = r.prompt     # left-pad
        cache, tok, cross = self._prefill_batch(prompts, kv_layout)
        front = self.cfg.frontend_tokens if self.cfg.frontend else 0
        pos = S0 + front
        max_new = max(r.max_new for r in requests)
        with self.mesh:
            for t in range(max_new):
                for i, r in enumerate(requests):
                    if t < r.max_new:
                        r.out.append(int(tok[i]))
                args = (self.params, cache, tok[:, None], jnp.int32(pos + t))
                if cross is not None:
                    logits, cache = decode(*args, cross)
                else:
                    logits, cache = decode(*args)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return {r.rid: r.out for r in requests}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=4)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    srv = Server(args.arch, reduced=True, batch=args.batch,
                 max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, srv.cfg.vocab_size, size=(8 + i,),
                                    dtype=np.int32), max_new=8)
            for i in range(args.requests)]
    t0 = time.time()
    out = srv.run(reqs)
    dt = time.time() - t0
    n_tok = sum(len(v) for v in out.values())
    print(f"kv_layout={srv.kv_layout} generated {n_tok} tokens "
          f"in {dt:.2f}s ({n_tok/dt:.1f} tok/s)")
    for rid, toks in out.items():
        print(f"  req {rid}: {toks}")


if __name__ == "__main__":
    main()
