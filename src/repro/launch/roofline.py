"""Roofline-term extraction from compiled SPMD executables.

compute   = HLO_FLOPs   / (chips * 197e12)      [s]
memory    = HLO_bytes   / (chips * 819e9)       [s]
collective= coll_bytes  / (chips * 50e9)        [s]

``cost_analysis`` reports *per-device* FLOPs/bytes post-SPMD, so the per-chip
division is already done; collective bytes are parsed from the optimized HLO
(per-device operand shapes) and likewise used per-chip.  MODEL_FLOPS uses the
6·N_active·D convention (repro.models.registry.model_flops).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

from repro.dtypes import HLO_DTYPE_BYTES as _DTYPE_BYTES
from repro.launch.mesh import HBM_BW, HBM_PER_CHIP, ICI_BW, PEAK_FLOPS_BF16

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

# a shape token: bf16[8,4096,5120]{2,1,0} or f32[] ...
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{?\[?([0-9]+),?([0-9]*)")


def _shape_bytes(shape_txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_CALL_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[a-zA-Z0-9_]+\[[0-9,]*\]\S*)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[([0-9,]+)\]<=")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        dims = [int(x) for x in m.group(1).split(",") if x]
        # iota reshape [num_groups, group_size, ...]: all but dim0 are in-group
        g = 1
        for d in dims[1:]:
            g *= d
        return max(g, 1)
    m = _LIST_GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def parse_collectives(hlo_text: str) -> Dict[str, int]:
    """Per-device ICI traffic (ring model) per collective kind, from the
    post-SPMD optimized HLO.  Result shapes are per-device; `-done` ops are
    skipped (their `-start` counterpart is counted).

    Ring traffic per device for payload/result R and group size g:
      all-reduce:       2*(g-1)/g * R     (reduce-scatter + all-gather phases)
      all-gather:       (g-1)/g   * R     (R = gathered result)
      reduce-scatter:   (g-1)     * R     (operand = g*R)
      all-to-all:       (g-1)/g   * R
      collective-permute: R
    """
    out = {k: 0 for k in _COLL_OPS}
    counts = {k: 0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        m = _CALL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        rbytes = _shape_bytes(m.group("result"))
        g = _group_size(line)
        if g <= 1:
            factor = 0.0
        elif op == "all-reduce":
            factor = 2.0 * (g - 1) / g
        elif op in ("all-gather", "all-to-all"):
            factor = (g - 1) / g
        elif op == "reduce-scatter":
            factor = float(g - 1)
        else:  # collective-permute
            factor = 1.0
        out[op] += int(rbytes * factor)
        counts[op] += 1
    out["count"] = sum(counts.values())
    out["counts"] = counts
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_breakdown: Dict[str, int]
    model_flops_total: float
    mem_args: int = 0
    mem_temp: int = 0
    mem_out: int = 0
    mem_alias: int = 0

    @property
    def compute_s(self):
        return self.flops_per_dev / PEAK_FLOPS_BF16

    @property
    def memory_s(self):
        return self.bytes_per_dev / HBM_BW

    @property
    def collective_s(self):
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def bound(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self):
        """Roofline step-time lower bound (no overlap assumption: max)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self):
        hlo_total = self.flops_per_dev * self.chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def mfu(self):
        """Model-FLOPs utilisation at the roofline bound."""
        if self.step_s <= 0:
            return 0.0
        return (self.model_flops_total / self.step_s) / \
            (self.chips * PEAK_FLOPS_BF16)

    @property
    def fits(self):
        used = self.mem_args + self.mem_temp - self.mem_alias
        return used <= HBM_PER_CHIP

    def to_json(self):
        d = asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, bound=self.bound,
                 step_s=self.step_s, useful_ratio=self.useful_ratio,
                 mfu=self.mfu, fits=self.fits,
                 bytes_per_chip=self.mem_args + self.mem_temp - self.mem_alias)
        return d


def extract_cost(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def build_roofline(arch, shape, mesh_name, chips, compiled, model_flops_total,
                   hlo_text: Optional[str] = None) -> Roofline:
    """Terms come from the trip-count-aware HLO analyzer (hlo_analysis):
    ``compiled.cost_analysis()`` counts while bodies once (verified), which
    would undercount every scanned model by the layer/microbatch/chunk trip
    counts.  The raw cost_analysis numbers are kept in coll_breakdown for
    reference."""
    from repro.launch.hlo_analysis import analyze
    txt = hlo_text if hlo_text is not None else compiled.as_text()
    cost = analyze(txt)
    raw = extract_cost(compiled)
    colls = {k: int(v) for k, v in cost.coll_by_op.items()}
    colls["count"] = parse_collectives(txt)["count"]
    colls["xla_cost_analysis_flops_untripped"] = raw["flops"]
    ma = compiled.memory_analysis()
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_dev=cost.flops, bytes_per_dev=cost.bytes,
        coll_bytes_per_dev=float(cost.coll_bytes), coll_breakdown=colls,
        model_flops_total=model_flops_total,
        mem_args=int(getattr(ma, "argument_size_in_bytes", 0)),
        mem_temp=int(getattr(ma, "temp_size_in_bytes", 0)),
        mem_out=int(getattr(ma, "output_size_in_bytes", 0)),
        mem_alias=int(getattr(ma, "alias_size_in_bytes", 0)))
