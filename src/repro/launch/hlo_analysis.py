"""Trip-count-aware HLO cost analysis (the roofline engine).

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified on this
backend: a 10-step scan of matmuls reports 1 matmul of FLOPs).  Every LM
step here is a nest of scans — layers x microbatches x chunk scans — so
FLOPs/bytes/collective-bytes would be undercounted by 1-3 orders of
magnitude.  This module parses the post-SPMD optimized HLO text and
recursively multiplies loop bodies by their trip counts:

  * trip counts come from each while's condition computation
    (compare(counter, constant(N)) pattern emitted by jax.lax.scan);
  * dot FLOPs from operand shapes + contracting dims;
  * HBM bytes: call-site operand+result sizes per instruction; fusion
    internals contribute their dots but NOT their intermediate bytes
    (fused intermediates stay on chip);
  * collective bytes via the ring model (see roofline.parse_collectives).

Shapes are per-device (post-partitioning), so results feed the per-chip
roofline directly.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"([\w\-]+)\((.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_TRIP_CFG = re.compile(r'known_trip_count[^}]*"n"\s*:\s*"?(\d+)')
_CALLED = re.compile(
    r"(?:to_apply|body|condition|true_computation|false_computation|"
    r"called_computations=\{)[=]?%?([\w.\-]+)")
_CALL_TARGETS = re.compile(r"(?:calls=|to_apply=|body=|condition=)%?([\w.\-]+)")

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")


def _shape_elems(txt: str) -> List[Tuple[str, int]]:
    out = []
    for dt, dims in _SHAPE_TOKEN.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dt, n))
    return out


def _shape_bytes(txt: str) -> int:
    return sum(n * _DTYPE_BYTES[dt] for dt, n in _shape_elems(txt))


@dataclass
class Instr:
    name: str
    result: str            # result type text
    op: str
    rest: str               # args + attributes


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # %name -> type txt


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: Dict[str, float] = field(default_factory=lambda: {
        k: 0.0 for k in _COLL_OPS})

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        for k in _COLL_OPS:
            self.coll_by_op[k] += o.coll_by_op[k]
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m, self.coll_bytes * m,
                    {k: v * m for k, v in self.coll_by_op.items()})


def parse_module(txt: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in txt.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.instrs.append(ins)
            cur.shapes[ins.name] = ins.result
    return comps


_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_CONST_CMP = re.compile(r"constant\((\d+)\)")


def _operands(ins: Instr, comp: Computation, limit=8) -> List[str]:
    """Operand type texts (resolved from the defining instrs)."""
    # operands appear before the first "), " attr boundary; cheap heuristic:
    args = ins.rest.split("), ")[0]
    names = _OPERAND_RE.findall(args)
    return [comp.shapes.get(n, "") for n in names[:limit]]


def _dims(txt: str) -> List[int]:
    m = _SHAPE_TOKEN.search(txt)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _dot_flops(ins: Instr, comp: Computation) -> float:
    ops = _operands(ins, comp, limit=2)
    if not ops:
        return 0.0
    lhs = _dims(ops[0])
    res_elems = sum(n for _, n in _shape_elems(ins.result))
    c = _CONTRACT_RE.search(ins.rest)
    k = 1
    if c and lhs:
        for d in c.group(1).split(","):
            if d and int(d) < len(lhs):
                k *= lhs[int(d)]
    return 2.0 * res_elems * k


def _trip_count(cond: Computation) -> int:
    """jax scans compare the counter to a constant; take the max constant
    used in a compare chain."""
    best = 1
    consts: Dict[str, int] = {}
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.match(r"(\d+)", ins.rest)
            if m:
                consts[ins.name] = int(m.group(1))
        if ins.op == "compare":
            for n in _OPERAND_RE.findall(ins.rest.split("), ")[0]):
                if n in consts:
                    best = max(best, consts[n])
    return max(best, 1)


_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "copy-start", "copy-done", "after-all",
               "opt-barrier", "partition-id", "replica-id",
               # dtype converts are standalone ops on XLA-CPU (no native
               # bf16 compute) but fuse into producers/consumers on TPU —
               # counting them would double every bf16 tensor's traffic
               "convert"}


class HloAnalyzer:
    def __init__(self, txt: str):
        self.comps = parse_module(txt)
        self.entry = self._find_entry(txt)
        self._memo: Dict[str, Cost] = {}

    def _find_entry(self, txt: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", txt, re.M)
        if m:
            return m.group(1)
        # fallback: computation named like main
        for name in self.comps:
            if "main" in name:
                return name
        return next(iter(self.comps))

    def cost(self) -> Cost:
        return self._cost_of(self.entry)

    def _cost_of(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = Cost()
        self._memo[name] = total      # break cycles defensively
        if comp is None:
            return total
        for ins in comp.instrs:
            total += self._instr_cost(ins, comp)
        return total

    def _fusion_bytes(self, ins: Instr, comp: Computation,
                      called: Optional[str]) -> float:
        """Call-boundary bytes of a fusion, aliasing-aware.

        A fusion whose root is a dynamic-update-slice writes IN PLACE into
        the aliased big operand: traffic is the small inputs + the updated
        slice, not the whole buffer (scan backward passes stack per-step
        states this way — counting the full buffer inflated rwkv train by
        ~60x).  A dynamic-slice-rooted fusion likewise reads only the slice.
        """
        rbytes = _shape_bytes(ins.result)
        operands = _operands(ins, comp)
        root_op = None
        if called and called in self.comps and self.comps[called].instrs:
            root_op = self.comps[called].instrs[-1].op
        if root_op == "dynamic-update-slice":
            small = sum(_shape_bytes(t) for t in operands
                        if _shape_bytes(t) < rbytes)
            return 2.0 * small
        if root_op in ("dynamic-slice", "slice", "gather"):
            return 2.0 * rbytes
        return rbytes + sum(_shape_bytes(t) for t in operands)

    def _instr_cost(self, ins: Instr, comp: Computation) -> Cost:
        op = ins.op
        c = Cost()
        if op == "while":
            body = cond = None
            mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
            mc = re.search(r"condition=%?([\w.\-]+)", ins.rest)
            if mb:
                body = mb.group(1)
            if mc:
                cond = mc.group(1)
            mt = _TRIP_CFG.search(ins.rest)
            if mt:
                trip = int(mt.group(1))
            elif cond in self.comps:
                trip = _trip_count(self.comps[cond])
            else:
                trip = 1
            if body:
                c += self._cost_of(body).scaled(trip)
            return c
        if op == "fusion":
            mt = re.search(r"calls=%?([\w.\-]+)", ins.rest)
            if mt:
                inner = self._cost_of(mt.group(1))
                # fused intermediates stay on-chip: count inner flops and
                # collectives, but bytes only at the call boundary
                c.flops += inner.flops
                c.coll_bytes += inner.coll_bytes
                for k in _COLL_OPS:
                    c.coll_by_op[k] += inner.coll_by_op[k]
            c.bytes += self._fusion_bytes(ins, comp,
                                          mt.group(1) if mt else None)
            return c
        if op in ("call", "custom-call", "conditional", "async-start"):
            for t in _CALL_TARGETS.findall(ins.rest):
                c += self._cost_of(t)
            mt = re.findall(r"called_computations=\{([^}]*)\}", ins.rest)
            for group in mt:
                for t in _OPERAND_RE.findall(group):
                    c += self._cost_of(t)
            c.bytes += _shape_bytes(ins.result)
            return c
        if op in _COLL_OPS or any(op == f"{k}-start" for k in _COLL_OPS):
            base = op.replace("-start", "")
            rbytes = _shape_bytes(ins.result)
            g = _group_size(ins.rest)
            if g <= 1:
                factor = 0.0
            elif base == "all-reduce":
                factor = 2.0 * (g - 1) / g
            elif base in ("all-gather", "all-to-all"):
                factor = (g - 1) / g
            elif base == "reduce-scatter":
                factor = float(g - 1)
            else:
                factor = 1.0
            moved = rbytes * factor
            c.coll_bytes += moved
            c.coll_by_op[base] += moved
            # collectives also read/write HBM
            c.bytes += 2 * rbytes
            return c
        if op in ("dot", "convolution"):
            c.flops += _dot_flops(ins, comp)
            c.bytes += _shape_bytes(ins.result)
            c.bytes += sum(_shape_bytes(t) for t in _operands(ins, comp))
            return c
        if op in _SKIP_BYTES:
            return c
        if op in ("dynamic-slice", "gather", "slice"):
            # reads only the sliced region (+ result write)
            c.bytes += 2 * _shape_bytes(ins.result)
            return c
        if op in ("dynamic-update-slice", "scatter"):
            # in-place on the aliased operand: read+write the update region
            ops = _operands(ins, comp)
            upd = _shape_bytes(ops[1]) if len(ops) > 1 else 0
            c.bytes += 2 * upd
            return c
        # generic op: touches operands + result once; ~1 flop/elem
        rbytes = _shape_bytes(ins.result)
        c.bytes += rbytes + sum(_shape_bytes(t) for t in _operands(ins, comp))
        c.flops += sum(n for _, n in _shape_elems(ins.result))
        return c


_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[([0-9,]+)\]<=")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _group_size(rest: str) -> int:
    m = _IOTA_GROUPS_RE.search(rest)
    if m:
        dims = [int(x) for x in m.group(1).split(",") if x]
        g = 1
        for d in dims[1:]:
            g *= d
        return max(g, 1)
    m = _LIST_GROUPS_RE.search(rest)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def analyze(hlo_text: str) -> Cost:
    return HloAnalyzer(hlo_text).cost()


def analyze_by_op(hlo_text: str) -> Dict[str, Tuple[float, float]]:
    """Trip-scaled per-op-kind (bytes, flops) attribution — the 'profile'
    view used by the perf-iteration loop.  Walks the call graph computing an
    effective execution multiplier per computation, then scales each
    computation's LEAF op costs."""
    an = HloAnalyzer(hlo_text)
    comps = an.comps
    # edges: computation -> [(child, multiplier, kind)]
    edges: Dict[str, List[Tuple[str, int, str]]] = {n: [] for n in comps}
    leaf: Dict[str, Dict[str, Cost]] = {n: {} for n in comps}
    for name, comp in comps.items():
        for ins in comp.instrs:
            if ins.op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                mt = _TRIP_CFG.search(ins.rest)
                trip = int(mt.group(1)) if mt else (
                    _trip_count(comps[mc.group(1)])
                    if mc and mc.group(1) in comps else 1)
                if mb:
                    edges[name].append((mb.group(1), trip, "while"))
                continue
            if ins.op == "fusion":
                mtg = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                if mtg:
                    edges[name].append((mtg.group(1), 1, "fusion"))
                d = leaf[name].setdefault("fusion", Cost())
                d.bytes += an._fusion_bytes(ins, comp,
                                            mtg.group(1) if mtg else None)
                continue
            if ins.op in ("call", "custom-call", "conditional", "async-start"):
                for t in _CALL_TARGETS.findall(ins.rest):
                    edges[name].append((t, 1, "call"))
                for group in re.findall(r"called_computations=\{([^}]*)\}",
                                        ins.rest):
                    for t in _OPERAND_RE.findall(group):
                        edges[name].append((t, 1, "call"))
                continue
            c = an._instr_cost(ins, comp)
            d = leaf[name].setdefault(ins.op, Cost())
            d += c
    # propagate multipliers via DFS (callees print before callers in HLO
    # text, so accumulate from the entry down the call graph); separate
    # accounting for fusion-reached comps (bytes stay on-chip there)
    mult: Dict[str, float] = {n: 0.0 for n in comps}
    mult_fused: Dict[str, float] = {n: 0.0 for n in comps}

    def visit(name: str, m: float, fused: bool, depth=0):
        if name not in comps or depth > 64 or m == 0:
            return
        if fused:
            mult_fused[name] += m
        else:
            mult[name] += m
        for child, trip, kind in edges.get(name, []):
            visit(child, m * trip, fused or kind == "fusion", depth + 1)

    visit(an.entry, 1.0, False)
    out: Dict[str, Tuple[float, float]] = {}
    for name, ops in leaf.items():
        m, mf = mult.get(name, 0.0), mult_fused.get(name, 0.0)
        if m == 0 and mf == 0:
            continue
        for op, c in ops.items():
            b, f = out.get(op, (0.0, 0.0))
            out[op] = (b + m * c.bytes, f + (m + mf) * c.flops)
    return out
