import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           # CPU-backend artifact mitigation (DESIGN.md §6):
                           # XLA-CPU's float normalization turns bf16 loop
                           # carries (stacked weights / KV caches) into f32 and
                           # WLICM hoists the converts into the while state,
                           # inflating per-chip memory 2-4x vs the TPU target
                           # (MXU reads bf16 natively; no such pass fires).
                           "--xla_disable_hlo_passes=while-loop-invariant-code-motion "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and record memory / cost / collective analysis.

The two lines above MUST stay the first statements in this module (before any
jax-importing import) — jax locks the device count on first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun [--force] [--tag baseline]
"""
import argparse
import gzip
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, SHAPES_BY_NAME, ParallelConfig,
                           TrainConfig, get_config, shapes_for)
from repro.distributed.sharding import mesh_axes
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import build_roofline
from repro.models.registry import model_flops
from repro.train.steps import make_decode_step, make_prefill_step, make_train_step

FSDP_DECODE_BYTES = 8 * 1024**3   # decode keeps params TP-only under this


def default_parallel(cfg, shape, multi_pod: bool) -> ParallelConfig:
    if shape.kind == "train":
        from repro.models.registry import param_count
        big = param_count(cfg) > 250e9
        # >=300B configs: 4 microbatches + bf16 accumulation — the f32 accum
        # tree alone (1.6 TB global) would not fit 256 chips (DESIGN.md §5)
        return ParallelConfig(fsdp=True, fsdp_pod=multi_pod,
                              seq_shard_saved=True, remat="block",
                              microbatches=4 if big else 1,
                              accum_dtype="bfloat16" if big else "float32")
    from repro.models.registry import param_count
    per_chip_tp_only = param_count(cfg) * 2 / 16
    need_fsdp = per_chip_tp_only > FSDP_DECODE_BYTES
    return ParallelConfig(fsdp=need_fsdp, fsdp_pod=multi_pod and need_fsdp,
                          seq_shard_saved=shape.kind == "prefill",
                          remat="none")


def _metrics_shardings(mesh):
    r = NamedSharding(mesh, P())
    return {"loss": r, "aux": r, "grad_norm": r, "lr": r, "total_loss": r}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               kv_layout: str = "bksd", parallel=None):
    """Build + lower + compile one cell.  Returns (compiled, lowered, meta)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    if shape not in shapes_for(cfg):
        raise SystemExit(f"SKIP: {arch} does not run {shape_name} "
                         f"(full attention; see DESIGN.md)")
    parallel = parallel or default_parallel(cfg, shape, multi_pod)
    tc = TrainConfig()

    params_abs, opt_abs = S.abstract_train_state(cfg)
    psh, osh = S.train_state_shardings(cfg, mesh, parallel)

    with mesh:
        if shape.kind == "train":
            step = make_train_step(cfg, mesh, parallel, tc)
            batch = S.batch_struct(cfg, shape)
            bsh = S.batch_shardings(cfg, shape, mesh)
            jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                             out_shardings=(psh, osh, _metrics_shardings(mesh)),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, batch)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, mesh, parallel, shape, kv_layout)
            batch = S.batch_struct(cfg, shape)
            bsh = S.batch_shardings(cfg, shape, mesh)
            dec_structs, dec_sh = S.decode_inputs(
                cfg, shape, mesh, kv_layout,
                kv_window=parallel.window_kv_cache)
            dp, tp, _ = mesh_axes(mesh)
            dp_size = int(np.prod([mesh.shape[a] for a in dp]))
            bdim = dp if shape.global_batch % dp_size == 0 and \
                shape.global_batch >= dp_size else None
            logits_sh = NamedSharding(mesh, P(bdim, None))
            outs = (logits_sh, dec_sh["cache"])
            if cfg.family == "encdec":
                outs = outs + (dec_sh["cross"],)
            jitted = jax.jit(step, in_shardings=(psh, bsh),
                             out_shardings=outs)
            lowered = jitted.lower(params_abs, batch)
        else:  # decode
            dec_structs, dec_sh = S.decode_inputs(
                cfg, shape, mesh, kv_layout,
                kv_window=parallel.window_kv_cache)
            with_cross = cfg.family == "encdec"
            step = make_decode_step(cfg, mesh, parallel, kv_layout,
                                    with_cross=with_cross)
            dp, tp, _ = mesh_axes(mesh)
            dp_size = int(np.prod([mesh.shape[a] for a in dp]))
            bdim = dp if shape.global_batch % dp_size == 0 and \
                shape.global_batch >= dp_size else None
            logits_sh = NamedSharding(mesh, P(bdim, None))
            in_sh = [psh, dec_sh["cache"], dec_sh["token"],
                     dec_sh["cache_len"]]
            args = [params_abs, dec_structs["cache"], dec_structs["token"],
                    dec_structs["cache_len"]]
            if with_cross:
                in_sh.append(dec_sh["cross"])
                args.append(dec_structs["cross"])
            jitted = jax.jit(step, in_shardings=tuple(in_sh),
                             out_shardings=(logits_sh, dec_sh["cache"]),
                             donate_argnums=(1,))
            lowered = jitted.lower(*args)
        compiled = lowered.compile()
    meta = {"chips": mesh.size, "mesh": "2x16x16" if multi_pod else "16x16",
            "parallel": parallel.__dict__ if hasattr(parallel, "__dict__")
            else str(parallel)}
    return compiled, lowered, meta, cfg, shape


def run_cell(arch, shape_name, multi_pod, out_dir: Path, force=False,
             tag="baseline", kv_layout="bksd", save_hlo=False, parallel=None):
    mesh_name = "multi" if multi_pod else "single"
    out = out_dir / mesh_name / f"{arch}__{shape_name}__{tag}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    if out.exists() and not force:
        d = json.loads(out.read_text())
        status = "cached" if "error" not in d else "cached-error"
        print(f"[{mesh_name}] {arch} x {shape_name}: {status}")
        return "error" not in d

    t0 = time.time()
    try:
        compiled, lowered, meta, cfg, shape = lower_cell(
            arch, shape_name, multi_pod, kv_layout, parallel)
        hlo = compiled.as_text()
        rf = build_roofline(arch, shape_name, meta["mesh"], meta["chips"],
                            compiled, model_flops(cfg, shape), hlo_text=hlo)
        d = rf.to_json()
        d.update(meta, tag=tag, kv_layout=kv_layout,
                 compile_s=time.time() - t0,
                 memory_analysis=str(compiled.memory_analysis()))
        out.write_text(json.dumps(d, indent=1, default=str))
        if save_hlo:
            with gzip.open(str(out).replace(".json", ".hlo.gz"), "wt") as f:
                f.write(hlo)
        print(f"[{mesh_name}] {arch} x {shape_name}: OK "
              f"compute={rf.compute_s*1e3:.1f}ms mem={rf.memory_s*1e3:.1f}ms "
              f"coll={rf.collective_s*1e3:.1f}ms bound={rf.bound} "
              f"fits={rf.fits} bytes/chip={(d['bytes_per_chip'])/2**30:.2f}GiB "
              f"({d['compile_s']:.0f}s)")
        return True
    except SystemExit as e:
        print(str(e))
        return True
    except Exception as e:
        err = traceback.format_exc()
        out.write_text(json.dumps(
            {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
             "error": str(e)[-2000:], "traceback": err[-4000:],
             "compile_s": time.time() - t0}, indent=1))
        print(f"[{mesh_name}] {arch} x {shape_name}: FAIL {str(e)[:160]}")
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--kv-layout", default="bksd", choices=["bksd", "sbkd"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    out_dir = Path(args.out)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_fail = 0
    for multi_pod in meshes:
        for arch in archs:
            cfg = get_config(arch)
            run_shapes = ([s.name for s in shapes_for(cfg)]
                          if args.shape == "all" else args.shape.split(","))
            for shape_name in run_shapes:
                if SHAPES_BY_NAME[shape_name] not in shapes_for(cfg):
                    print(f"skip {arch} x {shape_name} (inapplicable)")
                    continue
                ok = run_cell(arch, shape_name, multi_pod, out_dir,
                              force=args.force, tag=args.tag,
                              kv_layout=args.kv_layout,
                              save_hlo=args.save_hlo)
                n_ok += ok
                n_fail += (not ok)
    print(f"\nDRY-RUN SUMMARY: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
