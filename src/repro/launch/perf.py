import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           "--xla_disable_hlo_passes=while-loop-invariant-code-motion "
                           + os.environ.get("XLA_FLAGS", ""))

"""§Perf hillclimb driver: lowers optimized variants of the three selected
cells and records them next to the baselines (tag-suffixed JSONs).

Cells (selection criteria per the methodology):
  1. rwkv6_7b x train_4k       — worst roofline fraction (MFU 0.004,
     memory-bound by the sequential WKV state round trips);
  2. llama4_maverick_400b x train_4k — most collective-bound
     (expert-weight gathers re-executed under remat);
  3. gemma2_27b x decode_32k   — most representative of the paper's
     technique (per-layer heterogeneity: local layers want a window-sized
     ring cache; plus KV layout selection).

Usage: PYTHONPATH=src python -m repro.launch.perf [--exp all]
"""
import argparse
import json
from pathlib import Path

from repro.configs import ParallelConfig
from repro.configs import registry as REG
from repro.launch import dryrun as DR

OUT = Path("results/dryrun")


def run_variant(arch, shape, tag, multi_pod=False, cfg_patch=None,
                parallel=None, kv_layout="bksd"):
    """Lower one optimized variant; returns the recorded dict."""
    orig = REG.get_config
    if cfg_patch:
        base = orig(arch)
        patched = base.replace(**cfg_patch)

        def get_config(a):
            return patched if a == arch else orig(a)
        REG.get_config = get_config
        import repro.configs as C
        C.get_config = get_config
        DR.get_config = get_config
    try:
        ok = DR.run_cell(arch, shape, multi_pod, OUT, force=True, tag=tag,
                         kv_layout=kv_layout, save_hlo=True,
                         parallel=parallel)
    finally:
        if cfg_patch:
            REG.get_config = orig
            import repro.configs as C
            C.get_config = orig
            DR.get_config = orig
    mesh = "multi" if multi_pod else "single"
    return json.loads((OUT / mesh / f"{arch}__{shape}__{tag}.json").read_text())


def show(name, d):
    if "error" in d:
        print(f"{name}: ERROR {d['error'][:200]}")
        return
    print(f"{name}: bound={d['bound']} compute={d['compute_s']*1e3:.1f}ms "
          f"mem={d['memory_s']*1e3:.1f}ms coll={d['collective_s']*1e3:.1f}ms "
          f"mfu={d['mfu']:.4f} GiB={d['bytes_per_chip']/2**30:.2f} "
          f"fits={d['fits']}")


def exp_rwkv():
    # iteration 1: chunk-parallel WKV, chunk=128
    d = run_variant("rwkv6_7b", "train_4k", "opt_wkvchunk128",
                    cfg_patch={"rwkv_chunked": True})
    show("rwkv chunked c=128", d)
    # iteration 2: bigger chunks (more MXU work per state round trip)
    # chunk size is set inside rwkv_time_fwd default; sweep via env is
    # overkill — vary via cfg? chunk param is a fn default; emulate by
    # patching the module constant.
    import repro.models.rwkv as R
    orig_fwd = R.rwkv_time_fwd

    def fwd256(p, x, cfg, *, chunk=256, **kw):
        return orig_fwd(p, x, cfg, chunk=256, **kw)
    R.rwkv_time_fwd = fwd256
    import repro.models.transformer as T
    T.R.rwkv_time_fwd = fwd256
    try:
        d = run_variant("rwkv6_7b", "train_4k", "opt_wkvchunk256",
                        cfg_patch={"rwkv_chunked": True})
    finally:
        R.rwkv_time_fwd = orig_fwd
        T.R.rwkv_time_fwd = orig_fwd
    show("rwkv chunked c=256", d)


def exp_llama4():
    base_par = DR.default_parallel(REG.get_config("llama4_maverick_400b"),
                                   type("S", (), {"kind": "train"})(), False)
    # iteration 1: save MoE outputs in remat (skip re-running expert
    # gathers + a2a in the backward)
    par = ParallelConfig(fsdp=True, fsdp_pod=False, seq_shard_saved=True,
                         remat="block", remat_policy="save_moe",
                         microbatches=4, accum_dtype="bfloat16")
    d = run_variant("llama4_maverick_400b", "train_4k", "opt_savemoe",
                    parallel=par)
    show("llama4 save_moe", d)
    # iteration 2 (multi-pod): + bf16 gradient compression on the pod hop
    par2 = ParallelConfig(fsdp=True, fsdp_pod=True, seq_shard_saved=True,
                          remat="block", remat_policy="save_moe",
                          microbatches=4, accum_dtype="bfloat16",
                          grad_compression="bf16")
    d = run_variant("llama4_maverick_400b", "train_4k", "opt_savemoe_bf16comp",
                    multi_pod=True, parallel=par2)
    show("llama4 multi save_moe+bf16comp", d)


def exp_gemma2():
    # iteration 1: window-limited ring cache for local layers
    par = ParallelConfig(fsdp=False, seq_shard_saved=False, remat="none",
                         window_kv_cache=True)
    d = run_variant("gemma2_27b", "decode_32k", "opt_windowkv", parallel=par)
    show("gemma2 window kv", d)
    # iteration 2: + sbkd layout (paper layout selection: update-friendly)
    d = run_variant("gemma2_27b", "decode_32k", "opt_windowkv_sbkd",
                    parallel=par, kv_layout="sbkd")
    show("gemma2 window kv + sbkd", d)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default="all",
                    choices=["all", "rwkv", "llama4", "gemma2"])
    args = ap.parse_args()
    if args.exp in ("all", "rwkv"):
        exp_rwkv()
    if args.exp in ("all", "llama4"):
        exp_llama4()
    if args.exp in ("all", "gemma2"):
        exp_gemma2()


if __name__ == "__main__":
    main()
