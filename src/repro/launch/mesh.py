"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The single-pod mesh is
(data=16, model=16) = 256 chips of a v5e pod; multi-pod adds a leading
pod axis: (pod=2, data=16, model=16) = 512 chips.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax < 0.6 has no jax.sharding.AxisType; Auto is the default there, so
    # passing nothing is equivalent
    if hasattr(jax.sharding, "AxisType"):
        axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=axis_types)
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh over however many (fake) host devices exist — for tests."""
    if pod:
        shape, axes = (pod, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    return _make_mesh(shape, axes)


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~ per chip hop)
HBM_PER_CHIP = 16 * 1024**3     # 16 GiB
