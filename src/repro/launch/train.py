"""End-to-end training driver.

Usage (CPU example, 4 fake host devices):
  XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \\
    python -m repro.launch.train --arch qwen2_7b --reduced --steps 50 \\
    --batch 8 --seq 128 --mesh-data 2 --mesh-model 2

On a real cluster the same driver runs under ``jax.distributed.initialize``
with the production mesh (launch/mesh.py) — everything else is identical.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import (ParallelConfig, ShapeConfig, TrainConfig,
                           get_config, reduced_config)
from repro.data.pipeline import DataConfig, TokenStream, device_put_batch
from repro.distributed.sharding import param_specs, named
from repro.launch import specs as S
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as T
from repro.optim import adamw
from repro.runtime.fault_tolerance import FaultTolerantRunner, StragglerWatchdog
from repro.train.steps import make_train_step

log = logging.getLogger("repro.train")


def build(arch: str, *, reduced: bool, steps: int, batch: int, seq: int,
          mesh=None, parallel: ParallelConfig = None,
          tc: TrainConfig = None, data: DataConfig = None):
    cfg = get_config(arch)
    if reduced:
        cfg = reduced_config(cfg)
    shape = ShapeConfig("custom", "train", seq, batch)
    mesh = mesh or make_host_mesh(1, 1)
    parallel = parallel or ParallelConfig(
        fsdp=mesh.shape.get("data", 1) > 1,
        seq_shard_saved=mesh.shape.get("model", 1) > 1)
    tc = tc or TrainConfig(total_steps=steps)
    return cfg, shape, mesh, parallel, tc


def train(arch: str = "qwen2_7b", reduced: bool = True, steps: int = 20,
          batch: int = 8, seq: int = 128, mesh=None,
          checkpoint_dir: str = "/tmp/repro_ckpt", resume: bool = True,
          log_every: int = 10, parallel=None, inject_failure_at: int = -1):
    cfg, shape, mesh, parallel, tc = build(
        arch, reduced=reduced, steps=steps, batch=batch, seq=seq, mesh=mesh,
        parallel=parallel)
    tc = TrainConfig(total_steps=steps, checkpoint_dir=checkpoint_dir)

    pspecs = param_specs(cfg, mesh, parallel)
    psh = named(mesh, pspecs)
    osh = named(mesh, adamw.state_specs(pspecs))
    stream = TokenStream(cfg, shape)

    with mesh:
        params = jax.jit(lambda k: T.init_params(k, cfg),
                         out_shardings=psh)(jax.random.PRNGKey(tc.seed))
        opt = adamw.init(params, jnp.dtype(cfg.opt_state_dtype))
        step_fn_raw = make_train_step(cfg, mesh, parallel, tc)
        jitted = jax.jit(step_fn_raw, donate_argnums=(0, 1))

        ckpt = Checkpointer(checkpoint_dir)
        runner = FaultTolerantRunner(ckpt, save_every=max(1, tc.checkpoint_every
                                                          if steps > tc.checkpoint_every
                                                          else steps // 2 or 1))
        start = 0
        state = {"params": params, "opt": opt}
        if resume and ckpt.latest_step() is not None:
            abstract = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            start, state = ckpt.restore(abstract)
            log.info("resumed from step %d", start)

        losses = []
        injected = []

        def one_step(state, step):
            if step == inject_failure_at and not injected:
                injected.append(step)      # fail exactly once
                raise RuntimeError("injected failure (test)")
            batch_np = stream.batch_at(step)
            bt = device_put_batch(
                {k: v for k, v in batch_np.items()},
                None)
            bt = {k: (v.astype(jnp.bfloat16)
                      if k in ("embeds", "frames") else v)
                  for k, v in bt.items()}
            new_params, new_opt, metrics = jitted(state["params"],
                                                  state["opt"], bt)
            losses.append(float(metrics["loss"]))
            return {"params": new_params, "opt": new_opt}, metrics

        def on_metrics(step, metrics):
            if step % log_every == 0:
                log.info("step %d loss=%.4f gnorm=%.3f lr=%.2e", step,
                         float(metrics["loss"]), float(metrics["grad_norm"]),
                         float(metrics["lr"]))

        end_step, state = runner.run(state, one_step, steps, start_step=start,
                                     on_metrics=on_metrics)
    return {"losses": losses, "state": state, "steps": end_step,
            "stragglers": runner.watchdog.flagged}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    out = train(args.arch, reduced=args.reduced, steps=args.steps,
                batch=args.batch, seq=args.seq, mesh=mesh,
                checkpoint_dir=args.checkpoint_dir,
                resume=not args.no_resume)
    print(f"final loss: {out['losses'][-1]:.4f} (first {out['losses'][0]:.4f})")


if __name__ == "__main__":
    main()
