"""CNN request-serving driver: batch-adaptive fused inference (DESIGN.md §7).

The CNN twin of ``launch.serve``'s queue shape: requests (single images)
arrive in a queue, the admission loop drains up to ``max_bucket`` of them
per step, rounds the batch up to its pow-2 bucket, pads, and executes ONE
fused ``forward_fused`` batch under the bucket's cached plan.  Planning and
threshold calibration are both one-time costs paid per bucket / per
process, never per request:

  * layouts come from the ``PlanCache`` (replans only on first sight of a
    bucket — the paper's Nt threshold makes the plan batch-dependent);
  * thresholds come from ``measured_thresholds`` (real Pallas kernel
    timings, persisted), not the analytic sweep.

``--dtype bf16`` serves the mixed-precision fast path (DESIGN.md §8):
params and admission are cast to the storage dtype, kernels accumulate in
f32, and plans/thresholds come from the dtype's own cache rows — halving
every tensor's HBM footprint and shifting the layout crossovers.

``--dtype-policy mixed`` (DESIGN.md §9) goes further: the planner searches
per-layer (layout, storage dtype) states, so interior conv chains store
their activations as int8 (quantize folded into the producing kernel's
epilogue, per-channel dequant folded into the consumer conv's weights)
while the host input, the first conv chain, and the classifier head stay at
the base ``--dtype``.  Plans are cached under their own ``policy`` key, and
the int8 calibration row is measured alongside the base row.

Execution is GUARDED (DESIGN.md §14): every batch runs under a degradation
ladder — pallas+stacks → pallas stacks-off → mixed→uniform dtype →
decomposed XLA — with a cheap finite-check folded into the jitted forward.
A kernel exception or non-finite batch quarantines that (bucket, policy,
stack) plan variant and retries the next rung after exponential backoff;
subsequent batches of the bucket skip straight to the known-good rung
(their fallback plan is a PlanCache key, never an ad-hoc replan).  If every
rung fails, the in-flight requests return to the FRONT of the queue in
their original order — a failed step loses zero requests.  ``--inject``
drives the deterministic fault harness (``runtime.resilience``) for smoke
tests; every incident is counted and surfaced in the report.

The report shows per-bucket plan-cache hit rates, the plan's conv layouts
and storage dtypes, modeled HBM bytes, images/s, the serving rung, and the
incident/quarantine/straggler totals.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import os
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CNNConfig
from repro.configs.cnn_networks import (CNN_BUILDERS, CNN_CONFIGS,
                                        reduced_cnn)
from repro.cnn.layers import init_cnn
from repro.cnn.network import batch_output_ok, forward_fused, input_shape
from repro.distributed.cnn_mesh import (cnn_data_mesh, forward_fused_sharded,
                                        replicate_params)
from repro.dtypes import canon_dtype, dtype_bytes, jnp_dtype
from repro.perfmodel import Thresholds, calibrate, hardware_id
from repro.runtime.fault_tolerance import StragglerWatchdog
from repro.runtime.resilience import (FaultInjector, IncidentLog,
                                      InjectedKernelFault, Rung,
                                      ServingFault, degradation_ladder,
                                      parse_inject_spec)
from repro.serve import PlanCache, measured_thresholds, pad_to_bucket

log = logging.getLogger("repro.cnn_serve")


class NonFiniteOutput(RuntimeError):
    """The batch output failed the cheap finite check (``batch_output_ok``)."""


@dataclasses.dataclass
class ImageRequest:
    rid: int
    image: np.ndarray                  # [C, H, W] float32
    probs: Optional[np.ndarray] = None # filled by the server


@dataclasses.dataclass
class BucketReport:
    bucket: int                        # PER-SHARD bucket (§15)
    batches: int = 0
    images: int = 0
    padded: int = 0                    # pad rows executed (bucket waste)
    hits: int = 0
    misses: int = 0
    hbm_bytes: int = 0                 # modeled GLOBAL bytes, summed/batch
    per_chip_bytes: int = 0            # modeled per-chip bytes, summed (§15)
    seconds: float = 0.0
    degraded: int = 0                  # batches served below the top rung
    failures: int = 0                  # rung attempts that failed (§14)
    rung: str = ""                     # rung that served the LAST batch

    @property
    def hit_rate(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0


@dataclasses.dataclass
class _GuardResult:
    """One guarded batch execution: where it landed and what it cost."""
    bucket: int
    rung: Rung
    rung_index: int
    probs: np.ndarray
    seconds: float
    hit: bool                          # plan-cache hit for the serving rung


class CNNServer:
    """Queue-draining batch-adaptive server over the fused CNN engine.

    ``thresholds``, when supplied, is filed as THIS server's dtype row —
    the caller must have swept it at the matching element size
    (``calibrate(dtype_bytes=4)`` for an fp32 server; bare ``calibrate()``
    sweeps at the 2-byte paper-fidelity default).

    ``injector`` enables the deterministic fault harness (§14);
    ``backoff_s`` seeds the exponential backoff between rung retries (0 in
    tests); ``max_step_failures`` bounds how many times ``run`` retries a
    fully-failed step before giving up (requests survive regardless —
    they are re-queued before the failure propagates).

    ``devices`` > 1 (DESIGN.md §15) serves over a data-parallel mesh: the
    admitted batch is split batch-dim across the first ``devices`` jax
    devices via ``shard_map``, params are replicated, and every shard
    executes ONE cached plan — planned, bucketed, and quarantined at the
    PER-SHARD batch (``max_bucket`` bounds the shard bucket; admission
    drains up to ``max_bucket * devices`` requests per step).  The §14
    ladder, incident counters, and re-queue semantics operate on the whole
    shard-group batch, unchanged."""

    def __init__(self, network: str = "lenet", *, reduced: bool = True,
                 max_bucket: int = 64, impl: str = "xla",
                 interpret: bool = True, cache_path: Optional[str] = None,
                 calibration: str = "measured",
                 thresholds: Optional[Thresholds] = None,
                 calib_path: Optional[str] = None,
                 dtype: str = "float32",
                 dtype_policy: str = "uniform",
                 max_plans: Optional[int] = None,
                 injector: Optional[FaultInjector] = None,
                 backoff_s: float = 0.0,
                 max_step_failures: int = 8,
                 devices: int = 1):
        cfg = CNN_CONFIGS[network]
        if reduced and cfg.image_hw > 96:
            # branching nets re-derive skip edges (and the gap-pool window)
            # through their builder; a bare replace() would zero out the
            # global pool at the reduced size
            if cfg.name in CNN_BUILDERS:
                cfg = reduced_cnn(cfg, batch=cfg.batch)
            else:
                cfg = cfg.replace(image_hw=96)
        self.cfg = cfg
        self.impl = impl
        self.interpret = interpret
        self.dtype = canon_dtype(dtype)
        if dtype_policy not in ("uniform", "mixed"):
            raise ValueError(f"unknown dtype policy {dtype_policy!r}")
        self.dtype_policy = dtype_policy
        self._jdtype = jnp_dtype(self.dtype)
        if devices < 1:
            raise ValueError(f"devices must be >= 1, got {devices}")
        self.devices = devices
        # the §15 serving mesh: 1-D data-parallel over the first `devices`
        # jax devices; devices == 1 keeps the single-chip path bit-identical
        self._mesh = cnn_data_mesh(devices) if devices > 1 else None
        self.injector = injector
        self.backoff_s = backoff_s
        self.max_step_failures = max_step_failures
        self.incidents = IncidentLog()
        # the §14 degradation ladder, built from this server's operating
        # point; rung 0 is normal service
        self.ladder = degradation_ladder(impl, dtype_policy)
        # quarantined (bucket, policy, stack, impl) plan variants: a rung
        # that failed for a bucket is skipped by later batches, which start
        # straight at the known-good rung.  The PLAN stays cached — only
        # its use is suspended, so lifting a quarantine costs no replan.
        self._quarantine: set = set()
        # threshold rows are versioned by hardware id (DESIGN.md §13): a
        # cache file carried to a different accelerator keeps its old rows
        # under their id and measures fresh rows for this one
        self._hw = hardware_id(interpret)
        # build the cache first: a persisted cache already carries the
        # per-dtype threshold rows it was planned under, so calibration (the
        # ~4 s measured sweep) only runs when neither the caller nor the
        # cache has this dtype's row.  A corrupt cache file was renamed
        # aside inside load (§14) — count it, don't crash.
        self.cache = PlanCache(
            path=cache_path,
            thresholds=(None if thresholds is None
                        else {self.dtype: thresholds}),
            max_bucket=max_bucket, max_entries=max_plans)
        for dst in self.cache.corrupt_recoveries:
            self.incidents.record("corrupt_state",
                                  f"plan cache quarantined to {dst}")
        # mixed policy also measures the 1-byte row (ISSUE 5): the per-dtype
        # threshold contract covers every storage dtype the server's plans
        # use, and the sweep is one-time per cache dir (persisted) — ~4 s of
        # interpret-mode timing, never paid again on restart
        need_rows = [self.dtype]
        if self.dtype_policy == "mixed":
            need_rows.append("int8")
        if calib_path is None and cache_path:
            calib_path = os.path.join(os.path.dirname(cache_path),
                                      "thresholds.json")
        for row in need_rows:
            if self.cache.thresholds_for(row, self._hw) is not None:
                continue
            if calibration == "measured":
                self.cache.set_thresholds(
                    measured_thresholds(
                        calib_path, dtype=row, interpret=interpret,
                        hardware=self._hw,
                        on_corrupt=lambda dst, e: self.incidents.record(
                            "corrupt_state",
                            f"threshold table quarantined to {dst}")),
                    row, hardware=self._hw)
            else:
                self.cache.set_thresholds(
                    calibrate(dtype_bytes=dtype_bytes(row)), row,
                    hardware=self._hw)
        self.params = init_cnn(jax.random.PRNGKey(0), cfg,
                               dtype=self._jdtype)
        if self._mesh is not None:     # replicate once, serve forever
            self.params = replicate_params(self.params, self._mesh)
        self.queue: Deque[ImageRequest] = deque()
        self.reports: Dict[int, BucketReport] = {}
        self._fwd = {}                 # (bucket, rung.name) -> jitted fwd
        self._plan_stats = {}          # (bucket, rung.name) -> modeled bytes
        self._watchdogs: Dict[int, StragglerWatchdog] = {}

    # -- admission -----------------------------------------------------------

    def submit(self, req: ImageRequest) -> None:
        c, h = self.cfg.in_channels, self.cfg.image_hw
        if req.image.shape != (c, h, h):
            raise ValueError(
                f"request {req.rid}: image shape {req.image.shape} != "
                f"{(c, h, h)}")
        self.queue.append(req)

    def _modeled_bytes(self, bcfg: CNNConfig, plan) -> int:
        """Shape-only HBM accounting for one bucket batch (eval_shape —
        never executes)."""
        box = {}

        def f(p, x):
            y, st = forward_fused(p, x, bcfg, plan, impl="xla")
            box["st"] = st
            return y

        aparams = jax.eval_shape(lambda k: init_cnn(k, bcfg,
                                                    dtype=self._jdtype),
                                 jax.random.PRNGKey(0))
        jax.eval_shape(f, aparams,
                       jax.ShapeDtypeStruct(input_shape(bcfg), self._jdtype))
        return box["st"].hbm_bytes

    def _forward_for(self, bucket: int, rung: Optional[Rung] = None):
        """Jitted forward for (shard bucket, rung) — rung defaults to the
        top of the ladder.  The rung's plan is the PlanCache's own plan for
        that (policy, stack, devices) variant; the jitted function also
        returns the §14 finite-check scalar so the guard costs no extra
        device round trip.  Under a mesh (§15) the forward is the sharded
        executor: every shard runs the ONE per-shard-bucket plan, so this
        compiles once per (bucket, rung) across all shards."""
        rung = rung or self.ladder[0]
        key = (bucket, rung.name)
        if key not in self._fwd:
            bcfg = self.cfg.replace(batch=bucket)   # the SHARD config
            # step() already planned this bucket; peek keeps stats honest.
            # `bucket` is the PER-SHARD bucket, so pre_sharded=True — the
            # default path would divide by devices a second time and
            # resolve (then plan) a bogus bucket/devices key
            plan = self.cache.peek_fused(self.cfg, bucket, dtype=self.dtype,
                                         policy=rung.policy,
                                         stack=rung.stack,
                                         devices=self.devices,
                                         pre_sharded=True)
            if plan is None:
                plan, _, _ = self.cache.fused_plan(self.cfg, bucket,
                                                   dtype=self.dtype,
                                                   policy=rung.policy,
                                                   stack=rung.stack,
                                                   devices=self.devices,
                                                   pre_sharded=True)
            # _modeled_bytes at the shard config IS the per-chip traffic
            self._plan_stats[key] = self._modeled_bytes(bcfg, plan)
            impl, interp, mesh = rung.impl, self.interpret, self._mesh

            @jax.jit
            def fwd(params, x):
                if mesh is None:
                    y, _ = forward_fused(params, x, bcfg, plan, impl=impl,
                                         interpret=interp)
                else:
                    y = forward_fused_sharded(params, x, bcfg, plan, mesh,
                                              impl=impl, interpret=interp)
                return y, batch_output_ok(y)

            self._fwd[key] = fwd
        return self._fwd[key]

    # -- guarded execution (§14) ---------------------------------------------

    def _qkey(self, bucket: int, rung: Rung) -> Tuple[int, str, str, str]:
        """Quarantine key: the (bucket, policy, stack) plan variant plus the
        engine executing it (rungs 2 and 3 share a plan but not an impl)."""
        return (bucket, rung.policy, rung.stack, rung.impl)

    def _shard_bucket(self, B: int) -> int:
        """The per-shard bucket an admitted global batch of ``B`` lands in
        (== the plain bucket when devices == 1)."""
        return self.cache.bucket(-(-B // self.devices))

    def _run_guarded(self, x_np: np.ndarray, B: int) -> _GuardResult:
        """Run one admitted batch down the degradation ladder.  Raises
        ``ServingFault`` only when EVERY rung failed; the caller re-queues
        the batch before propagating."""
        bucket = self._shard_bucket(B)
        # skip straight to the first non-quarantined rung; the terminal
        # rung is always eligible (a fully-quarantined bucket still serves)
        start = next((i for i, r in enumerate(self.ladder)
                      if self._qkey(bucket, r) not in self._quarantine),
                     len(self.ladder) - 1)
        delay = self.backoff_s
        errors: List[str] = []
        for i in range(start, len(self.ladder)):
            rung = self.ladder[i]
            quals = (rung.name, rung.policy, rung.impl)
            t0 = time.perf_counter()
            try:
                if self.injector is not None:
                    self.injector.maybe_slow(quals)
                    self.injector.maybe_kernel_fault(quals)
                _, _, hit = self.cache.fused_plan(self.cfg, B,
                                                  dtype=self.dtype,
                                                  policy=rung.policy,
                                                  stack=rung.stack,
                                                  devices=self.devices)
                fwd = self._forward_for(bucket, rung)
                xb = jnp.asarray(x_np).astype(self._jdtype)
                # global pad: every shard gets exactly `bucket` rows
                y, ok = fwd(self.params,
                            pad_to_bucket(xb, bucket * self.devices))
                y = jax.block_until_ready(y)
                probs = np.asarray(y.astype(jnp.float32))
                if self.injector is not None:
                    probs = self.injector.maybe_poison(probs, quals)
                if not (bool(ok) and np.isfinite(probs[:B]).all()):
                    raise NonFiniteOutput(
                        f"non-finite batch output (bucket={bucket}, "
                        f"rung={rung.name})")
                return _GuardResult(bucket, rung, i, probs,
                                    time.perf_counter() - t0, hit)
            except Exception as e:     # noqa: BLE001 — the guard IS the
                # handler: any execution failure steps down the ladder
                kind = ("nonfinite" if isinstance(e, NonFiniteOutput)
                        else "kernel_fault")
                self.incidents.record(
                    kind, f"bucket={bucket} rung={rung.name}: {e}")
                rep = self.reports.setdefault(bucket, BucketReport(bucket))
                rep.failures += 1
                qk = self._qkey(bucket, rung)
                if qk not in self._quarantine:
                    self._quarantine.add(qk)
                    self.incidents.record(
                        "quarantine",
                        f"bucket={bucket} variant=({rung.policy},"
                        f"{rung.stack},{rung.impl})")
                errors.append(f"{rung.name}: {type(e).__name__}: {e}")
                if i + 1 < len(self.ladder) and delay > 0.0:
                    time.sleep(min(delay, 2.0))
                    delay *= 2.0       # exponential backoff down the chain
        raise ServingFault(
            f"all rungs failed for bucket {bucket}: {'; '.join(errors)}")

    # -- serving loop --------------------------------------------------------

    def step(self) -> List[ImageRequest]:
        """Drain up to ``max_bucket`` queued requests as one fused batch.

        Failure semantics (§14): the admitted batch either completes on
        some rung of the ladder, or returns to the FRONT of the queue in
        its original order before ``ServingFault`` propagates — a failed
        step loses zero requests."""
        if not self.queue:
            return []
        cap = self.cache.max_bucket * self.devices
        batch = [self.queue.popleft()
                 for _ in range(min(len(self.queue), cap))]
        B = len(batch)
        x_np = np.stack([r.image for r in batch])
        try:
            res = self._run_guarded(x_np, B)
        except Exception:
            self.queue.extendleft(reversed(batch))
            self.incidents.record(
                "requeue", f"{B} in-flight requests re-queued (front, "
                f"original order)")
            raise
        rep = self.reports.setdefault(res.bucket, BucketReport(res.bucket))
        rep.hits += int(res.hit)
        rep.misses += int(not res.hit)
        for i, r in enumerate(batch):
            r.probs = res.probs[i]
        rep.batches += 1
        rep.images += B
        rep.padded += res.bucket * self.devices - B
        per_chip = self._plan_stats[(res.bucket, res.rung.name)]
        rep.per_chip_bytes += per_chip
        rep.hbm_bytes += per_chip * self.devices
        rep.seconds += res.seconds
        rep.rung = res.rung.name
        if res.rung_index > 0:
            rep.degraded += 1
            self.incidents.record("degraded")
        # §14 satellite: serving and training share one anomaly detector —
        # per-batch wall time feeds the bucket's StragglerWatchdog; a
        # flagged bucket is an incident and a report line, the response
        # (swap/recalibration) stays a logged callback hook
        wd = self._watchdogs.setdefault(
            res.bucket, StragglerWatchdog(
                on_straggler=lambda step, dt, mean: log.warning(
                    "serving straggler: bucket=%d step=%d %.3fs (mean "
                    "%.3fs)", res.bucket, step, dt, mean)))
        if wd.observe(rep.batches, res.seconds):
            self.incidents.record("straggler",
                                  f"bucket={res.bucket} {res.seconds:.3f}s")
        return batch

    def run(self, requests: List[ImageRequest]) -> Dict[int, np.ndarray]:
        """Serve ``requests`` to completion.  A fully-failed step re-queues
        its batch and is retried (the quarantine makes the retry start at
        the next rung), bounded by ``max_step_failures`` consecutive
        failures — within the bound, every submitted request is served."""
        for r in requests:
            self.submit(r)
        done: Dict[int, np.ndarray] = {}
        failures = 0
        while self.queue:
            try:
                served = self.step()
            except ServingFault:
                failures += 1
                if failures > self.max_step_failures:
                    raise
                continue
            failures = 0
            for r in served:
                done[r.rid] = r.probs
        if self.cache.path:
            self.cache.save()
        return done

    # -- reporting -----------------------------------------------------------

    def prediction_errors(self) -> Dict[int, float]:
        """Per-bucket relative error of the plan's analytic seconds against
        the measured wall clock (DESIGN.md §13).  Analytic roofline seconds
        are not wall-clock on any one machine, so ONE global scale — the
        geomean of measured/analytic across buckets — is fitted first; the
        per-bucket error then reports how well the model ranks/shapes the
        buckets, which is what the planner actually relies on."""
        pairs: Dict[int, Tuple[float, float]] = {}
        for b, rep in self.reports.items():
            # report buckets ARE per-shard buckets — peek pre-sharded so
            # pred_err compares against the plan the step actually ran
            plan = self.cache.peek_fused(self.cfg, b, dtype=self.dtype,
                                         policy=self.dtype_policy,
                                         devices=self.devices,
                                         pre_sharded=True)
            if plan is None or not rep.batches or rep.seconds <= 0.0:
                continue
            if plan.total_s <= 0.0:
                continue
            pairs[b] = (plan.total_s, rep.seconds / rep.batches)
        if not pairs:
            return {}
        scale = float(np.exp(np.mean(
            [np.log(m / a) for a, m in pairs.values()])))
        return {b: abs(scale * a - m) / m for b, (a, m) in pairs.items()}

    def report_lines(self) -> List[str]:
        th = self.cache.thresholds_for(self.dtype, self._hw)
        lines = [f"net={self.cfg.name} dtype={self.dtype} "
                 f"policy={self.dtype_policy} hw={self._hw} "
                 f"devices={self.devices} "
                 f"thresholds=Ct:{th.Ct},Nt:{th.Nt} "
                 f"planner_calls={self.cache.planner_calls}"]
        errs = self.prediction_errors()
        for b in sorted(self.reports):
            rep = self.reports[b]
            plan = self.cache.peek_fused(self.cfg, b, dtype=self.dtype,
                                         policy=self.dtype_policy,
                                         devices=self.devices,
                                         pre_sharded=True)
            # a bounded cache may have LRU-evicted this bucket's plan since
            # it last executed; the report must not resurrect (replan) it
            sig = plan.conv_signature if plan is not None else "(evicted)"
            dsig = plan.dtype_signature if plan is not None else "(evicted)"
            ips = rep.images / rep.seconds if rep.seconds else 0.0
            perr = (f"{errs[b]:.2f}" if b in errs else "n/a")
            pcmb = (rep.per_chip_bytes / rep.batches / 1e6
                    if rep.batches else 0.0)
            wd = self._watchdogs.get(b)
            lines.append(
                f"  bucket={b:<4d} batches={rep.batches:<4d} "
                f"images={rep.images:<5d} pad_waste={rep.padded:<4d} "
                f"hit_rate={rep.hit_rate:.2f} conv_layouts={sig} "
                f"conv_dtypes={dsig} "
                f"modeled_MB={rep.hbm_bytes / 1e6:.1f} "
                f"per_chip_MB={pcmb:.1f} img/s={ips:.1f} "
                f"pred_err={perr} rung={rep.rung or 'n/a'} "
                f"degraded={rep.degraded} failures={rep.failures} "
                f"stragglers={len(wd.flagged) if wd else 0}")
        # §14: the resilience summary — incident taxonomy totals and the
        # quarantined plan variants currently being skipped
        lines.append(f"  {self.incidents.summary()} "
                     f"quarantined_variants={len(self._quarantine)}")
        return lines


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--network", default="lenet", choices=list(CNN_CONFIGS))
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-bucket", type=int, default=32)
    ap.add_argument("--impl", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "fp32", "bfloat16", "bf16"],
                    help="storage dtype: bf16 halves HBM bytes and plans "
                         "under its own calibrated threshold row")
    ap.add_argument("--dtype-policy", default="uniform",
                    choices=["uniform", "mixed"],
                    help="mixed: per-layer (layout, dtype) DP — interior "
                         "conv chains store int8, boundaries stay --dtype")
    ap.add_argument("--calibration", default="measured",
                    choices=["measured", "analytic"])
    ap.add_argument("--devices", type=int, default=1,
                    help="shard admitted batches data-parallel over this "
                         "many chips (§15); plans are made for the "
                         "per-shard bucket, so Nt flips taken at the shard "
                         "batch are honored")
    ap.add_argument("--cache-dir", default="/tmp/repro_serve")
    ap.add_argument("--max-plans", type=int, default=None,
                    help="LRU bound on cached plans per engine (default: "
                         "unbounded)")
    ap.add_argument("--inject", default="",
                    help="fault-injection spec 'site=rate,...' (§14), e.g. "
                         "'kernel=0.1,nan@mixed=1.0,slow=0.05'; sites are "
                         "kernel/nan/slow, optionally qualified @rung-name, "
                         "@policy or @impl; empty = injection off")
    ap.add_argument("--inject-seed", type=int, default=0,
                    help="seed for the deterministic fault injector")
    ap.add_argument("--backoff", type=float, default=0.0,
                    help="initial exponential-backoff delay (s) between "
                         "degradation-ladder retries")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    os.makedirs(args.cache_dir, exist_ok=True)
    srv = CNNServer(
        args.network, max_bucket=args.max_bucket, impl=args.impl,
        calibration=args.calibration, dtype=args.dtype,
        dtype_policy=args.dtype_policy, max_plans=args.max_plans,
        devices=args.devices,
        cache_path=os.path.join(args.cache_dir, f"{args.network}.plans.json"),
        calib_path=os.path.join(args.cache_dir, "thresholds.json"),
        injector=parse_inject_spec(args.inject, seed=args.inject_seed),
        backoff_s=args.backoff)
    rng = np.random.default_rng(args.seed)
    c, h = srv.cfg.in_channels, srv.cfg.image_hw
    reqs = [ImageRequest(i, rng.standard_normal((c, h, h)).astype(np.float32))
            for i in range(args.requests)]
    # bursty arrivals: drain in variable-size chunks to exercise buckets
    t0 = time.time()
    done: Dict[int, np.ndarray] = {}
    i = 0
    while i < len(reqs):
        n = int(rng.integers(1, args.max_bucket + 1))
        for r in reqs[i:i + n]:
            srv.submit(r)
        i += n
        try:
            for r in srv.step():
                done[r.rid] = r.probs
        except ServingFault as e:
            log.warning("step failed on every rung (%s); requests "
                        "re-queued", e)
    while srv.queue:
        try:
            for r in srv.step():
                done[r.rid] = r.probs
        except ServingFault as e:
            log.warning("step failed on every rung (%s); requests "
                        "re-queued", e)
    if srv.cache.path:
        srv.cache.save()
    dt = time.time() - t0
    dropped = len(reqs) - len(done)
    # replans of an already-planned key: the mesh CI job greps this to
    # prove the per-shard bucket compiles exactly once across all shards
    rr = sum(max(0, st.misses - 1) for st in srv.cache.per_key.values())
    print(f"served {len(done)}/{len(reqs)} requests in {dt:.2f}s "
          f"({len(done) / dt:.1f} img/s overall, dropped={dropped}, "
          f"devices={args.devices}, replans_repeat={rr})")
    for line in srv.report_lines():
        print(line)


if __name__ == "__main__":
    main()
