"""CNN request-serving driver: batch-adaptive fused inference (DESIGN.md §7).

The CNN twin of ``launch.serve``'s queue shape: requests (single images)
arrive in a queue, the admission loop drains up to ``max_bucket`` of them
per step, rounds the batch up to its pow-2 bucket, pads, and executes ONE
fused ``forward_fused`` batch under the bucket's cached plan.  Planning and
threshold calibration are both one-time costs paid per bucket / per
process, never per request:

  * layouts come from the ``PlanCache`` (replans only on first sight of a
    bucket — the paper's Nt threshold makes the plan batch-dependent);
  * thresholds come from ``measured_thresholds`` (real Pallas kernel
    timings, persisted), not the analytic sweep.

``--dtype bf16`` serves the mixed-precision fast path (DESIGN.md §8):
params and admission are cast to the storage dtype, kernels accumulate in
f32, and plans/thresholds come from the dtype's own cache rows — halving
every tensor's HBM footprint and shifting the layout crossovers.

``--dtype-policy mixed`` (DESIGN.md §9) goes further: the planner searches
per-layer (layout, storage dtype) states, so interior conv chains store
their activations as int8 (quantize folded into the producing kernel's
epilogue, per-channel dequant folded into the consumer conv's weights)
while the host input, the first conv chain, and the classifier head stay at
the base ``--dtype``.  Plans are cached under their own ``policy`` key, and
the int8 calibration row is measured alongside the base row.

The report shows per-bucket plan-cache hit rates, the plan's conv layouts
and storage dtypes, modeled HBM bytes, and images/s.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import os
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CNNConfig
from repro.configs.cnn_networks import (CNN_BUILDERS, CNN_CONFIGS,
                                        reduced_cnn)
from repro.cnn.layers import init_cnn
from repro.cnn.network import forward_fused, input_shape
from repro.dtypes import canon_dtype, dtype_bytes, jnp_dtype
from repro.perfmodel import Thresholds, calibrate, hardware_id
from repro.serve import PlanCache, measured_thresholds, pad_to_bucket

log = logging.getLogger("repro.cnn_serve")


@dataclasses.dataclass
class ImageRequest:
    rid: int
    image: np.ndarray                  # [C, H, W] float32
    probs: Optional[np.ndarray] = None # filled by the server


@dataclasses.dataclass
class BucketReport:
    bucket: int
    batches: int = 0
    images: int = 0
    padded: int = 0                    # pad rows executed (bucket waste)
    hits: int = 0
    misses: int = 0
    hbm_bytes: int = 0                 # modeled, per executed batch summed
    seconds: float = 0.0

    @property
    def hit_rate(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0


class CNNServer:
    """Queue-draining batch-adaptive server over the fused CNN engine.

    ``thresholds``, when supplied, is filed as THIS server's dtype row —
    the caller must have swept it at the matching element size
    (``calibrate(dtype_bytes=4)`` for an fp32 server; bare ``calibrate()``
    sweeps at the 2-byte paper-fidelity default)."""

    def __init__(self, network: str = "lenet", *, reduced: bool = True,
                 max_bucket: int = 64, impl: str = "xla",
                 interpret: bool = True, cache_path: Optional[str] = None,
                 calibration: str = "measured",
                 thresholds: Optional[Thresholds] = None,
                 calib_path: Optional[str] = None,
                 dtype: str = "float32",
                 dtype_policy: str = "uniform",
                 max_plans: Optional[int] = None):
        cfg = CNN_CONFIGS[network]
        if reduced and cfg.image_hw > 96:
            # branching nets re-derive skip edges (and the gap-pool window)
            # through their builder; a bare replace() would zero out the
            # global pool at the reduced size
            if cfg.name in CNN_BUILDERS:
                cfg = reduced_cnn(cfg, batch=cfg.batch)
            else:
                cfg = cfg.replace(image_hw=96)
        self.cfg = cfg
        self.impl = impl
        self.interpret = interpret
        self.dtype = canon_dtype(dtype)
        if dtype_policy not in ("uniform", "mixed"):
            raise ValueError(f"unknown dtype policy {dtype_policy!r}")
        self.dtype_policy = dtype_policy
        self._jdtype = jnp_dtype(self.dtype)
        # threshold rows are versioned by hardware id (DESIGN.md §13): a
        # cache file carried to a different accelerator keeps its old rows
        # under their id and measures fresh rows for this one
        self._hw = hardware_id(interpret)
        # build the cache first: a persisted cache already carries the
        # per-dtype threshold rows it was planned under, so calibration (the
        # ~4 s measured sweep) only runs when neither the caller nor the
        # cache has this dtype's row
        self.cache = PlanCache(
            path=cache_path,
            thresholds=(None if thresholds is None
                        else {self.dtype: thresholds}),
            max_bucket=max_bucket, max_entries=max_plans)
        # mixed policy also measures the 1-byte row (ISSUE 5): the per-dtype
        # threshold contract covers every storage dtype the server's plans
        # use, and the sweep is one-time per cache dir (persisted) — ~4 s of
        # interpret-mode timing, never paid again on restart
        need_rows = [self.dtype]
        if self.dtype_policy == "mixed":
            need_rows.append("int8")
        if calib_path is None and cache_path:
            calib_path = os.path.join(os.path.dirname(cache_path),
                                      "thresholds.json")
        for row in need_rows:
            if self.cache.thresholds_for(row, self._hw) is not None:
                continue
            if calibration == "measured":
                self.cache.set_thresholds(
                    measured_thresholds(calib_path, dtype=row,
                                        interpret=interpret,
                                        hardware=self._hw),
                    row, hardware=self._hw)
            else:
                self.cache.set_thresholds(
                    calibrate(dtype_bytes=dtype_bytes(row)), row,
                    hardware=self._hw)
        self.params = init_cnn(jax.random.PRNGKey(0), cfg,
                               dtype=self._jdtype)
        self.queue: Deque[ImageRequest] = deque()
        self.reports: Dict[int, BucketReport] = {}
        self._fwd = {}                 # bucket -> jitted forward
        self._plan_stats = {}          # bucket -> modeled RunStats bytes

    # -- admission -----------------------------------------------------------

    def submit(self, req: ImageRequest) -> None:
        c, h = self.cfg.in_channels, self.cfg.image_hw
        if req.image.shape != (c, h, h):
            raise ValueError(
                f"request {req.rid}: image shape {req.image.shape} != "
                f"{(c, h, h)}")
        self.queue.append(req)

    def _modeled_bytes(self, bcfg: CNNConfig, plan) -> int:
        """Shape-only HBM accounting for one bucket batch (eval_shape —
        never executes)."""
        box = {}

        def f(p, x):
            y, st = forward_fused(p, x, bcfg, plan, impl="xla")
            box["st"] = st
            return y

        aparams = jax.eval_shape(lambda k: init_cnn(k, bcfg,
                                                    dtype=self._jdtype),
                                 jax.random.PRNGKey(0))
        jax.eval_shape(f, aparams,
                       jax.ShapeDtypeStruct(input_shape(bcfg), self._jdtype))
        return box["st"].hbm_bytes

    def _forward_for(self, bucket: int):
        if bucket not in self._fwd:
            bcfg = self.cfg.replace(batch=bucket)
            # step() already planned this bucket; peek keeps stats honest
            plan = self.cache.peek_fused(self.cfg, bucket, dtype=self.dtype,
                                         policy=self.dtype_policy)
            if plan is None:
                plan, _, _ = self.cache.fused_plan(self.cfg, bucket,
                                                   dtype=self.dtype,
                                                   policy=self.dtype_policy)
            self._plan_stats[bucket] = self._modeled_bytes(bcfg, plan)
            impl, interp = self.impl, self.interpret

            @jax.jit
            def fwd(params, x):
                return forward_fused(params, x, bcfg, plan, impl=impl,
                                     interpret=interp)[0]

            self._fwd[bucket] = fwd
        return self._fwd[bucket]

    # -- serving loop --------------------------------------------------------

    def step(self) -> List[ImageRequest]:
        """Drain up to ``max_bucket`` queued requests as one fused batch."""
        if not self.queue:
            return []
        batch = [self.queue.popleft()
                 for _ in range(min(len(self.queue), self.cache.max_bucket))]
        B = len(batch)
        calls_before = self.cache.planner_calls
        plan, bucket, hit = self.cache.fused_plan(self.cfg, B,
                                                  dtype=self.dtype,
                                                  policy=self.dtype_policy)
        rep = self.reports.setdefault(bucket, BucketReport(bucket))
        rep.hits += int(hit)
        rep.misses += int(not hit)
        fwd = self._forward_for(bucket)
        assert self.cache.planner_calls in (calls_before, calls_before + 1)
        x = jnp.asarray(np.stack([r.image for r in batch])).astype(
            self._jdtype)
        t0 = time.perf_counter()
        y = jax.block_until_ready(fwd(self.params, pad_to_bucket(x, bucket)))
        dt = time.perf_counter() - t0
        probs = np.asarray(y.astype(jnp.float32))   # bf16-safe host dtype
        for i, r in enumerate(batch):
            r.probs = probs[i]
        rep.batches += 1
        rep.images += B
        rep.padded += bucket - B
        rep.hbm_bytes += self._plan_stats[bucket]
        rep.seconds += dt
        return batch

    def run(self, requests: List[ImageRequest]) -> Dict[int, np.ndarray]:
        for r in requests:
            self.submit(r)
        done: Dict[int, np.ndarray] = {}
        while self.queue:
            for r in self.step():
                done[r.rid] = r.probs
        if self.cache.path:
            self.cache.save()
        return done

    # -- reporting -----------------------------------------------------------

    def prediction_errors(self) -> Dict[int, float]:
        """Per-bucket relative error of the plan's analytic seconds against
        the measured wall clock (DESIGN.md §13).  Analytic roofline seconds
        are not wall-clock on any one machine, so ONE global scale — the
        geomean of measured/analytic across buckets — is fitted first; the
        per-bucket error then reports how well the model ranks/shapes the
        buckets, which is what the planner actually relies on."""
        pairs: Dict[int, Tuple[float, float]] = {}
        for b, rep in self.reports.items():
            plan = self.cache.peek_fused(self.cfg, b, dtype=self.dtype,
                                         policy=self.dtype_policy)
            if plan is None or not rep.batches or rep.seconds <= 0.0:
                continue
            if plan.total_s <= 0.0:
                continue
            pairs[b] = (plan.total_s, rep.seconds / rep.batches)
        if not pairs:
            return {}
        scale = float(np.exp(np.mean(
            [np.log(m / a) for a, m in pairs.values()])))
        return {b: abs(scale * a - m) / m for b, (a, m) in pairs.items()}

    def report_lines(self) -> List[str]:
        th = self.cache.thresholds_for(self.dtype, self._hw)
        lines = [f"net={self.cfg.name} dtype={self.dtype} "
                 f"policy={self.dtype_policy} hw={self._hw} "
                 f"thresholds=Ct:{th.Ct},Nt:{th.Nt} "
                 f"planner_calls={self.cache.planner_calls}"]
        errs = self.prediction_errors()
        for b in sorted(self.reports):
            rep = self.reports[b]
            plan = self.cache.peek_fused(self.cfg, b, dtype=self.dtype,
                                         policy=self.dtype_policy)
            # a bounded cache may have LRU-evicted this bucket's plan since
            # it last executed; the report must not resurrect (replan) it
            sig = plan.conv_signature if plan is not None else "(evicted)"
            dsig = plan.dtype_signature if plan is not None else "(evicted)"
            ips = rep.images / rep.seconds if rep.seconds else 0.0
            perr = (f"{errs[b]:.2f}" if b in errs else "n/a")
            lines.append(
                f"  bucket={b:<4d} batches={rep.batches:<4d} "
                f"images={rep.images:<5d} pad_waste={rep.padded:<4d} "
                f"hit_rate={rep.hit_rate:.2f} conv_layouts={sig} "
                f"conv_dtypes={dsig} "
                f"modeled_MB={rep.hbm_bytes / 1e6:.1f} img/s={ips:.1f} "
                f"pred_err={perr}")
        return lines


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--network", default="lenet", choices=list(CNN_CONFIGS))
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-bucket", type=int, default=32)
    ap.add_argument("--impl", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "fp32", "bfloat16", "bf16"],
                    help="storage dtype: bf16 halves HBM bytes and plans "
                         "under its own calibrated threshold row")
    ap.add_argument("--dtype-policy", default="uniform",
                    choices=["uniform", "mixed"],
                    help="mixed: per-layer (layout, dtype) DP — interior "
                         "conv chains store int8, boundaries stay --dtype")
    ap.add_argument("--calibration", default="measured",
                    choices=["measured", "analytic"])
    ap.add_argument("--cache-dir", default="/tmp/repro_serve")
    ap.add_argument("--max-plans", type=int, default=None,
                    help="LRU bound on cached plans per engine (default: "
                         "unbounded)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    os.makedirs(args.cache_dir, exist_ok=True)
    srv = CNNServer(
        args.network, max_bucket=args.max_bucket, impl=args.impl,
        calibration=args.calibration, dtype=args.dtype,
        dtype_policy=args.dtype_policy, max_plans=args.max_plans,
        cache_path=os.path.join(args.cache_dir, f"{args.network}.plans.json"),
        calib_path=os.path.join(args.cache_dir, "thresholds.json"))
    rng = np.random.default_rng(args.seed)
    c, h = srv.cfg.in_channels, srv.cfg.image_hw
    reqs = [ImageRequest(i, rng.standard_normal((c, h, h)).astype(np.float32))
            for i in range(args.requests)]
    # bursty arrivals: drain in variable-size chunks to exercise buckets
    t0 = time.time()
    done: Dict[int, np.ndarray] = {}
    i = 0
    while i < len(reqs):
        n = int(rng.integers(1, args.max_bucket + 1))
        for r in reqs[i:i + n]:
            srv.submit(r)
        i += n
        for r in srv.step():
            done[r.rid] = r.probs
    while srv.queue:
        for r in srv.step():
            done[r.rid] = r.probs
    if srv.cache.path:
        srv.cache.save()
    dt = time.time() - t0
    print(f"served {len(done)} requests in {dt:.2f}s "
          f"({len(done) / dt:.1f} img/s overall)")
    for line in srv.report_lines():
        print(line)


if __name__ == "__main__":
    main()
