"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``input_specs`` mirrors what the data pipeline / serving frontend would feed:
weak-type-correct, shardable, zero device allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.distributed.sharding import (batch_spec, cache_specs, mesh_axes,
                                        named, param_specs)
from repro.models import transformer as T
from repro.optim import adamw

CLIP_DIM = T.CLIP_DIM


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract training/prefill batch for one global step."""
    B, S = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    s_text = S
    if cfg.frontend == "clip_stub":
        s_text = S - cfg.frontend_tokens
        out["embeds"] = _sds((B, cfg.frontend_tokens, CLIP_DIM), jnp.bfloat16)
    if cfg.family == "encdec":
        out["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    out["tokens"] = _sds((B, s_text), jnp.int32)
    if shape.kind == "train":
        out["labels"] = _sds((B, S), jnp.int32)
        out["mask"] = _sds((B, S), jnp.float32)
    return out


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh):
    from repro.distributed.sharding import fit_spec
    struct = batch_struct(cfg, shape)
    return {k: NamedSharding(mesh, fit_spec(batch_spec(mesh, v.ndim),
                                            v.shape, mesh))
            for k, v in struct.items()}


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                  kv_layout: str = "bksd", kv_window: bool = False):
    """(structs, shardings) for (params-independent) decode inputs:
    cache, token, cache_len [, cross]."""
    B, S = shape.global_batch, shape.seq_len
    dp, tp, _ = mesh_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    batch_shardable = B % dp_size == 0 and B >= dp_size

    cache = T.abstract_cache(cfg, B, S, kv_layout, kv_window=kv_window)
    cspecs = cache_specs(cfg, mesh, shape, kv_layout, kv_window=kv_window)
    cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                            is_leaf=lambda x: isinstance(x, P))

    token = _sds((B, 1), jnp.int32)
    token_sh = NamedSharding(mesh, P(dp if batch_shardable else None, None))
    clen = _sds((), jnp.int32)
    clen_sh = NamedSharding(mesh, P())

    structs = {"cache": cache, "token": token, "cache_len": clen}
    shardings = {"cache": cache_sh, "token": token_sh, "cache_len": clen_sh}

    if cfg.family == "encdec":
        K, Dh, Pn = cfg.num_kv_heads, cfg.head_dim, cfg.num_periods
        Te = cfg.encoder_seq
        kv = _sds((Pn, B, K, Te, Dh), jnp.bfloat16)
        sh = NamedSharding(mesh, P(None, dp if batch_shardable else None,
                                   None, None, None))
        structs["cross"] = {"k": kv, "v": kv}
        shardings["cross"] = {"k": sh, "v": sh}
    return structs, shardings


def train_state_shardings(cfg: ModelConfig, mesh, parallel: ParallelConfig):
    pspecs = param_specs(cfg, mesh, parallel)
    osp = adamw.state_specs(pspecs)
    return (named(mesh, pspecs), named(mesh, osp))


def abstract_train_state(cfg: ModelConfig):
    ap = T.abstract_params(cfg)
    return ap, adamw.abstract_state(ap, jnp.dtype(cfg.opt_state_dtype))
