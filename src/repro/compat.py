"""Version-tolerant wrappers for jax APIs that moved between releases.

The LM-side modules target the jax >= 0.6 surface (``jax.shard_map``,
``jax.sharding.AxisType``); older runtimes ship the same functionality under
``jax.experimental.shard_map`` with ``auto``/``check_rep`` spellings.  These
shims pick whichever exists so the test suite runs on both.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """``jax.shard_map`` when available, else the jax<0.6 experimental one
    (``axis_names`` -> complement ``auto`` set, ``check_vma`` -> ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {}
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - set(axis_names)
        if auto:
            kw["auto"] = auto
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma, **kw)


def abstract_mesh(shape, axes):
    """``jax.sharding.AbstractMesh`` across both constructor generations:
    jax >= 0.6 takes (shape, axis_names, axis_types=...), jax < 0.6 takes a
    ((name, size), ...) tuple."""
    from jax.sharding import AbstractMesh
    if hasattr(jax.sharding, "AxisType"):
        return AbstractMesh(tuple(shape), tuple(axes),
                            axis_types=(jax.sharding.AxisType.Auto,)
                            * len(axes))
    return AbstractMesh(tuple(zip(axes, shape)))
