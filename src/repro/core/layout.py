"""Data-layout descriptors and layout algebra (paper §IV).

A layout is a string permutation of logical dim names, e.g. ``"NCHW"`` or
``"CHWN"`` for conv feature maps; the rightmost letter is minormost
(contiguous; on TPU it maps to the 128-wide lane dimension, the second
rightmost to sublanes).

The transform planner implements the paper's §IV.C algorithm generalized to
any pair of layouts: maximal runs of dims that appear contiguously in BOTH
layouts are collapsed (``CHWN -> NCHW`` collapses ``CHW``), reducing most
CNN/LM re-layouts to a single 2-D transpose that the tiled Pallas transpose
kernel executes at near-streaming bandwidth.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

CONV_LAYOUTS = ("NCHW", "CHWN", "NHWC", "HWCN")


def perm_between(src: str, dst: str) -> Tuple[int, ...]:
    """Axis permutation p such that transpose(x_src, p) is laid out as dst."""
    if sorted(src) != sorted(dst):
        raise ValueError(f"layouts {src!r} / {dst!r} name different dims")
    return tuple(src.index(d) for d in dst)


def shape_in(layout: str, dims: Dict[str, int]) -> Tuple[int, ...]:
    return tuple(dims[d] for d in layout)


def relayout_shape(shape: Sequence[int], src: str, dst: str) -> Tuple[int, ...]:
    dims = dict(zip(src, shape))
    return shape_in(dst, dims)


@dataclass(frozen=True)
class TransformPlan:
    """Collapsed view of a layout change.

    ``groups_src``: slices of the source layout that move as units;
    ``perm``: permutation of those groups;
    ``collapsed_shape``: source shape after collapsing;
    ``is_identity`` / ``is_2d_transpose``: fast paths.
    """
    src: str
    dst: str
    groups_src: Tuple[str, ...]
    perm: Tuple[int, ...]

    @property
    def is_identity(self) -> bool:
        return self.perm == tuple(range(len(self.perm)))

    @property
    def is_2d_transpose(self) -> bool:
        return len(self.perm) == 2 and self.perm == (1, 0)

    def collapsed_shape(self, shape: Sequence[int]) -> Tuple[int, ...]:
        dims = dict(zip(self.src, shape))
        return tuple(int(np.prod([dims[d] for d in g])) for g in self.groups_src)


def plan_transform(src: str, dst: str) -> TransformPlan:
    """Collapse maximal common substrings (paper §IV.C dimension combining).

    Greedy left-to-right over ``dst``: extend each group while the next dim in
    ``src`` order is also next in ``dst`` order.
    """
    if sorted(src) != sorted(dst):
        raise ValueError(f"layouts {src!r} / {dst!r} name different dims")
    # build groups by scanning src and splitting where dst order breaks
    groups: List[str] = []
    cur = src[0]
    for a, b in zip(src, src[1:]):
        if dst.index(b) == dst.index(a) + 1:
            cur += b
        else:
            groups.append(cur)
            cur = b
    groups.append(cur)
    # permutation of groups according to dst order
    order = sorted(range(len(groups)), key=lambda i: dst.index(groups[i][0]))
    return TransformPlan(src=src, dst=dst, groups_src=tuple(groups),
                         perm=tuple(order))


def transform_bytes(shape: Sequence[int], dtype_bytes: int) -> int:
    """A layout transform reads + writes every element once."""
    return 2 * int(np.prod(shape)) * dtype_bytes
