"""Layout-transform execution (paper §IV.C).

``apply_transform`` collapses common dim groups (layout.plan_transform) and
executes the minimal transpose; for the 2-D case it dispatches to the tiled
Pallas transpose kernel (repro.kernels.transpose) — the TPU analogue of the
paper's shared-memory tiled + vectorized transpose — or to XLA transpose when
running without kernels (e.g. inside jit-of-everything graphs).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.layout import TransformPlan, perm_between, plan_transform


def apply_transform(x, src: str, dst: str, *, use_pallas: bool = False,
                    interpret: bool = True):
    """Re-layout ``x`` from layout ``src`` to ``dst``."""
    if src == dst:
        return x
    plan = plan_transform(src, dst)
    if plan.is_identity:
        return x
    cshape = plan.collapsed_shape(x.shape)
    xc = x.reshape(cshape)
    if use_pallas and plan.is_2d_transpose:
        from repro.kernels.transpose.ops import transpose2d
        yc = transpose2d(xc, interpret=interpret)
    elif use_pallas and len(plan.perm) == 3 and plan.perm[0] == 0:
        # batched 2-D transpose (e.g. NCHW -> NHWC)
        from repro.kernels.transpose.ops import transpose2d_batched
        yc = transpose2d_batched(xc, interpret=interpret)
    else:
        yc = jnp.transpose(xc, plan.perm)
    dims = dict(zip(src, x.shape))
    return yc.reshape(tuple(dims[d] for d in dst))


def naive_transform(x, src: str, dst: str):
    """The paper's Fig. 7a baseline: direct 4-D transpose, no collapsing."""
    return jnp.transpose(x, perm_between(src, dst))
