"""Network-level automatic layout assignment (paper §IV.D).

The paper scans the network once, sets a per-layer layout field from the
heuristic, and inserts a transform wherever consecutive layers disagree,
using one-time profiling to confirm the transform overhead is amortized
(CV5/CV9 in §VI are cases where it is NOT and the layout change is skipped).

We implement that arbitration exactly, as a shortest-path dynamic program
over per-layer layout states: node cost = layer cost under a layout (from
the analytical/measured cost model), edge cost = transform cost between
consecutive layers' layouts.  With uniform-cost edges=0 this degenerates to
the paper's pure per-layer heuristic; with transform costs it reproduces the
paper's "don't transform for CV5/CV9" behaviour.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.paper_table1 import ConvLayer, PoolLayer
from repro.core.heuristic import (Thresholds, conv_cost, select_conv_layout,
                                  select_pool_layout)
from repro.core.layout import transform_bytes
from repro.launch.mesh import HBM_BW

LAYOUTS = ("CHWN", "NCHW")


@dataclass
class LayerDesc:
    """One network layer as seen by the selector."""
    name: str
    kind: str                       # conv | pool | act | fc | softmax | flatten
    conv: Optional[ConvLayer] = None
    pool: Optional[PoolLayer] = None
    out_shape: Tuple[int, ...] = ()   # logical NCHW shape of the output
    dtype_bytes: int = 2


def layer_cost(l: LayerDesc, layout: str) -> float:
    """Estimated seconds for this layer in this layout."""
    if l.kind == "conv" and l.conv is not None:
        return conv_cost(l.conv, layout, l.dtype_bytes).total_s
    if l.kind == "pool" and l.pool is not None:
        # memory bound: bytes / bw, de-rated by tile utilization of the
        # layout's minormost dims (paper Fig. 6: NCHW pooling is strided)
        p = l.pool
        ho = (p.HW - p.F) // p.S + 1
        bytes_ = (p.N * p.C * (p.HW * p.HW + ho * ho)) * l.dtype_bytes
        eff = 1.0 if layout == "CHWN" else 0.25   # strided window penalty
        return bytes_ / (HBM_BW * eff)
    if l.kind in ("act", "lrn"):
        n = float(np.prod(l.out_shape)) if l.out_shape else 0.0
        return 2 * n * l.dtype_bytes / HBM_BW
    return 0.0     # fc/softmax/flatten are layout-terminal (2-D)


def transform_cost(shape: Tuple[int, ...], dtype_bytes: int,
                   optimized: bool = True) -> float:
    """Seconds to re-layout a tensor of ``shape``; the optimized transform
    runs at ~streaming bandwidth (paper Fig. 11: up to 97.6% of peak), the
    naive one at ~1/8 of it."""
    eff = 0.9 if optimized else 0.12
    return transform_bytes(shape, dtype_bytes) / (HBM_BW * eff)


@dataclass
class Assignment:
    layouts: List[str]
    transforms: List[int]           # indices i where a transform happens before layer i
    total_s: float


def assign_layouts(layers: Sequence[LayerDesc], *,
                   input_layout: str = "NCHW",
                   optimized_transform: bool = True,
                   measure: Optional[Callable[[LayerDesc, str], float]] = None,
                   thresholds: Optional[Thresholds] = None) -> Assignment:
    """Shortest-path over (layer, layout) states."""
    cost_fn = measure or layer_cost
    n = len(layers)
    INF = float("inf")
    # dp[layout] = (cost, path)
    dp: Dict[str, Tuple[float, List[str]]] = {
        lay: ((0.0 if lay == input_layout else
               transform_cost(layers[0].out_shape, layers[0].dtype_bytes,
                              optimized_transform)), [lay])
        for lay in LAYOUTS}
    for i, l in enumerate(layers):
        ndp: Dict[str, Tuple[float, List[str]]] = {}
        for lay in LAYOUTS:
            best, path = INF, None
            for prev, (c0, p0) in dp.items():
                edge = 0.0
                if prev != lay:
                    # transform the layer input (= previous layer's output)
                    shape = layers[i - 1].out_shape if i else layers[0].out_shape
                    edge = transform_cost(shape, l.dtype_bytes,
                                          optimized_transform)
                c = c0 + edge + cost_fn(l, lay)
                if c < best:
                    best, path = c, p0 + [lay]
            ndp[lay] = (best, path)
        dp = ndp
    lay_best = min(dp, key=lambda k: dp[k][0])
    total, path = dp[lay_best]
    layouts = path[1:]
    transforms = [i for i in range(n)
                  if (layouts[i] != (layouts[i - 1] if i else input_layout))]
    return Assignment(layouts=layouts, transforms=transforms, total_s=total)


def paper_heuristic_layouts(layers: Sequence[LayerDesc],
                            th: Thresholds) -> List[str]:
    """The paper's §IV.D single-scan field assignment (no DP)."""
    out = []
    cur = "NCHW"
    for l in layers:
        if l.kind == "conv" and l.conv is not None:
            cur = select_conv_layout(l.conv, th)
        elif l.kind == "pool":
            cur = select_pool_layout(l.pool)
        out.append(cur)    # act/fc/softmax inherit the incoming layout
    return out
