"""Network-level automatic layout assignment (paper §IV.D) and fused-op
planning (DESIGN.md §5).

The paper scans the network once, sets a per-layer layout field from the
heuristic, and inserts a transform wherever consecutive layers disagree,
using one-time profiling to confirm the transform overhead is amortized
(CV5/CV9 in §VI are cases where it is NOT and the layout change is skipped).

We implement that arbitration exactly, as a shortest-path dynamic program
over per-layer layout states: node cost = layer cost under a layout (from
the analytical/measured cost model), edge cost = transform cost between
consecutive layers' layouts.  With uniform-cost edges=0 this degenerates to
the paper's pure per-layer heuristic; with transform costs it reproduces the
paper's "don't transform for CV5/CV9" behaviour.

``plan_fused`` extends the DP for the fused execution engine: an edge costs
*zero* when the re-layout folds into the producing kernel (conv/pool write
their output directly in the consumer's layout via the out BlockSpec, and
conv reads its input in the producer's layout), and conv->relu->pool runs
collapse into single FusedOp nodes priced by the fusion cost model
(``fused_chain_cost``), which credits the intermediate read+write bytes the
fusion removes.

Graph planning (DESIGN.md §11): layers are a DAG, not just a chain.  Each
``LayerDesc`` may name explicit producer ``inputs`` (layer indices; -1 is
the network input; empty means "the previous layer", so every existing
sequence keeps its meaning).  Branching networks bring merge kinds —
``add`` (residual), ``concat`` (skip), ``upsample`` — and both DPs become
frontier DPs over topologically-ordered nodes: the state is the (layout,
dtype) assignment of every LIVE edge (a produced tensor still awaiting a
consumer), joins price the transform/cast of each incoming edge with the
``heuristic.py`` cost model, and a residual add whose operands qualify
folds into the producing conv's epilogue (the skip tensor is read into the
VMEM accumulator through a second, layout-folding input BlockSpec — never
a standalone HBM add).  A linear graph takes the original chain code path
untouched, so its plans are byte-identical to the pre-DAG planner.

Mixed-dtype planning (DESIGN.md §9): with ``dtype_policy="mixed"`` both DPs
search the product space of per-layer **(layout, storage dtype)** states —
dtype becomes a third DP dimension next to layout, exactly as the ROADMAP
lever describes.  In ``plan_fused`` a dtype change is free wherever it folds
(the producing conv's epilogue quantizes the f32 VMEM accumulator on its
way out; the consuming conv dequantizes in VMEM via scale-folded weights),
so interior conv->conv edges store int8 at 1 byte/element; in
``assign_layouts`` every dtype boundary pays a standalone cast pass
(``cast_cost``), which is why the unfused DP provably never picks int8 —
the fold *is* the win.  Precision guardrails keep the search honest: the
host input, the first conv chain's output, and everything at/after flatten
(the classifier head) stay in the base float dtype.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.paper_table1 import ConvLayer, PoolLayer
from repro.core.layout import transform_bytes
from repro.perfmodel import (CostModel, DEFAULT_DTYPE_BYTES, Thresholds,
                             default_cost_model, select_conv_layout,
                             select_pool_layout)
from repro.dtypes import INT8_DTYPE, canon_dtype, dtype_bytes as _dtype_bytes
from repro.launch.mesh import HBM_BW
from repro.shapes import pool_out_hw

LAYOUTS = ("CHWN", "NCHW")
DTYPE_POLICIES = ("uniform", "mixed")

# reverse map for labeling plans built from bare LayerDescs (which carry
# only an element size); ambiguity at 2 bytes resolves to bf16, the TPU's
# native half dtype
_BYTES_TO_NAME = {4: "float32", 2: "bfloat16", 1: "int8"}


def _base_dtype_name(layers: Sequence["LayerDesc"],
                     base_dtype: Optional[str]) -> str:
    if base_dtype is not None:
        return canon_dtype(base_dtype)
    db = layers[0].dtype_bytes if layers else 4
    return _BYTES_TO_NAME.get(db, "float32")


@dataclass
class LayerDesc:
    """One network layer as seen by the selector."""
    name: str
    kind: str                       # conv | pool | act | fc | softmax |
                                    # flatten | add | concat | upsample
    conv: Optional[ConvLayer] = None
    pool: Optional[PoolLayer] = None
    out_shape: Tuple[int, ...] = ()   # logical NCHW shape of the output
    dtype_bytes: int = DEFAULT_DTYPE_BYTES   # storage element size
    trainable: bool = True          # False: frozen params, wgrad skipped
    # Graph edges: indices of the producer layers this layer consumes (-1 is
    # the network input).  Empty = "the previous layer" — the linear default,
    # under which both DPs take the original chain code path unchanged.
    inputs: Tuple[int, ...] = ()


def _resolved_inputs(layers: Sequence[LayerDesc]) -> List[Tuple[int, ...]]:
    """Per-layer producer indices with the linear default filled in."""
    rins: List[Tuple[int, ...]] = []
    for i, l in enumerate(layers):
        ins = tuple(l.inputs) if l.inputs else ((i - 1,) if i else (-1,))
        for p in ins:
            if p >= i or p < -1:
                raise ValueError(
                    f"layer {l.name!r}: input index {p} is not an earlier "
                    f"layer (layers must be topologically ordered)")
        rins.append(ins)
    return rins


def _is_linear(rins: Sequence[Tuple[int, ...]]) -> bool:
    return all(ins == ((i - 1,) if i else (-1,))
               for i, ins in enumerate(rins))


def _consumers(rins: Sequence[Tuple[int, ...]]) -> Dict[int, List[int]]:
    cons: Dict[int, List[int]] = {i: [] for i in range(-1, len(rins))}
    for i, ins in enumerate(rins):
        for p in ins:
            cons[p].append(i)
    return cons


def _pool_io_bytes(l: LayerDesc) -> Tuple[int, int]:
    p = l.pool
    ho = pool_out_hw(p.HW, p.F, p.S)   # shared with the pool kernels
    d = l.dtype_bytes
    return p.N * p.C * p.HW * p.HW * d, p.N * p.C * ho * ho * d


def _merge_io_bytes(l: LayerDesc, training: bool) -> int:
    """Modeled HBM bytes of a STANDALONE merge/branch layer.  ``add`` reads
    both operands and writes the sum (its backward is a pure gradient
    fan-out — routing, not traffic); ``concat``/``upsample`` stream read +
    write forward and again for the backward slice/reduction."""
    sz = int(np.prod(l.out_shape)) if l.out_shape else 0
    if l.kind == "add":
        return 3 * sz * l.dtype_bytes
    if l.kind in ("concat", "upsample"):
        return (4 if training else 2) * sz * l.dtype_bytes
    raise ValueError(l.kind)


def layer_cost(l: LayerDesc, layout: str, training: bool = False,
               cost_model: Optional[CostModel] = None) -> float:
    """Estimated seconds for this layer in this layout (forward, plus the
    backward direction when ``training``)."""
    cm = cost_model or default_cost_model()
    if l.kind == "conv" and l.conv is not None:
        t = cm.conv_cost(l.conv, layout, l.dtype_bytes).total_s
        if training:
            t += cm.conv_backward_cost(l.conv, layout, l.dtype_bytes,
                                       fused=False).total_s
        return t
    if l.kind == "pool" and l.pool is not None:
        # memory bound: bytes / bw, de-rated by tile utilization of the
        # layout's minormost dims (paper Fig. 6: NCHW pooling is strided)
        in_b, out_b = _pool_io_bytes(l)
        eff = 1.0 if layout == "CHWN" else 0.25   # strided window penalty
        bytes_ = in_b + out_b
        if training:                 # bwd: read g + read input (mask) + write
            bytes_ += 2 * in_b + out_b
        return bytes_ / (HBM_BW * eff)
    if l.kind == "act":
        n = float(np.prod(l.out_shape)) if l.out_shape else 0.0
        b = (5 if training else 2) * n * l.dtype_bytes
        return b / HBM_BW
    if l.kind in ("fc", "softmax", "flatten"):
        return 0.0     # layout-terminal (2-D)
    if l.kind in ("add", "concat", "upsample"):
        # merge/branch nodes are memory bound in either layout (elementwise /
        # channel-stack / nearest-neighbour expand all stream contiguously)
        return _merge_io_bytes(l, training) / HBM_BW
    # Anything else (lrn, or a conv/pool desc missing its descriptor) has no
    # executor behind it — cnn.network raises at run time, so refusing to
    # plan it here keeps planner and executor in agreement (ISSUE 3).
    raise ValueError(
        f"layer {l.name!r}: kind {l.kind!r} is not executable by the "
        "CNN engines; refusing to produce a plan the executor would reject")


def transform_cost(shape: Tuple[int, ...], dtype_bytes: int,
                   optimized: bool = True) -> float:
    """Seconds to re-layout a tensor of ``shape``; the optimized transform
    runs at ~streaming bandwidth (paper Fig. 11: up to 97.6% of peak), the
    naive one at ~1/8 of it."""
    eff = 0.9 if optimized else 0.12
    return transform_bytes(shape, dtype_bytes) / (HBM_BW * eff)


@dataclass
class Assignment:
    layouts: List[str]
    transforms: List[int]           # indices i where a transform happens before layer i
    total_s: float
    dtypes: List[str] = field(default_factory=list)  # per-layer storage dtype


def assign_layouts(layers: Sequence[LayerDesc], *,
                   input_layout: str = "NCHW",
                   input_shape: Optional[Tuple[int, ...]] = None,
                   optimized_transform: bool = True,
                   training: bool = False,
                   measure: Optional[Callable[[LayerDesc, str], float]] = None,
                   thresholds: Optional[Thresholds] = None,
                   dtype_policy: str = "uniform",
                   base_dtype: Optional[str] = None,
                   cost_model: Optional[CostModel] = None) -> Assignment:
    """Shortest-path over (layer, layout) states (the UNFUSED engine's plan;
    ``plan_fused`` is the variant whose edges fold into kernel I/O maps).

    ``input_shape`` is the logical NCHW shape of the *network input* — the
    tensor transformed by an i == 0 layout change (which generally differs
    from ``layers[0].out_shape``).  ``training`` plans the whole training
    graph: node costs include the backward direction and every transform
    edge is paid twice (the activation re-layout forward, its reversed twin
    on the gradient coming back).

    ``dtype_policy="mixed"`` widens the state space to (layout, storage
    dtype): a conv layer's output may be stored int8, but the unfused engine
    has no epilogue to fold the casts into, so quantize costs a standalone
    pass on the edge leaving the node and dequantize another on the edge
    into the consumer (``cast_cost``).  Both are strictly positive on top of
    the uniform path, so this DP degenerates to the uniform assignment — the
    search is kept because proving that is the point (mixed dtypes pay only
    under fusion; see DESIGN.md §9).
    """
    if dtype_policy not in DTYPE_POLICIES:
        raise ValueError(f"unknown dtype_policy {dtype_policy!r}; "
                         f"known: {DTYPE_POLICIES}")
    cm = cost_model or default_cost_model()
    cost_fn = measure or (lambda l, lay: layer_cost(l, lay, training, cm))
    n = len(layers)
    INF = float("inf")
    in_shape = tuple(input_shape) if input_shape else (
        layers[0].out_shape if layers else ())
    base = _base_dtype_name(layers, base_dtype)
    base_db = layers[0].dtype_bytes if layers else _dtype_bytes(base)
    rins = _resolved_inputs(layers)
    if not _is_linear(rins):
        return _assign_layouts_graph(
            layers, rins, input_layout=input_layout, in_shape=in_shape,
            optimized_transform=optimized_transform, training=training,
            cost_fn=cost_fn, dtype_policy=dtype_policy, base=base,
            base_db=base_db, cm=cm)
    tx = 2 if training else 1        # gradients re-cross every edge

    def cands(i: int) -> Tuple[str, ...]:
        # conv outputs may store int8 (unfused: never pays, but searched);
        # the last layer's output is the network result — keep it base
        if (dtype_policy == "mixed" and i + 1 < n
                and layers[i].kind == "conv"):
            return (base, INT8_DTYPE)
        return (base,)

    # dp[(layout, dtype)] = (cost, path of (layout, dtype)); start in the
    # input layout/base dtype only — the i == 0 edge below prices any
    # immediate re-layout of the network input
    State = Tuple[str, str]
    dp: Dict[State, Tuple[float, List[State]]] = {
        (lay, base): ((0.0 if lay == input_layout else INF), [(lay, base)])
        for lay in LAYOUTS}
    for i, l in enumerate(layers):
        ndp: Dict[State, Tuple[float, List[State]]] = {}
        for lay in LAYOUTS:
            for dt in cands(i):
                best, path = INF, None
                for (prev, prev_dt), (c0, p0) in dp.items():
                    edge = 0.0
                    # the layer input (= previous layer's output; the
                    # network input when i == 0)
                    shape = layers[i - 1].out_shape if i else in_shape
                    if prev_dt != base:     # dequant pass before compute
                        edge += tx * cm.cast_cost(shape,
                                                  _dtype_bytes(prev_dt),
                                                  base_db)
                    if prev != lay:
                        edge += tx * transform_cost(shape,
                                                    _dtype_bytes(prev_dt),
                                                    optimized_transform)
                    if dt != base:          # quant pass after compute
                        edge += tx * cm.cast_cost(l.out_shape, base_db,
                                                  _dtype_bytes(dt))
                    c = c0 + edge + cost_fn(l, lay)
                    if c < best:
                        best, path = c, p0 + [(lay, dt)]
                ndp[(lay, dt)] = (best, path)
        dp = ndp
    st_best = min(dp, key=lambda k: dp[k][0])
    total, path = dp[st_best]
    layouts = [st[0] for st in path[1:]]
    dtypes = [st[1] for st in path[1:]]
    transforms = [i for i in range(n)
                  if (layouts[i] != (layouts[i - 1] if i else input_layout))]
    return Assignment(layouts=layouts, transforms=transforms, total_s=total,
                      dtypes=dtypes)


def paper_heuristic_layouts(layers: Sequence[LayerDesc],
                            th: Thresholds) -> List[str]:
    """The paper's §IV.D single-scan field assignment (no DP)."""
    out = []
    cur = "NCHW"
    for l in layers:
        if l.kind == "conv" and l.conv is not None:
            cur = select_conv_layout(l.conv, th)
        elif l.kind == "pool":
            cur = select_pool_layout(l.pool)
        out.append(cur)    # act/fc/softmax inherit the incoming layout
    return out


# ---------------------------------------------------------------------------
# fused-op planning (DESIGN.md §5)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FusedOp:
    """One node of the fused execution plan.

    ``layout`` is the layout the kernel computes in; ``src_layout`` /
    ``dst_layout`` are the layouts it consumes/produces (folded re-layouts
    when they differ from ``layout``).  For conv nodes, ``relu`` and
    ``pool_index`` mark the folded epilogue layers.  ``src_dtype`` /
    ``dst_dtype`` are the STORAGE dtypes of the tensors the node reads /
    writes in HBM (mixed-dtype plans store interior activations as int8:
    the epilogue quantizes, the consumer conv dequantizes in VMEM).  Empty
    string means "the run's dtype" — plans persisted before ISSUE 5 load
    with that value and behave exactly as before.
    """
    kind: str                       # conv | pool | act | fc | softmax |
                                    # flatten | add | concat | upsample
    index: int                      # primary layer index in the LayerDesc list
    name: str
    layout: str
    src_layout: str
    dst_layout: str
    relu: bool = False
    pool_index: Optional[int] = None
    src_dtype: str = ""
    dst_dtype: str = ""
    # Graph fields (DESIGN.md §11).  Defaults keep pre-DAG persisted plans
    # loading unchanged through ``FusedOp(**op)``.
    inputs: Tuple[int, ...] = ()    # producer layer indices (main input first)
    out_index: int = -1             # layer index whose output this op stores
    add_index: Optional[int] = None   # residual-add layer folded into this op
    res_index: Optional[int] = None   # producer layer of the folded skip tensor
    res_layout: str = ""            # stored layout of the folded skip tensor
    # Cross-layer stack fusion (DESIGN.md §12).  A conv op with
    # ``stack_index`` set runs TWO convs in one kernel: ``index`` is the
    # first conv, ``stack_index`` the second; ``stack_relu`` is the act
    # folded between them, and relu/pool_index/add_index/res_index describe
    # the SECOND conv's epilogue.  The intermediate activation never touches
    # HBM.  Defaults keep pre-stack persisted plans loading unchanged.
    stack_index: Optional[int] = None
    stack_relu: bool = False

    def __post_init__(self):
        # JSON roundtrips tuples as lists; normalize so loaded plans compare
        # equal to freshly planned ones
        if not isinstance(self.inputs, tuple):
            object.__setattr__(self, "inputs", tuple(self.inputs))

    @property
    def is_fused(self) -> bool:
        return (self.relu or self.pool_index is not None or
                self.res_index is not None or
                self.stack_index is not None or
                self.src_layout != self.layout or
                self.dst_layout != self.layout)


# one-letter storage-dtype codes for plan signatures (reports/benchmarks)
DTYPE_CODES = {"float32": "f", "bfloat16": "b", "float16": "h", "int8": "8",
               "": "?"}


@dataclass
class FusedPlan:
    layouts: List[str]              # per-layer layout (DP assignment)
    ops: List[FusedOp]              # execution nodes, in order
    transforms: List[int]           # layer indices needing a STANDALONE pass
    total_s: float                  # modeled seconds under the fused engine
    fused_bytes: int                # modeled HBM bytes, fused engine
    unfused_bytes: int              # same layouts executed unfused
    dtypes: List[str] = field(default_factory=list)  # per-layer storage dtype
    base_dtype: str = ""            # the float dtype non-int8 layers run in
    # HBM bytes still round-tripping through the mid activation of adjacent,
    # structurally stackable conv pairs the planner did NOT fuse (DESIGN.md
    # §12) — zero when every such pair either fused or was legitimately
    # ineligible (VMEM bound, recompute arbitration, overlap with a fused
    # stack).  The fusion bench gates this at exactly zero, so a regression
    # that silently reintroduces the round trip fails CI.
    intermediate_roundtrip_bytes: int = 0

    @property
    def saved_bytes(self) -> int:
        return self.unfused_bytes - self.fused_bytes

    @property
    def conv_signature(self) -> str:
        """One letter per conv LAYER ('C'HWN / 'N'CHW) — the compact form the
        serving report and benchmarks use to show batch-dependent flips.  A
        stack op covers two conv layers in one kernel and contributes two
        (identical) letters, so the signature length is stable across
        stacking decisions."""
        return "".join(op.layout[0] * (2 if op.stack_index is not None else 1)
                       for op in self.ops if op.kind == "conv")

    @property
    def dtype_signature(self) -> str:
        """One letter per conv LAYER's OUTPUT storage dtype (f/b/h/8) — shows
        where the mixed DP placed the int8 layers.  A stack op's first conv
        never stores its output (that is the point); it reports the op's
        stored dtype so the signature length matches ``conv_signature``."""
        return "".join(DTYPE_CODES.get(op.dst_dtype, "?")
                       * (2 if op.stack_index is not None else 1)
                       for op in self.ops if op.kind == "conv")

    @property
    def stacked_convs(self) -> int:
        """Conv->conv stacks fused into single kernels (DESIGN.md §12)."""
        return sum(1 for op in self.ops
                   if op.kind == "conv" and op.stack_index is not None)

    @property
    def distinct_conv_dtypes(self) -> int:
        return len({op.dst_dtype for op in self.ops if op.kind == "conv"})

    @property
    def standalone_adds(self) -> int:
        """Residual adds the planner could NOT fold into a conv epilogue —
        the headline metric of DAG fusion (resnet18 plans at zero)."""
        return sum(1 for op in self.ops if op.kind == "add")


def _dst_layout(layers: Sequence[LayerDesc], layouts: Sequence[str],
                j: int, lay: str) -> str:
    """Layout a producer should write: the consumer's layout, or NCHW ahead
    of flatten/fc so the 2-D flatten is a free reshape."""
    if j >= len(layers):
        return lay
    if layers[j].kind in ("flatten", "fc", "softmax"):
        return "NCHW"
    return layouts[j]


@dataclass(frozen=True)
class _Group:
    """A fused-op DP node: a conv[->act][->pool] chain, a lone pool, or a
    passthrough layer.  The whole group executes in ONE layout (one kernel
    for conv chains), which is what makes its intermediates free."""
    start: int
    end: int                        # exclusive
    kind: str                       # chain head kind
    relu: bool = False
    pool_index: Optional[int] = None
    add_index: Optional[int] = None   # residual add folded into a conv group
    res_src: Optional[int] = None     # producer layer index of the skip tensor
    # Cross-layer stack pairing (DESIGN.md §12): a conv group absorbing a
    # SECOND conv group.  ``stack_index`` is the second conv's head layer,
    # ``stack_relu`` the act folded between the convs; relu/pool_index/
    # add_index/res_src above then describe the second conv's epilogue.
    stack_index: Optional[int] = None
    stack_relu: bool = False


def _group_layers(layers: Sequence[LayerDesc]) -> List[_Group]:
    groups: List[_Group] = []
    n = len(layers)
    flat = False
    i = 0
    while i < n:
        l = layers[i]
        if l.kind == "conv" and l.conv is not None and not flat:
            relu = False
            pool_idx = None
            j = i + 1
            if j < n and layers[j].kind == "act":
                relu = True          # elementwise: folds in any layout
                j += 1
            if j < n and layers[j].kind == "pool" and layers[j].pool is not None:
                pool_idx = j
                j += 1
            groups.append(_Group(i, j, "conv", relu, pool_idx))
            i = j
            continue
        if l.kind == "flatten":
            flat = True
        groups.append(_Group(i, i + 1, l.kind))
        i += 1
    return groups


def _group_layers_graph(layers: Sequence[LayerDesc],
                        rins: Sequence[Tuple[int, ...]],
                        cons: Dict[int, List[int]]) -> List[_Group]:
    """Graph grouping: a conv folds [->add][->act][->pool] when each folded
    layer is the SOLE consumer of its in-group predecessor — the group's
    interior tensors are then never needed elsewhere, which is exactly the
    condition under which they may skip HBM.  A corollary the DP relies on:
    every cross-group edge references a group TAIL (an interior layer with
    an external consumer would have blocked the fold that made it interior).
    On a linear graph this reproduces ``_group_layers`` exactly."""
    groups: List[_Group] = []
    n = len(layers)
    flat = False
    i = 0
    while i < n:
        l = layers[i]
        if l.kind == "conv" and l.conv is not None and not flat:
            relu = False
            pool_idx = None
            add_idx = None
            res_src = None
            j = i + 1
            if (j < n and layers[j].kind == "add" and cons[j - 1] == [j]
                    and (j - 1) in rins[j] and len(rins[j]) == 2):
                add_idx = j          # residual add -> conv epilogue
                res_src = next(p for p in rins[j] if p != j - 1)
                j += 1
            if (j < n and layers[j].kind == "act" and cons[j - 1] == [j]
                    and rins[j] == (j - 1,)):
                relu = True          # elementwise: folds in any layout
                j += 1
            if (j < n and layers[j].kind == "pool"
                    and layers[j].pool is not None and cons[j - 1] == [j]
                    and rins[j] == (j - 1,)):
                pool_idx = j
                j += 1
            groups.append(_Group(i, j, "conv", relu, pool_idx,
                                 add_index=add_idx, res_src=res_src))
            i = j
            continue
        if l.kind == "flatten":
            flat = True
        groups.append(_Group(i, i + 1, l.kind))
        i += 1
    return groups


def _group_pool(layers: Sequence[LayerDesc],
                g: _Group) -> Optional[Tuple[int, int]]:
    if g.pool_index is None:
        return None
    p = layers[g.pool_index].pool
    return (p.F, p.S)


# ---------------------------------------------------------------------------
# cross-layer stack pairing (DESIGN.md §12)
# ---------------------------------------------------------------------------

def _stackable_pair(layers: Sequence[LayerDesc], g1: _Group, g2: _Group,
                    rins: Sequence[Tuple[int, ...]],
                    cons: Dict[int, List[int]]) -> bool:
    """Structural predicate: (g1, g2) may run as one halo-fused stack kernel.
    g1 must be a bare conv[->act] group (no pool — the spatial decimation
    would break the halo arithmetic — and no folded residual), its tail must
    be the SOLE consumer edge into g2's MAIN conv input, and the geometry
    must chain (g2 reads exactly g1's output).  g2 keeps its full epilogue
    (add/act/pool) — the stack kernel runs it on the staged tile."""
    if g1.kind != "conv" or g2.kind != "conv":
        return False
    if g1.stack_index is not None or g2.stack_index is not None:
        return False
    l1, l2 = layers[g1.start].conv, layers[g2.start].conv
    if l1 is None or l2 is None:
        return False
    if g1.pool_index is not None or g1.add_index is not None:
        return False
    t1 = g1.end - 1
    if g2.start != g1.end:           # must be list-adjacent (topo order)
        return False
    if rins[g2.start] != (t1,) or cons[t1] != [g2.start]:
        return False
    return (l2.HW == l1.out_hw and l2.Ci == l1.Co and l2.N == l1.N)


def _stack_layouts(layers: Sequence[LayerDesc], g1: _Group, g2: _Group,
                   cm: CostModel) -> Tuple[str, ...]:
    """Layouts in which fusing (g1, g2) is both legal and profitable.

    Legal: the staged tile fits the VMEM budget (``stack_nt`` > 0).
    Profitable: the recomputed halo rows cost less time than the mid
    activation's round trip saves — Δcompute <= Δmemory on the roofline
    components — AND the stack moves strictly fewer HBM bytes than the two
    groups do separately.  This is the recompute-vs-round-trip arbitration
    the stack cost model exists for (DESIGN.md §12); an empty result means
    "do not pair" and the plan degenerates to the PR 6 shape byte-for-byte.
    """
    l1, l2 = layers[g1.start].conv, layers[g2.start].conv
    db = layers[g1.start].dtype_bytes
    pool_t = _group_pool(layers, g2)
    res = g2.add_index is not None
    b_stack = cm.stack_bytes(l1, l2, db, pool=pool_t, residual=res)
    b_pair = (cm.chain_bytes(l1, db, relu=g1.relu, fused=True) +
              cm.chain_bytes(l2, db, relu=g2.relu, pool=pool_t, fused=True,
                             residual=res))
    if b_stack >= b_pair:
        return ()
    out = []
    for lay in LAYOUTS:
        if cm.stack_nt(l1, l2, lay, db, pool=pool_t, residual=res) <= 0:
            continue                 # staged tile exceeds the VMEM bound
        c1 = cm.fused_chain_cost(l1, lay, db, relu=g1.relu)
        c2 = cm.fused_chain_cost(l2, lay, db, relu=g2.relu, pool=pool_t,
                                 residual=res)
        st = cm.stack_fused_cost(l1, l2, lay, db, pool=pool_t, residual=res)
        extra_compute = st.compute_s - (c1.compute_s + c2.compute_s)
        saved_memory = (c1.memory_s + c2.memory_s) - st.memory_s
        if extra_compute <= saved_memory:
            out.append(lay)
    return tuple(out)


def _pair_stacks(layers: Sequence[LayerDesc], groups: List[_Group],
                 rins: Sequence[Tuple[int, ...]],
                 cons: Dict[int, List[int]], cm: CostModel
                 ) -> Tuple[List[_Group], Dict[int, Tuple[str, ...]]]:
    """Greedy left-to-right pairing of adjacent conv groups into stack
    groups (like epilogue folding, the pairing is structural; the DP then
    arbitrates the stack's LAYOUT among the feasible set).  Returns the new
    group list and, keyed by new-group index, the feasible layouts of each
    stack group — the DP must not place a stack in a layout whose staged
    tile busts the VMEM budget."""
    out: List[_Group] = []
    stack_lays: Dict[int, Tuple[str, ...]] = {}
    i = 0
    while i < len(groups):
        g1 = groups[i]
        if i + 1 < len(groups):
            g2 = groups[i + 1]
            if _stackable_pair(layers, g1, g2, rins, cons):
                lays = _stack_layouts(layers, g1, g2, cm)
                if lays:
                    out.append(_Group(g1.start, g2.end, "conv", g2.relu,
                                      g2.pool_index, add_index=g2.add_index,
                                      res_src=g2.res_src,
                                      stack_index=g2.start,
                                      stack_relu=g1.relu))
                    stack_lays[len(out) - 1] = lays
                    i += 2
                    continue
        out.append(g1)
        i += 1
    return out, stack_lays


def _stack_miss_bytes(layers: Sequence[LayerDesc], groups: List[_Group],
                      rins: Sequence[Tuple[int, ...]],
                      cons: Dict[int, List[int]], cm: CostModel) -> int:
    """Round-trip HBM bytes of the mid activations of adjacent conv-group
    pairs that pass BOTH the structural predicate and the profitability
    arbitration yet are not fused in ``groups`` — the plan's
    ``intermediate_roundtrip_bytes``.  Zero after ``_pair_stacks`` by
    construction (every such pair got paired); nonzero means a profitable
    round trip was left on the table, which the bench trajectory gate treats
    as a regression with no tolerance."""
    missed = 0
    for g1, g2 in zip(groups, groups[1:]):
        if not _stackable_pair(layers, g1, g2, rins, cons):
            continue
        if not _stack_layouts(layers, g1, g2, cm):
            continue
        l1 = layers[g1.start].conv
        mid = l1.N * l1.Co * l1.out_hw * l1.out_hw
        missed += 2 * mid * layers[g1.start].dtype_bytes
    return missed


def _group_cost(layers: Sequence[LayerDesc], g: _Group, lay: str,
                training: bool = False,
                in_db: Optional[int] = None,
                out_db: Optional[int] = None,
                cm: Optional[CostModel] = None) -> float:
    cm = cm or default_cost_model()
    l = layers[g.start]
    if g.kind == "conv" and g.stack_index is not None:
        # stack groups are inference-only (pairing is gated on it)
        return cm.stack_fused_cost(l.conv, layers[g.stack_index].conv, lay,
                                   l.dtype_bytes,
                                   pool=_group_pool(layers, g),
                                   residual=g.add_index is not None,
                                   in_dtype_bytes=in_db,
                                   out_dtype_bytes=out_db).total_s
    if g.kind == "conv" and l.conv is not None:
        pool_t = _group_pool(layers, g)
        res = g.add_index is not None
        t = cm.fused_chain_cost(l.conv, lay, l.dtype_bytes,
                                relu=g.relu, pool=pool_t,
                                in_dtype_bytes=in_db,
                                out_dtype_bytes=out_db,
                                residual=res).total_s
        if training:
            # gradients stay at the base dtype — int8 is a forward-storage
            # lever; the backward chain is priced at the layer's dtype
            t += cm.conv_backward_cost(l.conv, lay, l.dtype_bytes,
                                       relu=g.relu, pool=pool_t, fused=True,
                                       residual=res).total_s
        return t
    return sum(layer_cost(layers[i], lay, training, cm)
               for i in range(g.start, g.end))


def _group_hbm_bytes(layers: Sequence[LayerDesc], g: _Group,
                     in_db: int, out_db: int, training: bool,
                     cm: Optional[CostModel] = None) -> int:
    """Secondary DP key: the group's modeled fused HBM bytes.  Layer kinds
    whose traffic is identical across all states (fc/act/flatten, standalone
    merges) contribute 0 — constants never move an argmin.  Time stays the
    primary objective; bytes break ties, which is what lets int8 win on
    compute-bound chains (the paper's currency is bytes moved)."""
    cm = cm or default_cost_model()
    l = layers[g.start]
    if g.kind == "conv" and g.stack_index is not None:
        return cm.stack_bytes(l.conv, layers[g.stack_index].conv,
                              l.dtype_bytes, pool=_group_pool(layers, g),
                              residual=g.add_index is not None,
                              in_dtype_bytes=in_db, out_dtype_bytes=out_db)
    if g.kind == "conv" and l.conv is not None:
        res = g.add_index is not None
        b = cm.chain_bytes(l.conv, l.dtype_bytes, relu=g.relu,
                           pool=_group_pool(layers, g), fused=True,
                           in_dtype_bytes=in_db, out_dtype_bytes=out_db,
                           residual=res)
        if training:
            b += cm.conv_backward_bytes(
                l.conv, "CHWN", l.dtype_bytes, relu=g.relu,
                pool=_group_pool(layers, g), fused=True,
                trainable=l.trainable, residual=res)
        return b
    if g.kind == "pool" and l.pool is not None:
        in_b, out_b = _pool_io_bytes(l)
        return in_b + out_b + ((2 * in_b + out_b) if training else 0)
    return 0


def plan_fused(layers: Sequence[LayerDesc], *,
               input_layout: str = "NCHW",
               input_shape: Optional[Tuple[int, ...]] = None,
               optimized_transform: bool = True,
               training: bool = False,
               dtype_policy: str = "uniform",
               base_dtype: Optional[str] = None,
               stack_policy: str = "auto",
               cost_model: Optional[CostModel] = None,
               _force_graph: bool = False) -> FusedPlan:
    """Turn a layer stack into a fused execution plan.

    Collapses conv[->relu][->pool] runs into fused-op nodes, then runs the
    shortest-path DP over (node, layout, storage dtype) states: node cost
    comes from the fusion cost model (``fused_chain_cost`` — the chain
    intermediate never hits HBM), and an edge costs zero when the re-layout
    folds into the producer's output write or the consumer conv's input
    read.  Standalone transform passes survive only where no adjacent kernel
    can fold them (never, for conv-led CNNs: the first layer is a conv and
    reads the host layout directly).

    ``dtype_policy="mixed"`` (DESIGN.md §9) lets interior conv chains store
    their output as int8: the quantize folds into the chain's epilogue (the
    f32 VMEM accumulator is scaled per channel on its way out) and the
    dequantize into the consumer conv's read (the per-channel scale folds
    exactly into the weights), so the dtype edge is as free as a folding
    layout edge.  Candidates are restricted to edges both sides can fold —
    conv-chain output consumed by another conv chain — and the first conv
    chain's output stays at the base dtype (early features are
    precision-sensitive; ZeroQuant/AWQ keep the first layer wide for the
    same reason).  Because the base-dtype path is always in the search
    space, the mixed plan is never worse than the uniform plan at the same
    base dtype.

    ``training`` plans the whole training graph: chain nodes add the
    custom-VJP backward (activation stash, one-kernel pool+mask backward,
    dgrad/wgrad) to both the time and byte models, the unfused comparison
    adds the XLA-decomposed backward, and non-folding transform edges are
    paid twice (forward + the reversed gradient re-layout) — folding edges
    stay free in BOTH directions, because dgrad consumes/produces through
    the same kernel I/O maps.  Gradients stay at the base dtype (the
    straight-through estimator passes them through int8 boundaries), so
    mixed plans shrink forward bytes only.

    ``stack_policy="auto"`` (DESIGN.md §12) additionally pairs adjacent
    conv groups into two-conv STACK nodes wherever a single halo-fused
    kernel is legal (VMEM-bounded staged tile) and profitable (recomputed
    halo rows cost less than the mid activation's round trip saves) — the
    intermediate between the convs then never touches HBM.  Stacks are an
    inference, uniform-dtype lever: training plans (the backward must
    rematerialize the mid) and mixed-dtype plans (int8 interior edges
    already shrink the round trip; composing packed storage with halo
    recompute is future work) never pair, and ``stack_policy="off"``
    disables pairing outright, degenerating byte-identically to the PR 6
    planner.
    """
    if dtype_policy not in DTYPE_POLICIES:
        raise ValueError(f"unknown dtype_policy {dtype_policy!r}; "
                         f"known: {DTYPE_POLICIES}")
    if stack_policy not in ("auto", "off"):
        raise ValueError(f"unknown stack_policy {stack_policy!r}; "
                         "known: ('auto', 'off')")
    cm = cost_model or default_cost_model()
    n = len(layers)
    in_shape = tuple(input_shape) if input_shape else (
        layers[0].out_shape if layers else ())
    base = _base_dtype_name(layers, base_dtype)
    rins = _resolved_inputs(layers)
    if not _is_linear(rins) or _force_graph:
        # branching networks take the frontier DP (DESIGN.md §11); linear
        # ones stay on the chain DP below, byte-identical to the pre-DAG
        # planner (``_force_graph`` exists so tests can prove the graph
        # path degenerates to the same plan)
        return _plan_fused_graph(
            layers, rins, input_layout=input_layout, in_shape=in_shape,
            optimized_transform=optimized_transform, training=training,
            dtype_policy=dtype_policy, base=base, stack_policy=stack_policy,
            cm=cm)

    def _in_shape(i: int) -> Tuple[int, ...]:
        return layers[i - 1].out_shape if i else in_shape

    groups = _group_layers(layers)
    cons = _consumers(rins)
    stack_lays: Dict[int, Tuple[str, ...]] = {}
    if stack_policy == "auto" and not training and dtype_policy == "uniform":
        groups, stack_lays = _pair_stacks(layers, groups, rins, cons, cm)
    roundtrip_b = _stack_miss_bytes(layers, groups, rins, cons, cm)
    first_conv = next((gi for gi, g in enumerate(groups)
                       if g.kind == "conv"), -1)

    def gcands(gi: int) -> Tuple[str, ...]:
        # a group's OUTPUT may store int8 only when both casts fold: the
        # producer is a conv chain (epilogue quantizes) and the consumer is
        # a conv chain (dequantizes in VMEM); the first conv chain stays at
        # base (precision-sensitive early features)
        g = groups[gi]
        if (dtype_policy == "mixed" and g.kind == "conv" and gi > first_conv
                and gi + 1 < len(groups) and groups[gi + 1].kind == "conv"):
            return (base, INT8_DTYPE)
        return (base,)

    # DP over (group, layout, out dtype); layout edges fold into conv/pool
    # kernel I/O maps, dtype edges into conv epilogues/reads (see gcands).
    # Costs are lexicographic (seconds, HBM bytes): on compute-bound chains
    # the roofline max() hides byte savings, and the byte tie-break is what
    # makes the dtype dimension decisive there.
    INF = (float("inf"), float("inf"))
    State = Tuple[str, str]
    dp: Dict[State, Tuple[Tuple[float, float], List[State]]] = {
        (lay, base): (((0.0, 0.0) if lay == input_layout else INF), [])
        for lay in LAYOUTS}
    for gi, g in enumerate(groups):
        l = layers[g.start]
        ndp: Dict[State, Tuple[Tuple[float, float], List[State]]] = {}
        # stack groups may only run in layouts whose staged tile fits VMEM
        for lay in stack_lays.get(gi, LAYOUTS):
            for dt in gcands(gi):
                best, path = INF, None
                for (prev, prev_dt), (c0, p0) in dp.items():
                    edge_s, edge_b = 0.0, 0.0
                    if prev != lay:
                        prev_g = groups[len(p0) - 1] if p0 else None
                        folds = (g.kind == "conv" or
                                 (prev_g is not None and
                                  prev_g.kind in ("conv", "pool")))
                        if not folds:
                            tx_e = 2 if training else 1
                            edge_s = tx_e * transform_cost(
                                _in_shape(g.start), _dtype_bytes(prev_dt),
                                optimized_transform)
                            edge_b = tx_e * transform_bytes(
                                _in_shape(g.start), _dtype_bytes(prev_dt))
                    in_db, out_db = _dtype_bytes(prev_dt), _dtype_bytes(dt)
                    c = (c0[0] + edge_s +
                         _group_cost(layers, g, lay, training,
                                     in_db=in_db, out_db=out_db, cm=cm),
                         c0[1] + edge_b +
                         _group_hbm_bytes(layers, g, in_db, out_db,
                                          training, cm))
                    if c < best:
                        best, path = c, p0 + [(lay, dt)]
                ndp[(lay, dt)] = (best, path)
        dp = ndp
    st_best = min(dp, key=lambda k: dp[k][0])
    _, gpath = dp[st_best]
    layouts: List[str] = [""] * n
    dtypes: List[str] = [base] * n
    for g, (glay, gdt) in zip(groups, gpath):
        for i in range(g.start, g.end):
            layouts[i] = glay
            dtypes[i] = gdt

    ops: List[FusedOp] = []
    transforms: List[int] = []
    total = 0.0
    fused_b = 0
    unfused_b = 0
    cur = input_layout
    cur_dt = base
    flat = False
    for g, (lay, gdt) in zip(groups, gpath):
        i = g.start
        l = layers[i]
        tx = 2 if training else 1    # gradients re-layout back through edges
        if g.kind == "conv" and g.stack_index is not None:
            dst = _dst_layout(layers, layouts, g.end, lay)
            pool_t = _group_pool(layers, g)
            in_db, out_db = _dtype_bytes(cur_dt), _dtype_bytes(gdt)
            l2 = layers[g.stack_index]
            ops.append(FusedOp("conv", i, l.name, lay, cur, dst,
                               relu=g.relu, pool_index=g.pool_index,
                               src_dtype=cur_dt, dst_dtype=gdt,
                               stack_index=g.stack_index,
                               stack_relu=g.stack_relu))
            total += cm.stack_fused_cost(l.conv, l2.conv, lay, l.dtype_bytes,
                                         pool=pool_t, residual=False,
                                         in_dtype_bytes=in_db,
                                         out_dtype_bytes=out_db).total_s
            fused_b += cm.stack_bytes(l.conv, l2.conv, l.dtype_bytes,
                                      pool=pool_t, residual=False,
                                      in_dtype_bytes=in_db,
                                      out_dtype_bytes=out_db)
            # the unfused comparison runs both convs separately, mid
            # activation round-tripping through HBM
            unfused_b += (cm.chain_bytes(l.conv, l.dtype_bytes,
                                         relu=g.stack_relu, fused=False) +
                          cm.chain_bytes(l2.conv, l.dtype_bytes, relu=g.relu,
                                         pool=pool_t, fused=False))
            if cur != lay:           # folded into the kernel's input read
                unfused_b += tx * transform_bytes(_in_shape(i), l.dtype_bytes)
            if dst != lay:           # folded into the kernel's output write
                unfused_b += tx * transform_bytes(
                    layers[g.end - 1].out_shape, l.dtype_bytes)
            cur = dst
            cur_dt = gdt
            continue
        if g.kind == "conv":
            dst = _dst_layout(layers, layouts, g.end, lay)
            pool_t = _group_pool(layers, g)
            in_db, out_db = _dtype_bytes(cur_dt), _dtype_bytes(gdt)
            ops.append(FusedOp("conv", i, l.name, lay, cur, dst,
                               relu=g.relu, pool_index=g.pool_index,
                               src_dtype=cur_dt, dst_dtype=gdt))
            total += cm.fused_chain_cost(l.conv, lay, l.dtype_bytes,
                                         relu=g.relu, pool=pool_t,
                                         in_dtype_bytes=in_db,
                                         out_dtype_bytes=out_db).total_s
            fused_b += cm.chain_bytes(l.conv, l.dtype_bytes, relu=g.relu,
                                      pool=pool_t, fused=True,
                                      in_dtype_bytes=in_db,
                                      out_dtype_bytes=out_db)
            # the unfused comparison runs uniformly at the base dtype — the
            # unfused engine has no epilogue to fold the casts into
            unfused_b += cm.chain_bytes(l.conv, l.dtype_bytes, relu=g.relu,
                                        pool=pool_t, fused=False)
            if training:
                total += cm.conv_backward_cost(l.conv, lay, l.dtype_bytes,
                                               relu=g.relu, pool=pool_t,
                                               fused=True).total_s
                fused_b += cm.conv_backward_bytes(
                    l.conv, lay, l.dtype_bytes, relu=g.relu, pool=pool_t,
                    fused=True, trainable=l.trainable)
                unfused_b += cm.conv_backward_bytes(
                    l.conv, lay, l.dtype_bytes, relu=g.relu, pool=pool_t,
                    fused=False, trainable=l.trainable)
            if cur != lay:           # folded into the kernel's input read
                unfused_b += tx * transform_bytes(_in_shape(i), l.dtype_bytes)
            if dst != lay:           # folded into the kernel's output write
                unfused_b += tx * transform_bytes(
                    layers[g.end - 1].out_shape, l.dtype_bytes)
            cur = dst
            cur_dt = gdt
            continue
        if g.kind == "pool" and l.pool is not None and not flat:
            if cur != lay:           # no producer to fold into: standalone
                transforms.append(i)
                total += tx * transform_cost(_in_shape(i), l.dtype_bytes,
                                             optimized_transform)
                tb = tx * transform_bytes(_in_shape(i), l.dtype_bytes)
                fused_b += tb
                unfused_b += tb
                cur = lay
            dst = _dst_layout(layers, layouts, g.end, lay)
            ops.append(FusedOp("pool", i, l.name, lay, cur, dst,
                               src_dtype=cur_dt, dst_dtype=gdt))
            total += layer_cost(l, lay, training, cm)
            in_b, out_b = _pool_io_bytes(l)
            io_b = in_b + out_b
            if training:             # bwd: read g + read input (mask) + write
                io_b += 2 * in_b + out_b
            fused_b += io_b
            unfused_b += io_b
            if dst != lay:           # folded into the pool's output write
                unfused_b += tx * transform_bytes(l.out_shape, l.dtype_bytes)
            cur = dst
            continue
        # layout-terminal / elementwise leftovers
        sz = int(np.prod(l.out_shape)) if l.out_shape else 0
        if l.kind == "flatten":
            flat = True
            fused_b += tx * 2 * sz * l.dtype_bytes if cur == "CHWN" else 0
            unfused_b += tx * 2 * sz * l.dtype_bytes if lay == "CHWN" else 0
        elif l.kind == "fc":
            in_f = (int(np.prod(layers[i - 1].out_shape)) // l.out_shape[0]
                    if i else l.out_shape[1])
            io_b = (int(np.prod(l.out_shape)) + in_f * l.out_shape[1] +
                    l.out_shape[1] + in_f * l.out_shape[0]) * l.dtype_bytes
            if training:             # dx = g W^T, dW = x^T g, db
                io_b *= 2
            fused_b += io_b
            unfused_b += io_b
        else:                        # act / softmax
            total += layer_cost(l, lay, training, cm)
            io_b = (5 if training else 2) * sz * l.dtype_bytes
            fused_b += io_b
            unfused_b += io_b
        ops.append(FusedOp(l.kind, i, l.name, lay, cur, cur if flat else lay,
                           src_dtype=cur_dt, dst_dtype=gdt))
    return FusedPlan(layouts=layouts, ops=ops, transforms=transforms,
                     total_s=total, fused_bytes=fused_b,
                     unfused_bytes=unfused_b, dtypes=dtypes,
                     base_dtype=base,
                     intermediate_roundtrip_bytes=roundtrip_b)


# ---------------------------------------------------------------------------
# DAG planning (DESIGN.md §11)
# ---------------------------------------------------------------------------

def _assign_layouts_graph(layers: Sequence[LayerDesc],
                          rins: Sequence[Tuple[int, ...]], *,
                          input_layout: str, in_shape: Tuple[int, ...],
                          optimized_transform: bool, training: bool,
                          cost_fn: Callable[[LayerDesc, str], float],
                          dtype_policy: str, base: str, base_db: int,
                          cm: Optional[CostModel] = None) -> Assignment:
    """Frontier DP over a DAG for the UNFUSED engine.  The state is the
    (layout, dtype) of every LIVE edge — a produced tensor still awaiting a
    consumer — so a merge node prices the transform/cast of each incoming
    branch independently, and a fork's producer is paid once while every
    consumer pays its own mismatch.  On a linear graph this is the same
    shortest path ``assign_layouts`` computes (one live edge at all times)."""
    cm = cm or default_cost_model()
    n = len(layers)
    cons = _consumers(rins)
    # an edge retires after its LAST consumer runs
    last_use = {p: max(c) for p, c in cons.items() if c}
    tx = 2 if training else 1

    def cands(i: int) -> Tuple[str, ...]:
        if (dtype_policy == "mixed" and i + 1 < n
                and layers[i].kind == "conv"):
            return (base, INT8_DTYPE)
        return (base,)

    def shape_of(p: int) -> Tuple[int, ...]:
        return in_shape if p < 0 else layers[p].out_shape

    # state: sorted tuple of (producer layer index, layout, dtype); -1 is
    # the network input
    State = Tuple[Tuple[int, str, str], ...]
    init: State = ((-1, input_layout, base),)
    dp: Dict[State, Tuple[float, List[Tuple[str, str]]]] = {init: (0.0, [])}
    for i, l in enumerate(layers):
        ndp: Dict[State, Tuple[float, List[Tuple[str, str]]]] = {}
        for st, (c0, asg) in dp.items():
            by_p = {e[0]: (e[1], e[2]) for e in st}
            for lay in LAYOUTS:
                for dt in cands(i):
                    c = c0 + cost_fn(l, lay)
                    for p in rins[i]:
                        p_lay, p_dt = by_p[p]
                        sh = shape_of(p)
                        if p_dt != base:    # dequant pass before compute
                            c += tx * cm.cast_cost(sh, _dtype_bytes(p_dt),
                                                   base_db)
                        if p_lay != lay:
                            c += tx * transform_cost(sh, _dtype_bytes(p_dt),
                                                     optimized_transform)
                    if dt != base:          # quant pass after compute
                        c += tx * cm.cast_cost(l.out_shape, base_db,
                                               _dtype_bytes(dt))
                    nst = tuple(sorted(
                        [e for e in st if last_use.get(e[0], -1) > i] +
                        ([(i, lay, dt)] if last_use.get(i, -1) > i else [])))
                    prev = ndp.get(nst)
                    if prev is None or c < prev[0]:
                        ndp[nst] = (c, asg + [(lay, dt)])
        dp = ndp
    total, path = min(dp.values(), key=lambda v: v[0])
    layouts = [st[0] for st in path]
    dtypes = [st[1] for st in path]
    transforms = [i for i in range(n)
                  if any((layouts[p] if p >= 0 else input_layout)
                         != layouts[i] for p in rins[i])]
    return Assignment(layouts=layouts, transforms=transforms, total_s=total,
                      dtypes=dtypes)


def _plan_fused_graph(layers: Sequence[LayerDesc],
                      rins: Sequence[Tuple[int, ...]], *,
                      input_layout: str, in_shape: Tuple[int, ...],
                      optimized_transform: bool, training: bool,
                      dtype_policy: str, base: str,
                      stack_policy: str = "auto",
                      cm: Optional[CostModel] = None) -> FusedPlan:
    """Fused-op planning over a DAG (DESIGN.md §11).

    Groups are conv[->add][->act][->pool] chains built by
    ``_group_layers_graph`` — a residual add rides the conv epilogue (the
    skip tensor is read straight into the VMEM accumulator through a second,
    layout-folding BlockSpec), so it costs ONE extra stream read instead of
    a standalone read+read+write pass.  The DP is a frontier DP: the state
    is the (stored layout, dtype) of every live group-output edge, and each
    incoming edge of a group prices per its role:

    * ``main`` — free when the consumer is a conv (input BlockSpec folds the
      read) or when the producer is a conv/pool whose SOLE consumer this is
      (output BlockSpec folds the write); otherwise a standalone transform.
    * ``aux`` — second operand of a standalone add/concat: pays a transform
      on any layout mismatch (no kernel to fold into).
    * ``res`` — the folded skip tensor: free in ANY layout (that is the
      point of the second BlockSpec).

    Mixed-dtype candidates keep the chain DP's fold-or-forget discipline:
    a group may store int8 only when its tail has exactly one consumer and
    that consumer is a conv group reading it as the MAIN input — a skip or
    concat consumer keeps the edge at the base dtype, which is how the
    merge-node dtype join stays correct by construction."""
    cm = cm or default_cost_model()
    n = len(layers)
    cons = _consumers(rins)
    groups = _group_layers_graph(layers, rins, cons)
    stack_lays: Dict[int, Tuple[str, ...]] = {}
    if stack_policy == "auto" and not training and dtype_policy == "uniform":
        groups, stack_lays = _pair_stacks(layers, groups, rins, cons, cm)
    roundtrip_b = _stack_miss_bytes(layers, groups, rins, cons, cm)
    g_of: Dict[int, int] = {}
    for gi, g in enumerate(groups):
        for i in range(g.start, g.end):
            g_of[i] = gi
    # producer layer index -> last consuming GROUP index (edge lifetime)
    last_g: Dict[int, int] = {}
    for p, cs in cons.items():
        ext = [g_of[c] for c in cs if p < 0 or g_of[c] != g_of[p]]
        if ext:
            last_g[p] = max(ext)
    first_conv = next((gi for gi, g in enumerate(groups)
                       if g.kind == "conv"), -1)

    def shape_of(p: int) -> Tuple[int, ...]:
        return in_shape if p < 0 else layers[p].out_shape

    def gcands(gi: int) -> Tuple[str, ...]:
        g = groups[gi]
        if (dtype_policy != "mixed" or g.kind != "conv"
                or gi <= first_conv):
            return (base,)
        t = g.end - 1
        cs = cons[t]
        if len(cs) != 1:             # forks must stay castable-free: base
            return (base,)
        c = cs[0]
        cg = groups[g_of[c]]
        if cg.kind == "conv" and c == cg.start and rins[c][0] == t:
            return (base, INT8_DTYPE)   # sole conv MAIN consumer: both fold
        return (base,)

    def edge_cost(g: _Group, lay: str, p: int, s_lay: str, s_dt: str,
                  role: str) -> Tuple[float, int]:
        if role == "res":
            return 0.0, 0            # second BlockSpec folds any layout
        if role == "main" and g.kind == "conv":
            return 0.0, 0            # conv reads any src layout (read-fold)
        if s_lay == lay:
            return 0.0, 0
        if (p >= 0 and groups[g_of[p]].kind in ("conv", "pool")
                and len(cons[p]) == 1):
            return 0.0, 0            # producer writes our layout (write-fold)
        tx_e = 2 if training else 1
        db = _dtype_bytes(s_dt)
        return (tx_e * transform_cost(shape_of(p), db, optimized_transform),
                tx_e * transform_bytes(shape_of(p), db))

    # frontier DP; state = sorted tuple of (producer layer, layout, dtype)
    INF = (float("inf"), float("inf"))
    State = Tuple[Tuple[int, str, str], ...]
    init: State = ((-1, input_layout, base),)
    dp: Dict[State, Tuple[Tuple[float, float], List[Tuple[str, str]]]] = {
        init: ((0.0, 0.0), [])}
    for gi, g in enumerate(groups):
        h = g.start
        ndp: Dict[State, Tuple[Tuple[float, float],
                               List[Tuple[str, str]]]] = {}
        for st, (c0, p0) in dp.items():
            by_p = {e[0]: (e[1], e[2]) for e in st}
            # stack groups may only run in layouts whose tile fits VMEM
            for lay in stack_lays.get(gi, LAYOUTS):
                for dt in gcands(gi):
                    s, b = c0
                    in_db = None
                    for k, p in enumerate(rins[h]):
                        s_lay, s_dt = by_p[p]
                        role = "main" if k == 0 else "aux"
                        es, eb = edge_cost(g, lay, p, s_lay, s_dt, role)
                        s += es
                        b += eb
                        if role == "main":
                            in_db = _dtype_bytes(s_dt)
                    out_db = _dtype_bytes(dt)
                    s += _group_cost(layers, g, lay, training,
                                     in_db=in_db, out_db=out_db, cm=cm)
                    b += _group_hbm_bytes(layers, g, in_db, out_db,
                                          training, cm)
                    t = g.end - 1
                    nst = tuple(sorted(
                        [e for e in st if last_g.get(e[0], -1) > gi] +
                        ([(t, lay, dt)] if last_g.get(t, -1) > gi else [])))
                    prev = ndp.get(nst)
                    if prev is None or (s, b) < prev[0]:
                        ndp[nst] = ((s, b), p0 + [(lay, dt)])
        dp = ndp
    _, gpath = min(dp.values(), key=lambda v: v[0])

    layouts: List[str] = [""] * n
    dtypes: List[str] = [base] * n
    for g, (glay, gdt) in zip(groups, gpath):
        for i in range(g.start, g.end):
            layouts[i] = glay
            dtypes[i] = gdt

    # --- emission -----------------------------------------------------------
    # stored[p] = (layout, dtype) the tensor produced by layer p sits in HBM
    # as; write-folds (a conv/pool producer with a sole consumer writes the
    # consumer's preferred layout directly) are applied here, so a consumer
    # pays a standalone transform exactly when stored layout != its layout
    # and it cannot read-fold.
    stored: Dict[int, Tuple[str, str]] = {-1: (input_layout, base)}
    ops: List[FusedOp] = []
    transforms: List[int] = []
    total = 0.0
    fused_b = 0
    unfused_b = 0
    tx = 2 if training else 1
    flat = False
    for gi, (g, (lay, gdt)) in enumerate(zip(groups, gpath)):
        h = g.start
        l = layers[h]
        t = g.end - 1
        cs = cons[t]
        dst = lay
        if len(cs) == 1 and g.kind in ("conv", "pool") and not flat:
            c = cs[0]
            cg = groups[g_of[c]]
            if cg.add_index == c and cg.res_src == t:
                dst = lay            # a res read folds any layout: keep ours
            elif layers[c].kind in ("flatten", "fc", "softmax"):
                dst = "NCHW"         # free 2-D reshape ahead of the head
            else:
                dst = layouts[c]
        stored[t] = (dst, gdt)
        if g.kind == "conv" and g.stack_index is not None:
            p = rins[h][0]
            src_lay, src_dt = stored[p]
            in_db, out_db = _dtype_bytes(src_dt), _dtype_bytes(gdt)
            pool_t = _group_pool(layers, g)
            res = g.add_index is not None
            res_lay = stored[g.res_src][0] if res else ""
            l2 = layers[g.stack_index]
            ops.append(FusedOp("conv", h, l.name, lay, src_lay, dst,
                               relu=g.relu, pool_index=g.pool_index,
                               src_dtype=src_dt, dst_dtype=gdt,
                               inputs=(p,), out_index=t,
                               add_index=g.add_index, res_index=g.res_src,
                               res_layout=res_lay,
                               stack_index=g.stack_index,
                               stack_relu=g.stack_relu))
            total += cm.stack_fused_cost(l.conv, l2.conv, lay, l.dtype_bytes,
                                         pool=pool_t, residual=res,
                                         in_dtype_bytes=in_db,
                                         out_dtype_bytes=out_db).total_s
            fused_b += cm.stack_bytes(l.conv, l2.conv, l.dtype_bytes,
                                      pool=pool_t, residual=res,
                                      in_dtype_bytes=in_db,
                                      out_dtype_bytes=out_db)
            unfused_b += (cm.chain_bytes(l.conv, l.dtype_bytes,
                                         relu=g.stack_relu, fused=False) +
                          cm.chain_bytes(l2.conv, l.dtype_bytes, relu=g.relu,
                                         pool=pool_t, fused=False,
                                         residual=res))
            if src_lay != lay:       # folded into the kernel's input read
                unfused_b += tx * transform_bytes(shape_of(p), l.dtype_bytes)
            if dst != lay:           # folded into the kernel's output write
                unfused_b += tx * transform_bytes(layers[t].out_shape,
                                                  l.dtype_bytes)
            if res and res_lay != lay:   # folded into the skip's second read
                unfused_b += tx * transform_bytes(shape_of(g.res_src),
                                                  l.dtype_bytes)
            continue
        if g.kind == "conv":
            p = rins[h][0]
            src_lay, src_dt = stored[p]
            in_db, out_db = _dtype_bytes(src_dt), _dtype_bytes(gdt)
            pool_t = _group_pool(layers, g)
            res = g.add_index is not None
            res_lay = stored[g.res_src][0] if res else ""
            ops.append(FusedOp("conv", h, l.name, lay, src_lay, dst,
                               relu=g.relu, pool_index=g.pool_index,
                               src_dtype=src_dt, dst_dtype=gdt,
                               inputs=(p,), out_index=t,
                               add_index=g.add_index, res_index=g.res_src,
                               res_layout=res_lay))
            total += cm.fused_chain_cost(l.conv, lay, l.dtype_bytes,
                                         relu=g.relu, pool=pool_t,
                                         in_dtype_bytes=in_db,
                                         out_dtype_bytes=out_db,
                                         residual=res).total_s
            fused_b += cm.chain_bytes(l.conv, l.dtype_bytes, relu=g.relu,
                                      pool=pool_t, fused=True,
                                      in_dtype_bytes=in_db,
                                      out_dtype_bytes=out_db, residual=res)
            unfused_b += cm.chain_bytes(l.conv, l.dtype_bytes, relu=g.relu,
                                        pool=pool_t, fused=False,
                                        residual=res)
            if training:
                total += cm.conv_backward_cost(l.conv, lay, l.dtype_bytes,
                                               relu=g.relu, pool=pool_t,
                                               fused=True,
                                               residual=res).total_s
                fused_b += cm.conv_backward_bytes(
                    l.conv, lay, l.dtype_bytes, relu=g.relu, pool=pool_t,
                    fused=True, trainable=l.trainable, residual=res)
                unfused_b += cm.conv_backward_bytes(
                    l.conv, lay, l.dtype_bytes, relu=g.relu, pool=pool_t,
                    fused=False, trainable=l.trainable, residual=res)
            if src_lay != lay:       # folded into the kernel's input read
                unfused_b += tx * transform_bytes(shape_of(p), l.dtype_bytes)
            if dst != lay:           # folded into the kernel's output write
                unfused_b += tx * transform_bytes(layers[t].out_shape,
                                                  l.dtype_bytes)
            if res and res_lay != lay:   # folded into the skip's second read
                unfused_b += tx * transform_bytes(shape_of(g.res_src),
                                                  l.dtype_bytes)
            continue
        if g.kind == "pool" and l.pool is not None and not flat:
            p = rins[h][0]
            src_lay, src_dt = stored[p]
            if src_lay != lay:       # no producer to fold into: standalone
                transforms.append(h)
                total += tx * transform_cost(shape_of(p), l.dtype_bytes,
                                             optimized_transform)
                tb = tx * transform_bytes(shape_of(p), l.dtype_bytes)
                fused_b += tb
                unfused_b += tb
                src_lay = lay
            ops.append(FusedOp("pool", h, l.name, lay, src_lay, dst,
                               src_dtype=src_dt, dst_dtype=gdt,
                               inputs=(p,), out_index=t))
            total += layer_cost(l, lay, training, cm)
            in_b, out_b = _pool_io_bytes(l)
            io_b = in_b + out_b + ((2 * in_b + out_b) if training else 0)
            fused_b += io_b
            unfused_b += io_b
            if dst != lay:           # folded into the pool's output write
                unfused_b += tx * transform_bytes(l.out_shape, l.dtype_bytes)
            continue
        if l.kind in ("add", "concat", "upsample"):
            ins = rins[h]
            srcs = [stored[p] for p in ins]
            for p, (s_lay, _) in zip(ins, srcs):
                if s_lay != lay:     # standalone merge: every mismatch pays
                    if h not in transforms:
                        transforms.append(h)
                    total += tx * transform_cost(shape_of(p), l.dtype_bytes,
                                                 optimized_transform)
                    tb = tx * transform_bytes(shape_of(p), l.dtype_bytes)
                    fused_b += tb
                    unfused_b += tb
            ops.append(FusedOp(l.kind, h, l.name, lay, srcs[0][0], dst,
                               src_dtype=srcs[0][1], dst_dtype=gdt,
                               inputs=tuple(ins), out_index=h))
            total += layer_cost(l, lay, training, cm)
            io_b = _merge_io_bytes(l, training)
            fused_b += io_b
            unfused_b += io_b
            continue
        # layout-terminal / elementwise leftovers
        p = rins[h][0]
        src_lay, src_dt = stored[p]
        sz = int(np.prod(l.out_shape)) if l.out_shape else 0
        if l.kind == "act" and not flat and src_lay != lay:
            transforms.append(h)     # standalone act can't fold a re-layout
            total += tx * transform_cost(shape_of(p), l.dtype_bytes,
                                         optimized_transform)
            tb = tx * transform_bytes(shape_of(p), l.dtype_bytes)
            fused_b += tb
            unfused_b += tb
            src_lay = lay
        if l.kind == "flatten":
            flat = True
            fused_b += tx * 2 * sz * l.dtype_bytes if src_lay == "CHWN" else 0
            unfused_b += tx * 2 * sz * l.dtype_bytes if lay == "CHWN" else 0
        elif l.kind == "fc":
            in_f = (int(np.prod(layers[p].out_shape)) // l.out_shape[0]
                    if p >= 0 else l.out_shape[1])
            io_b = (int(np.prod(l.out_shape)) + in_f * l.out_shape[1] +
                    l.out_shape[1] + in_f * l.out_shape[0]) * l.dtype_bytes
            if training:             # dx = g W^T, dW = x^T g, db
                io_b *= 2
            fused_b += io_b
            unfused_b += io_b
        else:                        # act / softmax
            total += layer_cost(l, lay, training, cm)
            io_b = (5 if training else 2) * sz * l.dtype_bytes
            fused_b += io_b
            unfused_b += io_b
        stored[t] = (src_lay if flat else dst, gdt)
        ops.append(FusedOp(l.kind, h, l.name, lay, src_lay,
                           src_lay if flat else dst,
                           src_dtype=src_dt, dst_dtype=gdt,
                           inputs=(p,), out_index=h))
    return FusedPlan(layouts=layouts, ops=ops, transforms=transforms,
                     total_s=total, fused_bytes=fused_b,
                     unfused_bytes=unfused_b, dtypes=dtypes,
                     base_dtype=base,
                     intermediate_roundtrip_bytes=roundtrip_b)
