"""Network-level automatic layout assignment (paper §IV.D) and fused-op
planning (DESIGN.md §5).

The paper scans the network once, sets a per-layer layout field from the
heuristic, and inserts a transform wherever consecutive layers disagree,
using one-time profiling to confirm the transform overhead is amortized
(CV5/CV9 in §VI are cases where it is NOT and the layout change is skipped).

We implement that arbitration exactly, as a shortest-path dynamic program
over per-layer layout states: node cost = layer cost under a layout (from
the analytical/measured cost model), edge cost = transform cost between
consecutive layers' layouts.  With uniform-cost edges=0 this degenerates to
the paper's pure per-layer heuristic; with transform costs it reproduces the
paper's "don't transform for CV5/CV9" behaviour.

``plan_fused`` extends the DP for the fused execution engine: an edge costs
*zero* when the re-layout folds into the producing kernel (conv/pool write
their output directly in the consumer's layout via the out BlockSpec, and
conv reads its input in the producer's layout), and conv->relu->pool runs
collapse into single FusedOp nodes priced by the fusion cost model
(``fused_chain_cost``), which credits the intermediate read+write bytes the
fusion removes.

Mixed-dtype planning (DESIGN.md §9): with ``dtype_policy="mixed"`` both DPs
search the product space of per-layer **(layout, storage dtype)** states —
dtype becomes a third DP dimension next to layout, exactly as the ROADMAP
lever describes.  In ``plan_fused`` a dtype change is free wherever it folds
(the producing conv's epilogue quantizes the f32 VMEM accumulator on its
way out; the consuming conv dequantizes in VMEM via scale-folded weights),
so interior conv->conv edges store int8 at 1 byte/element; in
``assign_layouts`` every dtype boundary pays a standalone cast pass
(``cast_cost``), which is why the unfused DP provably never picks int8 —
the fold *is* the win.  Precision guardrails keep the search honest: the
host input, the first conv chain's output, and everything at/after flatten
(the classifier head) stay in the base float dtype.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.paper_table1 import ConvLayer, PoolLayer
from repro.core.heuristic import (DEFAULT_DTYPE_BYTES, Thresholds,
                                  cast_cost, chain_bytes,
                                  conv_backward_bytes,
                                  conv_backward_cost, conv_cost,
                                  fused_chain_cost, select_conv_layout,
                                  select_pool_layout)
from repro.core.layout import transform_bytes
from repro.dtypes import INT8_DTYPE, canon_dtype, dtype_bytes as _dtype_bytes
from repro.launch.mesh import HBM_BW
from repro.shapes import pool_out_hw

LAYOUTS = ("CHWN", "NCHW")
DTYPE_POLICIES = ("uniform", "mixed")

# reverse map for labeling plans built from bare LayerDescs (which carry
# only an element size); ambiguity at 2 bytes resolves to bf16, the TPU's
# native half dtype
_BYTES_TO_NAME = {4: "float32", 2: "bfloat16", 1: "int8"}


def _base_dtype_name(layers: Sequence["LayerDesc"],
                     base_dtype: Optional[str]) -> str:
    if base_dtype is not None:
        return canon_dtype(base_dtype)
    db = layers[0].dtype_bytes if layers else 4
    return _BYTES_TO_NAME.get(db, "float32")


@dataclass
class LayerDesc:
    """One network layer as seen by the selector."""
    name: str
    kind: str                       # conv | pool | act | fc | softmax | flatten
    conv: Optional[ConvLayer] = None
    pool: Optional[PoolLayer] = None
    out_shape: Tuple[int, ...] = ()   # logical NCHW shape of the output
    dtype_bytes: int = DEFAULT_DTYPE_BYTES   # storage element size
    trainable: bool = True          # False: frozen params, wgrad skipped


def _pool_io_bytes(l: LayerDesc) -> Tuple[int, int]:
    p = l.pool
    ho = pool_out_hw(p.HW, p.F, p.S)   # shared with the pool kernels
    d = l.dtype_bytes
    return p.N * p.C * p.HW * p.HW * d, p.N * p.C * ho * ho * d


def layer_cost(l: LayerDesc, layout: str, training: bool = False) -> float:
    """Estimated seconds for this layer in this layout (forward, plus the
    backward direction when ``training``)."""
    if l.kind == "conv" and l.conv is not None:
        t = conv_cost(l.conv, layout, l.dtype_bytes).total_s
        if training:
            t += conv_backward_cost(l.conv, layout, l.dtype_bytes,
                                    fused=False).total_s
        return t
    if l.kind == "pool" and l.pool is not None:
        # memory bound: bytes / bw, de-rated by tile utilization of the
        # layout's minormost dims (paper Fig. 6: NCHW pooling is strided)
        in_b, out_b = _pool_io_bytes(l)
        eff = 1.0 if layout == "CHWN" else 0.25   # strided window penalty
        bytes_ = in_b + out_b
        if training:                 # bwd: read g + read input (mask) + write
            bytes_ += 2 * in_b + out_b
        return bytes_ / (HBM_BW * eff)
    if l.kind == "act":
        n = float(np.prod(l.out_shape)) if l.out_shape else 0.0
        b = (5 if training else 2) * n * l.dtype_bytes
        return b / HBM_BW
    if l.kind in ("fc", "softmax", "flatten"):
        return 0.0     # layout-terminal (2-D)
    # Anything else (lrn, or a conv/pool desc missing its descriptor) has no
    # executor behind it — cnn.network raises at run time, so refusing to
    # plan it here keeps planner and executor in agreement (ISSUE 3).
    raise ValueError(
        f"layer {l.name!r}: kind {l.kind!r} is not executable by the "
        "CNN engines; refusing to produce a plan the executor would reject")


def transform_cost(shape: Tuple[int, ...], dtype_bytes: int,
                   optimized: bool = True) -> float:
    """Seconds to re-layout a tensor of ``shape``; the optimized transform
    runs at ~streaming bandwidth (paper Fig. 11: up to 97.6% of peak), the
    naive one at ~1/8 of it."""
    eff = 0.9 if optimized else 0.12
    return transform_bytes(shape, dtype_bytes) / (HBM_BW * eff)


@dataclass
class Assignment:
    layouts: List[str]
    transforms: List[int]           # indices i where a transform happens before layer i
    total_s: float
    dtypes: List[str] = field(default_factory=list)  # per-layer storage dtype


def assign_layouts(layers: Sequence[LayerDesc], *,
                   input_layout: str = "NCHW",
                   input_shape: Optional[Tuple[int, ...]] = None,
                   optimized_transform: bool = True,
                   training: bool = False,
                   measure: Optional[Callable[[LayerDesc, str], float]] = None,
                   thresholds: Optional[Thresholds] = None,
                   dtype_policy: str = "uniform",
                   base_dtype: Optional[str] = None) -> Assignment:
    """Shortest-path over (layer, layout) states (the UNFUSED engine's plan;
    ``plan_fused`` is the variant whose edges fold into kernel I/O maps).

    ``input_shape`` is the logical NCHW shape of the *network input* — the
    tensor transformed by an i == 0 layout change (which generally differs
    from ``layers[0].out_shape``).  ``training`` plans the whole training
    graph: node costs include the backward direction and every transform
    edge is paid twice (the activation re-layout forward, its reversed twin
    on the gradient coming back).

    ``dtype_policy="mixed"`` widens the state space to (layout, storage
    dtype): a conv layer's output may be stored int8, but the unfused engine
    has no epilogue to fold the casts into, so quantize costs a standalone
    pass on the edge leaving the node and dequantize another on the edge
    into the consumer (``cast_cost``).  Both are strictly positive on top of
    the uniform path, so this DP degenerates to the uniform assignment — the
    search is kept because proving that is the point (mixed dtypes pay only
    under fusion; see DESIGN.md §9).
    """
    if dtype_policy not in DTYPE_POLICIES:
        raise ValueError(f"unknown dtype_policy {dtype_policy!r}; "
                         f"known: {DTYPE_POLICIES}")
    cost_fn = measure or (lambda l, lay: layer_cost(l, lay, training))
    n = len(layers)
    INF = float("inf")
    in_shape = tuple(input_shape) if input_shape else (
        layers[0].out_shape if layers else ())
    base = _base_dtype_name(layers, base_dtype)
    base_db = layers[0].dtype_bytes if layers else _dtype_bytes(base)
    tx = 2 if training else 1        # gradients re-cross every edge

    def cands(i: int) -> Tuple[str, ...]:
        # conv outputs may store int8 (unfused: never pays, but searched);
        # the last layer's output is the network result — keep it base
        if (dtype_policy == "mixed" and i + 1 < n
                and layers[i].kind == "conv"):
            return (base, INT8_DTYPE)
        return (base,)

    # dp[(layout, dtype)] = (cost, path of (layout, dtype)); start in the
    # input layout/base dtype only — the i == 0 edge below prices any
    # immediate re-layout of the network input
    State = Tuple[str, str]
    dp: Dict[State, Tuple[float, List[State]]] = {
        (lay, base): ((0.0 if lay == input_layout else INF), [(lay, base)])
        for lay in LAYOUTS}
    for i, l in enumerate(layers):
        ndp: Dict[State, Tuple[float, List[State]]] = {}
        for lay in LAYOUTS:
            for dt in cands(i):
                best, path = INF, None
                for (prev, prev_dt), (c0, p0) in dp.items():
                    edge = 0.0
                    # the layer input (= previous layer's output; the
                    # network input when i == 0)
                    shape = layers[i - 1].out_shape if i else in_shape
                    if prev_dt != base:     # dequant pass before compute
                        edge += tx * cast_cost(shape,
                                               _dtype_bytes(prev_dt), base_db)
                    if prev != lay:
                        edge += tx * transform_cost(shape,
                                                    _dtype_bytes(prev_dt),
                                                    optimized_transform)
                    if dt != base:          # quant pass after compute
                        edge += tx * cast_cost(l.out_shape, base_db,
                                               _dtype_bytes(dt))
                    c = c0 + edge + cost_fn(l, lay)
                    if c < best:
                        best, path = c, p0 + [(lay, dt)]
                ndp[(lay, dt)] = (best, path)
        dp = ndp
    st_best = min(dp, key=lambda k: dp[k][0])
    total, path = dp[st_best]
    layouts = [st[0] for st in path[1:]]
    dtypes = [st[1] for st in path[1:]]
    transforms = [i for i in range(n)
                  if (layouts[i] != (layouts[i - 1] if i else input_layout))]
    return Assignment(layouts=layouts, transforms=transforms, total_s=total,
                      dtypes=dtypes)


def paper_heuristic_layouts(layers: Sequence[LayerDesc],
                            th: Thresholds) -> List[str]:
    """The paper's §IV.D single-scan field assignment (no DP)."""
    out = []
    cur = "NCHW"
    for l in layers:
        if l.kind == "conv" and l.conv is not None:
            cur = select_conv_layout(l.conv, th)
        elif l.kind == "pool":
            cur = select_pool_layout(l.pool)
        out.append(cur)    # act/fc/softmax inherit the incoming layout
    return out


# ---------------------------------------------------------------------------
# fused-op planning (DESIGN.md §5)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FusedOp:
    """One node of the fused execution plan.

    ``layout`` is the layout the kernel computes in; ``src_layout`` /
    ``dst_layout`` are the layouts it consumes/produces (folded re-layouts
    when they differ from ``layout``).  For conv nodes, ``relu`` and
    ``pool_index`` mark the folded epilogue layers.  ``src_dtype`` /
    ``dst_dtype`` are the STORAGE dtypes of the tensors the node reads /
    writes in HBM (mixed-dtype plans store interior activations as int8:
    the epilogue quantizes, the consumer conv dequantizes in VMEM).  Empty
    string means "the run's dtype" — plans persisted before ISSUE 5 load
    with that value and behave exactly as before.
    """
    kind: str                       # conv | pool | act | fc | softmax | flatten
    index: int                      # primary layer index in the LayerDesc list
    name: str
    layout: str
    src_layout: str
    dst_layout: str
    relu: bool = False
    pool_index: Optional[int] = None
    src_dtype: str = ""
    dst_dtype: str = ""

    @property
    def is_fused(self) -> bool:
        return (self.relu or self.pool_index is not None or
                self.src_layout != self.layout or
                self.dst_layout != self.layout)


# one-letter storage-dtype codes for plan signatures (reports/benchmarks)
DTYPE_CODES = {"float32": "f", "bfloat16": "b", "float16": "h", "int8": "8",
               "": "?"}


@dataclass
class FusedPlan:
    layouts: List[str]              # per-layer layout (DP assignment)
    ops: List[FusedOp]              # execution nodes, in order
    transforms: List[int]           # layer indices needing a STANDALONE pass
    total_s: float                  # modeled seconds under the fused engine
    fused_bytes: int                # modeled HBM bytes, fused engine
    unfused_bytes: int              # same layouts executed unfused
    dtypes: List[str] = field(default_factory=list)  # per-layer storage dtype
    base_dtype: str = ""            # the float dtype non-int8 layers run in

    @property
    def saved_bytes(self) -> int:
        return self.unfused_bytes - self.fused_bytes

    @property
    def conv_signature(self) -> str:
        """One letter per conv node ('C'HWN / 'N'CHW) — the compact form the
        serving report and benchmarks use to show batch-dependent flips."""
        return "".join(op.layout[0] for op in self.ops if op.kind == "conv")

    @property
    def dtype_signature(self) -> str:
        """One letter per conv node's OUTPUT storage dtype (f/b/h/8) — shows
        where the mixed DP placed the int8 layers."""
        return "".join(DTYPE_CODES.get(op.dst_dtype, "?")
                       for op in self.ops if op.kind == "conv")

    @property
    def distinct_conv_dtypes(self) -> int:
        return len({op.dst_dtype for op in self.ops if op.kind == "conv"})


def _dst_layout(layers: Sequence[LayerDesc], layouts: Sequence[str],
                j: int, lay: str) -> str:
    """Layout a producer should write: the consumer's layout, or NCHW ahead
    of flatten/fc so the 2-D flatten is a free reshape."""
    if j >= len(layers):
        return lay
    if layers[j].kind in ("flatten", "fc", "softmax"):
        return "NCHW"
    return layouts[j]


@dataclass(frozen=True)
class _Group:
    """A fused-op DP node: a conv[->act][->pool] chain, a lone pool, or a
    passthrough layer.  The whole group executes in ONE layout (one kernel
    for conv chains), which is what makes its intermediates free."""
    start: int
    end: int                        # exclusive
    kind: str                       # chain head kind
    relu: bool = False
    pool_index: Optional[int] = None


def _group_layers(layers: Sequence[LayerDesc]) -> List[_Group]:
    groups: List[_Group] = []
    n = len(layers)
    flat = False
    i = 0
    while i < n:
        l = layers[i]
        if l.kind == "conv" and l.conv is not None and not flat:
            relu = False
            pool_idx = None
            j = i + 1
            if j < n and layers[j].kind == "act":
                relu = True          # elementwise: folds in any layout
                j += 1
            if j < n and layers[j].kind == "pool" and layers[j].pool is not None:
                pool_idx = j
                j += 1
            groups.append(_Group(i, j, "conv", relu, pool_idx))
            i = j
            continue
        if l.kind == "flatten":
            flat = True
        groups.append(_Group(i, i + 1, l.kind))
        i += 1
    return groups


def _group_pool(layers: Sequence[LayerDesc],
                g: _Group) -> Optional[Tuple[int, int]]:
    if g.pool_index is None:
        return None
    p = layers[g.pool_index].pool
    return (p.F, p.S)


def _group_cost(layers: Sequence[LayerDesc], g: _Group, lay: str,
                training: bool = False,
                in_db: Optional[int] = None,
                out_db: Optional[int] = None) -> float:
    l = layers[g.start]
    if g.kind == "conv" and l.conv is not None:
        pool_t = _group_pool(layers, g)
        t = fused_chain_cost(l.conv, lay, l.dtype_bytes,
                             relu=g.relu, pool=pool_t,
                             in_dtype_bytes=in_db,
                             out_dtype_bytes=out_db).total_s
        if training:
            # gradients stay at the base dtype — int8 is a forward-storage
            # lever; the backward chain is priced at the layer's dtype
            t += conv_backward_cost(l.conv, lay, l.dtype_bytes, relu=g.relu,
                                    pool=pool_t, fused=True).total_s
        return t
    return sum(layer_cost(layers[i], lay, training)
               for i in range(g.start, g.end))


def plan_fused(layers: Sequence[LayerDesc], *,
               input_layout: str = "NCHW",
               input_shape: Optional[Tuple[int, ...]] = None,
               optimized_transform: bool = True,
               training: bool = False,
               dtype_policy: str = "uniform",
               base_dtype: Optional[str] = None) -> FusedPlan:
    """Turn a layer stack into a fused execution plan.

    Collapses conv[->relu][->pool] runs into fused-op nodes, then runs the
    shortest-path DP over (node, layout, storage dtype) states: node cost
    comes from the fusion cost model (``fused_chain_cost`` — the chain
    intermediate never hits HBM), and an edge costs zero when the re-layout
    folds into the producer's output write or the consumer conv's input
    read.  Standalone transform passes survive only where no adjacent kernel
    can fold them (never, for conv-led CNNs: the first layer is a conv and
    reads the host layout directly).

    ``dtype_policy="mixed"`` (DESIGN.md §9) lets interior conv chains store
    their output as int8: the quantize folds into the chain's epilogue (the
    f32 VMEM accumulator is scaled per channel on its way out) and the
    dequantize into the consumer conv's read (the per-channel scale folds
    exactly into the weights), so the dtype edge is as free as a folding
    layout edge.  Candidates are restricted to edges both sides can fold —
    conv-chain output consumed by another conv chain — and the first conv
    chain's output stays at the base dtype (early features are
    precision-sensitive; ZeroQuant/AWQ keep the first layer wide for the
    same reason).  Because the base-dtype path is always in the search
    space, the mixed plan is never worse than the uniform plan at the same
    base dtype.

    ``training`` plans the whole training graph: chain nodes add the
    custom-VJP backward (activation stash, one-kernel pool+mask backward,
    dgrad/wgrad) to both the time and byte models, the unfused comparison
    adds the XLA-decomposed backward, and non-folding transform edges are
    paid twice (forward + the reversed gradient re-layout) — folding edges
    stay free in BOTH directions, because dgrad consumes/produces through
    the same kernel I/O maps.  Gradients stay at the base dtype (the
    straight-through estimator passes them through int8 boundaries), so
    mixed plans shrink forward bytes only.
    """
    if dtype_policy not in DTYPE_POLICIES:
        raise ValueError(f"unknown dtype_policy {dtype_policy!r}; "
                         f"known: {DTYPE_POLICIES}")
    n = len(layers)
    in_shape = tuple(input_shape) if input_shape else (
        layers[0].out_shape if layers else ())
    base = _base_dtype_name(layers, base_dtype)

    def _in_shape(i: int) -> Tuple[int, ...]:
        return layers[i - 1].out_shape if i else in_shape

    groups = _group_layers(layers)
    first_conv = next((gi for gi, g in enumerate(groups)
                       if g.kind == "conv"), -1)

    def gcands(gi: int) -> Tuple[str, ...]:
        # a group's OUTPUT may store int8 only when both casts fold: the
        # producer is a conv chain (epilogue quantizes) and the consumer is
        # a conv chain (dequantizes in VMEM); the first conv chain stays at
        # base (precision-sensitive early features)
        g = groups[gi]
        if (dtype_policy == "mixed" and g.kind == "conv" and gi > first_conv
                and gi + 1 < len(groups) and groups[gi + 1].kind == "conv"):
            return (base, INT8_DTYPE)
        return (base,)

    def _group_hbm_bytes(g: _Group, in_db: int, out_db: int) -> int:
        """Secondary DP key: the group's modeled fused HBM bytes.  Layer
        kinds whose traffic is identical across all states (fc/act/flatten)
        contribute 0 — constants never move an argmin.  Time stays the
        primary objective; bytes break ties, which is what lets int8 win on
        compute-bound chains (the paper's currency is bytes moved)."""
        l = layers[g.start]
        if g.kind == "conv" and l.conv is not None:
            b = chain_bytes(l.conv, l.dtype_bytes, relu=g.relu,
                            pool=_group_pool(layers, g), fused=True,
                            in_dtype_bytes=in_db, out_dtype_bytes=out_db)
            if training:
                b += conv_backward_bytes(
                    l.conv, "CHWN", l.dtype_bytes, relu=g.relu,
                    pool=_group_pool(layers, g), fused=True,
                    trainable=l.trainable)
            return b
        if g.kind == "pool" and l.pool is not None:
            in_b, out_b = _pool_io_bytes(l)
            return in_b + out_b + ((2 * in_b + out_b) if training else 0)
        return 0

    # DP over (group, layout, out dtype); layout edges fold into conv/pool
    # kernel I/O maps, dtype edges into conv epilogues/reads (see gcands).
    # Costs are lexicographic (seconds, HBM bytes): on compute-bound chains
    # the roofline max() hides byte savings, and the byte tie-break is what
    # makes the dtype dimension decisive there.
    INF = (float("inf"), float("inf"))
    State = Tuple[str, str]
    dp: Dict[State, Tuple[Tuple[float, float], List[State]]] = {
        (lay, base): (((0.0, 0.0) if lay == input_layout else INF), [])
        for lay in LAYOUTS}
    for gi, g in enumerate(groups):
        l = layers[g.start]
        ndp: Dict[State, Tuple[Tuple[float, float], List[State]]] = {}
        for lay in LAYOUTS:
            for dt in gcands(gi):
                best, path = INF, None
                for (prev, prev_dt), (c0, p0) in dp.items():
                    edge_s, edge_b = 0.0, 0.0
                    if prev != lay:
                        prev_g = groups[len(p0) - 1] if p0 else None
                        folds = (g.kind == "conv" or
                                 (prev_g is not None and
                                  prev_g.kind in ("conv", "pool")))
                        if not folds:
                            tx_e = 2 if training else 1
                            edge_s = tx_e * transform_cost(
                                _in_shape(g.start), _dtype_bytes(prev_dt),
                                optimized_transform)
                            edge_b = tx_e * transform_bytes(
                                _in_shape(g.start), _dtype_bytes(prev_dt))
                    in_db, out_db = _dtype_bytes(prev_dt), _dtype_bytes(dt)
                    c = (c0[0] + edge_s +
                         _group_cost(layers, g, lay, training,
                                     in_db=in_db, out_db=out_db),
                         c0[1] + edge_b + _group_hbm_bytes(g, in_db, out_db))
                    if c < best:
                        best, path = c, p0 + [(lay, dt)]
                ndp[(lay, dt)] = (best, path)
        dp = ndp
    st_best = min(dp, key=lambda k: dp[k][0])
    _, gpath = dp[st_best]
    layouts: List[str] = [""] * n
    dtypes: List[str] = [base] * n
    for g, (glay, gdt) in zip(groups, gpath):
        for i in range(g.start, g.end):
            layouts[i] = glay
            dtypes[i] = gdt

    ops: List[FusedOp] = []
    transforms: List[int] = []
    total = 0.0
    fused_b = 0
    unfused_b = 0
    cur = input_layout
    cur_dt = base
    flat = False
    for g, (lay, gdt) in zip(groups, gpath):
        i = g.start
        l = layers[i]
        tx = 2 if training else 1    # gradients re-layout back through edges
        if g.kind == "conv":
            dst = _dst_layout(layers, layouts, g.end, lay)
            pool_t = _group_pool(layers, g)
            in_db, out_db = _dtype_bytes(cur_dt), _dtype_bytes(gdt)
            ops.append(FusedOp("conv", i, l.name, lay, cur, dst,
                               relu=g.relu, pool_index=g.pool_index,
                               src_dtype=cur_dt, dst_dtype=gdt))
            total += fused_chain_cost(l.conv, lay, l.dtype_bytes,
                                      relu=g.relu, pool=pool_t,
                                      in_dtype_bytes=in_db,
                                      out_dtype_bytes=out_db).total_s
            fused_b += chain_bytes(l.conv, l.dtype_bytes, relu=g.relu,
                                   pool=pool_t, fused=True,
                                   in_dtype_bytes=in_db,
                                   out_dtype_bytes=out_db)
            # the unfused comparison runs uniformly at the base dtype — the
            # unfused engine has no epilogue to fold the casts into
            unfused_b += chain_bytes(l.conv, l.dtype_bytes, relu=g.relu,
                                     pool=pool_t, fused=False)
            if training:
                total += conv_backward_cost(l.conv, lay, l.dtype_bytes,
                                            relu=g.relu, pool=pool_t,
                                            fused=True).total_s
                fused_b += conv_backward_bytes(
                    l.conv, lay, l.dtype_bytes, relu=g.relu, pool=pool_t,
                    fused=True, trainable=l.trainable)
                unfused_b += conv_backward_bytes(
                    l.conv, lay, l.dtype_bytes, relu=g.relu, pool=pool_t,
                    fused=False, trainable=l.trainable)
            if cur != lay:           # folded into the kernel's input read
                unfused_b += tx * transform_bytes(_in_shape(i), l.dtype_bytes)
            if dst != lay:           # folded into the kernel's output write
                unfused_b += tx * transform_bytes(
                    layers[g.end - 1].out_shape, l.dtype_bytes)
            cur = dst
            cur_dt = gdt
            continue
        if g.kind == "pool" and l.pool is not None and not flat:
            if cur != lay:           # no producer to fold into: standalone
                transforms.append(i)
                total += tx * transform_cost(_in_shape(i), l.dtype_bytes,
                                             optimized_transform)
                tb = tx * transform_bytes(_in_shape(i), l.dtype_bytes)
                fused_b += tb
                unfused_b += tb
                cur = lay
            dst = _dst_layout(layers, layouts, g.end, lay)
            ops.append(FusedOp("pool", i, l.name, lay, cur, dst,
                               src_dtype=cur_dt, dst_dtype=gdt))
            total += layer_cost(l, lay, training)
            in_b, out_b = _pool_io_bytes(l)
            io_b = in_b + out_b
            if training:             # bwd: read g + read input (mask) + write
                io_b += 2 * in_b + out_b
            fused_b += io_b
            unfused_b += io_b
            if dst != lay:           # folded into the pool's output write
                unfused_b += tx * transform_bytes(l.out_shape, l.dtype_bytes)
            cur = dst
            continue
        # layout-terminal / elementwise leftovers
        sz = int(np.prod(l.out_shape)) if l.out_shape else 0
        if l.kind == "flatten":
            flat = True
            fused_b += tx * 2 * sz * l.dtype_bytes if cur == "CHWN" else 0
            unfused_b += tx * 2 * sz * l.dtype_bytes if lay == "CHWN" else 0
        elif l.kind == "fc":
            in_f = (int(np.prod(layers[i - 1].out_shape)) // l.out_shape[0]
                    if i else l.out_shape[1])
            io_b = (int(np.prod(l.out_shape)) + in_f * l.out_shape[1] +
                    l.out_shape[1] + in_f * l.out_shape[0]) * l.dtype_bytes
            if training:             # dx = g W^T, dW = x^T g, db
                io_b *= 2
            fused_b += io_b
            unfused_b += io_b
        else:                        # act / softmax
            total += layer_cost(l, lay, training)
            io_b = (5 if training else 2) * sz * l.dtype_bytes
            fused_b += io_b
            unfused_b += io_b
        ops.append(FusedOp(l.kind, i, l.name, lay, cur, cur if flat else lay,
                           src_dtype=cur_dt, dst_dtype=gdt))
    return FusedPlan(layouts=layouts, ops=ops, transforms=transforms,
                     total_s=total, fused_bytes=fused_b,
                     unfused_bytes=unfused_b, dtypes=dtypes,
                     base_dtype=base)
