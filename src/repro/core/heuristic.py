"""DEPRECATED shim — the cost machinery lives in ``repro.perfmodel``.

This module was the home of the layout-selection heuristic (paper §IV.A-B)
and every analytic byte/seconds model the planner prices decisions with.
That machinery is now a first-class subsystem (DESIGN.md §13):

* ``repro.perfmodel.traffic``     — the DeLTA-style analytic traffic model
  (conv chains, stacks, backward, cast edges; bytes AND roofline seconds);
* ``repro.perfmodel.calibration`` — the (Ct, Nt) thresholds, the measured
  Pallas sweep, hardware-versioned threshold rows, and predicted-vs-measured
  cross-validation;
* ``repro.perfmodel.model``       — the ``CostModel`` interface consumers
  plan through (``AnalyticCostModel`` / ``CalibratedCostModel``).

Every historical name re-exports below unchanged — imports keep working and
persisted plans stay byte-identical — but NEW code must import from
``repro.perfmodel`` (the boundary lint in ``tools/check_perfmodel_boundary``
fails on fresh ``*_cost``/``*_bytes`` imports from this module).
"""
from repro.perfmodel.traffic import (  # noqa: F401
    DEFAULT_DTYPE_BYTES, LANES, STACK_NT_CANDIDATES, STACK_VMEM_BUDGET,
    ConvCost, _round_up, _stack_geom, _sublanes, cast_bytes, cast_cost,
    chain_bytes, conv_backward_bytes, conv_backward_cost, conv_cost,
    conv_flops, dgrad_bytes, dilated_hw, fused_chain_cost,
    fusion_saved_bytes, select_conv_layout_cost, select_kv_layout,
    stack_bytes, stack_fused_cost, stack_nt, stack_vmem_bytes, sublanes,
    tile_utilization, train_chain_bytes, wgrad_bytes)
from repro.perfmodel.calibration import (  # noqa: F401
    Thresholds, calibrate, select_conv_layout, select_pool_layout)
