"""The paper's primary contribution: data-layout selection, fast layout
transformation, and memory-access optimization, as a composable system."""
from repro.core.layout import (  # noqa: F401
    CONV_LAYOUTS, TransformPlan, perm_between, plan_transform,
    relayout_shape, transform_bytes)
from repro.core.heuristic import (  # noqa: F401
    DEFAULT_DTYPE_BYTES, Thresholds, calibrate, cast_bytes, cast_cost,
    chain_bytes, conv_backward_bytes,
    conv_backward_cost, conv_cost, dgrad_bytes, fused_chain_cost,
    fusion_saved_bytes, select_conv_layout, select_conv_layout_cost,
    select_kv_layout, select_pool_layout, tile_utilization,
    train_chain_bytes, wgrad_bytes)
from repro.core.transform import apply_transform, naive_transform  # noqa: F401
from repro.core.selector import (  # noqa: F401
    Assignment, FusedOp, FusedPlan, LayerDesc, assign_layouts, layer_cost,
    paper_heuristic_layouts, plan_fused, transform_cost)
