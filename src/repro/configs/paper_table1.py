"""The paper's Table 1: benchmark layer configurations (verbatim).

These drive the per-layer benchmarks (paper Figs. 3, 5, 6, 10, 11, 12) and the
heuristic-validation tests.  Columns: Ni (batch), Co (output channels),
HW (input height=width), F (filter), Ci (input channels), S (stride).
"""
from dataclasses import dataclass

from repro.shapes import conv_out_hw


@dataclass(frozen=True)
class ConvLayer:
    name: str
    N: int
    Co: int
    HW: int
    F: int
    Ci: int
    S: int
    net: str
    pad: int = 0        # Table 1 layers are unpadded; network configs set it

    @property
    def out_hw(self) -> int:
        return conv_out_hw(self.HW, self.F, self.S, self.pad)


@dataclass(frozen=True)
class PoolLayer:
    name: str
    N: int
    C: int
    HW: int
    F: int
    S: int
    net: str

    @property
    def overlapped(self) -> bool:
        return self.F > self.S


@dataclass(frozen=True)
class SoftmaxLayer:
    name: str
    N: int
    C: int          # number of categories


CONV_LAYERS = (
    ConvLayer("CV1", 128, 16, 28, 5, 1, 1, "lenet"),
    ConvLayer("CV2", 128, 16, 14, 5, 16, 1, "lenet"),
    ConvLayer("CV3", 128, 64, 24, 5, 3, 1, "cifar"),
    ConvLayer("CV4", 128, 64, 12, 5, 64, 1, "cifar"),
    ConvLayer("CV5", 64, 96, 224, 3, 3, 2, "zfnet"),
    ConvLayer("CV6", 64, 256, 55, 5, 96, 2, "zfnet"),
    ConvLayer("CV7", 64, 384, 13, 3, 256, 1, "zfnet"),
    ConvLayer("CV8", 64, 384, 13, 3, 384, 1, "zfnet"),
    ConvLayer("CV9", 32, 64, 224, 3, 3, 1, "vgg"),
    ConvLayer("CV10", 32, 256, 56, 3, 128, 1, "vgg"),
    ConvLayer("CV11", 32, 512, 28, 3, 256, 1, "vgg"),
    ConvLayer("CV12", 32, 512, 14, 3, 512, 1, "vgg"),
)

POOL_LAYERS = (
    PoolLayer("PL1", 128, 16, 28, 2, 2, "lenet"),
    PoolLayer("PL2", 128, 16, 14, 2, 2, "lenet"),
    PoolLayer("PL3", 128, 64, 24, 3, 2, "cifar"),
    PoolLayer("PL4", 128, 64, 12, 3, 2, "cifar"),
    PoolLayer("PL5", 128, 96, 55, 3, 2, "alexnet"),
    PoolLayer("PL6", 128, 192, 27, 3, 2, "alexnet"),
    PoolLayer("PL7", 128, 256, 13, 3, 2, "alexnet"),
    PoolLayer("PL8", 64, 96, 110, 3, 2, "zfnet"),
    PoolLayer("PL9", 64, 256, 26, 3, 2, "zfnet"),
    PoolLayer("PL10", 64, 256, 13, 3, 2, "zfnet"),
)

# Paper §VI Fig. 13: twelve (batch x categories) softmax configs.
SOFTMAX_LAYERS = tuple(
    SoftmaxLayer(f"SM_{n}x{c}", n, c)
    for n in (32, 64, 128)
    for c in (10, 100, 1000, 10000)
)

CONV_BY_NAME = {l.name: l for l in CONV_LAYERS}
POOL_BY_NAME = {l.name: l for l in POOL_LAYERS}

# Paper Table 1 / §VI ground truth: preferred layout per conv layer
# (CHWN for CV1-CV5 & CV9; NCHW for CV6-CV8 & CV10-CV12); pooling always CHWN.
PAPER_PREFERRED_CONV_LAYOUT = {
    "CV1": "CHWN", "CV2": "CHWN", "CV3": "CHWN", "CV4": "CHWN",
    "CV5": "CHWN", "CV9": "CHWN",
    "CV6": "NCHW", "CV7": "NCHW", "CV8": "NCHW",
    "CV10": "NCHW", "CV11": "NCHW", "CV12": "NCHW",
}
