"""Registry: ``--arch <id>`` resolution + reduced smoke-test variants."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig

# id -> module name (one module per assigned architecture)
ARCH_MODULES: Dict[str, str] = {
    "phi3_vision_4p2b": "repro.configs.phi3_vision_4p2b",
    "qwen2_7b": "repro.configs.qwen2_7b",
    "yi_9b": "repro.configs.yi_9b",
    "phi3_mini_3p8b": "repro.configs.phi3_mini_3p8b",
    "gemma2_27b": "repro.configs.gemma2_27b",
    "dbrx_132b": "repro.configs.dbrx_132b",
    "llama4_maverick_400b": "repro.configs.llama4_maverick_400b",
    "jamba_1p5_large_398b": "repro.configs.jamba_1p5_large_398b",
    "rwkv6_7b": "repro.configs.rwkv6_7b",
    "whisper_base": "repro.configs.whisper_base",
}

ARCH_IDS = tuple(ARCH_MODULES)

# Friendly aliases (dashes etc.)
_ALIASES = {name.replace("_", "-"): name for name in ARCH_MODULES}
_ALIASES.update({
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "qwen2-7b": "qwen2_7b",
    "yi-9b": "yi_9b",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "gemma2-27b": "gemma2_27b",
    "dbrx-132b": "dbrx_132b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "jamba-1.5-large-398b": "jamba_1p5_large_398b",
    "rwkv6-7b": "rwkv6_7b",
    "whisper-base": "whisper_base",
})


def get_config(arch: str) -> ModelConfig:
    key = _ALIASES.get(arch, arch)
    if key not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_MODULES)}")
    return importlib.import_module(ARCH_MODULES[key]).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {name: get_config(name) for name in ARCH_MODULES}


def reduced_config(cfg: ModelConfig, periods: int = 2) -> ModelConfig:
    """Smoke-test variant of the same family: tiny width, few experts, small
    vocab, short frontends — but the SAME block pattern and code paths."""
    pat = cfg.block_pattern
    n_heads = 4
    head_dim = 16
    ratio = max(1, cfg.num_heads // cfg.num_kv_heads)
    kv = max(1, n_heads // ratio)
    d_model = n_heads * head_dim  # 64
    return cfg.replace(
        name=cfg.name + "_smoke",
        num_layers=periods * len(pat),
        d_model=d_model,
        num_heads=n_heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=4 * d_model,
        vocab_size=256,
        local_window=min(cfg.local_window, 8) if cfg.local_window else None,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        # drop-free routing so decode == teacher-forced forward in tests
        # (capacity depends on token count, which differs between the two)
        capacity_factor=8.0,
        moe_d_ff=4 * d_model if cfg.moe_d_ff else None,
        mamba_d_state=8,
        rwkv_head_dim=16,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 16) if cfg.encoder_seq else 0,
        frontend_tokens=min(cfg.frontend_tokens, 8) if cfg.frontend_tokens else 0,
        opt_state_dtype="float32",
    )
