"""yi-9b [arXiv:2403.04652] — llama-architecture dense GQA decoder.

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="yi_9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    block_pattern=(ATTN,),
    rope_theta=10_000.0,
    norm="rmsnorm",
    act="silu",
    sub_quadratic=False,
)
