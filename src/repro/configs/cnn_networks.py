"""The paper's five complete networks (§III.A / Fig. 14) as CNNConfigs.

Layer stacks follow the canonical publications; batch sizes follow Table 1.
"""
from repro.configs.base import CNNConfig, ConvSpec


def _conv(name, co, k, s=1, p=0):
    return ConvSpec(name, "conv", out_channels=co, kernel=k, stride=s, pad=p)


def _pool(name, k, s, op="max"):
    return ConvSpec(name, "pool", kernel=k, stride=s, pool_op=op)


def _relu(name):
    return ConvSpec(name, "relu")


def _fc(name, out):
    return ConvSpec(name, "fc", fc_out=out)


LENET = CNNConfig(
    name="lenet", batch=128, in_channels=1, image_hw=28, num_classes=10,
    layers=(
        _conv("conv1", 16, 5, 1, 2), _relu("relu1"), _pool("pool1", 2, 2),
        _conv("conv2", 16, 5, 1, 2), _relu("relu2"), _pool("pool2", 2, 2),
        ConvSpec("flatten", "flatten"),
        _fc("fc1", 128), _relu("relu3"), _fc("fc2", 10),
        ConvSpec("softmax", "softmax"),
    ))

CIFARNET = CNNConfig(
    name="cifarnet", batch=128, in_channels=3, image_hw=24, num_classes=10,
    layers=(
        _conv("conv1", 64, 5, 1, 2), _relu("relu1"), _pool("pool1", 3, 2),
        _conv("conv2", 64, 5, 1, 2), _relu("relu2"), _pool("pool2", 3, 2),
        ConvSpec("flatten", "flatten"),
        _fc("fc1", 64), _relu("relu3"), _fc("fc2", 10),
        ConvSpec("softmax", "softmax"),
    ))

ALEXNET = CNNConfig(
    name="alexnet", batch=128, in_channels=3, image_hw=227, num_classes=1000,
    layers=(
        _conv("conv1", 96, 11, 4, 0), _relu("relu1"), _pool("pool1", 3, 2),
        _conv("conv2", 256, 5, 1, 2), _relu("relu2"), _pool("pool2", 3, 2),
        _conv("conv3", 384, 3, 1, 1), _relu("relu3"),
        _conv("conv4", 384, 3, 1, 1), _relu("relu4"),
        _conv("conv5", 256, 3, 1, 1), _relu("relu5"), _pool("pool3", 3, 2),
        ConvSpec("flatten", "flatten"),
        _fc("fc6", 4096), _relu("relu6"),
        _fc("fc7", 4096), _relu("relu7"),
        _fc("fc8", 1000),
        ConvSpec("softmax", "softmax"),
    ))

ZFNET = CNNConfig(
    name="zfnet", batch=64, in_channels=3, image_hw=224, num_classes=1000,
    layers=(
        _conv("conv1", 96, 7, 2, 1), _relu("relu1"), _pool("pool1", 3, 2),
        _conv("conv2", 256, 5, 2, 0), _relu("relu2"), _pool("pool2", 3, 2),
        _conv("conv3", 384, 3, 1, 1), _relu("relu3"),
        _conv("conv4", 384, 3, 1, 1), _relu("relu4"),
        _conv("conv5", 256, 3, 1, 1), _relu("relu5"), _pool("pool3", 3, 2),
        ConvSpec("flatten", "flatten"),
        _fc("fc6", 4096), _relu("relu6"),
        _fc("fc7", 4096), _relu("relu7"),
        _fc("fc8", 1000),
        ConvSpec("softmax", "softmax"),
    ))


def _vgg_block(i, co, n):
    layers = []
    for j in range(n):
        layers += [_conv(f"conv{i}_{j+1}", co, 3, 1, 1), _relu(f"relu{i}_{j+1}")]
    layers.append(_pool(f"pool{i}", 2, 2))
    return layers

VGG16 = CNNConfig(
    name="vgg16", batch=32, in_channels=3, image_hw=224, num_classes=1000,
    layers=tuple(
        _vgg_block(1, 64, 2) + _vgg_block(2, 128, 2) + _vgg_block(3, 256, 3)
        + _vgg_block(4, 512, 3) + _vgg_block(5, 512, 3)
        + [ConvSpec("flatten", "flatten"),
           _fc("fc6", 4096), _relu("relu6"),
           _fc("fc7", 4096), _relu("relu7"),
           _fc("fc8", 1000),
           ConvSpec("softmax", "softmax")]
    ))

CNN_CONFIGS = {c.name: c for c in (LENET, CIFARNET, ALEXNET, ZFNET, VGG16)}


def reduced_cnn(cfg: CNNConfig, batch: int = 4) -> CNNConfig:
    """A smoke-test-sized variant: small batch, small images for big nets."""
    hw = min(cfg.image_hw, 32)
    # drop stride-heavy first convs cleanly by shrinking only batch + image
    return cfg.replace(batch=batch, image_hw=hw)
