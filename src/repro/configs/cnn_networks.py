"""The paper's five complete networks (§III.A / Fig. 14) as CNNConfigs,
plus the branching-topology configs (ResNet-18, U-Net mini) the DAG planner
exercises (DESIGN.md §11).

Layer stacks follow the canonical publications; batch sizes follow Table 1.
Branching networks are built by parameterized BUILDER functions
(``CNN_BUILDERS``) so ``reduced_cnn`` can downscale them without breaking
merge-shape consistency: a residual add needs both branches to agree on
(C, H, W) at every image size, which a naive ``replace(image_hw=...)``
cannot guarantee — the builder re-derives every skip edge instead.
"""
from repro.configs.base import CNNConfig, ConvSpec
from repro.shapes import conv_out_hw, pool_out_hw


def _conv(name, co, k, s=1, p=0, inputs=()):
    return ConvSpec(name, "conv", out_channels=co, kernel=k, stride=s, pad=p,
                    inputs=tuple(inputs))


def _pool(name, k, s, op="max"):
    return ConvSpec(name, "pool", kernel=k, stride=s, pool_op=op)


def _relu(name):
    return ConvSpec(name, "relu")


def _fc(name, out):
    return ConvSpec(name, "fc", fc_out=out)


LENET = CNNConfig(
    name="lenet", batch=128, in_channels=1, image_hw=28, num_classes=10,
    layers=(
        _conv("conv1", 16, 5, 1, 2), _relu("relu1"), _pool("pool1", 2, 2),
        _conv("conv2", 16, 5, 1, 2), _relu("relu2"), _pool("pool2", 2, 2),
        ConvSpec("flatten", "flatten"),
        _fc("fc1", 128), _relu("relu3"), _fc("fc2", 10),
        ConvSpec("softmax", "softmax"),
    ))

CIFARNET = CNNConfig(
    name="cifarnet", batch=128, in_channels=3, image_hw=24, num_classes=10,
    layers=(
        _conv("conv1", 64, 5, 1, 2), _relu("relu1"), _pool("pool1", 3, 2),
        _conv("conv2", 64, 5, 1, 2), _relu("relu2"), _pool("pool2", 3, 2),
        ConvSpec("flatten", "flatten"),
        _fc("fc1", 64), _relu("relu3"), _fc("fc2", 10),
        ConvSpec("softmax", "softmax"),
    ))

ALEXNET = CNNConfig(
    name="alexnet", batch=128, in_channels=3, image_hw=227, num_classes=1000,
    layers=(
        _conv("conv1", 96, 11, 4, 0), _relu("relu1"), _pool("pool1", 3, 2),
        _conv("conv2", 256, 5, 1, 2), _relu("relu2"), _pool("pool2", 3, 2),
        _conv("conv3", 384, 3, 1, 1), _relu("relu3"),
        _conv("conv4", 384, 3, 1, 1), _relu("relu4"),
        _conv("conv5", 256, 3, 1, 1), _relu("relu5"), _pool("pool3", 3, 2),
        ConvSpec("flatten", "flatten"),
        _fc("fc6", 4096), _relu("relu6"),
        _fc("fc7", 4096), _relu("relu7"),
        _fc("fc8", 1000),
        ConvSpec("softmax", "softmax"),
    ))

ZFNET = CNNConfig(
    name="zfnet", batch=64, in_channels=3, image_hw=224, num_classes=1000,
    layers=(
        _conv("conv1", 96, 7, 2, 1), _relu("relu1"), _pool("pool1", 3, 2),
        _conv("conv2", 256, 5, 2, 0), _relu("relu2"), _pool("pool2", 3, 2),
        _conv("conv3", 384, 3, 1, 1), _relu("relu3"),
        _conv("conv4", 384, 3, 1, 1), _relu("relu4"),
        _conv("conv5", 256, 3, 1, 1), _relu("relu5"), _pool("pool3", 3, 2),
        ConvSpec("flatten", "flatten"),
        _fc("fc6", 4096), _relu("relu6"),
        _fc("fc7", 4096), _relu("relu7"),
        _fc("fc8", 1000),
        ConvSpec("softmax", "softmax"),
    ))


def _vgg_block(i, co, n):
    layers = []
    for j in range(n):
        layers += [_conv(f"conv{i}_{j+1}", co, 3, 1, 1), _relu(f"relu{i}_{j+1}")]
    layers.append(_pool(f"pool{i}", 2, 2))
    return layers

VGG16 = CNNConfig(
    name="vgg16", batch=32, in_channels=3, image_hw=224, num_classes=1000,
    layers=tuple(
        _vgg_block(1, 64, 2) + _vgg_block(2, 128, 2) + _vgg_block(3, 256, 3)
        + _vgg_block(4, 512, 3) + _vgg_block(5, 512, 3)
        + [ConvSpec("flatten", "flatten"),
           _fc("fc6", 4096), _relu("relu6"),
           _fc("fc7", 4096), _relu("relu7"),
           _fc("fc8", 1000),
           ConvSpec("softmax", "softmax")]
    ))

CNN_CONFIGS = {c.name: c for c in (LENET, CIFARNET, ALEXNET, ZFNET, VGG16)}


# ---------------------------------------------------------------------------
# branching networks (DAG planner targets, DESIGN.md §11)
# ---------------------------------------------------------------------------

def _res_block(prefix, co, stride, skip, downsample):
    """One ResNet basic block (no BN in this stack — weights-only residual):
    convA -> reluA -> convB -> add(convB, skip') -> relu, with a 1x1/stride
    projection convS on the skip when the block changes shape.  Returns
    (layers, tail_name)."""
    layers = []
    skip2 = skip
    if downsample:
        layers.append(_conv(f"{prefix}_convS", co, 1, stride, 0,
                            inputs=(skip,)))
        skip2 = f"{prefix}_convS"
    layers += [
        _conv(f"{prefix}_convA", co, 3, stride, 1, inputs=(skip,)),
        _relu(f"{prefix}_reluA"),
        _conv(f"{prefix}_convB", co, 3, 1, 1),
        ConvSpec(f"{prefix}_add", "add",
                 inputs=(f"{prefix}_convB", skip2)),
        _relu(f"{prefix}_relu"),
    ]
    return layers, f"{prefix}_relu"


def build_resnet18(batch: int = 32, image_hw: int = 224,
                   num_classes: int = 1000, width: int = 64) -> CNNConfig:
    """ResNet-18 (residual-add family): stem conv7/2 + pool3/2, four stages
    of two basic blocks ([w, 2w, 4w, 8w] channels, stride-2 projection at
    each stage entry), global average pool, fc head."""
    layers = [_conv("conv1", width, 7, 2, 3), _relu("relu1"),
              _pool("pool1", 3, 2)]
    tail = "pool1"
    hw = pool_out_hw(conv_out_hw(image_hw, 7, 2, 3), 3, 2)
    for li, co in enumerate((width, 2 * width, 4 * width, 8 * width), 1):
        for bi in (1, 2):
            stride = 2 if (li > 1 and bi == 1) else 1
            blk, tail = _res_block(f"l{li}b{bi}", co, stride, tail,
                                   downsample=(stride != 1))
            layers += blk
            hw = conv_out_hw(hw, 3, stride, 1)
    layers += [_pool("gap", hw, hw, "avg"),
               ConvSpec("flatten", "flatten"),
               _fc("fc", num_classes),
               ConvSpec("softmax", "softmax")]
    return CNNConfig(name="resnet18", batch=batch, in_channels=3,
                     image_hw=image_hw, num_classes=num_classes,
                     layers=tuple(layers))


def build_unet_mini(batch: int = 8, image_hw: int = 32,
                    num_classes: int = 10, width: int = 8) -> CNNConfig:
    """Small U-Net (concat-skip family): two encoder levels, a middle conv,
    and two decoder levels whose upsampled features concat with the matching
    encoder activation, closed by a classification head (gap + fc) so it
    runs under the existing executors."""
    if image_hw % 4:
        raise ValueError(f"unet_mini needs image_hw % 4 == 0, "
                         f"got {image_hw}")
    w = width
    layers = [
        _conv("enc1", w, 3, 1, 1), _relu("enc1_relu"),
        _pool("pool1", 2, 2),
        _conv("enc2", 2 * w, 3, 1, 1), _relu("enc2_relu"),
        _pool("pool2", 2, 2),
        _conv("mid", 4 * w, 3, 1, 1), _relu("mid_relu"),
        ConvSpec("up2", "upsample", kernel=2),
        ConvSpec("cat2", "concat", inputs=("up2", "enc2_relu")),
        _conv("dec2", 2 * w, 3, 1, 1), _relu("dec2_relu"),
        ConvSpec("up1", "upsample", kernel=2),
        ConvSpec("cat1", "concat", inputs=("up1", "enc1_relu")),
        _conv("dec1", w, 3, 1, 1), _relu("dec1_relu"),
        _pool("gap", image_hw, image_hw, "avg"),
        ConvSpec("flatten", "flatten"),
        _fc("fc", num_classes),
        ConvSpec("softmax", "softmax"),
    ]
    return CNNConfig(name="unet_mini", batch=batch, in_channels=3,
                     image_hw=image_hw, num_classes=num_classes,
                     layers=tuple(layers))


# name -> builder(batch, image_hw, num_classes, width); reduced_cnn uses
# these to downscale branching topologies with consistent merge shapes
CNN_BUILDERS = {
    "resnet18": build_resnet18,
    "unet_mini": build_unet_mini,
}

RESNET18 = build_resnet18()
UNET_MINI = build_unet_mini()
CNN_CONFIGS[RESNET18.name] = RESNET18
CNN_CONFIGS[UNET_MINI.name] = UNET_MINI


def _first_conv_width(cfg: CNNConfig) -> int:
    return next(s.out_channels for s in cfg.layers if s.kind == "conv")


def reduced_cnn(cfg: CNNConfig, batch: int = 4) -> CNNConfig:
    """A smoke-test-sized variant: small batch, small images for big nets.

    Branching topologies go back through their builder so every skip edge is
    re-derived at the reduced size (merge shapes stay consistent); linear
    stacks keep the historical behaviour (shrink only batch + image, which
    preserves their legacy ``network_id`` fingerprints)."""
    builder = CNN_BUILDERS.get(cfg.name)
    hw = min(cfg.image_hw, 32)
    if builder is not None:
        width = min(_first_conv_width(cfg), 16)
        return builder(batch=batch, image_hw=hw,
                       num_classes=cfg.num_classes, width=width)
    # drop stride-heavy first convs cleanly by shrinking only batch + image
    return cfg.replace(batch=batch, image_hw=hw)
