"""rwkv6-7b "Finch" [arXiv:2404.05892] — attention-free RNN with
data-dependent decay (time mix) + channel mix.

32L d_model=4096 d_ff=14336 vocab=65536, rwkv head_dim=64 (64 heads).
SSM family -> long_500k RUNS (state is O(1) in sequence length).
The attention-layout machinery is inapplicable (no KV cache); noted in
DESIGN.md §Arch-applicability.
"""
from repro.configs.base import RWKV, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6_7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,            # d_model / rwkv_head_dim
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=(RWKV,),
    norm="layernorm",
    act="silu",
    rwkv_head_dim=64,
    sub_quadratic=True,
)
