"""gemma2-27b [arXiv:2408.00118] — local/global alternating attention,
attention- and final-logit softcapping, pre+post block norms.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000, head_dim=128
(heads*head_dim != d_model, as in the released model).  local_window=4096.
The alternating pattern is a scanned super-block of (local, global).
Global layers are full attention -> long_500k skipped.
"""
from repro.configs.base import ATTN, ATTN_LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma2_27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    block_pattern=(ATTN_LOCAL, ATTN),
    rope_theta=10_000.0,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    local_window=4096,
    norm="rmsnorm",
    post_norm=True,
    act="gelu",
    tie_embeddings=True,
    sub_quadratic=False,
)
