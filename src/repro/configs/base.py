"""Config dataclasses for models, input shapes, meshes and training runs.

Every assigned architecture gets one ``ModelConfig`` in its own module under
``repro.configs``; the paper's CNNs get ``CNNConfig``s.  Configs are frozen
dataclasses so they can be used as static args to ``jax.jit``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Block kinds understood by repro.models.transformer
# ---------------------------------------------------------------------------
ATTN = "attn"              # global self attention + dense MLP
ATTN_LOCAL = "attn_local"  # sliding-window self attention + dense MLP
ATTN_MOE = "attn_moe"      # global self attention + MoE FFN
MAMBA = "mamba"            # Mamba SSM mixer + dense MLP
MAMBA_MOE = "mamba_moe"    # Mamba SSM mixer + MoE FFN
RWKV = "rwkv"              # RWKV-6 time mix + channel mix
MOE_ONLY = "moe"           # (unused standalone)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description for the LM-family stack."""

    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # Super-block pattern: the stack is ``num_layers // len(block_pattern)``
    # repetitions of ``block_pattern`` (scanned).  Entries are block kinds.
    block_pattern: Tuple[str, ...] = (ATTN,)

    # Attention details ------------------------------------------------------
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    attn_logit_softcap: Optional[float] = None   # gemma2: 50.0
    final_logit_softcap: Optional[float] = None  # gemma2: 30.0
    local_window: Optional[int] = None           # sliding-window size
    norm: str = "rmsnorm"                        # rmsnorm | layernorm
    norm_eps: float = 1e-5
    post_norm: bool = False                      # gemma2 uses pre+post norms
    act: str = "silu"                            # silu | gelu
    tie_embeddings: bool = False

    # MoE --------------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: Optional[int] = None               # expert hidden size (defaults d_ff)
    num_shared_experts: int = 0                  # llama4-style shared expert
    router_jitter: float = 0.0
    capacity_factor: float = 1.25

    # Mamba (jamba) -----------------------------------------------------------
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # RWKV-6 ------------------------------------------------------------------
    rwkv_head_dim: int = 64
    rwkv_chunked: bool = False     # chunk-parallel WKV (beyond-paper perf)

    # Encoder-decoder (whisper) ----------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0                         # encoder positions (frames)

    # Modality frontend stub --------------------------------------------------
    frontend: Optional[str] = None               # clip_stub | audio_stub | None
    frontend_tokens: int = 0                     # prefix embedding positions

    # Numerics ----------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"             # bf16 for the >=300B configs

    # Sub-quadratic support: True when long-context decode is admissible.
    sub_quadratic: bool = False

    def __post_init__(self):
        if self.num_layers % len(self.block_pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"block_pattern of length {len(self.block_pattern)}")

    # -- derived -------------------------------------------------------------
    @property
    def num_periods(self) -> int:
        return self.num_layers // len(self.block_pattern)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    def param_count(self) -> int:
        """Analytical parameter count (embedding + blocks + head)."""
        from repro.models import registry as _r  # lazy, avoids cycle
        return _r.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import registry as _r
        return _r.param_count(self, active_only=True)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) column of the assignment grid."""

    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """The shape set an architecture actually runs (long_500k only when
    sub-quadratic; see DESIGN.md §Arch-applicability)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        out.append(LONG_500K)
    return tuple(out)


# ---------------------------------------------------------------------------
# Mesh / parallelism
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ParallelConfig:
    """How a model is laid out on the mesh."""

    fsdp: bool = True               # shard params/opt over the data axis
    fsdp_pod: bool = False          # additionally shard over the pod axis
    seq_shard_saved: bool = True    # SP: shard saved residuals over model axis
    remat: str = "block"            # none | block | full
    remat_policy: str = "none"      # none | save_moe (keep MoE outs in bwd)
    microbatches: int = 1           # gradient accumulation steps
    accum_dtype: str = "float32"    # grad-accum dtype (bf16 for >=300B cfgs)
    window_kv_cache: bool = False   # local-attn layers cache only the window
    pipeline_stages: int = 1        # >1: GPipe over the pod axis
    grad_compression: str = "none"  # none | bf16 | int8
    scan_layers: bool = True
    # Decode cache layout: auto = let the layout selector pick.
    kv_cache_layout: str = "auto"   # auto | bksd | sbkd


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    seed: int = 0
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10


# ---------------------------------------------------------------------------
# The paper's CNNs
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ConvSpec:
    name: str
    kind: str                      # conv | pool | fc | softmax | relu | lrn |
                                   # flatten | add | concat | upsample
    out_channels: int = 0
    kernel: int = 0                # also: upsample factor for kind="upsample"
    stride: int = 1
    pad: int = 0
    pool_op: str = "max"           # max | avg
    fc_out: int = 0
    # Graph edges: names of the producer layers this layer consumes.  Empty
    # means "the previous layer" (the linear default), so existing configs
    # are untouched.  Merge kinds ("add", "concat") name 2+ producers; a
    # branch is opened by naming a non-adjacent producer.  ``repr=False``
    # keeps ``repr(cfg.layers)`` — and with it the legacy linear
    # ``serve.plan_cache.network_id`` fingerprints — byte-identical; the
    # edge structure is fingerprinted separately (only when present).
    inputs: Tuple[str, ...] = field(default=(), repr=False)


@dataclass(frozen=True)
class CNNConfig:
    name: str
    batch: int
    in_channels: int
    image_hw: int
    num_classes: int
    layers: Tuple[ConvSpec, ...]

    def replace(self, **kw) -> "CNNConfig":
        return dataclasses.replace(self, **kw)
