"""whisper-base [arXiv:2212.04356] — encoder-decoder; conv audio frontend is a
STUB (``input_specs`` provides precomputed mel-frame embeddings).

6L encoder + 6L decoder, d_model=512 8H (kv=8) d_ff=2048 vocab=51865.
The released model caps at 1500 encoder / 448 decoder positions; the assigned
32k shapes exercise the backbone mechanically (documented).  Full attention
-> long_500k skipped.  Decoder caches self-attention KV per step and
cross-attention KV once at prefill.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="whisper_base",
    family="encdec",
    num_layers=6,                 # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    block_pattern=(ATTN,),
    norm="layernorm",
    act="gelu",
    encoder_layers=6,
    encoder_seq=1500,
    frontend="audio_stub",
    sub_quadratic=False,
)
