"""dbrx-132b [hf:databricks/dbrx-base] — fine-grained MoE, every layer MoE.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, 16 experts top-4.
~132B total / ~36B active.
"""
from repro.configs.base import ATTN_MOE, ModelConfig

CONFIG = ModelConfig(
    name="dbrx_132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    block_pattern=(ATTN_MOE,),
    rope_theta=500_000.0,
    norm="layernorm",
    act="silu",
    num_experts=16,
    experts_per_token=4,
    sub_quadratic=False,
)
