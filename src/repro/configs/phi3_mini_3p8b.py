"""phi3-mini-3.8b [arXiv:2404.14219] — dense decoder, RoPE + SwiGLU + MHA.

32L d_model=3072 32H (kv=32 i.e. MHA) d_ff=8192 vocab=32064.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="phi3_mini_3p8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    block_pattern=(ATTN,),
    rope_theta=10_000.0,
    norm="rmsnorm",
    act="silu",
    sub_quadratic=False,
)
