"""phi-3-vision-4.2b — phi3-mini text backbone + CLIP vision frontend (stub).

[hf:microsoft/Phi-3-vision-128k-instruct]  32L d_model=3072 32H (MHA kv=32)
d_ff=8192 vocab=32064.  The vision tower is a STUB: ``input_specs`` provides
precomputed patch embeddings that are concatenated in front of the token
embeddings (early fusion).  Pure full attention -> long_500k skipped.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="phi3_vision_4p2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    block_pattern=(ATTN,),
    rope_theta=10_000.0,
    norm="rmsnorm",
    act="silu",
    frontend="clip_stub",
    frontend_tokens=576,          # 24x24 CLIP-L patch grid per image
    sub_quadratic=False,
)
