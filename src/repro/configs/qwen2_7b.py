"""qwen2-7b [arXiv:2407.10671] — dense GQA decoder with QKV bias.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
Note 28 heads is NOT divisible by the 16-way model axis: GSPMD pads the head
dim (verified); the roofline table quantifies the padding waste.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen2_7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    block_pattern=(ATTN,),
    rope_theta=1_000_000.0,
    qkv_bias=True,
    norm="rmsnorm",
    act="silu",
    sub_quadratic=False,
)
