"""jamba-1.5-large-398b [arXiv:2403.19887] — hybrid Mamba+attention (1:7
attn:mamba interleave), MoE every other layer, 16 experts top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.
Super-block (period 8): attention on layer 3 of each period (as in Jamba),
MoE FFN on every odd layer within the period.  Hybrid -> long_500k RUNS
(only 9/72 layers hold a KV cache; mamba state is O(1) in sequence).
Optimizer state kept in bf16 (DESIGN.md §5).
"""
from repro.configs.base import ATTN_MOE, MAMBA, MAMBA_MOE, ModelConfig

# period of 8: [mamba, mamba_moe, mamba, attn_moe, mamba, mamba_moe, mamba, mamba_moe]
_PERIOD = (MAMBA, MAMBA_MOE, MAMBA, "attn_moe", MAMBA, MAMBA_MOE, MAMBA, MAMBA_MOE)

CONFIG = ModelConfig(
    name="jamba_1p5_large_398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    block_pattern=_PERIOD,
    norm="rmsnorm",
    act="silu",
    num_experts=16,
    experts_per_token=2,
    moe_d_ff=24576,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    opt_state_dtype="bfloat16",
    sub_quadratic=True,
)
