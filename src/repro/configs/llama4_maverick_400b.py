"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4 family] — interleaved
dense/MoE decoder, 128 routed experts top-1 + 1 shared expert, early-fusion
multimodal (text backbone here; vision frontend is out of assigned scope).

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.
MoE on every other layer (super-block = [dense, moe]).  ~400B total / ~17B
active.  Optimizer state kept in bf16 (see DESIGN.md §5 memory budget).
"""
from repro.configs.base import ATTN, ATTN_MOE, ModelConfig

CONFIG = ModelConfig(
    name="llama4_maverick_400b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=(ATTN, ATTN_MOE),
    rope_theta=500_000.0,
    norm="rmsnorm",
    act="silu",
    num_experts=128,
    experts_per_token=1,
    moe_d_ff=8192,
    num_shared_experts=1,
    opt_state_dtype="bfloat16",
    sub_quadratic=False,
)
