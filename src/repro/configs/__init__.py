from repro.configs.base import (  # noqa: F401
    ModelConfig, ShapeConfig, ParallelConfig, TrainConfig, CNNConfig,
    ConvSpec, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K, ALL_SHAPES,
    SHAPES_BY_NAME, shapes_for)
from repro.configs.registry import (  # noqa: F401
    ARCH_IDS, get_config, all_configs, reduced_config)
