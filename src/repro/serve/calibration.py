"""Measured threshold calibration for the serving path (DESIGN.md §7-§8).

``core.heuristic.calibrate`` has always accepted a ``measure(layer, layout)
-> seconds`` callback — the paper's one-time hardware profiling — but
nothing ever exercised it: every caller fell back to the analytic sweep.
DeLTA (Lym et al. 2019) shows why that is not good enough: memory-traffic
models drift from silicon, so the thresholds a server actually plans under
must come from measurement (and be cached, because profiling at admission
time is unaffordable).

``pallas_conv_measure`` times the real Pallas conv engines.  The calibration
sweep varies N and Ci (the threshold variables) — those are kept exact; the
non-swept dims (HW, Co) are scaled down to a proxy size so interpret-mode
timing stays tractable.  Both layouts are timed on the SAME proxied layer,
so the comparison the thresholds encode survives the proxy.

Thresholds are persisted as **per-dtype rows**: the element size scales
every byte term and doubles the sublane width (8 -> 16 at bf16), so (Ct,
Nt) are only valid for the storage dtype they were swept at — a bf16 server
must not plan under fp32 thresholds.  ``measured_thresholds`` is the
serving entry point: load the persisted row for the requested dtype if
present, otherwise calibrate that row (at that dtype's element size) and
merge it into the file.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.paper_table1 import ConvLayer
from repro.core.heuristic import Thresholds, calibrate
from repro.dtypes import DEFAULT_DTYPE, canon_dtype, dtype_bytes, jnp_dtype


def _load_rows(path: str) -> Dict[str, Dict]:
    """All persisted rows keyed by canonical dtype.  Reads both the v2
    per-dtype format ({"rows": {dtype: {Ct, Nt}}}) and the legacy flat
    {"Ct": ..., "Nt": ...} file (treated as a float32 row)."""
    with open(path) as f:
        obj = json.load(f)
    if "rows" in obj:
        return {canon_dtype(k): v for k, v in obj["rows"].items()}
    if "Ct" in obj:                    # legacy single-row file
        return {DEFAULT_DTYPE: {"Ct": obj["Ct"], "Nt": obj["Nt"]}}
    return {}


def save_thresholds(th: Thresholds, path: str, *,
                    dtype: str = DEFAULT_DTYPE,
                    source: str = "measured") -> str:
    """Merge one dtype's (Ct, Nt) row into the persisted threshold table."""
    dtype = canon_dtype(dtype)
    rows = _load_rows(path) if os.path.exists(path) else {}
    rows[dtype] = {**dataclasses.asdict(th), "source": source}
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"version": 2, "rows": rows}, f, indent=1)
    os.replace(tmp, path)
    return path


def load_thresholds(path: str, dtype: str = DEFAULT_DTYPE) -> Thresholds:
    """The persisted row for ``dtype``; KeyError when that row is missing
    (callers treat a missing row as "calibrate it now")."""
    row = _load_rows(path)[canon_dtype(dtype)]
    return Thresholds(Ct=row["Ct"], Nt=row["Nt"])


def pallas_conv_measure(*, proxy_hw: int = 8, proxy_co: int = 32,
                        reps: int = 2, interpret: bool = True,
                        dtype: str = DEFAULT_DTYPE
                        ) -> Callable[[ConvLayer, str], float]:
    """Build a ``measure(layer, layout) -> seconds`` callback that times the
    real Pallas conv engines (direct-CHWN / im2col-MM-NCHW).

    N and Ci are taken from the layer verbatim (they are what ``calibrate``
    sweeps); HW and Co are clamped to the proxy size.  Operands are created
    in the storage ``dtype`` so the timing reflects the element size the
    thresholds will be used for.  The 1-byte (int8) row times the engines on
    genuine int8 activations — random values in the quantized range, with
    float weights, exactly what the mixed-dtype executor feeds them (the
    per-channel scale rides the weights).  Each timing is the best of
    ``reps`` after one warm-up call (which also absorbs compile)."""
    from repro.cnn.layers import conv_forward
    dtype = canon_dtype(dtype)
    jdt = jnp_dtype(dtype)

    def measure(l: ConvLayer, layout: str) -> float:
        hw = max(min(l.HW, proxy_hw), l.F)
        co = min(l.Co, proxy_co)
        key = jax.random.PRNGKey(0)
        if layout == "CHWN":
            shape = (l.Ci, hw, hw, l.N)
        else:
            shape = (l.N, l.Ci, hw, hw)
        if dtype == "int8":
            x = jax.random.randint(key, shape, -127, 128, jnp.int8)
            w = (jax.random.normal(key, (co, l.Ci, l.F, l.F), jnp.float32)
                 * 0.1)
        else:
            x = jax.random.normal(key, shape, jnp.float32).astype(jdt)
            w = (jax.random.normal(key, (co, l.Ci, l.F, l.F), jnp.float32)
                 * 0.1).astype(jdt)

        def f():
            return conv_forward(x, w, layout, l.S, 0, impl="pallas",
                                interpret=interpret)

        jax.block_until_ready(f())          # warm-up + compile
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(f())
            best = min(best, time.perf_counter() - t0)
        return best

    return measure


def measured_thresholds(path: Optional[str] = None, *,
                        dtype: str = DEFAULT_DTYPE, force: bool = False,
                        measure: Optional[Callable[[ConvLayer, str], float]]
                        = None, interpret: bool = True) -> Thresholds:
    """Serving-default thresholds for one storage dtype: persisted
    measurement, not the analytic sweep.  Loads ``path``'s row for
    ``dtype`` when present (unless ``force``); otherwise runs ``calibrate``
    at that dtype's element size with the Pallas measurement callback and
    merges the new row into the file."""
    dtype = canon_dtype(dtype)
    if path and os.path.exists(path) and not force:
        try:
            return load_thresholds(path, dtype)
        except KeyError:
            pass                        # file exists but lacks this row
    th = calibrate(measure or pallas_conv_measure(interpret=interpret,
                                                  dtype=dtype),
                   dtype_bytes=dtype_bytes(dtype))
    if path:
        save_thresholds(th, path, dtype=dtype, source="measured")
    return th
