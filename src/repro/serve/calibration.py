"""DEPRECATED shim — measured calibration moved to
``repro.perfmodel.calibration`` (DESIGN.md §13).

The serving path still imports its calibration entry points from here
(``repro.serve`` re-exports them), but the implementation — the Pallas
measurement callback, per-(hardware, dtype) threshold persistence, and the
predicted-vs-measured cross-validation — lives with the rest of the perf
model.  New code should import from ``repro.perfmodel``.
"""
from repro.perfmodel.calibration import (  # noqa: F401
    DEFAULT_HARDWARE, CalibrationPoint, CrossValidation, cross_validate,
    hardware_id, load_thresholds, measured_thresholds, pallas_conv_measure,
    proxied_layer, save_thresholds)
