"""Measured threshold calibration for the serving path (DESIGN.md §7).

``core.heuristic.calibrate`` has always accepted a ``measure(layer, layout)
-> seconds`` callback — the paper's one-time hardware profiling — but
nothing ever exercised it: every caller fell back to the analytic sweep.
DeLTA (Lym et al. 2019) shows why that is not good enough: memory-traffic
models drift from silicon, so the thresholds a server actually plans under
must come from measurement (and be cached, because profiling at admission
time is unaffordable).

``pallas_conv_measure`` times the real Pallas conv engines.  The calibration
sweep varies N and Ci (the threshold variables) — those are kept exact; the
non-swept dims (HW, Co) are scaled down to a proxy size so interpret-mode
timing stays tractable.  Both layouts are timed on the SAME proxied layer,
so the comparison the thresholds encode survives the proxy.

``measured_thresholds`` is the serving entry point: load the persisted
thresholds if present, otherwise calibrate measured and persist.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.paper_table1 import ConvLayer
from repro.core.heuristic import Thresholds, calibrate


def save_thresholds(th: Thresholds, path: str, source: str = "measured"
                    ) -> str:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({**dataclasses.asdict(th), "source": source}, f, indent=1)
    os.replace(tmp, path)
    return path


def load_thresholds(path: str) -> Thresholds:
    with open(path) as f:
        obj = json.load(f)
    return Thresholds(Ct=obj["Ct"], Nt=obj["Nt"])


def pallas_conv_measure(*, proxy_hw: int = 8, proxy_co: int = 32,
                        reps: int = 2, interpret: bool = True
                        ) -> Callable[[ConvLayer, str], float]:
    """Build a ``measure(layer, layout) -> seconds`` callback that times the
    real Pallas conv engines (direct-CHWN / im2col-MM-NCHW).

    N and Ci are taken from the layer verbatim (they are what ``calibrate``
    sweeps); HW and Co are clamped to the proxy size.  Each timing is the
    best of ``reps`` after one warm-up call (which also absorbs compile)."""
    from repro.cnn.layers import conv_forward

    def measure(l: ConvLayer, layout: str) -> float:
        hw = max(min(l.HW, proxy_hw), l.F)
        co = min(l.Co, proxy_co)
        key = jax.random.PRNGKey(0)
        if layout == "CHWN":
            x = jax.random.normal(key, (l.Ci, hw, hw, l.N), jnp.float32)
        else:
            x = jax.random.normal(key, (l.N, l.Ci, hw, hw), jnp.float32)
        w = jax.random.normal(key, (co, l.Ci, l.F, l.F), jnp.float32) * 0.1

        def f():
            return conv_forward(x, w, layout, l.S, 0, impl="pallas",
                                interpret=interpret)

        jax.block_until_ready(f())          # warm-up + compile
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(f())
            best = min(best, time.perf_counter() - t0)
        return best

    return measure


def measured_thresholds(path: Optional[str] = None, *, force: bool = False,
                        measure: Optional[Callable[[ConvLayer, str], float]]
                        = None, interpret: bool = True) -> Thresholds:
    """Serving-default thresholds: persisted measurement, not the analytic
    sweep.  Loads ``path`` when it exists (unless ``force``); otherwise runs
    ``calibrate`` with the Pallas measurement callback and persists."""
    if path and os.path.exists(path) and not force:
        return load_thresholds(path)
    th = calibrate(measure or pallas_conv_measure(interpret=interpret))
    if path:
        save_thresholds(th, path, source="measured")
    return th
