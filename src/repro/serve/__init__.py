"""Batch-adaptive serving subsystem for the fused CNN engine (DESIGN.md §7).

The paper's central result is that the best layout flips with batch size and
channel count (§IV.A thresholds Ct/Nt): a production server seeing variable
batch sizes must replan per batch *bucket* — and must do so exactly once per
bucket, cuDNN-style (cached algorithm selection behind layout-flexible
primitives).  This package provides:

  * ``PlanCache`` — memoizes ``plan_network_fused`` / ``assign_layouts``
    results keyed on (network, batch-bucket, dtype, training), with pow-2
    batch bucketing (pad-to-bucket), LRU bounding (``max_entries``), and
    JSON persistence.  The dtype key selects dtype-specific plans: bf16
    buckets are planned at 2-byte element size (halved byte models, doubled
    sublane width) and can carry different layouts than fp32;
  * measured threshold calibration — ``calibrate(measure=...)`` over the
    real Pallas kernels at the serving dtype, persisted as per-dtype (Ct,
    Nt) rows next to the plans, replacing the hard-coded analytic sweep as
    the serving default.
"""
from repro.serve.plan_cache import (  # noqa: F401
    CacheStats, PlanCache, PlanKey, bucket_for, network_id, pad_to_bucket)
from repro.serve.calibration import (  # noqa: F401
    load_thresholds, measured_thresholds, pallas_conv_measure,
    save_thresholds)
