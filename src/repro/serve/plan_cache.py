"""Plan cache: one layout plan per (network, batch-bucket, dtype, training).

``plan_network_fused`` re-runs the layout DP from scratch on every call and
only ever plans the batch baked into the config — but the Nt threshold makes
the CHWN/NCHW choice *batch-dependent* (paper §IV.A / Fig. 4), so a server
seeing variable batch sizes needs one plan per batch bucket, computed once.
Incoming batches are rounded up to pow-2 buckets and padded to the bucket
size; the padded rows are sliced off after the fused forward (conv/pool/fc
/softmax are all row-independent, so real rows are unaffected).

The dtype key is load-bearing, not just a label: plans are produced at the
key's storage dtype (``plan_network_fused(cfg, dtype=...)``), so a bf16
bucket can carry a different layout assignment than the same fp32 bucket
(halved byte models, doubled sublane width), and calibrated thresholds are
held as per-dtype rows (``thresholds_for``).  The ``policy`` key dimension
(ISSUE 5) separates ``uniform`` plans (one storage dtype network-wide —
the key's ``dtype``) from ``mixed`` plans (per-layer (layout, dtype) DP:
``dtype`` is then the BASE float dtype and interior conv chains may store
int8), so a server can flip ``--dtype-policy`` without invalidating either
family's cached plans.

The ``stack`` key dimension (DESIGN.md §14) separates plans produced with
cross-layer stack fusion (``"auto"``, the default) from stacks-off plans
(``"off"``): the guarded serving ladder falls back to the stacks-off
variant of a failing plan, and that fallback must be the planner's OWN
plan for the variant — a cache key, never an ad-hoc replan.

The ``devices`` key dimension (DESIGN.md §15) serves the multi-chip mesh:
plans for a data-parallel server are keyed on the PER-SHARD bucket
(``ceil(batch / devices)``) and produced at that shard batch, because the
per-shard N is what crosses (or stops crossing) the Nt threshold — a global
batch of 128 on 8 chips must get the 16-image plan, not the 128-image one.
Every shard of the mesh executes the one cached plan, so a bucket compiles
once no matter how many chips serve it.  ``devices == 1`` is omitted from
the serialized key, keeping legacy cache files byte-identical.

The cache persists to JSON (plans + the calibrated threshold rows they were
planned under) so a restarted server never replans or recalibrates, and is
bounded: ``max_entries`` caps each plan map with least-recently-hit
eviction, with the recency order itself persisted across restarts.
Persistence is crash-safe (DESIGN.md §14): ``save`` stamps a payload
checksum and fsyncs before the atomic replace, and ``load`` validates
schema + checksum, renaming an unreadable/torn/tampered file aside as
``*.corrupt`` and rebuilding (replan) instead of refusing to construct.
"""
from __future__ import annotations

import dataclasses
import hashlib
import logging
import os
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple, Union

import jax.numpy as jnp

from repro.configs.base import CNNConfig
from repro.core.selector import Assignment, FusedOp, FusedPlan
from repro.dtypes import DEFAULT_DTYPE, canon_dtype
from repro.perfmodel import DEFAULT_HARDWARE, Thresholds
from repro.runtime.resilience import (CorruptStateError, atomic_json_dump,
                                      load_json_guarded, quarantine_file)

log = logging.getLogger("repro.serve.plan_cache")


def bucket_for(batch: int, *, min_bucket: int = 1,
               max_bucket: Optional[int] = None) -> int:
    """Smallest pow-2 bucket >= ``batch`` (clamped below by ``min_bucket``).

    Raises when the batch exceeds ``max_bucket`` — admission control must
    split oversized batches *before* bucketing, padding can't help there.
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    b = max(min_bucket, 1 << (batch - 1).bit_length())
    if max_bucket is not None and b > max_bucket:
        if batch <= max_bucket:
            return max_bucket           # min(pow2, cap): cap is the bucket
        raise ValueError(
            f"batch {batch} exceeds max_bucket {max_bucket}; split the "
            "admission before bucketing")
    return b


def pad_to_bucket(x_nchw, bucket: int):
    """Zero-pad the batch (leading) dim up to ``bucket`` rows."""
    B = x_nchw.shape[0]
    if B > bucket:
        raise ValueError(f"batch {B} larger than bucket {bucket}")
    if B == bucket:
        return x_nchw
    pad = [(0, bucket - B)] + [(0, 0)] * (x_nchw.ndim - 1)
    return jnp.pad(x_nchw, pad)


def network_id(cfg: CNNConfig) -> str:
    """Cache identity of a network: the name alone is not enough (a reduced
    96px "alexnet" must not collide with the full 227px one), so the layer
    structure is fingerprinted into the key.  Graph edges are part of that
    structure — ``ConvSpec.inputs`` is excluded from ``repr`` (which keeps
    every pre-DAG linear fingerprint stable), so topology is folded in
    explicitly, and only when some layer actually carries edges: two configs
    that differ only in how their branches wire up must not collide."""
    desc = repr((cfg.name, cfg.in_channels, cfg.image_hw, cfg.num_classes,
                 cfg.layers))
    edges = tuple((s.name, s.inputs) for s in cfg.layers if s.inputs)
    if edges:
        desc += repr(edges)
    return f"{cfg.name}@{hashlib.sha1(desc.encode()).hexdigest()[:10]}"


@dataclass(frozen=True)
class PlanKey:
    network: str                       # network_id(), not the bare name
    bucket: int                        # PER-SHARD batch bucket (== the
                                       # global bucket when devices == 1)
    dtype: str                         # canonical storage dtype name
    training: bool
    policy: str = "uniform"            # "uniform" (dtype network-wide) |
                                       # "mixed" (per-layer dtype DP over
                                       # the base `dtype`)
    stack: str = "auto"                # stack_policy the plan was produced
                                       # under: "auto" | "off" (§14 ladder)
    devices: int = 1                   # data-parallel mesh width the plan
                                       # serves (DESIGN.md §15); the plan
                                       # itself is produced at ``bucket``,
                                       # the SHARD batch

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        if d.get("stack") == "auto":
            # the default is omitted so pre-§14 cache files stay
            # byte-identical (and older readers keep loading new files)
            d.pop("stack")
        if d.get("devices") == 1:
            # same contract for the §15 mesh dimension: single-chip keys
            # (and therefore every legacy cache file) serialize unchanged
            d.pop("devices")
        return d


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


def _plan_to_obj(plan: FusedPlan) -> Dict:
    return dataclasses.asdict(plan)


def _plan_from_obj(obj: Dict) -> FusedPlan:
    # pre-ISSUE-5 entries lack the dtype fields; the dataclass defaults
    # ("" = "the run's dtype") reproduce the old behaviour exactly
    ops = [FusedOp(**op) for op in obj["ops"]]
    return FusedPlan(layouts=list(obj["layouts"]), ops=ops,
                     transforms=list(obj["transforms"]),
                     total_s=obj["total_s"], fused_bytes=obj["fused_bytes"],
                     unfused_bytes=obj["unfused_bytes"],
                     dtypes=list(obj.get("dtypes", [])),
                     base_dtype=obj.get("base_dtype", ""),
                     # pre-ISSUE-7 entries lack the stack round-trip field
                     intermediate_roundtrip_bytes=obj.get(
                         "intermediate_roundtrip_bytes", 0))


def _assignment_from_obj(obj: Dict) -> Assignment:
    return Assignment(layouts=list(obj["layouts"]),
                      transforms=list(obj["transforms"]),
                      total_s=obj["total_s"],
                      dtypes=list(obj.get("dtypes", [])))


ThresholdsArg = Union[Thresholds, Dict[str, Thresholds], None]


class PlanCache:
    """Memoized layout planning over batch buckets, with disk persistence.

    ``planner_calls`` counts actual (re)planning work — the acceptance
    criterion for the serving path is that it stays flat when the same
    bucket recurs.  Per-key hit/miss stats feed the serving report.

    ``thresholds`` accepts either a single ``Thresholds`` (stored as the
    float32 row, the historical behaviour — note that bare ``calibrate()``
    sweeps at ``DEFAULT_DTYPE_BYTES`` = 2, so fp32-faithful rows should be
    produced with ``calibrate(dtype_bytes=4)`` or per-dtype
    ``measured_thresholds``) or a dict of per-dtype rows;
    ``thresholds_for(dtype)`` is the dtype-aware accessor.  ``max_entries``
    bounds each plan map (fused / unfused separately): inserting beyond the
    cap evicts the least-recently-HIT entry, and the recency order is
    persisted so a restarted bounded cache evicts in the same order it
    would have unrestarted.  Evicted keys keep their per-key stats; a
    re-seen evicted key replans (another ``planner_calls`` increment).
    """

    def __init__(self, path: Optional[str] = None,
                 thresholds: ThresholdsArg = None, *,
                 min_bucket: Optional[int] = None,
                 max_bucket: Optional[int] = None,
                 max_entries: Optional[int] = None):
        self.path = path
        # caller-supplied settings always win over persisted ones; the
        # persisted values only fill in what the caller left unspecified
        if isinstance(thresholds, Thresholds):
            thresholds = {DEFAULT_DTYPE: thresholds}
        # threshold rows are versioned by (hardware id, dtype) — DESIGN.md
        # §13.  Caller-supplied and legacy (unversioned) rows land under
        # DEFAULT_HARDWARE, which every lookup falls back to.
        self._thresholds: Dict[Tuple[str, str], Thresholds] = {
            (DEFAULT_HARDWARE, canon_dtype(k)): v
            for k, v in (thresholds or {}).items()}
        self._explicit = {"thresholds": set(self._thresholds),
                          "min_bucket": min_bucket is not None,
                          "max_bucket": max_bucket is not None,
                          "max_entries": max_entries is not None}
        self.min_bucket = 1 if min_bucket is None else min_bucket
        self.max_bucket = 256 if max_bucket is None else max_bucket
        if max_entries is not None and max_entries < 1:
            raise ValueError(
                f"max_entries must be >= 1 (or None for unbounded), got "
                f"{max_entries}")
        self.max_entries = max_entries          # None: unbounded
        self.planner_calls = 0
        self.evictions = 0
        self.stats = CacheStats()
        self.per_key: Dict[PlanKey, CacheStats] = {}
        # OrderedDicts in recency order (least-recently-hit first)
        self._fused: "OrderedDict[PlanKey, FusedPlan]" = OrderedDict()
        self._unfused: "OrderedDict[PlanKey, Assignment]" = OrderedDict()
        # quarantined file paths from corrupt-state recoveries (§14): the
        # server reports each as a ``corrupt_state`` incident
        self.corrupt_recoveries: List[str] = []
        if path and os.path.exists(path):
            self.load(path)

    # -- thresholds ----------------------------------------------------------

    @property
    def thresholds(self) -> Optional[Thresholds]:
        """The float32 row (legacy single-dtype accessor)."""
        return self._thresholds.get((DEFAULT_HARDWARE, DEFAULT_DTYPE))

    @thresholds.setter
    def thresholds(self, th: ThresholdsArg) -> None:
        if th is None:
            self._thresholds.pop((DEFAULT_HARDWARE, DEFAULT_DTYPE), None)
            return
        if isinstance(th, Thresholds):
            th = {DEFAULT_DTYPE: th}
        for k, v in th.items():
            self.set_thresholds(v, dtype=k)

    def thresholds_for(self, dtype: str = DEFAULT_DTYPE,
                       hardware: Optional[str] = None
                       ) -> Optional[Thresholds]:
        """Row for (``hardware``, ``dtype``); a hardware id with no row of
        its own falls back to the DEFAULT_HARDWARE (legacy/unversioned)
        row, so old caches keep planning after a hardware change."""
        dtype = canon_dtype(dtype)
        if hardware is not None:
            row = self._thresholds.get((hardware, dtype))
            if row is not None:
                return row
        return self._thresholds.get((DEFAULT_HARDWARE, dtype))

    def set_thresholds(self, th: Thresholds, dtype: str = DEFAULT_DTYPE,
                       hardware: Optional[str] = None) -> None:
        key = (hardware or DEFAULT_HARDWARE, canon_dtype(dtype))
        self._thresholds[key] = th
        self._explicit["thresholds"].add(key)

    # -- bucketing -----------------------------------------------------------

    def bucket(self, batch: int) -> int:
        return bucket_for(batch, min_bucket=self.min_bucket,
                          max_bucket=self.max_bucket)

    def _key(self, cfg: CNNConfig, batch: Optional[int], dtype: str,
             training: bool, policy: str = "uniform",
             stack: str = "auto", devices: int = 1,
             pre_sharded: bool = False) -> PlanKey:
        if policy not in ("uniform", "mixed"):
            raise ValueError(f"unknown dtype policy {policy!r}")
        if stack not in ("auto", "off"):
            raise ValueError(f"unknown stack policy {stack!r}")
        if devices < 1:
            raise ValueError(f"devices must be >= 1, got {devices}")
        # §15 planning invariant: the bucket — and therefore the plan — is
        # the PER-SHARD batch, so a global batch above the Nt crossover
        # whose shard batch sits below it gets the shard batch's layouts.
        # The devices division happens exactly once: callers holding the
        # GLOBAL batch use the default, callers already holding the
        # per-shard batch/bucket pass ``pre_sharded=True`` — dividing an
        # already-sharded batch again would resolve a bogus smaller key.
        g = cfg.batch if batch is None else batch
        b = self.bucket(g if pre_sharded else -(-g // devices))
        return PlanKey(network_id(cfg), b, canon_dtype(dtype), training,
                       policy, stack, devices)

    def _record(self, key: PlanKey, hit: bool) -> None:
        ks = self.per_key.setdefault(key, CacheStats())
        if hit:
            self.stats.hits += 1
            ks.hits += 1
        else:
            self.stats.misses += 1
            ks.misses += 1

    def _touch(self, store: OrderedDict, key: PlanKey, hit: bool) -> None:
        """Refresh recency on a hit; evict the LRU entry past the bound."""
        if hit:
            store.move_to_end(key)
            return
        if self.max_entries is not None:
            while len(store) > self.max_entries:
                store.popitem(last=False)
                self.evictions += 1

    # -- planning entry points ----------------------------------------------

    def fused_plan(self, cfg: CNNConfig, batch: Optional[int] = None, *,
                   dtype: str = DEFAULT_DTYPE, training: bool = False,
                   policy: str = "uniform", stack: str = "auto",
                   devices: int = 1,
                   pre_sharded: bool = False) -> Tuple[FusedPlan, int, bool]:
        """Fused-engine plan for ``batch`` (default: cfg.batch), planned at
        the bucket size AND the key's storage dtype/policy/stack-policy.
        ``devices`` > 1 (DESIGN.md §15) buckets and plans the PER-SHARD
        batch (ceil(batch / devices)): every shard of the mesh executes the
        one returned plan, so the same shard bucket compiles exactly once
        regardless of how many chips serve it.  ``pre_sharded=True`` means
        ``batch`` is ALREADY the per-shard batch (no further division) —
        the key still carries ``devices``, so it resolves to the same entry
        the global-batch call planned.  Returns
        (plan, shard_bucket, cache_hit)."""
        from repro.cnn.network import plan_network_fused
        key = self._key(cfg, batch, dtype, training, policy, stack, devices,
                        pre_sharded)
        hit = key in self._fused
        self._record(key, hit)
        if not hit:
            self.planner_calls += 1
            self._fused[key] = plan_network_fused(
                cfg.replace(batch=key.bucket), dtype=key.dtype,
                policy=key.policy, stack_policy=key.stack)
        self._touch(self._fused, key, hit)
        return self._fused[key], key.bucket, hit

    def assignment(self, cfg: CNNConfig, batch: Optional[int] = None, *,
                   dtype: str = DEFAULT_DTYPE, training: bool = False,
                   policy: str = "uniform") -> Tuple[Assignment, int, bool]:
        """Unfused-engine layout assignment, same keying and memoization."""
        from repro.cnn.network import input_shape, network_descs
        from repro.core.selector import assign_layouts
        key = self._key(cfg, batch, dtype, training, policy)
        hit = key in self._unfused
        self._record(key, hit)
        if not hit:
            self.planner_calls += 1
            bcfg = cfg.replace(batch=key.bucket)
            self._unfused[key] = assign_layouts(
                network_descs(bcfg, key.dtype), input_layout="NCHW",
                input_shape=input_shape(bcfg), training=training,
                dtype_policy=key.policy, base_dtype=key.dtype)
        self._touch(self._unfused, key, hit)
        return self._unfused[key], key.bucket, hit

    def peek_fused(self, cfg: CNNConfig, batch: Optional[int] = None, *,
                   dtype: str = DEFAULT_DTYPE, training: bool = False,
                   policy: str = "uniform", stack: str = "auto",
                   devices: int = 1,
                   pre_sharded: bool = False) -> Optional[FusedPlan]:
        """Cached fused plan or None — no stats recorded, no planning
        triggered, no recency refresh (reporting/introspection path).
        ``pre_sharded`` as in :meth:`fused_plan`."""
        return self._fused.get(self._key(cfg, batch, dtype, training,
                                         policy, stack, devices,
                                         pre_sharded))

    def heuristic_layouts(self, cfg: CNNConfig,
                          batch: Optional[int] = None,
                          dtype: str = DEFAULT_DTYPE,
                          hardware: Optional[str] = None) -> list:
        """The paper's single-scan §IV.D heuristic under the cache's
        (measured) thresholds for ``dtype`` — the O(L) planning fast path.
        Cheap enough that it is not memoized; it exists so the calibrated
        per-dtype rows the cache persists are consumed by an actual
        planner."""
        from repro.cnn.network import network_descs
        from repro.core.selector import paper_heuristic_layouts
        dtype = canon_dtype(dtype)
        th = self.thresholds_for(dtype, hardware)
        if th is None:
            raise ValueError(
                f"heuristic planning needs calibrated thresholds for "
                f"dtype {dtype!r}")
        bcfg = cfg.replace(batch=self.bucket(
            cfg.batch if batch is None else batch))
        return paper_heuristic_layouts(network_descs(bcfg, dtype), th)

    # -- persistence ---------------------------------------------------------

    def to_json(self) -> Dict:
        hw_rows: Dict[str, Dict[str, Dict]] = {}
        for (hw, dt), v in self._thresholds.items():
            if hw != DEFAULT_HARDWARE:
                hw_rows.setdefault(hw, {})[dt] = dataclasses.asdict(v)
        obj = {
            "version": 2,
            "min_bucket": self.min_bucket,
            "max_bucket": self.max_bucket,
            "max_entries": self.max_entries,
            # legacy field keeps its pre-§13 shape (the DEFAULT_HARDWARE
            # rows) so older readers still load; hardware-versioned rows
            # ride in the additive "thresholds_hw" map
            "thresholds": {dt: dataclasses.asdict(v)
                           for (hw, dt), v in self._thresholds.items()
                           if hw == DEFAULT_HARDWARE},
            # serialized in recency order (least-recently-hit first), so a
            # reloaded bounded cache evicts in the same order
            "fused": [{"key": k.as_dict(), "plan": _plan_to_obj(p)}
                      for k, p in self._fused.items()],
            "unfused": [{"key": k.as_dict(),
                         "plan": dataclasses.asdict(a)}
                        for k, a in self._unfused.items()],
        }
        if hw_rows:
            obj["thresholds_hw"] = hw_rows
        return obj

    def save(self, path: Optional[str] = None) -> str:
        """Crash-safe persist (§14): payload checksum + fsync before the
        atomic replace, so a crash at ANY instant leaves either the previous
        generation or the complete new one on disk — never a torn file."""
        path = path or self.path
        if not path:
            raise ValueError("no cache path configured")
        atomic_json_dump(self.to_json(), path)
        self.path = path
        return path

    def load(self, path: str) -> None:
        """Load persisted plans/thresholds, or recover from their
        corruption: truncated/garbage JSON, an unknown schema version, or a
        checksum mismatch renames the file aside as ``*.corrupt`` (recorded
        in ``corrupt_recoveries``) and leaves the cache empty — the server
        constructs and replans instead of refusing to start."""

        def _validate(o: Dict) -> None:
            if o.get("version") not in (1, 2):
                raise CorruptStateError(
                    f"unknown plan-cache version {o.get('version')!r} in "
                    f"{path!r}")

        obj = load_json_guarded(
            path, validate=_validate,
            on_corrupt=lambda dst, e: self.corrupt_recoveries.append(dst))
        if obj is None:
            return
        try:
            self._load_obj(obj)
        except (KeyError, TypeError, ValueError) as e:
            # structurally valid JSON whose entries don't deserialize (a
            # legacy checksum-free file with mangled payload): quarantine
            # and reset whatever half-loaded state the attempt left behind
            self._fused.clear()
            self._unfused.clear()
            self._thresholds = {k: v for k, v in self._thresholds.items()
                                if k in self._explicit["thresholds"]}
            dst = quarantine_file(path)
            log.warning("malformed plan-cache payload %s (%s) — renamed "
                        "aside to %s; rebuilding", path, e, dst)
            self.corrupt_recoveries.append(dst)

    def _load_obj(self, obj: Dict) -> None:
        if not self._explicit["min_bucket"]:
            self.min_bucket = obj.get("min_bucket", self.min_bucket)
        if not self._explicit["max_bucket"]:
            self.max_bucket = obj.get("max_bucket", self.max_bucket)
        if (not self._explicit["max_entries"]
                and obj.get("max_entries") is not None):
            self.max_entries = obj["max_entries"]
        th = obj.get("thresholds")
        if th is not None:
            if "Ct" in th:             # v1: one flat (float32) row
                th = {DEFAULT_DTYPE: th}
            # unversioned rows = the default-hardware row (legacy files
            # predate hardware ids and keep loading unchanged)
            for k, v in th.items():
                key = (DEFAULT_HARDWARE, canon_dtype(k))
                if key not in self._explicit["thresholds"]:
                    self._thresholds[key] = Thresholds(**v)
        for hw, rows in (obj.get("thresholds_hw") or {}).items():
            for k, v in rows.items():
                key = (hw, canon_dtype(k))
                if key not in self._explicit["thresholds"]:
                    self._thresholds[key] = Thresholds(**v)
        for ent in obj.get("fused", ()):
            key = PlanKey(**{**ent["key"],
                             "dtype": canon_dtype(ent["key"]["dtype"])})
            self._fused[key] = _plan_from_obj(ent["plan"])
            self._touch(self._fused, key, hit=False)
        for ent in obj.get("unfused", ()):
            key = PlanKey(**{**ent["key"],
                             "dtype": canon_dtype(ent["key"]["dtype"])})
            self._unfused[key] = _assignment_from_obj(ent["plan"])
            self._touch(self._unfused, key, hit=False)
