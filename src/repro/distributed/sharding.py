"""Sharding rules: DP / FSDP / TP / EP / SP assignment for every param,
batch, optimizer and cache leaf.

Conventions (mesh axes: optional "pod", "data", "model"):
  * DP    — batch over ("pod","data") (pod composes data-parallel by default);
  * FSDP  — params + optimizer state sharded over "data" (and "pod" when
            ``fsdp_pod``) on a non-TP dim (ZeRO-3 style);
  * TP    — Megatron-style column/row sharding over "model" (heads, d_ff,
            vocab, expert-internal dims);
  * EP    — MoE expert dim over "model";
  * SP    — saved residual stream sharded over "model" on the sequence dim
            (applied via with_sharding_constraint in the model, see
            transformer.ShardCtx);
  * decode KV cache — sequence dim over "model" (flash-decoding style
    partial-softmax reduction), batch over DP; for global_batch==1 the
    sequence is additionally sharded over "data".

Head counts that don't divide the 16-way model axis (qwen2: 28H/4KV) rely on
GSPMD uneven-sharding padding (verified); the roofline quantifies the waste.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.models.transformer import ShardCtx, abstract_params


def mesh_axes(mesh) -> Tuple[Tuple[str, ...], str, bool]:
    """Returns (dp_axes, tp_axis, multi_pod)."""
    names = mesh.axis_names
    multi_pod = "pod" in names
    dp = ("pod", "data") if multi_pod else ("data",)
    return dp, "model", multi_pod


def make_shard_ctx(mesh, parallel: ParallelConfig,
                   for_decode: bool = False) -> ShardCtx:
    dp, tp, multi_pod = mesh_axes(mesh)
    if not parallel.fsdp:
        fsdp_axes = ()
    elif parallel.fsdp_pod and multi_pod:
        fsdp_axes = ("pod", "data")
    else:
        fsdp_axes = ("data",)
    return ShardCtx(batch_axes=dp, model_axis=tp,
                    seq_shard_saved=parallel.seq_shard_saved and not for_decode,
                    fsdp_axes=fsdp_axes,
                    model_size=mesh.shape[tp],
                    moe_a2a=not for_decode,
                    mesh=mesh)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def _rule(path_keys, leaf_ndim, F, T):
    """Spec for one leaf given its path key names (innermost last)."""
    name = path_keys[-1]
    parents = path_keys[:-1]
    in_moe = "moe" in parents
    in_mamba = "mamba" in parents
    in_channel = "channel" in parents
    in_time = "time" in parents

    def spec(*dims):
        return P(*dims)

    if name in ("scale", "bias", "ln_scale", "ln_bias", "mu_x", "mu_rkvwg",
                "mu_k", "mu_r", "w0", "u", "conv_b", "dt_proj_b", "D"):
        return spec(*([None] * leaf_ndim))
    if name == "table":                       # embed / unembed [V, D]
        return spec(T, F)
    if name == "proj":                        # frontend [clip, D]
        return spec(None, T)
    if name in ("wq", "wk", "wv"):            # [D, X] col-parallel
        return spec(F, T)
    if name in ("bq", "bk", "bv"):
        return spec(T)
    if name == "wo":
        if in_time:                           # rwkv wo [D, D] row-parallel
            return spec(T, F)
        return spec(T, F)                     # attn wo [Q, D]
    if in_moe and leaf_ndim == 3:             # routed experts (EP over T)
        if name in ("w_gate", "w_up"):        # [E, D, F]
            return spec(T, F, None)
        if name == "w_down":                  # [E, F, D]
            return spec(T, None, F)
        # shared expert is 2-D and handled by the plain-mlp rules below
    if name == "w_gate" or name == "w_up":    # mlp [D, F]
        return spec(F, T)
    if name == "w_down":                      # mlp [F, D]
        return spec(T, F)
    if name == "router":
        return spec(F, None)
    if in_mamba:
        if name == "in_proj":                 # [D, 2dI]
            return spec(F, T)
        if name == "conv_w":                  # [dC, dI]
            return spec(None, T)
        if name == "x_proj":                  # [dI, R+2dS]
            return spec(T, None)
        if name == "dt_proj_w":               # [R, dI]
            return spec(None, T)
        if name == "A_log":                   # [dI, dS]
            return spec(T, None)
        if name == "out_proj":                # [dI, D]
            return spec(T, F)
    if in_time or in_channel:
        if name in ("wr", "wk_", "wg"):       # [D, D]
            return spec(F, T)
        if name == "wk":
            return spec(F, T)
        if name == "wv":                      # channel [F, D] / time [D, D]
            return spec(T, F) if in_channel else spec(F, T)
        if name in ("lora_a", "wa"):          # [D, R]
            return spec(F, None)
        if name in ("lora_b",):               # [5, R, D]
            return spec(None, None, T)
        if name == "wb":                      # [R, D]
            return spec(None, T)
    # fallback: replicate
    return spec(*([None] * leaf_ndim))


_STACKED_ROOTS = ("blocks", "cross")          # leading period dim
_ENC_STACKED = ("encoder", "blocks")


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            out.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def fit_spec(spec: P, shape: Tuple[int, ...], mesh) -> P:
    """Drop sharding axes that do not evenly divide the dim (jit in/out
    shardings must divide; intermediates may stay uneven via GSPMD padding).
    Tuple axes are reduced from the left: ("pod","data") -> ("data",) -> None.
    """
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim_size, entry in zip(shape, dims):
        if entry is None:
            out.append(None)
            continue
        cand = entry if isinstance(entry, tuple) else (entry,)
        while cand and dim_size % _axis_size(mesh, cand) != 0:
            cand = cand[1:]
        if not cand:
            out.append(None)
        elif len(cand) == 1:
            out.append(cand[0])
        else:
            out.append(cand)
    return P(*out)


def fit_spec_tree(spec_tree, abstract_tree, mesh):
    return jax.tree.map(
        lambda s, a: fit_spec(s, a.shape, mesh), spec_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, P))


def param_specs(cfg: ModelConfig, mesh, parallel: ParallelConfig):
    """PartitionSpec tree matching ``abstract_params(cfg)``."""
    dp, tp, multi_pod = mesh_axes(mesh)
    if not parallel.fsdp:
        F = None
    elif parallel.fsdp_pod and multi_pod:
        F = ("pod", "data")
    else:
        F = "data"
    T = tp
    tree = abstract_params(cfg)

    def one(path, leaf):
        names = _path_names(path)
        stacked = (names[0] in _STACKED_ROOTS or
                   (len(names) >= 2 and names[0] == "encoder"
                    and names[1] == "blocks"))
        core = _rule(names, leaf.ndim - (1 if stacked else 0), F, T)
        if stacked:
            core = P(*((None,) + tuple(core)))
        return fit_spec(core, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(one, tree)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_spec(mesh, ndim: int = 2) -> P:
    dp, _, _ = mesh_axes(mesh)
    return P(*((dp,) + (None,) * (ndim - 1)))


def cache_specs(cfg: ModelConfig, mesh, shape: ShapeConfig,
                kv_layout: str = "bksd", kv_window: bool = False):
    """Spec tree matching ``abstract_cache``.  Leaves carry a leading period
    dim (stacked) -> prepend None."""
    dp, tp, _ = mesh_axes(mesh)
    B = shape.global_batch
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    batch_shardable = B % dp_size == 0 and B >= dp_size

    bdim = dp if batch_shardable else None
    # sequence of the KV cache: model axis; plus data axis when batch idle
    sdim = tp if batch_shardable else (tp, "data") if "data" in mesh.axis_names else tp
    # when KV heads divide the model axis, shard heads instead of S: the
    # decode cache update is then a cheap DUS on an unsharded dim
    kv_head_sharded = cfg.num_kv_heads % mesh.shape[tp] == 0

    def kv_spec(layout):
        if kv_head_sharded:
            if layout == "bksd":
                return P(None, bdim, tp, None, None)
            return P(None, None, bdim, tp, None)    # sbkd
        if layout == "bksd":
            return P(None, bdim, None, sdim, None)
        return P(None, sdim, bdim, None, None)      # sbkd

    def one(path, leaf):
        names = _path_names(path)
        name = names[-1]
        if name in ("k", "v"):
            return kv_spec(kv_layout)
        if name == "ssm":                           # [P,B,dI,dS]
            return P(None, bdim, tp, None)
        if name == "conv":                          # [P,B,dC-1,dI]
            return P(None, bdim, None, tp)
        if name == "wkv":                           # [P,B,H,N,N]
            return P(None, bdim, tp, None, None)
        if name in ("tm_shift", "cm_shift"):        # [P,B,1,D]
            return P(None, bdim, None, None)
        return P(*([None] * leaf.ndim))

    from repro.models.transformer import abstract_cache
    tree = abstract_cache(cfg, B, shape.seq_len, kv_layout,
                          kv_window=kv_window)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: fit_spec(one(p, l), l.shape, mesh), tree)


def cross_kv_specs(mesh, batch_shardable: bool = True):
    """Spec for the prefill-produced cross-attention KV ([P,B,K,T,Dh])."""
    dp, tp, _ = mesh_axes(mesh)
    bdim = dp if batch_shardable else None
    kv = P(None, bdim, None, None, None)
    return {"k": kv, "v": kv}
