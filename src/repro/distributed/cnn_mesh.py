"""Multi-chip CNN serving mesh: batch-dim data parallelism over shard_map
(DESIGN.md §15).

One interpreter serves one chip; a mesh absorbs production traffic by
sharding the admitted batch data-parallel across ``devices`` chips and
running the SAME fused plan inside every shard.  The load-bearing planning
invariant is that the plan is produced for the *shard* batch, never the
global one: the paper's Nt threshold makes the CHWN/NCHW choice
batch-dependent (§IV.A), so a global batch of 128 on 8 chips is sixteen
images per chip — below the crossover where the 128-image plan lives.
``PlanCache`` therefore keys plans on (per-shard bucket, devices) and plans
at ``cfg.replace(batch=shard_bucket)``; this module provides the mesh, the
sharded executor, and the check that the invariant holds.

Kernels are untouched: ``forward_fused`` executes the per-shard plan
unchanged inside each shard — ``shard_map`` hands every device a
``[shard_bucket, C, H, W]`` block and replicated params, and conv/pool/fc/
softmax are all batch-row-independent, so the sharded output is the
unsharded output (no cross-shard reductions exist in inference).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import CNNConfig

# the single mesh axis batch rows shard over (matches the LM-side "data"
# axis naming so a future pod/model extension composes)
BATCH_AXIS = "data"


def shard_batch_for(global_batch: int, devices: int) -> int:
    """Per-shard batch: ceil so every request fits (the last shard's
    shortfall is padding, sliced off after the forward)."""
    if devices < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    if global_batch < 1:
        raise ValueError(f"batch must be >= 1, got {global_batch}")
    return math.ceil(global_batch / devices)


def cnn_data_mesh(devices: Optional[int] = None) -> Mesh:
    """1-D data-parallel mesh over the first ``devices`` jax devices
    (default: all of them).  Serving needs no model axis — params are small
    enough to replicate and every request is independent."""
    avail = jax.devices()
    d = len(avail) if devices is None else devices
    if d < 1 or d > len(avail):
        raise ValueError(
            f"devices={d} but jax sees {len(avail)} device(s); force host "
            f"devices with XLA_FLAGS=--xla_force_host_platform_device_count=N")
    return Mesh(np.array(avail[:d]), (BATCH_AXIS,))


def replicate_params(params, mesh: Mesh):
    """Replicate the param tree onto every mesh device (pure data
    parallelism: weights are read-only at serving time)."""
    return jax.device_put(params, NamedSharding(mesh, P()))


def forward_fused_sharded(params, x, shard_cfg: CNNConfig, plan,
                          mesh: Mesh, *, impl: str = "pallas",
                          interpret: bool = True):
    """Data-parallel ``forward_fused``: ``x`` is the GLOBAL padded batch
    ``[shard_cfg.batch * devices, C, H, W]``; each shard executes the fused
    plan on its own ``shard_cfg.batch`` rows with replicated params.
    Returns the global ``[N, classes]`` probabilities.

    The plan MUST be the per-shard plan (``shard_cfg.batch`` is the shard
    batch) — ``verify_shard_plan`` is the planner-side check.  Stats are not
    returned: modeled per-chip traffic is shape-only arithmetic, accounted
    once outside the mesh (``jax.eval_shape`` at the shard config)."""
    from repro.cnn.network import forward_fused
    devices = mesh.shape[BATCH_AXIS]
    if x.shape[0] != shard_cfg.batch * devices:
        raise ValueError(
            f"global batch {x.shape[0]} != shard batch {shard_cfg.batch} x "
            f"{devices} devices; pad to the shard bucket before sharding")

    def _shard(p, xs):
        y, _ = forward_fused(p, xs, shard_cfg, plan, impl=impl,
                             interpret=interpret)
        return y

    f = shard_map(_shard, mesh=mesh, in_specs=(P(), P(BATCH_AXIS)),
                  out_specs=P(BATCH_AXIS))
    return f(params, x)


class ShardPlanError(AssertionError):
    """A sharded bucket is executing a plan that was not produced for its
    shard batch (the global-batch plan leaked through)."""


def verify_shard_plan(plan, cfg: CNNConfig, shard_bucket: int, *,
                      dtype: str = "float32", policy: str = "uniform",
                      stack: str = "auto") -> None:
    """Roofline check (DESIGN.md §15): assert ``plan`` is byte-identical to
    a fresh plan at the SHARD batch — layouts, conv signature, and modeled
    fused bytes all match, so any per-shard Nt flip was taken rather than
    inherited from the global batch.  Deterministic planner arithmetic;
    called from tests and the scaling bench, not the serving hot path."""
    from repro.cnn.network import plan_network_fused
    fresh = plan_network_fused(cfg.replace(batch=shard_bucket), dtype=dtype,
                               policy=policy, stack_policy=stack)
    if (plan.layouts != fresh.layouts
            or plan.conv_signature != fresh.conv_signature
            or plan.fused_bytes != fresh.fused_bytes):
        raise ShardPlanError(
            f"plan for shard bucket {shard_bucket} is not the shard-batch "
            f"plan: {plan.conv_signature} ({plan.fused_bytes}B) vs fresh "
            f"{fresh.conv_signature} ({fresh.fused_bytes}B) — the planner "
            f"must plan for the shard batch, not the global one")


def shard_flip(cfg: CNNConfig, global_batch: int, devices: int, *,
               dtype: str = "float32") -> Tuple[str, str]:
    """(global-batch signature, shard-batch signature) for a fixed global
    batch — shows where sharding itself flips the layout choice (per-shard
    N drops below Nt while the global N sits above it)."""
    from repro.cnn.network import plan_network_fused
    gsig = plan_network_fused(cfg.replace(batch=global_batch),
                              dtype=dtype).conv_signature
    ssig = plan_network_fused(
        cfg.replace(batch=shard_batch_for(global_batch, devices)),
        dtype=dtype).conv_signature
    return gsig, ssig
