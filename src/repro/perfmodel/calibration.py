"""Threshold calibration + predicted-vs-measured cross-validation
(DESIGN.md §13; paper §IV.A-B; DeLTA, Lym et al. 2019).

Two jobs live here:

1. **The paper's (Ct, Nt) thresholds.**  ``calibrate`` reproduces the
   one-time profiling sweep (analytic cost model, or a ``measure(layer,
   layout) -> seconds`` callback timing the real Pallas engines via
   ``pallas_conv_measure``); ``select_conv_layout`` / ``select_pool_layout``
   apply the two-rule decision per layer.  Thresholds persist as rows keyed
   by **(hardware id, storage dtype)**: the element size scales every byte
   term and the sublane width, and the crossover points measured under the
   interpreter on one machine are NOT the crossover points of a real TPU —
   a server must only plan under thresholds swept on its own silicon.
   ``hardware_id()`` is ``jax.devices()[0].device_kind`` plus an
   ``/interpret`` suffix for interpreter-mode timings; legacy files (flat
   {Ct, Nt} or per-dtype ``rows``) load as the unversioned ``default``
   hardware row, and lookups for an unknown hardware id fall back to it.

2. **Prediction-error cross-validation.**  DeLTA's discipline: an analytic
   model you never compare against measurement drifts silently.
   ``cross_validate`` times the real Pallas kernels on the calibration sweep,
   fits the ``CalibratedCostModel`` scale (analytic priors x measured
   overlay), and reports per-point predicted-vs-measured relative error —
   the ``prediction_error`` number the fusion bench emits and
   ``check_trajectory`` gates lower-is-better.
"""
from __future__ import annotations

import dataclasses
import logging
import math
import os
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.configs.paper_table1 import ConvLayer, PoolLayer
from repro.dtypes import DEFAULT_DTYPE, canon_dtype, dtype_bytes, jnp_dtype
from repro.perfmodel.traffic import DEFAULT_DTYPE_BYTES, conv_cost
from repro.runtime.resilience import (atomic_json_dump, load_json_guarded,
                                      quarantine_file)

log = logging.getLogger("repro.perfmodel.calibration")

# Row key for threshold files that predate hardware versioning (and for
# callers that do not say where their measurements came from).  An
# unversioned legacy file IS this row.
DEFAULT_HARDWARE = "default"


def hardware_id(interpret: bool = True) -> str:
    """Stable identity of the silicon a measurement ran on.  Interpreter
    timings get their own rows: they measure the Pallas *interpreter* on the
    host CPU, and must never be mistaken for compiled-TPU thresholds."""
    import jax
    kind = jax.devices()[0].device_kind
    return f"{kind}/interpret" if interpret else kind


# ---------------------------------------------------------------------------
# the paper's two-threshold heuristic + calibration sweep
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Thresholds:
    Ct: int
    Nt: int


def select_conv_layout(l: ConvLayer, th: Thresholds) -> str:
    """Verbatim paper heuristic (§IV.A)."""
    if l.Ci < th.Ct:
        return "CHWN"
    if l.N >= th.Nt:
        return "CHWN"
    return "NCHW"


def select_pool_layout(l: Optional[PoolLayer] = None) -> str:
    """Paper §IV.B: pooling always prefers CHWN (window access in NCHW is
    strided/uncoalesced; on TPU, sub-lane-sized W tiles)."""
    return "CHWN"


def _cal_base() -> ConvLayer:
    return ConvLayer("CAL", 128, 384, 13, 3, 256, 1, "cal")


def calibrate(measure: Optional[Callable[[ConvLayer, str], float]] = None,
              base: Optional[ConvLayer] = None,
              dtype_bytes: int = DEFAULT_DTYPE_BYTES) -> Thresholds:
    """One-time per-hardware calibration (paper Fig. 4).

    Sweeps C with fixed large N (finding Ct = first C where NCHW wins) and
    N with mid-size C (finding Nt = first N where CHWN wins again).  Uses the
    analytical cost model unless a ``measure(layer, layout) -> seconds``
    callback (real-hardware profiling) is supplied.

    ``dtype_bytes`` is the STORAGE element size the thresholds are valid
    for: halving it halves every byte term and doubles the sublane width, so
    each storage dtype gets its own (Ct, Nt) row (a measured ``measure``
    callback must time kernels at the same element size).
    """
    base = base or _cal_base()
    cost = measure or (lambda l, lay: conv_cost(l, lay, dtype_bytes).total_s)

    Ct = 1
    for c in (1, 2, 4, 8, 16, 32, 64, 128, 256, 512):
        l = ConvLayer("CAL", 64, base.Co, base.HW, base.F, c, base.S, "cal")
        if cost(l, "NCHW") < cost(l, "CHWN"):
            Ct = c
            break
    else:
        Ct = 512

    Nt = None
    for n in (16, 32, 64, 128, 256, 512):
        l = ConvLayer("CAL", n, base.Co, base.HW, base.F, max(base.Ci, Ct),
                      base.S, "cal")
        if cost(l, "CHWN") <= cost(l, "NCHW"):
            Nt = n
            break
    if Nt is None:
        Nt = 1 << 30     # CHWN never wins at high C on this hardware
    return Thresholds(Ct=Ct, Nt=Nt)


# ---------------------------------------------------------------------------
# persisted threshold rows: {hardware id: {dtype: {Ct, Nt}}}
# ---------------------------------------------------------------------------

def _parse_table(obj: Dict) -> Dict[str, Dict[str, Dict]]:
    if "hardware" in obj:
        return {hw: {canon_dtype(k): v for k, v in ent.get("rows", {}).items()}
                for hw, ent in obj["hardware"].items()}
    if "rows" in obj:
        return {DEFAULT_HARDWARE:
                {canon_dtype(k): v for k, v in obj["rows"].items()}}
    if "Ct" in obj:                    # legacy single-row file
        return {DEFAULT_HARDWARE:
                {DEFAULT_DTYPE: {"Ct": obj["Ct"], "Nt": obj["Nt"]}}}
    return {}


def _load_table(path: str,
                on_corrupt: Optional[Callable[[str, Exception], None]] = None
                ) -> Dict[str, Dict[str, Dict]]:
    """All persisted rows keyed (hardware id, canonical dtype).  Reads the
    v3 hardware-versioned format ({"hardware": {hw: {"rows": ...}}}), the
    v2 per-dtype format ({"rows": {dtype: {Ct, Nt}}}) and the legacy flat
    {"Ct": ..., "Nt": ...} file — both pre-v3 shapes become the unversioned
    ``DEFAULT_HARDWARE`` row, which is exactly how their measurements were
    taken (no hardware recorded).

    Corrupt files (truncated/garbage JSON, checksum mismatch — §14) are
    renamed aside as ``*.corrupt`` and read as an EMPTY table, so callers
    recalibrate instead of raising: thresholds are a ~4 s measured sweep,
    always cheaper than a server that refuses to start."""
    obj = load_json_guarded(path, on_corrupt=on_corrupt)
    if obj is None:
        return {}
    try:
        return _parse_table(obj)
    except (KeyError, TypeError, AttributeError, ValueError) as e:
        dst = quarantine_file(path)
        log.warning("malformed threshold table %s (%s) — renamed aside to "
                    "%s; recalibrating", path, e, dst)
        if on_corrupt is not None:
            on_corrupt(dst, e)
        return {}


def save_thresholds(th: Thresholds, path: str, *,
                    dtype: str = DEFAULT_DTYPE,
                    source: str = "measured",
                    hardware: Optional[str] = None) -> str:
    """Merge one (hardware, dtype) row into the persisted threshold table.
    ``hardware=None`` writes the unversioned default row (the pre-v3
    behaviour, kept so explicit-threshold callers stay hardware-agnostic).
    The write is crash-safe (§14): payload checksum + fsync before the
    atomic replace."""
    dtype = canon_dtype(dtype)
    hw = hardware or DEFAULT_HARDWARE
    table = _load_table(path) if os.path.exists(path) else {}
    table.setdefault(hw, {})[dtype] = {**dataclasses.asdict(th),
                                       "source": source}
    atomic_json_dump({"version": 3,
                      "hardware": {h: {"rows": rows}
                                   for h, rows in table.items()}}, path)
    return path


def load_thresholds(path: str, dtype: str = DEFAULT_DTYPE,
                    hardware: Optional[str] = None,
                    on_corrupt: Optional[Callable[[str, Exception], None]]
                    = None) -> Thresholds:
    """The persisted row for (``hardware``, ``dtype``); KeyError when no row
    covers it (callers treat that as "calibrate it now").  A corrupt file
    reads as an empty table (renamed aside — §14), so it also lands here as
    KeyError -> recalibrate.

    ``hardware=None`` means "this machine": try the current hardware id
    (interpret, then compiled), then the unversioned default row.  An
    explicit hardware id missing from the file also falls back to the
    default row — an unversioned legacy file serves every hardware until
    per-hardware measurements replace it."""
    table = _load_table(path, on_corrupt=on_corrupt)
    dtype = canon_dtype(dtype)
    if hardware is None:
        cands = [hardware_id(True), hardware_id(False), DEFAULT_HARDWARE]
    else:
        cands = [hardware, DEFAULT_HARDWARE]
    for hw in cands:
        row = table.get(hw, {}).get(dtype)
        if row is not None:
            return Thresholds(Ct=row["Ct"], Nt=row["Nt"])
    raise KeyError(f"no threshold row for dtype={dtype!r} under any of "
                   f"{cands} in {path}")


def pallas_conv_measure(*, proxy_hw: int = 8, proxy_co: int = 32,
                        reps: int = 2, interpret: bool = True,
                        dtype: str = DEFAULT_DTYPE
                        ) -> Callable[[ConvLayer, str], float]:
    """Build a ``measure(layer, layout) -> seconds`` callback that times the
    real Pallas conv engines (direct-CHWN / im2col-MM-NCHW).

    N and Ci are taken from the layer verbatim (they are what ``calibrate``
    sweeps); HW and Co are clamped to the proxy size.  Operands are created
    in the storage ``dtype`` so the timing reflects the element size the
    thresholds will be used for.  The 1-byte (int8) row times the engines on
    genuine int8 activations — random values in the quantized range, with
    float weights, exactly what the mixed-dtype executor feeds them (the
    per-channel scale rides the weights).  Each timing is the best of
    ``reps`` after one warm-up call (which also absorbs compile)."""
    import jax
    import jax.numpy as jnp
    from repro.cnn.layers import conv_forward
    dtype = canon_dtype(dtype)
    jdt = jnp_dtype(dtype)

    def measure(l: ConvLayer, layout: str) -> float:
        hw = max(min(l.HW, proxy_hw), l.F)
        co = min(l.Co, proxy_co)
        key = jax.random.PRNGKey(0)
        if layout == "CHWN":
            shape = (l.Ci, hw, hw, l.N)
        else:
            shape = (l.N, l.Ci, hw, hw)
        if dtype == "int8":
            x = jax.random.randint(key, shape, -127, 128, jnp.int8)
            w = (jax.random.normal(key, (co, l.Ci, l.F, l.F), jnp.float32)
                 * 0.1)
        else:
            x = jax.random.normal(key, shape, jnp.float32).astype(jdt)
            w = (jax.random.normal(key, (co, l.Ci, l.F, l.F), jnp.float32)
                 * 0.1).astype(jdt)

        def f():
            return conv_forward(x, w, layout, l.S, 0, impl="pallas",
                                interpret=interpret)

        jax.block_until_ready(f())          # warm-up + compile
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(f())
            best = min(best, time.perf_counter() - t0)
        return best

    return measure


def proxied_layer(l: ConvLayer, *, proxy_hw: int = 8,
                  proxy_co: int = 32) -> ConvLayer:
    """The layer ``pallas_conv_measure`` ACTUALLY times: N and Ci verbatim,
    HW/Co clamped to the proxy.  Analytic predictions that will be compared
    against those measurements must be computed on this layer — predicting
    the full layer while measuring the proxy would bake the proxy ratio into
    every reported error."""
    hw = max(min(l.HW, proxy_hw), l.F)
    co = min(l.Co, proxy_co)
    return dataclasses.replace(l, HW=hw, Co=co)


def measured_thresholds(path: Optional[str] = None, *,
                        dtype: str = DEFAULT_DTYPE, force: bool = False,
                        measure: Optional[Callable[[ConvLayer, str], float]]
                        = None, interpret: bool = True,
                        hardware: Optional[str] = None,
                        on_corrupt: Optional[
                            Callable[[str, Exception], None]] = None
                        ) -> Thresholds:
    """Serving-default thresholds for one storage dtype: persisted
    measurement, not the analytic sweep.  Loads ``path``'s row for this
    hardware + ``dtype`` when present (unless ``force``); otherwise runs
    ``calibrate`` at that dtype's element size with the Pallas measurement
    callback and merges the new row in under this machine's hardware id.
    A corrupt threshold file is renamed aside (``on_corrupt`` notified —
    §14) and simply re-measured."""
    dtype = canon_dtype(dtype)
    hw = hardware or hardware_id(interpret)
    if path and os.path.exists(path) and not force:
        try:
            return load_thresholds(path, dtype, hardware=hw,
                                   on_corrupt=on_corrupt)
        except KeyError:
            pass                        # file exists but lacks this row
    th = calibrate(measure or pallas_conv_measure(interpret=interpret,
                                                  dtype=dtype),
                   dtype_bytes=dtype_bytes(dtype))
    if path:
        save_thresholds(th, path, dtype=dtype, source="measured",
                        hardware=hw)
    return th


# ---------------------------------------------------------------------------
# predicted-vs-measured cross-validation (the DeLTA loop)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CalibrationPoint:
    """One sweep point: the proxied layer timed by ``pallas_conv_measure``
    next to what the (calibrated) analytic model predicted for it."""
    Ci: int
    N: int
    layout: str
    analytic_s: float        # raw roofline seconds, no measured overlay
    predicted_s: float       # after the fitted per-layout scale
    measured_s: float
    rel_err: float           # |predicted - measured| / measured

    def to_obj(self) -> Dict:
        return dataclasses.asdict(self)


@dataclass
class CrossValidation:
    """The fitted overlay + its residuals for one (hardware, dtype)."""
    hardware: str
    dtype: str
    scales: Dict[str, Tuple[float, float]]   # layout -> (a, b): t = a * s^b
    points: List[CalibrationPoint]
    mean_rel_err: float
    max_rel_err: float

    def to_obj(self) -> Dict:
        return {"hardware": self.hardware, "dtype": self.dtype,
                "scales": {k: list(v) for k, v in self.scales.items()},
                "mean_rel_err": self.mean_rel_err,
                "max_rel_err": self.max_rel_err,
                "points": [p.to_obj() for p in self.points]}


def _fit_overlay(pairs: List[Tuple[float, float]]) -> Tuple[float, float]:
    """Fit measured ≈ a * analytic^b in log space.

    A pure multiplicative scale (b = 1) is the honest overlay when the
    analytic model already tracks the measurement's shape; under the
    interpreter the per-call dispatch floor compresses the dynamic range, so
    the log-log slope soaks up that compression.  Geometric-mean residuals
    make the fit scale-free (a 2x error on a fast point weighs the same as
    on a slow one)."""
    lp = [math.log(max(p, 1e-12)) for p, _ in pairs]
    lm = [math.log(max(m, 1e-12)) for _, m in pairs]
    n = len(pairs)
    mp, mm = sum(lp) / n, sum(lm) / n
    var = sum((x - mp) ** 2 for x in lp)
    if var < 1e-12:
        return math.exp(mm - mp), 1.0      # all analytic values equal
    b = sum((x - mp) * (y - mm) for x, y in zip(lp, lm)) / var
    a = math.exp(mm - b * mp)
    return a, b


def cross_validate(measure: Optional[Callable[[ConvLayer, str], float]]
                   = None, *, dtype: str = DEFAULT_DTYPE,
                   interpret: bool = True,
                   hardware: Optional[str] = None,
                   proxy_hw: int = 8, proxy_co: int = 32,
                   reps: int = 2,
                   c_points: Tuple[int, ...] = (4, 32, 128),
                   n_points: Tuple[int, ...] = (16, 64, 256)
                   ) -> CrossValidation:
    """Time the real Pallas kernels on the calibration sweep and score the
    analytic model's predictions against them (DeLTA's validation loop).

    Per layout, a two-parameter overlay (``_fit_overlay``) maps analytic
    roofline seconds onto the measured clock — that overlay IS what
    ``CalibratedCostModel`` applies — and each point reports the relative
    error of the calibrated prediction.  The analytic side is computed on
    ``proxied_layer`` (the layer the measurement actually ran), so the
    comparison is apples-to-apples.
    """
    dtype = canon_dtype(dtype)
    db = dtype_bytes(dtype)
    hw_id = hardware or hardware_id(interpret)
    measure = measure or pallas_conv_measure(
        proxy_hw=proxy_hw, proxy_co=proxy_co, reps=reps,
        interpret=interpret, dtype=dtype)
    base = _cal_base()
    sweep = ([ConvLayer("CAL", 64, base.Co, base.HW, base.F, c, base.S,
                        "cal") for c in c_points] +
             [ConvLayer("CAL", n, base.Co, base.HW, base.F, base.Ci, base.S,
                        "cal") for n in n_points])
    raw: Dict[str, List[Tuple[ConvLayer, float, float]]] = {}
    for l in sweep:
        proxy = proxied_layer(l, proxy_hw=proxy_hw, proxy_co=proxy_co)
        for lay in ("CHWN", "NCHW"):
            analytic = conv_cost(proxy, lay, db).total_s
            measured = measure(l, lay)
            raw.setdefault(lay, []).append((l, analytic, measured))
    scales: Dict[str, Tuple[float, float]] = {}
    points: List[CalibrationPoint] = []
    for lay, rows in raw.items():
        a, b = _fit_overlay([(an, me) for _, an, me in rows])
        scales[lay] = (a, b)
        for l, an, me in rows:
            pred = a * (an ** b)
            err = abs(pred - me) / max(me, 1e-12)
            points.append(CalibrationPoint(
                Ci=l.Ci, N=l.N, layout=lay, analytic_s=an,
                predicted_s=pred, measured_s=me, rel_err=err))
    errs = [p.rel_err for p in points]
    return CrossValidation(hardware=hw_id, dtype=dtype, scales=scales,
                           points=points,
                           mean_rel_err=sum(errs) / len(errs),
                           max_rel_err=max(errs))
