"""First-class performance-model subsystem (DESIGN.md §13).

Every DP decision the planner makes — layout, storage dtype, stack pairing
— is priced by one analytic memory-traffic model in the DeLTA mould (Lym et
al. 2019, PAPERS.md): predicted HBM bytes AND roofline seconds per
(fused-op, layout, dtype).  This package is its single home:

  * ``traffic``     — the analytic byte/seconds models (conv chains, stacks,
                      backward, cast edges), formerly ``core.heuristic``;
  * ``calibration`` — the paper's (Ct, Nt) thresholds, the measured Pallas
                      sweep, threshold rows versioned by hardware id, and
                      the predicted-vs-measured cross-validation that feeds
                      the ``prediction_error`` CI gate;
  * ``model``       — the ``CostModel`` interface the planner and executors
                      consume (``AnalyticCostModel`` pure priors,
                      ``CalibratedCostModel`` overlaying measured timings).

``core.heuristic`` remains as a thin deprecation shim re-exporting this
package, so historical imports and persisted plans stay byte-identical.
"""
from repro.perfmodel.traffic import (  # noqa: F401
    DEFAULT_DTYPE_BYTES, LANES, STACK_NT_CANDIDATES, STACK_VMEM_BUDGET,
    ConvCost, cast_bytes, cast_cost, chain_bytes, conv_backward_bytes,
    conv_backward_cost, conv_cost, conv_flops, dgrad_bytes, dilated_hw,
    fused_chain_cost, fusion_saved_bytes, select_conv_layout_cost,
    select_kv_layout, stack_bytes, stack_fused_cost, stack_nt,
    stack_vmem_bytes, sublanes, tile_utilization, train_chain_bytes,
    wgrad_bytes)
from repro.perfmodel.calibration import (  # noqa: F401
    DEFAULT_HARDWARE, CalibrationPoint, CrossValidation, Thresholds,
    calibrate, cross_validate, hardware_id, load_thresholds,
    measured_thresholds, pallas_conv_measure, save_thresholds,
    select_conv_layout, select_pool_layout)
from repro.perfmodel.model import (  # noqa: F401
    AnalyticCostModel, CalibratedCostModel, CostModel, default_cost_model)
