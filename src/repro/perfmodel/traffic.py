"""DeLTA-style analytic traffic model (DESIGN.md §13, paper §IV.A-B).

Predicted HBM bytes AND roofline seconds per (fused-op, layout, dtype):
conv chains, cross-layer stacks, the backward direction, and standalone
cast/transform edges.  Each byte model counts the streams a kernel actually
moves (DeLTA's per-layer traffic discipline, Lym et al. 2019); each seconds
model is the roofline max(compute, memory) with TPU tile-utilization
de-rating.

The paper's GPU mechanisms map to TPU as (DESIGN.md §2):
  * coalescing      -> lane utilization   (minormost dim vs 128 lanes)
  * 2nd-order       -> sublane utilization (dim -2 vs 8/16/32 sublanes)
  * register reuse  -> VMEM-block reuse along the minormost dim
  * matrix expansion -> explicit im2col materialization bytes

This module holds the pure analytic functions; ``perfmodel.model`` wraps
them behind the ``CostModel`` interface and ``perfmodel.calibration``
cross-validates them against measured Pallas timings.  It was grown out of
``core.heuristic`` (now a deprecation shim over this package).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.configs.paper_table1 import ConvLayer
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16
from repro.shapes import pool_out_hw

LANES = 128

# One shared default element size for EVERY cost/byte model in this module.
# Historically ``conv_cost`` defaulted to 2 while the chain/backward byte
# models defaulted to 4, so mixed default-arg calls silently priced compute
# and memory at different element sizes.  The shared default is 2 (the TPU's
# native bf16 element size — what the paper-fidelity calibration and the
# Table-1 agreement tests are pinned to); callers modelling a specific
# storage dtype pass ``dtype_bytes`` explicitly (4 for fp32 serving).
DEFAULT_DTYPE_BYTES = 2

_SUBLANES = {4: 8, 2: 16, 1: 32}


def sublanes(dtype_bytes: int) -> int:
    """Native sublane width for an element size.  Unknown sizes raise: the
    old silent 8-sublane fallback priced f64 (or a typo'd size) like f32,
    quietly skewing every tile-utilization term downstream — mirroring the
    ``layer_cost`` unknown-kind fix, an unpriceable input is an error."""
    try:
        return _SUBLANES[dtype_bytes]
    except KeyError:
        raise ValueError(
            f"no native sublane width for dtype_bytes={dtype_bytes!r}; "
            f"known element sizes: {sorted(_SUBLANES)}")


# legacy-private alias (pre-perfmodel callers imported the underscore name)
_sublanes = sublanes


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def tile_utilization(shape: Tuple[int, ...],
                     dtype_bytes: int = DEFAULT_DTYPE_BYTES) -> float:
    """Fraction of each native (sublane x lane) VMEM tile holding real data
    for the two minormost dims of ``shape``."""
    if not shape:
        return 1.0
    lane = shape[-1]
    sub = shape[-2] if len(shape) >= 2 else 1
    sl = sublanes(dtype_bytes)
    return (lane / _round_up(lane, LANES)) * (sub / _round_up(sub, sl))


# ---------------------------------------------------------------------------
# cast edges (mixed-dtype DP, DESIGN.md §9): converting a stored tensor
# between storage dtypes as a STANDALONE pass reads it at the source element
# size and writes it at the destination size.  The fused engine never pays
# this — quantize folds into the producer's epilogue and dequantize into the
# consumer conv's VMEM read — but the unfused product-space DP prices it,
# which is exactly why mixed dtypes only win under fusion.
# ---------------------------------------------------------------------------

def cast_bytes(shape: Tuple[int, ...], src_dtype_bytes: int,
               dst_dtype_bytes: int) -> int:
    """HBM bytes of a standalone dtype-cast pass (read src + write dst);
    symmetric in (src, dst) — a quant pass costs what its dequant costs."""
    n = int(np.prod(shape)) if shape else 0
    return n * (src_dtype_bytes + dst_dtype_bytes)


def cast_cost(shape: Tuple[int, ...], src_dtype_bytes: int,
              dst_dtype_bytes: int, bw=HBM_BW) -> float:
    """Seconds for the standalone cast pass (streams at ~full bandwidth —
    elementwise, no re-layout)."""
    return cast_bytes(shape, src_dtype_bytes, dst_dtype_bytes) / (bw * 0.9)


# ---------------------------------------------------------------------------
# conv cost model: direct(CHWN) vs im2col-MM(NCHW)  [per DESIGN.md §2 table]
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ConvCost:
    layout: str
    compute_s: float
    memory_s: float

    @property
    def total_s(self) -> float:
        return max(self.compute_s, self.memory_s)


def conv_flops(l: ConvLayer) -> float:
    ho = wo = l.out_hw
    return 2.0 * l.N * l.Co * ho * wo * l.Ci * l.F * l.F


def conv_cost(l: ConvLayer, layout: str,
              dtype_bytes: int = DEFAULT_DTYPE_BYTES,
              peak=PEAK_FLOPS_BF16, bw=HBM_BW, *,
              packed_span: bool = True) -> ConvCost:
    """Analytical single-chip cost of one conv layer under a layout.

    direct/CHWN: the MXU contraction is [Ci*F*F] x [N] per output pixel —
    N occupies lanes (the paper's coalescing dim), Ci*F*F the reduction.
    MXU efficiency is the tile utilization of (reduction, N).

    im2col/NCHW: materializes the [N*Ho*Wo, Ci*F*F] patch matrix (extra
    read+write traffic — the paper's "matrix expansion overhead"), then a
    well-aligned matmul with Co on lanes.
    """
    ho = wo = l.out_hw
    flops = conv_flops(l)
    in_bytes = l.N * l.Ci * l.HW * l.HW * dtype_bytes
    out_bytes = l.N * l.Co * ho * wo * dtype_bytes
    w_bytes = l.Co * l.Ci * l.F * l.F * dtype_bytes

    if layout == "CHWN":
        red = l.Ci * l.F * l.F
        eff = tile_utilization((red, l.N), dtype_bytes)
        # coalescing span: the lane dim must also cover LANES native 2-byte
        # elements (256 B) — the span both calibrated rows sit at (fp32
        # crosses at N=64 x 4 B, bf16 at N=128 x 2 B).  In elements that is
        # N*db/256, which is >= the element-count lane fill whenever
        # db >= 2, so the min() only bites for packed sub-bf16 dtypes:
        # int8 needs N=256 to fill the same span, quadrupling Nt vs fp32.
        # ``packed_span=False`` is for engines that dequantize the packed
        # operand to the compute dtype in VMEM before the MXU (the fused
        # int8 path), where the stored width never reaches the lane feed.
        if packed_span:
            eff = min(eff, l.N * dtype_bytes / (LANES * 2))
        # reuse of input window across Co is perfect in VMEM; traffic is
        # essentially streaming in+out+weights
        mem = in_bytes + out_bytes + w_bytes
        return ConvCost("CHWN", flops / (peak * max(eff, 1e-3)), mem / bw)

    if layout == "NCHW":
        red = l.Ci * l.F * l.F
        eff = tile_utilization((red, _round_up(l.Co, LANES)), dtype_bytes)
        im2col = l.N * ho * wo * red * dtype_bytes
        # expansion write + read back (the paper's expansion overhead), minus
        # the benefit: the matmul streams the expanded matrix once
        mem = in_bytes + 2 * im2col + out_bytes + w_bytes
        return ConvCost("NCHW", flops / (peak * max(eff, 1e-3)), mem / bw)

    raise ValueError(layout)


def select_conv_layout_cost(l: ConvLayer,
                            dtype_bytes: int = DEFAULT_DTYPE_BYTES) -> str:
    """Cost-model arbitration (used for calibration)."""
    c = {lay: conv_cost(l, lay, dtype_bytes).total_s
         for lay in ("CHWN", "NCHW")}
    return min(c, key=c.get)


# ---------------------------------------------------------------------------
# fusion cost model (DESIGN.md §5): conv -> relu -> pool chains executed as
# one kernel keep the intermediate in VMEM, so its HBM round trips vanish
# ---------------------------------------------------------------------------

def chain_bytes(l: ConvLayer, dtype_bytes: int = DEFAULT_DTYPE_BYTES, *,
                relu: bool = False,
                pool: Optional[Tuple[int, int]] = None,
                fused: bool = True,
                in_dtype_bytes: Optional[int] = None,
                out_dtype_bytes: Optional[int] = None,
                residual: bool = False) -> int:
    """HBM bytes moved by a conv[->add][->relu][->pool] chain.

    Unfused, every intermediate makes a full round trip: the conv writes its
    output, the residual add reads both operands and writes the sum, the relu
    reads+writes it, the pool reads it and writes the pooled map.  Fused,
    only the conv input, the weights, the skip tensor (``residual``), and the
    final (post-pool) output touch HBM — the chain intermediate lives in the
    kernel's VMEM accumulator.  ``pool`` is ``(F, S)`` of the folded pooling
    layer; ``residual`` marks a folded residual-add epilogue (DESIGN.md §11):
    the skip tensor has the conv's output shape and stays at the layer dtype
    (merge edges never store int8).

    ``in_dtype_bytes``/``out_dtype_bytes`` (mixed-dtype plans, DESIGN.md §9)
    override the element size of the chain's stored input/output — the conv
    reads the producer's storage dtype and its epilogue emits the consumer's
    — while weights and the unfused intermediates stay at ``dtype_bytes``
    (the layer's compute/storage dtype).  Per-channel quant scales (one f32
    per channel) are negligible next to the activation and are not modeled.
    """
    in_db = dtype_bytes if in_dtype_bytes is None else in_dtype_bytes
    out_db = dtype_bytes if out_dtype_bytes is None else out_dtype_bytes
    ho = l.out_hw
    in_b = l.N * l.Ci * l.HW * l.HW * in_db
    w_b = l.Co * l.Ci * l.F * l.F * dtype_bytes
    out_b = l.N * l.Co * ho * ho * dtype_bytes
    final_n = l.N * l.Co * ho * ho
    if pool is not None:
        pho = pool_out_hw(ho, pool[0], pool[1])
        final_n = l.N * l.Co * pho * pho
    final_b = final_n * out_db
    if fused:
        # fused residual: one extra stream — the skip tensor read in VMEM
        return in_b + w_b + final_b + (out_b if residual else 0)
    total = in_b + w_b + out_b
    if residual:
        total += 3 * out_b       # standalone add: read a, read skip, write
    if relu:
        total += 2 * out_b
    if pool is not None:
        total += out_b + final_b
    return total


def fusion_saved_bytes(l: ConvLayer, dtype_bytes: int = DEFAULT_DTYPE_BYTES,
                       *, relu: bool = False,
                       pool: Optional[Tuple[int, int]] = None) -> int:
    """Intermediate read+write traffic a fused chain removes."""
    return (chain_bytes(l, dtype_bytes, relu=relu, pool=pool, fused=False) -
            chain_bytes(l, dtype_bytes, relu=relu, pool=pool, fused=True))


def fused_chain_cost(l: ConvLayer, layout: str,
                     dtype_bytes: int = DEFAULT_DTYPE_BYTES, *,
                     relu: bool = False,
                     pool: Optional[Tuple[int, int]] = None,
                     in_dtype_bytes: Optional[int] = None,
                     out_dtype_bytes: Optional[int] = None,
                     residual: bool = False,
                     peak=PEAK_FLOPS_BF16, bw=HBM_BW) -> ConvCost:
    """Cost of the fused conv[->relu][->pool] node: compute side unchanged
    (the epilogue rides the existing VMEM->HBM write), memory side is exactly
    the fused kernel's traffic — input + weights + final (post-pool) output,
    per ``chain_bytes``.  In particular the NCHW im2col expansion bytes of
    ``conv_cost`` are NOT charged: the fused engine's native im2col-MM kernel
    keeps the patch matrix virtual in VMEM.

    With ``in_dtype_bytes`` (mixed-dtype plans) the compute side is priced
    at the *input's* storage tiling: the contraction operand streams from
    VMEM at the stored element size, so int8 inputs see 32-wide sublanes.
    """
    in_db = dtype_bytes if in_dtype_bytes is None else in_dtype_bytes
    base = conv_cost(l, layout, in_db, peak, bw, packed_span=False)
    mem_bytes = chain_bytes(l, dtype_bytes, relu=relu, pool=pool, fused=True,
                            in_dtype_bytes=in_dtype_bytes,
                            out_dtype_bytes=out_dtype_bytes,
                            residual=residual)
    return ConvCost(layout, base.compute_s, mem_bytes / bw)


# ---------------------------------------------------------------------------
# cross-layer stack fusion cost model (DESIGN.md §12): two stacked convs in
# one kernel trade recomputed halo rows for the mid activation's round trip
# ---------------------------------------------------------------------------

# VMEM the staged stack tile may occupy.  TPU cores have ~16 MiB of VMEM;
# the budget leaves headroom for Pallas bookkeeping and double-buffering of
# the streamed input blocks.  The planner only fuses a stack when
# ``stack_vmem_bytes`` fits — full (Ci, Cm, Co) channel slabs live in VMEM
# because the stack kernel does not grid-block channels.
STACK_VMEM_BUDGET = 14 * (1 << 20)

# N-tile candidates for the CHWN stack engine, largest first: the widest
# lane block that still fits the VMEM budget wins (NCHW is per-sample).
STACK_NT_CANDIDATES = (8, 4, 2, 1)


def _stack_geom(l1: ConvLayer, l2: ConvLayer,
                pool: Optional[Tuple[int, int, str]] = None):
    """Composite blocking + staged-tile widths for a conv->conv stack.
    Geometry lives in ``kernels.conv.ops.stack_blocking`` (one source of
    truth with the kernel); imported lazily to keep the perf model free of a
    module-level kernels dependency."""
    from repro.kernels.conv.ops import stack_blocking
    if pool is not None and len(pool) == 2:
        pool = (pool[0], pool[1], "max")   # cost-model pools carry no op
    bho, IBH, n_ho, mho = stack_blocking(l2.out_hw, l1.F, l1.S,
                                         l2.F, l2.S, pool)
    w_pad = l1.HW + 2 * (l1.pad + l1.S * l2.pad)
    wm = l1.out_hw + 2 * l2.pad
    return bho, IBH, n_ho, mho, w_pad, wm


def stack_vmem_bytes(l1: ConvLayer, l2: ConvLayer, layout: str,
                     dtype_bytes: int = DEFAULT_DTYPE_BYTES, *,
                     pool: Optional[Tuple[int, int, str]] = None,
                     residual: bool = False, nt: int = 8,
                     in_dtype_bytes: Optional[int] = None) -> int:
    """VMEM footprint of one stack grid step: the stitched input block, both
    full weight slabs, the f32 staged mid tile, the f32 output accumulator,
    and the residual block when conv2 folds a skip add."""
    in_db = dtype_bytes if in_dtype_bytes is None else in_dtype_bytes
    bho, IBH, _, mho, w_pad, wm = _stack_geom(l1, l2, pool)
    ntv = min(nt, max(l1.N, 1)) if layout == "CHWN" else 1
    x_b = l1.Ci * 2 * IBH * w_pad * ntv * in_db
    w_b = (l1.Co * l1.Ci * l1.F * l1.F +
           l2.Co * l2.Ci * l2.F * l2.F) * dtype_bytes
    mid_b = l1.Co * mho * wm * ntv * 4
    out_b = l2.Co * bho * l2.out_hw * ntv * 4
    res_b = l2.Co * bho * l2.out_hw * ntv * dtype_bytes if residual else 0
    return x_b + w_b + mid_b + out_b + res_b


def stack_nt(l1: ConvLayer, l2: ConvLayer, layout: str,
             dtype_bytes: int = DEFAULT_DTYPE_BYTES, *,
             pool: Optional[Tuple[int, int, str]] = None,
             residual: bool = False,
             in_dtype_bytes: Optional[int] = None,
             budget: int = STACK_VMEM_BUDGET) -> int:
    """Largest legal N tile for the stack under the VMEM budget, or 0 when
    the stack does not fit at any tile (the planner's fuse/don't gate).
    The executor calls this with the SAME arguments so plan and kernel
    agree on the tile."""
    cands = STACK_NT_CANDIDATES if layout == "CHWN" else (1,)
    for nt in cands:
        if stack_vmem_bytes(l1, l2, layout, dtype_bytes, pool=pool,
                            residual=residual, nt=nt,
                            in_dtype_bytes=in_dtype_bytes) <= budget:
            return nt
    return 0


def stack_bytes(l1: ConvLayer, l2: ConvLayer,
                dtype_bytes: int = DEFAULT_DTYPE_BYTES, *,
                pool: Optional[Tuple[int, int, str]] = None,
                residual: bool = False,
                in_dtype_bytes: Optional[int] = None,
                out_dtype_bytes: Optional[int] = None) -> int:
    """HBM bytes of the fused stack: conv1's input, both weight tensors, the
    final (post-pool) output, and the skip tensor when conv2 folds a
    residual.  The mid activation contributes NOTHING — that is the entire
    point (its unfused round trip is ``chain_bytes(l1, fused=True)``'s
    output write plus conv2's input read)."""
    in_db = dtype_bytes if in_dtype_bytes is None else in_dtype_bytes
    out_db = dtype_bytes if out_dtype_bytes is None else out_dtype_bytes
    in_b = l1.N * l1.Ci * l1.HW * l1.HW * in_db
    w_b = (l1.Co * l1.Ci * l1.F * l1.F +
           l2.Co * l2.Ci * l2.F * l2.F) * dtype_bytes
    ho2 = l2.out_hw
    final_n = l2.N * l2.Co * ho2 * ho2
    if pool is not None:
        pho = pool_out_hw(ho2, pool[0], pool[1])
        final_n = l2.N * l2.Co * pho * pho
    out_b = l2.N * l2.Co * ho2 * ho2 * dtype_bytes
    return in_b + w_b + final_n * out_db + (out_b if residual else 0)


def stack_fused_cost(l1: ConvLayer, l2: ConvLayer, layout: str,
                     dtype_bytes: int = DEFAULT_DTYPE_BYTES, *,
                     pool: Optional[Tuple[int, int, str]] = None,
                     residual: bool = False,
                     in_dtype_bytes: Optional[int] = None,
                     out_dtype_bytes: Optional[int] = None,
                     peak=PEAK_FLOPS_BF16, bw=HBM_BW) -> ConvCost:
    """Roofline cost of the fused conv->conv stack node.

    Compute: conv2 runs exactly once, but conv1 recomputes its halo — each
    of the ``n_ho`` row blocks stages ``mho`` mid rows (and ``wm`` mid
    columns), so conv1's compute scales by (n_ho*mho/Ho1) * (wm/Wo1)
    relative to computing y1 once.  Memory: ``stack_bytes`` — the saved mid
    round trip is priced against those recomputed rows, which is the
    fuse/don't-fuse arbitration the DP performs (DESIGN.md §12)."""
    in_db = dtype_bytes if in_dtype_bytes is None else in_dtype_bytes
    _, _, n_ho, mho, _, wm = _stack_geom(l1, l2, pool)
    c1 = conv_cost(l1, layout, in_db, peak, bw, packed_span=False).compute_s
    c2 = conv_cost(l2, layout, dtype_bytes, peak, bw,
                   packed_span=False).compute_s
    recompute = ((n_ho * mho) / max(l1.out_hw, 1)) * (wm / max(l1.out_hw, 1))
    mem = stack_bytes(l1, l2, dtype_bytes, pool=pool, residual=residual,
                      in_dtype_bytes=in_dtype_bytes,
                      out_dtype_bytes=out_dtype_bytes)
    return ConvCost(layout, c1 * recompute + c2, mem / bw)


# ---------------------------------------------------------------------------
# backward-direction cost entries: dgrad / wgrad (training; paper applied to
# backward propagation, where the gradient convs are layout-sensitive
# primitives of their own)
# ---------------------------------------------------------------------------

def dilated_hw(l: ConvLayer) -> int:
    """Rows of the dilated+padded output gradient the transposed-conv dgrad
    consumes: stride-S dilation re-inflates Ho to the input scale, and the
    F-1 border re-centres the rotated filter."""
    return (l.out_hw - 1) * l.S + 1 + 2 * (l.F - 1)


def dgrad_bytes(l: ConvLayer, layout: str = "CHWN",
                dtype_bytes: int = DEFAULT_DTYPE_BYTES) -> int:
    """HBM bytes of the input-gradient conv.  For S > 1 the dilated gradient
    is materialized (one write) and re-read by the conv engine on top of the
    original gradient read; S == 1 streams the gradient directly."""
    ho = l.out_hw
    out_b = l.N * l.Co * ho * ho * dtype_bytes
    in_b = l.N * l.Ci * l.HW * l.HW * dtype_bytes
    w_b = l.Co * l.Ci * l.F * l.F * dtype_bytes
    if l.S > 1:
        hd = dilated_hw(l)
        g_b = out_b + 2 * l.N * l.Co * hd * hd * dtype_bytes
    else:
        g_b = out_b
    return g_b + w_b + in_b


def wgrad_bytes(l: ConvLayer, layout: str = "CHWN",
                dtype_bytes: int = DEFAULT_DTYPE_BYTES,
                native: bool = True) -> int:
    """HBM bytes of the weight-gradient contraction.  The native Pallas
    kernel keeps the im2col patch matrix virtual in VMEM for either layout;
    the decomposed NCHW path (Caffe-style) re-materializes it."""
    ho = l.out_hw
    base = (l.N * l.Ci * l.HW * l.HW + l.N * l.Co * ho * ho +
            l.Co * l.Ci * l.F * l.F) * dtype_bytes
    if not native and layout == "NCHW":
        base += 2 * l.N * ho * ho * l.Ci * l.F * l.F * dtype_bytes
    return base


def conv_backward_bytes(l: ConvLayer, layout: str = "CHWN",
                        dtype_bytes: int = DEFAULT_DTYPE_BYTES, *,
                        relu: bool = False,
                        pool: Optional[Tuple[int, int]] = None,
                        bias: bool = False, fused: bool = True,
                        trainable: bool = True,
                        residual: bool = False) -> int:
    """HBM bytes of the backward pass of a conv[->add][->relu][->pool] chain.

    Fused (custom-VJP engine): the forward kernel stashed the pre-pool
    activation from VMEM (one extra write + one read), the pool backward and
    the ReLU mask run as ONE kernel, and the reversed re-layout chain folds
    into the dgrad/wgrad I/O maps.  A folded residual add (``residual``,
    DESIGN.md §11) fans the masked gradient out to the skip branch: one
    extra dres write fused, a read+write pair for the standalone fan-out
    unfused.  Unfused (XLA-decomposed autodiff): every backward stage makes
    its own round trips, and NCHW wgrad re-materializes the patch matrix.
    ``trainable=False`` drops the wgrad contraction (frozen weights)."""
    ho = l.out_hw
    out_b = l.N * l.Co * ho * ho * dtype_bytes
    fin_b = out_b
    if pool is not None:
        pho = pool_out_hw(ho, pool[0], pool[1])
        fin_b = l.N * l.Co * pho * pho * dtype_bytes
    total = dgrad_bytes(l, layout, dtype_bytes)
    if trainable:
        total += wgrad_bytes(l, layout, dtype_bytes, native=fused)
    if fused:
        if pool is not None:
            total += 2 * out_b            # activation stash: write + read
            total += fin_b + out_b        # pool(+mask) bwd: read g, write dz
        elif relu:
            total += 2 * out_b            # mask from saved y: read + write
        if residual:
            total += out_b                # dres: the masked g written once
    else:
        if pool is not None:
            total += fin_b + 2 * out_b    # read g, read stored act, write dz
        if relu:
            total += 3 * out_b            # read dz, read mask source, write
        if residual:
            total += 2 * out_b            # standalone fan-out: read g, write
    if bias:
        total += out_b
    return total


def train_chain_bytes(l: ConvLayer, layout: str = "CHWN",
                      dtype_bytes: int = DEFAULT_DTYPE_BYTES, *,
                      relu: bool = False,
                      pool: Optional[Tuple[int, int]] = None,
                      bias: bool = False, fused: bool = True,
                      trainable: bool = True) -> int:
    """Forward + backward HBM bytes of one chain (one training step's view)."""
    return (chain_bytes(l, dtype_bytes, relu=relu, pool=pool, fused=fused) +
            conv_backward_bytes(l, layout, dtype_bytes, relu=relu, pool=pool,
                                bias=bias, fused=fused, trainable=trainable))


def conv_backward_cost(l: ConvLayer, layout: str,
                       dtype_bytes: int = DEFAULT_DTYPE_BYTES, *,
                       relu: bool = False,
                       pool: Optional[Tuple[int, int]] = None,
                       fused: bool = True, residual: bool = False,
                       peak=PEAK_FLOPS_BF16, bw=HBM_BW) -> ConvCost:
    """Roofline cost of the backward chain: dgrad + wgrad each move the
    forward FLOPs (2x total) at the layout's MXU tile efficiency; the memory
    side is ``conv_backward_bytes``."""
    fwd = conv_cost(l, layout, dtype_bytes, peak, bw)
    mem_bytes = conv_backward_bytes(l, layout, dtype_bytes, relu=relu,
                                    pool=pool, fused=fused,
                                    residual=residual)
    return ConvCost(layout, 2 * fwd.compute_s, mem_bytes / bw)


# ---------------------------------------------------------------------------
# LM-side layout scoring (activations, KV cache) — paper principle carried
# to the assigned architectures
# ---------------------------------------------------------------------------

def select_kv_layout(batch: int, kv_heads: int, seq: int, head_dim: int,
                     steps_per_read: float = 1.0,
                     dtype_bytes: int = 2) -> str:
    """Choose the decode KV-cache layout (DESIGN.md §4.1b).

    ``bksd`` reads contiguously but each decode step UPDATES a size-1 slice
    of the S dim (sublane dim)  -> update writes pad to a full (sublane,lane)
    tile per (b,k): waste = B*K*(sublanes-1)*head_dim.
    ``sbkd`` updates one full row [1,B,K,Dh] (perfectly tiled) but attention
    reads stride across S-major tiles; read cost is identical at the HBM
    level (whole cache is streamed) as long as B*K*Dh fills tiles.

    Selection mirrors the paper's update-vs-read analysis: prefer ``sbkd``
    when the padded-update waste exceeds the read-side tile waste.
    """
    sl = sublanes(dtype_bytes)
    # bksd: update touches B*K tiles of (sl x 128) to write 1 x Dh each
    upd_bksd = batch * kv_heads * sl * max(head_dim, LANES) * dtype_bytes
    # sbkd: update writes ceil(B*K*Dh / lanes) contiguous tiles exactly once
    row = batch * kv_heads * head_dim
    upd_sbkd = _round_up(row, sl * LANES) * dtype_bytes
    # read: both stream B*K*S*Dh; sbkd wastes if row < tile
    read_eff_sbkd = row / _round_up(row, sl * LANES)
    read_eff_bksd = min(1.0, (seq * head_dim) /
                        (_round_up(seq, sl) * _round_up(head_dim, LANES)))
    read_bytes = batch * kv_heads * seq * head_dim * dtype_bytes
    cost_bksd = upd_bksd + steps_per_read * read_bytes / max(read_eff_bksd, 1e-3)
    cost_sbkd = upd_sbkd + steps_per_read * read_bytes / max(read_eff_sbkd, 1e-3)
    return "bksd" if cost_bksd <= cost_sbkd else "sbkd"
