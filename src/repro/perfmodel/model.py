"""The ``CostModel`` interface (DESIGN.md §13).

Everything that prices a planner decision — the selector DPs, the RunStats
byte accounting, the serving report — talks to one of these instead of a
dozen direct ``traffic`` imports:

* ``AnalyticCostModel`` — the pure DeLTA-style priors: every method
  delegates verbatim to ``perfmodel.traffic``, so plans produced through it
  are byte-identical to plans produced against the bare functions.
* ``CalibratedCostModel`` — the analytic priors with a measured overlay from
  ``perfmodel.cross_validate``: per-layout, seconds are mapped through the
  fitted ``t = a * s^b`` curve (bytes pass through untouched — measurement
  calibrates the CLOCK, not the traffic, and byte models are exact by
  construction against the executor).

``plan_bytes`` is the whole-plan predictor: it replays a ``FusedPlan``'s op
stream through the byte models and returns the total HBM bytes the fused
engine will move — the number the planner stored in ``plan.fused_bytes``
and the executor's RunStats must both agree with exactly (the §13 agreement
property test pins all three together).

NOTE this module must not import ``repro.core`` at module scope:
``core.heuristic`` is a deprecation shim over this package, so a module-level
import back into ``core`` would be circular.  ``transform_bytes`` is pulled
lazily inside ``plan_bytes``.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.configs.paper_table1 import ConvLayer, PoolLayer
from repro.dtypes import dtype_bytes as _dtype_bytes
from repro.perfmodel import traffic
from repro.perfmodel.calibration import (CrossValidation, Thresholds,
                                         select_conv_layout,
                                         select_pool_layout)
from repro.perfmodel.traffic import ConvCost, DEFAULT_DTYPE_BYTES
from repro.shapes import pool_out_hw


class CostModel:
    """One interface for every byte/seconds question the planner asks.

    The analytic base class delegates to ``perfmodel.traffic`` verbatim;
    subclasses overlay measurement (``CalibratedCostModel``) or could swap
    in a different hardware model wholesale.  Methods mirror the traffic
    functions' signatures exactly so the selector's call sites stay
    mechanical.
    """

    # --- seconds (roofline ConvCost) ------------------------------------
    def conv_cost(self, l: ConvLayer, layout: str,
                  dtype_bytes: int = DEFAULT_DTYPE_BYTES,
                  **kw) -> ConvCost:
        return self._seconds(traffic.conv_cost(l, layout, dtype_bytes, **kw))

    def fused_chain_cost(self, l: ConvLayer, layout: str,
                         dtype_bytes: int = DEFAULT_DTYPE_BYTES,
                         **kw) -> ConvCost:
        return self._seconds(
            traffic.fused_chain_cost(l, layout, dtype_bytes, **kw))

    def stack_fused_cost(self, l1: ConvLayer, l2: ConvLayer, layout: str,
                         dtype_bytes: int = DEFAULT_DTYPE_BYTES,
                         **kw) -> ConvCost:
        return self._seconds(
            traffic.stack_fused_cost(l1, l2, layout, dtype_bytes, **kw))

    def conv_backward_cost(self, l: ConvLayer, layout: str,
                           dtype_bytes: int = DEFAULT_DTYPE_BYTES,
                           **kw) -> ConvCost:
        return self._seconds(
            traffic.conv_backward_cost(l, layout, dtype_bytes, **kw))

    def cast_cost(self, shape: Tuple[int, ...], src_dtype_bytes: int,
                  dst_dtype_bytes: int) -> float:
        return traffic.cast_cost(shape, src_dtype_bytes, dst_dtype_bytes)

    # --- HBM bytes (exact against the fused executor) -------------------
    def chain_bytes(self, l: ConvLayer,
                    dtype_bytes: int = DEFAULT_DTYPE_BYTES, **kw) -> int:
        return traffic.chain_bytes(l, dtype_bytes, **kw)

    def stack_bytes(self, l1: ConvLayer, l2: ConvLayer,
                    dtype_bytes: int = DEFAULT_DTYPE_BYTES, **kw) -> int:
        return traffic.stack_bytes(l1, l2, dtype_bytes, **kw)

    def conv_backward_bytes(self, l: ConvLayer, layout: str = "CHWN",
                            dtype_bytes: int = DEFAULT_DTYPE_BYTES,
                            **kw) -> int:
        return traffic.conv_backward_bytes(l, layout, dtype_bytes, **kw)

    def cast_bytes(self, shape: Tuple[int, ...], src_dtype_bytes: int,
                   dst_dtype_bytes: int) -> int:
        return traffic.cast_bytes(shape, src_dtype_bytes, dst_dtype_bytes)

    def stack_nt(self, l1: ConvLayer, l2: ConvLayer, layout: str,
                 dtype_bytes: int = DEFAULT_DTYPE_BYTES, **kw) -> int:
        """Shared planner/executor stack tile arbitration — geometry, not a
        price, but it lives on the model so both sides ask the same oracle."""
        return traffic.stack_nt(l1, l2, layout, dtype_bytes, **kw)

    # --- the paper's threshold heuristic --------------------------------
    def select_conv_layout(self, l: ConvLayer, th: Thresholds) -> str:
        return select_conv_layout(l, th)

    def select_pool_layout(self, l: Optional[PoolLayer] = None) -> str:
        return select_pool_layout(l)

    # --- measurement overlay hooks --------------------------------------
    def _seconds(self, c: ConvCost) -> ConvCost:
        """Hook for subclasses to overlay measurement on an analytic cost."""
        return c

    def predict_seconds(self, analytic_s: float,
                        layout: Optional[str] = None) -> float:
        """Wall-clock prediction for ``analytic_s`` modeled seconds (a plan's
        ``total_s``, a ConvCost total).  Analytic model: identity."""
        return analytic_s

    # --- whole-plan prediction ------------------------------------------
    def plan_bytes(self, layers: Sequence, plan, *,
                   input_shape: Optional[Tuple[int, ...]] = None,
                   input_layout: str = "NCHW",
                   training: bool = False) -> int:
        """Replay a ``FusedPlan``'s op stream through the byte models: the
        HBM bytes the fused engine moves executing it.  This is the same
        accounting the planner emitted into ``plan.fused_bytes`` and the
        executor tallies into ``RunStats.hbm_bytes`` — the three agree
        exactly, which the perfmodel property test asserts per network x
        dtype policy x stack policy."""
        from repro.core.layout import transform_bytes
        tx = 2 if training else 1
        in_shape = tuple(input_shape) if input_shape else (
            tuple(layers[0].out_shape) if len(layers) else ())

        def shape_of(p: int) -> Tuple[int, ...]:
            return in_shape if p < 0 else tuple(layers[p].out_shape)

        stored_lay: Dict[int, str] = {-1: input_layout}
        total = 0
        flat = False
        for op in plan.ops:
            l = layers[op.index]
            db = l.dtype_bytes
            in_db = _dtype_bytes(op.src_dtype) if op.src_dtype else db
            out_db = _dtype_bytes(op.dst_dtype) if op.dst_dtype else db
            p = op.inputs[0] if op.inputs else (
                op.index - 1 if op.index else -1)
            if op.out_index >= 0:
                stored_lay[op.out_index] = op.dst_layout
            if op.kind == "conv":
                pool_t = None
                if op.pool_index is not None:
                    pl = layers[op.pool_index].pool
                    pool_t = (pl.F, pl.S)
                res = op.res_index is not None
                if op.stack_index is not None:
                    total += self.stack_bytes(
                        l.conv, layers[op.stack_index].conv, db, pool=pool_t,
                        residual=res, in_dtype_bytes=in_db,
                        out_dtype_bytes=out_db)
                    continue
                total += self.chain_bytes(
                    l.conv, db, relu=op.relu, pool=pool_t, fused=True,
                    in_dtype_bytes=in_db, out_dtype_bytes=out_db,
                    residual=res)
                if training:
                    total += self.conv_backward_bytes(
                        l.conv, op.layout, db, relu=op.relu, pool=pool_t,
                        fused=True, trainable=l.trainable, residual=res)
                continue
            if op.kind == "pool" and l.pool is not None and not flat:
                if op.index in plan.transforms:   # standalone re-layout pass
                    total += tx * transform_bytes(shape_of(p), db)
                pl = l.pool
                ho = pool_out_hw(pl.HW, pl.F, pl.S)
                in_b = pl.N * pl.C * pl.HW * pl.HW * db
                out_b = pl.N * pl.C * ho * ho * db
                total += in_b + out_b + ((2 * in_b + out_b)
                                         if training else 0)
                continue
            sz = int(np.prod(l.out_shape)) if l.out_shape else 0
            if op.kind in ("add", "concat", "upsample"):
                for pi in op.inputs:    # standalone merge: every mismatch pays
                    if stored_lay.get(pi, input_layout) != op.layout:
                        total += tx * transform_bytes(shape_of(pi), db)
                total += (3 if op.kind == "add"
                          else (4 if training else 2)) * sz * db
                continue
            if op.kind == "act" and not flat and op.index in plan.transforms:
                total += tx * transform_bytes(shape_of(p), db)
            if op.kind == "flatten":
                flat = True
                if op.src_layout == "CHWN":   # CHWN->2D: one real transpose
                    total += tx * 2 * sz * db
            elif op.kind == "fc":
                in_f = (int(np.prod(shape_of(p))) // l.out_shape[0]
                        if p >= 0 else l.out_shape[1])
                io_b = (int(np.prod(l.out_shape)) + in_f * l.out_shape[1] +
                        l.out_shape[1] + in_f * l.out_shape[0]) * db
                total += io_b * (2 if training else 1)
            else:                        # act / softmax (incl. post-flatten)
                total += (5 if training else 2) * sz * db
        return total


class AnalyticCostModel(CostModel):
    """The pure analytic priors — ``CostModel``'s base behaviour, named."""


class CalibratedCostModel(AnalyticCostModel):
    """Analytic priors with a measured per-layout overlay.

    ``cv.scales[layout] = (a, b)`` maps analytic roofline seconds onto the
    measured clock as ``t = a * s^b`` (fitted by ``cross_validate`` on the
    calibration sweep).  Seconds-returning methods scale BOTH roofline
    components by ``overlay(total)/total`` so the compute/memory balance —
    and therefore every fuse/don't-fuse arbitration that compares the two —
    is preserved while the absolute clock matches silicon.  Byte models are
    inherited untouched.
    """

    def __init__(self, cv: CrossValidation):
        self.cv = cv
        self.scales = dict(cv.scales)

    def _overlay(self, s: float, layout: Optional[str]) -> float:
        ab = self.scales.get(layout or "")
        if ab is None and self.scales:      # no row for this layout: average
            ab = tuple(np.mean(list(self.scales.values()), axis=0))
        if ab is None or s <= 0.0:
            return s
        a, b = ab
        return a * (s ** b)

    def _seconds(self, c: ConvCost) -> ConvCost:
        t = c.total_s
        if t <= 0.0:
            return c
        k = self._overlay(t, c.layout) / t
        return ConvCost(c.layout, c.compute_s * k, c.memory_s * k)

    def predict_seconds(self, analytic_s: float,
                        layout: Optional[str] = None) -> float:
        return self._overlay(analytic_s, layout)


_DEFAULT: Optional[AnalyticCostModel] = None


def default_cost_model() -> AnalyticCostModel:
    """The process-wide analytic model (stateless, so one instance serves
    every caller that did not inject its own)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = AnalyticCostModel()
    return _DEFAULT
