"""jit-able step functions: train_step / prefill_step / decode_step.

Factories close over (cfg, mesh, parallel, train-config) and return functions
suitable for ``jax.jit`` with explicit in/out shardings — the same objects are
used by the real trainer, the serving loop and the multi-pod dry-run.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig, TrainConfig
from repro.distributed.sharding import make_shard_ctx, mesh_axes
from repro.models import transformer as T
from repro.optim import adamw, compress_psum

AUX_WEIGHT = 0.01      # MoE load-balance loss weight


def _positions(tokens):
    B, S = tokens.shape
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))


def loss_fn(params, batch: Dict[str, Any], cfg: ModelConfig, ctx=None,
            remat_policy: str = "none"):
    h, aux = T.forward(params, batch["tokens"], _positions(batch["tokens"]),
                       cfg, embeds=batch.get("embeds"),
                       frames=batch.get("frames"), ctx=ctx,
                       remat_policy=remat_policy)
    loss = T.chunked_xent(params, h, batch["labels"], cfg,
                          mask=batch.get("mask"))
    total = loss + AUX_WEIGHT * aux
    return total, {"loss": loss, "aux": aux}


def make_train_step(cfg: ModelConfig, mesh, parallel: ParallelConfig,
                    tc: TrainConfig):
    ctx = make_shard_ctx(mesh, parallel)
    _, _, multi_pod = mesh_axes(mesh)
    compress = parallel.grad_compression
    grad_fn = jax.value_and_grad(
        partial(loss_fn, cfg=cfg, ctx=ctx,
                remat_policy=parallel.remat_policy), has_aux=True)

    def compute_grads(params, batch):
        if parallel.microbatches > 1:
            mb = parallel.microbatches

            def mb_slice(x):
                B = x.shape[0]
                return x.reshape((mb, B // mb) + x.shape[1:])

            mb_batch = {k: mb_slice(v) for k, v in batch.items()}

            adt = jnp.dtype(parallel.accum_dtype)

            def body(acc, mbatch):
                (l, m), g = grad_fn(params, mbatch)
                acc_g, acc_l = acc
                acc_g = jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  + b.astype(jnp.float32) / mb).astype(adt),
                    acc_g, g)
                return (acc_g, acc_l + l / mb), m

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), params)
            (grads, loss), metrics = jax.lax.scan(
                body, (zero_g, jnp.zeros((), jnp.float32)), mb_batch)
            metrics = jax.tree.map(lambda x: x.mean(), metrics)
            return loss, metrics, grads
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        new_params, new_state, stats = adamw.update(grads, opt_state, params, tc)
        metrics = dict(metrics, **stats, total_loss=loss)
        return new_params, new_state, metrics

    if compress != "none" and multi_pod:
        # pod-local grads + explicit compressed cross-pod reduce.
        # shard_map over "pod" only; data/model stay under GSPMD (auto axes).
        from jax.sharding import PartitionSpec as P

        def train_step_compressed(params, opt_state, batch):
            def pod_body(params, opt_state, batch):
                loss, metrics, grads = compute_grads(params, batch)
                grads = compress_psum(grads, "pod", compress)
                loss = jax.lax.pmean(loss, "pod")
                metrics = jax.tree.map(lambda x: jax.lax.pmean(x, "pod"),
                                       metrics)
                new_params, new_state, stats = adamw.update(
                    grads, opt_state, params, tc)
                return new_params, new_state, dict(metrics, **stats,
                                                   total_loss=loss)

            pspec = jax.tree.map(lambda _: P(), params)
            ospec = jax.tree.map(lambda _: P(), opt_state)
            bspec = {k: P("pod") for k in batch}
            from repro.compat import shard_map as _shard_map
            return _shard_map(
                pod_body, mesh=mesh,
                in_specs=(pspec, ospec, bspec),
                out_specs=(pspec, ospec,
                           jax.tree.map(lambda _: P(),
                                        {"loss": 0, "aux": 0, "grad_norm": 0,
                                         "lr": 0, "total_loss": 0})),
                check_vma=False,
                axis_names={"pod"})(params, opt_state, batch)

        return train_step_compressed

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh, parallel: ParallelConfig,
                      shape: ShapeConfig, kv_layout: str = "bksd"):
    ctx = make_shard_ctx(mesh, parallel)

    def prefill_step(params, batch):
        out = T.prefill(params, batch["tokens"], cfg, max_len=shape.seq_len,
                        kv_layout=kv_layout, embeds=batch.get("embeds"),
                        frames=batch.get("frames"), ctx=ctx,
                        kv_window=parallel.window_kv_cache)
        logits, cache, cross = out
        if cross is None:
            return logits, cache
        return logits, cache, cross

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh, parallel: ParallelConfig,
                     kv_layout: str = "bksd", with_cross: bool = False):
    ctx = make_shard_ctx(mesh, parallel, for_decode=True)
    _, tp, _ = mesh_axes(mesh)
    # sharding-aware cache-write selection (see layers._cache_write_masked):
    # head-sharded cache -> cheap DUS; sequence-sharded cache -> masked select
    kv_update = "dus" if cfg.num_kv_heads % mesh.shape[tp] == 0 else "masked"

    if with_cross:
        def decode_step(params, cache, token, cache_len, cross):
            return T.decode_step(params, cache, token, cache_len, cfg,
                                 kv_layout=kv_layout, cross=cross, ctx=ctx,
                                 kv_update=kv_update,
                                 kv_window=parallel.window_kv_cache)
        return decode_step

    def decode_step(params, cache, token, cache_len):
        return T.decode_step(params, cache, token, cache_len, cfg,
                             kv_layout=kv_layout, ctx=ctx,
                             kv_update=kv_update,
                             kv_window=parallel.window_kv_cache)

    return decode_step
