"""Canonical storage-dtype handling for the dtype-generic CNN stack.

The engines follow cuDNN's reduced-precision recipe (Chetlur et al. 2014):
tensors are *stored* in a narrow dtype (the HBM-byte lever — the paper's
whole thesis is that CNNs are bound by bytes moved) while every kernel
*accumulates* in f32 VMEM scratch.  Planning must track the storage element
size too: it scales every byte model linearly and doubles the sublane width
(8 -> 16 at 2 bytes), which moves the Ct/Nt layout-crossover thresholds.

This module is the single source of truth for dtype naming so plan-cache
keys, calibration rows, and CLI flags all agree ("bf16" == "bfloat16").

int8 is a *storage* dtype only (ISSUE 5): tensors quantized per-channel
(``repro.quant``) live in HBM at 1 byte/element with 32-wide sublanes, the
conv engines dequantize in VMEM (the per-channel scale folds exactly into
the weights), and all arithmetic still accumulates in f32.  A network can
therefore never run "uniform int8" end to end — the host input and the
classifier head stay in a float dtype — which is why int8 appears in plans
as a per-layer storage choice, not as a network dtype.
"""
from __future__ import annotations

import jax.numpy as jnp

DEFAULT_DTYPE = "float32"
INT8_DTYPE = "int8"

_ALIASES = {
    "float32": "float32", "f32": "float32", "fp32": "float32",
    "bfloat16": "bfloat16", "bf16": "bfloat16",
    "float16": "float16", "f16": "float16", "fp16": "float16",
    "int8": "int8", "i8": "int8",
}

_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2, "int8": 1}

# element sizes by HLO short dtype name (the spelling ``cost_analysis`` and
# optimized-HLO text use: bf16[8,4096]{...}).  Single source of truth for
# every byte model in the repo — launch.roofline parses shapes against THIS
# table rather than hand-rolling its own (DESIGN.md §13 boundary).
HLO_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# dtypes a whole network (params, host I/O, classifier head) can run in;
# int8 is storage-only and deliberately NOT in this set
FLOAT_DTYPES = ("float32", "bfloat16", "float16")


def canon_dtype(dtype: str) -> str:
    """Canonical name ("bf16" -> "bfloat16"); raises on unknown dtypes."""
    try:
        return _ALIASES[str(dtype)]
    except KeyError:
        raise ValueError(
            f"unknown storage dtype {dtype!r}; known: {sorted(_ALIASES)}")


def dtype_bytes(dtype: str) -> int:
    """Element size in bytes of a (canonicalized) storage dtype."""
    return _BYTES[canon_dtype(dtype)]


def jnp_dtype(dtype: str):
    """The jnp dtype object for a storage dtype name."""
    return jnp.dtype(canon_dtype(dtype))


def is_float_dtype(dtype: str) -> bool:
    """True when ``dtype`` can carry a whole network (see FLOAT_DTYPES)."""
    return canon_dtype(dtype) in FLOAT_DTYPES
