"""Canonical storage-dtype handling for the dtype-generic CNN stack.

The engines follow cuDNN's reduced-precision recipe (Chetlur et al. 2014):
tensors are *stored* in a narrow dtype (the HBM-byte lever — the paper's
whole thesis is that CNNs are bound by bytes moved) while every kernel
*accumulates* in f32 VMEM scratch.  Planning must track the storage element
size too: it scales every byte model linearly and doubles the sublane width
(8 -> 16 at 2 bytes), which moves the Ct/Nt layout-crossover thresholds.

This module is the single source of truth for dtype naming so plan-cache
keys, calibration rows, and CLI flags all agree ("bf16" == "bfloat16").
"""
from __future__ import annotations

import jax.numpy as jnp

DEFAULT_DTYPE = "float32"

_ALIASES = {
    "float32": "float32", "f32": "float32", "fp32": "float32",
    "bfloat16": "bfloat16", "bf16": "bfloat16",
    "float16": "float16", "f16": "float16", "fp16": "float16",
}

_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2}


def canon_dtype(dtype: str) -> str:
    """Canonical name ("bf16" -> "bfloat16"); raises on unknown dtypes."""
    try:
        return _ALIASES[str(dtype)]
    except KeyError:
        raise ValueError(
            f"unknown storage dtype {dtype!r}; known: {sorted(_ALIASES)}")


def dtype_bytes(dtype: str) -> int:
    """Element size in bytes of a (canonicalized) storage dtype."""
    return _BYTES[canon_dtype(dtype)]


def jnp_dtype(dtype: str):
    """The jnp dtype object for a storage dtype name."""
    return jnp.dtype(canon_dtype(dtype))
