import jax.numpy as jnp


def matmul_ref(x, y, out_dtype=None):
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(
        out_dtype or x.dtype)
