"""Tiled MXU matmul Pallas kernel (workhorse for the im2col conv path).

Grid (M/bm, N/bn, K/bk) with the reduction dim innermost; a VMEM f32 scratch
accumulates partial products and is flushed on the last K step.  Block shapes
are multiples of the (8,128) native tile so the MXU sees aligned operands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k):
    @pl.when(pl.program_id(2) == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(x, y, bm: int = 128, bn: int = 128, bk: int = 128,
                  interpret: bool = True, out_dtype=None):
    """x: [M, K] @ y: [K, N] -> [M, N].  Dims must divide blocks (ops pads)."""
    M, K = x.shape
    K2, N = y.shape
    assert K == K2
    n_k = K // bk
    kern = functools.partial(_matmul_kernel, n_k=n_k)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype or x.dtype),
        grid=(M // bm, N // bn, n_k),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                  pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)
