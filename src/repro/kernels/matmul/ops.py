from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.matmul.matmul import matmul_pallas


def _pad(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(x, y, bm: int = 128, bn: int = 128, bk: int = 128,
           interpret: bool = True):
    M, K = x.shape
    _, N = y.shape
    xp = _pad(x, bm, bk)
    yp = _pad(y, bk, bn)
    out = matmul_pallas(xp, yp, bm, bn, bk, interpret=interpret)
    return out[:M, :N]
