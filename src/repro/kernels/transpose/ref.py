"""Pure-jnp oracle for the transpose kernel."""
import jax.numpy as jnp


def transpose2d_ref(x):
    return x.T


def transpose2d_batched_ref(x):
    return jnp.swapaxes(x, 1, 2)
