"""jit'd wrappers: dtype-aware tile sizing + padding for arbitrary shapes."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.transpose.transpose import (transpose2d_batched_pallas,
                                               transpose2d_pallas)

LANES = 128
VMEM_BUDGET = 2 * 1024 * 1024      # per-block in+out working set


def _sublanes(dtype) -> int:
    return {2: 16, 4: 8, 1: 32}.get(jnp.dtype(dtype).itemsize, 8)


def pick_blocks(M: int, N: int, dtype) -> tuple:
    """Largest aligned square-ish tile fitting the VMEM budget.  The doubled
    sublane count of 2-byte dtypes is the paper's float2 trick."""
    sl = _sublanes(dtype)
    item = jnp.dtype(dtype).itemsize
    bm, bn = sl, LANES
    # grow alternately while under budget and under the dims
    while True:
        grew = False
        if 2 * (2 * bm) * bn * item <= VMEM_BUDGET and bm * 2 <= max(M, sl):
            bm *= 2
            grew = True
        if 2 * bm * (2 * bn) * item <= VMEM_BUDGET and bn * 2 <= max(N, LANES):
            bn *= 2
            grew = True
        if not grew:
            return bm, bn


def _pad_to(x, m0: int, m1: int):
    p0 = (-x.shape[-2]) % m0
    p1 = (-x.shape[-1]) % m1
    if p0 or p1:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, p0), (0, p1)]
        x = jnp.pad(x, pad)
    return x


@partial(jax.jit, static_argnames=("interpret",))
def transpose2d(x, interpret: bool = True):
    """[M, N] -> [N, M] via the tiled Pallas kernel."""
    M, N = x.shape
    bm, bn = pick_blocks(M, N, x.dtype)
    xp = _pad_to(x, bm, bn)
    y = transpose2d_pallas(xp, bm, bn, interpret=interpret)
    return y[:N, :M]


@partial(jax.jit, static_argnames=("interpret",))
def transpose2d_batched(x, interpret: bool = True):
    """[B, M, N] -> [B, N, M]."""
    B, M, N = x.shape
    bm, bn = pick_blocks(M, N, x.dtype)
    xp = _pad_to(x, bm, bn)
    y = transpose2d_batched_pallas(xp, bm, bn, interpret=interpret)
    return y[:, :N, :M]
