"""Tiled 2-D transpose Pallas kernel — the paper's §IV.C fast layout
transform, TPU-native.

GPU original: flatten 4-D -> 2-D, shared-memory 32x32 tile transpose with
+1 padding (bank conflicts), float2 vectorized stores.
TPU adaptation: VMEM-resident (bm x bn) tiles aligned to the native
(sublane x lane) tiling — (8,128) f32 / (16,128) bf16; the in-register
transpose is a VPU shuffle emitted by Mosaic for ``.T`` on the block; the
float2 analogue is the doubled sublane count of 2-byte dtypes (handled by
dtype-aware block sizing in ops.py).  There is no bank-conflict padding on
TPU — the corresponding constraint is tile alignment, which the BlockSpecs
encode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _transpose_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


def transpose2d_pallas(x, bm: int, bn: int, interpret: bool = True):
    """x: [M, N] -> [N, M].  M % bm == 0 and N % bn == 0 (ops.py pads)."""
    M, N = x.shape
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        _transpose_kernel,
        out_shape=jax.ShapeDtypeStruct((N, M), x.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (j, i)),
        interpret=interpret,
    )(x)


def _batched_kernel(x_ref, o_ref):
    o_ref[...] = jnp.swapaxes(x_ref[...], 1, 2)


def transpose2d_batched_pallas(x, bm: int, bn: int, interpret: bool = True):
    """x: [B, M, N] -> [B, N, M] (batched tile transpose)."""
    B, M, N = x.shape
    grid = (B, M // bm, N // bn)
    return pl.pallas_call(
        _batched_kernel,
        out_shape=jax.ShapeDtypeStruct((B, N, M), x.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bm, bn), lambda b, i, j: (b, i, j))],
        out_specs=pl.BlockSpec((1, bn, bm), lambda b, i, j: (b, j, i)),
        interpret=interpret,
    )(x)
