"""Pure-jnp oracle: materialized-logits cross entropy."""
import jax
import jax.numpy as jnp


def xent_ref(h, table, labels, softcap=None):
    logits = (h.astype(jnp.float32) @ table.astype(jnp.float32).T)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lse - gold
