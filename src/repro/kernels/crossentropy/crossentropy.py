"""Fused unembed + softmax + cross-entropy Pallas kernel (beyond-paper;
DESIGN.md §4.2b).

The LM head is the paper's softmax layer at vocab scale (up to 202k classes
here): materializing [T, V] logits then running softmax+CE costs 3x the
logits in HBM traffic and dominates activation memory.  This kernel streams
vocab blocks: per (t-block) program, grid-innermost over v-blocks, computing
the [bt, bv] logits tile on the MXU and folding it into online
logsumexp + gold-logit accumulators in VMEM scratch.  The full logits tensor
never exists — the 5-kernel -> 1-kernel fusion, at 202k categories.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _xent_kernel(h_ref, t_ref, lab_ref, loss_ref, m_ref, s_ref, g_ref, *,
                 bv, n_v, softcap, vocab):
    v_i = pl.program_id(1)

    @pl.when(v_i == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    h = h_ref[...].astype(jnp.float32)          # [bt, d]
    t = t_ref[...].astype(jnp.float32)          # [bv, d]
    logits = h @ t.T                            # [bt, bv] on the MXU
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)

    lab = lab_ref[...]                          # [bt]
    bt = logits.shape[0]
    vpos = v_i * bv + jax.lax.broadcasted_iota(jnp.int32, (bt, bv), 1)
    logits = jnp.where(vpos < vocab, logits, NEG_INF)   # mask pad columns
    hit = vpos == lab[:, None]
    g_ref[...] += jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    s_ref[...] = s_ref[...] * jnp.exp(m_prev - m_new) + \
        jnp.sum(jnp.exp(logits - m_new[:, None]), axis=-1)
    m_ref[...] = m_new

    @pl.when(v_i == n_v - 1)
    def _():
        loss_ref[...] = (m_ref[...] + jnp.log(jnp.maximum(s_ref[...], 1e-30))
                         - g_ref[...])


def xent_pallas(h, table, labels, *, bt: int = 128, bv: int = 2048,
                softcap=None, interpret: bool = True, vocab: int = 0):
    """h: [T, D]; table: [V, D]; labels: [T] -> per-token loss [T] f32.
    T % bt == 0 and V % bv == 0 (ops pads)."""
    T, D = h.shape
    V = table.shape[0]
    n_v = V // bv
    kern = functools.partial(_xent_kernel, bv=bv, n_v=n_v, softcap=softcap,
                             vocab=vocab if vocab else V)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((T,), jnp.float32),
        grid=(T // bt, n_v),
        in_specs=[
            pl.BlockSpec((bt, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, D), lambda i, j: (j, 0)),
            pl.BlockSpec((bt,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda i, j: (i,)),
        scratch_shapes=[
            pltpu.VMEM((bt,), jnp.float32),
            pltpu.VMEM((bt,), jnp.float32),
            pltpu.VMEM((bt,), jnp.float32),
        ],
        interpret=interpret,
    )(h, table, labels)
