from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.crossentropy.crossentropy import xent_pallas


@partial(jax.jit, static_argnames=("bt", "bv", "softcap", "interpret"))
def fused_xent(h, table, labels, bt: int = 128, bv: int = 2048,
               softcap=None, interpret: bool = True):
    """Streaming unembed+CE: h [T,D], table [V,D], labels [T] -> [T] f32.
    T and V are padded to block multiples; padded vocab columns are masked
    to -inf inside the kernel, padded tokens sliced off the result."""
    T, D = h.shape
    V = table.shape[0]
    bt = min(bt, max(8, T))
    bv = min(bv, max(128, V))
    pt = (-T) % bt
    pv = (-V) % bv
    hp = jnp.pad(h, ((0, pt), (0, 0)))
    lp = jnp.pad(labels, (0, pt))
    tp = jnp.pad(table, ((0, pv), (0, 0))) if pv else table
    loss = xent_pallas(hp, tp, lp, bt=bt, bv=bv, softcap=softcap,
                       interpret=interpret, vocab=V)
    return loss[:T]
