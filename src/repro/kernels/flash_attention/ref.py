"""Pure-jnp attention oracle."""
import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """q,k,v: [BH, S, D]."""
    D = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        Sq, Sk = s.shape[-2:]
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
