"""Fused (flash) attention Pallas kernel — the paper's §V.B kernel-fusion
principle applied to attention (beyond-paper feature; DESIGN.md §4.2a).

Online-softmax over KV blocks: per (batch-head, q-block) program, running
max m / normalizer l / f32 accumulator live in VMEM scratch; the [Sq, Sk]
score matrix never exists in HBM — exactly the paper's elimination of
inter-step off-chip traffic, one level up.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, bq, bk, n_kv):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_i = pl.program_id(1)
    run = True
    if causal:
        # skip fully-masked kv blocks (upper triangle)
        run = kv_i * bk <= (q_i + 1) * bq - 1

    @pl.when(run if causal else True)
    def _():
        q = q_ref[0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = (q @ k.T) * scale                       # [bq, bk]
        if causal:
            qpos = q_i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(kv_i == n_kv - 1)
    def _():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, bq: int = 128,
                           bk: int = 128, interpret: bool = True):
    """q,k,v: [BH, S, D] -> [BH, S, D].  S % bq == 0 and S % bk == 0."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    n_kv = Sk // bk
    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             bq=bq, bk=bk, n_kv=n_kv)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        grid=(BH, Sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
