from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


@partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = True):
    """q,k,v: [B, H, S, D] or [BH, S, D]."""
    squeeze = False
    if q.ndim == 4:
        B, H, S, D = q.shape
        q = q.reshape(B * H, S, D)
        k = k.reshape(B * H, k.shape[2], D)
        v = v.reshape(B * H, v.shape[2], D)
        squeeze = (B, H)
    S = q.shape[1]
    bq = min(bq, S)
    bk = min(bk, k.shape[1])
    while S % bq:
        bq //= 2
    while k.shape[1] % bk:
        bk //= 2
    out = flash_attention_pallas(q, k, v, causal=causal, bq=max(bq, 1),
                                 bk=max(bk, 1), interpret=interpret)
    if squeeze:
        B, H = squeeze
        out = out.reshape(B, H, S, -1)
    return out
