"""jit'd wrappers with row-block sizing + padding."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.softmax.softmax import softmax_pallas, softmax_xent_pallas

VMEM_BUDGET = 4 * 1024 * 1024


def pick_bn(N: int, C: int, itemsize: int) -> int:
    bn = 8
    while 2 * (2 * bn) * C * max(itemsize, 4) <= VMEM_BUDGET and 2 * bn <= N:
        bn *= 2
    return bn


def _pad_rows(x, bn):
    p = (-x.shape[0]) % bn
    if p:
        pad = [(0, p)] + [(0, 0)] * (x.ndim - 1)
        x = jnp.pad(x, pad)
    return x


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def _softmax_vjp(x, interpret):
    N, C = x.shape
    bn = pick_bn(N, C, x.dtype.itemsize)
    xp = _pad_rows(x, bn)
    return softmax_pallas(xp, bn, interpret=interpret)[:N]


def _softmax_fwd(x, interpret):
    y = _softmax_vjp(x, interpret)
    return y, y


def _softmax_bwd(interpret, y, g):
    yf = y.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    dx = (gf - (gf * yf).sum(-1, keepdims=True)) * yf
    return (dx.astype(y.dtype),)


_softmax_vjp.defvjp(_softmax_fwd, _softmax_bwd)


@partial(jax.jit, static_argnames=("interpret",))
def softmax(x, interpret: bool = True):
    """Fused row softmax for [N, C] (paper §V.B single-kernel);
    differentiable via the closed-form softmax VJP on the saved output."""
    return _softmax_vjp(x, interpret)


@partial(jax.jit, static_argnames=("interpret",))
def softmax_xent(x, labels, interpret: bool = True):
    """Fused softmax+NLL rows: x [N, C], labels [N] -> [N] f32."""
    N, C = x.shape
    bn = pick_bn(N, C, 4)
    xp = _pad_rows(x, bn)
    lp = _pad_rows(labels, bn)
    return softmax_xent_pallas(xp, lp, bn, interpret=interpret)[:N]
