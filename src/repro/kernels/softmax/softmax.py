"""Fused softmax Pallas kernel — the paper's §V.B five-step fusion.

GPU original: five kernels (max / shift / exp / sum / normalize) each
round-tripping [N, C] through DRAM, with the inner reduction parallelized
via shared memory.  TPU adaptation: ONE kernel; a row-block (Bn x C) lives in
VMEM, the five steps run back-to-back on the VPU with f32 accumulation, and
the only HBM traffic is one read + one write of the matrix — the 5x-kernel
inter-step traffic is gone by construction.  Reductions across lanes/sublanes
(the warp-shuffle analogue) are emitted by Mosaic for jnp.max/sum on the
block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)       # step 1
    e = jnp.exp(x - m)                           # steps 2+3
    s = jnp.sum(e, axis=-1, keepdims=True)       # step 4
    o_ref[...] = (e / s).astype(o_ref.dtype)     # step 5


def softmax_pallas(x, bn: int, interpret: bool = True):
    """Row softmax of x: [N, C];  N % bn == 0 (ops pads)."""
    N, C = x.shape
    return pl.pallas_call(
        _softmax_kernel,
        out_shape=jax.ShapeDtypeStruct((N, C), x.dtype),
        grid=(N // bn,),
        in_specs=[pl.BlockSpec((bn, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bn, C), lambda i: (i, 0)),
        interpret=interpret,
    )(x)


def _softmax_xent_kernel(x_ref, lab_ref, loss_ref):
    """Fused softmax + NLL for one row block (used by the CNN classifier)."""
    x = x_ref[...].astype(jnp.float32)
    lab = lab_ref[...]
    m = jnp.max(x, axis=-1)
    e = jnp.exp(x - m[:, None])
    lse = jnp.log(jnp.sum(e, axis=-1)) + m
    C = x.shape[-1]
    onehot = (lab[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, C), 1))
    gold = jnp.sum(jnp.where(onehot, x, 0.0), axis=-1)
    loss_ref[...] = lse - gold


def softmax_xent_pallas(x, labels, bn: int, interpret: bool = True):
    """Row-wise cross entropy: x [N, C], labels [N] -> loss [N]."""
    N, C = x.shape
    return pl.pallas_call(
        _softmax_xent_kernel,
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        grid=(N // bn,),
        in_specs=[pl.BlockSpec((bn, C), lambda i: (i, 0)),
                  pl.BlockSpec((bn,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        interpret=interpret,
    )(x, labels)
