"""Pure-jnp oracle: the paper's five-step softmax, written as five separate
passes (the multi-kernel baseline we fuse away)."""
import jax
import jax.numpy as jnp


def softmax_ref(x):
    """Numerically-stable row softmax (jnp one-liner oracle)."""
    return jax.nn.softmax(x.astype(jnp.float32), axis=-1).astype(x.dtype)


def softmax_5step_ref(x):
    """The paper's literal 5 steps as 5 materialized passes."""
    xf = x.astype(jnp.float32)
    maxv = jnp.max(xf, axis=-1, keepdims=True)          # kernel 1
    midv1 = xf - maxv                                   # kernel 2
    midv2 = jnp.exp(midv1)                              # kernel 3
    sumv = jnp.sum(midv2, axis=-1, keepdims=True)       # kernel 4
    return (midv2 / sumv).astype(x.dtype)               # kernel 5


def softmax_xent_ref(x, labels):
    xf = x.astype(jnp.float32)
    lse = jax.nn.logsumexp(xf, axis=-1)
    gold = jnp.take_along_axis(xf, labels[:, None], axis=-1)[:, 0]
    return lse - gold
