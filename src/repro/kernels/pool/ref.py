"""Pure-jnp oracle for pooling (any layout via reduce_window)."""
import jax.numpy as jnp
from jax import lax


def pool_ref(x, F: int, S: int, op: str = "max", layout: str = "CHWN"):
    """x in the given layout; pooling over the H, W dims."""
    hw = {"CHWN": (1, 2), "NCHW": (2, 3), "NHWC": (1, 2)}[layout]
    dims = [1] * x.ndim
    strides = [1] * x.ndim
    for d in hw:
        dims[d] = F
        strides[d] = S
    xf = x.astype(jnp.float32)
    if op == "max":
        y = lax.reduce_window(xf, -jnp.inf, lax.max, dims, strides, "VALID")
    else:
        y = lax.reduce_window(xf, 0.0, lax.add, dims, strides, "VALID") / (F * F)
    return y.astype(x.dtype)
