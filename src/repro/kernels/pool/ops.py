"""jit'd pooling wrappers + the paper's hill-climbing coarsening auto-tune."""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels.pool.pool import pool_chwn_pallas, pool_nchw_pallas

VMEM_BUDGET = 4 * 1024 * 1024


def _pad_axis(x, axis, m):
    p = (-x.shape[axis]) % m
    if p:
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, p)
        x = jnp.pad(x, pad)
    return x


def vmem_bytes_chwn(H, W, nt, itemsize) -> int:
    return H * W * nt * max(itemsize, 4)


def autotune_nt(H: int, W: int, N: int, itemsize: int,
                measure: Optional[Callable[[int], float]] = None) -> int:
    """The paper's §V.A hill climb: start at a small expansion factor, keep
    doubling while the cost improves (or, analytically, while the working set
    fits VMEM); stop at the first regression."""
    nt, best = 128, None
    while nt * 2 <= max(N, 128):
        cand = nt * 2
        if measure is not None:
            c = measure(cand)
            if best is not None and c >= best:
                break
            best = c
        elif vmem_bytes_chwn(H, W, cand, itemsize) > VMEM_BUDGET:
            break
        nt = cand
    return nt


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5, 6))
def _pool_chwn_vjp(x, F, S, op, nt, dst_layout, interpret):
    xp = _pad_axis(x, 3, nt)
    y = pool_chwn_pallas(xp, F, S, op, nt, dst_layout=dst_layout,
                         interpret=interpret)
    N = x.shape[3]
    return y[:N] if dst_layout == "NCHW" else y[..., :N]


def _pool_chwn_fwd(x, F, S, op, nt, dst_layout, interpret):
    return _pool_chwn_vjp(x, F, S, op, nt, dst_layout, interpret), x


def _pool_chwn_bwd(F, S, op, nt, dst_layout, interpret, x, g):
    from repro.kernels.pool.backward import pool_backward
    dx = pool_backward(x, g, F, S, op, layout="CHWN", g_layout=dst_layout,
                       interpret=interpret)
    return (dx.astype(x.dtype),)


_pool_chwn_vjp.defvjp(_pool_chwn_fwd, _pool_chwn_bwd)


@partial(jax.jit, static_argnames=("F", "S", "op", "interpret", "nt",
                                   "dst_layout"))
def pool_chwn(x, F: int, S: int, op: str = "max", nt: int = 0,
              dst_layout: str = "CHWN", interpret: bool = True):
    """[C,H,W,N] pooling with VMEM window reuse (preferred layout).
    ``dst_layout="NCHW"`` writes the result directly in the consumer's
    layout, replacing a standalone transform pass.  Differentiable: the VJP
    runs the max-mask/avg-scatter Pallas kernel, consuming the cotangent in
    ``dst_layout`` (the reversed re-layout folds into its input read)."""
    C, H, W, N = x.shape
    if nt == 0:
        nt = autotune_nt(H, W, N, x.dtype.itemsize)
    nt = min(nt, max(N, 1))
    return _pool_chwn_vjp(x, F, S, op, nt, dst_layout, interpret)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5, 6))
def _pool_nchw_vjp(x, F, S, op, ct, dst_layout, interpret):
    xp = _pad_axis(x, 1, ct)
    y = pool_nchw_pallas(xp, F, S, op, ct, dst_layout=dst_layout,
                         interpret=interpret)
    C = x.shape[1]
    return y[:C] if dst_layout == "CHWN" else y[:, :C]


def _pool_nchw_fwd(x, F, S, op, ct, dst_layout, interpret):
    return _pool_nchw_vjp(x, F, S, op, ct, dst_layout, interpret), x


def _pool_nchw_bwd(F, S, op, ct, dst_layout, interpret, x, g):
    from repro.kernels.pool.backward import pool_backward
    dx = pool_backward(x, g, F, S, op, layout="NCHW", g_layout=dst_layout,
                       interpret=interpret)
    return (dx.astype(x.dtype),)


_pool_nchw_vjp.defvjp(_pool_nchw_fwd, _pool_nchw_bwd)


@partial(jax.jit, static_argnames=("F", "S", "op", "interpret", "ct",
                                   "dst_layout"))
def pool_nchw(x, F: int, S: int, op: str = "max", ct: int = 8,
              dst_layout: str = "NCHW", interpret: bool = True):
    """[N,C,H,W] pooling (the paper's inefficient-layout baseline);
    differentiable like ``pool_chwn``."""
    ct = min(ct, x.shape[1])
    return _pool_nchw_vjp(x, F, S, op, ct, dst_layout, interpret)
