"""Pooling backward Pallas kernels: max-mask routing and avg scatter.

Same slab decomposition as the forward kernels (§V.A): each program owns one
(c, n-tile) slab with the whole H x W input block in VMEM, so every
overlapping window routes its gradient from registers — the backward twin of
the thread-coarsening reuse.  Max pooling recomputes the window max from the
slab and routes each window's gradient to its FIRST maximal element in
row-major tap order (matching XLA's select-and-scatter tie-breaking, so the
differential tests agree exactly).  Avg pooling scatter-adds g/F^2 over each
window.

Layout fusion, reversed: ``g_layout`` lets the kernel consume the incoming
gradient in the *downstream* op's layout (the backward analogue of
``dst_layout`` on the forward kernels), and ``relu_mask`` folds the ReLU
backward mask into the same pass — the pool input is in VMEM for the max
mask anyway, so the fused conv block's whole relu+pool backward is one
kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pool.ops import _pad_axis


def _route(x, g, F, S, Ho, Wo, op, ha, wa, relu_mask):
    """Scatter the window gradients of one VMEM slab back onto x's grid.
    ``ha``/``wa`` are x's spatial axes; x and g share layout."""
    def hs(d):
        return slice(d, d + (Ho - 1) * S + 1, S)

    def ws(d):
        return slice(d, d + (Wo - 1) * S + 1, S)

    def at(a, dy, dx):
        idx = [slice(None)] * a.ndim
        idx[ha], idx[wa] = hs(dy), ws(dx)
        return tuple(idx)

    acc = jnp.zeros(x.shape, jnp.float32)
    if op == "avg":
        gavg = g / (F * F)
        for dy in range(F):
            for dx in range(F):
                acc = acc.at[at(acc, dy, dx)].add(gavg)
    else:
        mx = jnp.full(g.shape, -jnp.inf, jnp.float32)
        for dy in range(F):
            for dx in range(F):
                mx = jnp.maximum(mx, x[at(x, dy, dx)])
        claimed = jnp.zeros(g.shape, jnp.bool_)
        for dy in range(F):
            for dx in range(F):
                win = x[at(x, dy, dx)]
                take = (win == mx) & (~claimed)
                claimed = claimed | take
                acc = acc.at[at(acc, dy, dx)].add(jnp.where(take, g, 0.0))
    if relu_mask:
        acc = acc * (x > 0.0)
    return acc


def _pool_bwd_chwn_kernel(x_ref, g_ref, o_ref, *, F, S, op, Ho, Wo,
                          g_layout, relu_mask):
    x = x_ref[...].astype(jnp.float32)          # [1, H, W, nt]
    g = g_ref[...]
    if g_layout == "NCHW":                      # [nt, 1, Ho, Wo]
        g = jnp.transpose(g, (1, 2, 3, 0))
    g = g.astype(jnp.float32)                   # [1, Ho, Wo, nt]
    acc = _route(x, g, F, S, Ho, Wo, op, 1, 2, relu_mask)
    o_ref[...] = acc.astype(o_ref.dtype)


def _pool_bwd_nchw_kernel(x_ref, g_ref, o_ref, *, F, S, op, Ho, Wo,
                          g_layout, relu_mask):
    x = x_ref[...].astype(jnp.float32)          # [1, ct, H, W]
    g = g_ref[...]
    if g_layout == "CHWN":                      # [ct, Ho, Wo, 1]
        g = jnp.transpose(g, (3, 0, 1, 2))
    g = g.astype(jnp.float32)                   # [1, ct, Ho, Wo]
    acc = _route(x, g, F, S, Ho, Wo, op, 2, 3, relu_mask)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("F", "S", "op", "layout",
                                             "g_layout", "relu_mask", "nt",
                                             "ct", "interpret"))
def pool_backward(x, g, F: int, S: int, op: str = "max", *,
                  layout: str = "CHWN", g_layout: str = None,
                  relu_mask: bool = False, nt: int = 128, ct: int = 8,
                  interpret: bool = True):
    """dx of pool(x, F, S, op): x the pool input in ``layout``, g the pooled
    output's gradient in ``g_layout``.  Returns dx in ``layout``; rows/cols
    beyond the last window get zero gradient.  ``relu_mask`` multiplies dx by
    (x > 0) in the same pass."""
    g_layout = g_layout or layout
    if F == 1 and S == 1:
        # identity pool (e.g. a global-average window degenerated to 1x1 at
        # reduced image sizes): dx is g re-laid-out, with the optional mask
        from repro.core.transform import apply_transform
        ga = apply_transform(g, g_layout, layout).astype(jnp.float32)
        if relu_mask:
            ga = ga * (x > 0.0)
        return ga.astype(x.dtype)
    if layout == "CHWN":
        C, H, W, N = x.shape
        Ho = g.shape[2] if g_layout == "NCHW" else g.shape[1]
        Wo = g.shape[3] if g_layout == "NCHW" else g.shape[2]
        nt = min(nt, max(N, 1))
        xp = _pad_axis(x, 3, nt)
        gp = _pad_axis(g, 0 if g_layout == "NCHW" else 3, nt)
        if g_layout == "NCHW":
            g_spec = pl.BlockSpec((nt, 1, Ho, Wo), lambda c, n: (n, c, 0, 0))
        else:
            g_spec = pl.BlockSpec((1, Ho, Wo, nt), lambda c, n: (c, 0, 0, n))
        kern = functools.partial(_pool_bwd_chwn_kernel, F=F, S=S, op=op,
                                 Ho=Ho, Wo=Wo, g_layout=g_layout,
                                 relu_mask=relu_mask)
        dx = pl.pallas_call(
            kern,
            out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
            grid=(C, xp.shape[3] // nt),
            in_specs=[pl.BlockSpec((1, H, W, nt), lambda c, n: (c, 0, 0, n)),
                      g_spec],
            out_specs=pl.BlockSpec((1, H, W, nt), lambda c, n: (c, 0, 0, n)),
            interpret=interpret,
        )(xp, gp)
        return dx[..., :N]
    N, C, H, W = x.shape
    Ho = g.shape[1] if g_layout == "CHWN" else g.shape[2]
    Wo = g.shape[2] if g_layout == "CHWN" else g.shape[3]
    ct = min(ct, C)
    xp = _pad_axis(x, 1, ct)
    gp = _pad_axis(g, 0 if g_layout == "CHWN" else 1, ct)
    if g_layout == "CHWN":
        g_spec = pl.BlockSpec((ct, Ho, Wo, 1), lambda n, c: (c, 0, 0, n))
    else:
        g_spec = pl.BlockSpec((1, ct, Ho, Wo), lambda n, c: (n, c, 0, 0))
    kern = functools.partial(_pool_bwd_nchw_kernel, F=F, S=S, op=op,
                             Ho=Ho, Wo=Wo, g_layout=g_layout,
                             relu_mask=relu_mask)
    dx = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        grid=(N, xp.shape[1] // ct),
        in_specs=[pl.BlockSpec((1, ct, H, W), lambda n, c: (n, c, 0, 0)),
                  g_spec],
        out_specs=pl.BlockSpec((1, ct, H, W), lambda n, c: (n, c, 0, 0)),
        interpret=interpret,
    )(xp, gp)
    return dx[:, :C]
