"""Pooling Pallas kernels — the paper's §V.A off-chip-access optimization.

GPU original: CHWN layout + thread coarsening: each thread produces E output
elements so overlapping input windows are loaded into registers once
(hill-climbed E).  TPU adaptation: each program owns one (c, n-tile) slab;
the full H x W x Nt input block is loaded into VMEM ONCE and every
overlapping window is computed from it (VMEM plays the register file).  The
coarsening factor maps to the N-tile width Nt, auto-tuned in ops.py by the
same hill-climbing rule.  The N dim rides the 128 lanes (the paper's
coalescing dim).

An NCHW variant is provided for the paper's layout comparison: there the
window slides along the minormost W (lanes), producing the strided accesses
the paper measures as uncoalesced — on TPU, sub-tile-width W wastes lanes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.shapes import pool_out_hw


def _pool_chwn_kernel(x_ref, o_ref, *, F, S, op, Ho, Wo, dst_layout):
    x = x_ref[...].astype(jnp.float32)          # [1, H, W, Nt]
    init = -jnp.inf if op == "max" else 0.0
    acc = jnp.full((1, Ho, Wo, x.shape[-1]), init, jnp.float32)
    for dy in range(F):
        for dx in range(F):
            win = x[:, dy:dy + (Ho - 1) * S + 1:S, dx:dx + (Wo - 1) * S + 1:S, :]
            acc = jnp.maximum(acc, win) if op == "max" else acc + win
    if op == "avg":
        acc = acc / (F * F)
    if dst_layout == "NCHW":
        acc = jnp.transpose(acc, (3, 0, 1, 2))  # [Nt, 1, Ho, Wo]
    o_ref[...] = acc.astype(o_ref.dtype)


def pool_chwn_pallas(x, F: int, S: int, op: str = "max", nt: int = 128,
                     dst_layout: str = "CHWN", interpret: bool = True):
    """x: [C, H, W, N] -> [C, Ho, Wo, N] (or [N, C, Ho, Wo] when
    ``dst_layout == "NCHW"``: the re-layout folds into the output write via
    the out BlockSpec index map).  N % nt == 0."""
    C, H, W, N = x.shape
    Ho = pool_out_hw(H, F, S)          # shared with the selector's byte model
    Wo = pool_out_hw(W, F, S)
    import functools
    kern = functools.partial(_pool_chwn_kernel, F=F, S=S, op=op, Ho=Ho, Wo=Wo,
                             dst_layout=dst_layout)
    if dst_layout == "NCHW":
        out_shape = jax.ShapeDtypeStruct((N, C, Ho, Wo), x.dtype)
        out_specs = pl.BlockSpec((nt, 1, Ho, Wo), lambda c, n: (n, c, 0, 0))
    else:
        out_shape = jax.ShapeDtypeStruct((C, Ho, Wo, N), x.dtype)
        out_specs = pl.BlockSpec((1, Ho, Wo, nt), lambda c, n: (c, 0, 0, n))
    return pl.pallas_call(
        kern,
        out_shape=out_shape,
        grid=(C, N // nt),
        in_specs=[pl.BlockSpec((1, H, W, nt), lambda c, n: (c, 0, 0, n))],
        out_specs=out_specs,
        interpret=interpret,
    )(x)


def _pool_nchw_kernel(x_ref, o_ref, *, F, S, op, Ho, Wo, dst_layout):
    x = x_ref[...].astype(jnp.float32)          # [1, Ct, H, W]
    init = -jnp.inf if op == "max" else 0.0
    acc = jnp.full((1, x.shape[1], Ho, Wo), init, jnp.float32)
    for dy in range(F):
        for dx in range(F):
            win = x[:, :, dy:dy + (Ho - 1) * S + 1:S, dx:dx + (Wo - 1) * S + 1:S]
            acc = jnp.maximum(acc, win) if op == "max" else acc + win
    if op == "avg":
        acc = acc / (F * F)
    if dst_layout == "CHWN":
        acc = jnp.transpose(acc, (1, 2, 3, 0))  # [Ct, Ho, Wo, 1]
    o_ref[...] = acc.astype(o_ref.dtype)


def pool_nchw_pallas(x, F: int, S: int, op: str = "max", ct: int = 8,
                     dst_layout: str = "NCHW", interpret: bool = True):
    """x: [N, C, H, W] -> [N, C, Ho, Wo] (or [C, Ho, Wo, N] when
    ``dst_layout == "CHWN"``).  C % ct == 0.  The W dim (lanes) is
    window-strided — the layout the paper shows to be memory-inefficient."""
    N, C, H, W = x.shape
    Ho = pool_out_hw(H, F, S)          # shared with the selector's byte model
    Wo = pool_out_hw(W, F, S)
    import functools
    kern = functools.partial(_pool_nchw_kernel, F=F, S=S, op=op, Ho=Ho, Wo=Wo,
                             dst_layout=dst_layout)
    if dst_layout == "CHWN":
        out_shape = jax.ShapeDtypeStruct((C, Ho, Wo, N), x.dtype)
        out_specs = pl.BlockSpec((ct, Ho, Wo, 1), lambda n, c: (c, 0, 0, n))
    else:
        out_shape = jax.ShapeDtypeStruct((N, C, Ho, Wo), x.dtype)
        out_specs = pl.BlockSpec((1, ct, Ho, Wo), lambda n, c: (n, c, 0, 0))
    return pl.pallas_call(
        kern,
        out_shape=out_shape,
        grid=(N, C // ct),
        in_specs=[pl.BlockSpec((1, ct, H, W), lambda n, c: (n, c, 0, 0))],
        out_specs=out_specs,
        interpret=interpret,
    )(x)
