"""Layout-aware conv backward: dgrad + wgrad Pallas engines (paper applied to
training — the layout study covers backward propagation, where the two
gradient convolutions are first-class layout-sensitive primitives, cuDNN
style).

dgrad (input gradient) uses the **transposed-conv formulation**: the output
gradient is spatially dilated by the forward stride and padded by F-1-pad,
then convolved (stride 1) with the 180°-rotated, channel-swapped filter.
The convolution itself runs on the existing layout-bound Pallas engines
(direct-CHWN / im2col-MM-NCHW), so dgrad inherits the whole layout-fusion
protocol: it consumes the incoming gradient in the *downstream* op's layout
(``g_layout`` -> the engine's ``src_layout``) and writes dx directly in the
*upstream* producer's layout (``dst_layout``) — the reversed re-layout chain
folds into kernel I/O maps exactly like the forward one.

wgrad (weight gradient) is a **native Pallas kernel** in the im2col-MM
formulation: dw = (virtual patch matrix)^T @ (output-gradient matrix).  Each
(dy, dx) filter tap contributes one [Co-block] x [Ci-block] MXU contraction
over (rows x N) — the im2col expansion stays virtual in VMEM, and the tiny
[Co, Ci, F, F] result accumulates in a VMEM scratch across the (N, row-block)
grid dims (innermost, so output-block revisits are consecutive).  The same
halo-stitch trick as the forward kernels covers row blocks whose windows
overlap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spatial_axes(layout: str):
    return (2, 3) if layout == "NCHW" else (1, 2)


def dilate_grad(g, S: int, F: int, layout: str):
    """Spatially dilate ``g`` by the forward stride and pad by F-1: the
    transposed-conv input.  Identity (plus padding) when S == 1."""
    ha, wa = _spatial_axes(layout)
    if S > 1:
        shape = list(g.shape)
        shape[ha] = (shape[ha] - 1) * S + 1
        shape[wa] = (shape[wa] - 1) * S + 1
        idx = [slice(None)] * g.ndim
        idx[ha] = slice(None, None, S)
        idx[wa] = slice(None, None, S)
        g = jnp.zeros(shape, g.dtype).at[tuple(idx)].set(g)
    if F > 1:
        pads = [(0, 0)] * g.ndim
        pads[ha] = (F - 1, F - 1)
        pads[wa] = (F - 1, F - 1)
        g = jnp.pad(g, pads)
    return g


def conv_dgrad(g, w, x_hw, stride: int = 1, pad: int = 0, *,
               layout: str = "CHWN", g_layout: str = None,
               dst_layout: str = None, interpret: bool = True):
    """Input gradient of conv(x, w, stride, pad).

    g: conv-output gradient in ``g_layout`` (NCHW [N,Co,Ho,Wo] or CHWN
    [Co,Ho,Wo,N]); w: canonical [Co,Ci,F,F]; x_hw: (H, W) of the forward
    input.  Computes in ``layout``'s Pallas engine, returns dx in
    ``dst_layout``.  Rows/cols of x beyond the last consumed window (when
    (H + 2*pad - F) % stride != 0) receive zero gradient.
    """
    g_layout = g_layout or layout
    dst_layout = dst_layout or layout
    F = w.shape[2]
    S = stride
    H, W = x_hw
    gd = dilate_grad(g, S, F, g_layout)
    # rotate 180° and swap channel roles: the transposed filter maps Co->Ci
    wt = jnp.transpose(w[:, :, ::-1, ::-1], (1, 0, 2, 3))     # [Ci, Co, F, F]
    from repro.kernels.conv.ops import (conv_direct_chwn,
                                        conv_im2col_nchw_fused)
    if layout == "CHWN":
        dx = conv_direct_chwn(gd, jnp.transpose(wt, (1, 2, 3, 0)), stride=1,
                              pad=0, interpret=interpret, src_layout=g_layout,
                              dst_layout=dst_layout)
    else:
        dx = conv_im2col_nchw_fused(gd, wt, stride=1, pad=0,
                                    interpret=interpret, src_layout=g_layout,
                                    dst_layout=dst_layout)
    # dx now covers the PADDED input rows 0..(Ho-1)*S+F-1; the unpadded
    # gradient is the [pad, pad+H) window, zero-filled past the last
    # consumed window when (H + 2*pad - F) % S != 0
    ha, wa = _spatial_axes(dst_layout)
    idx = [slice(None)] * dx.ndim
    idx[ha] = slice(pad, pad + H)
    idx[wa] = slice(pad, pad + W)
    dx = dx[tuple(idx)]
    tail_h = H - dx.shape[ha]
    tail_w = W - dx.shape[wa]
    if tail_h or tail_w:
        pads = [(0, 0)] * dx.ndim
        pads[ha] = (0, tail_h)
        pads[wa] = (0, tail_w)
        dx = jnp.pad(dx, pads)
    return dx


def bias_grad(g, layout: str = "CHWN"):
    """d(bias): reduce the conv-output gradient over all non-Co dims."""
    axes = (0, 2, 3) if layout == "NCHW" else (1, 2, 3)
    return g.astype(jnp.float32).sum(axes)


# ---------------------------------------------------------------------------
# native wgrad kernel
# ---------------------------------------------------------------------------

def _wgrad_kernel(xa_ref, xb_ref, g_ref, o_ref, acc_ref, *, F, S, bho, Wo,
                  n_n, n_ho, x_layout, g_layout):
    @pl.when((pl.program_id(2) == 0) & (pl.program_id(3) == 0))
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xa = xa_ref[...]
    xb = xb_ref[...]
    if x_layout == "NCHW":               # blocks arrive [nt, cit, IBH, W]
        xa = jnp.transpose(xa, (1, 2, 3, 0))
        xb = jnp.transpose(xb, (1, 2, 3, 0))
    x2 = jnp.concatenate([xa, xb], axis=1)       # [cit, 2*IBH, W, nt]
    g = g_ref[...]
    if g_layout == "NCHW":               # [nt, cot, bho, Wo]
        g = jnp.transpose(g, (1, 2, 3, 0))       # [cot, bho, Wo, nt]

    taps = []
    for dy in range(F):
        for dx in range(F):
            xs = x2[:, dy:dy + (bho - 1) * S + 1:S,
                    dx:dx + (Wo - 1) * S + 1:S, :]       # [cit, bho, Wo, nt]
            # one [Co-block] x [Ci-block] tap of the virtual-im2col matmul:
            # contraction over the (rows x N) output positions on the MXU
            taps.append(jnp.einsum("khwn,chwn->kc", g, xs,
                                   preferred_element_type=jnp.float32))
    upd = jnp.stack(taps).reshape(F, F, *taps[0].shape)
    acc_ref[...] = acc_ref[...] + jnp.transpose(upd, (2, 3, 0, 1))

    @pl.when((pl.program_id(2) == n_n - 1) & (pl.program_id(3) == n_ho - 1))
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def wgrad_pallas(x, g, F: int, S: int, *, bho: int = 4, cot: int = 0,
                 cit: int = 0, nt: int = 128, ibh: int = 0,
                 x_layout: str = "CHWN", g_layout: str = None,
                 interpret: bool = True):
    """dw[Co,Ci,F,F] = wgrad(x, g): x the (pre-padded) forward input in
    ``x_layout``, g the conv-output gradient in ``g_layout``.

    Requirements (conv_wgrad pads): N % nt == 0, Co % cot == 0,
    Ci % cit == 0, Ho % bho == 0, rows >= (row blocks + 1)*IBH.
    """
    g_layout = g_layout or x_layout
    if x_layout == "NCHW":
        N, Ci, H, W = x.shape
    else:
        Ci, H, W, N = x.shape
    if g_layout == "NCHW":
        Co, Ho, Wo = g.shape[1], g.shape[2], g.shape[3]
    else:
        Co, Ho, Wo = g.shape[0], g.shape[1], g.shape[2]
    cot = cot or min(Co, 128)
    cit = cit or min(Ci, 32)
    IBH = ibh or bho * S
    n_ho = Ho // bho
    n_n = N // nt
    assert IBH == bho * S or n_ho == 1, (IBH, bho, S, n_ho)

    if x_layout == "NCHW":
        x_specs = [
            pl.BlockSpec((nt, cit, IBH, W), lambda c, k, n, h: (n, k, h, 0)),
            pl.BlockSpec((nt, cit, IBH, W),
                         lambda c, k, n, h: (n, k, h + 1, 0)),
        ]
    else:
        x_specs = [
            pl.BlockSpec((cit, IBH, W, nt), lambda c, k, n, h: (k, h, 0, n)),
            pl.BlockSpec((cit, IBH, W, nt),
                         lambda c, k, n, h: (k, h + 1, 0, n)),
        ]
    if g_layout == "NCHW":
        g_spec = pl.BlockSpec((nt, cot, bho, Wo),
                              lambda c, k, n, h: (n, c, h, 0))
    else:
        g_spec = pl.BlockSpec((cot, bho, Wo, nt),
                              lambda c, k, n, h: (c, h, 0, n))

    kern = functools.partial(_wgrad_kernel, F=F, S=S, bho=bho, Wo=Wo,
                             n_n=n_n, n_ho=n_ho, x_layout=x_layout,
                             g_layout=g_layout)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((Co, Ci, F, F), jnp.float32),
        # accumulation dims (N, row blocks) innermost: the (c, k) output
        # block is revisited consecutively, accumulating in VMEM scratch
        grid=(Co // cot, Ci // cit, n_n, n_ho),
        in_specs=x_specs + [g_spec],
        out_specs=pl.BlockSpec((cot, cit, F, F),
                               lambda c, k, n, h: (c, k, 0, 0)),
        scratch_shapes=[pltpu.VMEM((cot, cit, F, F), jnp.float32)],
        interpret=interpret,
    )(x, x, g)


def conv_wgrad(x, g, F: int, S: int = 1, pad: int = 0, *,
               x_layout: str = "CHWN", g_layout: str = None, nt: int = 128,
               interpret: bool = True):
    """Weight gradient of conv(x, w, S, pad) -> canonical [Co, Ci, F, F].

    x: the forward input (unpadded) in ``x_layout``; g: the conv-output
    gradient in ``g_layout``.  Pads channels/batch to tile multiples (zero
    contributions) and preps halo rows like the forward wrappers.
    """
    from repro.kernels.conv.ops import _pad_axis, _prep_rows, conv_blocking
    g_layout = g_layout or x_layout
    if x_layout == "NCHW":
        n_axis, ci_axis, h_axis = 0, 1, 2
        if pad:
            x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    else:
        n_axis, ci_axis, h_axis = 3, 0, 1
        if pad:
            x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    if g_layout == "NCHW":
        N, Co, Ho = g.shape[0], g.shape[1], g.shape[2]
        g_n, g_co = 0, 1
    else:
        Co, Ho, N = g.shape[0], g.shape[1], g.shape[3]
        g_n, g_co = 3, 0
    Ci = x.shape[ci_axis]
    cit = min(Ci, 32)
    cot = min(Co, 128)
    nt = min(nt, max(N, 1))
    x = _pad_axis(_pad_axis(x, ci_axis, cit), n_axis, nt)
    g = _pad_axis(_pad_axis(g, g_co, cot), g_n, nt)
    bho, IBH, n_ho = conv_blocking(Ho, F, S)
    x = _prep_rows(x, h_axis, (n_ho + 1) * IBH)
    dw = wgrad_pallas(x, g, F, S, bho=bho, cot=cot, cit=cit, nt=nt, ibh=IBH,
                      x_layout=x_layout, g_layout=g_layout,
                      interpret=interpret)
    return dw[:Co, :Ci]
