"""Native im2col-MM convolution Pallas kernel in the NCHW layout.

The seed's NCHW path materialized the im2col patch matrix with XLA and only
ran the matmul in Pallas; this kernel is the all-Pallas analogue of the
Caffe/cuDNN lowering (paper §II.B): the patch matrix is *virtual* — each
(dy, dx) filter tap contributes one [Ci-block] x [Co-block] MXU matmul
against the strided input window, which is exactly the im2col matrix-multiply
with the expansion unrolled into the tap loop and kept in VMEM.

Blocking: grid (N, Ho blocks, Co blocks, Ci blocks), Ci innermost
accumulating into a VMEM f32 scratch; the halo-stitch trick (the input
passed twice at consecutive row-block indices) covers windows that overlap
row blocks.  The same epilogue protocol as the CHWN kernel applies
(bias/ReLU/pool on the VMEM accumulator, ``src_layout``/``dst_layout``
fusion via the BlockSpec index maps) — see DESIGN.md §5.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.conv.conv import Epilogue, pool_block, pool_tiles_block
from repro.shapes import conv_out_hw, pool_out_hw


def _conv_nchw_kernel(*refs, F, S, bho, Wo, n_ci, epilogue: Epilogue,
                      src_layout: str, dst_layout: str,
                      res_layout: str = "NCHW", save_act: bool = False):
    xa_ref, xb_ref, w_ref = refs[:3]
    rest = refs[3:]
    b_ref = r_ref = None
    if epilogue.bias:
        b_ref, rest = rest[0], rest[1:]
    if epilogue.residual:
        r_ref, rest = rest[0], rest[1:]
    if save_act:
        o_ref, z_ref, acc_ref = rest
    else:
        (o_ref, acc_ref), z_ref = rest, None

    @pl.when(pl.program_id(3) == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if src_layout == "CHWN":             # blocks arrive [cit, IBH, W, 1]
        xa = xa_ref[...][..., 0]
        xb = xb_ref[...][..., 0]
    else:                                # native: [1, cit, IBH, W]
        xa = xa_ref[...][0]
        xb = xb_ref[...][0]
    x2 = jnp.concatenate([xa, xb], axis=1)      # [cit, 2*IBH, W]
    if jnp.issubdtype(x2.dtype, jnp.integer):
        # int8 storage (DESIGN.md §9): the VMEM dequant — per-channel scale
        # already folded into w by the caller, so the cast IS the dequant
        x2 = x2.astype(jnp.float32)
    w = w_ref[...]                       # [cot, cit, F, F]

    acc = acc_ref[...]                   # [cot, bho, Wo]
    for dy in range(F):
        for dx in range(F):
            xs = x2[:, dy:dy + (bho - 1) * S + 1:S,
                    dx:dx + (Wo - 1) * S + 1:S]         # [cit, bho, Wo]
            # one column-block of the virtual im2col matrix x one row-block
            # of the filter matrix: contraction over Ci on the MXU
            acc = acc + jnp.einsum(
                "chw,kc->khw", xs, w[:, :, dy, dx],
                preferred_element_type=jnp.float32)
    acc_ref[...] = acc

    @pl.when(pl.program_id(3) == n_ci - 1)
    def _():
        y = acc_ref[...]                 # [cot, bho, Wo] f32, in VMEM
        if epilogue.bias:
            y = y + b_ref[...].reshape(-1, 1, 1)
        if epilogue.residual:            # folded skip add, pre-ReLU
            r = (r_ref[...][..., 0] if res_layout == "CHWN"
                 else r_ref[...][0])     # -> [cot, bho, Wo]
            y = y + r.astype(jnp.float32)
        if epilogue.relu:
            y = jnp.maximum(y, 0.0)
        if save_act:                     # training residual: pre-pool, native
            z_ref[...] = y[None].astype(z_ref.dtype)
        if epilogue.pool is not None:
            pF, pS, pop = epilogue.pool
            y = pool_block(y, pF, pS, pop)
        if dst_layout == "CHWN":
            y = y[..., None]             # [cot, obho, OWo, 1]
        else:
            y = y[None]                  # [1, cot, obho, OWo]
        o_ref[...] = y.astype(o_ref.dtype)


def conv_nchw_pallas(x, w, F: int, S: int, *, bho: int = 4, cot: int = 0,
                     cit: int = 0, ibh: int = 0, bias=None, res=None,
                     res_layout: str = "NCHW",
                     epilogue: Epilogue = Epilogue(),
                     src_layout: str = "NCHW", dst_layout: str = "NCHW",
                     save_act: bool = False, interpret: bool = True):
    """im2col-MM NCHW conv with fused epilogue and layout-fused I/O.

    x: [N, Ci, H, W] (or [Ci, H, W, N] when ``src_layout == "CHWN"``);
    w: [Co, Ci, F, F] (canonical); bias: [Co, 1] when ``epilogue.bias``;
    ``res`` (when ``epilogue.residual``) is the skip tensor in
    ``res_layout``, pre-padded by ops.py to the kernel's Co/row-block grid.
    Result: [N, Co, Ho', Wo'] (or [Co, Ho', Wo', N] for dst CHWN), Ho'/Wo'
    post-pool when a pool epilogue is fused.

    Requirements (ops.py pads): Co % cot == 0, Ci % cit == 0, Ho % bho == 0,
    H >= (row blocks + 1)*IBH, and with a pool epilogue
    ``pool_tiles_block(bho, n_ho, pF, pS)``.  ``ibh`` overrides the input
    row-block height (default bho*S); legal only when there is a single row
    block, where it lets the two stitched blocks cover a window span larger
    than 2*bho*S.  ``save_act`` (training) adds a second output: the pre-pool
    post-bias/relu activation [N, Co, Ho, Wo] in the kernel's native NCHW
    layout, written from the same VMEM accumulator.
    """
    if src_layout == "CHWN":
        Ci, H, W, N = x.shape
    else:
        N, Ci, H, W = x.shape
    Co = w.shape[0]
    Ho = conv_out_hw(H, F, S)          # input arrives pre-padded
    Wo = conv_out_hw(W, F, S)
    cot = cot or min(Co, 128)
    cit = cit or min(Ci, 32)
    IBH = ibh or bho * S
    n_ci = Ci // cit
    if IBH == bho * S:
        n_ho = Ho // bho          # may exceed the true count (halo padding);
    else:                         # ops.py slices the spurious rows off
        n_ho = 1                  # ibh override: single row block by contract
        assert 2 * IBH >= (bho - 1) * S + F, (IBH, bho, S, F)

    obho, OWo = bho, Wo
    if epilogue.pool is not None:
        pF, pS, _ = epilogue.pool
        assert pool_tiles_block(bho, n_ho, pF, pS), (bho, n_ho, pF, pS)
        obho = pool_out_hw(bho, pF, pS)
        OWo = pool_out_hw(Wo, pF, pS)
    OHo = n_ho * obho

    if src_layout == "CHWN":
        in_specs = [
            pl.BlockSpec((cit, IBH, W, 1), lambda n, h, c, k: (k, h, 0, n)),
            pl.BlockSpec((cit, IBH, W, 1),
                         lambda n, h, c, k: (k, h + 1, 0, n)),
        ]
    else:
        in_specs = [
            pl.BlockSpec((1, cit, IBH, W), lambda n, h, c, k: (n, k, h, 0)),
            pl.BlockSpec((1, cit, IBH, W),
                         lambda n, h, c, k: (n, k, h + 1, 0)),
        ]
    in_specs.append(pl.BlockSpec((cot, cit, F, F),
                                 lambda n, h, c, k: (c, k, 0, 0)))
    operands = [x, x, w]
    if epilogue.bias:
        assert bias is not None
        in_specs.append(pl.BlockSpec((cot, 1), lambda n, h, c, k: (c, 0)))
        operands.append(bias)
    if epilogue.residual:
        assert res is not None
        if res_layout == "CHWN":
            in_specs.append(pl.BlockSpec((cot, bho, Wo, 1),
                                         lambda n, h, c, k: (c, h, 0, n)))
        else:
            in_specs.append(pl.BlockSpec((1, cot, bho, Wo),
                                         lambda n, h, c, k: (n, c, h, 0)))
        operands.append(res)

    # int8 x emits the float compute dtype (= w's dtype); see conv.py
    odt = jnp.result_type(x.dtype, w.dtype)
    if dst_layout == "CHWN":
        out_shape = jax.ShapeDtypeStruct((Co, OHo, OWo, N), odt)
        out_specs = pl.BlockSpec((cot, obho, OWo, 1),
                                 lambda n, h, c, k: (c, h, 0, n))
    else:
        out_shape = jax.ShapeDtypeStruct((N, Co, OHo, OWo), odt)
        out_specs = pl.BlockSpec((1, cot, obho, OWo),
                                 lambda n, h, c, k: (n, c, h, 0))
    if save_act:
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((N, Co, n_ho * bho, Wo), odt)]
        out_specs = [out_specs,
                     pl.BlockSpec((1, cot, bho, Wo),
                                  lambda n, h, c, k: (n, c, h, 0))]

    kern = functools.partial(_conv_nchw_kernel, F=F, S=S, bho=bho, Wo=Wo,
                             n_ci=n_ci, epilogue=epilogue,
                             src_layout=src_layout, dst_layout=dst_layout,
                             res_layout=res_layout, save_act=save_act)
    return pl.pallas_call(
        kern,
        out_shape=out_shape,
        grid=(N, n_ho, Co // cot, n_ci),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((cot, bho, Wo), jnp.float32)],
        interpret=interpret,
    )(*operands)
