"""Cross-layer halo fusion: two stacked convolutions in ONE Pallas kernel.

The biggest HBM round-trip left after epilogue fusion (DESIGN.md §5/§11) is
the intermediate activation between adjacent convs.  This kernel removes it:
the first conv consumes a halo-widened input row block (the halo is what the
SECOND conv's receptive field needs beyond the block boundary), stages its
post-bias/ReLU output tile in VMEM, and the second conv — with the full
bias/residual-add/ReLU/pool epilogue protocol — contracts straight off the
staged tile.  The mid activation never touches HBM; the price is recomputing
the halo rows of conv1 once per block (DESIGN.md §12).

Blocking composes the two convs into one virtual conv:

    S_eff = S1 * S2,   F_eff = (F2 - 1) * S1 + F1

so ``conv_blocking(Ho2, F_eff, S_eff)`` yields (bho, IBH, n_ho) with the
standard halo-stitch guarantee 2*IBH >= (bho-1)*S_eff + F_eff — exactly the
input span one block of ``mho = (bho-1)*S2 + F2`` mid rows needs.

Padding of the second conv folds into the input: the wrapper pre-pads the
input by ``pad1 + S1*pad2`` rows/cols per side, which makes the staged mid
tile exactly ``pad2``-padded y1 — EXCEPT that conv1's epilogue (bias/ReLU)
is nonzero on the padding rows, so the kernel masks mid rows/cols outside
the valid global range [pad2, pad2 + Ho1) back to zero before conv2 reads
them (``jax.lax.broadcasted_iota`` against the block's global row offset).

Both engines are provided, mirroring the single-conv pair: the CHWN variant
blocks N on the 128 lanes (grid (row blocks, N blocks)); the NCHW variant is
per-sample (grid (N, row blocks)).  Channels are NOT grid-blocked — the
full (Ci, Cm, Co) slabs live in VMEM, which is why the planner gates stack
fusion on a VMEM-footprint bound (``stack_vmem_bytes``) instead.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.conv.conv import Epilogue, pool_block, pool_tiles_block
from repro.shapes import conv_out_hw, pool_out_hw


def _mask_mid(mid, h_axis: int, w_axis: int, row0, valid_rows, valid_cols):
    """Zero mid rows/cols outside the valid global range [pad2, pad2+Ho1):
    those are conv2's zero padding, but conv1's bias/ReLU made them nonzero.
    ``row0`` is the block's global mid-row offset; columns are unblocked so
    their iota is already global."""
    lo, hi = valid_rows
    rows = row0 + jax.lax.broadcasted_iota(jnp.int32, mid.shape, h_axis)
    keep = (rows >= lo) & (rows < hi)
    lo, hi = valid_cols
    cols = jax.lax.broadcasted_iota(jnp.int32, mid.shape, w_axis)
    keep = keep & (cols >= lo) & (cols < hi)
    return jnp.where(keep, mid, 0.0)


def _stack_chwn_kernel(*refs, F1, S1, F2, S2, bho, mho, Wm, Wo2,
                       relu1: bool, epilogue: Epilogue, valid_rows,
                       valid_cols, src_layout: str, dst_layout: str,
                       res_layout: str = "CHWN"):
    xa_ref, xb_ref, w1_ref, b1_ref, w2_ref = refs[:5]
    rest = refs[5:]
    b2_ref = r_ref = None
    if epilogue.bias:
        b2_ref, rest = rest[0], rest[1:]
    if epilogue.residual:
        r_ref, rest = rest[0], rest[1:]
    (o_ref,) = rest

    xa = xa_ref[...]                     # [Ci, IBH, W, nt] (CHWN blocks)
    xb = xb_ref[...]
    if src_layout == "NCHW":             # blocks arrive [nt, Ci, IBH, W]
        xa = jnp.transpose(xa, (1, 2, 3, 0))
        xb = jnp.transpose(xb, (1, 2, 3, 0))
    x2 = jnp.concatenate([xa, xb], axis=1)
    if jnp.issubdtype(x2.dtype, jnp.integer):
        x2 = x2.astype(jnp.float32)      # VMEM dequant (scale folded into w1)
    w1 = w1_ref[...]                     # [Ci, F1, F1, Cm]

    # ---- conv1 on the halo-widened block: mho staged mid rows ----
    mid = jnp.zeros((w1.shape[-1], mho, Wm, x2.shape[-1]), jnp.float32)
    for dy in range(F1):
        for dx in range(F1):
            xs = x2[:, dy:dy + (mho - 1) * S1 + 1:S1,
                    dx:dx + (Wm - 1) * S1 + 1:S1, :]    # [Ci, mho, Wm, nt]
            mid = mid + jnp.einsum(
                "chwn,ck->khwn", xs, w1[:, dy, dx, :],
                preferred_element_type=jnp.float32)
    mid = mid + b1_ref[...].reshape(-1, 1, 1, 1)
    if relu1:
        mid = jnp.maximum(mid, 0.0)
    mid = _mask_mid(mid, 1, 2, pl.program_id(0) * bho * S2,
                    valid_rows, valid_cols)

    # ---- conv2 straight off the staged VMEM tile ----
    w2 = w2_ref[...]                     # [Cm, F2, F2, Co]
    y = jnp.zeros((w2.shape[-1], bho, Wo2, x2.shape[-1]), jnp.float32)
    for dy in range(F2):
        for dx in range(F2):
            ms = mid[:, dy:dy + (bho - 1) * S2 + 1:S2,
                     dx:dx + (Wo2 - 1) * S2 + 1:S2, :]  # [Cm, bho, Wo2, nt]
            y = y + jnp.einsum(
                "chwn,ck->khwn", ms, w2[:, dy, dx, :],
                preferred_element_type=jnp.float32)

    if epilogue.bias:
        y = y + b2_ref[...].reshape(-1, 1, 1, 1)
    if epilogue.residual:                # folded skip add, pre-ReLU
        r = r_ref[...]
        if res_layout == "NCHW":         # block arrives [nt, Co, bho, Wo2]
            r = jnp.transpose(r, (1, 2, 3, 0))
        y = y + r.astype(jnp.float32)
    if epilogue.relu:
        y = jnp.maximum(y, 0.0)
    if epilogue.pool is not None:
        pF, pS, pop = epilogue.pool
        y = pool_block(y, pF, pS, pop)
    if dst_layout == "NCHW":
        y = jnp.transpose(y, (3, 0, 1, 2))
    o_ref[...] = y.astype(o_ref.dtype)


def _stack_nchw_kernel(*refs, F1, S1, F2, S2, bho, mho, Wm, Wo2,
                       relu1: bool, epilogue: Epilogue, valid_rows,
                       valid_cols, src_layout: str, dst_layout: str,
                       res_layout: str = "NCHW"):
    xa_ref, xb_ref, w1_ref, b1_ref, w2_ref = refs[:5]
    rest = refs[5:]
    b2_ref = r_ref = None
    if epilogue.bias:
        b2_ref, rest = rest[0], rest[1:]
    if epilogue.residual:
        r_ref, rest = rest[0], rest[1:]
    (o_ref,) = rest

    if src_layout == "CHWN":             # blocks arrive [Ci, IBH, W, 1]
        xa = xa_ref[...][..., 0]
        xb = xb_ref[...][..., 0]
    else:                                # native: [1, Ci, IBH, W]
        xa = xa_ref[...][0]
        xb = xb_ref[...][0]
    x2 = jnp.concatenate([xa, xb], axis=1)      # [Ci, 2*IBH, W]
    if jnp.issubdtype(x2.dtype, jnp.integer):
        x2 = x2.astype(jnp.float32)
    w1 = w1_ref[...]                     # [Cm, Ci, F1, F1] (canonical)

    mid = jnp.zeros((w1.shape[0], mho, Wm), jnp.float32)
    for dy in range(F1):
        for dx in range(F1):
            xs = x2[:, dy:dy + (mho - 1) * S1 + 1:S1,
                    dx:dx + (Wm - 1) * S1 + 1:S1]       # [Ci, mho, Wm]
            mid = mid + jnp.einsum(
                "chw,kc->khw", xs, w1[:, :, dy, dx],
                preferred_element_type=jnp.float32)
    mid = mid + b1_ref[...].reshape(-1, 1, 1)
    if relu1:
        mid = jnp.maximum(mid, 0.0)
    mid = _mask_mid(mid, 1, 2, pl.program_id(1) * bho * S2,
                    valid_rows, valid_cols)

    w2 = w2_ref[...]                     # [Co, Cm, F2, F2]
    y = jnp.zeros((w2.shape[0], bho, Wo2), jnp.float32)
    for dy in range(F2):
        for dx in range(F2):
            ms = mid[:, dy:dy + (bho - 1) * S2 + 1:S2,
                     dx:dx + (Wo2 - 1) * S2 + 1:S2]     # [Cm, bho, Wo2]
            y = y + jnp.einsum(
                "chw,kc->khw", ms, w2[:, :, dy, dx],
                preferred_element_type=jnp.float32)

    if epilogue.bias:
        y = y + b2_ref[...].reshape(-1, 1, 1)
    if epilogue.residual:
        r = (r_ref[...][..., 0] if res_layout == "CHWN"
             else r_ref[...][0])         # -> [Co, bho, Wo2]
        y = y + r.astype(jnp.float32)
    if epilogue.relu:
        y = jnp.maximum(y, 0.0)
    if epilogue.pool is not None:
        pF, pS, pop = epilogue.pool
        y = pool_block(y, pF, pS, pop)
    if dst_layout == "CHWN":
        y = y[..., None]                 # [Co, obho, OWo2, 1]
    else:
        y = y[None]                      # [1, Co, obho, OWo2]
    o_ref[...] = y.astype(o_ref.dtype)


def conv_stack_chwn_pallas(x, w1, b1, w2, F1: int, S1: int, F2: int,
                           S2: int, *, bho: int, ibh: int, mho: int,
                           nt: int = 128, valid_rows, valid_cols,
                           relu1: bool = True, bias2=None, res=None,
                           res_layout: str = "CHWN",
                           epilogue: Epilogue = Epilogue(),
                           src_layout: str = "CHWN",
                           dst_layout: str = "CHWN",
                           interpret: bool = True):
    """Fused conv->conv stack, CHWN engine.

    x: [Ci, H, W, N] (or [N, Ci, H, W] for src NCHW) pre-padded by ops.py
    with ``pad1 + S1*pad2`` rows/cols per side plus the halo row block;
    w1: [Ci, F1, F1, Cm]; b1: [Cm, 1] f32 (conv1's epilogue is bias[+ReLU]
    only — anything richer keeps the stack unfused); w2: [Cm, F2, F2, Co];
    ``bias2``/``res``/``epilogue`` follow the single-conv protocol, applied
    to conv2.  ``valid_rows``/``valid_cols`` = (pad2, pad2 + Ho1/Wo1): the
    global mid range that is real y1 rather than conv2 zero padding.
    Result: [Co, Ho2', Wo2', N] (or NCHW for dst NCHW), post-pool heights
    when a pool epilogue is fused.
    """
    if src_layout == "NCHW":
        N, Ci, H, W = x.shape
    else:
        Ci, H, W, N = x.shape
    Cm, Co = w1.shape[-1], w2.shape[-1]
    S_eff, F_eff = S1 * S2, (F2 - 1) * S1 + F1
    Wm = conv_out_hw(W, F1, S1)
    Wo2 = conv_out_hw(Wm, F2, S2)
    IBH = ibh
    if IBH == bho * S_eff:
        n_ho = conv_out_hw(H, F_eff, S_eff) // bho
    else:
        n_ho = 1                  # ibh override: single row block by contract
        assert 2 * IBH >= (bho - 1) * S_eff + F_eff, (IBH, bho, S_eff, F_eff)
    assert 2 * IBH >= (mho - 1) * S1 + F1, (IBH, mho, S1, F1)

    obho, OWo = bho, Wo2
    if epilogue.pool is not None:
        pF, pS, _ = epilogue.pool
        assert pool_tiles_block(bho, n_ho, pF, pS), (bho, n_ho, pF, pS)
        obho = pool_out_hw(bho, pF, pS)
        OWo = pool_out_hw(Wo2, pF, pS)
    OHo = n_ho * obho

    if src_layout == "NCHW":
        in_specs = [
            pl.BlockSpec((nt, Ci, IBH, W), lambda h, n: (n, 0, h, 0)),
            pl.BlockSpec((nt, Ci, IBH, W), lambda h, n: (n, 0, h + 1, 0)),
        ]
    else:
        in_specs = [
            pl.BlockSpec((Ci, IBH, W, nt), lambda h, n: (0, h, 0, n)),
            pl.BlockSpec((Ci, IBH, W, nt), lambda h, n: (0, h + 1, 0, n)),
        ]
    in_specs += [
        pl.BlockSpec((Ci, F1, F1, Cm), lambda h, n: (0, 0, 0, 0)),
        pl.BlockSpec((Cm, 1), lambda h, n: (0, 0)),
        pl.BlockSpec((Cm, F2, F2, Co), lambda h, n: (0, 0, 0, 0)),
    ]
    operands = [x, x, w1, b1, w2]
    if epilogue.bias:
        assert bias2 is not None
        in_specs.append(pl.BlockSpec((Co, 1), lambda h, n: (0, 0)))
        operands.append(bias2)
    if epilogue.residual:
        assert res is not None
        if res_layout == "NCHW":
            in_specs.append(pl.BlockSpec((nt, Co, bho, Wo2),
                                         lambda h, n: (n, 0, h, 0)))
        else:
            in_specs.append(pl.BlockSpec((Co, bho, Wo2, nt),
                                         lambda h, n: (0, h, 0, n)))
        operands.append(res)

    odt = jnp.result_type(x.dtype, w1.dtype)
    if dst_layout == "NCHW":
        out_shape = jax.ShapeDtypeStruct((N, Co, OHo, OWo), odt)
        out_specs = pl.BlockSpec((nt, Co, obho, OWo),
                                 lambda h, n: (n, 0, h, 0))
    else:
        out_shape = jax.ShapeDtypeStruct((Co, OHo, OWo, N), odt)
        out_specs = pl.BlockSpec((Co, obho, OWo, nt),
                                 lambda h, n: (0, h, 0, n))

    kern = functools.partial(_stack_chwn_kernel, F1=F1, S1=S1, F2=F2, S2=S2,
                             bho=bho, mho=mho, Wm=Wm, Wo2=Wo2, relu1=relu1,
                             epilogue=epilogue, valid_rows=valid_rows,
                             valid_cols=valid_cols, src_layout=src_layout,
                             dst_layout=dst_layout, res_layout=res_layout)
    return pl.pallas_call(
        kern,
        out_shape=out_shape,
        grid=(n_ho, N // nt),
        in_specs=in_specs,
        out_specs=out_specs,
        interpret=interpret,
    )(*operands)


def conv_stack_nchw_pallas(x, w1, b1, w2, F1: int, S1: int, F2: int,
                           S2: int, *, bho: int, ibh: int, mho: int,
                           valid_rows, valid_cols, relu1: bool = True,
                           bias2=None, res=None, res_layout: str = "NCHW",
                           epilogue: Epilogue = Epilogue(),
                           src_layout: str = "NCHW",
                           dst_layout: str = "NCHW",
                           interpret: bool = True):
    """Fused conv->conv stack, per-sample NCHW (im2col-MM) engine.

    x: [N, Ci, H, W] (or [Ci, H, W, N] for src CHWN), pre-padded as in the
    CHWN variant; w1: [Cm, Ci, F1, F1], w2: [Co, Cm, F2, F2] (canonical);
    everything else mirrors ``conv_stack_chwn_pallas``.
    """
    if src_layout == "CHWN":
        Ci, H, W, N = x.shape
    else:
        N, Ci, H, W = x.shape
    Cm, Co = w1.shape[0], w2.shape[0]
    S_eff, F_eff = S1 * S2, (F2 - 1) * S1 + F1
    Wm = conv_out_hw(W, F1, S1)
    Wo2 = conv_out_hw(Wm, F2, S2)
    IBH = ibh
    if IBH == bho * S_eff:
        n_ho = conv_out_hw(H, F_eff, S_eff) // bho
    else:
        n_ho = 1
        assert 2 * IBH >= (bho - 1) * S_eff + F_eff, (IBH, bho, S_eff, F_eff)
    assert 2 * IBH >= (mho - 1) * S1 + F1, (IBH, mho, S1, F1)

    obho, OWo = bho, Wo2
    if epilogue.pool is not None:
        pF, pS, _ = epilogue.pool
        assert pool_tiles_block(bho, n_ho, pF, pS), (bho, n_ho, pF, pS)
        obho = pool_out_hw(bho, pF, pS)
        OWo = pool_out_hw(Wo2, pF, pS)
    OHo = n_ho * obho

    if src_layout == "CHWN":
        in_specs = [
            pl.BlockSpec((Ci, IBH, W, 1), lambda n, h: (0, h, 0, n)),
            pl.BlockSpec((Ci, IBH, W, 1), lambda n, h: (0, h + 1, 0, n)),
        ]
    else:
        in_specs = [
            pl.BlockSpec((1, Ci, IBH, W), lambda n, h: (n, 0, h, 0)),
            pl.BlockSpec((1, Ci, IBH, W), lambda n, h: (n, 0, h + 1, 0)),
        ]
    in_specs += [
        pl.BlockSpec((Cm, Ci, F1, F1), lambda n, h: (0, 0, 0, 0)),
        pl.BlockSpec((Cm, 1), lambda n, h: (0, 0)),
        pl.BlockSpec((Co, Cm, F2, F2), lambda n, h: (0, 0, 0, 0)),
    ]
    operands = [x, x, w1, b1, w2]
    if epilogue.bias:
        assert bias2 is not None
        in_specs.append(pl.BlockSpec((Co, 1), lambda n, h: (0, 0)))
        operands.append(bias2)
    if epilogue.residual:
        assert res is not None
        if res_layout == "CHWN":
            in_specs.append(pl.BlockSpec((Co, bho, Wo2, 1),
                                         lambda n, h: (0, h, 0, n)))
        else:
            in_specs.append(pl.BlockSpec((1, Co, bho, Wo2),
                                         lambda n, h: (n, 0, h, 0)))
        operands.append(res)

    odt = jnp.result_type(x.dtype, w1.dtype)
    if dst_layout == "CHWN":
        out_shape = jax.ShapeDtypeStruct((Co, OHo, OWo, N), odt)
        out_specs = pl.BlockSpec((Co, obho, OWo, 1),
                                 lambda n, h: (0, h, 0, n))
    else:
        out_shape = jax.ShapeDtypeStruct((N, Co, OHo, OWo), odt)
        out_specs = pl.BlockSpec((1, Co, obho, OWo),
                                 lambda n, h: (n, 0, h, 0))

    kern = functools.partial(_stack_nchw_kernel, F1=F1, S1=S1, F2=F2, S2=S2,
                             bho=bho, mho=mho, Wm=Wm, Wo2=Wo2, relu1=relu1,
                             epilogue=epilogue, valid_rows=valid_rows,
                             valid_cols=valid_cols, src_layout=src_layout,
                             dst_layout=dst_layout, res_layout=res_layout)
    return pl.pallas_call(
        kern,
        out_shape=out_shape,
        grid=(N, n_ho),
        in_specs=in_specs,
        out_specs=out_specs,
        interpret=interpret,
    )(*operands)
