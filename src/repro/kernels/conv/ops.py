"""Conv wrappers: direct-CHWN Pallas kernel + im2col/matmul NCHW path + FFT.

These are the paper's three convolution implementations, each bound to its
preferred layout (§II.B, §IV.A):
  * direct  (CHWN)  — cuda-convnet analogue, Pallas kernel;
  * im2col + MXU matmul (NCHW) — Caffe/cuDNN analogue;
  * FFT (NCHW) — cuDNN-FFT analogue (jnp.fft; XLA).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.conv.conv import conv_chwn_pallas
from repro.kernels.conv.ref import im2col_nchw
from repro.kernels.matmul.ops import matmul


def _pad_axis(x, axis, m):
    p = (-x.shape[axis]) % m
    if p:
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, p)
        x = jnp.pad(x, pad)
    return x


@partial(jax.jit, static_argnames=("stride", "pad", "interpret", "bho", "nt"))
def conv_direct_chwn(x, w, stride: int = 1, pad: int = 0, bho: int = 4,
                     nt: int = 128, interpret: bool = True):
    """Direct conv, CHWN: x [Ci,H,W,N], w [Ci,F,F,Co] -> [Co,Ho,Wo,N]."""
    Ci, H, W, N = x.shape
    F = w.shape[1]
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
        H, W = x.shape[1], x.shape[2]
    Ho = (H - F) // stride + 1
    Wo = (W - F) // stride + 1
    # halo trick uses exactly two row blocks: 2*bho*S >= (bho-1)*S + F
    min_bho = max(1, -(-(F - stride) // stride))
    cands = [d for d in range(1, Ho + 1) if Ho % d == 0 and d >= min_bho]
    bho = min(cands) if cands else Ho
    bho = max(bho, min(bho, Ho))
    nt = min(nt, max(N, 1))
    xn = _pad_axis(x, 3, nt)
    # halo block (j+1) must exist: pad rows by one extra input block
    IBH = bho * stride
    n_ho = Ho // bho
    need_rows = (n_ho + 1) * IBH
    if xn.shape[1] < need_rows:
        xn = _pad_axis(xn, 1, 1)  # no-op guard
        xn = jnp.pad(xn, ((0, 0), (0, need_rows - xn.shape[1]), (0, 0), (0, 0)))
    y = conv_chwn_pallas(xn, w, F, stride, bho=bho, nt=nt,
                         interpret=interpret)
    return y[:, :Ho, :Wo, :N]


@partial(jax.jit, static_argnames=("stride", "pad", "interpret", "use_pallas_mm"))
def conv_im2col_nchw(x, w, stride: int = 1, pad: int = 0,
                     interpret: bool = True, use_pallas_mm: bool = True):
    """im2col + matmul, NCHW: x [N,Ci,H,W], w [Co,Ci,F,F] -> [N,Co,Ho,Wo]."""
    N, Ci, H, W = x.shape
    Co, _, F, _ = w.shape
    patches, (n, Ho, Wo) = im2col_nchw(x, F, stride, pad)
    wmat = w.reshape(Co, Ci * F * F).T            # [CiFF, Co]
    if use_pallas_mm:
        out = matmul(patches, wmat, interpret=interpret)
    else:
        out = patches @ wmat
    return out.reshape(N, Ho, Wo, Co).transpose(0, 3, 1, 2)


@partial(jax.jit, static_argnames=("stride", "pad"))
def conv_fft_nchw(x, w, stride: int = 1, pad: int = 0):
    """FFT conv (NCHW): pads the filter to the image size, multiplies in the
    frequency domain (the paper's cuDNN-FFT mode; memory overhead included).
    Only exact for stride 1; strided layers subsample the full conv."""
    N, Ci, H, W = x.shape
    Co, _, F, _ = w.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
        H, W = x.shape[2], x.shape[3]
    Hf = H + F - 1
    Wf = W + F - 1
    xf = jnp.fft.rfft2(x.astype(jnp.float32), (Hf, Wf))          # [N,Ci,Hf,Wf']
    wf = jnp.fft.rfft2(w[:, :, ::-1, ::-1].astype(jnp.float32), (Hf, Wf))
    yf = jnp.einsum("nchw,ochw->nohw", xf, wf)
    y = jnp.fft.irfft2(yf, (Hf, Wf))
    y = y[:, :, F - 1:H, F - 1:W]                                # valid region
    if stride > 1:
        y = y[:, :, ::stride, ::stride]
    return y.astype(x.dtype)
