"""Conv wrappers: direct-CHWN Pallas kernel + im2col/matmul NCHW paths + FFT.

These are the paper's three convolution implementations, each bound to its
preferred layout (§II.B, §IV.A):
  * direct  (CHWN)  — cuda-convnet analogue, Pallas kernel;
  * im2col + MXU matmul (NCHW) — Caffe/cuDNN analogue.  Two forms: the
    native all-Pallas kernel (``conv_im2col_nchw_fused``, the default engine)
    and the seed's XLA-expansion + Pallas-matmul baseline
    (``conv_im2col_nchw``, kept for comparison);
  * FFT (NCHW) — cuDNN-FFT analogue (jnp.fft; XLA).

The two Pallas wrappers speak the fused-epilogue protocol (DESIGN.md §5):
``bias``/``relu``/``pool`` fold elementwise and pooling work into the conv's
output write, and ``src_layout``/``dst_layout`` make the kernel consume and
produce tensors in the neighbouring layers' layouts so no standalone
re-layout pass is needed.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.conv.conv import (Epilogue, conv_chwn_pallas,
                                     pool_tiles_block)
from repro.kernels.conv.im2col_mm import conv_nchw_pallas
from repro.kernels.conv.ref import im2col_nchw
from repro.kernels.matmul.ops import matmul


def _pad_axis(x, axis, m):
    p = (-x.shape[axis]) % m
    if p:
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, p)
        x = jnp.pad(x, pad)
    return x


def pick_bho(Ho: int, F: int, S: int,
             pool: Optional[Tuple[int, int, str]] = None) -> int:
    """Smallest output-row block: the halo trick needs 2*bho*S to cover one
    window span, and a fused pool additionally needs its windows to tile the
    block (falling back to one whole-height block, which always tiles)."""
    min_bho = max(1, -(-(F - S) // S))
    cands = [d for d in range(1, Ho + 1) if Ho % d == 0 and d >= min_bho]
    if pool is not None:
        pF, pS, _ = pool
        cands = [d for d in cands if pool_tiles_block(d, Ho // d, pF, pS)]
        if not cands:
            return Ho
    return min(cands) if cands else Ho


def _prep_rows(x, h_axis: int, need_rows: int):
    if x.shape[h_axis] < need_rows:
        pad = [(0, 0)] * x.ndim
        pad[h_axis] = (0, need_rows - x.shape[h_axis])
        x = jnp.pad(x, pad)
    return x


def _pad_channels(x, w, bias, ci_axes, co_axes, cit: int, cot: int):
    """Zero-pad Ci/Co to tile multiples: zero input channels contribute
    nothing and padded output channels are sliced off by the caller.
    ``ci_axes`` = (x axis, w axis) of Ci; ``co_axes`` = (w axis,) of Co."""
    x = _pad_axis(x, ci_axes[0], cit)
    w = _pad_axis(_pad_axis(w, ci_axes[1], cit), co_axes[0], cot)
    if bias is not None:
        bias = _pad_axis(bias, 0, cot)
    return x, w, bias


@partial(jax.jit, static_argnames=("stride", "pad", "interpret", "nt", "relu",
                                   "pool", "src_layout", "dst_layout"))
def conv_direct_chwn(x, w, stride: int = 1, pad: int = 0, nt: int = 128,
                     interpret: bool = True, *, bias=None, relu: bool = False,
                     pool: Optional[Tuple[int, int, str]] = None,
                     src_layout: str = "CHWN", dst_layout: str = "CHWN"):
    """Direct conv, CHWN engine: x [Ci,H,W,N] (or [N,Ci,H,W] for src NCHW),
    w [Ci,F,F,Co] -> [Co,Ho',Wo',N] (or NCHW for dst NCHW), with optional
    fused bias/ReLU/pool epilogue."""
    F = w.shape[1]
    if src_layout == "NCHW":
        N = x.shape[0]
        if pad:
            x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
        H, W = x.shape[2], x.shape[3]
        n_axis, h_axis = 0, 2
    else:
        N = x.shape[3]
        if pad:
            x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
        H, W = x.shape[1], x.shape[2]
        n_axis, h_axis = 3, 1
    Ho = (H - F) // stride + 1
    Wo = (W - F) // stride + 1
    Co = w.shape[-1]
    cit = min(w.shape[0], 32)
    cot = min(Co, 128)
    x, w, bias = _pad_channels(x, w, bias,
                               ci_axes=(1 if src_layout == "NCHW" else 0, 0),
                               co_axes=(3,), cit=cit, cot=cot)
    bho = pick_bho(Ho, F, stride, pool)
    nt = min(nt, max(N, 1))
    xn = _pad_axis(x, n_axis, nt)
    # halo block (j+1) must exist: pad rows by one extra input block.  When
    # the whole-height fallback gives bho < ceil((F-S)/S) (single row block),
    # widen the block so the two stitched blocks still cover the window span.
    IBH = max(bho * stride, -(-((bho - 1) * stride + F) // 2))
    n_ho = Ho // bho
    xn = _prep_rows(xn, h_axis, (n_ho + 1) * IBH)
    ep = Epilogue(bias=bias is not None, relu=relu, pool=pool)
    b2 = bias.reshape(-1, 1).astype(jnp.float32) if bias is not None else None
    y = conv_chwn_pallas(xn, w, F, stride, bho=bho, cit=cit, cot=cot, nt=nt,
                         ibh=IBH, bias=b2, epilogue=ep, src_layout=src_layout,
                         dst_layout=dst_layout, interpret=interpret)
    return y[:N, :Co] if dst_layout == "NCHW" else y[:Co, ..., :N]


@partial(jax.jit, static_argnames=("stride", "pad", "interpret", "relu",
                                   "pool", "src_layout", "dst_layout"))
def conv_im2col_nchw_fused(x, w, stride: int = 1, pad: int = 0,
                           interpret: bool = True, *, bias=None,
                           relu: bool = False,
                           pool: Optional[Tuple[int, int, str]] = None,
                           src_layout: str = "NCHW",
                           dst_layout: str = "NCHW"):
    """Native im2col-MM conv, NCHW engine: x [N,Ci,H,W] (or [Ci,H,W,N] for
    src CHWN), w canonical [Co,Ci,F,F] -> [N,Co,Ho',Wo'] (or CHWN for dst
    CHWN), with optional fused bias/ReLU/pool epilogue."""
    F = w.shape[2]
    if src_layout == "CHWN":
        if pad:
            x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
        H, W = x.shape[1], x.shape[2]
        h_axis = 1
    else:
        if pad:
            x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
        H, W = x.shape[2], x.shape[3]
        h_axis = 2
    Ho = (H - F) // stride + 1
    Co = w.shape[0]
    cit = min(w.shape[1], 32)
    cot = min(Co, 128)
    x, w, bias = _pad_channels(x, w, bias,
                               ci_axes=(0 if src_layout == "CHWN" else 1, 1),
                               co_axes=(0,), cit=cit, cot=cot)
    bho = pick_bho(Ho, F, stride, pool)
    IBH = max(bho * stride, -(-((bho - 1) * stride + F) // 2))
    n_ho = Ho // bho
    xn = _prep_rows(x, h_axis, (n_ho + 1) * IBH)
    ep = Epilogue(bias=bias is not None, relu=relu, pool=pool)
    b2 = bias.reshape(-1, 1).astype(jnp.float32) if bias is not None else None
    y = conv_nchw_pallas(xn, w, F, stride, bho=bho, cit=cit, cot=cot, ibh=IBH,
                         bias=b2, epilogue=ep, src_layout=src_layout,
                         dst_layout=dst_layout, interpret=interpret)
    return y[:Co] if dst_layout == "CHWN" else y[:, :Co]


@partial(jax.jit, static_argnames=("stride", "pad", "interpret", "use_pallas_mm"))
def conv_im2col_nchw(x, w, stride: int = 1, pad: int = 0,
                     interpret: bool = True, use_pallas_mm: bool = True):
    """im2col + matmul, NCHW: x [N,Ci,H,W], w [Co,Ci,F,F] -> [N,Co,Ho,Wo].
    The seed baseline: XLA materializes the patch matrix (the paper's
    'matrix expansion' traffic), only the matmul runs in Pallas."""
    N, Ci, H, W = x.shape
    Co, _, F, _ = w.shape
    patches, (n, Ho, Wo) = im2col_nchw(x, F, stride, pad)
    wmat = w.reshape(Co, Ci * F * F).T            # [CiFF, Co]
    if use_pallas_mm:
        out = matmul(patches, wmat, interpret=interpret)
    else:
        out = patches @ wmat
    return out.reshape(N, Ho, Wo, Co).transpose(0, 3, 1, 2)


@partial(jax.jit, static_argnames=("stride", "pad"))
def conv_fft_nchw(x, w, stride: int = 1, pad: int = 0):
    """FFT conv (NCHW): pads the filter to the image size, multiplies in the
    frequency domain (the paper's cuDNN-FFT mode; memory overhead included).
    Only exact for stride 1; strided layers subsample the full conv."""
    N, Ci, H, W = x.shape
    Co, _, F, _ = w.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
        H, W = x.shape[2], x.shape[3]
    Hf = H + F - 1
    Wf = W + F - 1
    xf = jnp.fft.rfft2(x.astype(jnp.float32), (Hf, Wf))          # [N,Ci,Hf,Wf']
    wf = jnp.fft.rfft2(w[:, :, ::-1, ::-1].astype(jnp.float32), (Hf, Wf))
    yf = jnp.einsum("nchw,ochw->nohw", xf, wf)
    y = jnp.fft.irfft2(yf, (Hf, Wf))
    y = y[:, :, F - 1:H, F - 1:W]                                # valid region
    if stride > 1:
        y = y[:, :, ::stride, ::stride]
    return y.astype(x.dtype)
