"""Conv wrappers: direct-CHWN Pallas kernel + im2col/matmul NCHW paths + FFT.

These are the paper's three convolution implementations, each bound to its
preferred layout (§II.B, §IV.A):
  * direct  (CHWN)  — cuda-convnet analogue, Pallas kernel;
  * im2col + MXU matmul (NCHW) — Caffe/cuDNN analogue.  Two forms: the
    native all-Pallas kernel (``conv_im2col_nchw_fused``, the default engine)
    and the seed's XLA-expansion + Pallas-matmul baseline
    (``conv_im2col_nchw``, kept for comparison);
  * FFT (NCHW) — cuDNN-FFT analogue (jnp.fft; XLA).

The two Pallas wrappers speak the fused-epilogue protocol (DESIGN.md §5):
``bias``/``relu``/``pool`` fold elementwise and pooling work into the conv's
output write, and ``src_layout``/``dst_layout`` make the kernel consume and
produce tensors in the neighbouring layers' layouts so no standalone
re-layout pass is needed.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.conv.conv import (Epilogue, conv_chwn_pallas,
                                     pool_tiles_block)
from repro.kernels.conv.im2col_mm import conv_nchw_pallas
from repro.kernels.conv.ref import im2col_nchw
from repro.kernels.conv.stack import (conv_stack_chwn_pallas,
                                      conv_stack_nchw_pallas)
from repro.kernels.matmul.ops import matmul
from repro.shapes import conv_out_hw


def _pad_axis(x, axis, m):
    p = (-x.shape[axis]) % m
    if p:
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, p)
        x = jnp.pad(x, pad)
    return x


def pick_bho(Ho: int, F: int, S: int,
             pool: Optional[Tuple[int, int, str]] = None) -> int:
    """Smallest output-row block: the halo trick needs 2*bho*S to cover one
    window span, and a fused pool additionally needs its windows to tile the
    block (falling back to one whole-height block, which always tiles)."""
    min_bho = max(1, -(-(F - S) // S))
    cands = [d for d in range(1, Ho + 1) if Ho % d == 0 and d >= min_bho]
    if pool is not None:
        pF, pS, _ = pool
        cands = [d for d in cands if pool_tiles_block(d, Ho // d, pF, pS)]
        if not cands:
            return Ho
    return min(cands) if cands else Ho


def conv_blocking(Ho: int, F: int, S: int,
                  pool: Optional[Tuple[int, int, str]] = None):
    """Row blocking shared by the conv forward engines and wgrad:
    (output row block, input row block, row-block count).  The halo trick
    needs the two stitched input blocks to cover one window span, so when
    the whole-height fallback gives bho below that bound the input block is
    widened: IBH = max(bho*S, ceil(((bho-1)*S + F)/2))."""
    bho = pick_bho(Ho, F, S, pool)
    IBH = max(bho * S, -(-((bho - 1) * S + F) // 2))
    return bho, IBH, Ho // bho


def stack_blocking(Ho2: int, F1: int, S1: int, F2: int, S2: int,
                   pool: Optional[Tuple[int, int, str]] = None):
    """Row blocking for a fused conv->conv stack (DESIGN.md §12): the stack
    is blocked as ONE virtual conv with the composite receptive field

        S_eff = S1*S2,  F_eff = (F2-1)*S1 + F1

    so ``conv_blocking`` gives (bho, IBH, n_ho) over the SECOND conv's
    output rows, and the halo-stitch invariant 2*IBH >= (bho-1)*S_eff +
    F_eff is exactly the input span that ``mho = (bho-1)*S2 + F2`` staged
    mid rows (conv1 outputs) need.  Returns (bho, IBH, n_ho, mho)."""
    S_eff, F_eff = S1 * S2, (F2 - 1) * S1 + F1
    bho, IBH, n_ho = conv_blocking(Ho2, F_eff, S_eff, pool)
    mho = (bho - 1) * S2 + F2
    assert 2 * IBH >= (mho - 1) * S1 + F1, (IBH, mho, S1, F1)
    return bho, IBH, n_ho, mho


def _prep_rows(x, h_axis: int, need_rows: int):
    if x.shape[h_axis] < need_rows:
        pad = [(0, 0)] * x.ndim
        pad[h_axis] = (0, need_rows - x.shape[h_axis])
        x = jnp.pad(x, pad)
    return x


def _pad_channels(x, w, bias, ci_axes, co_axes, cit: int, cot: int):
    """Zero-pad Ci/Co to tile multiples: zero input channels contribute
    nothing and padded output channels are sliced off by the caller.
    ``ci_axes`` = (x axis, w axis) of Ci; ``co_axes`` = (w axis,) of Co."""
    x = _pad_axis(x, ci_axes[0], cit)
    w = _pad_axis(_pad_axis(w, ci_axes[1], cit), co_axes[0], cot)
    if bias is not None:
        bias = _pad_axis(bias, 0, cot)
    return x, w, bias


def _kernel_rows(H_padded: int, F: int, S: int, bho: int, IBH: int) -> int:
    """Pre-pool output rows the kernel's grid will actually write: the
    engine re-derives its row-block count from the halo-padded input (one
    block when the ibh override is active), so grid-shaped side operands
    (the folded residual) must be padded to this height, not the true Ho."""
    if IBH != bho * S:
        return bho                      # ibh override: single row block
    return (conv_out_hw(H_padded, F, S) // bho) * bho


def _prep_res(res, res_layout: str, cot: int, nt: int, grid_rows: int):
    """Zero-pad the skip operand of a folded residual add to the kernel's
    grid: channels to the ``cot`` multiple, rows to the halo-padded
    row-block grid (which can exceed the true output height when F <= S),
    and N to the ``nt`` multiple when the engine blocks N.  Zeros are the
    additive identity and the spurious rows land in output rows the caller
    slices off, so padding never perturbs the result."""
    c_ax, h_ax, n_ax = (1, 2, 0) if res_layout == "NCHW" else (0, 1, 3)
    res = _pad_axis(res, c_ax, cot)
    if nt:
        res = _pad_axis(res, n_ax, nt)
    return _prep_rows(res, h_ax, grid_rows)


def _conv_chwn_core(x, w, bias, res, stride, pad, nt, interpret, relu, pool,
                    src_layout, dst_layout, res_layout: str = "CHWN",
                    save_act: bool = False):
    F = w.shape[1]
    if src_layout == "NCHW":
        N = x.shape[0]
        if pad:
            x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
        H, W = x.shape[2], x.shape[3]
        n_axis, h_axis = 0, 2
    else:
        N = x.shape[3]
        if pad:
            x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
        H, W = x.shape[1], x.shape[2]
        n_axis, h_axis = 3, 1
    Ho = conv_out_hw(H, F, stride)     # H/W already padded above
    Wo = conv_out_hw(W, F, stride)
    Co = w.shape[-1]
    cit = min(w.shape[0], 32)
    cot = min(Co, 128)
    x, w, bias = _pad_channels(x, w, bias,
                               ci_axes=(1 if src_layout == "NCHW" else 0, 0),
                               co_axes=(3,), cit=cit, cot=cot)
    bho, IBH, n_ho = conv_blocking(Ho, F, stride, pool)
    nt = min(nt, max(N, 1))
    xn = _pad_axis(x, n_axis, nt)
    # halo block (j+1) must exist: pad rows by one extra input block
    xn = _prep_rows(xn, h_axis, (n_ho + 1) * IBH)
    if res is not None:
        res = _prep_res(res, res_layout, cot, nt,
                        _kernel_rows(xn.shape[h_axis], F, stride, bho, IBH))
    ep = Epilogue(bias=bias is not None, relu=relu, pool=pool,
                  residual=res is not None)
    b2 = bias.reshape(-1, 1).astype(jnp.float32) if bias is not None else None
    y = conv_chwn_pallas(xn, w, F, stride, bho=bho, cit=cit, cot=cot, nt=nt,
                         ibh=IBH, bias=b2, res=res, res_layout=res_layout,
                         epilogue=ep, src_layout=src_layout,
                         dst_layout=dst_layout, save_act=save_act,
                         interpret=interpret)
    # the engine recomputes its row count from the halo-padded input, which
    # gains spurious row blocks when F <= S: slice back to the true height
    obho = bho if pool is None else (bho - pool[0]) // pool[1] + 1
    OHo = n_ho * obho
    if save_act:
        y, z = y
        z = z[:Co, :n_ho * bho, :, :N]   # pre-pool act, native CHWN
    else:
        z = None
    y = (y[:N, :Co, :OHo] if dst_layout == "NCHW"
         else y[:Co, :OHo, :, :N])
    return y, z


def _conv_bwd(prims, g, *, layout, stride, pad, interpret, relu, pool,
              src_layout, dst_layout, res_layout="CHWN"):
    """Shared VJP body for both conv engines.

    ``x``/``w``/``bias`` enter in the engine's native forms; ``g`` arrives in
    ``dst_layout``.  The reversed re-layout chain folds into kernel I/O maps:
    pool backward consumes ``g`` in ``dst_layout`` directly and the dgrad
    engine writes dx straight in ``src_layout``.  Residual ``z`` (pre-pool
    post-relu activation, compute layout) was stashed by the forward kernel's
    ``save_act`` epilogue — no recompute pass.

    A folded skip add (``skip`` is not None) fans the gradient out: the
    post-relu-mask/pool-backward gradient IS d(skip) up to a re-layout,
    because the add sits right before the ReLU in the epilogue order.
    """
    from repro.kernels.conv.backward import bias_grad, conv_dgrad, conv_wgrad
    from repro.kernels.pool.backward import pool_backward
    x, w, bias, skip, y, z = prims
    if layout == "CHWN":
        w_oihw = jnp.transpose(w, (3, 0, 1, 2))
        F = w.shape[1]
    else:
        w_oihw = w
        F = w.shape[2]
    if src_layout == "NCHW":
        x_hw = (x.shape[2], x.shape[3])
    else:
        x_hw = (x.shape[1], x.shape[2])
    if pool is not None:
        # one kernel: route g through the max-mask/avg-scatter AND apply the
        # relu mask (z is in VMEM for the mask anyway)
        ga = pool_backward(z, g, pool[0], pool[1], pool[2], layout=layout,
                           g_layout=dst_layout, relu_mask=relu,
                           interpret=interpret)
        g_lay = layout
    else:
        ga = g * (y > 0).astype(g.dtype) if relu else g
        g_lay = dst_layout
    dx = conv_dgrad(ga, w_oihw, x_hw, stride, pad, layout=layout,
                    g_layout=g_lay, dst_layout=src_layout,
                    interpret=interpret)
    dw_oihw = conv_wgrad(x, ga, F, stride, pad, x_layout=src_layout,
                         g_layout=g_lay, interpret=interpret)
    dw = (jnp.transpose(dw_oihw, (1, 2, 3, 0)) if layout == "CHWN"
          else dw_oihw)
    db = None
    if bias is not None:
        db = bias_grad(ga, g_lay).astype(bias.dtype)
    dskip = None
    if skip is not None:
        from repro.core.transform import apply_transform
        dskip = apply_transform(ga, g_lay, res_layout).astype(skip.dtype)
    return dx.astype(x.dtype), dw.astype(w.dtype), db, dskip


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9, 10, 11, 12))
def _conv_chwn_vjp(x, w, bias, res, stride, pad, nt, interpret, relu, pool,
                   src_layout, dst_layout, res_layout):
    return _conv_chwn_core(x, w, bias, res, stride, pad, nt, interpret, relu,
                           pool, src_layout, dst_layout, res_layout)[0]


def _conv_chwn_fwd(x, w, bias, res, stride, pad, nt, interpret, relu, pool,
                   src_layout, dst_layout, res_layout):
    y, z = _conv_chwn_core(x, w, bias, res, stride, pad, nt, interpret, relu,
                           pool, src_layout, dst_layout, res_layout,
                           save_act=pool is not None)
    return y, (x, w, bias, res, y, z)


def _conv_chwn_bwd(stride, pad, nt, interpret, relu, pool, src_layout,
                   dst_layout, res_layout, prims, g):
    return _conv_bwd(prims, g, layout="CHWN", stride=stride, pad=pad,
                     interpret=interpret, relu=relu, pool=pool,
                     src_layout=src_layout, dst_layout=dst_layout,
                     res_layout=res_layout)


_conv_chwn_vjp.defvjp(_conv_chwn_fwd, _conv_chwn_bwd)


@partial(jax.jit, static_argnames=("stride", "pad", "interpret", "nt", "relu",
                                   "pool", "src_layout", "dst_layout",
                                   "res_layout"))
def conv_direct_chwn(x, w, stride: int = 1, pad: int = 0, nt: int = 128,
                     interpret: bool = True, *, bias=None, relu: bool = False,
                     pool: Optional[Tuple[int, int, str]] = None,
                     res=None, res_layout: str = "CHWN",
                     src_layout: str = "CHWN", dst_layout: str = "CHWN"):
    """Direct conv, CHWN engine: x [Ci,H,W,N] (or [N,Ci,H,W] for src NCHW),
    w [Ci,F,F,Co] -> [Co,Ho',Wo',N] (or NCHW for dst NCHW), with optional
    fused bias/residual-add/ReLU/pool epilogue (``res`` is the skip tensor,
    stored in ``res_layout``).  Differentiable: a custom VJP routes the
    backward pass through the layout-aware dgrad/wgrad Pallas engines and
    fans the gradient out to the skip branch when a residual is folded."""
    return _conv_chwn_vjp(x, w, bias, res, stride, pad, nt, interpret, relu,
                          pool, src_layout, dst_layout, res_layout)


def _conv_nchw_core(x, w, bias, res, stride, pad, interpret, relu, pool,
                    src_layout, dst_layout, res_layout: str = "NCHW",
                    save_act: bool = False):
    F = w.shape[2]
    if src_layout == "CHWN":
        N = x.shape[3]
        if pad:
            x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
        H, W = x.shape[1], x.shape[2]
        h_axis = 1
    else:
        N = x.shape[0]
        if pad:
            x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
        H, W = x.shape[2], x.shape[3]
        h_axis = 2
    Ho = conv_out_hw(H, F, stride)     # H already padded above
    Co = w.shape[0]
    cit = min(w.shape[1], 32)
    cot = min(Co, 128)
    x, w, bias = _pad_channels(x, w, bias,
                               ci_axes=(0 if src_layout == "CHWN" else 1, 1),
                               co_axes=(0,), cit=cit, cot=cot)
    bho, IBH, n_ho = conv_blocking(Ho, F, stride, pool)
    xn = _prep_rows(x, h_axis, (n_ho + 1) * IBH)
    if res is not None:
        res = _prep_res(res, res_layout, cot, 0,
                        _kernel_rows(xn.shape[h_axis], F, stride, bho, IBH))
    ep = Epilogue(bias=bias is not None, relu=relu, pool=pool,
                  residual=res is not None)
    b2 = bias.reshape(-1, 1).astype(jnp.float32) if bias is not None else None
    y = conv_nchw_pallas(xn, w, F, stride, bho=bho, cit=cit, cot=cot, ibh=IBH,
                         bias=b2, res=res, res_layout=res_layout,
                         epilogue=ep, src_layout=src_layout,
                         dst_layout=dst_layout, save_act=save_act,
                         interpret=interpret)
    # slice off spurious row blocks from the halo padding (F <= S cases)
    obho = bho if pool is None else (bho - pool[0]) // pool[1] + 1
    OHo = n_ho * obho
    if save_act:
        y, z = y
        z = z[:, :Co, :n_ho * bho]       # pre-pool act, native NCHW
    else:
        z = None
    y = y[:Co, :OHo] if dst_layout == "CHWN" else y[:, :Co, :OHo]
    return y, z


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9, 10, 11))
def _conv_nchw_vjp(x, w, bias, res, stride, pad, interpret, relu, pool,
                   src_layout, dst_layout, res_layout):
    return _conv_nchw_core(x, w, bias, res, stride, pad, interpret, relu,
                           pool, src_layout, dst_layout, res_layout)[0]


def _conv_nchw_fwd(x, w, bias, res, stride, pad, interpret, relu, pool,
                   src_layout, dst_layout, res_layout):
    y, z = _conv_nchw_core(x, w, bias, res, stride, pad, interpret, relu,
                           pool, src_layout, dst_layout, res_layout,
                           save_act=pool is not None)
    return y, (x, w, bias, res, y, z)


def _conv_nchw_bwd(stride, pad, interpret, relu, pool, src_layout,
                   dst_layout, res_layout, prims, g):
    return _conv_bwd(prims, g, layout="NCHW", stride=stride, pad=pad,
                     interpret=interpret, relu=relu, pool=pool,
                     src_layout=src_layout, dst_layout=dst_layout,
                     res_layout=res_layout)


_conv_nchw_vjp.defvjp(_conv_nchw_fwd, _conv_nchw_bwd)


@partial(jax.jit, static_argnames=("stride", "pad", "interpret", "relu",
                                   "pool", "src_layout", "dst_layout",
                                   "res_layout"))
def conv_im2col_nchw_fused(x, w, stride: int = 1, pad: int = 0,
                           interpret: bool = True, *, bias=None,
                           relu: bool = False,
                           pool: Optional[Tuple[int, int, str]] = None,
                           res=None, res_layout: str = "NCHW",
                           src_layout: str = "NCHW",
                           dst_layout: str = "NCHW"):
    """Native im2col-MM conv, NCHW engine: x [N,Ci,H,W] (or [Ci,H,W,N] for
    src CHWN), w canonical [Co,Ci,F,F] -> [N,Co,Ho',Wo'] (or CHWN for dst
    CHWN), with optional fused bias/residual-add/ReLU/pool epilogue (``res``
    is the skip tensor, stored in ``res_layout``).  Differentiable via the
    same custom-VJP machinery as the CHWN engine."""
    return _conv_nchw_vjp(x, w, bias, res, stride, pad, interpret, relu,
                          pool, src_layout, dst_layout, res_layout)


# ---------------------------------------------------------------------------
# fused conv->conv stacks (DESIGN.md §12): the mid activation never leaves
# VMEM; conv1 runs on a halo-widened block, conv2's full epilogue applies
# ---------------------------------------------------------------------------

def _stack_core(x, w1, b1, w2, b2, res, stride1, pad1, stride2, pad2, nt,
                interpret, relu1, relu2, pool, src_layout, dst_layout,
                res_layout, engine):
    """Shared stack wrapper: pads (conv1 padding + conv2 padding pulled to
    the input at stride1 scale + halo block), derives the composite blocking,
    dispatches to the engine kernel, and slices the spurious halo rows."""
    if engine == "CHWN":
        F1, F2 = w1.shape[1], w2.shape[1]
        Cm, Co = w1.shape[-1], w2.shape[-1]
    else:
        F1, F2 = w1.shape[2], w2.shape[2]
        Cm, Co = w1.shape[0], w2.shape[0]
    P = pad1 + stride1 * pad2        # conv2 padding folded to the input
    if src_layout == "NCHW":
        N = x.shape[0]
        H0, W0 = x.shape[2], x.shape[3]
        if P:
            x = jnp.pad(x, ((0, 0), (0, 0), (P, P), (P, P)))
        h_axis, n_axis = 2, 0
    else:
        N = x.shape[3]
        H0, W0 = x.shape[1], x.shape[2]
        if P:
            x = jnp.pad(x, ((0, 0), (P, P), (P, P), (0, 0)))
        h_axis, n_axis = 1, 3
    Ho1 = conv_out_hw(H0 + 2 * pad1, F1, stride1)
    Wo1 = conv_out_hw(W0 + 2 * pad1, F1, stride1)
    Ho2 = conv_out_hw(Ho1 + 2 * pad2, F2, stride2)
    bho, IBH, n_ho, mho = stack_blocking(Ho2, F1, stride1, F2, stride2, pool)
    S_eff, F_eff = stride1 * stride2, (F2 - 1) * stride1 + F1
    xn = x
    if engine == "CHWN":
        nt = min(nt, max(N, 1))
        xn = _pad_axis(xn, n_axis, nt)
    xn = _prep_rows(xn, h_axis, (n_ho + 1) * IBH)
    if res is not None:
        res = _prep_res(res, res_layout, 1, nt if engine == "CHWN" else 0,
                        _kernel_rows(xn.shape[h_axis], F_eff, S_eff,
                                     bho, IBH))
    ep = Epilogue(bias=b2 is not None, relu=relu2, pool=pool,
                  residual=res is not None)
    b1v = (b1 if b1 is not None else jnp.zeros((Cm,)))
    b1v = b1v.reshape(-1, 1).astype(jnp.float32)
    b2v = b2.reshape(-1, 1).astype(jnp.float32) if b2 is not None else None
    valid = ((pad2, pad2 + Ho1), (pad2, pad2 + Wo1))
    if engine == "CHWN":
        y = conv_stack_chwn_pallas(
            xn, w1, b1v, w2, F1, stride1, F2, stride2, bho=bho, ibh=IBH,
            mho=mho, nt=nt, valid_rows=valid[0], valid_cols=valid[1],
            relu1=relu1, bias2=b2v, res=res, res_layout=res_layout,
            epilogue=ep, src_layout=src_layout, dst_layout=dst_layout,
            interpret=interpret)
    else:
        y = conv_stack_nchw_pallas(
            xn, w1, b1v, w2, F1, stride1, F2, stride2, bho=bho, ibh=IBH,
            mho=mho, valid_rows=valid[0], valid_cols=valid[1],
            relu1=relu1, bias2=b2v, res=res, res_layout=res_layout,
            epilogue=ep, src_layout=src_layout, dst_layout=dst_layout,
            interpret=interpret)
    obho = bho if pool is None else (bho - pool[0]) // pool[1] + 1
    OHo = (Ho2 // bho) * obho
    return (y[:N, :Co, :OHo] if dst_layout == "NCHW"
            else y[:Co, :OHo, :, :N])


def _stack_bwd_unfused(prims, g, *, engine, stride1, pad1, stride2, pad2,
                       nt, interpret, relu1, relu2, pool, src_layout,
                       dst_layout, res_layout):
    """Stack backward = VJP of the UNFUSED two-conv composition: y1 is
    recomputed with one fused conv1 call (gradient-checkpoint style) and the
    gradient then flows through the existing layout-aware single-conv custom
    VJPs (Pallas dgrad/wgrad/pool-backward) — fused-forward memory wins,
    unfused-backward correctness (DESIGN.md §12)."""
    x, w1, b1, w2, b2, res = prims
    conv = (conv_direct_chwn if engine == "CHWN" else conv_im2col_nchw_fused)
    kw1 = dict(stride=stride1, pad=pad1, interpret=interpret, relu=relu1,
               src_layout=src_layout, dst_layout=engine)
    kw2 = dict(stride=stride2, pad=pad2, interpret=interpret, relu=relu2,
               pool=pool, res_layout=res_layout, src_layout=engine,
               dst_layout=dst_layout)
    if engine == "CHWN":
        kw1["nt"] = kw2["nt"] = nt

    diff = {"x": x, "w1": w1, "w2": w2}
    for k, v in (("b1", b1), ("b2", b2), ("res", res)):
        if v is not None:
            diff[k] = v

    def unfused(d):
        y1 = conv(d["x"], d["w1"], bias=d.get("b1"), **kw1)
        return conv(y1, d["w2"], bias=d.get("b2"), res=d.get("res"), **kw2)

    _, vjp = jax.vjp(unfused, diff)
    (gd,) = vjp(g)
    return (gd["x"], gd["w1"], gd.get("b1"), gd["w2"], gd.get("b2"),
            gd.get("res"))


@partial(jax.custom_vjp, nondiff_argnums=tuple(range(6, 18)))
def _stack_chwn_vjp(x, w1, b1, w2, b2, res, stride1, pad1, stride2, pad2,
                    nt, interpret, relu1, relu2, pool, src_layout,
                    dst_layout, res_layout):
    return _stack_core(x, w1, b1, w2, b2, res, stride1, pad1, stride2, pad2,
                       nt, interpret, relu1, relu2, pool, src_layout,
                       dst_layout, res_layout, "CHWN")


def _stack_chwn_fwd(x, w1, b1, w2, b2, res, stride1, pad1, stride2, pad2,
                    nt, interpret, relu1, relu2, pool, src_layout,
                    dst_layout, res_layout):
    y = _stack_core(x, w1, b1, w2, b2, res, stride1, pad1, stride2, pad2,
                    nt, interpret, relu1, relu2, pool, src_layout,
                    dst_layout, res_layout, "CHWN")
    return y, (x, w1, b1, w2, b2, res)


def _stack_chwn_bwd(stride1, pad1, stride2, pad2, nt, interpret, relu1,
                    relu2, pool, src_layout, dst_layout, res_layout,
                    prims, g):
    return _stack_bwd_unfused(prims, g, engine="CHWN", stride1=stride1,
                              pad1=pad1, stride2=stride2, pad2=pad2, nt=nt,
                              interpret=interpret, relu1=relu1, relu2=relu2,
                              pool=pool, src_layout=src_layout,
                              dst_layout=dst_layout, res_layout=res_layout)


_stack_chwn_vjp.defvjp(_stack_chwn_fwd, _stack_chwn_bwd)


@partial(jax.custom_vjp, nondiff_argnums=tuple(range(6, 18)))
def _stack_nchw_vjp(x, w1, b1, w2, b2, res, stride1, pad1, stride2, pad2,
                    nt, interpret, relu1, relu2, pool, src_layout,
                    dst_layout, res_layout):
    return _stack_core(x, w1, b1, w2, b2, res, stride1, pad1, stride2, pad2,
                       nt, interpret, relu1, relu2, pool, src_layout,
                       dst_layout, res_layout, "NCHW")


def _stack_nchw_fwd(x, w1, b1, w2, b2, res, stride1, pad1, stride2, pad2,
                    nt, interpret, relu1, relu2, pool, src_layout,
                    dst_layout, res_layout):
    y = _stack_core(x, w1, b1, w2, b2, res, stride1, pad1, stride2, pad2,
                    nt, interpret, relu1, relu2, pool, src_layout,
                    dst_layout, res_layout, "NCHW")
    return y, (x, w1, b1, w2, b2, res)


def _stack_nchw_bwd(stride1, pad1, stride2, pad2, nt, interpret, relu1,
                    relu2, pool, src_layout, dst_layout, res_layout,
                    prims, g):
    return _stack_bwd_unfused(prims, g, engine="NCHW", stride1=stride1,
                              pad1=pad1, stride2=stride2, pad2=pad2, nt=nt,
                              interpret=interpret, relu1=relu1, relu2=relu2,
                              pool=pool, src_layout=src_layout,
                              dst_layout=dst_layout, res_layout=res_layout)


_stack_nchw_vjp.defvjp(_stack_nchw_fwd, _stack_nchw_bwd)


@partial(jax.jit, static_argnames=("stride1", "pad1", "stride2", "pad2",
                                   "nt", "interpret", "relu1", "relu2",
                                   "pool", "src_layout", "dst_layout",
                                   "res_layout"))
def conv_stack_chwn(x, w1, w2, stride1: int = 1, pad1: int = 0,
                    stride2: int = 1, pad2: int = 0, nt: int = 128,
                    interpret: bool = True, *, bias1=None, bias2=None,
                    relu1: bool = True, relu2: bool = False,
                    pool: Optional[Tuple[int, int, str]] = None,
                    res=None, res_layout: str = "CHWN",
                    src_layout: str = "CHWN", dst_layout: str = "CHWN"):
    """Fused conv->conv stack, CHWN engine: x [Ci,H,W,N] (or [N,Ci,H,W] for
    src NCHW), w1 [Ci,F1,F1,Cm], w2 [Cm,F2,F2,Co] -> [Co,Ho2',Wo2',N] (or
    NCHW for dst NCHW).  Conv1 carries a bias[+ReLU]-only epilogue; conv2
    takes the full bias/residual-add/ReLU/pool protocol.  The intermediate
    activation stays in VMEM.  Differentiable: the custom VJP replays the
    unfused two-conv composition (see ``_stack_bwd_unfused``)."""
    return _stack_chwn_vjp(x, w1, bias1, w2, bias2, res, stride1, pad1,
                           stride2, pad2, nt, interpret, relu1, relu2, pool,
                           src_layout, dst_layout, res_layout)


@partial(jax.jit, static_argnames=("stride1", "pad1", "stride2", "pad2",
                                   "interpret", "relu1", "relu2", "pool",
                                   "src_layout", "dst_layout", "res_layout"))
def conv_stack_nchw(x, w1, w2, stride1: int = 1, pad1: int = 0,
                    stride2: int = 1, pad2: int = 0,
                    interpret: bool = True, *, bias1=None, bias2=None,
                    relu1: bool = True, relu2: bool = False,
                    pool: Optional[Tuple[int, int, str]] = None,
                    res=None, res_layout: str = "NCHW",
                    src_layout: str = "NCHW", dst_layout: str = "NCHW"):
    """Fused conv->conv stack, per-sample im2col-MM NCHW engine: x
    [N,Ci,H,W] (or [Ci,H,W,N] for src CHWN), w1 [Cm,Ci,F1,F1], w2
    [Co,Cm,F2,F2] (canonical) -> [N,Co,Ho2',Wo2'] (or CHWN for dst CHWN);
    otherwise identical to ``conv_stack_chwn``."""
    return _stack_nchw_vjp(x, w1, bias1, w2, bias2, res, stride1, pad1,
                           stride2, pad2, 0, interpret, relu1, relu2, pool,
                           src_layout, dst_layout, res_layout)


@partial(jax.jit, static_argnames=("stride", "pad", "interpret", "use_pallas_mm"))
def conv_im2col_nchw(x, w, stride: int = 1, pad: int = 0,
                     interpret: bool = True, use_pallas_mm: bool = True):
    """im2col + matmul, NCHW: x [N,Ci,H,W], w [Co,Ci,F,F] -> [N,Co,Ho,Wo].
    The seed baseline: XLA materializes the patch matrix (the paper's
    'matrix expansion' traffic), only the matmul runs in Pallas."""
    N, Ci, H, W = x.shape
    Co, _, F, _ = w.shape
    patches, (n, Ho, Wo) = im2col_nchw(x, F, stride, pad)
    wmat = w.reshape(Co, Ci * F * F).T            # [CiFF, Co]
    if use_pallas_mm:
        out = matmul(patches, wmat, interpret=interpret)
    else:
        out = patches @ wmat
    return out.reshape(N, Ho, Wo, Co).transpose(0, 3, 1, 2)


@partial(jax.jit, static_argnames=("stride", "pad"))
def conv_fft_nchw(x, w, stride: int = 1, pad: int = 0):
    """FFT conv (NCHW): pads the filter to the image size, multiplies in the
    frequency domain (the paper's cuDNN-FFT mode; memory overhead included).
    Only exact for stride 1; strided layers subsample the full conv."""
    N, Ci, H, W = x.shape
    Co, _, F, _ = w.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
        H, W = x.shape[2], x.shape[3]
    Hf = H + F - 1
    Wf = W + F - 1
    xf = jnp.fft.rfft2(x.astype(jnp.float32), (Hf, Wf))          # [N,Ci,Hf,Wf']
    wf = jnp.fft.rfft2(w[:, :, ::-1, ::-1].astype(jnp.float32), (Hf, Wf))
    yf = jnp.einsum("nchw,ochw->nohw", xf, wf)
    y = jnp.fft.irfft2(yf, (Hf, Wf))
    y = y[:, :, F - 1:H, F - 1:W]                                # valid region
    if stride > 1:
        y = y[:, :, ::stride, ::stride]
    return y.astype(x.dtype)
