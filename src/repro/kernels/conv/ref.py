"""Pure-jnp conv oracles in both layouts + the im2col formulation."""
import jax.numpy as jnp
from jax import lax


def conv_nchw_ref(x, w, stride: int = 1, pad: int = 0):
    """x: [N, Ci, H, W]; w: [Co, Ci, F, F] -> [N, Co, Ho, Wo]."""
    return lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW")).astype(x.dtype)


def conv_chwn_ref(x, w, stride: int = 1, pad: int = 0):
    """x: [Ci, H, W, N]; w: [Ci, F, F, Co] -> [Co, Ho, Wo, N]."""
    xn = jnp.transpose(x, (3, 0, 1, 2))
    wn = jnp.transpose(w, (3, 0, 1, 2))
    y = conv_nchw_ref(xn, wn, stride, pad)
    return jnp.transpose(y, (1, 2, 3, 0))


def im2col_nchw(x, F: int, stride: int = 1, pad: int = 0):
    """x: [N, Ci, H, W] -> patches [N*Ho*Wo, Ci*F*F] (the paper's 'matrix
    expansion' used by the NCHW/matmul path)."""
    N, Ci, H, W = x.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    Ho = (x.shape[2] - F) // stride + 1
    Wo = (x.shape[3] - F) // stride + 1
    cols = []
    for dy in range(F):
        for dx in range(F):
            cols.append(x[:, :, dy:dy + (Ho - 1) * stride + 1:stride,
                          dx:dx + (Wo - 1) * stride + 1:stride])
    patches = jnp.stack(cols, axis=2)              # [N, Ci, F*F, Ho, Wo]
    patches = patches.transpose(0, 3, 4, 1, 2)     # [N, Ho, Wo, Ci, F*F]
    return patches.reshape(N * Ho * Wo, Ci * F * F), (N, Ho, Wo)
