"""Direct convolution Pallas kernel in the CHWN layout (the cuda-convnet
analogue the paper pairs with CHWN).

Formulation: for each output-row block, the contraction
    out[co, ho, wo, n] += x[ci, ho*S+dy, wo*S+dx, n] * w[ci, dy, dx, co]
is an MXU matmul over ci with N on the 128 lanes — the CHWN layout's
coalescing dim becomes the MXU minor dim with zero re-layout (the paper's
§IV.A observation, TPU-native).

Blocking: grid (Ho blocks, Co blocks, N blocks, Ci blocks) with Ci innermost
(sequential accumulation into a VMEM f32 scratch).  Overlapping input rows
(stride/halo) are handled by passing the input twice with consecutive
row-block indices — the halo-stitch trick — so BlockSpec offsets stay
aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _conv_kernel(xa_ref, xb_ref, w_ref, o_ref, acc_ref, *,
                 F, S, bho, Wo, n_ci):
    @pl.when(pl.program_id(3) == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xa = xa_ref[...]                     # [cit, IBH, W, nt]
    xb = xb_ref[...]
    x2 = jnp.concatenate([xa, xb], axis=1)      # rows j*IBH .. j*IBH+2*IBH
    w = w_ref[...]                       # [cit, F, F, cot]

    acc = acc_ref[...]
    for dy in range(F):
        for dx in range(F):
            xs = x2[:, dy:dy + (bho - 1) * S + 1:S,
                    dx:dx + (Wo - 1) * S + 1:S, :]      # [cit,bho,Wo,nt]
            acc = acc + jnp.einsum(
                "chwn,ck->khwn", xs, w[:, dy, dx, :],
                preferred_element_type=jnp.float32)
    acc_ref[...] = acc

    @pl.when(pl.program_id(3) == n_ci - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def conv_chwn_pallas(x, w, F: int, S: int, *, bho: int = 4, cot: int = 0,
                     cit: int = 0, nt: int = 128, interpret: bool = True):
    """x: [Ci, H, W, N]; w: [Ci, F, F, Co] -> [Co, Ho, Wo, N].

    Requirements (ops.py pads): N % nt == 0, Co % cot == 0, Ci % cit == 0,
    Ho % bho == 0, and H >= (number of row blocks)*IBH with IBH = bho*S.
    """
    Ci, H, W, N = x.shape
    Co = w.shape[-1]
    Ho = (H - F) // S + 1
    Wo = (W - F) // S + 1
    cot = cot or min(Co, 128)
    cit = cit or min(Ci, 32)
    IBH = bho * S
    n_ci = Ci // cit
    n_ho = Ho // bho
    # the "j+1" halo block must stay in range: pad H so (n_ho)*IBH+IBH <= Hp
    kern = functools.partial(_conv_kernel, F=F, S=S, bho=bho, Wo=Wo, n_ci=n_ci)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((Co, Ho, Wo, N), x.dtype),
        grid=(n_ho, Co // cot, N // nt, n_ci),
        in_specs=[
            pl.BlockSpec((cit, IBH, W, nt), lambda h, c, n, k: (k, h, 0, n)),
            pl.BlockSpec((cit, IBH, W, nt),
                         lambda h, c, n, k: (k, h + 1, 0, n)),
            pl.BlockSpec((cit, F, F, cot), lambda h, c, n, k: (k, 0, 0, c)),
        ],
        out_specs=pl.BlockSpec((cot, bho, Wo, nt),
                               lambda h, c, n, k: (c, h, 0, n)),
        scratch_shapes=[pltpu.VMEM((cot, bho, Wo, nt), jnp.float32)],
        interpret=interpret,
    )(x, x, w)
