"""Direct convolution Pallas kernel in the CHWN layout (the cuda-convnet
analogue the paper pairs with CHWN), with a fused epilogue protocol.

Formulation: for each output-row block, the contraction
    out[co, ho, wo, n] += x[ci, ho*S+dy, wo*S+dx, n] * w[ci, dy, dx, co]
is an MXU matmul over ci with N on the 128 lanes — the CHWN layout's
coalescing dim becomes the MXU minor dim with zero re-layout (the paper's
§IV.A observation, TPU-native).

Blocking: grid (Ho blocks, Co blocks, N blocks, Ci blocks) with Ci innermost
(sequential accumulation into a VMEM f32 scratch).  Overlapping input rows
(stride/halo) are handled by passing the input twice with consecutive
row-block indices — the halo-stitch trick — so BlockSpec offsets stay
aligned.

Fusion (DESIGN.md §5): on the last Ci step the epilogue runs on the f32
accumulator while it still lives in VMEM — bias add, ReLU, and (when the
pool window tiles the output row block) max/avg pooling — and the result is
written directly in the *consumer's* layout via the out BlockSpec index map
(``dst_layout``).  The kernel can likewise consume its input in the
producer's layout (``src_layout``), so a conv absorbs the re-layout on both
sides and the conv->relu->pool intermediate never touches HBM.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.shapes import conv_out_hw, pool_out_hw


@dataclass(frozen=True)
class Epilogue:
    """What the conv kernel folds into its final VMEM->HBM write.

    ``pool`` is ``(F, S, op)`` with op in {"max", "avg"}; it is only legal
    when the pool windows tile the conv-output row block (see
    ``pool_tiles_block``) so no window crosses a grid-block boundary.
    ``residual`` folds a skip-tensor add onto the VMEM accumulator (after
    bias, before ReLU — the ResNet epilogue order); the skip arrives through
    a second layout-folding input BlockSpec, so the standalone add AND its
    operand re-layout both vanish from HBM traffic (DESIGN.md §11).
    """
    bias: bool = False
    relu: bool = False
    pool: Optional[Tuple[int, int, str]] = None
    residual: bool = False


def pool_tiles_block(bho: int, n_ho: int, pF: int, pS: int) -> bool:
    """True when every pool window lies inside one conv-output row block:
    either one block covers the whole height, or the block height is a
    multiple of the pool stride and windows don't overlap block seams."""
    if pF > bho:
        return False
    return n_ho == 1 or (bho % pS == 0 and pF <= pS)


def pool_block(y, pF: int, pS: int, op: str):
    """Pool dims (1, 2) of ``y`` ([C, H, W] or [C, H, W, N]) in VMEM."""
    bho, wo = y.shape[1], y.shape[2]
    bpho = pool_out_hw(bho, pF, pS)
    pwo = pool_out_hw(wo, pF, pS)
    init = -jnp.inf if op == "max" else 0.0
    acc = jnp.full(y.shape[:1] + (bpho, pwo) + y.shape[3:], init, jnp.float32)
    for dy in range(pF):
        for dx in range(pF):
            win = y[:, dy:dy + (bpho - 1) * pS + 1:pS,
                    dx:dx + (pwo - 1) * pS + 1:pS, ...]
            acc = jnp.maximum(acc, win) if op == "max" else acc + win
    return acc / (pF * pF) if op == "avg" else acc


def _conv_kernel(*refs, F, S, bho, Wo, n_ci, epilogue: Epilogue,
                 src_layout: str, dst_layout: str, res_layout: str = "CHWN",
                 save_act: bool = False):
    xa_ref, xb_ref, w_ref = refs[:3]
    rest = refs[3:]
    b_ref = r_ref = None
    if epilogue.bias:
        b_ref, rest = rest[0], rest[1:]
    if epilogue.residual:
        r_ref, rest = rest[0], rest[1:]
    if save_act:
        o_ref, z_ref, acc_ref = rest
    else:
        (o_ref, acc_ref), z_ref = rest, None

    @pl.when(pl.program_id(3) == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xa = xa_ref[...]                     # [cit, IBH, W, nt] (CHWN blocks)
    xb = xb_ref[...]
    if src_layout == "NCHW":             # blocks arrive [nt, cit, IBH, W]
        xa = jnp.transpose(xa, (1, 2, 3, 0))
        xb = jnp.transpose(xb, (1, 2, 3, 0))
    x2 = jnp.concatenate([xa, xb], axis=1)      # rows j*IBH .. j*IBH+2*IBH
    if jnp.issubdtype(x2.dtype, jnp.integer):
        # int8 storage (DESIGN.md §9): HBM held 1-byte values; the dequant
        # happens here in VMEM (the per-channel scale was folded into w by
        # the caller, so the cast IS the dequant)
        x2 = x2.astype(jnp.float32)
    w = w_ref[...]                       # [cit, F, F, cot]

    acc = acc_ref[...]
    for dy in range(F):
        for dx in range(F):
            xs = x2[:, dy:dy + (bho - 1) * S + 1:S,
                    dx:dx + (Wo - 1) * S + 1:S, :]      # [cit,bho,Wo,nt]
            acc = acc + jnp.einsum(
                "chwn,ck->khwn", xs, w[:, dy, dx, :],
                preferred_element_type=jnp.float32)
    acc_ref[...] = acc

    @pl.when(pl.program_id(3) == n_ci - 1)
    def _():
        y = acc_ref[...]                 # [cot, bho, Wo, nt] f32, in VMEM
        if epilogue.bias:
            y = y + b_ref[...].reshape(-1, 1, 1, 1)
        if epilogue.residual:            # folded skip add, pre-ReLU
            r = r_ref[...]
            if res_layout == "NCHW":     # block arrives [nt, cot, bho, Wo]
                r = jnp.transpose(r, (1, 2, 3, 0))
            y = y + r.astype(jnp.float32)
        if epilogue.relu:
            y = jnp.maximum(y, 0.0)
        if save_act:                     # training residual: pre-pool, native
            z_ref[...] = y.astype(z_ref.dtype)
        if epilogue.pool is not None:
            pF, pS, pop = epilogue.pool
            y = pool_block(y, pF, pS, pop)
        if dst_layout == "NCHW":
            y = jnp.transpose(y, (3, 0, 1, 2))
        o_ref[...] = y.astype(o_ref.dtype)


def conv_chwn_pallas(x, w, F: int, S: int, *, bho: int = 4, cot: int = 0,
                     cit: int = 0, nt: int = 128, ibh: int = 0,
                     bias=None, res=None, res_layout: str = "CHWN",
                     epilogue: Epilogue = Epilogue(),
                     src_layout: str = "CHWN", dst_layout: str = "CHWN",
                     save_act: bool = False, interpret: bool = True):
    """Direct CHWN conv with fused epilogue and layout-fused I/O.

    x: [Ci, H, W, N] (or [N, Ci, H, W] when ``src_layout == "NCHW"``);
    w: [Ci, F, F, Co]; bias: [Co, 1] when ``epilogue.bias``; ``res`` (when
    ``epilogue.residual``) is the skip tensor in ``res_layout``, pre-padded
    by ops.py to the kernel's Co/row-block/N grid (zero padding — additive
    identity on rows the caller slices off anyway).
    Result: [Co, Ho', Wo', N] (or [N, Co, Ho', Wo'] when
    ``dst_layout == "NCHW"``) where Ho'/Wo' are post-pool when a pool
    epilogue is fused.  ``save_act`` (training) adds a second output: the
    pre-pool post-bias/relu activation [Co, Ho, Wo, N] in the kernel's native
    CHWN layout — the residual the fused backward needs, written from the
    same VMEM accumulator (no recompute).

    Requirements (ops.py pads): N % nt == 0, Co % cot == 0, Ci % cit == 0,
    Ho % bho == 0, H >= (row blocks + 1)*IBH, and — with a pool epilogue —
    ``pool_tiles_block(bho, n_ho, pF, pS)``.  ``ibh`` overrides the input
    row-block height (default bho*S); legal only when there is a single row
    block, where it lets the two stitched blocks cover a window span larger
    than 2*bho*S.
    """
    if src_layout == "NCHW":
        N, Ci, H, W = x.shape
    else:
        Ci, H, W, N = x.shape
    Co = w.shape[-1]
    Ho = conv_out_hw(H, F, S)          # input arrives pre-padded
    Wo = conv_out_hw(W, F, S)
    cot = cot or min(Co, 128)
    cit = cit or min(Ci, 32)
    IBH = ibh or bho * S
    n_ci = Ci // cit
    if IBH == bho * S:
        n_ho = Ho // bho          # may exceed the true count (halo padding);
    else:                         # ops.py slices the spurious rows off
        n_ho = 1                  # ibh override: single row block by contract
        assert 2 * IBH >= (bho - 1) * S + F, (IBH, bho, S, F)

    obho, OWo = bho, Wo
    if epilogue.pool is not None:
        pF, pS, _ = epilogue.pool
        assert pool_tiles_block(bho, n_ho, pF, pS), (bho, n_ho, pF, pS)
        obho = pool_out_hw(bho, pF, pS)
        OWo = pool_out_hw(Wo, pF, pS)
    OHo = n_ho * obho

    if src_layout == "NCHW":
        in_specs = [
            pl.BlockSpec((nt, cit, IBH, W), lambda h, c, n, k: (n, k, h, 0)),
            pl.BlockSpec((nt, cit, IBH, W),
                         lambda h, c, n, k: (n, k, h + 1, 0)),
        ]
    else:
        in_specs = [
            pl.BlockSpec((cit, IBH, W, nt), lambda h, c, n, k: (k, h, 0, n)),
            pl.BlockSpec((cit, IBH, W, nt),
                         lambda h, c, n, k: (k, h + 1, 0, n)),
        ]
    in_specs.append(pl.BlockSpec((cit, F, F, cot),
                                 lambda h, c, n, k: (k, 0, 0, c)))
    operands = [x, x, w]
    if epilogue.bias:
        assert bias is not None
        in_specs.append(pl.BlockSpec((cot, 1), lambda h, c, n, k: (c, 0)))
        operands.append(bias)
    if epilogue.residual:
        assert res is not None
        if res_layout == "NCHW":
            in_specs.append(pl.BlockSpec((nt, cot, bho, Wo),
                                         lambda h, c, n, k: (n, c, h, 0)))
        else:
            in_specs.append(pl.BlockSpec((cot, bho, Wo, nt),
                                         lambda h, c, n, k: (c, h, 0, n)))
        operands.append(res)

    # int8 x emits the float compute dtype (= w's dtype: the storage cast
    # back to int8, when planned, is the NEXT boundary's quantize)
    odt = jnp.result_type(x.dtype, w.dtype)
    if dst_layout == "NCHW":
        out_shape = jax.ShapeDtypeStruct((N, Co, OHo, OWo), odt)
        out_specs = pl.BlockSpec((nt, cot, obho, OWo),
                                 lambda h, c, n, k: (n, c, h, 0))
    else:
        out_shape = jax.ShapeDtypeStruct((Co, OHo, OWo, N), odt)
        out_specs = pl.BlockSpec((cot, obho, OWo, nt),
                                 lambda h, c, n, k: (c, h, 0, n))
    if save_act:
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((Co, n_ho * bho, Wo, N), odt)]
        out_specs = [out_specs,
                     pl.BlockSpec((cot, bho, Wo, nt),
                                  lambda h, c, n, k: (c, h, 0, n))]

    kern = functools.partial(_conv_kernel, F=F, S=S, bho=bho, Wo=Wo,
                             n_ci=n_ci, epilogue=epilogue,
                             src_layout=src_layout, dst_layout=dst_layout,
                             res_layout=res_layout, save_act=save_act)
    return pl.pallas_call(
        kern,
        out_shape=out_shape,
        grid=(n_ho, Co // cot, N // nt, n_ci),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((cot, bho, Wo, nt), jnp.float32)],
        interpret=interpret,
    )(*operands)
