"""LM-cell roofline summary (deliverable g): renders the dry-run results
(results/dryrun/*/*.json) as the per-(arch x shape x mesh) table."""
from __future__ import annotations

import glob
import json

from benchmarks.common import emit


def run(quick: bool = True, out_dir: str = "results/dryrun"):
    files = sorted(glob.glob(f"{out_dir}/*/*.json"))
    if not files:
        emit("lm_roofline/missing", 0.0, "run repro.launch.dryrun first")
        return
    for f in files:
        d = json.load(open(f))
        if "error" in d:
            emit(f"lm_roofline/{d.get('mesh','?')}/{d['arch']}/{d['shape']}",
                 0.0, "ERROR")
            continue
        emit(f"lm_roofline/{d['mesh']}/{d['arch']}/{d['shape']}",
             d["step_s"] * 1e6,
             f"bound={d['bound']};compute_s={d['compute_s']:.3e};"
             f"memory_s={d['memory_s']:.3e};"
             f"collective_s={d['collective_s']:.3e};mfu={d['mfu']:.3f};"
             f"useful={d['useful_ratio']:.2f};fits={d['fits']};"
             f"GiB={d['bytes_per_chip']/2**30:.2f}")


if __name__ == "__main__":
    run()
