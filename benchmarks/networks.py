"""Paper Fig. 14 / Fig. 15: whole-network comparison.

Each of the paper's five CNNs under the three mechanisms:
  cuda-convnet (all CHWN), cuDNN (all NCHW), Opt (per-layer selection +
  fast transforms).  Derived: layout assignment, transform count, modeled
  total seconds from the selector's cost model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.configs.cnn_networks import CNN_CONFIGS
from repro.cnn.layers import init_cnn
from repro.cnn.network import (forward, input_shape, network_descs,
                               plan_network)
from repro.core import assign_layouts


def run(quick: bool = True):
    for name, cfg0 in CNN_CONFIGS.items():
        # deep nets (alexnet/zfnet/vgg) downsample ~32x: keep >= 96 px
        hw_quick = 32 if cfg0.image_hw <= 32 else 96
        cfg = cfg0.replace(batch=8 if quick else cfg0.batch,
                           image_hw=hw_quick if quick else cfg0.image_hw)
        params = init_cnn(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (cfg.batch, cfg.in_channels, cfg.image_hw,
                               cfg.image_hw), jnp.float32)
        for mode in ("cuda-convnet", "cudnn", "opt"):
            layouts = plan_network(cfg, mode)
            f = jax.jit(lambda p, x: forward(p, x, cfg, layouts)[0])
            t = timeit(f, params, x)
            _, stats = forward(params, x, cfg, layouts)
            derived = f"transforms={stats.transforms}"
            if mode == "opt":
                a = assign_layouts(network_descs(cfg0),
                                   input_shape=input_shape(cfg0))
                derived += f";model_total_s={a.total_s:.2e}"
            emit(f"networks/{name}/{mode}", t, derived)


if __name__ == "__main__":
    run()
