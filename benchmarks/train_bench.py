"""Fused vs XLA-decomposed TRAINING step (ISSUE 2 acceptance).

Compares one full training step (forward + backward) of the fused engine
(``train_step_fused``: fused Pallas forward, custom-VJP backward with
activation stash, one-kernel pool+mask backward, native dgrad/wgrad) against
the seed ``train_step`` (``jax.value_and_grad`` over the unfused XLA
forward) on the paper's CNNs:

  * full-size HBM traffic comes from tracing both executors with
    ``training=True`` under ``jax.eval_shape`` — the backward accounting is
    shape-only, so the paper-size networks are measured without running;
  * numerics run BOTH train steps for 5 real steps at quick size and report
    the worst per-step |loss difference| (acceptance: < 1e-4);
  * the wall-time rows decompose both steps to XLA (interpret-mode Pallas
    wall time on CPU is meaningless) — they compare plan shapes only, the
    kernel-level win is what the traffic rows model.

Derived columns: ``seed_MB``/``fused_MB`` (fwd+bwd modeled HBM traffic),
``bwd_MB`` pairs, ``saving``, ``maxloss`` (worst |loss delta| over 5 steps).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, record, timeit
from repro.configs.cnn_networks import CNN_CONFIGS
from repro.cnn.layers import init_cnn
from repro.cnn.network import (forward, forward_fused, init_velocity,
                               input_shape, make_train_step,
                               make_train_step_fused, plan_network,
                               plan_network_fused)
from repro.dtypes import canon_dtype, jnp_dtype


def _traced_train_stats(cfg, fused: bool, dtype: str = "float32",
                        policy: str = "uniform"):
    """Training RunStats for a full-size step without executing it."""
    jdt = jnp_dtype(dtype)
    params = jax.eval_shape(lambda k: init_cnn(k, cfg, dtype=jdt),
                            jax.random.PRNGKey(0))
    box = {}

    def f(p, x):
        if fused:
            y, st = forward_fused(p, x, cfg,
                                  plan_network_fused(cfg, dtype=dtype,
                                                     policy=policy),
                                  impl="xla", training=True)
        else:
            y, st = forward(p, x, cfg, plan_network(cfg, "opt", dtype=dtype),
                            training=True)
        box["stats"] = st
        return y

    jax.eval_shape(f, params,
                   jax.ShapeDtypeStruct(input_shape(cfg), jdt))
    return box["stats"]


def run(quick: bool = True, dtype: str = "bfloat16"):
    dtype = canon_dtype(dtype)
    names = ["alexnet", "lenet"] if quick else list(CNN_CONFIGS)
    for name in names:
        cfg0 = CNN_CONFIGS[name]
        # (a) full-size modeled fwd+bwd traffic: the acceptance numbers
        seed = _traced_train_stats(cfg0, fused=False)
        fused = _traced_train_stats(cfg0, fused=True)
        saving = 1.0 - fused.total_hbm_bytes / max(seed.total_hbm_bytes, 1)
        emit(f"train/{name}/traffic", 0.0,
             f"seed_MB={seed.total_hbm_bytes / 1e6:.1f};"
             f"fused_MB={fused.total_hbm_bytes / 1e6:.1f};"
             f"seed_bwd_MB={seed.bwd_hbm_bytes / 1e6:.1f};"
             f"fused_bwd_MB={fused.bwd_hbm_bytes / 1e6:.1f};"
             f"saving={saving:.2f}")
        record(f"train/{name}/traffic", network=name, dtype="float32",
               seed_bytes=seed.total_hbm_bytes,
               fused_bytes=fused.total_hbm_bytes, saving=saving)
        assert fused.total_hbm_bytes < seed.total_hbm_bytes, name

        # (a') the element-size lever on the whole training step: the fused
        # engine's fwd+bwd modeled bytes at the reduced storage dtype
        if dtype != "float32":
            fused_lo = _traced_train_stats(cfg0, fused=True, dtype=dtype)
            ratio = fused.total_hbm_bytes / max(fused_lo.total_hbm_bytes, 1)
            emit(f"train/{name}/dtype", 0.0,
                 f"dtype={dtype};fp32_MB={fused.total_hbm_bytes / 1e6:.1f};"
                 f"{dtype}_MB={fused_lo.total_hbm_bytes / 1e6:.1f};"
                 f"bytes_ratio={ratio:.2f};ok={ratio >= 1.8}")
            record(f"train/{name}/dtype", network=name, dtype=dtype,
                   fp32_bytes=fused.total_hbm_bytes,
                   reduced_bytes=fused_lo.total_hbm_bytes,
                   bytes_ratio=ratio)

            # (a'') per-layer mixed-dtype training step (ISSUE 5): int8
            # interior storage shrinks forward bytes (gradients stay at the
            # base dtype via the straight-through casts), so the whole-step
            # traffic lands strictly below the uniform reduced plan on
            # int8-eligible networks
            mixed = _traced_train_stats(cfg0, fused=True, dtype=dtype,
                                        policy="mixed")
            emit(f"train/{name}/mixed", 0.0,
                 f"base={dtype};"
                 f"uniform_MB={fused_lo.total_hbm_bytes / 1e6:.1f};"
                 f"mixed_MB={mixed.total_hbm_bytes / 1e6:.1f};"
                 f"fwd_MB={mixed.hbm_bytes / 1e6:.1f};"
                 f"below_uniform="
                 f"{mixed.total_hbm_bytes <= fused_lo.total_hbm_bytes}")
            record(f"train/{name}/mixed", network=name, dtype=dtype,
                   policy="mixed",
                   uniform_bytes=fused_lo.total_hbm_bytes,
                   mixed_bytes=mixed.total_hbm_bytes,
                   mixed_fwd_bytes=mixed.hbm_bytes)

        # (b) quick-size execution: 5 real steps of both engines
        hw_quick = 32 if cfg0.image_hw <= 32 else 96
        cfg = cfg0.replace(batch=4 if quick else cfg0.batch,
                           image_hw=hw_quick if quick else cfg0.image_hw)
        params = init_cnn(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), input_shape(cfg),
                              jnp.float32)
        y = jax.random.randint(jax.random.PRNGKey(2), (cfg.batch,), 0,
                               cfg.num_classes)
        layouts = plan_network(cfg, "opt")
        plan = plan_network_fused(cfg)
        step_seed = make_train_step(cfg, layouts)
        step_fused = make_train_step_fused(cfg, plan)
        p1, v1 = params, init_velocity(params)
        p2, v2 = params, init_velocity(params)
        maxloss = 0.0
        for _ in range(5):
            p1, v1, l1 = step_seed(p1, v1, x, y)
            p2, v2, l2 = step_fused(p2, v2, x, y)
            maxloss = max(maxloss, abs(float(l1) - float(l2)))
        step_x = make_train_step_fused(cfg, plan, impl="xla")
        t_seed = timeit(lambda p, v: step_seed(p, v, x, y), p1, v1)
        t_fused = timeit(lambda p, v: step_x(p, v, x, y), p2, v2)
        emit(f"train/{name}/seed_step", t_seed, "impl=xla")
        emit(f"train/{name}/fused_step", t_fused,
             f"impl=xla_decomposed;maxloss={maxloss:.2e}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--dtype", default="bf16",
                    choices=["float32", "fp32", "bfloat16", "bf16"])
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, dtype=args.dtype)
