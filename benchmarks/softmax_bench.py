"""Paper Fig. 13: softmax configurations — fused kernel vs 5-pass baseline.

The paper's twelve (batch x categories) configs; 'BL' is the literal 5-kernel
pipeline (5 HBM round trips), 'Opt' the single fused kernel.  Derived column:
modeled HBM bytes each way (the 5x -> 2x traffic reduction the paper
measures as 58 -> 221 GB/s).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.configs.paper_table1 import SOFTMAX_LAYERS
from repro.kernels.softmax.ops import softmax as softmax_fused
from repro.kernels.softmax.ref import softmax_5step_ref


def run(quick: bool = True):
    five = jax.jit(softmax_5step_ref)
    for l in SOFTMAX_LAYERS:
        x = jax.random.normal(jax.random.PRNGKey(0), (l.N, l.C), jnp.float32)
        t_bl = timeit(five, x)
        t_opt = timeit(lambda x: softmax_fused(x), x)
        sz = l.N * l.C * 4
        # baseline: read+write each of 5 steps (max/shift/exp/sum/div);
        # fused: one read + one write
        emit(f"softmax/{l.name}/BL5", t_bl, f"hbm_bytes={5*2*sz}")
        emit(f"softmax/{l.name}/Opt", t_opt, f"hbm_bytes={2*sz};"
             f"traffic_reduction={5.0:.1f}x")


if __name__ == "__main__":
    run()
