"""Paper Fig. 6 / Fig. 12: pooling layers — layouts + window-reuse kernel.

Reports: XLA reduce_window in CHWN vs NCHW (layout effect), the Pallas
window-reuse kernel (interpret), and the redundant-access model the paper
uses (total loads naive vs reused).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.configs.paper_table1 import POOL_LAYERS
from repro.kernels.pool.ops import pool_chwn
from repro.kernels.pool.ref import pool_ref
from repro.shapes import pool_out_hw


def run(quick: bool = True):
    for l in POOL_LAYERS:
        scale = 2 if (quick and l.HW > 50) else 1
        hw = max(l.F + l.S, l.HW // scale)
        n = max(32, l.N // (4 if quick else 1))
        c = max(8, l.C // (2 if quick else 1))
        key = jax.random.PRNGKey(0)
        x_chwn = jax.random.normal(key, (c, hw, hw, n), jnp.float32)
        x_nchw = jnp.transpose(x_chwn, (3, 0, 1, 2))

        f_chwn = jax.jit(lambda x: pool_ref(x, l.F, l.S, "max", "CHWN"))
        f_nchw = jax.jit(lambda x: pool_ref(x, l.F, l.S, "max", "NCHW"))
        t_chwn = timeit(f_chwn, x_chwn)
        t_nchw = timeit(f_nchw, x_nchw)
        t_kern = timeit(lambda x: pool_chwn(x, l.F, l.S, "max"), x_chwn)

        ho = pool_out_hw(hw, l.F, l.S)
        naive_loads = c * n * ho * ho * l.F * l.F          # paper Fig. 8
        reused_loads = c * n * hw * hw                     # each input once
        emit(f"pool/{l.name}/CHWN", t_chwn,
             f"overlap={l.overlapped};naive_loads={naive_loads};"
             f"reused_loads={reused_loads};"
             f"redundancy={naive_loads/max(reused_loads,1):.2f}x")
        emit(f"pool/{l.name}/NCHW", t_nchw, "")
        emit(f"pool/{l.name}/pallas_reuse", t_kern, "interpret")


if __name__ == "__main__":
    run()
