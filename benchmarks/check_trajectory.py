"""Bench-trajectory CI gate (ISSUE 5): validate freshly generated
``BENCH_<table>.json`` files against the committed trajectory.

Two checks, per table:

  * **schema** — the file is ``{"table": str, "quick": bool, "records":
    [{"name": str, ...}]}`` with JSON-scalar/list field values, and every
    committed record (keyed by its discriminating fields) still exists in
    the regenerated file — a benchmark silently dropping a row is a
    regression too;
  * **no modeled-bytes regression** — every ``*_bytes`` field may shrink
    freely but may not GROW beyond ``--tolerance`` (default 5%) over the
    committed value, and every higher-is-better field in ``FIELD_DIRECTION``
    (``bytes_ratio``, ``saving``, ``hit_rate``) may not shrink below
    committed minus the tolerance.  The modeled numbers are deterministic
    planner arithmetic, so the tolerance only absorbs benign cost-model
    refinements; a fusion or dtype lever accidentally switched off shows up
    as a 2x jump and fails loudly.  Exact fusion counters (``COUNT_FIELDS``:
    ``standalone_adds``, ``intermediate_roundtrip_bytes``,
    ``dropped_requests``) get NO tolerance: they may not grow at all.
    ``devices`` (ISSUE 10) is stricter still — EXACT match both ways,
    because a scale row regenerating at a different mesh size silently
    changes what the row measures; paired with the lower-is-better
    ``per_chip_bytes`` gate it pins the weak-scaling claim (per-chip HBM
    traffic flat as the mesh grows).

Exit code 0 = gate passes; 1 = schema violation or regression (each listed
on stderr).  Run locally as::

    PYTHONPATH=src python benchmarks/check_trajectory.py \
        --baseline . --candidate bench-out --tables fusion,serve,train
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Tuple

# fields that identify a record within its table (name alone repeats across
# dtype/bucket sweeps)
KEY_FIELDS = ("name", "network", "dtype", "bucket", "policy", "impl")
# larger-is-worse numeric fields under the tolerance gate
BYTES_SUFFIX = "_bytes"
# exact counters that may never grow: a fusion lever switching off shows up
# as residual adds falling out of the conv epilogues (ISSUE 6) or a stack
# intermediate going back through HBM (ISSUE 7) — zero tolerance.
# ``dropped_requests`` (ISSUE 9) is the serving-resilience contract: under
# seeded fault injection the guarded ladder must serve 100% of requests,
# so the committed value is 0 and any growth fails the gate outright.
COUNT_FIELDS = ("standalone_adds", "intermediate_roundtrip_bytes",
                "dropped_requests", "devices")
# COUNT_FIELDS that must match the committed value EXACTLY (both
# directions): ``devices`` is mesh topology, not a monotone counter — a
# scale row silently regenerating at a different device count would
# invalidate the weak-scaling claim even if every byte field "improved"
EXACT_MATCH_FIELDS = ("devices",)
# per-field gate direction (ISSUE 7): +1 = higher is better, so the gate
# fires on SHRINKAGE below committed-minus-tolerance; -1 = lower is better,
# so the gate fires on growth.  ``*_bytes`` fields default to -1 via
# BYTES_SUFFIX (relative tolerance); COUNT_FIELDS override both with an
# exact no-growth rule; every other numeric field not listed here is
# informational and ungated.
FIELD_DIRECTION = {
    "saving": +1,
    "stack_saving": +1,
    "stacks_fused": +1,
    "bytes_ratio": +1,
    "hit_rate": +1,
    # DESIGN.md §15: modeled per-chip HBM bytes of a scale row — the
    # weak-scaling contract is that these stay FLAT as devices grow, so
    # any growth past tolerance is a sharding-efficiency regression.
    # (Listed explicitly even though the _bytes suffix already implies
    # -1: the flatness claim is the point of the scale rows.)
    "per_chip_bytes": -1,
    # DESIGN.md §13: mean relative error of the analytic cost model against
    # measured Pallas timings on the calibration sweep — lower is better
    "prediction_error": -1,
}
# per-field tolerance overrides (fraction).  prediction_error compares
# MEASURED interpret-mode timings across machines/runs, so it gets a much
# wider band than the deterministic modeled-bytes fields: the gate only
# fires when the error more than doubles (the model structurally breaking),
# not on timer noise.
FIELD_TOLERANCE = {
    "prediction_error": 1.0,
}

Scalar = (str, int, float, bool, type(None))


def load(path: str) -> Dict:
    with open(path) as f:
        return json.load(f)


def schema_errors(obj: Dict, path: str) -> List[str]:
    errs = []
    if not isinstance(obj.get("table"), str):
        errs.append(f"{path}: missing/non-string 'table'")
    if not isinstance(obj.get("quick"), bool):
        errs.append(f"{path}: missing/non-bool 'quick'")
    recs = obj.get("records")
    if not isinstance(recs, list):
        return errs + [f"{path}: 'records' is not a list"]
    for i, r in enumerate(recs):
        if not isinstance(r, dict) or not isinstance(r.get("name"), str):
            errs.append(f"{path}: records[{i}] has no string 'name'")
            continue
        for k, v in r.items():
            if isinstance(v, list):
                bad = [e for e in v if not isinstance(e, Scalar)]
                if bad:
                    errs.append(f"{path}: records[{i}].{k} has non-scalar "
                                f"list entries")
            elif not isinstance(v, Scalar):
                errs.append(f"{path}: records[{i}].{k} is "
                            f"{type(v).__name__}, not a JSON scalar/list")
    return errs


def rec_key(r: Dict) -> Tuple:
    return tuple((k, r.get(k)) for k in KEY_FIELDS if k in r)


def index(obj: Dict) -> Dict[Tuple, Dict]:
    out = {}
    for r in obj.get("records", ()):
        out[rec_key(r)] = r
    return out


def compare(base: Dict, cand: Dict, table: str, tol: float) -> List[str]:
    errs = []
    bidx, cidx = index(base), index(cand)
    for key, brec in bidx.items():
        crec = cidx.get(key)
        if crec is None:
            errs.append(f"{table}: committed record {dict(key)} missing "
                        f"from regenerated file")
            continue
        for k, bv in brec.items():
            if not isinstance(bv, (int, float)) or isinstance(bv, bool):
                continue
            cv = crec.get(k)
            if not isinstance(cv, (int, float)) or isinstance(cv, bool):
                errs.append(f"{table}: {dict(key)}.{k} lost its numeric "
                            f"value ({cv!r})")
                continue
            if k in COUNT_FIELDS:
                if k in EXACT_MATCH_FIELDS:
                    if cv != bv:
                        errs.append(f"{table}: {dict(key)}.{k} changed "
                                    f"{bv} -> {cv} (exact match required)")
                elif cv > bv:
                    errs.append(f"{table}: {dict(key)}.{k} grew {bv} -> {cv} "
                                f"(exact counter, no tolerance)")
                continue
            direction = FIELD_DIRECTION.get(
                k, -1 if k.endswith(BYTES_SUFFIX) else 0)
            ftol = FIELD_TOLERANCE.get(k, tol)
            if direction < 0 and cv > bv * (1 + ftol):
                errs.append(
                    f"{table}: {dict(key)}.{k} regressed "
                    f"{bv} -> {cv} (+{(cv / max(bv, 1) - 1) * 100:.1f}% > "
                    f"{ftol * 100:.0f}% tolerance)")
            elif direction > 0 and cv < bv - tol:
                errs.append(f"{table}: {dict(key)}.{k} regressed "
                            f"{bv:.3f} -> {cv:.3f} (higher-is-better)")
    return errs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=".",
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--candidate", required=True,
                    help="directory holding the freshly generated files")
    ap.add_argument("--tables", default="fusion,serve,train")
    ap.add_argument("--tolerance", type=float, default=0.05,
                    help="allowed fractional modeled-bytes growth")
    args = ap.parse_args()

    errs: List[str] = []
    for table in args.tables.split(","):
        bpath = os.path.join(args.baseline, f"BENCH_{table}.json")
        cpath = os.path.join(args.candidate, f"BENCH_{table}.json")
        if not os.path.exists(bpath):
            errs.append(f"{table}: no committed baseline {bpath}")
            continue
        if not os.path.exists(cpath):
            errs.append(f"{table}: benchmark did not emit {cpath}")
            continue
        base, cand = load(bpath), load(cpath)
        errs += schema_errors(base, bpath)
        errs += schema_errors(cand, cpath)
        errs += compare(base, cand, table, args.tolerance)
        print(f"checked {table}: {len(cand.get('records', []))} records "
              f"vs {len(base.get('records', []))} committed")
    if errs:
        for e in errs:
            print(f"REGRESSION: {e}", file=sys.stderr)
        return 1
    print("bench trajectory gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
