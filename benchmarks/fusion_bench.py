"""Fused vs unfused execution engine (DESIGN.md §5 / ISSUE 1 acceptance).

Compares the seed ``forward`` (per-op kernels + standalone layout
transforms) against ``forward_fused`` (one kernel per conv->relu->pool
chain, every re-layout folded into a kernel I/O map) on the paper's CNNs:

  * full-size HBM traffic + transform counts come from tracing both
    executors under ``jax.eval_shape`` — RunStats accounting is shape-only,
    so the paper-size networks are measured without running them;
  * numerics run the real fused Pallas engine at quick size
    (``maxdiff`` vs the unfused XLA reference);
  * the wall-time rows decompose BOTH executors to XLA (interpret-mode
    Pallas wall time on CPU is meaningless), so they compare only the
    plan-level graph shapes, not the fused kernels — the kernel-level win
    is what the traffic rows model.

Derived columns: ``seed_MB``/``fused_MB`` (modeled HBM traffic),
``saving`` (fraction of bytes removed), ``seed_tr``/``fused_tr``
(standalone transform passes), ``maxdiff`` (fused-vs-reference |delta|).

The final row is the DESIGN.md §13 cross-validation: real Pallas kernels
timed on the calibration sweep vs the (calibrated) analytic prediction.
``prediction_error`` (mean relative error) is gated lower-is-better by
``check_trajectory``; the full point-by-point report is persisted to
``BENCH_calibration_report.json`` (uploaded as a CI artifact).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, record, timeit
from repro.configs.cnn_networks import CNN_BUILDERS, CNN_CONFIGS, reduced_cnn
from repro.cnn.layers import init_cnn
from repro.cnn.network import (forward, forward_fused, input_shape,
                               plan_network, plan_network_fused)
from repro.perfmodel import cross_validate


def _traced_stats(cfg, fused: bool, plan=None):
    """RunStats for a full-size run without executing it: eval_shape traces
    the executor with abstract values; the byte accounting only reads static
    shapes, so it is exact."""
    params = jax.eval_shape(lambda k: init_cnn(k, cfg), jax.random.PRNGKey(0))
    box = {}

    def f(p, x):
        if fused:
            y, st = forward_fused(p, x, cfg, plan, impl="xla")
        else:
            y, st = forward(p, x, cfg, plan_network(cfg, "opt"))
        box["stats"] = st
        return y

    jax.eval_shape(f, params,
                   jax.ShapeDtypeStruct(input_shape(cfg), jnp.float32))
    return box["stats"]


def run(quick: bool = True):
    names = ["alexnet", "lenet", "resnet18"] if quick else list(CNN_CONFIGS)
    for name in names:
        cfg0 = CNN_CONFIGS[name]
        # (a) full-size modeled traffic: the acceptance numbers
        plan0 = plan_network_fused(cfg0)
        seed = _traced_stats(cfg0, fused=False)
        fused = _traced_stats(cfg0, fused=True, plan=plan0)
        saving = 1.0 - fused.hbm_bytes / max(seed.hbm_bytes, 1)
        n_adds = sum(1 for s in cfg0.layers if s.kind == "add")
        emit(f"fusion/{name}/traffic", 0.0,
             f"seed_MB={seed.hbm_bytes / 1e6:.1f};"
             f"fused_MB={fused.hbm_bytes / 1e6:.1f};"
             f"saving={saving:.2f};seed_tr={seed.transforms};"
             f"fused_tr={fused.transforms};fused_ops={fused.fused_ops};"
             f"adds={n_adds};standalone_adds={plan0.standalone_adds}")
        record(f"fusion/{name}/traffic", network=name, dtype="float32",
               seed_bytes=seed.hbm_bytes, fused_bytes=fused.hbm_bytes,
               saving=saving, conv_layouts=plan0.conv_signature,
               dtype_signature=plan0.dtype_signature,
               graph_adds=n_adds, standalone_adds=plan0.standalone_adds)

        # (a') cross-layer stacks (DESIGN.md §12): auto plan vs the same
        # planner with stacking held off.  ``intermediate_roundtrip_bytes``
        # is zero-tolerance in the trajectory gate — any profitable stack
        # left unfused is a planner regression, not noise.
        plan_off = plan_network_fused(cfg0, stack_policy="off")
        off_st = _traced_stats(cfg0, fused=True, plan=plan_off)
        n_stacks = sum(1 for op in plan0.ops if op.stack_index is not None)
        stack_saving = 1.0 - fused.hbm_bytes / max(off_st.hbm_bytes, 1)
        emit(f"fusion/{name}/stack_fusion", 0.0,
             f"stacks={n_stacks};off_MB={off_st.hbm_bytes / 1e6:.1f};"
             f"stacked_MB={fused.hbm_bytes / 1e6:.1f};"
             f"stack_saving={stack_saving:.2f};"
             f"roundtrip_B={plan0.intermediate_roundtrip_bytes}")
        record(f"fusion/{name}/stack_fusion", network=name, dtype="float32",
               stacks_fused=n_stacks, off_bytes=off_st.hbm_bytes,
               stacked_bytes=fused.hbm_bytes, stack_saving=stack_saving,
               intermediate_roundtrip_bytes=
               plan0.intermediate_roundtrip_bytes)

        # (b) quick-size execution: numerics + wall time.  Branching nets
        # go through reduced_cnn (the builder re-derives skip edges at the
        # small size); linear nets keep the historical replace().
        if cfg0.name in CNN_BUILDERS:
            cfg = reduced_cnn(cfg0, batch=4 if quick else cfg0.batch)
        else:
            hw_quick = 32 if cfg0.image_hw <= 32 else 96
            cfg = cfg0.replace(batch=4 if quick else cfg0.batch,
                               image_hw=hw_quick if quick else cfg0.image_hw)
        params = init_cnn(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), input_shape(cfg),
                              jnp.float32)
        layouts = plan_network(cfg, "opt")
        plan = plan_network_fused(cfg)
        ref, _ = forward(params, x, cfg, layouts, impl="xla")
        got, _ = forward_fused(params, x, cfg, plan, impl="pallas")
        maxdiff = float(jnp.abs(got - ref).max())
        f_seed = jax.jit(lambda p, x: forward(p, x, cfg, layouts,
                                              impl="xla")[0])
        f_fused = jax.jit(lambda p, x: forward_fused(p, x, cfg, plan,
                                                     impl="xla")[0])
        t_seed = timeit(f_seed, params, x)
        t_fused = timeit(f_fused, params, x)
        emit(f"fusion/{name}/seed_step", t_seed, "impl=xla")
        emit(f"fusion/{name}/fused_step", t_fused,
             f"impl=xla_decomposed;maxdiff={maxdiff:.2e}")

    # (c) DESIGN.md §13 prediction-error cross-validation: time the REAL
    # Pallas conv engines on the calibration sweep and score the calibrated
    # analytic model against the measurements.  The sweep starts at Ci=32:
    # smaller layers sit on the interpreter's per-call dispatch floor
    # (~3 ms regardless of shape), which no traffic model should be asked
    # to predict.  Quick mode drops the N=256 point (it alone is ~20 s of
    # interpret-mode wall time) but keeps both layouts and both sweep axes.
    cv = cross_validate(reps=3,
                        c_points=(32, 128),
                        n_points=(16, 64) if quick else (16, 64, 256))
    emit("fusion/calibration/cross_validation", 0.0,
         f"hw={cv.hardware};points={len(cv.points)};"
         f"mean_rel_err={cv.mean_rel_err:.3f};"
         f"max_rel_err={cv.max_rel_err:.3f}")
    record("fusion/calibration/cross_validation", network="calibration",
           dtype=cv.dtype, points=len(cv.points),
           prediction_error=cv.mean_rel_err,
           max_prediction_error=cv.max_rel_err)
    report_path = os.path.join(os.environ.get("BENCH_OUT_DIR", "."),
                               "BENCH_calibration_report.json")
    with open(report_path, "w") as f:
        json.dump(cv.to_obj(), f, indent=1)
    print(f"# wrote {report_path} ({len(cv.points)} calibration points)",
          flush=True)


if __name__ == "__main__":
    run()
