"""Paper Fig. 3 / Fig. 10 / Table 1: conv-layer layout comparison.

For each Table-1 conv layer: measured time in each layout engine (XLA conv
running natively in CHWN vs NCHW, plus FFT/NCHW), the TPU cost-model seconds,
the heuristic's pick, and the paper's preferred layout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.configs.paper_table1 import (CONV_LAYERS,
                                        PAPER_PREFERRED_CONV_LAYOUT)
from repro.perfmodel import (Thresholds, calibrate, conv_cost,
                             select_conv_layout)
from repro.cnn.layers import conv_forward


def run(quick: bool = True):
    th = calibrate()
    emit("conv_layout/thresholds", 0.0, f"Ct={th.Ct};Nt={th.Nt}")
    agree = 0
    for l in CONV_LAYERS:
        scale = 4 if (quick and l.HW > 60) else 1
        hw = max(l.F, l.HW // scale)
        n = max(8, l.N // (4 if quick else 1))
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (l.Co, l.Ci, l.F, l.F), jnp.float32) * 0.1
        x_nchw = jax.random.normal(key, (n, l.Ci, hw, hw), jnp.float32)
        x_chwn = jnp.transpose(x_nchw, (1, 2, 3, 0))

        f_nchw = jax.jit(lambda x, w: conv_forward(x, w, "NCHW", l.S))
        f_chwn = jax.jit(lambda x, w: conv_forward(x, w, "CHWN", l.S))
        t_nchw = timeit(f_nchw, x_nchw, w)
        t_chwn = timeit(f_chwn, x_chwn, w)
        try:
            f_fft = jax.jit(lambda x, w: conv_forward(x, w, "NCHW", l.S,
                                                      impl="fft"))
            t_fft = timeit(f_fft, x_nchw, w)
        except Exception:
            t_fft = float("nan")

        pick = select_conv_layout(l, th)
        want = PAPER_PREFERRED_CONV_LAYOUT[l.name]
        agree += pick == want
        cost_c = conv_cost(l, "CHWN").total_s
        cost_n = conv_cost(l, "NCHW").total_s
        emit(f"conv_layout/{l.name}/CHWN", t_chwn,
             f"model_s={cost_c:.2e};pick={pick};paper={want}")
        emit(f"conv_layout/{l.name}/NCHW", t_nchw,
             f"model_s={cost_n:.2e}")
        emit(f"conv_layout/{l.name}/FFT", t_fft, "")
    emit("conv_layout/heuristic_agreement", 0.0, f"{agree}/12")


if __name__ == "__main__":
    run()
