"""Benchmark utilities: timing + CSV emission.

CPU wall times are NOT TPU-representative; each benchmark therefore also
emits the analytical TPU cost-model seconds ("derived") next to the measured
interpret/XLA-CPU microseconds, and the dry-run roofline tables (lm_roofline)
carry the compiled-HLO numbers.  The harness structure (one entry per paper
table) is the deliverable; on real hardware the same functions time the real
kernels.
"""
from __future__ import annotations

import time
from typing import Callable

import jax

ROWS = []
RECORDS = []


def record(name: str, **fields):
    """Append one machine-readable trajectory record (modeled bytes, img/s,
    layout strings, dtype, ...).  ``benchmarks/run.py`` flushes the records
    accumulated during each table into ``BENCH_<table>.json`` so the perf
    trajectory is diffable across PRs."""
    RECORDS.append({"name": name, **fields})


def take_records(start: int = 0):
    """Records appended since ``start`` (run.py snapshots the length before
    each table)."""
    return RECORDS[start:]


def timeit(fn: Callable, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall-time (us) of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    row = f"{name},{us:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)
