"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` uses paper-size inputs
(slow on CPU); default is the quick mode with identical structure.

  conv_layout      — Fig. 3 / Fig. 10 / Table 1 (layout per conv layer)
  pooling          — Fig. 6 / Fig. 12 (pool layouts + window reuse)
  softmax          — Fig. 13 (5-kernel baseline vs fused)
  transform        — Fig. 7 / Fig. 11 (naive vs opt1 vs opt2 transforms)
  networks         — Fig. 14 / Fig. 15 (five CNNs x three mechanisms)
  fusion           — fused engine vs seed forward (traffic + transforms)
  train            — fused vs xla_decomposed TRAINING step (fwd+bwd traffic)
  serve            — batch-adaptive plan cache (Nt flip + 0 replans + numerics)
  heuristic        — Fig. 4 (N/C sensitivity + threshold calibration)
  lm_roofline      — assigned-architecture dry-run roofline table
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def write_bench_json(table: str, records, out_dir: str = ".",
                     quick: bool = True) -> str:
    """Persist one table's trajectory records as ``BENCH_<table>.json`` —
    the machine-readable perf history (modeled bytes, img/s, layout strings
    per network/dtype) that makes regressions diffable across PRs."""
    path = os.path.join(out_dir, f"BENCH_{table}.json")
    with open(path, "w") as f:
        json.dump({"table": table, "quick": quick,
                   "records": list(records)}, f, indent=1)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma list: conv_layout,pooling,softmax,transform,"
                         "networks,fusion,train,serve,heuristic,lm_roofline")
    ap.add_argument("--out-dir", default=".",
                    help="where BENCH_<table>.json trajectory files land")
    args = ap.parse_args()
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    from benchmarks import (common, conv_layout, fusion_bench,
                            heuristic_sweep, lm_roofline, networks, pooling,
                            serve_bench, softmax_bench, train_bench,
                            transform_bench)
    tables = {
        "heuristic": heuristic_sweep.run,
        "conv_layout": conv_layout.run,
        "pooling": pooling.run,
        "softmax": softmax_bench.run,
        "transform": transform_bench.run,
        "networks": networks.run,
        "fusion": fusion_bench.run,
        "train": train_bench.run,
        "serve": serve_bench.run,
        "lm_roofline": lm_roofline.run,
    }
    for name, fn in tables.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", flush=True)
        mark = len(common.RECORDS)
        fn(quick=quick)
        recs = common.take_records(mark)
        if recs:
            path = write_bench_json(name, recs, args.out_dir, quick)
            print(f"# wrote {path} ({len(recs)} records)", flush=True)


if __name__ == "__main__":
    main()
