"""Paper Fig. 7 / Fig. 11: layout-transform bandwidth.

Naive 4-D transpose vs dimension-collapsed 2-D transpose (Opt1) vs the tiled
Pallas kernel with dtype-doubled tiles (Opt2, the float2 analogue).  Derived:
achieved GB/s on the CPU run + the modeled TPU fraction-of-peak.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.configs.paper_table1 import CONV_LAYERS
from repro.core import apply_transform, naive_transform
from repro.kernels.transpose.ops import transpose2d


def run(quick: bool = True):
    for l in CONV_LAYERS[:6] if quick else CONV_LAYERS:
        scale = 4 if (quick and l.HW > 60) else 1
        hw = max(4, l.HW // scale)
        n = max(32, l.N // (2 if quick else 1))
        x = jax.random.normal(jax.random.PRNGKey(0),
                              (max(l.Ci, 1), hw, hw, n), jnp.float32)  # CHWN
        nbytes = 2 * x.size * 4

        f_naive = jax.jit(lambda x: naive_transform(x, "CHWN", "NCHW"))
        f_opt1 = jax.jit(lambda x: apply_transform(x, "CHWN", "NCHW"))
        x2d = x.reshape(-1, n)

        t_naive = timeit(f_naive, x)
        t_opt1 = timeit(f_opt1, x)
        t_opt2 = timeit(lambda v: transpose2d(v), x2d)

        for name, t in [("naive", t_naive), ("opt1_collapse", t_opt1),
                        ("opt2_pallas", t_opt2)]:
            gbs = nbytes / (t * 1e-6) / 1e9 if t > 0 else 0.0
            emit(f"transform/{l.name}/{name}", t, f"GBps={gbs:.2f}")


if __name__ == "__main__":
    run()
