"""Paper Fig. 4: layout sensitivity sweep over N and C (the calibration
experiment).  Emits the cost-model-preferred layout across the sweep and the
extracted thresholds."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs.paper_table1 import ConvLayer
from repro.perfmodel import calibrate, conv_cost


def run(quick: bool = True):
    th = calibrate()
    emit("heuristic/thresholds", 0.0, f"Ct={th.Ct};Nt={th.Nt}")
    # Fig 4a: vary N at CONV7 shape
    for n in (16, 32, 64, 128, 256):
        l = ConvLayer("S", n, 384, 13, 3, 256, 1, "sweep")
        c = {lay: conv_cost(l, lay).total_s for lay in ("CHWN", "NCHW")}
        emit(f"heuristic/varyN/{n}", 0.0,
             f"CHWN={c['CHWN']:.2e};NCHW={c['NCHW']:.2e};"
             f"pick={min(c, key=c.get)}")
    # Fig 4b: vary C
    for cch in (1, 3, 16, 32, 64, 128, 256, 512):
        l = ConvLayer("S", 64, 384, 13, 3, cch, 1, "sweep")
        c = {lay: conv_cost(l, lay).total_s for lay in ("CHWN", "NCHW")}
        emit(f"heuristic/varyC/{cch}", 0.0,
             f"CHWN={c['CHWN']:.2e};NCHW={c['NCHW']:.2e};"
             f"pick={min(c, key=c.get)}")


if __name__ == "__main__":
    run()
