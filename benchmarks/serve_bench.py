"""Batch-adaptive serving sweep (ISSUE 3 + ISSUE 4 + ISSUE 5 acceptance).

Five claims, per network:

  * **flip** — sweeping batch 1 -> 256, the cached planner selects different
    conv layouts for at least two buckets of the same network (the paper's
    Nt threshold in action);
  * **dtype** — the same sweep at the reduced-precision storage dtype
    (bf16): modeled fused HBM bytes drop ~2x vs fp32 (the element-size
    lever), and at least one (network, bucket) point is assigned DIFFERENT
    conv layouts under bf16 than fp32 — the sublane width doubling moves the
    crossover, it doesn't just scale the bytes;
  * **mixed** — the per-layer (layout, dtype) DP (``--dtype-policy mixed``):
    modeled fused HBM bytes strictly below the uniform reduced-precision
    plan wherever the network has int8-eligible interior chains (AlexNet:
    conv2-4 store int8, ``b888b``), with >= 2 distinct storage dtypes
    across conv layers, and the int8 fused forward matching the fp32
    reference within the documented tolerance (``INT8_FORWARD_ATOL``);
  * **cache** — replaying a bursty request stream whose batch sizes repeat,
    the ``PlanCache`` replans 0 times after each bucket's first sight
    (``replans_repeat=0``), with hits accumulating;
  * **numerics** — executing a small batch under its *bucket's* padded plan
    matches the exact-batch plan's outputs on the real rows to <= 1e-5
    (quick-size networks, real fused Pallas kernels for lenet);
  * **scale** — weak-scaling the serving mesh (ISSUE 10): global batch
    B0*D over D in {1,2,4,8} chips holds the per-shard bucket at B0, so
    modeled per-chip HBM bytes stay exactly flat while modeled img/s grows
    linearly, every point passing ``verify_shard_plan`` (the plan cached
    under the (bucket, devices) key IS the shard-batch plan) — plus the
    shard-flip row showing where per-shard N crossing under Nt changes the
    layout the global batch would have picked.

Derived columns: ``conv_layouts`` per bucket/dtype, ``modeled_MB``
(fused-engine HBM bytes at the bucket size), ``bytes_ratio`` (fp32/bf16),
``dtype_flip``, ``distinct``/``flip``, ``replans_repeat``, ``hit_rate``,
``maxdiff``.  Structured trajectory records go to ``BENCH_serve.json`` via
``benchmarks/run.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, record
from repro.configs.cnn_networks import CNN_BUILDERS, CNN_CONFIGS, reduced_cnn
from repro.cnn.layers import init_cnn
from repro.cnn.network import forward_fused, input_shape, plan_network_fused
from repro.perfmodel import calibrate
from repro.dtypes import canon_dtype, dtype_bytes
from repro.quant import INT8_FORWARD_ATOL
from repro.serve import PlanCache, pad_to_bucket

BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)
# bursty stream with repeating sizes: every bucket recurs at least once
STREAM = (1, 3, 7, 1, 4, 64, 9, 130, 2, 128, 64, 5, 255, 16, 3, 100, 12)


def run(quick: bool = True, dtype: str = "bfloat16"):
    """``dtype`` is the reduced-precision fast path compared against the
    fp32 baseline; pass "float32" to skip the dtype-comparison section."""
    dtype = canon_dtype(dtype)
    names = ["lenet", "alexnet", "resnet18"] if quick else list(CNN_CONFIGS)
    dtypes = ["float32"] + ([dtype] if dtype != "float32" else [])
    th = {d: calibrate(dtype_bytes=dtype_bytes(d)) for d in dtypes}
    for name in names:
        cfg0 = CNN_CONFIGS[name]
        cache = PlanCache(thresholds=th)

        # (a) full-size bucket sweep per dtype: where does the layout flip
        # with batch, and where does it flip with element size?
        sigs = {d: {} for d in dtypes}
        mb = {d: {} for d in dtypes}
        for d in dtypes:
            for b in BUCKETS:
                plan, bkt, _ = cache.fused_plan(cfg0, b, dtype=d)
                sigs[d][bkt] = plan.conv_signature
                mb[d][bkt] = plan.fused_bytes
                emit(f"serve/{name}/{d}/bucket{bkt}", 0.0,
                     f"conv_layouts={sigs[d][bkt]};"
                     f"modeled_MB={plan.fused_bytes / 1e6:.1f}")
                record(f"serve/{name}/bucket{bkt}", network=name, dtype=d,
                       bucket=bkt, conv_layouts=sigs[d][bkt],
                       modeled_bytes=plan.fused_bytes,
                       standalone_adds=plan.standalone_adds)
        distinct = len(set(sigs["float32"].values()))
        emit(f"serve/{name}/flip", 0.0,
             f"distinct={distinct};flip={distinct >= 2}")

        if dtype != "float32":
            # element-size lever: fused bytes at the network's native batch.
            # Stacking (DESIGN.md §12) is held off on BOTH sides — fp32 and
            # bf16 plans can fuse different stacks, which would contaminate
            # a ratio that exists to isolate the dtype lever alone.
            bkt0 = cache.bucket(cfg0.batch)
            bcfg = cfg0.replace(batch=bkt0)
            ratio = (plan_network_fused(bcfg, dtype="float32",
                                        stack_policy="off").fused_bytes
                     / plan_network_fused(bcfg, dtype=dtype,
                                          stack_policy="off").fused_bytes)
            flips = [b for b in sigs["float32"]
                     if sigs["float32"][b] != sigs[dtype][b]]
            emit(f"serve/{name}/dtype", 0.0,
                 f"dtype={dtype};bytes_ratio={ratio:.2f};"
                 f"ok={ratio >= 1.8};dtype_flip_buckets={flips};"
                 f"dtype_flip={bool(flips)}")
            record(f"serve/{name}/dtype", network=name, dtype=dtype,
                   bucket=bkt0, bytes_ratio=ratio,
                   fp32_bytes=mb["float32"][bkt0],
                   reduced_bytes=mb[dtype][bkt0],
                   dtype_flip_buckets=flips)

        # (a'') per-layer mixed-dtype DP (ISSUE 5): interior conv chains
        # store int8 where both casts fold; bytes must land strictly below
        # the uniform reduced-precision plan on int8-eligible networks
        base = dtype                   # the mixed plan's float base dtype
        bkt0 = cache.bucket(cfg0.batch)
        pm, _, _ = cache.fused_plan(cfg0, cfg0.batch, dtype=base,
                                    policy="mixed")
        uni_b = mb[base][bkt0]
        mratio = uni_b / max(pm.fused_bytes, 1)
        emit(f"serve/{name}/mixed", 0.0,
             f"base={base};conv_dtypes={pm.dtype_signature};"
             f"uniform_MB={uni_b / 1e6:.1f};"
             f"mixed_MB={pm.fused_bytes / 1e6:.1f};"
             f"bytes_ratio={mratio:.2f};"
             f"distinct={pm.distinct_conv_dtypes};"
             f"below_uniform={pm.fused_bytes < uni_b}")
        record(f"serve/{name}/mixed", network=name, dtype=base,
               bucket=bkt0, policy="mixed",
               dtype_signature=pm.dtype_signature,
               uniform_bytes=uni_b, mixed_bytes=pm.fused_bytes,
               distinct_dtypes=pm.distinct_conv_dtypes)

        # (b) replay the bursty stream: repeats must not replan
        first_sight = cache.planner_calls
        seen = set(cache.per_key)
        replans_repeat = 0
        for b in STREAM:
            bkt = cache.bucket(b)
            known = any(k.bucket == bkt and k.dtype == "float32"
                        for k in seen)
            before = cache.planner_calls
            _, _, hit = cache.fused_plan(cfg0, b)
            if known and cache.planner_calls != before:
                replans_repeat += 1
            seen = set(cache.per_key)
        emit(f"serve/{name}/cache", 0.0,
             f"planner_calls={cache.planner_calls};"
             f"first_sight={first_sight};replans_repeat={replans_repeat};"
             f"hit_rate={cache.stats.hit_rate:.2f}")

        # (c) quick-size numerics: padded bucket plan == exact plan on the
        # real rows (fused Pallas for lenet; decomposed-xla for big nets).
        # Branching nets downscale through their builder so merge shapes
        # stay consistent at the quick size.
        impl = "pallas" if cfg0.image_hw <= 32 else "xla"
        if cfg0.image_hw <= 32:
            cfgq = cfg0
        elif cfg0.name in CNN_BUILDERS:
            cfgq = reduced_cnn(cfg0, batch=cfg0.batch)
        else:
            cfgq = cfg0.replace(image_hw=96)
        params = init_cnn(jax.random.PRNGKey(0), cfgq.replace(batch=1))
        worst = 0.0
        for B in (1, 3, 6):
            bkt = cache.bucket(B)
            bplan, _, _ = cache.fused_plan(cfgq, B)
            eplan = plan_network_fused(cfgq.replace(batch=B))
            x = jax.random.normal(jax.random.PRNGKey(B),
                                  input_shape(cfgq.replace(batch=B)),
                                  jnp.float32)
            yb, _ = forward_fused(params, pad_to_bucket(x, bkt),
                                  cfgq.replace(batch=bkt), bplan, impl=impl)
            ye, _ = forward_fused(params, x, cfgq.replace(batch=B), eplan,
                                  impl=impl)
            worst = max(worst, float(jnp.abs(yb[:B] - ye).max()))
        emit(f"serve/{name}/numerics", 0.0,
             f"impl={impl};maxdiff={worst:.2e};ok={worst <= 1e-5}")

        # (c') int8 numerics: the mixed plan at base fp32 isolates the
        # quantization error — softmax outputs must track the uniform fp32
        # reference within the documented tolerance
        B = 3
        bq = cfgq.replace(batch=B)
        mplan = plan_network_fused(bq, policy="mixed")
        xq = jax.random.normal(jax.random.PRNGKey(B), input_shape(bq),
                               jnp.float32)
        ym, _ = forward_fused(params, xq, bq, mplan, impl=impl)
        ye, _ = forward_fused(params, xq, bq, plan_network_fused(bq),
                              impl=impl)
        mdiff = float(jnp.abs(ym - ye).max())
        emit(f"serve/{name}/mixed_numerics", 0.0,
             f"impl={impl};conv_dtypes={mplan.dtype_signature};"
             f"maxdiff={mdiff:.2e};tol={INT8_FORWARD_ATOL};"
             f"ok={mdiff <= INT8_FORWARD_ATOL}")
        record(f"serve/{name}/mixed_numerics", network=name,
               dtype="float32", policy="mixed", impl=impl,
               dtype_signature=mplan.dtype_signature)

        # (d) resilience (ISSUE 9 / DESIGN.md §14): the same serving stack
        # under seeded fault injection — a kernel-fault rate on every rung —
        # must serve 100% of the stream by degrading down the ladder and
        # re-queueing fully-failed batches.  ``dropped_requests`` is an
        # exact-zero trajectory counter (check_trajectory COUNT_FIELDS).
        from repro.launch.cnn_serve import CNNServer, ImageRequest
        from repro.runtime.resilience import FaultInjector
        srv = CNNServer(name, max_bucket=8, impl="xla",
                        calibration="analytic",
                        injector=FaultInjector(seed=0,
                                               rates={"kernel": 0.5}))
        rng = np.random.default_rng(0)
        c, h = srv.cfg.in_channels, srv.cfg.image_hw
        reqs = [ImageRequest(i, rng.standard_normal(
            (c, h, h)).astype(np.float32)) for i in range(20)]
        done = srv.run(reqs)
        dropped = len(reqs) - len(done)
        counts = srv.incidents.counts
        emit(f"serve/{name}/resilience", 0.0,
             f"incidents={srv.incidents.total};"
             f"kernel_faults={counts.get('kernel_fault', 0)};"
             f"requeues={counts.get('requeue', 0)};"
             f"dropped_requests={dropped};ok={dropped == 0}")
        record(f"serve/{name}/resilience", network=name, dtype="float32",
               impl="xla", incidents=srv.incidents.total,
               dropped_requests=dropped)

        # (e) multi-chip weak scaling (ISSUE 10 / DESIGN.md §15): a global
        # batch of B0*D sharded over D chips keeps the per-shard bucket at
        # B0, so every scale point executes the SAME per-shard plan —
        # modeled per-chip HBM bytes are exactly flat while modeled img/s
        # scales linearly with D.  Rows are planner arithmetic only (no
        # device execution), so a 1-device CI host regenerates them
        # byte-identically; the sharded-vs-unsharded numerics live in
        # tests/test_cnn_mesh.py under forced host devices.
        from repro.distributed.cnn_mesh import (shard_batch_for, shard_flip,
                                                verify_shard_plan)
        B0 = 16
        scache = PlanCache(thresholds=th)
        ips0 = pcb0 = None
        for D in (1, 2, 4, 8):
            g = B0 * D
            plan, bkt, _ = scache.fused_plan(cfg0, g, devices=D)
            assert bkt == shard_batch_for(g, D) == B0
            # roofline check: the cached plan IS the shard-batch plan
            verify_shard_plan(plan, cfg0, bkt)
            ips = bkt * D / plan.total_s
            ips0 = ips if ips0 is None else ips0
            pcb0 = plan.fused_bytes if pcb0 is None else pcb0
            flat = abs(plan.fused_bytes - pcb0) <= 0.05 * pcb0
            emit(f"serve/{name}/scale/d{D}", 0.0,
                 f"devices={D};global_batch={g};shard_bucket={bkt};"
                 f"conv_layouts={plan.conv_signature};"
                 f"per_chip_MB={plan.fused_bytes / 1e6:.1f};"
                 f"img_s_modeled={ips:.1f};speedup={ips / ips0:.2f};"
                 f"planner_calls={scache.planner_calls};"
                 f"per_chip_flat={flat};ok={flat and ips >= ips0}")
            record(f"serve/{name}/scale/d{D}", network=name,
                   dtype="float32", bucket=bkt, devices=D,
                   conv_layouts=plan.conv_signature,
                   per_chip_bytes=plan.fused_bytes,
                   modeled_bytes=plan.fused_bytes * D,
                   img_s_modeled=ips, planner_calls=scache.planner_calls)
        # one plan per (shard bucket, devices) key: a re-admitted global
        # batch at the same D must hit, never replan
        before = scache.planner_calls
        _, _, hit = scache.fused_plan(cfg0, B0 * 8, devices=8)
        emit(f"serve/{name}/scale/replan", 0.0,
             f"planner_calls={scache.planner_calls};hit={hit};"
             f"replans_repeat={scache.planner_calls - before}")

        # where sharding itself flips the layout: per-shard N under a fixed
        # global batch drops below the calibrated Nt threshold
        gsig, ssig = shard_flip(cfg0, 128, 8)
        emit(f"serve/{name}/scale/flip", 0.0,
             f"global_batch=128;devices=8;global_sig={gsig};"
             f"shard_sig={ssig};shard_flip={gsig != ssig}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--dtype", default="bf16",
                    choices=["float32", "fp32", "bfloat16", "bf16"],
                    help="reduced-precision path compared against the fp32 "
                         "baseline (float32: baseline only)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, dtype=args.dtype)
